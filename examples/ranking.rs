//! Learning-to-rank with `rank:pairwise` over query groups — the fourth
//! task family the paper's §1 claims ("regression, classification,
//! multiclass classification, and ranking"), with gradients computed on
//! the host per §2.5.
//!
//! ```text
//! cargo run --release --example ranking [-- --rows 20000 --rounds 30]
//! ```

use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::gbm::{Learner, MetricKind, ObjectiveKind};
use xgb_tpu::util::ArgParser;

fn main() -> anyhow::Result<()> {
    let args = ArgParser::from_env();
    let rows: usize = args.get_parse("rows", 20_000);
    let rounds: usize = args.get_parse("rounds", 30);

    let data = generate(&DatasetSpec::ranking_like(rows), 3);
    println!(
        "webrank-like: {} docs in {} queries ({} valid docs / {} queries)",
        data.train.n_rows(),
        data.train.groups.len().saturating_sub(1),
        data.valid.n_rows(),
        data.valid.groups.len().saturating_sub(1),
    );

    let mut learner = Learner::builder()
        .objective(ObjectiveKind::RankPairwise)
        .num_rounds(rounds)
        .eta(0.1)
        .max_depth(6)
        .max_bins(64)
        .eval_metric(MetricKind::Ndcg)
        .eval_every(3)
        .build()?;
    let booster = learner.train(&data.train, Some(&data.valid))?;

    println!("\nround  train-ndcg  valid-ndcg");
    for rec in &booster.eval_history {
        println!(
            "{:>5}  {:>10.4}  {:>10.4}",
            rec.round,
            rec.train,
            rec.valid.unwrap_or(f64::NAN)
        );
    }
    let h = &booster.eval_history;
    println!(
        "\nndcg@10 improved {:.4} -> {:.4} over {} rounds ({:.2}s)",
        h.first().unwrap().valid.unwrap_or(0.0),
        h.last().unwrap().valid.unwrap_or(0.0),
        booster.n_rounds(),
        booster.train_secs
    );

    // show the top of one query's ranking
    let g = &data.valid.groups;
    if g.len() > 1 {
        let (lo, hi) = (g[0], g[1]);
        let scores = booster.predict(&data.valid.x);
        let mut order: Vec<usize> = (lo..hi).collect();
        order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
        println!("\nquery 0 ranking (score, relevance):");
        for &d in order.iter().take(5) {
            println!("  {:>8.4}  rel={}", scores[d], data.valid.y[d]);
        }
    }
    Ok(())
}
