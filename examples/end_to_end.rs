//! End-to-end driver: proves the full three-layer stack composes on a
//! real workload (the validation run recorded in EXPERIMENTS.md §E2E).
//!
//! Phase A — full-scale training: several hundred boosting rounds on a
//!   Higgs-like dataset via the multi-device coordinator (Algorithm 1)
//!   with compression + ring all-reduce; logs the accuracy/logloss curve.
//!
//! Phase B — AOT pipeline: the same system with every device-resident
//!   stage of Figure 1 executed through the AOT-compiled XLA artifacts:
//!   gradients (grad_logistic.hlo.txt, §2.5), histograms (the Pallas
//!   one-hot-matmul kernel, §2.3), prediction (predict.hlo.txt, §2.4) —
//!   Python nowhere on the path — and cross-checks every stage against
//!   the native implementations.
//!
//! ```text
//! make artifacts && cargo run --release --example end_to_end
//!   [-- --rows 40000 --rounds 200 --xla-rounds 3]
//! ```

use std::sync::Arc;

use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::gbm::{Learner, MetricKind, ObjectiveKind};
use xgb_tpu::runtime::{Artifacts, GradKind, XlaHistBackend, XlaPredictor};
use xgb_tpu::util::ArgParser;

fn main() -> anyhow::Result<()> {
    let args = ArgParser::from_env();
    let rows: usize = args.get_parse("rows", 40_000);
    let rounds: usize = args.get_parse("rounds", 200);
    let xla_rows: usize = args.get_parse("xla-rows", 4_000);
    let xla_rounds: usize = args.get_parse("xla-rounds", 3);

    // ---------------------------------------------------- Phase A: train
    println!("=== Phase A: full training run (native backend) ===");
    let data = generate(&DatasetSpec::higgs_like(rows), 7);
    println!(
        "dataset: higgs-like, {} train / {} valid rows, {} features",
        data.train.n_rows(),
        data.valid.n_rows(),
        data.train.n_cols()
    );
    let mut learner = Learner::builder()
        .objective(ObjectiveKind::BinaryLogistic)
        .num_rounds(rounds)
        .eta(0.1)
        .max_depth(6)
        .max_bins(256)
        .n_devices(8)
        .compress(true)
        .eval_metric(MetricKind::LogLoss)
        .eval_every(10)
        .build()?;
    let booster = learner.train(&data.train, Some(&data.valid))?;
    println!("\nround  train-logloss  valid-logloss");
    for rec in &booster.eval_history {
        println!(
            "{:>5}  {:>13.5}  {:>13.5}",
            rec.round,
            rec.train,
            rec.valid.unwrap_or(f64::NAN)
        );
    }
    let acc = booster.evaluate(&data.valid, "accuracy")?;
    let auc = booster.evaluate(&data.valid, "auc")?;
    println!(
        "\n{} rounds in {:.2}s wall; simulated 8-device clock {:.3}s",
        booster.n_rounds(),
        booster.train_secs,
        booster.simulated_secs
    );
    println!("valid accuracy {acc:.3}%, auc {auc:.4}");
    let curve_ok = {
        let h = &booster.eval_history;
        h.last().unwrap().valid.unwrap() < h.first().unwrap().valid.unwrap()
    };
    assert!(curve_ok, "validation logloss must decrease over training");

    // ------------------------------------------------- Phase B: XLA path
    println!("\n=== Phase B: AOT artifact pipeline (PJRT, no Python) ===");
    let artifacts = Arc::new(Artifacts::discover()?);
    println!("PJRT platform: {}", artifacts.platform());

    // B1: §2.5 gradients through grad_logistic.hlo.txt vs native
    let margins = booster.predict_margins(&data.valid.x).remove(0);
    let (g_xla, h_xla) =
        artifacts.gradients(GradKind::Logistic, &margins, &data.valid.y)?;
    let mut max_err = 0.0f32;
    for i in 0..margins.len() {
        let p = 1.0 / (1.0 + (-margins[i]).exp());
        max_err = max_err
            .max((g_xla[i] - (p - data.valid.y[i])).abs())
            .max((h_xla[i] - p * (1.0 - p)).abs());
    }
    println!("B1 gradients: {} rows through XLA, max |err| vs eq.(1-2) = {max_err:.2e}", margins.len());
    assert!(max_err < 1e-4);

    // B2: §2.4 prediction through predict.hlo.txt vs native traversal
    let predictor = XlaPredictor::new(artifacts.clone());
    let native_margins = booster.predict_margins(&data.valid.x).remove(0);
    let xla_margins =
        predictor.predict_margins(&booster.trees[0], booster.base_score[0], &data.valid.x)?;
    let mut pred_err = 0.0f32;
    for (n, x) in native_margins.iter().zip(xla_margins.iter()) {
        pred_err = pred_err.max((n - x).abs());
    }
    println!(
        "B2 prediction: {} trees x {} rows through XLA, max |margin err| = {pred_err:.2e}",
        booster.trees[0].len(),
        data.valid.n_rows()
    );
    assert!(pred_err < 1e-3);

    // B3: §2.3 histograms — train a model end-to-end with the Pallas
    // kernel artifact as the histogram engine, and compare quality with
    // the native engine on identical data/params.
    println!(
        "B3 training {xla_rounds} rounds on {xla_rows} rows with the XLA histogram backend \
         (interpret-mode Pallas; slow but bit-faithful)..."
    );
    let small = generate(&DatasetSpec::higgs_like(xla_rows), 11);
    let small_learner = || -> anyhow::Result<Learner> {
        Ok(Learner::builder()
            .objective(ObjectiveKind::BinaryLogistic)
            .num_rounds(xla_rounds)
            .max_bins(64)
            .max_depth(5)
            .eval_metric(MetricKind::LogLoss)
            .build()?)
    };
    let b_native = small_learner()?.train(&small.train, Some(&small.valid))?;
    let b_xla = small_learner()?.train_with_backend(
        &small.train,
        Some(&small.valid),
        Box::new(XlaHistBackend::new(artifacts.clone())),
    )?;
    let ll_native = b_native.eval_history.last().unwrap().valid.unwrap();
    let ll_xla = b_xla.eval_history.last().unwrap().valid.unwrap();
    println!(
        "B3 valid logloss: native={ll_native:.5} xla={ll_xla:.5} (delta {:.2e}); \
         xla wall {:.1}s",
        (ll_native - ll_xla).abs(),
        b_xla.train_secs
    );
    assert!((ll_native - ll_xla).abs() < 5e-3, "XLA training must match native");

    let counts = artifacts.exec_counts.borrow();
    println!(
        "artifact executions: grad_logistic={} grad_squared={} histogram={} predict={}",
        counts[0], counts[1], counts[2], counts[3]
    );
    println!("\nEND-TO-END OK: all three layers compose.");
    Ok(())
}
