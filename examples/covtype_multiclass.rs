//! Multiclass training on the Cover-Type-like dataset (7 classes) —
//! exercises the CPU-side softmax objective (paper §2.5: multiclass
//! gradients are computed on the host) with one tree per class per round.
//!
//! ```text
//! cargo run --release --example covtype_multiclass [-- --rows 30000 --rounds 20]
//! ```

use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::gbm::{Learner, MetricKind, ObjectiveKind};
use xgb_tpu::util::ArgParser;

fn main() -> anyhow::Result<()> {
    let args = ArgParser::from_env();
    let rows: usize = args.get_parse("rows", 30_000);
    let rounds: usize = args.get_parse("rounds", 20);

    let data = generate(&DatasetSpec::covtype_like(rows), 5);
    println!(
        "covtype-like: {} train rows, {} features, 7 classes",
        data.train.n_rows(),
        data.train.n_cols()
    );

    let mut learner = Learner::builder()
        .objective(ObjectiveKind::MultiSoftmax)
        .num_class(7)
        .num_rounds(rounds)
        .eta(0.3)
        .max_depth(6)
        .max_bins(64)
        .n_devices(2)
        .eval_metric(MetricKind::Accuracy)
        .eval_every(2)
        .build()?;
    let booster = learner.train(&data.train, Some(&data.valid))?;

    println!("\nround  train-acc  valid-acc");
    for rec in &booster.eval_history {
        println!(
            "{:>5}  {:>9.3}  {:>9.3}",
            rec.round,
            rec.train,
            rec.valid.unwrap_or(f64::NAN)
        );
    }
    println!(
        "\n{} rounds x 7 classes = {} trees in {:.2}s",
        booster.n_rounds(),
        booster.trees.iter().map(|t| t.len()).sum::<usize>(),
        booster.train_secs
    );
    println!(
        "valid merror = {:.3}%",
        booster.evaluate(&data.valid, "merror")?
    );

    // per-class confusion summary
    let preds = booster.predict(&data.valid.x);
    let mut confusion = [[0usize; 7]; 7];
    for (p, &y) in preds.iter().zip(data.valid.y.iter()) {
        confusion[y as usize][*p as usize] += 1;
    }
    println!("\nconfusion (rows = truth):");
    for (c, row) in confusion.iter().enumerate() {
        let total: usize = row.iter().sum();
        if total > 0 {
            println!(
                "  class {c}: {:?} (recall {:.1}%)",
                row,
                100.0 * row[c] as f64 / total as f64
            );
        }
    }
    Ok(())
}
