//! Regenerate Table 1 of the paper: the dataset registry with measured
//! shape / sparsity / quantisation statistics of the synthetic stand-ins.
//!
//! ```text
//! cargo run --release --example datasets_table [-- --scale 0.002]
//! ```

use xgb_tpu::bench::Table;
use xgb_tpu::compress::CompressedMatrix;
use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::quantile::{HistogramCuts, Quantizer};
use xgb_tpu::util::ArgParser;

fn main() -> anyhow::Result<()> {
    let args = ArgParser::from_env();
    let scale: f64 = args.get_parse("scale", 0.002);
    let max_bins: usize = args.get_parse("max-bins", 256);

    let mut table = Table::new(&[
        "Name", "Rows(paper)", "Rows(run)", "Columns", "Task", "Density",
        "Bins", "Sym bits", "vs f32", "vs csr-entry",
    ]);
    for spec in DatasetSpec::table1(scale) {
        let paper_rows = match spec.name {
            "YearPredictionMSD" => 515_000usize,
            "Synthetic" => 10_000_000,
            "Higgs" => 11_000_000,
            "Cover Type" => 581_000,
            "Bosch" => 1_000_000,
            "Airline" => 115_000_000,
            _ => 0,
        };
        let g = generate(&spec, 42);
        let cuts = HistogramCuts::from_dmatrix(&g.train.x, max_bins, None);
        let qm = Quantizer::new(cuts.clone()).quantize(&g.train.x);
        let cm = CompressedMatrix::from_quantized(&qm);
        table.add_row(vec![
            spec.name.to_string(),
            format!("{paper_rows}"),
            format!("{}", g.train.n_rows() + g.valid.n_rows()),
            format!("{}", spec.cols),
            format!("{:?}", spec.task),
            format!("{:.2}", g.train.x.density()),
            format!("{}", cuts.total_bins()),
            format!("{}", cm.symbol_bits),
            format!("{:.2}x", cm.ratio_vs_float()),
            format!("{:.2}x", cm.ratio_vs_csr_entry()),
        ]);
    }
    println!("Table 1 (synthetic stand-ins at scale {scale}; DESIGN.md §2):\n");
    print!("{}", table.render());
    Ok(())
}
