//! Figure 2 reproduction: runtime on the Airline dataset for 1–8 devices.
//!
//! The paper's Figure 2 shows XGBoost's end-to-end runtime on the 115M-row
//! airline dataset falling from 1 to 8 V100s. Here each device's shard
//! compute is *measured* and the ring all-reduce is priced by the
//! calibrated α–β cost model (DESIGN.md §5) — see `benches/fig2_scaling.rs`
//! for the paper-format series; this example is the interactive version.
//!
//! ```text
//! cargo run --release --example airline_scaling [-- --rows 200000 --rounds 20]
//! ```

use xgb_tpu::bench::Table;
use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::gbm::{Learner, ObjectiveKind};
use xgb_tpu::util::ArgParser;

fn main() -> anyhow::Result<()> {
    let args = ArgParser::from_env();
    let rows: usize = args.get_parse("rows", 200_000);
    let rounds: usize = args.get_parse("rounds", 20);
    let max_p: usize = args.get_parse("max-devices", 8);

    let data = generate(&DatasetSpec::airline_like(rows), 1);
    println!(
        "airline-like: {} rows x {} cols ({}x smaller than the paper's 115M)",
        data.train.n_rows(),
        data.train.n_cols(),
        115_000_000 / rows.max(1)
    );

    let mut table = Table::new(&[
        "devices", "simulated time (s)", "speedup", "hist max/dev (s)", "comm (s)",
        "MB/device",
    ]);
    let mut t1 = 0.0f64;
    for p in 1..=max_p {
        let mut learner = Learner::builder()
            .objective(ObjectiveKind::BinaryLogistic)
            .num_rounds(rounds)
            .max_bins(256)
            .max_depth(6)
            .n_devices(p)
            .compress(true)
            .eval_every(0)
            .build()?;
        let booster = learner.train(&data.train, None)?;
        let sim = booster.simulated_secs;
        if p == 1 {
            t1 = sim;
        }
        let s = &booster.build_stats;
        table.add_row(vec![
            format!("{p}"),
            format!("{sim:.3}"),
            format!("{:.2}x", t1 / sim),
            format!("{:.3}", s.hist_secs.iter().cloned().fold(0.0, f64::max)),
            format!("{:.4}", s.allreduce_sim_secs),
            format!("{:.1}", s.comm_bytes_per_device as f64 / 1e6),
        ]);
        eprintln!("p={p}: simulated {sim:.3}s");
    }
    println!("\nFigure 2 (simulated multi-device clock, DESIGN.md §5):\n");
    print!("{}", table.render());
    println!(
        "\npaper shape check: runtime should fall with p until the per-round\n\
         all-reduce cost (constant in p for large histograms) catches the\n\
         shrinking per-device histogram work."
    );
    Ok(())
}
