//! Quickstart: train a binary classifier on a Higgs-like dataset through
//! the typed `Learner` API — builder-validated parameters, a training
//! callback, and registry-resolved metrics.
//!
//! ```text
//! cargo run --release --example quickstart [-- --rows 50000 --rounds 50 --devices 4]
//! ```

use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::gbm::{EarlyStopping, Learner, MetricKind, ObjectiveKind};
use xgb_tpu::util::ArgParser;

fn main() -> anyhow::Result<()> {
    let args = ArgParser::from_env();
    let rows: usize = args.get_parse("rows", 50_000);
    let rounds: usize = args.get_parse("rounds", 50);
    let devices: usize = args.get_parse("devices", 4);

    // 1. generate a dataset shaped like the paper's HIGGS (Table 1)
    let data = generate(&DatasetSpec::higgs_like(rows), 42);
    println!(
        "dataset: {} ({} train / {} valid rows, {} features)",
        data.spec.name,
        data.train.n_rows(),
        data.valid.n_rows(),
        data.train.n_cols()
    );

    // 2. configure the learner — typed enums instead of strings, and
    //    `build()` validates the whole cross-field matrix up front,
    //    reporting every problem at once
    let mut learner = Learner::builder()
        .objective(ObjectiveKind::BinaryLogistic)
        .eval_metric(MetricKind::Accuracy)
        .num_rounds(rounds)
        .eta(0.3)
        .max_depth(6)
        .max_bins(256)
        .n_devices(devices) // simulated GPUs (Algorithm 1)
        .compress(true) // §2.2 bit-packed shards
        .eval_every(5)
        // stop when validation accuracy stalls for 4 evaluations
        .callback(Box::new(EarlyStopping::new(4)))
        .build()?;

    // 3. train
    let booster = learner.train(&data.train, Some(&data.valid))?;

    // 4. inspect
    println!("\nround  train-acc  valid-acc");
    for rec in &booster.eval_history {
        println!(
            "{:>5}  {:>9.3}  {:>9.3}",
            rec.round,
            rec.train,
            rec.valid.unwrap_or(f64::NAN)
        );
    }
    println!(
        "\ntrained {} trees in {:.2}s wall ({:.3}s simulated on {} devices)",
        booster.n_rounds(),
        booster.train_secs,
        booster.simulated_secs,
        devices
    );
    println!("auc = {:.4}", booster.evaluate(&data.valid, "auc")?);

    // 5. predict on fresh rows
    let preds = booster.predict(&data.valid.x);
    println!("first predictions: {:?}", &preds[..5.min(preds.len())]);
    Ok(())
}
