#!/usr/bin/env bash
# Tier-1 CI gate: formatting, lints, build, tests.
#
#   ./ci.sh          # run everything
#   ./ci.sh --fast   # skip fmt/clippy (build + test only)
#
# The build is fully offline (anyhow is vendored under rust/vendor/; the
# PJRT runtime is feature-gated), so no network or crates.io mirror is
# required.

set -euo pipefail
cd "$(dirname "$0")"

FAST=0
[[ "${1:-}" == "--fast" ]] && FAST=1

if [[ "$FAST" -eq 0 ]]; then
    echo "==> cargo fmt --check"
    cargo fmt --check

    echo "==> cargo clippy --all-targets -- -D warnings"
    cargo clippy --all-targets -- -D warnings
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "CI OK"
