#!/usr/bin/env bash
# Tier-1 CI gate: formatting, lints, build, tests.
#
#   ./ci.sh          # run everything
#   ./ci.sh --fast   # skip fmt/clippy (build + test only)
#
# The build is fully offline (anyhow is vendored under rust/vendor/; the
# PJRT runtime is feature-gated), so no network or crates.io mirror is
# required.

set -euo pipefail
cd "$(dirname "$0")"

FAST=0
[[ "${1:-}" == "--fast" ]] && FAST=1

if [[ "$FAST" -eq 0 ]]; then
    echo "==> cargo fmt --check"
    cargo fmt --check

    echo "==> cargo clippy --all-targets -- -D warnings"
    cargo clippy --all-targets -- -D warnings
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q (debug)"
cargo test -q

echo "==> cargo test -q --release"
cargo test -q --release

# Thread-sweep smoke: exercise the real parallel engine end-to-end from
# the CLI at several budgets (results must agree; these runs just have to
# succeed — the bit-identity contract is enforced by the test suite).
echo "==> threads-sweep smoke (CLI)"
for t in 1 2 4; do
    echo "--- xgb-tpu train --threads $t"
    ./target/release/xgb-tpu train --dataset higgs --rows 4000 \
        --num-rounds 3 --max-bins 32 --n-devices 2 --threads "$t"
done

# Streaming-ingest smoke: train from a generated LibSVM file through the
# out-of-core pipeline (--stream --batch-rows 32) and require the exact
# same final eval metric as the in-memory run over the same file
# (--valid-frac 0 keeps the file's row order, so the two are comparable
# bit-for-bit).
echo "==> streaming-ingest smoke (CLI)"
SMOKE_DIR=$(mktemp -d)
trap 'rm -rf "$SMOKE_DIR"' EXIT
./target/release/xgb-tpu export --dataset higgs --rows 3000 \
    --format libsvm --out "$SMOKE_DIR/higgs.libsvm"
SMOKE_FLAGS=(--libsvm "$SMOKE_DIR/higgs.libsvm" --objective binary:logistic
             --num-rounds 3 --max-bins 32 --n-devices 2 --valid-frac 0)
# `|| true`: a crashed run (no `final:` line) must reach the explicit
# mismatch check below instead of silently aborting via set -e/pipefail
MEM_FINAL=$(./target/release/xgb-tpu train "${SMOKE_FLAGS[@]}" 2>/dev/null \
    | grep '^final:' || true)
STREAM_FINAL=$(./target/release/xgb-tpu train "${SMOKE_FLAGS[@]}" \
    --stream --batch-rows 32 2>/dev/null | grep '^final:' || true)
echo "in-memory: $MEM_FINAL"
echo "streaming: $STREAM_FINAL"
if [[ -z "$MEM_FINAL" || "$MEM_FINAL" != "$STREAM_FINAL" ]]; then
    echo "FAIL: streaming eval metric does not match the in-memory run"
    exit 1
fi

# External-memory smoke: the same file trained fully resident vs with a
# 2-page residency budget (pages spilled to disk, prefetched back per
# histogram round) must produce the exact same final metric. TMPDIR is
# pointed inside SMOKE_DIR so any spill files a crashed run leaves behind
# are swept by the trap above (normal runs delete them on drop).
echo "==> external-memory smoke (CLI)"
PAGED_TMP="$SMOKE_DIR/spill"
mkdir -p "$PAGED_TMP"
PAGED_FINAL=$(TMPDIR="$PAGED_TMP" ./target/release/xgb-tpu train "${SMOKE_FLAGS[@]}" \
    --max-resident-pages 2 --page-rows 256 2>/dev/null | grep '^final:' || true)
echo "resident:  $MEM_FINAL"
echo "paged:     $PAGED_FINAL"
if [[ -z "$PAGED_FINAL" || "$MEM_FINAL" != "$PAGED_FINAL" ]]; then
    echo "FAIL: paged eval metric does not match the fully resident run"
    exit 1
fi
LEFTOVER=$(find "$PAGED_TMP" -name '*.pages' | wc -l)
if [[ "$LEFTOVER" -ne 0 ]]; then
    echo "FAIL: $LEFTOVER spill page file(s) left behind after training"
    exit 1
fi

# Compressed-prediction smoke: train once, then score the same file
# through the float path, the streaming-quantised path (--stream) and
# the external-memory path (--max-resident-pages 2). Every path prints a
# `predictions: n=... checksum=...` fingerprint over the raw prediction
# bits — all three must be byte-identical. The eval subcommand must
# agree between the float and streamed paths too.
echo "==> compressed-prediction smoke (CLI)"
MODEL="$SMOKE_DIR/model.txt"
./target/release/xgb-tpu train "${SMOKE_FLAGS[@]}" --model-out "$MODEL" >/dev/null 2>&1
PRED_ARGS=(predict --model "$MODEL" --libsvm "$SMOKE_DIR/higgs.libsvm" --out /dev/null)
# `|| true`: a crashed run (no checksum line) must reach the explicit
# mismatch check below instead of aborting via set -e/pipefail
SUM_FLOAT=$(./target/release/xgb-tpu "${PRED_ARGS[@]}" 2>&1 >/dev/null \
    | grep '^predictions:' || true)
SUM_STREAM=$(./target/release/xgb-tpu "${PRED_ARGS[@]}" --stream --batch-rows 64 2>&1 >/dev/null \
    | grep '^predictions:' || true)
SUM_PAGED=$(TMPDIR="$PAGED_TMP" ./target/release/xgb-tpu "${PRED_ARGS[@]}" \
    --max-resident-pages 2 --page-rows 256 2>&1 >/dev/null \
    | grep '^predictions:' || true)
echo "float:  $SUM_FLOAT"
echo "stream: $SUM_STREAM"
echo "paged:  $SUM_PAGED"
if [[ -z "$SUM_FLOAT" || "$SUM_FLOAT" != "$SUM_STREAM" || "$SUM_FLOAT" != "$SUM_PAGED" ]]; then
    echo "FAIL: prediction checksums differ across the float/stream/paged paths"
    exit 1
fi
LEFTOVER=$(find "$PAGED_TMP" -name '*.pages' | wc -l)
if [[ "$LEFTOVER" -ne 0 ]]; then
    echo "FAIL: $LEFTOVER spill page file(s) left behind after paged prediction"
    exit 1
fi
EVAL_FLOAT=$(./target/release/xgb-tpu eval --model "$MODEL" \
    --libsvm "$SMOKE_DIR/higgs.libsvm" 2>/dev/null | grep '^eval' || true)
EVAL_STREAM=$(./target/release/xgb-tpu eval --model "$MODEL" \
    --libsvm "$SMOKE_DIR/higgs.libsvm" --stream --batch-rows 64 2>/dev/null \
    | grep '^eval' || true)
echo "eval float:  $EVAL_FLOAT"
echo "eval stream: $EVAL_STREAM"
if [[ -z "$EVAL_FLOAT" || "$EVAL_FLOAT" != "$EVAL_STREAM" ]]; then
    echo "FAIL: eval metric differs between the float and streamed paths"
    exit 1
fi

# Kernel-mode smoke: the blocked hot-loop kernels (default) and the
# scalar reference loops (XGB_SCALAR_KERNELS=1) must produce byte-
# identical training metrics and prediction checksums — the CLI-level
# pin of the bit-parity contract the kernel property tests enforce
# in-process.
echo "==> kernel-mode smoke (CLI)"
SCALAR_FINAL=$(XGB_SCALAR_KERNELS=1 ./target/release/xgb-tpu train \
    "${SMOKE_FLAGS[@]}" 2>/dev/null | grep '^final:' || true)
echo "blocked: $MEM_FINAL"
echo "scalar:  $SCALAR_FINAL"
if [[ -z "$SCALAR_FINAL" || "$MEM_FINAL" != "$SCALAR_FINAL" ]]; then
    echo "FAIL: scalar-kernel training metric does not match the blocked kernels"
    exit 1
fi
SUM_SCALAR=$(XGB_SCALAR_KERNELS=1 ./target/release/xgb-tpu "${PRED_ARGS[@]}" \
    --stream --batch-rows 64 2>&1 >/dev/null | grep '^predictions:' || true)
echo "blocked: $SUM_FLOAT"
echo "scalar:  $SUM_SCALAR"
if [[ -z "$SUM_SCALAR" || "$SUM_FLOAT" != "$SUM_SCALAR" ]]; then
    echo "FAIL: scalar-kernel prediction checksum does not match the blocked kernels"
    exit 1
fi

# Exec-mode smoke: the persistent parked worker pool (default) and the
# scoped spawn-per-call reference engine (XGB_SCOPED_EXEC=1) must produce
# byte-identical training metrics and prediction checksums — the CLI-
# level pin of the engine-parity contract the exec property tests
# enforce in-process.
echo "==> exec-mode smoke (CLI)"
SCOPED_FINAL=$(XGB_SCOPED_EXEC=1 ./target/release/xgb-tpu train \
    "${SMOKE_FLAGS[@]}" --threads 4 2>/dev/null | grep '^final:' || true)
POOL_FINAL=$(./target/release/xgb-tpu train \
    "${SMOKE_FLAGS[@]}" --threads 4 2>/dev/null | grep '^final:' || true)
echo "persistent: $POOL_FINAL"
echo "scoped:     $SCOPED_FINAL"
if [[ -z "$SCOPED_FINAL" || "$POOL_FINAL" != "$SCOPED_FINAL" ]]; then
    echo "FAIL: scoped-engine training metric does not match the persistent pool"
    exit 1
fi
if [[ -z "$MEM_FINAL" || "$MEM_FINAL" != "$POOL_FINAL" ]]; then
    echo "FAIL: threads=4 training metric does not match the default run"
    exit 1
fi
SUM_SCOPED=$(XGB_SCOPED_EXEC=1 ./target/release/xgb-tpu "${PRED_ARGS[@]}" \
    --stream --batch-rows 64 2>&1 >/dev/null | grep '^predictions:' || true)
echo "persistent: $SUM_FLOAT"
echo "scoped:     $SUM_SCOPED"
if [[ -z "$SUM_SCOPED" || "$SUM_FLOAT" != "$SUM_SCOPED" ]]; then
    echo "FAIL: scoped-engine prediction checksum does not match the persistent pool"
    exit 1
fi

# Serving smoke: pipe the same rows through `serve` over stdin (labels
# stripped, so requests are LibSVM-style sparse tokens with --col-base 1)
# and require the shutdown fingerprint line to byte-match `predict`'s
# checksum over the same file. Then rewrite the model file mid-stream and
# `!reload`: the ack must report the epoch flip, every request must still
# get exactly one response, and the stats line must count the swap.
echo "==> serve smoke (CLI)"
REQS="$SMOKE_DIR/requests.txt"
cut -d' ' -f2- "$SMOKE_DIR/higgs.libsvm" > "$REQS"
SERVE_OUT="$SMOKE_DIR/serve.out"
SERVE_ERR="$SMOKE_DIR/serve.err"
./target/release/xgb-tpu serve --model "$MODEL" --col-base 1 --batch-max 32 \
    < "$REQS" > "$SERVE_OUT" 2> "$SERVE_ERR"
SUM_SERVE=$(grep '^predictions:' "$SERVE_ERR" || true)
echo "float:  $SUM_FLOAT"
echo "serve:  $SUM_SERVE"
if [[ -z "$SUM_SERVE" || "$SUM_SERVE" != "$SUM_FLOAT" ]]; then
    echo "FAIL: served fingerprint does not byte-match predict's checksum line"
    exit 1
fi
if [[ "$(wc -l < "$SERVE_OUT")" -ne "$(wc -l < "$REQS")" ]]; then
    echo "FAIL: serve did not answer every request with exactly one line"
    exit 1
fi

echo "==> serve hot-swap smoke (CLI)"
MODEL2="$SMOKE_DIR/model2.txt"
TRAINLOG="$SMOKE_DIR/train_log.csv"
./target/release/xgb-tpu train --libsvm "$SMOKE_DIR/higgs.libsvm" \
    --objective binary:logistic --num-rounds 5 --max-bins 32 --n-devices 2 \
    --valid-frac 0 --model-out "$MODEL2" --log-file "$TRAINLOG" >/dev/null 2>&1
# --log-file telemetry rides along: header + one record per round
if [[ "$(wc -l < "$TRAINLOG")" -ne 6 ]]; then
    echo "FAIL: --log-file wrote $(wc -l < "$TRAINLOG") lines, expected 6 (header + 5 rounds)"
    exit 1
fi
SWAP_MODEL="$SMOKE_DIR/swap_model.txt"
cp "$MODEL" "$SWAP_MODEL"
SWAP_OUT="$SMOKE_DIR/swap.out"
SWAP_ERR="$SMOKE_DIR/swap.err"
# the brace group writes 200 requests, rewrites the model file on disk,
# then issues !reload — so the swap lands mid-stream, with the remaining
# requests served by the new epoch
{
    head -n 200 "$REQS"
    cp "$MODEL2" "$SWAP_MODEL"
    echo '!reload'
    tail -n +201 "$REQS"
} | ./target/release/xgb-tpu serve --model "$SWAP_MODEL" --col-base 1 \
    --batch-max 32 > "$SWAP_OUT" 2> "$SWAP_ERR"
if [[ "$(sed -n '201p' "$SWAP_OUT")" != "!ok epoch=2 swaps=1" ]]; then
    echo "FAIL: expected the reload ack '!ok epoch=2 swaps=1' at response 201, got:"
    sed -n '201p' "$SWAP_OUT"
    exit 1
fi
EXPECT_LINES=$(( $(wc -l < "$REQS") + 1 ))
if [[ "$(wc -l < "$SWAP_OUT")" -ne "$EXPECT_LINES" ]]; then
    echo "FAIL: hot-swap stream answered $(wc -l < "$SWAP_OUT") lines, expected $EXPECT_LINES"
    exit 1
fi
if ! grep -q 'swaps=1' "$SWAP_ERR"; then
    echo "FAIL: serve stats do not report the hot-swap"
    exit 1
fi

# Scenario smokes: the bit-identity contract extended to the scenario
# surface — new objectives, categorical features, and training
# continuation. Each pins two CLI runs (resident vs streamed, or
# split-vs-uninterrupted) to byte-identical saved model files, the
# strongest equality the CLI can observe.
echo "==> quantile-objective smoke (CLI, resident vs streamed byte-compare)"
QUANT_FLAGS=(--libsvm "$SMOKE_DIR/higgs.libsvm" --objective reg:quantile
             --quantile-alpha 0.9 --num-rounds 3 --max-bins 32 --n-devices 2
             --valid-frac 0)
QMODEL_RES="$SMOKE_DIR/quantile_resident.txt"
QMODEL_STR="$SMOKE_DIR/quantile_streamed.txt"
./target/release/xgb-tpu train "${QUANT_FLAGS[@]}" \
    --model-out "$QMODEL_RES" >/dev/null 2>&1
./target/release/xgb-tpu train "${QUANT_FLAGS[@]}" --stream --batch-rows 32 \
    --model-out "$QMODEL_STR" >/dev/null 2>&1
if ! cmp -s "$QMODEL_RES" "$QMODEL_STR"; then
    echo "FAIL: reg:quantile alpha=0.9 resident and streamed models differ"
    exit 1
fi
if ! grep -q '^quantile_alpha = 0.9' "$QMODEL_RES"; then
    echo "FAIL: quantile model file does not persist quantile_alpha = 0.9"
    exit 1
fi

echo "==> categorical-feature smoke (CLI, cat: header, resident vs streamed)"
CATCSV="$SMOKE_DIR/cat.csv"
{
    echo "cat:c0,f1,label"
    awk 'BEGIN {
        for (i = 0; i < 512; i++) {
            c = i % 7;
            y = (c == 1 || c == 4 || c == 6) ? 1 : 0;
            printf "%d,%.4f,%d\n", c, (i % 97) / 97.0, y;
        }
    }'
} > "$CATCSV"
CAT_FLAGS=(--csv "$CATCSV" --header --label-col 2 --objective binary:logistic
           --num-rounds 3 --max-bins 32 --n-devices 2 --valid-frac 0)
CMODEL_RES="$SMOKE_DIR/cat_resident.txt"
CMODEL_STR="$SMOKE_DIR/cat_streamed.txt"
./target/release/xgb-tpu train "${CAT_FLAGS[@]}" \
    --model-out "$CMODEL_RES" >/dev/null 2>&1
./target/release/xgb-tpu train "${CAT_FLAGS[@]}" --stream --batch-rows 32 \
    --model-out "$CMODEL_STR" >/dev/null 2>&1
if ! cmp -s "$CMODEL_RES" "$CMODEL_STR"; then
    echo "FAIL: categorical resident and streamed models differ"
    exit 1
fi
if ! grep -q '^cuts categorical = ' "$CMODEL_RES"; then
    echo "FAIL: categorical model file does not record the categorical feature set"
    exit 1
fi
if ! grep -q ' cat ' "$CMODEL_RES"; then
    echo "FAIL: categorical model contains no membership-split nodes"
    exit 1
fi

echo "==> training-continuation smoke (CLI, 5+resume-5 vs train-10 byte-compare)"
RES_FLAGS=(--libsvm "$SMOKE_DIR/higgs.libsvm" --objective binary:logistic
           --max-bins 32 --n-devices 2 --valid-frac 0)
RMODEL_FULL="$SMOKE_DIR/resume_full10.txt"
RMODEL_HALF="$SMOKE_DIR/resume_half5.txt"
RMODEL_CONT="$SMOKE_DIR/resume_cont10.txt"
./target/release/xgb-tpu train "${RES_FLAGS[@]}" --num-rounds 10 \
    --model-out "$RMODEL_FULL" >/dev/null 2>&1
./target/release/xgb-tpu train "${RES_FLAGS[@]}" --num-rounds 5 \
    --model-out "$RMODEL_HALF" >/dev/null 2>&1
./target/release/xgb-tpu train "${RES_FLAGS[@]}" --num-rounds 5 \
    --resume "$RMODEL_HALF" --model-out "$RMODEL_CONT" >/dev/null 2>&1
if ! cmp -s "$RMODEL_FULL" "$RMODEL_CONT"; then
    echo "FAIL: train(5)+resume(5) model does not byte-match train(10)"
    exit 1
fi

# Distributed smoke: train the same file as 3 real OS processes over a
# loopback TCP ring (ranks 1 and 2 in the background, rank 0 in the
# foreground) and require rank 0's `final:` line AND its saved model's
# streamed-predict checksum to byte-match a single-process --n-devices 3
# run — the CLI-level pin of the wire ring's bit-identity contract. The
# port base is randomised so parallel CI runs don't collide.
echo "==> distributed-training smoke (CLI, 3 processes over loopback)"
BASE_PORT=$(( 20000 + RANDOM % 20000 ))
PEERS="127.0.0.1:$BASE_PORT,127.0.0.1:$((BASE_PORT+1)),127.0.0.1:$((BASE_PORT+2))"
DIST_FLAGS=(--libsvm "$SMOKE_DIR/higgs.libsvm" --objective binary:logistic
            --num-rounds 3 --max-bins 32 --valid-frac 0 --n-devices 3)
MODEL3="$SMOKE_DIR/model3.txt"
REF3_FINAL=$(./target/release/xgb-tpu train "${DIST_FLAGS[@]}" \
    --model-out "$MODEL3" 2>/dev/null | grep '^final:' || true)
DIST_MODEL="$SMOKE_DIR/model_dist.txt"
./target/release/xgb-tpu train "${DIST_FLAGS[@]}" --dist-rank 1 \
    --dist-peers "$PEERS" > "$SMOKE_DIR/rank1.log" 2>&1 &
W1=$!
./target/release/xgb-tpu train "${DIST_FLAGS[@]}" --dist-rank 2 \
    --dist-peers "$PEERS" > "$SMOKE_DIR/rank2.log" 2>&1 &
W2=$!
# widen the trap while workers run so a failed rank 0 can't orphan them
trap 'kill "$W1" "$W2" 2>/dev/null || true; rm -rf "$SMOKE_DIR"' EXIT
DIST_FINAL=$(./target/release/xgb-tpu train "${DIST_FLAGS[@]}" --dist-rank 0 \
    --dist-peers "$PEERS" --model-out "$DIST_MODEL" 2>/dev/null \
    | grep '^final:' || true)
WORKERS_OK=1
wait "$W1" || WORKERS_OK=0
wait "$W2" || WORKERS_OK=0
trap 'rm -rf "$SMOKE_DIR"' EXIT
echo "single-process: $REF3_FINAL"
echo "distributed:    $DIST_FINAL"
if [[ "$WORKERS_OK" -ne 1 ]]; then
    echo "FAIL: a distributed worker rank exited nonzero"
    tail -n 5 "$SMOKE_DIR"/rank*.log
    exit 1
fi
if [[ -z "$DIST_FINAL" || "$REF3_FINAL" != "$DIST_FINAL" ]]; then
    echo "FAIL: distributed final metric does not byte-match the single-process run"
    exit 1
fi
SUM_REF3=$(./target/release/xgb-tpu predict --model "$MODEL3" \
    --libsvm "$SMOKE_DIR/higgs.libsvm" --out /dev/null --stream --batch-rows 64 \
    2>&1 >/dev/null | grep '^predictions:' || true)
SUM_DIST=$(./target/release/xgb-tpu predict --model "$DIST_MODEL" \
    --libsvm "$SMOKE_DIR/higgs.libsvm" --out /dev/null --stream --batch-rows 64 \
    2>&1 >/dev/null | grep '^predictions:' || true)
echo "single-process: $SUM_REF3"
echo "distributed:    $SUM_DIST"
if [[ -z "$SUM_DIST" || "$SUM_REF3" != "$SUM_DIST" ]]; then
    echo "FAIL: distributed model's streamed-predict checksum does not match single-process"
    exit 1
fi
# no orphan worker processes, no lingering ring sockets
ORPHANS=$(pgrep -f "xgb-tpu train.*--dist-rank" | wc -l || true)
if [[ "$ORPHANS" -ne 0 ]]; then
    echo "FAIL: $ORPHANS orphan distributed worker process(es) left running"
    pkill -f "xgb-tpu train.*--dist-rank" || true
    exit 1
fi
for port in "$BASE_PORT" "$((BASE_PORT+1))" "$((BASE_PORT+2))"; do
    if (exec 3<>"/dev/tcp/127.0.0.1/$port") 2>/dev/null; then
        echo "FAIL: port $port still accepting connections after the distributed smoke"
        exit 1
    fi
done

echo "CI OK"
