#!/usr/bin/env bash
# Tier-1 CI gate: formatting, lints, build, tests.
#
#   ./ci.sh          # run everything
#   ./ci.sh --fast   # skip fmt/clippy (build + test only)
#
# The build is fully offline (anyhow is vendored under rust/vendor/; the
# PJRT runtime is feature-gated), so no network or crates.io mirror is
# required.

set -euo pipefail
cd "$(dirname "$0")"

FAST=0
[[ "${1:-}" == "--fast" ]] && FAST=1

if [[ "$FAST" -eq 0 ]]; then
    echo "==> cargo fmt --check"
    cargo fmt --check

    echo "==> cargo clippy --all-targets -- -D warnings"
    cargo clippy --all-targets -- -D warnings
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q (debug)"
cargo test -q

echo "==> cargo test -q --release"
cargo test -q --release

# Thread-sweep smoke: exercise the real parallel engine end-to-end from
# the CLI at several budgets (results must agree; these runs just have to
# succeed — the bit-identity contract is enforced by the test suite).
echo "==> threads-sweep smoke (CLI)"
for t in 1 2 4; do
    echo "--- xgb-tpu train --threads $t"
    ./target/release/xgb-tpu train --dataset higgs --rows 4000 \
        --num-rounds 3 --max-bins 32 --n-devices 2 --threads "$t"
done

echo "CI OK"
