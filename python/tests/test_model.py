"""L2 model graphs vs oracles: gradients (paper eq. 1-2), the histogram
wrapper, and the array-encoded ensemble predictor vs a plain python
traversal."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import histogram as hk
from compile.kernels import ref


# --------------------------------------------------------------- gradients

@pytest.mark.parametrize("seed", range(3))
def test_logistic_gradients_match_ref(seed):
    rng = np.random.default_rng(seed)
    m = jnp.asarray(rng.normal(size=512).astype(np.float32) * 3)
    y = jnp.asarray((rng.random(512) < 0.5).astype(np.float32))
    g, h = model.logistic_gradients(m, y)
    rg, rh = ref.logistic_gradients_ref(m, y)
    np.testing.assert_allclose(np.asarray(g), np.asarray(rg), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(h), np.asarray(rh), rtol=1e-6, atol=1e-15)
    # hessian positivity (clamped)
    assert float(jnp.min(h)) > 0.0


def test_squared_gradients_match_ref():
    rng = np.random.default_rng(0)
    m = jnp.asarray(rng.normal(size=256).astype(np.float32))
    y = jnp.asarray(rng.normal(size=256).astype(np.float32))
    g, h = model.squared_gradients(m, y)
    rg, rh = ref.squared_gradients_ref(m, y)
    np.testing.assert_allclose(np.asarray(g), np.asarray(rg), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(h), np.asarray(rh))


def test_logistic_gradient_values_paper_eq():
    # at margin 0: p=0.5 -> g = 0.5 - y, h = 0.25
    g, h = model.logistic_gradients(jnp.zeros(2), jnp.asarray([0.0, 1.0]))
    np.testing.assert_allclose(np.asarray(g), [0.5, -0.5], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(h), [0.25, 0.25], rtol=1e-6)


# --------------------------------------------------------------- histogram

@pytest.mark.parametrize("seed", range(3))
def test_histogram_fn_windows(seed):
    rng = np.random.default_rng(seed)
    r, s = 1024, 16
    total_bins = 1200  # wider than one window
    bins = rng.integers(0, total_bins + 1, size=(r, s)).astype(np.int32)
    grads = rng.normal(size=(r, 2)).astype(np.float32)
    full = np.zeros((total_bins + 1, 2), dtype=np.float64)
    for i in range(r):
        for j in range(s):
            full[bins[i, j]] += grads[i]
    for offset in (0, hk.BINS):
        got = model.histogram_fn(jnp.asarray(bins), jnp.asarray(grads),
                                 jnp.int32(offset))
        want = np.zeros((hk.BINS, 2), dtype=np.float64)
        hi = min(offset + hk.BINS, total_bins)  # exclude the null symbol
        want[: hi - offset] = full[offset:hi]
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-3)


def test_histogram_fn_padded_rows_ignored():
    r, s = 1024, 16
    bins = np.full((r, s), 7, dtype=np.int32)
    grads = np.ones((r, 2), dtype=np.float32)
    grads[512:] = 0.0  # padded rows must carry zero gradients
    got = np.asarray(model.histogram_fn(jnp.asarray(bins), jnp.asarray(grads),
                                        jnp.int32(0)))
    assert got[7, 0] == pytest.approx(512 * s)


# ----------------------------------------------------------------- predict

def _random_tree(rng, max_nodes, n_features, depth=4):
    """Build a random valid tree in array encoding; returns dict."""
    feature = np.zeros(max_nodes, dtype=np.int32)
    threshold = np.zeros(max_nodes, dtype=np.float32)
    left = np.full(max_nodes, -1, dtype=np.int32)
    right = np.full(max_nodes, -1, dtype=np.int32)
    default_left = np.ones(max_nodes, dtype=np.int32)
    leaf_value = np.zeros(max_nodes, dtype=np.float32)
    next_id = [1]

    def grow(nid, d):
        if d >= depth or rng.random() < 0.3 or next_id[0] + 2 > max_nodes:
            leaf_value[nid] = rng.normal()
            return
        feature[nid] = rng.integers(0, n_features)
        threshold[nid] = rng.normal()
        default_left[nid] = rng.integers(0, 2)
        l, r = next_id[0], next_id[0] + 1
        next_id[0] += 2
        left[nid], right[nid] = l, r
        grow(l, d + 1)
        grow(r, d + 1)

    grow(0, 0)
    return dict(feature=feature, threshold=threshold, left=left, right=right,
                default_left=default_left, leaf_value=leaf_value)


@pytest.mark.parametrize("seed", range(4))
def test_predict_matches_reference_traversal(seed):
    rng = np.random.default_rng(seed)
    r, f, t, m = 256, 8, 5, 64
    x = rng.normal(size=(r, f)).astype(np.float32)
    x[rng.random((r, f)) < 0.15] = np.nan  # missing values
    trees = [_random_tree(rng, m, f) for _ in range(t)]
    stack = lambda k, dt: jnp.asarray(np.stack([tr[k] for tr in trees]).astype(dt))
    got = model.predict_ensemble(
        jnp.asarray(x),
        stack("feature", np.int32),
        stack("threshold", np.float32),
        stack("left", np.int32),
        stack("right", np.int32),
        stack("default_left", np.int32),
        stack("leaf_value", np.float32),
        max_iters=16,
    )
    want = ref.predict_ensemble_ref(x, trees)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


def test_predict_padding_trees_contribute_zero():
    r, f = 16, 4
    x = np.zeros((r, f), dtype=np.float32)
    # two identical stumps + one all-padding tree
    stump = dict(
        feature=np.zeros(8, np.int32), threshold=np.full(8, 0.5, np.float32),
        left=np.array([1] + [-1] * 7, np.int32),
        right=np.array([2] + [-1] * 7, np.int32),
        default_left=np.ones(8, np.int32),
        leaf_value=np.array([0, 1.5, -1.0] + [0] * 5, np.float32),
    )
    pad = dict(
        feature=np.zeros(8, np.int32), threshold=np.zeros(8, np.float32),
        left=np.full(8, -1, np.int32), right=np.full(8, -1, np.int32),
        default_left=np.ones(8, np.int32), leaf_value=np.zeros(8, np.float32),
    )
    trees = [stump, stump, pad]
    stack = lambda k, dt: jnp.asarray(np.stack([tr[k] for tr in trees]).astype(dt))
    got = model.predict_ensemble(
        jnp.asarray(x), stack("feature", np.int32), stack("threshold", np.float32),
        stack("left", np.int32), stack("right", np.int32),
        stack("default_left", np.int32), stack("leaf_value", np.float32),
        max_iters=8,
    )
    np.testing.assert_allclose(np.asarray(got), np.full(r, 3.0), rtol=1e-6)
