"""Pallas histogram kernel vs pure-jnp oracle — the core L1 correctness
signal, swept over shapes, bin counts, paddings and degenerate inputs
(hand-rolled sweep; the `hypothesis` package is not available offline)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import histogram as hk
from compile.kernels import ref


def _rand_case(rng, n, n_bins, p_invalid=0.1, tile=None):
    bins = rng.integers(0, n_bins, size=n).astype(np.int32)
    # sprinkle out-of-range symbols (null / other windows)
    mask = rng.random(n) < p_invalid
    bins[mask] = n_bins + rng.integers(0, 1000, size=mask.sum())
    neg = rng.random(n) < p_invalid / 2
    bins[neg] = -rng.integers(1, 1000, size=neg.sum()).astype(np.int32)
    w = rng.normal(size=(n, 2)).astype(np.float32)
    return jnp.asarray(bins), jnp.asarray(w)


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("n,tile", [(4096, 4096), (8192, 4096), (16384, 4096)])
def test_kernel_matches_ref(seed, n, tile):
    rng = np.random.default_rng(seed)
    bins, w = _rand_case(rng, n, hk.BINS)
    got = hk.histogram_tile(bins, w, n_bins=hk.BINS, tile=tile)
    want = ref.histogram_ref(bins, w, hk.BINS)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("n_bins", [8, 64, 512])
def test_kernel_bin_widths(n_bins):
    rng = np.random.default_rng(42)
    bins, w = _rand_case(rng, 4096, n_bins)
    got = hk.histogram_tile(bins, w, n_bins=n_bins, tile=4096)
    want = ref.histogram_ref(bins, w, n_bins)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_all_invalid_symbols_give_zero():
    bins = jnp.full((4096,), 10_000, dtype=jnp.int32)
    w = jnp.ones((4096, 2), dtype=jnp.float32)
    got = hk.histogram_tile(bins, w, n_bins=hk.BINS, tile=4096)
    assert float(jnp.abs(got).max()) == 0.0


def test_single_bin_concentration():
    bins = jnp.zeros((4096,), dtype=jnp.int32)
    w = jnp.ones((4096, 2), dtype=jnp.float32)
    got = np.asarray(hk.histogram_tile(bins, w, n_bins=hk.BINS, tile=4096))
    assert got[0, 0] == pytest.approx(4096.0)
    assert got[0, 1] == pytest.approx(4096.0)
    assert np.abs(got[1:]).max() == 0.0

def test_multi_step_accumulation_matches_single():
    # the same data as one grid step vs four must agree exactly
    rng = np.random.default_rng(7)
    bins, w = _rand_case(rng, 16384, hk.BINS)
    one = hk.histogram_tile(bins, w, n_bins=hk.BINS, tile=16384)
    four = hk.histogram_tile(bins, w, n_bins=hk.BINS, tile=4096)
    np.testing.assert_allclose(np.asarray(one), np.asarray(four),
                               rtol=1e-6, atol=1e-5)


def test_weighted_sum_total_preserved():
    rng = np.random.default_rng(3)
    bins = jnp.asarray(rng.integers(0, hk.BINS, size=4096).astype(np.int32))
    w = jnp.asarray(rng.normal(size=(4096, 2)).astype(np.float32))
    got = np.asarray(hk.histogram_tile(bins, w, n_bins=hk.BINS, tile=4096))
    np.testing.assert_allclose(got.sum(axis=0), np.asarray(w).sum(axis=0),
                               rtol=1e-4, atol=1e-3)


def test_vmem_estimate_within_budget():
    # DESIGN.md §7: one grid step's working set must fit a 16 MiB VMEM
    assert hk.vmem_bytes() <= 16 * 1024 * 1024
