"""Pure-jnp correctness oracles for the L1/L2 computations.

Every kernel / model function has an oracle here written with the most
obvious jnp formulation; pytest asserts allclose between the two across a
shape/dtype sweep (python/tests/).  The Rust side re-checks the same
numerics against its native implementations through the AOT artifacts.
"""

import jax.numpy as jnp


def histogram_ref(bins_local, weights, n_bins):
    """Segment-sum gradient histogram.

    Args:
      bins_local: (N,) int32 bin ids; out-of-range ids are dropped.
      weights: (N, 2) float32 gradient pairs.
      n_bins: output width.

    Returns:
      (n_bins, 2) float32.
    """
    bins_local = bins_local.astype(jnp.int32)
    valid = (bins_local >= 0) & (bins_local < n_bins)
    clamped = jnp.where(valid, bins_local, 0)
    w = jnp.where(valid[:, None], weights, 0.0)
    out = jnp.zeros((n_bins, 2), dtype=jnp.float32)
    return out.at[clamped].add(w)


def sigmoid(x):
    return 1.0 / (1.0 + jnp.exp(-x))


def logistic_gradients_ref(margins, labels):
    """Paper equations (1)-(2)."""
    p = sigmoid(margins)
    return p - labels, p * (1.0 - p)


def squared_gradients_ref(margins, labels):
    return margins - labels, jnp.ones_like(margins)


def softmax_gradients_ref(margins, labels, n_class):
    """margins: (N, K); labels: (N,) int. Returns (N, K) g and h."""
    z = margins - margins.max(axis=1, keepdims=True)
    e = jnp.exp(z)
    p = e / e.sum(axis=1, keepdims=True)
    onehot = jnp.eye(n_class, dtype=p.dtype)[labels.astype(jnp.int32)]
    g = p - onehot
    h = 2.0 * p * (1.0 - p)
    return g, h


def predict_ensemble_ref(x, trees):
    """Reference predictor: plain python traversal.

    Args:
      x: (N, F) numpy-like with NaN missing.
      trees: list of dicts with keys feature/threshold/left/right/
        default_left/leaf_value, each a 1-D array indexed by node id.

    Returns:
      (N,) float margins (sum over trees).
    """
    import numpy as np

    x = np.asarray(x)
    n = x.shape[0]
    out = np.zeros(n, dtype=np.float32)
    for t in trees:
        feature = np.asarray(t["feature"])
        threshold = np.asarray(t["threshold"])
        left = np.asarray(t["left"])
        right = np.asarray(t["right"])
        default_left = np.asarray(t["default_left"])
        leaf_value = np.asarray(t["leaf_value"])
        for i in range(n):
            nid = 0
            while left[nid] != -1:
                v = x[i, feature[nid]]
                if np.isnan(v):
                    go_left = bool(default_left[nid])
                else:
                    go_left = bool(v < threshold[nid])
                nid = int(left[nid] if go_left else right[nid])
            out[i] += leaf_value[nid]
    return out
