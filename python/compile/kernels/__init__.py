# L1: Pallas kernels for the paper's compute hot-spot (gradient histogram
# accumulation) plus the pure-jnp correctness oracles in ref.py.
from . import histogram, ref  # noqa: F401
