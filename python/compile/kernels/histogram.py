"""L1 Pallas kernel: gradient histogram accumulation.

The paper's CUDA kernel accumulates gradient pairs into shared-memory
histograms with atomic adds (§2.3).  Atomics do not exist in the TPU
programming model, so the kernel re-expresses the same segmented reduction
as dense linear algebra the MXU can run (DESIGN.md §1):

    hist[b, :] = sum_i  onehot(bin_i)[b] * weight_i[:]
               = onehot(bins)^T @ weights

Per grid step a ``(TILE, )`` slice of quantised bin symbols and the
matching ``(TILE, 2)`` gradient-pair rows are staged into VMEM, the one-hot
``(TILE, BINS)`` matrix is formed in registers from an iota comparison and
contracted against the weights on the MXU; the ``(BINS, 2)`` output block
lives in VMEM across grid steps and accumulates (sequential-grid revisiting
on TPU).

Out-of-range symbols (the ELLPACK null/padding symbol, or bins outside the
``bin_offset`` window the caller selected) one-hot to the zero row and so
contribute nothing — this is how a single fixed-shape artifact covers
matrices whose total bin count exceeds ``BINS``.

Shapes are static: callers (``compile/model.py`` and the Rust runtime via
the AOT artifact) pad the last tile.  ``interpret=True`` everywhere — the
CPU PJRT plugin cannot execute Mosaic custom-calls; real-TPU performance is
estimated analytically in DESIGN.md §7.

The native CPU builders mirror this kernel's block/accumulate/merge shape
in scalar code: ``rust/src/hist`` decodes each block of rows through the
multi-symbol unpacker (``rust/src/compress/unpack.rs``) and accumulates
branchlessly into a one-slot-wider partial — the null symbol indexes a
scratch slot discarded on merge, the moral equivalent of this kernel's
zero one-hot row.  ``XGB_SCALAR_KERNELS=1`` selects the row-at-a-time
reference loops there; both are bit-identical (see the hist module docs).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default artifact tile geometry (see aot.py).
TILE = 4096  # flattened (row, slot) symbols per grid step
BINS = 512   # histogram bins per call window


def _hist_kernel(bins_ref, w_ref, out_ref, *, n_bins: int):
    """One grid step: out += onehot(bins)^T @ w."""
    step = pl.program_id(0)

    bins = bins_ref[...]  # (TILE,) int32, already offset-local
    w = w_ref[...]        # (TILE, 2) float32

    # one-hot via iota comparison; out-of-range symbols match nothing
    ids = jax.lax.broadcasted_iota(jnp.int32, (bins.shape[0], n_bins), 1)
    onehot = (bins[:, None] == ids).astype(jnp.float32)  # (TILE, BINS)

    # (BINS, TILE) @ (TILE, 2) on the MXU
    partial_hist = jax.lax.dot_general(
        onehot,
        w,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (BINS, 2)

    # zero the accumulator on the first step, then accumulate
    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial_hist


def histogram_tile(bins_local: jax.Array, weights: jax.Array,
                   n_bins: int = BINS, tile: int = TILE) -> jax.Array:
    """Histogram of one row tile via the Pallas kernel.

    Args:
      bins_local: ``(N,)`` int32 — bin symbols already shifted by the
        caller's bin-window offset; anything outside ``[0, n_bins)`` is
        ignored (null symbol, padding, other windows).
      weights: ``(N, 2)`` float32 — (grad, hess) per symbol (rows repeated
        per slot by the caller); padded entries must be zero.
      n_bins: histogram width of this call window.
      tile: symbols per grid step; must divide ``N``.

    Returns:
      ``(n_bins, 2)`` float32 gradient histogram.
    """
    n = bins_local.shape[0]
    assert n % tile == 0, f"flattened length {n} not a multiple of tile {tile}"
    grid = (n // tile,)
    return pl.pallas_call(
        partial(_hist_kernel, n_bins=n_bins),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile, 2), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n_bins, 2), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_bins, 2), jnp.float32),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(bins_local, weights)


def vmem_bytes(tile: int = TILE, n_bins: int = BINS) -> int:
    """Static VMEM footprint estimate of one grid step (DESIGN.md §7):
    bins block + weights block + one-hot intermediate + output block."""
    return (
        tile * 4              # bins int32
        + tile * 2 * 4        # weights f32
        + tile * n_bins * 4   # one-hot f32 (register/VMEM resident)
        + n_bins * 2 * 4      # output accumulator
    )
