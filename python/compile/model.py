"""L2: the JAX compute graphs AOT-lowered into the Rust hot path.

Three entry points, mirroring the phases the paper moves onto the device
(Figure 1):

* ``logistic_gradients`` / ``squared_gradients`` — per-instance gradient
  pairs (paper §2.5, equations 1-2; one thread per instance becomes one
  vector lane per instance),
* ``histogram_fn`` — the §2.3 hot-spot, calling the L1 Pallas kernel
  (kernels/histogram.py),
* ``predict_ensemble`` — §2.4 batched tree traversal over array-encoded
  trees (one lane per instance, trees iterated sequentially, exactly the
  paper's mapping).

Everything here executes at build time only: aot.py lowers these with
fixed tile shapes to HLO text, and rust/src/runtime/ replays them through
PJRT.
"""

import jax
import jax.numpy as jnp

from .kernels import histogram as hist_kernel


# ---------------------------------------------------------------- gradients

def logistic_gradients(margins, labels):
    """Paper equations (1)-(2): g = sigmoid(ŷ) − y, h = σ(ŷ)(1−σ(ŷ)).

    Returns (g, h) as float32 vectors; the Rust booster masks padded rows.
    """
    p = jax.nn.sigmoid(margins)
    return p - labels, jnp.maximum(p * (1.0 - p), 1e-16)


def squared_gradients(margins, labels):
    """reg:squarederror: g = ŷ − y, h = 1."""
    return margins - labels, jnp.ones_like(margins)


# ---------------------------------------------------------------- histogram

def histogram_fn(bins, grads, bin_offset):
    """Gradient histogram of one row tile over one bin window.

    Args:
      bins: (R, S) int32 global bin symbols (ELLPACK layout; null/padding
        symbols are any value outside the window).
      grads: (R, 2) float32 gradient pairs per row (zero for padded rows).
      bin_offset: () int32 — start of the bin window this call covers.

    Returns:
      (BINS, 2) float32 histogram of bins [offset, offset + BINS).
    """
    r, s = bins.shape
    local = bins - bin_offset  # out-of-window symbols fall outside [0, BINS)
    flat_bins = local.reshape(r * s)
    # each of a row's S slots carries the row's gradient pair
    flat_w = jnp.broadcast_to(grads[:, None, :], (r, s, 2)).reshape(r * s, 2)
    return hist_kernel.histogram_tile(
        flat_bins, flat_w, n_bins=hist_kernel.BINS, tile=min(hist_kernel.TILE, r * s)
    )


# ----------------------------------------------------------------- predict

def predict_ensemble(x, feature, threshold, left, right, default_left,
                     leaf_value, *, max_iters=32):
    """Batched prediction over an array-encoded tree ensemble (§2.4).

    Args:
      x: (R, F) float32, NaN = missing.
      feature, threshold, left, right, default_left, leaf_value:
        (T, M) per-tree node arrays (see rust RegTree::to_arrays); padding
        trees are single leaves with leaf_value 0.
      max_iters: static traversal depth bound (>= max node depth).

    Returns:
      (R,) float32 margin sums over all T trees.
    """
    r = x.shape[0]
    t = feature.shape[0]

    # Node-id state is laid out (T, R) so per-tree node-array gathers run
    # along axis 1 with take_along_axis.
    nid = jnp.zeros((t, r), dtype=jnp.int32)

    def step(_, nid):
        feat = jnp.take_along_axis(feature, nid, axis=1)         # (T, R)
        thr = jnp.take_along_axis(threshold, nid, axis=1)
        lft = jnp.take_along_axis(left, nid, axis=1)
        rgt = jnp.take_along_axis(right, nid, axis=1)
        dfl = jnp.take_along_axis(default_left, nid, axis=1)
        is_leaf = lft == -1
        # x values: rows gather their feature column per tree
        fv = x[jnp.arange(r)[None, :], jnp.clip(feat, 0, x.shape[1] - 1)]  # (T, R)
        missing = jnp.isnan(fv)
        go_left = jnp.where(missing, dfl == 1, fv < thr)
        nxt = jnp.where(go_left, lft, rgt)
        return jnp.where(is_leaf, nid, nxt)

    nid = jax.lax.fori_loop(0, max_iters, step, nid)
    leaves = jnp.take_along_axis(leaf_value, nid, axis=1)  # (T, R)
    return leaves.sum(axis=0)


# --------------------------------------------------------------- jit wrappers

def lowerable_histogram(r, s):
    """jit-able histogram closure for fixed (R, S)."""
    def fn(bins, grads, bin_offset):
        return (histogram_fn(bins, grads, bin_offset),)
    return fn, (
        jax.ShapeDtypeStruct((r, s), jnp.int32),
        jax.ShapeDtypeStruct((r, 2), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )


def lowerable_gradients(kind, n):
    fn = {"logistic": logistic_gradients, "squared": squared_gradients}[kind]
    def wrapped(margins, labels):
        return fn(margins, labels)
    return wrapped, (
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
    )


def lowerable_predict(r, f, t, m, max_iters=32):
    def fn(x, feature, threshold, left, right, default_left, leaf_value):
        return (
            predict_ensemble(
                x, feature, threshold, left, right, default_left, leaf_value,
                max_iters=max_iters,
            ),
        )
    i32 = jnp.int32
    f32 = jnp.float32
    return fn, (
        jax.ShapeDtypeStruct((r, f), f32),
        jax.ShapeDtypeStruct((t, m), i32),
        jax.ShapeDtypeStruct((t, m), f32),
        jax.ShapeDtypeStruct((t, m), i32),
        jax.ShapeDtypeStruct((t, m), i32),
        jax.ShapeDtypeStruct((t, m), i32),
        jax.ShapeDtypeStruct((t, m), f32),
    )
