//! Memory-footprint reproduction (M1 + A1 in DESIGN.md §4):
//!
//! * §2.2's "compression ... typically reduces GPU memory consumption by
//!   four times or more over the standard floating point representation",
//! * §3's "After compression and distributing training rows between 8
//!   GPUs, we only require 600MB per GPU to store the entire [airline]
//!   matrix".
//!
//! Measures the packed bytes of each dataset's ELLPACK matrix at bench
//! scale and projects the airline number analytically to the paper's full
//! 115M rows (the bits/symbol is scale-invariant once cuts saturate).

use xgb_tpu::bench::Table;
use xgb_tpu::compress::CompressedMatrix;
use xgb_tpu::coordinator::{CoordinatorParams, MultiDeviceCoordinator};
use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::quantile::{HistogramCuts, Quantizer};

fn env_f64(k: &str, d: f64) -> f64 {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() -> anyhow::Result<()> {
    let scale = env_f64("XGB_BENCH_SCALE", 0.002);
    let max_bins = 256usize;
    eprintln!("memory_footprint: scale={scale} max_bins={max_bins}");

    let mut t = Table::new(&[
        "Dataset", "Rows", "Stride", "f32 MB", "u32-bin MB", "packed MB",
        "bits/sym", "vs f32", "vs csr-entry",
    ]);
    let mut four_x = 0usize;
    let mut total = 0usize;
    for spec in DatasetSpec::table1(scale) {
        let g = generate(&spec, 42);
        let cuts = HistogramCuts::from_dmatrix(&g.train.x, max_bins, None);
        let qm = Quantizer::new(cuts).quantize(&g.train.x);
        let cm = CompressedMatrix::from_quantized(&qm);
        let f32_mb = (qm.n_rows * qm.row_stride * 4) as f64 / 1e6;
        let u32_mb = qm.bytes() as f64 / 1e6;
        let packed_mb = cm.bytes() as f64 / 1e6;
        let ratio = cm.ratio_vs_float();
        let csr_ratio = cm.ratio_vs_csr_entry();
        total += 1;
        four_x += usize::from(csr_ratio >= 4.0);
        t.add_row(vec![
            spec.name.into(),
            format!("{}", qm.n_rows),
            format!("{}", qm.row_stride),
            format!("{f32_mb:.1}"),
            format!("{u32_mb:.1}"),
            format!("{packed_mb:.1}"),
            format!("{}", cm.symbol_bits),
            format!("{ratio:.2}x"),
            format!("{csr_ratio:.2}x"),
        ]);
        eprintln!("  {}: {:.2}x vs csr-entry ({} bits/symbol)", spec.name, csr_ratio, cm.symbol_bits);
    }
    println!("\n=== A1: compression ratios (paper §2.2: \"four times or more\") ===\n");
    print!("{}", t.render());
    println!(
        "\n{four_x}/{total} datasets reach >= 4x vs the pre-quantisation device \
         representation\n(8-byte CSR (index,value) entries — Mitchell & Frank 2017) at \
         {max_bins} bins/feature;\nratio = 64 / ceil(log2(total_bins+1))."
    );

    // M1: airline per-device bytes, measured at bench scale + projection
    println!("\n=== M1: airline per-device footprint (paper: ~600 MB/GPU at 115M rows) ===\n");
    let spec = DatasetSpec::airline_like(((115_000_000f64 * scale) as usize).max(10_000));
    let g = generate(&spec, 1);
    let params = CoordinatorParams {
        n_devices: 8,
        compress: true,
        max_bins,
        ..Default::default()
    };
    let c = MultiDeviceCoordinator::from_dmatrix(&g.train.x, params)?;
    let bytes = c.device_bytes();
    let per_dev_mb = bytes.iter().sum::<usize>() as f64 / bytes.len() as f64 / 1e6;
    println!("measured at {} rows over 8 devices: {per_dev_mb:.2} MB/device", g.train.n_rows());

    // analytic projection to the paper's full scale
    let cuts = HistogramCuts::from_dmatrix(&g.train.x, max_bins, None);
    let qm = Quantizer::new(cuts).quantize(&g.train.x);
    let cm = CompressedMatrix::from_quantized(&qm);
    let full_rows = 115_000_000f64;
    let projected_mb =
        full_rows / 8.0 * qm.row_stride as f64 * cm.symbol_bits as f64 / 8.0 / 1e6;
    println!(
        "projected at 115M rows: {projected_mb:.0} MB/device \
         ({} slots x {} bits/symbol)",
        qm.row_stride, cm.symbol_bits
    );
    println!(
        "paper reports ~600 MB/device; [{}] same order of magnitude",
        if (100.0..1500.0).contains(&projected_mb) { "ok" } else { "DIFF" }
    );
    Ok(())
}
