//! Memory-footprint reproduction (M1 + A1 in DESIGN.md §4) plus the
//! streaming-ingestion transient-memory trajectory:
//!
//! * §2.2's "compression ... typically reduces GPU memory consumption by
//!   four times or more over the standard floating point representation",
//! * §3's "After compression and distributing training rows between 8
//!   GPUs, we only require 600MB per GPU to store the entire [airline]
//!   matrix",
//! * the out-of-core contract: streaming ingestion's peak transient
//!   (non-packed) bytes are bounded by the batch size, not the dataset
//!   size — compared per dataset against the in-memory path's transient
//!   footprint (full float matrix + full u32 bin matrix) and emitted as
//!   the tracked trajectory artifact `BENCH_memory.json` (override the
//!   path with `XGB_BENCH_OUT`; batch rows with `XGB_BENCH_BATCH_ROWS`),
//! * the external-memory contract (M3): with packed pages spilled to
//!   disk (`max_resident_pages`, `XGB_BENCH_RESIDENT_PAGES`; page size
//!   `XGB_BENCH_PAGE_ROWS`), measured peak resident compressed bytes per
//!   tree stay within `max_resident_pages × page_bytes` while the full
//!   matrix lives on disk — resident vs spilled bytes per dataset also
//!   land in `BENCH_memory.json`.
//!
//! Measures the packed bytes of each dataset's ELLPACK matrix at bench
//! scale and projects the airline number analytically to the paper's full
//! 115M rows (the bits/symbol is scale-invariant once cuts saturate).

use xgb_tpu::bench::Table;
use xgb_tpu::compress::CompressedMatrix;
use xgb_tpu::coordinator::{CoordinatorParams, MultiDeviceCoordinator};
use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::data::DMatrixSource;
use xgb_tpu::quantile::{HistogramCuts, Quantizer};

fn env_f64(k: &str, d: f64) -> f64 {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() -> anyhow::Result<()> {
    let scale = env_f64("XGB_BENCH_SCALE", 0.002);
    let max_bins = 256usize;
    eprintln!("memory_footprint: scale={scale} max_bins={max_bins}");

    let mut t = Table::new(&[
        "Dataset", "Rows", "Stride", "f32 MB", "u32-bin MB", "packed MB",
        "bits/sym", "vs f32", "vs csr-entry",
    ]);
    let mut four_x = 0usize;
    let mut total = 0usize;
    for spec in DatasetSpec::table1(scale) {
        let g = generate(&spec, 42);
        let cuts = HistogramCuts::from_dmatrix(&g.train.x, max_bins, None);
        let qm = Quantizer::new(cuts).quantize(&g.train.x);
        let cm = CompressedMatrix::from_quantized(&qm);
        let f32_mb = (qm.n_rows * qm.row_stride * 4) as f64 / 1e6;
        let u32_mb = qm.bytes() as f64 / 1e6;
        let packed_mb = cm.bytes() as f64 / 1e6;
        let ratio = cm.ratio_vs_float();
        let csr_ratio = cm.ratio_vs_csr_entry();
        total += 1;
        four_x += usize::from(csr_ratio >= 4.0);
        t.add_row(vec![
            spec.name.into(),
            format!("{}", qm.n_rows),
            format!("{}", qm.row_stride),
            format!("{f32_mb:.1}"),
            format!("{u32_mb:.1}"),
            format!("{packed_mb:.1}"),
            format!("{}", cm.symbol_bits),
            format!("{ratio:.2}x"),
            format!("{csr_ratio:.2}x"),
        ]);
        eprintln!("  {}: {:.2}x vs csr-entry ({} bits/symbol)", spec.name, csr_ratio, cm.symbol_bits);
    }
    println!("\n=== A1: compression ratios (paper §2.2: \"four times or more\") ===\n");
    print!("{}", t.render());
    println!(
        "\n{four_x}/{total} datasets reach >= 4x vs the pre-quantisation device \
         representation\n(8-byte CSR (index,value) entries — Mitchell & Frank 2017) at \
         {max_bins} bins/feature;\nratio = 64 / ceil(log2(total_bins+1))."
    );

    // Streaming vs in-memory transient footprint: the in-memory path once
    // materialized the full float matrix plus the full u32 bin matrix
    // before the first packed word existed; the streaming pipeline holds
    // only one batch of floats + symbols at a time.
    let batch_rows = env_usize("XGB_BENCH_BATCH_ROWS", 8192);
    println!(
        "\n=== M2: ingestion peak transient (non-packed) bytes — in-memory vs \
         streaming (batch_rows={batch_rows}) ===\n"
    );
    let mut t2 = Table::new(&[
        "Dataset", "Rows", "inmem transient MB", "stream transient MB", "reduction",
    ]);
    let mut json_rows: Vec<String> = Vec::new();
    for spec in DatasetSpec::table1(scale) {
        let g = generate(&spec, 42);
        let params = CoordinatorParams {
            n_devices: 1,
            compress: true,
            max_bins,
            ..Default::default()
        };
        let mut src = DMatrixSource::from_dataset(&g.train, batch_rows);
        let (coord, meta) = MultiDeviceCoordinator::from_source(&mut src, params)?;
        let packed: usize = coord.device_bytes().iter().sum();
        // in-memory transient: the whole float matrix + the whole u32 bin
        // matrix (rows × stride × 4) existed simultaneously pre-refactor
        let stride = coord.devices[0].storage.row_stride();
        let inmem_transient = g.train.x.float_bytes() + g.train.n_rows() * stride * 4;
        let stream_transient = meta.peak_transient_bytes;
        let reduction = inmem_transient as f64 / stream_transient.max(1) as f64;
        t2.add_row(vec![
            spec.name.into(),
            format!("{}", g.train.n_rows()),
            format!("{:.2}", inmem_transient as f64 / 1e6),
            format!("{:.2}", stream_transient as f64 / 1e6),
            format!("{reduction:.1}x"),
        ]);
        json_rows.push(format!(
            "    {{\"name\": \"{}\", \"rows\": {}, \"batch_rows\": {}, \
             \"packed_bytes\": {}, \"inmem_transient_bytes\": {}, \
             \"stream_transient_bytes\": {}, \"reduction\": {:.3}}}",
            spec.name,
            g.train.n_rows(),
            batch_rows,
            packed,
            inmem_transient,
            stream_transient,
            reduction
        ));
    }
    print!("{}", t2.render());

    // M3: external-memory footprint — spill the packed pages to disk and
    // train one tree per dataset under a small residency budget; the
    // resident share (measured peak) must be a small, budget-bounded
    // fraction of the spilled (on-disk) matrix.
    let page_rows = env_usize("XGB_BENCH_PAGE_ROWS", 8192);
    let budget = env_usize("XGB_BENCH_RESIDENT_PAGES", 4);
    println!(
        "\n=== M3: external-memory resident vs spilled bytes \
         (max_resident_pages={budget}, page_rows={page_rows}) ===\n"
    );
    let mut t3 = Table::new(&[
        "Dataset", "Rows", "spilled MB", "peak resident MB", "bound MB", "pages loaded",
        "prefetch-hidden s",
    ]);
    let mut json_m3: Vec<String> = Vec::new();
    for spec in DatasetSpec::table1(scale) {
        let g = generate(&spec, 42);
        let params = CoordinatorParams {
            n_devices: 1,
            compress: true,
            max_bins,
            max_resident_pages: budget,
            page_rows,
            ..Default::default()
        };
        let mut src = DMatrixSource::from_dataset(&g.train, batch_rows);
        let (mut coord, _meta) = MultiDeviceCoordinator::from_source(&mut src, params)?;
        let mean: f32 = g.train.y.iter().sum::<f32>() / g.train.y.len().max(1) as f32;
        let grads: Vec<xgb_tpu::GradPair> = g
            .train
            .y
            .iter()
            .map(|&y| xgb_tpu::GradPair::new(mean - y, 1.0))
            .collect();
        let r = coord.build_tree(&grads)?;
        let spilled: usize = coord.device_bytes().iter().sum();
        let max_page: usize = coord
            .devices
            .iter()
            .map(|d| match &d.storage {
                xgb_tpu::coordinator::device::ShardStorage::Paged(ps) => ps.max_page_bytes(),
                _ => 0,
            })
            .max()
            .unwrap_or(0);
        let bound = budget * max_page;
        let peak = r.stats.peak_resident_page_bytes;
        assert!(peak <= bound, "{}: peak {peak} exceeds bound {bound}", spec.name);
        t3.add_row(vec![
            spec.name.into(),
            format!("{}", g.train.n_rows()),
            format!("{:.2}", spilled as f64 / 1e6),
            format!("{:.2}", peak as f64 / 1e6),
            format!("{:.2}", bound as f64 / 1e6),
            format!("{}", r.stats.pages_loaded),
            format!("{:.3}", r.stats.prefetch_hidden_secs()),
        ]);
        json_m3.push(format!(
            "    {{\"name\": \"{}\", \"rows\": {}, \"page_rows\": {}, \
             \"max_resident_pages\": {}, \"spilled_bytes\": {}, \
             \"peak_resident_bytes\": {}, \"resident_bound_bytes\": {}, \
             \"pages_loaded\": {}, \"page_load_secs\": {:.6}, \
             \"page_wait_secs\": {:.6}}}",
            spec.name,
            g.train.n_rows(),
            page_rows,
            budget,
            spilled,
            peak,
            bound,
            r.stats.pages_loaded,
            r.stats.page_load_secs,
            r.stats.page_wait_secs,
        ));
    }
    print!("{}", t3.render());

    // M4: prediction peak transient bytes — the float path materializes
    // the whole input matrix before the first prediction exists; the
    // streaming quantised path holds one batch of floats + one batch of
    // unclamped bins at a time (predictions are bit-identical — pinned
    // by rust/tests/compressed_predict.rs, asserted per dataset here).
    println!(
        "\n=== M4: prediction peak transient bytes — float matrix vs streaming \
         quantised (batch_rows={batch_rows}) ===\n"
    );
    let mut t4 = Table::new(&[
        "Dataset", "Rows", "float matrix MB", "stream peak MB", "reduction", "batches",
    ]);
    let mut json_m4: Vec<String> = Vec::new();
    for spec in DatasetSpec::table1(scale) {
        let g = generate(&spec, 42);
        let params = xgb_tpu::gbm::LearnerParams {
            objective: spec.task.objective().parse().expect("infallible"),
            num_class: spec.task.num_class(),
            num_rounds: 2,
            max_depth: 3,
            max_bins,
            eval_every: 0,
            ..Default::default()
        };
        let booster = xgb_tpu::gbm::Learner::from_params(params)?
            .train(&g.train, None)?;
        let float_bytes = g.train.x.float_bytes();
        let mut src = DMatrixSource::from_dataset(&g.train, batch_rows);
        let (preds, sm) = booster.predict_stream(&mut src)?;
        assert_eq!(
            preds,
            booster.predict(&g.train.x),
            "{}: streamed predictions must be bit-identical",
            spec.name
        );
        let reduction = float_bytes as f64 / sm.peak_transient_bytes.max(1) as f64;
        t4.add_row(vec![
            spec.name.into(),
            format!("{}", g.train.n_rows()),
            format!("{:.2}", float_bytes as f64 / 1e6),
            format!("{:.2}", sm.peak_transient_bytes as f64 / 1e6),
            format!("{reduction:.1}x"),
            format!("{}", sm.n_batches),
        ]);
        json_m4.push(format!(
            "    {{\"name\": \"{}\", \"rows\": {}, \"batch_rows\": {}, \
             \"float_matrix_bytes\": {}, \"stream_peak_transient_bytes\": {}, \
             \"reduction\": {:.3}}}",
            spec.name,
            g.train.n_rows(),
            batch_rows,
            float_bytes,
            sm.peak_transient_bytes,
            reduction
        ));
    }
    print!("{}", t4.render());

    let out_path =
        std::env::var("XGB_BENCH_OUT").unwrap_or_else(|_| "BENCH_memory.json".to_string());
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"memory_footprint\",\n");
    json.push_str(&format!("  \"scale\": {scale},\n"));
    json.push_str(&format!("  \"max_bins\": {max_bins},\n"));
    json.push_str(&format!("  \"batch_rows\": {batch_rows},\n"));
    json.push_str("  \"datasets\": [\n");
    json.push_str(&json_rows.join(",\n"));
    json.push_str("\n  ],\n");
    json.push_str("  \"external_memory\": [\n");
    json.push_str(&json_m3.join(",\n"));
    json.push_str("\n  ],\n");
    json.push_str("  \"prediction\": [\n");
    json.push_str(&json_m4.join(",\n"));
    json.push_str("\n  ]\n}\n");
    std::fs::write(&out_path, &json)?;
    eprintln!("wrote {out_path}");

    // M1: airline per-device bytes, measured at bench scale + projection
    println!("\n=== M1: airline per-device footprint (paper: ~600 MB/GPU at 115M rows) ===\n");
    let spec = DatasetSpec::airline_like(((115_000_000f64 * scale) as usize).max(10_000));
    let g = generate(&spec, 1);
    let params = CoordinatorParams {
        n_devices: 8,
        compress: true,
        max_bins,
        ..Default::default()
    };
    let c = MultiDeviceCoordinator::from_dmatrix(&g.train.x, params)?;
    let bytes = c.device_bytes();
    let per_dev_mb = bytes.iter().sum::<usize>() as f64 / bytes.len() as f64 / 1e6;
    println!("measured at {} rows over 8 devices: {per_dev_mb:.2} MB/device", g.train.n_rows());

    // analytic projection to the paper's full scale
    let cuts = HistogramCuts::from_dmatrix(&g.train.x, max_bins, None);
    let qm = Quantizer::new(cuts).quantize(&g.train.x);
    let cm = CompressedMatrix::from_quantized(&qm);
    let full_rows = 115_000_000f64;
    let projected_mb =
        full_rows / 8.0 * qm.row_stride as f64 * cm.symbol_bits as f64 / 8.0 / 1e6;
    println!(
        "projected at 115M rows: {projected_mb:.0} MB/device \
         ({} slots x {} bits/symbol)",
        qm.row_stride, cm.symbol_bits
    );
    println!(
        "paper reports ~600 MB/device; [{}] same order of magnitude",
        if (100.0..1500.0).contains(&projected_mb) { "ok" } else { "DIFF" }
    );
    Ok(())
}
