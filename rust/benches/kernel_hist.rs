//! L1 kernel benchmark: histogram-build throughput of the native builders
//! (the device-compute reference used by the Table 2 / Figure 2 numbers)
//! vs the AOT-compiled Pallas one-hot-matmul artifact through PJRT.
//!
//! Sweeps **Scalar vs Blocked** kernel modes (the blocked multi-symbol
//! unpack + branchless null-scratch-slot accumulation of
//! `rust/src/hist`, bit-identical by construction) for the quantized and
//! bit-packed builders across thread counts {1,2,4,8} and two symbol
//! widths (max_bins 16 and 256), plus the external-memory paged path.
//! Emits a `BENCH_kernel.json` trajectory artifact (path override:
//! `XGB_BENCH_OUT`) with cells/s, GB/s and blocked-over-scalar speedup
//! per cell of the sweep — the perf baseline future PRs diff against.
//!
//! NOTE: the XLA artifact row runs the kernel in interpret mode on the
//! CPU plugin; its wall-clock here is a correctness path, NOT a TPU
//! performance proxy. The TPU estimate is static — DESIGN.md §7.

use xgb_tpu::bench::{fmt_secs, Runner, Table};
use xgb_tpu::compress::page::PagedMatrixBuilder;
use xgb_tpu::compress::CompressedMatrix;
use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::exec::{ExecContext, KernelMode};
use xgb_tpu::hist::{
    build_histogram_compressed_par_mode, build_histogram_paged_mode,
    build_histogram_quantized_par_mode, HistArena, Histogram,
};
use xgb_tpu::quantile::{HistogramCuts, Quantizer};
use xgb_tpu::GradPair;

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn mode_name(mode: KernelMode) -> &'static str {
    match mode {
        KernelMode::Blocked => "blocked",
        KernelMode::Scalar => "scalar",
    }
}

/// One sweep cell, ready for both the table and the JSON artifact.
struct Cell {
    builder: &'static str,
    mode: KernelMode,
    threads: usize,
    max_bins: usize,
    symbol_bits: u32,
    mean_secs: f64,
    cells_per_sec: f64,
    gb_per_sec: f64,
}

fn main() -> anyhow::Result<()> {
    let rows = env_usize("XGB_BENCH_ROWS", 100_000);
    let runner = Runner::from_env();
    eprintln!("kernel_hist: rows={rows}");

    let data = generate(&DatasetSpec::higgs_like(rows), 17);
    let n = data.train.n_rows();
    let grads: Vec<GradPair> = (0..n)
        .map(|i| GradPair::new((i % 7) as f32 / 7.0 - 0.5, 1.0))
        .collect();
    let rows_all: Vec<u32> = (0..n as u32).collect();
    let threads_sweep = [1usize, 2, 4, 8];
    let modes = [KernelMode::Scalar, KernelMode::Blocked];
    // long-lived arena: the bench measures steady-state (recycled
    // scratch) throughput, matching a training run after round 1
    let arena = HistArena::default();

    let mut cells_out: Vec<Cell> = Vec::new();
    let mut t = Table::new(&[
        "kernel",
        "bins",
        "bits",
        "threads",
        "mean",
        "cells/s (M)",
        "GB/s (u32 equiv)",
        "speedup vs scalar",
    ]);

    for max_bins in [16usize, 256] {
        let cuts = HistogramCuts::from_dmatrix(&data.train.x, max_bins, None);
        let qm = Quantizer::new(cuts.clone()).quantize(&data.train.x);
        let cm = CompressedMatrix::from_quantized(&qm);
        let cells = (n * qm.row_stride) as f64;
        let bits = cm.symbol_bits;
        eprintln!("max_bins={max_bins}: n_bins={} symbol_bits={bits}", qm.n_bins);

        // spill once per width for the paged sweep
        let dir = std::env::temp_dir().join(format!(
            "xgb_tpu_bench_kernel_{}_{max_bins}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir)?;
        let mut pb = PagedMatrixBuilder::new(
            dir.join("bench.pages"),
            qm.n_rows,
            qm.n_features,
            qm.row_stride,
            qm.n_bins,
            qm.dense,
            8192,
            2,
        )?;
        for r in 0..qm.n_rows {
            pb.push_row(qm.row(r))?;
        }
        let store = pb.finish()?;

        for threads in threads_sweep {
            let exec = ExecContext::new(threads);
            let mut h = Histogram::zeros(qm.n_bins);
            for builder in ["quantized", "compressed", "paged"] {
                let mut scalar_mean = 0.0f64;
                for mode in modes {
                    let label =
                        format!("{builder}/{}/bins{max_bins}/t{threads}", mode_name(mode));
                    let res = match builder {
                        "quantized" => runner.run(&label, || {
                            h = Histogram::zeros(qm.n_bins);
                            build_histogram_quantized_par_mode(
                                &qm, &grads, &rows_all, &mut h, &exec, mode, &arena,
                            );
                        }),
                        "compressed" => runner.run(&label, || {
                            h = Histogram::zeros(qm.n_bins);
                            build_histogram_compressed_par_mode(
                                &cm, &grads, &rows_all, &mut h, &exec, mode, &arena,
                            );
                        }),
                        _ => runner.run(&label, || {
                            h = Histogram::zeros(qm.n_bins);
                            build_histogram_paged_mode(
                                &store, &grads, &rows_all, &mut h, &exec, mode, &arena,
                            )
                            .unwrap();
                        }),
                    };
                    if mode == KernelMode::Scalar {
                        scalar_mean = res.mean_secs;
                    }
                    let speedup = if mode == KernelMode::Scalar {
                        1.0
                    } else {
                        scalar_mean / res.mean_secs
                    };
                    t.add_row(vec![
                        format!("{builder}/{}", mode_name(mode)),
                        format!("{max_bins}"),
                        format!("{bits}"),
                        format!("{threads}"),
                        fmt_secs(res.mean_secs),
                        format!("{:.1}", cells / res.mean_secs / 1e6),
                        format!("{:.2}", cells * 4.0 / res.mean_secs / 1e9),
                        format!("{speedup:.2}x"),
                    ]);
                    cells_out.push(Cell {
                        builder,
                        mode,
                        threads,
                        max_bins,
                        symbol_bits: bits,
                        mean_secs: res.mean_secs,
                        cells_per_sec: cells / res.mean_secs,
                        gb_per_sec: cells * 4.0 / res.mean_secs / 1e9,
                    });
                }
            }
        }
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    // XLA artifact path (correctness engine; tile-sized workload)
    if let Some(dir) = xgb_tpu::runtime::find_artifact_dir(None) {
        let artifacts = xgb_tpu::runtime::Artifacts::load(dir)?;
        let m = artifacts.manifest.clone();
        let bins_tile: Vec<i32> = (0..m.hist_rows * m.hist_slots)
            .map(|i| (i % m.hist_bins) as i32)
            .collect();
        let grads_tile: Vec<f32> = (0..m.hist_rows * 2).map(|i| (i % 3) as f32).collect();
        let tile_cells = (m.hist_rows * m.hist_slots) as f64;
        let r = runner.run("xla/pallas-interpret", || {
            artifacts.histogram_tile(&bins_tile, &grads_tile, 0).unwrap()
        });
        t.add_row(vec![
            "xla pallas (interpret, correctness path)".into(),
            "-".into(),
            "-".into(),
            "1".into(),
            fmt_secs(r.mean_secs),
            format!("{:.2}", tile_cells / r.mean_secs / 1e6),
            "-".into(),
            "-".into(),
        ]);
    } else {
        eprintln!("artifacts not built; skipping XLA row");
    }

    println!("\n=== L1 histogram kernel throughput (scalar vs blocked) ===\n");
    print!("{}", t.render());

    // trajectory artifact: one record per sweep cell, speedup keyed
    // against the scalar cell of the same (builder, threads, max_bins)
    let out_path =
        std::env::var("XGB_BENCH_OUT").unwrap_or_else(|_| "BENCH_kernel.json".to_string());
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"kernel_hist\",\n");
    json.push_str(&format!("  \"rows\": {n},\n"));
    json.push_str(&format!(
        "  \"warmup\": {}, \"iters\": {},\n",
        runner.warmup, runner.iters
    ));
    json.push_str("  \"results\": [\n");
    for (i, c) in cells_out.iter().enumerate() {
        let scalar = cells_out
            .iter()
            .find(|s| {
                s.mode == KernelMode::Scalar
                    && s.builder == c.builder
                    && s.threads == c.threads
                    && s.max_bins == c.max_bins
            })
            .expect("scalar baseline ran first");
        json.push_str(&format!(
            "    {{\"builder\": \"{}\", \"mode\": \"{}\", \"threads\": {}, \"max_bins\": {}, \
             \"symbol_bits\": {}, \"mean_secs\": {:.6e}, \"cells_per_sec\": {:.6e}, \
             \"gb_per_sec\": {:.4}, \"speedup_vs_scalar\": {:.4}}}{}\n",
            c.builder,
            mode_name(c.mode),
            c.threads,
            c.max_bins,
            c.symbol_bits,
            c.mean_secs,
            c.cells_per_sec,
            c.gb_per_sec,
            scalar.mean_secs / c.mean_secs,
            if i + 1 == cells_out.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write(&out_path, &json)?;
    eprintln!("wrote {out_path}");
    Ok(())
}
