//! L1 kernel benchmark: histogram-build throughput of the native builders
//! (the device-compute reference used by the Table 2 / Figure 2 numbers)
//! vs the AOT-compiled Pallas one-hot-matmul artifact through PJRT.
//!
//! NOTE: the artifact runs the kernel in interpret mode on the CPU plugin;
//! its wall-clock here is a correctness path, NOT a TPU performance proxy.
//! The TPU estimate (VMEM footprint, MXU shapes) is static — DESIGN.md §7.

use xgb_tpu::bench::{Runner, Table};
use xgb_tpu::compress::CompressedMatrix;
use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::hist::{build_histogram_compressed, build_histogram_quantized, Histogram};
use xgb_tpu::quantile::{HistogramCuts, Quantizer};
use xgb_tpu::GradPair;

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() -> anyhow::Result<()> {
    let rows = env_usize("XGB_BENCH_ROWS", 100_000);
    let runner = Runner::from_env();
    eprintln!("kernel_hist: rows={rows}");

    let data = generate(&DatasetSpec::higgs_like(rows), 17);
    let n = data.train.n_rows();
    let cuts = HistogramCuts::from_dmatrix(&data.train.x, 256, None);
    let qm = Quantizer::new(cuts.clone()).quantize(&data.train.x);
    let cm = CompressedMatrix::from_quantized(&qm);
    let grads: Vec<GradPair> = (0..n)
        .map(|i| GradPair::new((i % 7) as f32 / 7.0 - 0.5, 1.0))
        .collect();
    let rows_all: Vec<u32> = (0..n as u32).collect();
    let cells = (n * qm.row_stride) as f64;

    let mut t = Table::new(&["engine", "mean", "cells/s (M)", "GB/s (u32 equiv)"]);
    let mut h = Histogram::zeros(qm.n_bins);

    let r1 = runner.run("native/u32", || {
        h = Histogram::zeros(qm.n_bins);
        build_histogram_quantized(&qm, &grads, &rows_all, &mut h);
    });
    t.add_row(vec![
        "native u32 bins".into(),
        xgb_tpu::bench::fmt_secs(r1.mean_secs),
        format!("{:.1}", cells / r1.mean_secs / 1e6),
        format!("{:.2}", cells * 4.0 / r1.mean_secs / 1e9),
    ]);

    let r2 = runner.run("native/packed", || {
        h = Histogram::zeros(qm.n_bins);
        build_histogram_compressed(&cm, &grads, &rows_all, &mut h);
    });
    t.add_row(vec![
        "native bit-packed (§2.2)".into(),
        xgb_tpu::bench::fmt_secs(r2.mean_secs),
        format!("{:.1}", cells / r2.mean_secs / 1e6),
        format!("{:.2}", cells * 4.0 / r2.mean_secs / 1e9),
    ]);

    // XLA artifact path (correctness engine; tile-sized workload)
    if let Some(dir) = xgb_tpu::runtime::find_artifact_dir(None) {
        let artifacts = xgb_tpu::runtime::Artifacts::load(dir)?;
        let m = artifacts.manifest.clone();
        let bins_tile: Vec<i32> = (0..m.hist_rows * m.hist_slots)
            .map(|i| (i % m.hist_bins) as i32)
            .collect();
        let grads_tile: Vec<f32> = (0..m.hist_rows * 2).map(|i| (i % 3) as f32).collect();
        let tile_cells = (m.hist_rows * m.hist_slots) as f64;
        let r3 = runner.run("xla/pallas-interpret", || {
            artifacts.histogram_tile(&bins_tile, &grads_tile, 0).unwrap()
        });
        t.add_row(vec![
            "xla pallas kernel (interpret, correctness path)".into(),
            xgb_tpu::bench::fmt_secs(r3.mean_secs),
            format!("{:.2}", tile_cells / r3.mean_secs / 1e6),
            "-".into(),
        ]);
    } else {
        eprintln!("artifacts not built; skipping XLA row");
    }

    println!("\n=== L1 histogram kernel throughput ===\n");
    print!("{}", t.render());
    println!(
        "\npacked/unpacked ratio: {:.2}x (paper §2.2: \"no visible performance penalty\")",
        r2.mean_secs / r1.mean_secs
    );
    Ok(())
}
