//! A1-runtime ablation: §2.2 claims bit-pack/unpack "incur[s] no visible
//! performance penalty" while cutting memory 4x. Measures histogram-build
//! throughput and end-to-end training over the packed vs unpacked matrix,
//! and the memory saved.

use xgb_tpu::bench::{Runner, Table};
use xgb_tpu::coordinator::{CoordinatorParams, MultiDeviceCoordinator};
use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::gbm::{Learner, LearnerParams, MetricKind, ObjectiveKind};
use xgb_tpu::GradPair;

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() -> anyhow::Result<()> {
    let rows = env_usize("XGB_BENCH_ROWS", 60_000);
    let rounds = env_usize("XGB_BENCH_ROUNDS", 15);
    eprintln!("ablation_compression: rows={rows} rounds={rounds}");
    let runner = Runner::from_env();

    let data = generate(&DatasetSpec::higgs_like(rows), 13);
    let grads: Vec<GradPair> = data
        .train
        .y
        .iter()
        .map(|&y| GradPair::new(0.5 - y, 0.25))
        .collect();

    let mut t = Table::new(&[
        "storage", "matrix MB", "hist build (ms)", "cells/s (M)", "train (s)",
        "valid acc",
    ]);
    for compress in [false, true] {
        let params = CoordinatorParams {
            n_devices: 1,
            compress,
            max_bins: 256,
            // serial engine: cells/sec must measure the storage format,
            // not thread-count-dependent contention
            threads: 1,
            ..Default::default()
        };
        let mut c = MultiDeviceCoordinator::from_dmatrix(&data.train.x, params)?;
        let mb = c.device_bytes().iter().sum::<usize>() as f64 / 1e6;
        // histogram micro-bench: one full root build
        let res = runner.run(format!("hist compress={compress}"), || {
            c.build_tree(&grads).unwrap()
        });
        // full training
        let bp = LearnerParams {
            objective: ObjectiveKind::BinaryLogistic,
            num_rounds: rounds,
            max_bins: 256,
            compress,
            eval_metric: Some(MetricKind::Accuracy),
            eval_every: 0,
            threads: 1,
            ..Default::default()
        };
        let b = Learner::from_params(bp)?.train(&data.train, Some(&data.valid))?;
        let acc = b.eval_history.last().and_then(|r| r.valid).unwrap_or(f64::NAN);
        let stats = c.build_tree(&grads)?.stats;
        let cells_per_sec =
            stats.hist_cells as f64 / stats.hist_secs.iter().sum::<f64>().max(1e-9);
        t.add_row(vec![
            if compress { "packed (§2.2)" } else { "u32 bins" }.into(),
            format!("{mb:.1}"),
            format!("{:.1}", res.mean_secs * 1e3),
            format!("{:.1}", cells_per_sec / 1e6),
            format!("{:.2}", b.train_secs),
            format!("{acc:.3}"),
        ]);
        eprintln!("  compress={compress}: {mb:.1} MB, tree build {:.1} ms", res.mean_secs * 1e3);
    }
    println!("\n=== A1-runtime: compression on/off ===\n");
    print!("{}", t.render());
    println!(
        "\npaper claim: packed form costs ~nothing at runtime while using\n\
         ~4x less memory (here: per-symbol shift/mask on unpack)."
    );
    Ok(())
}
