//! Serving throughput/latency sweep (the tentpole's acceptance bench):
//! push a fixed request stream through the full serve path — line parse
//! → bounded queue → micro-batch coalescing → FlatForest traversal →
//! ordered reply writer — for every (batch_max × threads) grid point,
//! and report rows/sec with the latency histogram's p50/p99 and the
//! observed batch-size distribution. Every grid point also re-checks
//! the determinism contract: the stream checksum must equal
//! `prediction_checksum` of `Booster::predict` on the same rows.
//!
//! Knobs: `XGB_BENCH_ROWS` (training rows, default 4000),
//! `XGB_BENCH_REQUESTS` (request lines, default 20000),
//! `XGB_BENCH_OUT` (artifact path, default `BENCH_serving.json`).

use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Instant;

use xgb_tpu::bench::Table;
use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::data::DMatrix;
use xgb_tpu::gbm::{Learner, LearnerParams};
use xgb_tpu::predict::prediction_checksum;
use xgb_tpu::serve::{ModelRegistry, ServeOptions, Server};
use xgb_tpu::Float;

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() -> anyhow::Result<()> {
    let rows = env_usize("XGB_BENCH_ROWS", 4000);
    let n_requests = env_usize("XGB_BENCH_REQUESTS", 20_000);
    eprintln!("serve_throughput: rows={rows} requests={n_requests}");

    // one model, one request stream, reused across the whole grid
    let g = generate(&DatasetSpec::higgs_like(rows), 42);
    let params = LearnerParams {
        objective: "binary:logistic".parse().expect("infallible"),
        num_rounds: 10,
        max_depth: 5,
        max_bins: 64,
        eval_every: 0,
        ..Default::default()
    };
    let booster = Learner::from_params(params)?.train(&g.train, None)?;
    let model_path = std::env::temp_dir().join(format!(
        "xgb_tpu_serve_bench_{}.txt",
        std::process::id()
    ));
    xgb_tpu::gbm::save_model_file(&booster, &model_path)?;

    // request lines cycle the valid matrix; the parity reference is
    // `predict` over the identical row sequence
    let src = &g.valid.x;
    let cols = src.n_cols();
    let mut input = String::new();
    let mut vals: Vec<Float> = Vec::with_capacity(n_requests * cols);
    for i in 0..n_requests {
        let r = i % src.n_rows();
        for c in 0..cols {
            let v = src.get(r, c).unwrap_or(Float::NAN);
            vals.push(v);
            if c > 0 {
                input.push(',');
            }
            let _ = write!(input, "{v}");
        }
        input.push('\n');
    }
    let expected = booster.predict(&DMatrix::dense(vals, n_requests, cols));
    let want_checksum = prediction_checksum(&expected);

    let mut t = Table::new(&[
        "batch_max", "threads", "rows/s", "p50 us", "p99 us", "mean batch", "batches",
    ]);
    let mut json_rows: Vec<String> = Vec::new();
    for &batch_max in &[1usize, 16, 64, 256] {
        for &threads in &[1usize, 4] {
            let registry = Arc::new(ModelRegistry::open(&model_path)?);
            let opts = ServeOptions {
                batch_max,
                threads,
                ..Default::default()
            };
            let server = Server::start(registry, opts, None);
            let mut sink: Vec<u8> = Vec::with_capacity(n_requests * 12);
            let start = Instant::now();
            let summary = server.serve_stream(input.as_bytes(), &mut sink)?;
            let secs = start.elapsed().as_secs_f64();
            let stats = server.shutdown();
            assert_eq!(summary.served, n_requests as u64);
            assert_eq!(
                summary.checksum, want_checksum,
                "b={batch_max} t={threads}: served bits must match predict"
            );
            let rows_per_sec = n_requests as f64 / secs.max(1e-9);
            t.add_row(vec![
                format!("{batch_max}"),
                format!("{threads}"),
                format!("{rows_per_sec:.0}"),
                format!("{}", stats.p50_us),
                format!("{}", stats.p99_us),
                format!("{:.2}", stats.mean_batch()),
                format!("{}", stats.batches),
            ]);
            eprintln!(
                "  batch_max={batch_max} threads={threads}: {rows_per_sec:.0} rows/s, \
                 p50<={}us p99<={}us, mean batch {:.2}",
                stats.p50_us,
                stats.p99_us,
                stats.mean_batch()
            );
            json_rows.push(format!(
                "    {{\"batch_max\": {batch_max}, \"threads\": {threads}, \
                 \"rows_per_sec\": {rows_per_sec:.1}, \"secs\": {secs:.6}, \
                 \"p50_us\": {}, \"p90_us\": {}, \"p99_us\": {}, \"mean_us\": {:.2}, \
                 \"max_us\": {}, \"mean_batch\": {:.3}, \"batches\": {}, \
                 \"queue_depth_max\": {}, \"checksum_ok\": true}}",
                stats.p50_us,
                stats.p90_us,
                stats.p99_us,
                stats.mean_us,
                stats.max_us,
                stats.mean_batch(),
                stats.batches,
                stats.queue_depth_max,
            ));
        }
    }
    println!("\n=== serve throughput: {n_requests} requests, {cols}-feature rows ===\n");
    print!("{}", t.render());
    println!(
        "\nevery grid point's stream checksum matched predict's \
         ({want_checksum:#018x}) — batching and threading change latency only"
    );

    let out_path =
        std::env::var("XGB_BENCH_OUT").unwrap_or_else(|_| "BENCH_serving.json".to_string());
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"serve_throughput\",\n");
    json.push_str(&format!("  \"train_rows\": {rows},\n"));
    json.push_str(&format!("  \"requests\": {n_requests},\n"));
    json.push_str(&format!("  \"features\": {cols},\n"));
    json.push_str(&format!("  \"checksum\": \"{want_checksum:#018x}\",\n"));
    json.push_str("  \"grid\": [\n");
    json.push_str(&json_rows.join(",\n"));
    json.push_str("\n  ]\n}\n");
    std::fs::write(&out_path, &json)?;
    eprintln!("wrote {out_path}");
    std::fs::remove_file(&model_path).ok();
    Ok(())
}
