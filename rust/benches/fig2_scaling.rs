//! Figure 2 reproduction: XGBoost runtime on the Airline dataset, 1–8
//! devices. Prints the measured-compute + modeled-communication series
//! (DESIGN.md §5) and the closed-form analytic projection, plus the
//! paper-shape check (monotone decrease, diminishing returns).
//!
//! Also sweeps the **real parallel engine** (`--threads`-style knob) at a
//! fixed device count and emits `BENCH_scaling.json` (override the path
//! with `XGB_BENCH_OUT`): measured histogram+partition wall-clock,
//! rows/sec and speedup vs 1 thread — the perf baseline for future PRs.

use xgb_tpu::bench::Table;
use xgb_tpu::comm::CostModel;
use xgb_tpu::coordinator::builder::project_scaling;
use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::exec::{set_exec_mode_override, ExecMode};
use xgb_tpu::gbm::{Learner, LearnerParams, ObjectiveKind};

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() -> anyhow::Result<()> {
    let rows = env_usize("XGB_BENCH_FIG2_ROWS", 200_000);
    let rounds = env_usize("XGB_BENCH_ROUNDS", 20);
    eprintln!("fig2: airline-like rows={rows} rounds={rounds}");

    let data = generate(&DatasetSpec::airline_like(rows), 1);
    let mut table = Table::new(&[
        "devices", "simulated (s)", "speedup", "analytic (s)", "hist max/dev (s)",
        "allreduce (s)",
    ]);

    let mut results: Vec<(usize, f64)> = Vec::new();
    let mut t1 = 0.0;
    let mut single_compute = 0.0;
    let mut hist_elems = 0usize;
    let mut hist_rounds = 0usize;
    for p in [1usize, 2, 3, 4, 5, 6, 7, 8] {
        let params = LearnerParams {
            objective: ObjectiveKind::BinaryLogistic,
            num_rounds: rounds,
            max_bins: 256,
            max_depth: 6,
            n_devices: p,
            compress: true,
            eval_every: 0,
            // pin the engine serial so per-device compute (the simulated
            // clock's input) is measured single-threaded, as in the paper
            threads: 1,
            ..Default::default()
        };
        let b = Learner::from_params(params)?.train(&data.train, None)?;
        let s = &b.build_stats;
        if p == 1 {
            t1 = b.simulated_secs;
            single_compute = s.total_compute_secs();
            hist_elems = 2 * (s.comm_bytes_per_device / 8).max(1); // approx per-round
            hist_rounds = s.hist_rounds;
        }
        let analytic = project_scaling(
            single_compute,
            if hist_rounds > 0 { hist_elems / hist_rounds.max(1) } else { 0 },
            hist_rounds,
            p,
            &CostModel::default(),
        );
        table.add_row(vec![
            format!("{p}"),
            format!("{:.3}", b.simulated_secs),
            format!("{:.2}x", t1 / b.simulated_secs),
            format!("{analytic:.3}"),
            format!("{:.3}", s.hist_secs.iter().cloned().fold(0.0, f64::max)),
            format!("{:.4}", s.allreduce_sim_secs),
        ]);
        results.push((p, b.simulated_secs));
        eprintln!("  p={p}: simulated {:.3}s", b.simulated_secs);
    }

    println!("\n=== Figure 2: runtime vs devices (airline-like) ===\n");
    print!("{}", table.render());

    // paper-shape checks
    let t8 = results.last().unwrap().1;
    let monotone_mostly = results.windows(2).filter(|w| w[1].1 <= w[0].1 * 1.05).count();
    println!("\nshape checks:");
    println!(
        "  [\u{2713}?] runtime falls 1->8 devices: {:.3}s -> {:.3}s ({:.2}x, paper fig2 ~4-5x at 8 GPUs)",
        t1, t8, t1 / t8
    );
    println!(
        "  [{}] near-monotone decrease: {}/{} steps non-increasing",
        if monotone_mostly >= 5 { "ok" } else { "DIFF" },
        monotone_mostly,
        results.len() - 1
    );
    let mid = results[3].1; // p=4
    println!(
        "  [{}] diminishing returns: speedup(4)={:.2}x vs speedup(8)={:.2}x",
        if (t1 / mid) / 4.0 > (t1 / t8) / 8.0 { "ok" } else { "DIFF" },
        t1 / mid,
        t1 / t8
    );

    // === real parallel engine: thread sweep at a fixed device count ===
    let devices = 4usize;
    let thread_counts = [1usize, 2, 4, 8];
    let mut sweep: Vec<(usize, f64, f64, f64, f64)> = Vec::new();
    let mut thread_table = Table::new(&[
        "threads",
        "hist wall (s)",
        "partition wall (s)",
        "device wall (s)",
        "rows/sec",
        "speedup",
    ]);
    for &t in &thread_counts {
        let params = LearnerParams {
            objective: ObjectiveKind::BinaryLogistic,
            num_rounds: rounds,
            max_bins: 256,
            max_depth: 6,
            n_devices: devices,
            compress: true,
            eval_every: 0,
            threads: t,
            ..Default::default()
        };
        let b = Learner::from_params(params)?.train(&data.train, None)?;
        let s = &b.build_stats;
        let wall = s.device_wall_secs();
        let rows_per_sec = (data.train.n_rows() * b.n_rounds()) as f64 / wall.max(1e-9);
        let w1 = sweep.first().map(|e| e.3).unwrap_or(wall);
        let speedup = w1 / wall.max(1e-9);
        thread_table.add_row(vec![
            format!("{t}"),
            format!("{:.3}", s.hist_wall_secs),
            format!("{:.3}", s.partition_wall_secs),
            format!("{wall:.3}"),
            format!("{rows_per_sec:.0}"),
            format!("{speedup:.2}x"),
        ]);
        sweep.push((t, s.hist_wall_secs, s.partition_wall_secs, wall, rows_per_sec));
        eprintln!("  threads={t}: device wall {wall:.3}s ({rows_per_sec:.0} rows/sec)");
    }

    println!(
        "\n=== Real engine: hist+partition wall-clock vs threads ({devices} devices) ===\n"
    );
    print!("{}", thread_table.render());
    let w1 = sweep[0].3;
    let w4 = sweep.iter().find(|e| e.0 == 4).map(|e| e.3).unwrap_or(w1);
    println!(
        "\n  [{}] acceptance: threads=4 wall {:.3}s vs threads=1 {:.3}s ({:.2}x, target >= 2x)",
        if w1 / w4.max(1e-9) >= 2.0 { "ok" } else { "DIFF" },
        w4,
        w1,
        w1 / w4.max(1e-9)
    );

    // === exec engine: scoped spawn-per-call vs persistent parked pool ===
    let exec_threads = 4usize;
    // (engine, train s, wake s, wake ms/round, rounds/sec, allocs/round)
    let mut engines: Vec<(&str, f64, f64, f64, f64, f64)> = Vec::new();
    let mut engine_table = Table::new(&[
        "engine",
        "train (s)",
        "wake/spawn (s)",
        "overhead/round (us)",
        "rounds/sec",
        "allocs/round",
        "arena reuse (MB)",
    ]);
    for (name, mode) in [
        ("scoped", ExecMode::Scoped),
        ("persistent", ExecMode::Persistent),
    ] {
        set_exec_mode_override(Some(mode));
        let params = LearnerParams {
            objective: ObjectiveKind::BinaryLogistic,
            num_rounds: rounds,
            max_bins: 256,
            max_depth: 6,
            n_devices: devices,
            compress: true,
            eval_every: 0,
            threads: exec_threads,
            ..Default::default()
        };
        let b = Learner::from_params(params)?.train(&data.train, None)?;
        set_exec_mode_override(None);
        let s = &b.build_stats;
        let per_round_us = if s.hist_rounds > 0 {
            s.wake_wall_secs / s.hist_rounds as f64 * 1e6
        } else {
            0.0
        };
        let allocs_per_round = if s.hist_rounds > 0 {
            s.arena_allocs as f64 / s.hist_rounds as f64
        } else {
            0.0
        };
        let rounds_per_sec = b.n_rounds() as f64 / b.train_secs.max(1e-9);
        engine_table.add_row(vec![
            name.to_string(),
            format!("{:.3}", b.train_secs),
            format!("{:.4}", s.wake_wall_secs),
            format!("{per_round_us:.1}"),
            format!("{rounds_per_sec:.2}"),
            format!("{allocs_per_round:.1}"),
            format!("{:.2}", s.arena_bytes_reused as f64 / 1e6),
        ]);
        engines.push((
            name,
            b.train_secs,
            s.wake_wall_secs,
            per_round_us,
            rounds_per_sec,
            allocs_per_round,
        ));
        eprintln!(
            "  engine={name}: train {:.3}s wake {:.4}s ({per_round_us:.1} us/round)",
            b.train_secs, s.wake_wall_secs
        );
    }

    println!(
        "\n=== Exec engine: scoped spawn-per-call vs persistent pool \
         ({devices} devices, {exec_threads} threads) ===\n"
    );
    print!("{}", engine_table.render());

    // machine-readable trajectory for future PRs
    let out_path =
        std::env::var("XGB_BENCH_OUT").unwrap_or_else(|_| "BENCH_scaling.json".to_string());
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"fig2_scaling\",\n");
    json.push_str(&format!("  \"rows\": {rows},\n"));
    json.push_str(&format!("  \"rounds\": {rounds},\n"));
    json.push_str(&format!("  \"devices\": {devices},\n"));
    json.push_str("  \"simulated_secs_by_device\": [");
    for (i, (p, secs)) in results.iter().enumerate() {
        if i > 0 {
            json.push_str(", ");
        }
        json.push_str(&format!("{{\"devices\": {p}, \"simulated_secs\": {secs:.6}}}"));
    }
    json.push_str("],\n");
    json.push_str("  \"thread_sweep\": [");
    for (i, (t, hist, part, wall, rps)) in sweep.iter().enumerate() {
        if i > 0 {
            json.push_str(", ");
        }
        json.push_str(&format!(
            "{{\"threads\": {t}, \"hist_wall_secs\": {hist:.6}, \
             \"partition_wall_secs\": {part:.6}, \"device_wall_secs\": {wall:.6}, \
             \"rows_per_sec\": {rps:.1}, \"speedup_vs_1\": {:.4}}}",
            w1 / wall.max(1e-9)
        ));
    }
    json.push_str("],\n");
    json.push_str(&format!("  \"exec_threads\": {exec_threads},\n"));
    json.push_str("  \"exec_mode_sweep\": [");
    for (i, (name, train, wake, per_round_us, rps, apr)) in engines.iter().enumerate() {
        if i > 0 {
            json.push_str(", ");
        }
        json.push_str(&format!(
            "{{\"engine\": \"{name}\", \"train_secs\": {train:.6}, \
             \"wake_wall_secs\": {wake:.6}, \"wake_us_per_round\": {per_round_us:.3}, \
             \"rounds_per_sec\": {rps:.4}, \"allocs_per_round\": {apr:.2}}}"
        ));
    }
    json.push_str("]\n}\n");
    std::fs::write(&out_path, &json)?;
    println!("\nwrote {out_path}");
    Ok(())
}
