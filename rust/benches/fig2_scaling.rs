//! Figure 2 reproduction: XGBoost runtime on the Airline dataset, 1–8
//! devices. Prints the measured-compute + modeled-communication series
//! (DESIGN.md §5) and the closed-form analytic projection, plus the
//! paper-shape check (monotone decrease, diminishing returns).

use xgb_tpu::bench::Table;
use xgb_tpu::comm::CostModel;
use xgb_tpu::coordinator::builder::project_scaling;
use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::gbm::{Learner, LearnerParams, ObjectiveKind};

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() -> anyhow::Result<()> {
    let rows = env_usize("XGB_BENCH_FIG2_ROWS", 200_000);
    let rounds = env_usize("XGB_BENCH_ROUNDS", 20);
    eprintln!("fig2: airline-like rows={rows} rounds={rounds}");

    let data = generate(&DatasetSpec::airline_like(rows), 1);
    let mut table = Table::new(&[
        "devices", "simulated (s)", "speedup", "analytic (s)", "hist max/dev (s)",
        "allreduce (s)",
    ]);

    let mut results: Vec<(usize, f64)> = Vec::new();
    let mut t1 = 0.0;
    let mut single_compute = 0.0;
    let mut hist_elems = 0usize;
    let mut hist_rounds = 0usize;
    for p in [1usize, 2, 3, 4, 5, 6, 7, 8] {
        let params = LearnerParams {
            objective: ObjectiveKind::BinaryLogistic,
            num_rounds: rounds,
            max_bins: 256,
            max_depth: 6,
            n_devices: p,
            compress: true,
            eval_every: 0,
            ..Default::default()
        };
        let b = Learner::from_params(params)?.train(&data.train, None)?;
        let s = &b.build_stats;
        if p == 1 {
            t1 = b.simulated_secs;
            single_compute = s.total_compute_secs();
            hist_elems = 2 * (s.comm_bytes_per_device / 8).max(1); // approx per-round
            hist_rounds = s.hist_rounds;
        }
        let analytic = project_scaling(
            single_compute,
            if hist_rounds > 0 { hist_elems / hist_rounds.max(1) } else { 0 },
            hist_rounds,
            p,
            &CostModel::default(),
        );
        table.add_row(vec![
            format!("{p}"),
            format!("{:.3}", b.simulated_secs),
            format!("{:.2}x", t1 / b.simulated_secs),
            format!("{analytic:.3}"),
            format!("{:.3}", s.hist_secs.iter().cloned().fold(0.0, f64::max)),
            format!("{:.4}", s.allreduce_sim_secs),
        ]);
        results.push((p, b.simulated_secs));
        eprintln!("  p={p}: simulated {:.3}s", b.simulated_secs);
    }

    println!("\n=== Figure 2: runtime vs devices (airline-like) ===\n");
    print!("{}", table.render());

    // paper-shape checks
    let t8 = results.last().unwrap().1;
    let monotone_mostly = results.windows(2).filter(|w| w[1].1 <= w[0].1 * 1.05).count();
    println!("\nshape checks:");
    println!(
        "  [\u{2713}?] runtime falls 1->8 devices: {:.3}s -> {:.3}s ({:.2}x, paper fig2 ~4-5x at 8 GPUs)",
        t1, t8, t1 / t8
    );
    println!(
        "  [{}] near-monotone decrease: {}/{} steps non-increasing",
        if monotone_mostly >= 5 { "ok" } else { "DIFF" },
        monotone_mostly,
        results.len() - 1
    );
    let mid = results[3].1; // p=4
    println!(
        "  [{}] diminishing returns: speedup(4)={:.2}x vs speedup(8)={:.2}x",
        if (t1 / mid) / 4.0 > (t1 / t8) / 8.0 { "ok" } else { "DIFF" },
        t1 / mid,
        t1 / t8
    );
    Ok(())
}
