//! A2 ablation: the §2.3 "reconfigurable growth strategy" — depth-wise
//! (expand closest to root) vs loss-guided (expand highest gain) on equal
//! leaf budgets: time, tree shape, accuracy.

use xgb_tpu::bench::Table;
use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::gbm::{Learner, LearnerParams, MetricKind, ObjectiveKind};

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() -> anyhow::Result<()> {
    let rows = env_usize("XGB_BENCH_ROWS", 40_000);
    let rounds = env_usize("XGB_BENCH_ROUNDS", 30);
    eprintln!("ablation_growth: rows={rows} rounds={rounds}");

    let data = generate(&DatasetSpec::higgs_like(rows), 3);
    let mut t = Table::new(&[
        "policy", "constraint", "time (s)", "valid acc", "mean leaves/tree",
        "mean depth",
    ]);

    for (policy, max_depth, max_leaves, label) in [
        ("depthwise", 6usize, 0usize, "max_depth=6"),
        ("lossguide", 0, 64, "max_leaves=64"),
        ("depthwise", 4, 0, "max_depth=4"),
        ("lossguide", 0, 16, "max_leaves=16"),
    ] {
        let params = LearnerParams {
            objective: ObjectiveKind::BinaryLogistic,
            num_rounds: rounds,
            max_bins: 64,
            max_depth,
            max_leaves,
            grow_policy: policy.parse().map_err(|e: String| anyhow::anyhow!(e))?,
            eval_metric: Some(MetricKind::Accuracy),
            eval_every: 0,
            // serial engine keeps the policy comparison's timings stable
            threads: 1,
            ..Default::default()
        };
        let b = Learner::from_params(params)?.train(&data.train, Some(&data.valid))?;
        let acc = b.eval_history.last().and_then(|r| r.valid).unwrap_or(f64::NAN);
        let trees = &b.trees[0];
        let leaves: f64 =
            trees.iter().map(|t| t.n_leaves() as f64).sum::<f64>() / trees.len() as f64;
        let depth: f64 =
            trees.iter().map(|t| t.max_depth() as f64).sum::<f64>() / trees.len() as f64;
        t.add_row(vec![
            policy.into(),
            label.into(),
            format!("{:.2}", b.train_secs),
            format!("{acc:.3}"),
            format!("{leaves:.1}"),
            format!("{depth:.1}"),
        ]);
        eprintln!("  {policy} {label}: {:.2}s acc={acc:.3}", b.train_secs);
    }
    println!("\n=== A2: growth policy ablation (§2.3) ===\n");
    print!("{}", t.render());
    println!(
        "\nexpected shape: lossguide reaches deeper, more unbalanced trees for\n\
         the same leaf count; accuracy comparable on tabular data of this kind."
    );
    Ok(())
}
