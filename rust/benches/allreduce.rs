//! Collective benchmark: the exact ring all-reduce simulation vs the
//! gather+broadcast reference, host execution time and modeled NCCL-ring
//! wall-clock across device counts and histogram sizes (§2.3's
//! `AllReduceHistograms` step).

use xgb_tpu::bench::{fmt_secs, Runner, Table};
use xgb_tpu::comm::{allreduce, AllReduceAlgo, CostModel};
use xgb_tpu::util::Pcg64;

fn main() -> anyhow::Result<()> {
    let runner = Runner::from_env();
    let cost = CostModel::default();
    let mut t = Table::new(&[
        "algo", "devices", "hist elems", "host time", "modeled GPU time",
        "bytes/device",
    ]);

    // histogram sizes: 256 bins x 28 feats x 2 = 14k elems (higgs-like),
    // and a big 968-feature bosch-like one
    for &n in &[14_336usize, 123_904] {
        for &p in &[2usize, 4, 8] {
            for algo in [AllReduceAlgo::Ring, AllReduceAlgo::Serial] {
                let mut rng = Pcg64::new((n + p) as u64);
                let template: Vec<Vec<f64>> = (0..p)
                    .map(|_| (0..n).map(|_| rng.next_f64()).collect())
                    .collect();
                let mut stats = None;
                let res = runner.run(format!("{algo:?}/p{p}/n{n}"), || {
                    let mut bufs = template.clone();
                    stats = Some(allreduce(algo, &mut bufs));
                    bufs
                });
                let stats = stats.unwrap();
                t.add_row(vec![
                    format!("{algo:?}"),
                    format!("{p}"),
                    format!("{n}"),
                    fmt_secs(res.mean_secs),
                    fmt_secs(cost.time(&stats)),
                    format!("{}", stats.bytes_per_device),
                ]);
            }
        }
    }
    println!("\n=== AllReduce: ring vs serial ===\n");
    print!("{}", t.render());
    println!(
        "\nshape: ring bytes/device ~ 2(p-1)/p * n * 8 (constant-ish in p);\n\
         serial leader traffic grows linearly in p -> ring wins at scale,\n\
         which is why the paper uses NCCL's ring."
    );
    Ok(())
}
