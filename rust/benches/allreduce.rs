//! Collective benchmark: the exact ring all-reduce simulation vs the
//! gather+broadcast reference, host execution time and modeled NCCL-ring
//! wall-clock across device counts and histogram sizes (§2.3's
//! `AllReduceHistograms` step) — plus the real TCP wire ring over
//! loopback, comparing quantised vs raw chunk encodings by measured
//! wire bytes.

use std::net::TcpListener;

use xgb_tpu::bench::{fmt_secs, Runner, Table};
use xgb_tpu::comm::{allreduce, AllReduceAlgo, CostModel, WirePayload, WireRing};
use xgb_tpu::util::Pcg64;

/// Run one wire-ring all-reduce with `p` in-process ranks over loopback;
/// returns (wall seconds, max bytes actually sent by any rank).
fn wire_round(p: usize, template: &[Vec<f64>], payload: WirePayload) -> (f64, usize) {
    let listeners: Vec<TcpListener> = (0..p)
        .map(|_| TcpListener::bind("127.0.0.1:0").expect("bind loopback"))
        .collect();
    let peers: Vec<String> = listeners
        .iter()
        .map(|l| l.local_addr().unwrap().to_string())
        .collect();
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = listeners
        .into_iter()
        .enumerate()
        .map(|(r, listener)| {
            let peers = peers.clone();
            let mut buf = template[r].clone();
            std::thread::spawn(move || {
                let mut ring = WireRing::establish_with_listener(r, &peers, listener, payload)
                    .expect("ring assembly");
                ring.allreduce(&mut buf).expect("wire allreduce")
            })
        })
        .collect();
    let max_sent = handles
        .into_iter()
        .map(|h| h.join().expect("rank thread").bytes_sent)
        .max()
        .unwrap_or(0);
    (t0.elapsed().as_secs_f64(), max_sent)
}

fn main() -> anyhow::Result<()> {
    let runner = Runner::from_env();
    let cost = CostModel::default();
    let mut t = Table::new(&[
        "algo", "devices", "hist elems", "host time", "modeled GPU time",
        "bytes/device",
    ]);

    // histogram sizes: 256 bins x 28 feats x 2 = 14k elems (higgs-like),
    // and a big 968-feature bosch-like one
    for &n in &[14_336usize, 123_904] {
        for &p in &[2usize, 4, 8] {
            for algo in [AllReduceAlgo::Ring, AllReduceAlgo::Serial] {
                let mut rng = Pcg64::new((n + p) as u64);
                let template: Vec<Vec<f64>> = (0..p)
                    .map(|_| (0..n).map(|_| rng.next_f64()).collect())
                    .collect();
                let mut stats = None;
                let res = runner.run(format!("{algo:?}/p{p}/n{n}"), || {
                    let mut bufs = template.clone();
                    stats = Some(allreduce(algo, &mut bufs));
                    bufs
                });
                let stats = stats.unwrap();
                t.add_row(vec![
                    format!("{algo:?}"),
                    format!("{p}"),
                    format!("{n}"),
                    fmt_secs(res.mean_secs),
                    fmt_secs(cost.time(&stats)),
                    format!("{}", stats.bytes_per_device),
                ]);
            }
        }
    }
    println!("\n=== AllReduce: ring vs serial ===\n");
    print!("{}", t.render());
    println!(
        "\nshape: ring bytes/device ~ 2(p-1)/p * n * 8 (constant-ish in p);\n\
         serial leader traffic grows linearly in p -> ring wins at scale,\n\
         which is why the paper uses NCCL's ring."
    );

    // real TCP ring over loopback: histogram-shaped buffers (40% empty
    // bins, f32-origin sums) so the quant codec's mask + narrow packing
    // shows its wire-byte cut vs plain f64 chunks
    let mut wt = Table::new(&[
        "payload", "ranks", "hist elems", "wall time", "max wire bytes/rank",
        "vs raw",
    ]);
    for &n in &[14_336usize, 123_904] {
        for &p in &[2usize, 4] {
            let mut rng = Pcg64::new((n * p) as u64);
            let template: Vec<Vec<f64>> = (0..p)
                .map(|_| {
                    (0..n)
                        .map(|_| {
                            if rng.next_u32() % 5 < 2 {
                                0.0
                            } else {
                                (rng.next_f32() * 2.0 - 1.0) as f64
                            }
                        })
                        .collect()
                })
                .collect();
            let (_, raw_bytes) = wire_round(p, &template, WirePayload::Raw);
            for payload in [WirePayload::Raw, WirePayload::Quant] {
                let mut max_sent = 0;
                let res = runner.run(format!("wire-{payload}/p{p}/n{n}"), || {
                    let (secs, sent) = wire_round(p, &template, payload);
                    max_sent = sent;
                    secs
                });
                wt.add_row(vec![
                    format!("{payload}"),
                    format!("{p}"),
                    format!("{n}"),
                    fmt_secs(res.mean_secs),
                    format!("{max_sent}"),
                    format!("{:.0}%", max_sent as f64 / raw_bytes as f64 * 100.0),
                ]);
            }
        }
    }
    println!("\n=== Wire ring (TCP loopback): quant vs raw chunk encoding ===\n");
    print!("{}", wt.render());
    println!(
        "\nquant packs each chunk losslessly (zero-bin mask + trailing-zero\n\
         shift + narrowest-width symbols), so its wire bytes land well under\n\
         raw f64 on histogram-shaped data while the merged buffers stay\n\
         bit-identical in both modes."
    );
    Ok(())
}
