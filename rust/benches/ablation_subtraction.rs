//! A3 ablation: the histogram subtraction trick (sibling = parent − built
//! child). With it, each split costs one histogram build over the smaller
//! child; without it, both children are built — ~2x the histogram cells on
//! balanced trees, more on skewed ones.

use xgb_tpu::bench::Table;
use xgb_tpu::coordinator::{CoordinatorParams, MultiDeviceCoordinator};
use xgb_tpu::data::synthetic::{generate, DatasetSpec};
use xgb_tpu::tree::TreeParams;
use xgb_tpu::GradPair;

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() -> anyhow::Result<()> {
    let rows = env_usize("XGB_BENCH_ROWS", 60_000);
    let trees = env_usize("XGB_BENCH_TREES", 10);
    eprintln!("ablation_subtraction: rows={rows} trees={trees}");

    let data = generate(&DatasetSpec::higgs_like(rows), 9);
    let grads: Vec<GradPair> = data
        .train
        .y
        .iter()
        .map(|&y| GradPair::new(0.5 - y, 0.25))
        .collect();

    let mut t = Table::new(&[
        "subtraction", "hist rounds", "hist cells (M)", "hist time (s)",
        "simulated (s)", "identical trees",
    ]);
    let mut results = Vec::new();
    for subtraction in [true, false] {
        let params = CoordinatorParams {
            n_devices: 1,
            compress: false,
            subtraction,
            // serial engine: the ablation compares histogram work, so the
            // simulated clock must be contention-free
            threads: 1,
            max_bins: 64,
            tree: TreeParams {
                max_depth: 6,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut c = MultiDeviceCoordinator::from_dmatrix(&data.train.x, params)?;
        let mut stats = xgb_tpu::coordinator::BuildStats::default();
        let mut built = Vec::new();
        for _ in 0..trees {
            let r = c.build_tree(&grads)?;
            stats.accumulate(&r.stats);
            built.push(r.tree);
        }
        results.push((subtraction, stats, built));
    }

    let same = results[0].2 == results[1].2;
    for (subtraction, stats, _) in &results {
        t.add_row(vec![
            format!("{subtraction}"),
            format!("{}", stats.hist_rounds),
            format!("{:.1}", stats.hist_cells as f64 / 1e6),
            format!("{:.3}", stats.hist_secs.iter().sum::<f64>()),
            format!("{:.3}", stats.simulated_secs),
            format!("{same}"),
        ]);
    }
    println!("\n=== A3: subtraction trick ablation ===\n");
    print!("{}", t.render());
    let with = &results[0].1;
    let without = &results[1].1;
    println!(
        "\ncells without/with = {:.2}x (expected ~1.5-2x); trees identical: {same}",
        without.hist_cells as f64 / with.hist_cells as f64
    );
    assert!(same, "the trick must not change results");
    Ok(())
}
