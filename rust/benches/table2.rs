//! Table 2 reproduction: training time and accuracy for six systems on the
//! six Table-1 datasets.
//!
//! Systems:
//! * `xgb-cpu-hist`  — this crate's booster, 1 device, measured wall-clock.
//! * `xgb-gpu-hist`  — the paper's contribution: 8 simulated devices with
//!   compression; time = the simulated multi-device clock (measured
//!   per-shard compute + ring-all-reduce cost model, DESIGN.md §5).
//! * `lightgbm-cpu`  — leaf-wise + GOSS re-implementation, measured.
//! * `lightgbm-gpu`  — modeled: LightGBM's GPU code accelerates histogram
//!   construction only and pays a per-histogram launch overhead, which is
//!   why the paper shows it *slower* than its own CPU on several datasets.
//!   model: t = other + partition + hist/HIST_SPEEDUP + rounds·OVERHEAD.
//! * `cat-cpu`       — oblivious-tree re-implementation, measured.
//! * `cat-gpu`       — modeled: CatBoost's symmetric trees map extremely
//!   well to GPU (one histogram pass per level, massive leaves);
//!   model: t = other + partition/8 + hist/CAT_GPU_SPEEDUP + rounds·OVERHEAD.
//!   Reported N/A for multiclass (unsupported, as in the paper).
//!
//! Accuracy columns are measured from the actually-trained models in all
//! six rows (the GPU models change time only — the algorithms are
//! identical, as they are in the real packages).
//!
//! Scale: rows default to paper × `XGB_BENCH_SCALE` (default 0.002) and
//! `XGB_BENCH_ROUNDS` boosting rounds (default 50; paper used 500).
//! Absolute times are incomparable to the paper's testbed (1 core here);
//! the reproduction targets are the *orderings and ratios* — see
//! EXPERIMENTS.md §T2.

use xgb_tpu::baselines::{
    train_catboost_like, train_lightgbm_like, CatBoostParams, LightGbmParams,
};
use xgb_tpu::bench::Table;
use xgb_tpu::data::synthetic::{generate, DatasetSpec, Task};
use xgb_tpu::gbm::{Learner, LearnerParams};

fn env_f64(k: &str, d: f64) -> f64 {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}
fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

// GPU execution-model constants (documented above; ablate via env).
const LGB_GPU_HIST_SPEEDUP: f64 = 4.0;
const LGB_GPU_ROUND_OVERHEAD: f64 = 120e-6; // per histogram build
const CAT_GPU_HIST_SPEEDUP: f64 = 24.0;
const CAT_GPU_ROUND_OVERHEAD: f64 = 60e-6;

struct Row {
    system: &'static str,
    time: Option<f64>,
    score: Option<f64>,
}

fn main() -> anyhow::Result<()> {
    let scale = env_f64("XGB_BENCH_SCALE", 0.002);
    let rounds = env_usize("XGB_BENCH_ROUNDS", 50);
    let max_bins = env_usize("XGB_BENCH_BINS", 64);
    eprintln!("table2: scale={scale} rounds={rounds} max_bins={max_bins} (paper: full data, 500 rounds)");

    let paper: &[(&str, [(&str, f64, f64); 6])] = &[
        ("YearPredictionMSD", [
            ("xgb-cpu-hist", 216.71, 8.8794), ("xgb-gpu-hist", 30.39, 8.8799),
            ("lightgbm-cpu", 30.82, 8.8777), ("lightgbm-gpu", 25.39, 8.8777),
            ("cat-cpu", 39.93, 8.9933), ("cat-gpu", 10.15, 9.0637)]),
        ("Synthetic", [
            ("xgb-cpu-hist", 580.72, 13.6105), ("xgb-gpu-hist", 43.14, 13.4606),
            ("lightgbm-cpu", 463.79, 13.585), ("lightgbm-gpu", 576.67, 13.585),
            ("cat-cpu", 426.31, 9.387), ("cat-gpu", 36.66, 9.3805)]),
        ("Higgs", [
            ("xgb-cpu-hist", 509.29, 74.74), ("xgb-gpu-hist", 38.41, 74.75),
            ("lightgbm-cpu", 330.25, 74.74), ("lightgbm-gpu", 725.91, 74.70),
            ("cat-cpu", 393.21, 74.06), ("cat-gpu", 30.37, 74.08)]),
        ("Cover Type", [
            ("xgb-cpu-hist", 3532.26, 89.20), ("xgb-gpu-hist", 107.70, 89.34),
            ("lightgbm-cpu", 186.27, 89.28), ("lightgbm-gpu", 383.03, 89.26),
            ("cat-cpu", 306.17, 85.14), ("cat-gpu", f64::NAN, f64::NAN)]),
        ("Bosch", [
            ("xgb-cpu-hist", 810.36, 99.45), ("xgb-gpu-hist", 27.97, 99.44),
            ("lightgbm-cpu", 162.29, 99.44), ("lightgbm-gpu", 409.93, 99.44),
            ("cat-cpu", 255.72, 99.44), ("cat-gpu", f64::NAN, f64::NAN)]),
        ("Airline", [
            ("xgb-cpu-hist", 1948.26, 74.94), ("xgb-gpu-hist", 110.29, 74.95),
            ("lightgbm-cpu", 916.04, 75.05), ("lightgbm-gpu", 614.74, 74.99),
            ("cat-cpu", 2949.04, 72.66), ("cat-gpu", 303.36, 72.77)]),
    ];

    let mut all_rows: Vec<(String, Vec<Row>)> = Vec::new();
    for spec in DatasetSpec::table1(scale) {
        eprintln!("== {} ({} rows x {} cols) ==", spec.name, spec.rows, spec.cols);
        let data = generate(&spec, 42);
        let metric = spec.task.metric();
        let objective = spec.task.objective().to_string();
        let num_class = spec.task.num_class();
        let mut rows: Vec<Row> = Vec::new();

        // ---- xgb-cpu-hist
        let params_cpu = LearnerParams {
            objective: objective.parse().expect("infallible"),
            num_class,
            num_rounds: rounds,
            max_bins,
            eval_every: 0,
            eval_metric: Some(metric.parse().expect("infallible")),
            n_devices: 1,
            compress: false,
            // pin the engine serial so per-device compute (the simulated
            // clock's input) is contention-free and host-independent
            threads: 1,
            ..Default::default()
        };
        let b = Learner::from_params(params_cpu.clone())?.train(&data.train, Some(&data.valid))?;
        let score = b.eval_history.last().and_then(|r| r.valid);
        rows.push(Row { system: "xgb-cpu-hist", time: Some(b.train_secs), score });
        eprintln!("  xgb-cpu-hist: {:.2}s {metric}={:?}", b.train_secs, score);

        // ---- xgb-gpu-hist (8 simulated devices, compressed)
        let params_gpu = LearnerParams {
            n_devices: 8,
            compress: true,
            ..params_cpu.clone()
        };
        let b = Learner::from_params(params_gpu)?.train(&data.train, Some(&data.valid))?;
        let score = b.eval_history.last().and_then(|r| r.valid);
        rows.push(Row { system: "xgb-gpu-hist", time: Some(b.simulated_secs), score });
        eprintln!("  xgb-gpu-hist: {:.2}s (simulated) {metric}={:?}", b.simulated_secs, score);

        // ---- lightgbm-cpu / -gpu
        let lgb = LightGbmParams {
            objective: objective.clone(),
            num_class,
            num_rounds: rounds,
            max_bins,
            ..Default::default()
        };
        let (b, stats) = train_lightgbm_like(&lgb, &data.train)?;
        let score = Some(b.evaluate(&data.valid, metric)?);
        rows.push(Row { system: "lightgbm-cpu", time: Some(stats.total()), score });
        let lgb_gpu = stats.other_secs
            + stats.partition_secs
            + stats.hist_secs / LGB_GPU_HIST_SPEEDUP
            + stats.hist_rounds as f64 * LGB_GPU_ROUND_OVERHEAD;
        rows.push(Row { system: "lightgbm-gpu", time: Some(lgb_gpu), score });
        eprintln!("  lightgbm: cpu {:.2}s / gpu-model {:.2}s {metric}={:?}",
                  stats.total(), lgb_gpu, score);

        // ---- cat-cpu / -gpu
        let cat = CatBoostParams {
            objective: objective.clone(),
            num_class,
            num_rounds: rounds,
            max_bins: max_bins.min(128),
            ..Default::default()
        };
        let (b, stats) = train_catboost_like(&cat, &data.train)?;
        let score = Some(b.evaluate(&data.valid, metric)?);
        rows.push(Row { system: "cat-cpu", time: Some(stats.total()), score });
        if matches!(spec.task, Task::Multiclass(_)) {
            // the real cat-gpu lacks multiclass (paper prints N/A)
            rows.push(Row { system: "cat-gpu", time: None, score: None });
            eprintln!("  cat: cpu {:.2}s / gpu N/A (multiclass)", stats.total());
        } else {
            let cat_gpu = stats.other_secs
                + stats.partition_secs / 8.0
                + stats.hist_secs / CAT_GPU_HIST_SPEEDUP
                + stats.hist_rounds as f64 * CAT_GPU_ROUND_OVERHEAD;
            rows.push(Row { system: "cat-gpu", time: Some(cat_gpu), score });
            eprintln!("  cat: cpu {:.2}s / gpu-model {:.2}s {metric}={:?}",
                      stats.total(), cat_gpu, score);
        }
        all_rows.push((spec.name.to_string(), rows));
    }

    // render measured table
    println!("\n=== Table 2 (this reproduction; time in seconds) ===\n");
    let mut t = Table::new(&["System", "Dataset", "Time(s)", "Metric"]);
    for (ds, rows) in &all_rows {
        for r in rows {
            t.add_row(vec![
                r.system.to_string(),
                ds.clone(),
                r.time.map(|v| format!("{v:.2}")).unwrap_or("N/A".into()),
                r.score.map(|v| format!("{v:.4}")).unwrap_or("N/A".into()),
            ]);
        }
    }
    print!("{}", t.render());

    // shape checks vs the paper
    println!("\n=== Shape checks vs paper Table 2 ===\n");
    let mut checks_passed = 0;
    let mut checks_total = 0;
    for (ds, rows) in &all_rows {
        let get = |name: &str| rows.iter().find(|r| r.system == name).and_then(|r| r.time);
        let paper_row = paper.iter().find(|(n, _)| n == ds).map(|(_, r)| r);
        let mut check = |label: String, ours: bool, paper_holds: bool| {
            checks_total += 1;
            let ok = ours == paper_holds;
            checks_passed += usize::from(ok);
            println!("  [{}] {ds}: {label} (paper: {paper_holds}, ours: {ours})",
                     if ok { "ok" } else { "DIFF" });
        };
        if let (Some(cpu), Some(gpu), Some(prow)) =
            (get("xgb-cpu-hist"), get("xgb-gpu-hist"), paper_row)
        {
            let p_cpu = prow[0].1;
            let p_gpu = prow[1].1;
            check("xgb-gpu faster than xgb-cpu".into(), gpu < cpu, p_gpu < p_cpu);
        }
        if let (Some(lc), Some(lg), Some(prow)) =
            (get("lightgbm-cpu"), get("lightgbm-gpu"), paper_row)
        {
            check(
                "lightgbm-gpu faster than lightgbm-cpu".into(),
                lg < lc,
                prow[3].1 < prow[2].1,
            );
        }
        if let (Some(cc), Some(cg), Some(prow)) = (get("cat-cpu"), get("cat-gpu"), paper_row) {
            if !prow[5].1.is_nan() {
                check("cat-gpu faster than cat-cpu".into(), cg < cc, prow[5].1 < prow[4].1);
            }
        }
    }
    println!("\nshape checks: {checks_passed}/{checks_total} match the paper's orderings");
    println!("(absolute times are per-core on this host; the paper used 64 CPU cores / 8 V100s)");
    Ok(())
}
