//! Line-based request/response protocol and the [`Server`] front door.
//!
//! One non-empty input line is one prediction request:
//!
//! * **dense CSV** — `0.5,,3.2,nan,7` — one value per model feature;
//!   empty / `na` / `nan` / `?` (case-insensitive) are *missing*,
//!   exactly the CSV loader's token rules, so a served file produces
//!   the same floats — and therefore the same prediction bits — as
//!   `predict --csv` on it;
//! * **sparse** — `3:1.5 17:0.25` (any token containing `:`) —
//!   `feature:value` pairs, `--col-base` subtracted from the raw index
//!   (1 for LibSVM-style requests); an explicit `nan` *value* here is a
//!   stored NaN (present, routes right everywhere), matching the
//!   LibSVM loader and `QuantisedBatch`;
//! * **control verbs** — `!reload` (hot-swap the model file; replies
//!   `!ok epoch=N swaps=M` in stream position), `!stats` (JSON
//!   [`ServeStats`] snapshot), `!quit` (end this stream), `!shutdown`
//!   (end this stream and stop the TCP accept loop).
//!
//! Each request row is answered with one line: its prediction value(s)
//! formatted exactly like `predict --out` (`{}` Display), or
//! `!err <reason>`. Responses come back **in request order** — control
//! responses included, via a queue flush barrier — and the writer
//! verifies that order (`seq` bookkeeping), making the determinism
//! contract a checked invariant rather than a hope. A running FNV-1a
//! fingerprint over the served prediction bits (errors excluded) lets
//! the shutdown line `predictions: n=… checksum=…` byte-match the
//! `predict` CLI's checksum for the same rows.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::serve::queue::{
    start_scorer, QueueHandle, Reply, RowValues, ScoreRequest, ServeOptions,
};
use crate::serve::registry::ModelRegistry;
use crate::serve::stats::{ServeStats, StatsCollector};
use crate::Float;

/// Parse one value token with the CSV loader's missing-value rules.
fn parse_value(t: &str) -> Result<Float, String> {
    let t = t.trim();
    if t.is_empty()
        || t.eq_ignore_ascii_case("na")
        || t.eq_ignore_ascii_case("nan")
        || t == "?"
    {
        return Ok(Float::NAN);
    }
    t.parse::<Float>()
        .map_err(|_| format!("bad value {t:?}"))
}

/// Control verbs a stream can issue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Control {
    Reload,
    Stats,
    Quit,
    Shutdown,
}

/// One classified input line.
#[derive(Debug, Clone)]
pub enum ParsedLine {
    Row(RowValues),
    Control(Control),
    Empty,
}

/// Classify and parse one request line (module docs for the grammar).
pub fn parse_line(line: &str, col_base: u32) -> Result<ParsedLine, String> {
    let line = line.trim();
    if line.is_empty() {
        return Ok(ParsedLine::Empty);
    }
    if let Some(verb) = line.strip_prefix('!') {
        return match verb.trim() {
            "reload" => Ok(ParsedLine::Control(Control::Reload)),
            "stats" => Ok(ParsedLine::Control(Control::Stats)),
            "quit" => Ok(ParsedLine::Control(Control::Quit)),
            "shutdown" => Ok(ParsedLine::Control(Control::Shutdown)),
            other => Err(format!("unknown control verb {other:?}")),
        };
    }
    if line.contains(':') {
        let mut pairs = Vec::new();
        for tok in line.split_whitespace() {
            let (idx, val) = tok
                .split_once(':')
                .ok_or_else(|| format!("bad sparse token {tok:?}"))?;
            let c: u32 = idx
                .trim()
                .parse()
                .map_err(|_| format!("bad column index {idx:?}"))?;
            if c < col_base {
                return Err(format!(
                    "column index {c} below the stream's column base {col_base}"
                ));
            }
            pairs.push((c - col_base, parse_value(val)?));
        }
        return Ok(ParsedLine::Row(RowValues::Sparse(pairs)));
    }
    let vals = line
        .split(',')
        .map(parse_value)
        .collect::<Result<Vec<Float>, String>>()?;
    Ok(ParsedLine::Row(RowValues::Dense(vals)))
}

/// Incremental FNV-1a 64 over prediction bit patterns — identical, byte
/// for byte, to [`crate::predict::prediction_checksum`] over the
/// concatenated served values.
#[derive(Debug, Clone)]
pub struct Fingerprint {
    hash: u64,
    n: u64,
}

impl Default for Fingerprint {
    fn default() -> Self {
        Self::new()
    }
}

impl Fingerprint {
    pub fn new() -> Self {
        Fingerprint {
            hash: 0xcbf2_9ce4_8422_2325,
            n: 0,
        }
    }

    pub fn update(&mut self, values: &[Float]) {
        for v in values {
            for b in v.to_bits().to_le_bytes() {
                self.hash ^= b as u64;
                self.hash = self.hash.wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        self.n += values.len() as u64;
    }

    pub fn checksum(&self) -> u64 {
        self.hash
    }

    /// Values hashed so far (`n=` in the summary line).
    pub fn count(&self) -> u64 {
        self.n
    }
}

/// Per-stream outcome returned by [`Server::serve_stream`].
#[derive(Debug, Clone)]
pub struct StreamSummary {
    /// Rows answered with predictions.
    pub served: u64,
    /// Rows answered with `!err`.
    pub errors: u64,
    /// Prediction values fingerprinted (`served × outputs_per_row`).
    pub n_values: u64,
    /// FNV-1a 64 over the served prediction bits, request order.
    pub checksum: u64,
    /// Whether this stream asked the whole server to shut down.
    pub shutdown: bool,
}

impl StreamSummary {
    /// The `predict` CLI's checksum line, byte for byte.
    pub fn prediction_line(&self) -> String {
        format!(
            "predictions: n={} checksum={:#018x}",
            self.n_values, self.checksum
        )
    }
}

struct ServerInner {
    registry: Arc<ModelRegistry>,
    opts: ServeOptions,
    stats: Arc<StatsCollector>,
    queue: QueueHandle,
    shutdown: AtomicBool,
}

/// A running serving stack: registry + stats + one scorer thread (and
/// optionally a reload poller). Streams attach via
/// [`serve_stream`](Self::serve_stream) (stdin/stdout, in-memory tests,
/// the bench) or [`serve_tcp`](Self::serve_tcp).
pub struct Server {
    inner: Arc<ServerInner>,
    scorer: JoinHandle<()>,
    poller: Option<JoinHandle<()>>,
}

impl Server {
    /// Start the scorer (and the mtime poller when `reload_poll` is
    /// set — the SIGHUP-style reload for pipelines that rewrite the
    /// model file in place).
    pub fn start(
        registry: Arc<ModelRegistry>,
        opts: ServeOptions,
        reload_poll: Option<Duration>,
    ) -> Server {
        let stats = Arc::new(StatsCollector::new());
        let (queue, scorer) = start_scorer(registry.clone(), opts.clone(), stats.clone());
        let inner = Arc::new(ServerInner {
            registry,
            opts,
            stats,
            queue,
            shutdown: AtomicBool::new(false),
        });
        let poller = reload_poll.map(|period| {
            let inner = inner.clone();
            std::thread::spawn(move || {
                let mut elapsed = Duration::ZERO;
                let tick = Duration::from_millis(20).min(period);
                while !inner.shutdown.load(Ordering::SeqCst) {
                    std::thread::sleep(tick);
                    elapsed += tick;
                    if elapsed >= period {
                        elapsed = Duration::ZERO;
                        // a broken rewrite keeps the old model serving;
                        // nothing useful to do with the error here
                        let _ = inner.registry.reload_if_changed();
                    }
                }
            })
        });
        Server {
            inner,
            scorer,
            poller,
        }
    }

    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.inner.registry
    }

    /// Telemetry snapshot (includes the registry's swap count).
    pub fn stats(&self) -> ServeStats {
        self.inner.stats.snapshot(self.inner.registry.swaps())
    }

    /// Serve one request stream to completion (EOF or `!quit` /
    /// `!shutdown`). The reader runs on the calling thread, responses
    /// are written by a scoped writer thread, and the two meet only in
    /// the reply channel — so queue backpressure can never deadlock the
    /// response path.
    pub fn serve_stream<R: BufRead, W: Write + Send>(
        &self,
        mut reader: R,
        writer: W,
    ) -> Result<StreamSummary> {
        let inner = &self.inner;
        let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
        std::thread::scope(|scope| {
            let writer_thread = scope.spawn(move || write_replies(writer, reply_rx));
            let mut seq = 0u64;
            let mut shutdown = false;
            let mut line = String::new();
            'stream: loop {
                line.clear();
                // Retry on read timeouts: `serve_tcp` puts a read
                // timeout on every accepted connection so an idle
                // stream wakes up periodically to honour a server-wide
                // `!shutdown` instead of parking in `read_line`
                // forever. A timed-out `read_line` may already have
                // consumed a partial line into `line`, so the buffer is
                // cleared once per logical line — never between
                // retries — and the partial content survives until the
                // terminating newline arrives.
                let n_read = loop {
                    match reader.read_line(&mut line) {
                        Ok(n) => break n,
                        Err(e)
                            if matches!(
                                e.kind(),
                                std::io::ErrorKind::WouldBlock
                                    | std::io::ErrorKind::TimedOut
                            ) =>
                        {
                            if inner.shutdown.load(Ordering::SeqCst) {
                                break 'stream;
                            }
                        }
                        Err(e) => return Err(e).context("reading request"),
                    }
                };
                if n_read == 0 {
                    break;
                }
                match parse_line(&line, inner.opts.col_base) {
                    Ok(ParsedLine::Empty) => {}
                    Ok(ParsedLine::Row(row)) => {
                        inner.queue.enqueue(ScoreRequest {
                            seq,
                            row,
                            enqueued: Instant::now(),
                            reply: reply_tx.clone(),
                        })?;
                        seq += 1;
                    }
                    Ok(ParsedLine::Control(ctl)) => {
                        // barrier first: every response for an earlier
                        // request reaches the writer channel before the
                        // control response — stream order is preserved
                        inner.queue.flush()?;
                        match ctl {
                            Control::Reload => {
                                let text = match inner.registry.reload() {
                                    Ok(epoch) => format!(
                                        "!ok epoch={epoch} swaps={}",
                                        inner.registry.swaps()
                                    ),
                                    Err(e) => format!("!err reload failed: {e:#}"),
                                };
                                let _ = reply_tx.send(Reply::Control { text });
                            }
                            Control::Stats => {
                                let snap = inner.stats.snapshot(inner.registry.swaps());
                                let _ = reply_tx.send(Reply::Control {
                                    text: format!("!ok {}", snap.to_json()),
                                });
                            }
                            Control::Quit => break,
                            Control::Shutdown => {
                                inner.shutdown.store(true, Ordering::SeqCst);
                                shutdown = true;
                                break;
                            }
                        }
                    }
                    Err(msg) => {
                        inner.queue.flush()?;
                        let _ = reply_tx.send(Reply::Control {
                            text: format!("!err {msg}"),
                        });
                    }
                }
            }
            // all replies into the channel, then close it so the writer
            // drains and exits
            inner.queue.flush()?;
            drop(reply_tx);
            let mut summary = writer_thread
                .join()
                .expect("serve writer thread panicked")?;
            summary.shutdown = shutdown;
            Ok(summary)
        })
    }

    /// Accept loop: one reader thread per connection, all feeding the
    /// shared micro-batch queue. Returns when a stream issues
    /// `!shutdown`. Per-connection response order follows each
    /// connection's own request order (FIFO queue + sequential scorer);
    /// cross-connection batch composition is whatever arrival timing
    /// produced — the values never depend on it.
    pub fn serve_tcp(&self, listener: TcpListener) -> Result<()> {
        listener
            .set_nonblocking(true)
            .context("serve listener nonblocking")?;
        std::thread::scope(|scope| -> Result<()> {
            loop {
                if self.inner.shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        // Idle connections must not pin the scope open:
                        // without a read timeout a reader thread parks
                        // in `read_line` indefinitely and
                        // `thread::scope` can never join after
                        // `!shutdown`. With one, every reader becomes a
                        // periodic poll on the shutdown flag (the retry
                        // loop in `serve_stream`).
                        stream
                            .set_read_timeout(Some(Duration::from_millis(50)))
                            .context("setting serve read timeout")?;
                        scope.spawn(move || {
                            let Ok(read_half) = stream.try_clone() else {
                                return;
                            };
                            // a failed/hung-up connection only ends its
                            // own stream
                            let _ = self.serve_stream(BufReader::new(read_half), stream);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(e) => return Err(e).context("accepting serve connection"),
                }
            }
        })
    }

    /// Stop everything and return the final stats snapshot.
    pub fn shutdown(self) -> ServeStats {
        let Server {
            inner,
            scorer,
            poller,
        } = self;
        inner.shutdown.store(true, Ordering::SeqCst);
        let stats = inner.stats.clone();
        let registry = inner.registry.clone();
        // dropping the inner (and with it the queue handle) lets the
        // scorer drain and exit; stream handles are scoped so none can
        // still hold a clone here
        drop(inner);
        let _ = scorer.join();
        if let Some(p) = poller {
            let _ = p.join();
        }
        stats.snapshot(registry.swaps())
    }
}

/// Writer half of one stream: drain replies in channel order, format,
/// fingerprint, and *check* the per-stream ordering contract.
fn write_replies<W: Write>(mut w: W, rx: mpsc::Receiver<Reply>) -> Result<StreamSummary> {
    let mut fp = Fingerprint::new();
    let mut served = 0u64;
    let mut errors = 0u64;
    let mut line = String::new();
    for reply in rx {
        match reply {
            Reply::Scored { seq, values, .. } => {
                anyhow::ensure!(
                    seq == served + errors,
                    "response order violation: got row {seq}, expected {}",
                    served + errors
                );
                line.clear();
                for (i, v) in values.iter().enumerate() {
                    if i > 0 {
                        line.push(' ');
                    }
                    // the exact Display formatting `predict --out` uses
                    use std::fmt::Write as _;
                    let _ = write!(line, "{v}");
                }
                writeln!(w, "{line}")?;
                fp.update(&values);
                served += 1;
            }
            Reply::Error { seq, message } => {
                anyhow::ensure!(
                    seq == served + errors,
                    "response order violation: got row {seq}, expected {}",
                    served + errors
                );
                writeln!(w, "!err {message}")?;
                errors += 1;
            }
            Reply::Control { text } => writeln!(w, "{text}")?,
        }
    }
    w.flush()?;
    Ok(StreamSummary {
        served,
        errors,
        n_values: fp.count(),
        checksum: fp.checksum(),
        shutdown: false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_dense_with_missing_tokens() {
        let ParsedLine::Row(RowValues::Dense(v)) =
            parse_line("1.5,,na,NaN,?,2", 0).unwrap()
        else {
            panic!("expected dense row")
        };
        assert_eq!(v.len(), 6);
        assert_eq!(v[0], 1.5);
        assert!(v[1].is_nan() && v[2].is_nan() && v[3].is_nan() && v[4].is_nan());
        assert_eq!(v[5], 2.0);
    }

    #[test]
    fn parse_sparse_applies_col_base() {
        let ParsedLine::Row(RowValues::Sparse(p)) =
            parse_line("1:0.5 7:nan 12:3", 1).unwrap()
        else {
            panic!("expected sparse row")
        };
        assert_eq!(p[0].0, 0);
        assert_eq!(p[0].1, 0.5);
        assert_eq!(p[1].0, 6);
        assert!(p[1].1.is_nan(), "stored NaN survives parsing");
        assert_eq!(p[2], (11, 3.0));
        assert!(parse_line("0:1", 1).is_err(), "index below col base");
    }

    #[test]
    fn parse_controls_and_garbage() {
        assert!(matches!(
            parse_line("!reload", 0),
            Ok(ParsedLine::Control(Control::Reload))
        ));
        assert!(matches!(
            parse_line(" !stats ", 0),
            Ok(ParsedLine::Control(Control::Stats))
        ));
        assert!(matches!(parse_line("", 0), Ok(ParsedLine::Empty)));
        assert!(parse_line("!frobnicate", 0).is_err());
        assert!(parse_line("1.0,abc", 0).is_err());
        assert!(parse_line("x:1", 0).is_err());
    }

    #[test]
    fn fingerprint_matches_prediction_checksum() {
        let preds: Vec<Float> = vec![0.25, -1.5, Float::NAN, 0.0, -0.0, 1e-30];
        let mut fp = Fingerprint::new();
        // update in uneven slices — incrementality must not matter
        fp.update(&preds[..2]);
        fp.update(&preds[2..3]);
        fp.update(&preds[3..]);
        assert_eq!(fp.checksum(), crate::predict::prediction_checksum(&preds));
        assert_eq!(fp.count(), preds.len() as u64);
        assert_eq!(
            Fingerprint::new().checksum(),
            crate::predict::prediction_checksum(&[]),
            "empty stream matches empty predict"
        );
    }
}
