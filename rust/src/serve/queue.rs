//! Micro-batching request queue ([`QueueHandle`] → scorer thread).
//!
//! Readers (stdin or one thread per TCP connection) parse rows and push
//! [`ScoreRequest`]s into one bounded `sync_channel` — the queue cap is
//! the backpressure valve: when the scorer falls behind, `enqueue`
//! blocks the reader instead of growing memory. A single scorer thread
//! drains the channel into micro-batches:
//!
//! * take one request (blocking), then keep draining until the batch
//!   holds [`ServeOptions::batch_max`] rows or
//!   [`ServeOptions::batch_wait`] has elapsed since the batch opened —
//!   under load batches fill instantly, when idle a lone request waits
//!   at most `batch_wait`;
//! * clone the registry's current model `Arc` **once per batch** —
//!   every row of a batch is quantised and scored against that one
//!   epoch, so a hot-swap never splits a batch (in-flight requests
//!   finish on the old epoch);
//! * quantise the rows into one [`FlatBatch`] and score it on the
//!   shared [`ExecContext`] pool, then reply row by row **in batch
//!   order**.
//!
//! # Determinism contract
//!
//! The channel is FIFO and the single scorer processes batches
//! sequentially, replying in batch order — so each connection's
//! responses come back exactly in the order its requests were sent,
//! with values bit-identical to the `predict` CLI on the same rows,
//! independent of `--threads`, `--batch-max` and how requests happened
//! to coalesce. Parallelism only ever lives *inside* a batch
//! (`for_each_slice_mut` row chunks), which is bit-stable by the PR 1
//! exec contract.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::exec::ExecContext;
use crate::serve::flat::FlatBatch;
use crate::serve::registry::ModelRegistry;
use crate::serve::stats::StatsCollector;
use crate::Float;

/// Serving knobs (CLI flags of the `serve` subcommand).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Rows coalesced into one scored block (≥ 1).
    pub batch_max: usize,
    /// How long an open batch waits for more rows before scoring.
    pub batch_wait: Duration,
    /// Bounded queue capacity (backpressure threshold).
    pub queue_cap: usize,
    /// Scorer pool width (`0` = all cores, `1` = serial).
    pub threads: usize,
    /// Subtracted from sparse `idx:val` column indices (1 for 1-based
    /// LibSVM-style requests) — same convention as ingestion.
    pub col_base: u32,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            batch_max: 64,
            batch_wait: Duration::from_micros(200),
            queue_cap: 1024,
            threads: 0,
            col_base: 0,
        }
    }
}

/// One parsed request row, before quantisation.
#[derive(Debug, Clone)]
pub enum RowValues {
    /// One float per model feature (NaN = missing).
    Dense(Vec<Float>),
    /// `(feature, value)` pairs, column base already subtracted; an
    /// explicit NaN value is a *stored* NaN (present, always right).
    Sparse(Vec<(u32, Float)>),
}

/// A row enqueued for scoring.
pub struct ScoreRequest {
    /// Caller-assigned sequence number, echoed in the reply.
    pub seq: u64,
    pub row: RowValues,
    /// Enqueue instant — the latency histogram measures from here.
    pub enqueued: Instant,
    /// Where the reply goes (one channel per connection keeps per-
    /// connection FIFO order).
    pub reply: mpsc::Sender<Reply>,
}

/// What the scorer (or the control path) sends back.
pub enum Reply {
    /// `values` is one float per output (length 1, or `k` for
    /// `multi:softprob`), bit-identical to the `predict` CLI.
    Scored {
        seq: u64,
        epoch: u64,
        values: Vec<Float>,
    },
    /// Malformed/incompatible row: excluded from the fingerprint.
    Error { seq: u64, message: String },
    /// Pre-formatted control response (`!ok ...`), routed through the
    /// reply channel so it lands in stream order.
    Control { text: String },
}

enum Request {
    Score(ScoreRequest),
    /// Barrier: acked only after every earlier request has been scored
    /// *and its reply sent* — the ordering hook `!reload` uses.
    Flush(mpsc::Sender<()>),
}

/// Cloneable producer side of the queue.
#[derive(Clone)]
pub struct QueueHandle {
    tx: SyncSender<Request>,
    depth: Arc<AtomicUsize>,
}

impl QueueHandle {
    /// Enqueue one row; blocks when the bounded queue is full
    /// (backpressure). Errors only after scorer shutdown.
    pub fn enqueue(&self, req: ScoreRequest) -> anyhow::Result<()> {
        self.depth.fetch_add(1, Ordering::SeqCst);
        self.tx
            .send(Request::Score(req))
            .map_err(|_| anyhow::anyhow!("serve queue is shut down"))
    }

    /// Block until everything enqueued before this call has been scored
    /// and replied to.
    pub fn flush(&self) -> anyhow::Result<()> {
        let (ack_tx, ack_rx) = mpsc::channel();
        self.tx
            .send(Request::Flush(ack_tx))
            .map_err(|_| anyhow::anyhow!("serve queue is shut down"))?;
        ack_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("scorer exited before flush ack"))
    }
}

/// Spawn the scorer thread. It runs until every [`QueueHandle`] clone
/// has been dropped, then drains and exits.
pub fn start_scorer(
    registry: Arc<ModelRegistry>,
    opts: ServeOptions,
    stats: Arc<StatsCollector>,
) -> (QueueHandle, JoinHandle<()>) {
    let (tx, rx) = mpsc::sync_channel(opts.queue_cap.max(1));
    let depth = Arc::new(AtomicUsize::new(0));
    let handle = QueueHandle {
        tx,
        depth: depth.clone(),
    };
    let join = std::thread::spawn(move || scorer_loop(rx, registry, opts, stats, depth));
    (handle, join)
}

/// Decode scratch owned by the scorer thread: the quantised batch and
/// per-row error slots are cleared and refilled each micro-batch, never
/// reallocated once grown — the serve-side arena.
struct ScorerScratch {
    fb: FlatBatch,
    row_err: Vec<Option<String>>,
}

fn scorer_loop(
    rx: Receiver<Request>,
    registry: Arc<ModelRegistry>,
    opts: ServeOptions,
    stats: Arc<StatsCollector>,
    depth: Arc<AtomicUsize>,
) {
    let exec = ExecContext::new(opts.threads);
    let batch_max = opts.batch_max.max(1);
    let mut scratch = ScorerScratch {
        fb: FlatBatch::zeroed(0, 0),
        row_err: Vec::new(),
    };
    'outer: loop {
        // block for the batch opener
        let first = match rx.recv() {
            Ok(Request::Score(r)) => r,
            Ok(Request::Flush(ack)) => {
                let _ = ack.send(());
                continue;
            }
            Err(_) => break,
        };
        depth.fetch_sub(1, Ordering::SeqCst);
        let mut batch = vec![first];
        let mut pending_acks: Vec<mpsc::Sender<()>> = Vec::new();
        let mut disconnected = false;
        let deadline = Instant::now() + opts.batch_wait;
        while batch.len() < batch_max && pending_acks.is_empty() {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Request::Score(r)) => {
                    depth.fetch_sub(1, Ordering::SeqCst);
                    batch.push(r);
                }
                // a flush closes the batch: its ack must come after
                // these rows' replies
                Ok(Request::Flush(ack)) => pending_acks.push(ack),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        score_batch(&batch, &registry, &exec, &stats, &depth, &mut scratch);
        for ack in pending_acks {
            let _ = ack.send(());
        }
        if disconnected {
            break 'outer;
        }
    }
}

fn score_batch(
    batch: &[ScoreRequest],
    registry: &ModelRegistry,
    exec: &ExecContext,
    stats: &StatsCollector,
    depth: &AtomicUsize,
    scratch: &mut ScorerScratch,
) {
    // one model per batch: the hot-swap atomicity unit
    let model = registry.current();
    let cuts = model.cuts();
    let n_features = model.n_features();
    let n = batch.len();
    let fb_reused = scratch.fb.reset(n, n_features);
    let err_reused = scratch.row_err.capacity() >= n;
    scratch.row_err.clear();
    scratch.row_err.resize(n, None);
    if fb_reused && err_reused {
        stats.record_arena_reuse();
    }
    let fb = &mut scratch.fb;
    let row_err = &mut scratch.row_err;
    for (i, req) in batch.iter().enumerate() {
        match &req.row {
            RowValues::Dense(vals) => {
                if vals.len() != n_features {
                    row_err[i] = Some(format!(
                        "row has {} features but the model was trained on {n_features}",
                        vals.len()
                    ));
                    continue;
                }
                for (f, &v) in vals.iter().enumerate() {
                    // dense NaN is a MISSING value (DMatrix semantics),
                    // not a stored NaN — leave the slot absent
                    if !v.is_nan() {
                        fb.set_value(i, f, v, cuts);
                    }
                }
            }
            RowValues::Sparse(pairs) => {
                for &(f, v) in pairs {
                    if (f as usize) < n_features {
                        fb.set_value(i, f as usize, v, cuts);
                    } else {
                        row_err[i] = Some(format!(
                            "row uses feature {f} but the model was trained on {n_features}"
                        ));
                        break;
                    }
                }
            }
        }
    }
    let preds = model.predict_batch(fb, exec);
    let k = if n == 0 { 1 } else { (preds.len() / n).max(1) };
    let mut errors = 0u64;
    for (i, req) in batch.iter().enumerate() {
        stats.record_latency(req.enqueued.elapsed());
        let reply = match row_err[i].take() {
            Some(message) => {
                errors += 1;
                Reply::Error {
                    seq: req.seq,
                    message,
                }
            }
            None => Reply::Scored {
                seq: req.seq,
                epoch: model.epoch,
                values: preds[i * k..(i + 1) * k].to_vec(),
            },
        };
        // a hung-up connection just drops its replies
        let _ = req.reply.send(reply);
    }
    stats.record_batch(n, depth.load(Ordering::SeqCst), errors);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetSpec};
    use crate::gbm::params::LearnerParams;

    fn serve_fixture(name: &str) -> (Arc<ModelRegistry>, crate::data::Dataset) {
        let g = generate(&DatasetSpec::higgs_like(400), 9);
        let params = LearnerParams {
            objective: "binary:logistic".parse().expect("infallible"),
            num_rounds: 3,
            max_depth: 3,
            max_bins: 16,
            eval_every: 0,
            ..Default::default()
        };
        let booster = crate::gbm::Learner::from_params(params)
            .unwrap()
            .train(&g.train, None)
            .unwrap();
        let path = std::env::temp_dir().join(format!(
            "xgb_tpu_queue_{name}_{}.txt",
            std::process::id()
        ));
        crate::gbm::save_model_file(&booster, &path).unwrap();
        let reg = Arc::new(ModelRegistry::open(&path).unwrap());
        std::fs::remove_file(&path).ok();
        (reg, g.valid)
    }

    #[test]
    fn scored_rows_match_predict_bitwise_in_order() {
        let (reg, valid) = serve_fixture("parity");
        let want = reg.current().booster().predict(&valid.x);
        let n = valid.x.n_rows();
        let stats = Arc::new(StatsCollector::new());
        let opts = ServeOptions {
            batch_max: 7,
            threads: 2,
            ..Default::default()
        };
        let (q, join) = start_scorer(reg.clone(), opts, stats.clone());
        let (reply_tx, reply_rx) = mpsc::channel();
        for row in 0..n {
            let vals: Vec<Float> = (0..valid.x.n_cols())
                .map(|c| valid.x.get(row, c).unwrap_or(Float::NAN))
                .collect();
            q.enqueue(ScoreRequest {
                seq: row as u64,
                row: RowValues::Dense(vals),
                enqueued: Instant::now(),
                reply: reply_tx.clone(),
            })
            .unwrap();
        }
        q.flush().unwrap();
        drop(reply_tx);
        let mut got = Vec::new();
        for reply in reply_rx.iter().take(n) {
            match reply {
                Reply::Scored { seq, values, epoch } => {
                    assert_eq!(seq, got.len() as u64, "FIFO reply order");
                    assert_eq!(epoch, 1);
                    got.push(values[0]);
                }
                Reply::Error { message, .. } => panic!("unexpected error: {message}"),
                Reply::Control { .. } => panic!("unexpected control"),
            }
        }
        assert_eq!(got.len(), n);
        for (row, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "row {row}");
        }
        drop(q);
        join.join().unwrap();
        let s = stats.snapshot(0);
        assert_eq!(s.requests, n as u64);
        assert!(s.batches >= (n / 7) as u64);
        assert!(!s.batch_sizes.is_empty());
    }

    #[test]
    fn bad_rows_get_error_replies_not_panics() {
        let (reg, _) = serve_fixture("badrow");
        let n_features = reg.current().n_features();
        let stats = Arc::new(StatsCollector::new());
        let (q, join) = start_scorer(reg, ServeOptions::default(), stats.clone());
        let (reply_tx, reply_rx) = mpsc::channel();
        // wrong arity dense + out-of-range sparse feature
        q.enqueue(ScoreRequest {
            seq: 0,
            row: RowValues::Dense(vec![1.0; n_features + 3]),
            enqueued: Instant::now(),
            reply: reply_tx.clone(),
        })
        .unwrap();
        q.enqueue(ScoreRequest {
            seq: 1,
            row: RowValues::Sparse(vec![(n_features as u32 + 10, 1.0)]),
            enqueued: Instant::now(),
            reply: reply_tx.clone(),
        })
        .unwrap();
        q.flush().unwrap();
        drop(reply_tx);
        let replies: Vec<Reply> = reply_rx.iter().take(2).collect();
        for r in &replies {
            match r {
                Reply::Error { message, .. } => {
                    assert!(message.contains("features") || message.contains("feature"))
                }
                _ => panic!("expected error reply"),
            }
        }
        drop(q);
        join.join().unwrap();
        assert_eq!(stats.snapshot(0).errors, 2);
    }
}
