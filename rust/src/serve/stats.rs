//! Serving telemetry: latency/batch/queue statistics ([`ServeStats`]).
//!
//! The collector is a single mutex over plain counters plus a
//! power-of-two latency histogram — one lock per scored batch on the
//! (single) scorer thread, so contention is nil and recording stays off
//! the reader/writer hot path. Quantiles come from the histogram:
//! exact enough for p50/p90/p99 reporting (each bucket spans one
//! doubling) with O(1) memory however long the server runs.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// Number of ×2 latency buckets: bucket `i ≥ 1` holds latencies of bit
/// length `i` (`[2^{i-1}, 2^i)` µs, upper edge `2^i`), bucket 0 holds
/// sub-µs; 40 buckets cover out past 2^39 µs ≈ 6 days.
const LAT_BUCKETS: usize = 40;

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    errors: u64,
    batches: u64,
    lat_hist: Vec<u64>,
    lat_sum_us: u64,
    lat_max_us: u64,
    batch_sizes: BTreeMap<usize, u64>,
    queue_depth_max: usize,
    queue_depth_sum: u64,
    arena_reuse: u64,
}

/// Thread-safe recorder the scorer feeds; snapshot with
/// [`StatsCollector::snapshot`].
#[derive(Debug)]
pub struct StatsCollector {
    inner: Mutex<Inner>,
}

impl Default for StatsCollector {
    fn default() -> Self {
        Self::new()
    }
}

impl StatsCollector {
    pub fn new() -> Self {
        StatsCollector {
            inner: Mutex::new(Inner {
                lat_hist: vec![0; LAT_BUCKETS],
                ..Default::default()
            }),
        }
    }

    /// Record one scored micro-batch: its size, the queue depth left
    /// behind after draining it, and whether each member succeeded.
    pub fn record_batch(&self, batch_size: usize, queue_depth: usize, errors: u64) {
        let mut s = self.inner.lock().unwrap();
        s.batches += 1;
        s.requests += batch_size as u64;
        s.errors += errors;
        *s.batch_sizes.entry(batch_size).or_insert(0) += 1;
        s.queue_depth_max = s.queue_depth_max.max(queue_depth);
        s.queue_depth_sum += queue_depth as u64;
    }

    /// Record that a scored batch was served entirely from the scorer's
    /// reusable scratch (no fresh decode-buffer allocation).
    pub fn record_arena_reuse(&self) {
        self.inner.lock().unwrap().arena_reuse += 1;
    }

    /// Record one request's enqueue→scored latency.
    pub fn record_latency(&self, lat: Duration) {
        let us = lat.as_micros().min(u64::MAX as u128) as u64;
        let mut s = self.inner.lock().unwrap();
        let idx = (64 - us.leading_zeros() as usize).min(LAT_BUCKETS - 1);
        s.lat_hist[idx] += 1;
        s.lat_sum_us += us;
        s.lat_max_us = s.lat_max_us.max(us);
    }

    /// Point-in-time copy of everything recorded so far.
    pub fn snapshot(&self, swaps: u64) -> ServeStats {
        let s = self.inner.lock().unwrap();
        let total: u64 = s.lat_hist.iter().sum();
        let q = |p: f64| -> u64 {
            if total == 0 {
                return 0;
            }
            let target = ((p * total as f64).ceil() as u64).max(1);
            let mut seen = 0u64;
            for (i, &c) in s.lat_hist.iter().enumerate() {
                seen += c;
                if seen >= target {
                    // upper edge of the bucket: conservative, monotone in p
                    return if i == 0 { 1 } else { 1u64 << i };
                }
            }
            s.lat_max_us
        };
        ServeStats {
            requests: s.requests,
            errors: s.errors,
            batches: s.batches,
            swaps,
            p50_us: q(0.50),
            p90_us: q(0.90),
            p99_us: q(0.99),
            mean_us: if s.requests == 0 {
                0.0
            } else {
                s.lat_sum_us as f64 / s.requests as f64
            },
            max_us: s.lat_max_us,
            batch_sizes: s.batch_sizes.iter().map(|(&k, &v)| (k, v)).collect(),
            queue_depth_max: s.queue_depth_max,
            queue_depth_mean: if s.batches == 0 {
                0.0
            } else {
                s.queue_depth_sum as f64 / s.batches as f64
            },
            arena_reuse: s.arena_reuse,
        }
    }
}

/// A snapshot of serving telemetry — printed on shutdown and returned
/// to the bench harness.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeStats {
    pub requests: u64,
    pub errors: u64,
    pub batches: u64,
    /// Model hot-swaps performed by the registry.
    pub swaps: u64,
    /// Histogram-bucket (×2) upper-bound quantiles, µs.
    pub p50_us: u64,
    pub p90_us: u64,
    pub p99_us: u64,
    pub mean_us: f64,
    pub max_us: u64,
    /// `(batch size, count)` ascending — the coalescing distribution.
    pub batch_sizes: Vec<(usize, u64)>,
    pub queue_depth_max: usize,
    pub queue_depth_mean: f64,
    /// Batches scored without allocating fresh scratch (the scorer's
    /// decode buffers were recycled from the previous batch).
    pub arena_reuse: u64,
}

impl ServeStats {
    /// Mean rows per scored batch.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// Human summary, one stat per line (what `serve` prints to stderr
    /// on shutdown).
    pub fn render(&self) -> String {
        let dist: Vec<String> = self
            .batch_sizes
            .iter()
            .map(|(sz, n)| format!("{sz}x{n}"))
            .collect();
        format!(
            "serve stats: requests={} errors={} batches={} swaps={}\n\
             serve latency (us): p50<={} p90<={} p99<={} mean={:.1} max={}\n\
             serve batches: mean_size={:.2} dist=[{}] queue_depth max={} mean={:.2} arena_reuse={}",
            self.requests,
            self.errors,
            self.batches,
            self.swaps,
            self.p50_us,
            self.p90_us,
            self.p99_us,
            self.mean_us,
            self.max_us,
            self.mean_batch(),
            dist.join(","),
            self.queue_depth_max,
            self.queue_depth_mean,
            self.arena_reuse,
        )
    }

    /// Compact single-line JSON (bench artifact rows embed it).
    pub fn to_json(&self) -> String {
        let dist: Vec<String> = self
            .batch_sizes
            .iter()
            .map(|(sz, n)| format!("[{sz},{n}]"))
            .collect();
        format!(
            "{{\"requests\":{},\"errors\":{},\"batches\":{},\"swaps\":{},\
             \"p50_us\":{},\"p90_us\":{},\"p99_us\":{},\"mean_us\":{:.2},\"max_us\":{},\
             \"mean_batch\":{:.3},\"batch_dist\":[{}],\
             \"queue_depth_max\":{},\"queue_depth_mean\":{:.3},\"arena_reuse\":{}}}",
            self.requests,
            self.errors,
            self.batches,
            self.swaps,
            self.p50_us,
            self.p90_us,
            self.p99_us,
            self.mean_us,
            self.max_us,
            self.mean_batch(),
            dist.join(","),
            self.queue_depth_max,
            self.queue_depth_mean,
            self.arena_reuse,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_are_monotone_and_bucketed() {
        let c = StatsCollector::new();
        for us in [3u64, 5, 9, 17, 33, 65, 129, 257, 513, 1025] {
            c.record_latency(Duration::from_micros(us));
        }
        c.record_batch(10, 3, 0);
        let s = c.snapshot(2);
        assert_eq!(s.requests, 10);
        assert_eq!(s.batches, 1);
        assert_eq!(s.swaps, 2);
        assert!(s.p50_us > 0);
        assert!(s.p50_us <= s.p90_us && s.p90_us <= s.p99_us);
        // p99 bucket upper bound covers the max sample
        assert!(s.p99_us >= 1025);
        assert_eq!(s.max_us, 1025);
        assert_eq!(s.batch_sizes, vec![(10, 1)]);
        assert_eq!(s.queue_depth_max, 3);
    }

    #[test]
    fn batch_distribution_accumulates() {
        let c = StatsCollector::new();
        c.record_batch(1, 0, 0);
        c.record_batch(4, 1, 1);
        c.record_batch(4, 2, 0);
        c.record_arena_reuse();
        c.record_arena_reuse();
        let s = c.snapshot(0);
        assert_eq!(s.requests, 9);
        assert_eq!(s.errors, 1);
        assert_eq!(s.arena_reuse, 2);
        assert_eq!(s.batch_sizes, vec![(1, 1), (4, 2)]);
        assert!((s.mean_batch() - 3.0).abs() < 1e-9);
        assert!((s.queue_depth_mean - 1.0).abs() < 1e-9);
        // render/json don't panic and carry the headline numbers
        assert!(s.render().contains("requests=9"));
        assert!(s.render().contains("arena_reuse=2"));
        assert!(s.to_json().contains("\"requests\":9"));
        assert!(s.to_json().contains("\"arena_reuse\":2"));
    }

    #[test]
    fn empty_snapshot_is_all_zero() {
        let s = StatsCollector::new().snapshot(0);
        assert_eq!(s.requests, 0);
        assert_eq!(s.p99_us, 0);
        assert_eq!(s.mean_batch(), 0.0);
    }
}
