//! Low-latency serving stack (`xgb-tpu serve`): flat SoA forest,
//! hot-swap model registry, micro-batched scoring.
//!
//! Training optimises throughput over a fixed dataset; serving
//! optimises latency over an endless trickle of single rows — the
//! "heavy traffic from millions of users" half of the north star.
//! This module is that second half, built entirely on the frozen-cuts
//! prediction substrate PR 5 proved exact:
//!
//! ```text
//! model file ──load──▶ Booster ──translate──▶ BinForest ──flatten──▶ FlatForest
//!      ▲                 (float trees)        (bin thresholds)       (SoA arena)
//!      │ !reload / mtime poll                                            │
//! ModelRegistry ◀─────────── Arc hot-swap ────────────────────────────────┘
//!      │ current()  (one clone per micro-batch)
//! requests ─parse─▶ bounded queue ─coalesce─▶ FlatBatch ─score─▶ replies
//!   (protocol.rs)     (queue.rs)              (flat.rs)        (in order)
//! ```
//!
//! * [`flat`] — [`FlatForest`](flat::FlatForest): the ensemble as
//!   parallel SoA arrays, BFS-relabelled so hot top levels lead and
//!   children sit adjacent, traversed branchlessly over shifted bins.
//!   Bit-identical to `BinForest` and float traversal (proof in the
//!   module docs), so serving inherits PR 5's exactness.
//! * [`registry`] — [`ModelRegistry`](registry::ModelRegistry):
//!   `RwLock<Arc<ServedModel>>` hot-swap; in-flight micro-batches keep
//!   the old epoch, new batches see the new one; `cuts: None` files are
//!   rejected at (re)load with the retrain/re-save error.
//! * [`queue`] — bounded-channel micro-batching with a single scorer
//!   thread: backpressure by blocking, deterministic per-stream reply
//!   order, parallelism only inside a batch.
//! * [`protocol`] — the line grammar (dense CSV / sparse `idx:val` /
//!   `!`-verbs), [`Server`](protocol::Server), and the incremental
//!   FNV-1a [`Fingerprint`](protocol::Fingerprint) whose shutdown line
//!   byte-matches the `predict` CLI's checksum.
//! * [`stats`] — [`ServeStats`](stats::ServeStats): p50/p90/p99 latency
//!   from a ×2 histogram, batch-size distribution, queue depth, swap
//!   count; printed on shutdown, returned to the bench.
//!
//! # Determinism contract
//!
//! For a given model file and request stream, every response value is
//! bit-identical to `predict` on the same rows regardless of
//! `--threads`, `--batch-max`, `--batch-wait-us`, connection count, or
//! how requests coalesced into batches — and each stream's responses
//! arrive exactly in its request order (checked, not assumed, by the
//! writer's sequence bookkeeping). The only observable nondeterminism
//! is *which epoch* serves a row when a hot-swap races an in-flight
//! stream, and even then each row is scored wholly by one epoch and
//! batches never straddle a swap.

pub mod flat;
pub mod protocol;
pub mod queue;
pub mod registry;
pub mod stats;

pub use flat::{FlatBatch, FlatForest};
pub use protocol::{parse_line, Control, Fingerprint, ParsedLine, Server, StreamSummary};
pub use queue::{QueueHandle, Reply, RowValues, ScoreRequest, ServeOptions};
pub use registry::{ModelRegistry, ServedModel};
pub use stats::{ServeStats, StatsCollector};
