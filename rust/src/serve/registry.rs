//! Model registry with atomic hot-swap ([`ModelRegistry`]).
//!
//! The registry owns the path of the model file and the currently
//! served [`ServedModel`] behind `RwLock<Arc<_>>` — the std-only
//! equivalent of an arc-swap. Readers take the lock only long enough to
//! clone the `Arc` (nanoseconds; the write lock is held only for the
//! pointer store, never during a model load), so:
//!
//! * **in-flight requests finish on the old epoch** — the scorer clones
//!   the `Arc` once per micro-batch, and every row of that batch is
//!   quantised and scored against that one model, even if a swap lands
//!   mid-batch;
//! * **new requests see the new one** — the next batch's clone observes
//!   the swapped pointer;
//! * the old model is freed when its last in-flight batch drops it.
//!
//! Loads go through [`crate::gbm::load_servable_model_file`], so a
//! legacy `cuts: None` file is rejected at open/reload time with the
//! actionable retrain/re-save error — a failed reload leaves the
//! current model serving untouched.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::SystemTime;

use anyhow::{Context, Result};

use crate::exec::ExecContext;
use crate::gbm::Booster;
use crate::predict::quantised::BinForest;
use crate::quantile::HistogramCuts;
use crate::serve::flat::{FlatBatch, FlatForest};
use crate::Float;

/// One immutable, fully-prepared model generation: the booster (for
/// base score / objective transform), its flattened forest, and the
/// epoch stamp responses carry.
pub struct ServedModel {
    booster: Booster,
    flat: FlatForest,
    /// 1 for the model loaded at open; +1 per completed swap.
    pub epoch: u64,
}

impl ServedModel {
    /// Prepare a booster for serving (fails fast on `cuts: None`).
    pub fn from_booster(booster: Booster, epoch: u64) -> Result<Self> {
        let cuts = booster.require_cuts()?;
        let flat = BinForest::from_trees(&booster.trees, cuts).flatten()?;
        Ok(ServedModel {
            booster,
            flat,
            epoch,
        })
    }

    /// The frozen cuts requests are quantised against (presence is the
    /// construction invariant, hence no `Result` here).
    pub fn cuts(&self) -> &HistogramCuts {
        self.booster.cuts.as_ref().expect("checked at construction")
    }

    pub fn n_features(&self) -> usize {
        self.cuts().n_features()
    }

    pub fn flat(&self) -> &FlatForest {
        &self.flat
    }

    pub fn booster(&self) -> &Booster {
        &self.booster
    }

    /// Score one micro-batch: flat margins (bit-identical to the
    /// `predict` CLI's traversal) followed by the objective transform.
    /// Every transform is row-local, so transforming batch-at-a-time
    /// equals transforming the whole stream — the served fingerprint
    /// matches `predict`'s.
    pub fn predict_batch(&self, batch: &FlatBatch, exec: &ExecContext) -> Vec<Float> {
        let margins = self.flat.predict_margins(&self.booster.base_score, batch, exec);
        self.booster.objective.transform(&margins)
    }
}

/// The registry: current model + swap machinery (module docs).
pub struct ModelRegistry {
    path: PathBuf,
    current: RwLock<Arc<ServedModel>>,
    /// Completed swaps (epoch of the current model is `swaps + 1`).
    swaps: AtomicU64,
    /// `(mtime, len)` of the file backing the current model — the
    /// change detector for [`reload_if_changed`](Self::reload_if_changed).
    stamp: Mutex<Option<(SystemTime, u64)>>,
    /// Serialises reloads so two concurrent pollers can't both bump the
    /// epoch for one file change.
    reload_gate: Mutex<()>,
}

impl ModelRegistry {
    /// Load the model at `path` and start serving it as epoch 1.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let booster = crate::gbm::load_servable_model_file(&path)?;
        let model = ServedModel::from_booster(booster, 1)?;
        let stamp = file_stamp(&path);
        Ok(ModelRegistry {
            path,
            current: RwLock::new(Arc::new(model)),
            swaps: AtomicU64::new(0),
            stamp: Mutex::new(stamp),
            reload_gate: Mutex::new(()),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The model new requests should use. Cheap: clones an `Arc` under
    /// a read lock.
    pub fn current(&self) -> Arc<ServedModel> {
        self.current.read().unwrap().clone()
    }

    /// Completed hot-swaps.
    pub fn swaps(&self) -> u64 {
        self.swaps.load(Ordering::SeqCst)
    }

    /// Reload the model file and atomically swap it in. Returns the new
    /// epoch. On error the old model keeps serving.
    pub fn reload(&self) -> Result<u64> {
        let _gate = self.reload_gate.lock().unwrap();
        let stamp = file_stamp(&self.path);
        let booster = crate::gbm::load_servable_model_file(&self.path)
            .with_context(|| format!("hot-swap reload of {}", self.path.display()))?;
        let epoch = self.current().epoch + 1;
        let model = Arc::new(ServedModel::from_booster(booster, epoch)?);
        *self.current.write().unwrap() = model;
        self.swaps.fetch_add(1, Ordering::SeqCst);
        *self.stamp.lock().unwrap() = stamp;
        Ok(epoch)
    }

    /// Reload only if the file's `(mtime, len)` stamp changed since the
    /// last (re)load — the `--reload-poll-ms` SIGHUP-style poll hook.
    /// Returns the new epoch if a swap happened.
    pub fn reload_if_changed(&self) -> Result<Option<u64>> {
        let changed = {
            let stamp = self.stamp.lock().unwrap();
            file_stamp(&self.path) != *stamp
        };
        if changed {
            self.reload().map(Some)
        } else {
            Ok(None)
        }
    }
}

fn file_stamp(path: &Path) -> Option<(SystemTime, u64)> {
    std::fs::metadata(path)
        .ok()
        .map(|m| (m.modified().unwrap_or(SystemTime::UNIX_EPOCH), m.len()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetSpec};
    use crate::gbm::params::LearnerParams;

    fn train(seed: u64, rounds: usize) -> Booster {
        let g = generate(&DatasetSpec::higgs_like(600), seed);
        let params = LearnerParams {
            objective: "binary:logistic".parse().expect("infallible"),
            num_rounds: rounds,
            max_depth: 3,
            max_bins: 16,
            eval_every: 0,
            ..Default::default()
        };
        crate::gbm::Learner::from_params(params)
            .unwrap()
            .train(&g.train, None)
            .unwrap()
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("xgb_tpu_registry_{name}_{}.txt", std::process::id()))
    }

    #[test]
    fn open_reload_bumps_epoch_and_swaps_model() {
        let path = tmp("swap");
        let a = train(1, 2);
        let b = train(2, 3);
        crate::gbm::save_model_file(&a, &path).unwrap();
        let reg = ModelRegistry::open(&path).unwrap();
        let m1 = reg.current();
        assert_eq!(m1.epoch, 1);
        assert_eq!(reg.swaps(), 0);
        crate::gbm::save_model_file(&b, &path).unwrap();
        // old Arc stays alive across the swap (in-flight semantics)
        let epoch = reg.reload().unwrap();
        assert_eq!(epoch, 2);
        assert_eq!(reg.swaps(), 1);
        let m2 = reg.current();
        assert_eq!(m2.epoch, 2);
        assert_eq!(m2.booster().trees[0].len(), 3);
        assert_eq!(m1.booster().trees[0].len(), 2, "old epoch untouched");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn reload_if_changed_only_fires_on_new_stamp() {
        let path = tmp("stamp");
        crate::gbm::save_model_file(&train(3, 2), &path).unwrap();
        let reg = ModelRegistry::open(&path).unwrap();
        assert_eq!(reg.reload_if_changed().unwrap(), None, "no change");
        // rewrite with different content (len changes even if mtime
        // granularity is coarse)
        crate::gbm::save_model_file(&train(4, 3), &path).unwrap();
        assert_eq!(reg.reload_if_changed().unwrap(), Some(2));
        assert_eq!(reg.reload_if_changed().unwrap(), None);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn failed_reload_keeps_serving_old_model() {
        let path = tmp("failfast");
        crate::gbm::save_model_file(&train(5, 2), &path).unwrap();
        let reg = ModelRegistry::open(&path).unwrap();
        std::fs::write(&path, "not a model").unwrap();
        assert!(reg.reload().is_err());
        assert_eq!(reg.current().epoch, 1, "old model keeps serving");
        assert_eq!(reg.swaps(), 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn open_rejects_legacy_model_without_cuts() {
        let path = tmp("legacy");
        std::fs::write(
            &path,
            "xgb-tpu-model v1\nobjective = reg:squarederror\nnum_class = 1\n\
             eta = 0.3\nbase_score = 0\ngroups = 1\ngroup 0 trees = 1\n\
             0 leaf 0.5 1\n",
        )
        .unwrap();
        let err = ModelRegistry::open(&path).unwrap_err();
        let msg = format!("{err:#}");
        // either parse or cuts error is acceptable for this minimal
        // text, but a cuts-less valid file must name the fix
        std::fs::write(
            &path,
            "xgb-tpu-model v1\nobjective = reg:squarederror\nnum_class = 1\n\
             eta = 0.3\nbase_score = 0\ngroups = 1\ngroup 0 trees = 1\n\
             tree 0 0 nodes = 1\n0 leaf 0.5 1\n",
        )
        .unwrap();
        let err2 = ModelRegistry::open(&path).unwrap_err();
        let msg2 = format!("{err2:#}");
        assert!(msg2.contains("cuts"), "{msg2}");
        assert!(msg2.contains("retrain"), "{msg2}");
        let _ = msg;
        std::fs::remove_file(&path).ok();
    }
}
