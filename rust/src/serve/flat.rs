//! Cache-friendly flattened forest — the serving-side twin of
//! [`crate::predict::quantised::BinForest`].
//!
//! `BinForest` keeps each tree as a `Vec<BinNode>` in the source
//! `RegTree`'s node order (allocation order, i.e. roughly DFS), with
//! 24-byte nodes and a two-way branch per level. Serving traffic scores
//! a few rows at a time over and over, so the layout — not the
//! arithmetic — dominates latency. *Booster* (arXiv 2011.02022) and the
//! cache-aware design axis of XGBoost itself (arXiv 1603.02754) both
//! point the same way: contiguous per-field arrays, hot levels first.
//! [`FlatForest`] applies that:
//!
//! * **SoA arrays** — `feature`, `split`, `left`, `miss` are parallel
//!   `u32` arrays and `leaf` a parallel [`Float`] array, one slot per
//!   node, all trees concatenated into one arena. A traversal step
//!   touches 16 bytes across four cache-resident streams instead of a
//!   24-byte record.
//! * **Hot-top-levels-first** — each tree is relabelled breadth-first,
//!   so the top levels (hit by *every* row) are packed at the front of
//!   the tree's range and stay in L1 across a block of rows.
//! * **Children adjacent** — BFS enqueues left and right together, so
//!   `right == left + 1` always, and the child step is the branchless
//!   `nid = left + (bin >= split)`.
//! * **Leaf sentinel** — `left == 0` marks a leaf. Slot 0 is the first
//!   tree's root, which is never anybody's child, so 0 is free.
//!
//! # Shifted-bin encoding (the missing-value trick)
//!
//! `BinTree` routes `Option<u32>`: present bin `b` goes left iff
//! `b < split`, missing follows `default_left`. A branchless step needs
//! both cases in one unsigned compare. The flat side shifts every bin
//! by one:
//!
//! * present bin `b`  →  `b + 1` (so `0` never means a value),
//! * absent           →  [`ABSENT`]` == 0`,
//! * stored NaN       →  [`NAN_BIN`]` == u32::MAX` (sparse streams can
//!   carry explicit `nan` values: float traversal evaluates `NaN < t` =
//!   false everywhere — "present, always right" — which `u32::MAX`
//!   represents exactly, as in `QuantisedBatch`),
//!
//! and interior nodes store `split + 1` plus a `miss` substitute bin —
//! `0` when `default_left`, `u32::MAX` when `default_right`. One step is
//!
//! ```text
//! x = bins[feature]; if x == ABSENT { x = miss }; nid = left + (x >= split)
//! ```
//!
//! Exactness, case by case against `BinTree::leaf_for`:
//! * present `b`: `b + 1 < split + 1  ⇔  b < split` — identical;
//! * missing, `default_left`: substitute `0 < split + 1` is always true
//!   (`split + 1 ≥ 1`), so the row goes left — **including** the
//!   pathological `split == 0` node (a hand-edited threshold below the
//!   feature's first cut), which an unshifted substitute cannot express;
//! * missing, `default_right`: substitute `u32::MAX < split + 1` is
//!   false because construction rejects `split == u32::MAX`;
//! * stored NaN: same compare as the `default_right` substitute — always
//!   right, matching `Some(u32::MAX) < split` = false on the `BinTree`.
//!
//! Categorical membership nodes (`cats != 0`) keep the same shifted
//! encoding: `split` stores the feature's shifted first global bin, so
//! `x - split` recovers the local bin tested against the bitset; absent
//! follows the node default and [`NAN_BIN`] wraps past 64 (never a
//! member, always right) — case-for-case the `BinTree` behaviour.
//!
//! Routing is therefore bit-identical to `BinForest`, which PR 5 pinned
//! bit-identical to float traversal; margins accumulate in the same
//! row-major tree order and chunk bracketing as
//! `predict_margins_batch`, so served predictions carry the same FNV-1a
//! fingerprint as the `predict` CLI.

use anyhow::{ensure, Result};

use crate::exec::{ExecContext, ROW_CHUNK};
use crate::predict::quantised::{BinForest, QuantisedBatch};
use crate::quantile::HistogramCuts;
use crate::Float;

/// Shifted bin of an absent value (see module docs).
pub const ABSENT: u32 = 0;
/// Shifted bin of a stored (explicit) NaN: present, always right.
pub const NAN_BIN: u32 = u32::MAX;

/// Rows traversed per tree before moving to the next tree — keeps a
/// tree's hot top levels in L1 across the block while preserving the
/// per-row tree-order accumulation bracketing bit for bit. Shared with
/// the training-side blocked traversal (`predict/quantised.rs`), which
/// adopted this loop shape; re-exported from [`crate::exec`] so both
/// stay in lockstep.
pub use crate::exec::BLOCK_ROWS;

/// An ensemble flattened to parallel SoA arrays (module docs). Grouped
/// by output exactly like `Booster::trees` / `BinForest::groups`.
#[derive(Debug, Clone)]
pub struct FlatForest {
    /// Split feature per node (0 at leaves).
    feature: Vec<u32>,
    /// Shifted exclusive-upper bin per interior node
    /// (`BinNode::split + 1`); 0 at leaves.
    split: Vec<u32>,
    /// Absolute arena index of the left child; `right == left + 1`;
    /// `0` marks a leaf (slot 0 is a root, never a child).
    left: Vec<u32>,
    /// Substitute shifted bin for absent lookups: [`ABSENT`] when the
    /// node defaults left, [`NAN_BIN`] when it defaults right.
    miss: Vec<u32>,
    /// Local-bin membership bitset for categorical splits (0 = numeric
    /// node). Mirrors `BinNode::cats`: for a membership node `split`
    /// holds the feature's *shifted* first global bin (`ptrs[f] + 1`),
    /// so a shifted lookup `x` lands on local bin `x - split` and goes
    /// left iff that bit is set.
    cats: Vec<u64>,
    /// Leaf payload, parallel to the node arrays (0.0 at interiors).
    leaf: Vec<Float>,
    /// Arena index of each tree's root, all groups concatenated.
    roots: Vec<u32>,
    /// `roots[group_ptr[g]..group_ptr[g + 1]]` are output group `g`.
    group_ptr: Vec<usize>,
}

impl FlatForest {
    /// Flatten a bin-translated forest. Fails only on a forest whose
    /// split bins reach `u32::MAX` (impossible for translated trees —
    /// splits are bounded by the cut count — but the encoding's one
    /// reserved value is checked, not assumed).
    pub fn from_bin_forest(forest: &BinForest) -> Result<Self> {
        let mut f = FlatForest {
            feature: Vec::new(),
            split: Vec::new(),
            left: Vec::new(),
            miss: Vec::new(),
            cats: Vec::new(),
            leaf: Vec::new(),
            roots: Vec::new(),
            group_ptr: vec![0],
        };
        for group in &forest.groups {
            for tree in group {
                let root = f.push_tree(tree)?;
                f.roots.push(root);
            }
            f.group_ptr.push(f.roots.len());
        }
        Ok(f)
    }

    /// Append one tree in BFS order; returns its root's arena index.
    fn push_tree(&mut self, tree: &crate::predict::quantised::BinTree) -> Result<u32> {
        let base = self.feature.len();
        ensure!(
            base + tree.nodes.len() <= u32::MAX as usize - 1,
            "flat forest arena exceeds u32 indexing"
        );
        // BFS relabel: visit order IS the slot order, and a node's two
        // children are enqueued together, so they land adjacent.
        let mut order: Vec<usize> = Vec::with_capacity(tree.nodes.len());
        order.push(0);
        let mut head = 0;
        while head < order.len() {
            let n = &tree.nodes[order[head]];
            head += 1;
            if !n.is_leaf() {
                order.push(n.left as usize);
                order.push(n.right as usize);
            }
        }
        let mut slot_of = vec![0u32; tree.nodes.len()];
        for (i, &src) in order.iter().enumerate() {
            slot_of[src] = (base + i) as u32;
        }
        for &src in &order {
            let n = &tree.nodes[src];
            if n.is_leaf() {
                self.feature.push(0);
                self.split.push(0);
                self.left.push(0);
                self.miss.push(0);
                self.cats.push(0);
                self.leaf.push(n.leaf_value);
            } else {
                ensure!(
                    n.split < u32::MAX,
                    "split bin {} leaves no room for the shifted encoding",
                    n.split
                );
                // Membership nodes store `split = ptrs[f]`, numeric nodes
                // the exclusive-upper split bin — both shift by one, so
                // the shifted lookup subtracts back to the same local
                // bin the BinTree computes.
                self.feature.push(n.feature);
                self.split.push(n.split + 1);
                self.left.push(slot_of[n.left as usize]);
                self.miss.push(if n.default_left { ABSENT } else { NAN_BIN });
                self.cats.push(n.cats);
                self.leaf.push(0.0);
            }
        }
        Ok(base as u32)
    }

    pub fn n_groups(&self) -> usize {
        self.group_ptr.len() - 1
    }

    pub fn n_trees(&self) -> usize {
        self.roots.len()
    }

    pub fn n_nodes(&self) -> usize {
        self.feature.len()
    }

    /// Resident bytes of the arena (what the registry reports on load).
    pub fn bytes(&self) -> usize {
        self.feature.len() * 4 * 4
            + self.cats.len() * 8
            + self.leaf.len() * std::mem::size_of::<Float>()
            + self.roots.len() * 4
            + self.group_ptr.len() * std::mem::size_of::<usize>()
    }

    /// Root arena indices of output group `g`.
    #[inline]
    pub fn group_roots(&self, g: usize) -> &[u32] {
        &self.roots[self.group_ptr[g]..self.group_ptr[g + 1]]
    }

    /// Route one row (shifted bins via `bin_of(feature)`) from `root` to
    /// its leaf value. Branchless child select for numeric nodes; one
    /// unsigned compare per level (module docs). Membership nodes test
    /// the local-bin bitset instead: shifted lookup and shifted stored
    /// `ptrs[f]` subtract back to the local bin, absent follows the
    /// node's default, and a stored NaN ([`NAN_BIN`]) wraps far past 64
    /// — never in the set, always right — exactly like the `BinTree`.
    #[inline]
    pub fn leaf_value(&self, root: u32, mut bin_of: impl FnMut(u32) -> u32) -> Float {
        let mut nid = root as usize;
        loop {
            let l = self.left[nid];
            if l == 0 {
                return self.leaf[nid];
            }
            let mut x = bin_of(self.feature[nid]);
            let c = self.cats[nid];
            if c != 0 {
                let go_left = if x == ABSENT {
                    self.miss[nid] == ABSENT
                } else {
                    let local = x.wrapping_sub(self.split[nid]);
                    local < 64 && (c >> local) & 1 == 1
                };
                nid = (l + !go_left as u32) as usize;
                continue;
            }
            if x == ABSENT {
                x = self.miss[nid];
            }
            nid = (l + (x >= self.split[nid]) as u32) as usize;
        }
    }

    /// Margins for a batch — the flat twin of
    /// `predict_margins_batch`, bit-identical to it (and hence to float
    /// traversal) at every thread count: rows are chunked per output
    /// group exactly like `margins_with_lookup`, and inside a chunk each
    /// row still accumulates trees in forest order; the [`BLOCK_ROWS`]
    /// interchange only reorders *which row* traverses next, never a
    /// row's own `+=` bracketing.
    pub fn predict_margins(
        &self,
        base_score: &[Float],
        batch: &FlatBatch,
        exec: &ExecContext,
    ) -> Vec<Vec<Float>> {
        let n = batch.n_rows();
        let mut out: Vec<Vec<Float>> = base_score.iter().map(|&b| vec![b; n]).collect();
        for g in 0..self.n_groups() {
            let roots = self.group_roots(g);
            exec.for_each_slice_mut(&mut out[g], ROW_CHUNK, |_, start, chunk| {
                let mut lo = 0;
                while lo < chunk.len() {
                    let hi = (lo + BLOCK_ROWS).min(chunk.len());
                    for &root in roots {
                        for (i, m) in chunk[lo..hi].iter_mut().enumerate() {
                            let row = start + lo + i;
                            *m += self.leaf_value(root, |f| batch.bin(row, f as usize));
                        }
                    }
                    lo = hi;
                }
            });
        }
        out
    }
}

/// A dense row-major batch of **shifted** bins (module docs): `0` =
/// absent, present bin `b` stored as `b + 1`, stored NaN as
/// [`NAN_BIN`]. The serving queue fills one per micro-batch.
#[derive(Debug, Clone)]
pub struct FlatBatch {
    bins: Vec<u32>,
    n_rows: usize,
    n_cols: usize,
}

impl FlatBatch {
    /// An all-absent batch to be filled with [`set_present`](Self::set_present).
    pub fn zeroed(n_rows: usize, n_cols: usize) -> Self {
        FlatBatch {
            bins: vec![ABSENT; n_rows * n_cols],
            n_rows,
            n_cols,
        }
    }

    /// Reshape to `n_rows × n_cols` with every slot absent, reusing the
    /// existing bin buffer — the serve scorer's per-micro-batch scratch
    /// path. Returns `true` when the resize fit in the buffer's existing
    /// capacity (an arena reuse, counted by `ServeStats::arena_reuse`),
    /// `false` when it had to grow.
    pub fn reset(&mut self, n_rows: usize, n_cols: usize) -> bool {
        let len = n_rows * n_cols;
        let reused = self.bins.capacity() >= len;
        self.bins.clear();
        self.bins.resize(len, ABSENT);
        self.n_rows = n_rows;
        self.n_cols = n_cols;
        reused
    }

    /// Shift-encode a [`QuantisedBatch`] (`n_cols` = the model's feature
    /// count; sparse batches don't carry it). Dense `u32::MAX` slots are
    /// *absent* there and become [`ABSENT`]; sparse `u32::MAX` entries
    /// are *stored NaN* and stay [`NAN_BIN`].
    pub fn from_quantised(qb: &QuantisedBatch, n_cols: usize) -> Self {
        let mut out = FlatBatch::zeroed(qb.n_rows(), n_cols);
        match qb {
            QuantisedBatch::Dense {
                bins,
                n_rows,
                n_cols: qc,
            } => {
                for row in 0..*n_rows {
                    for f in 0..*qc {
                        let b = bins[row * qc + f];
                        if b != u32::MAX {
                            out.bins[row * out.n_cols + f] = b + 1;
                        }
                    }
                }
            }
            QuantisedBatch::Sparse {
                indptr, cols, bins, ..
            } => {
                for row in 0..qb.n_rows() {
                    for k in indptr[row]..indptr[row + 1] {
                        let f = cols[k] as usize;
                        let b = bins[k];
                        out.bins[row * out.n_cols + f] =
                            if b == u32::MAX { NAN_BIN } else { b + 1 };
                    }
                }
            }
        }
        out
    }

    /// Quantise and store one present float value (the protocol layer's
    /// per-token path — same `bin_index_unclamped` mapping as
    /// `QuantisedBatch::from_dmatrix`, so fingerprints match `predict`).
    #[inline]
    pub fn set_value(&mut self, row: usize, f: usize, v: Float, cuts: &HistogramCuts) {
        self.bins[row * self.n_cols + f] = if v.is_nan() {
            NAN_BIN
        } else {
            cuts.bin_index_unclamped(f, v) + 1
        };
    }

    /// Mark a slot absent (dense-stream NaN: missing, not stored NaN).
    #[inline]
    pub fn set_absent(&mut self, row: usize, f: usize) {
        self.bins[row * self.n_cols + f] = ABSENT;
    }

    #[inline]
    pub fn bin(&self, row: usize, f: usize) -> u32 {
        self.bins[row * self.n_cols + f]
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DMatrix;
    use crate::predict::quantised::{BinNode, BinTree};
    use crate::util::prop::{check, Gen};

    /// Hand-build a bin-space stump: route on feature 0, split bin `s`.
    fn stump(split: u32, default_left: bool) -> BinTree {
        BinTree {
            nodes: vec![
                BinNode {
                    feature: 0,
                    split,
                    left: 1,
                    right: 2,
                    default_left,
                    leaf_value: 0.0,
                    cats: 0,
                },
                leaf(-1.0),
                leaf(1.0),
            ],
        }
    }

    fn leaf(v: Float) -> BinNode {
        BinNode {
            feature: 0,
            split: 0,
            left: crate::tree::regtree::NO_CHILD,
            right: crate::tree::regtree::NO_CHILD,
            default_left: false,
            leaf_value: v,
            cats: 0,
        }
    }

    fn flat_of(trees: Vec<BinTree>) -> FlatForest {
        FlatForest::from_bin_forest(&BinForest {
            groups: vec![trees],
        })
        .unwrap()
    }

    /// One row with a single shifted bin for feature 0.
    fn route(f: &FlatForest, shifted: u32) -> Float {
        f.leaf_value(0, |_| shifted)
    }

    #[test]
    fn shifted_encoding_matches_bintree_per_case() {
        for split in [0u32, 1, 5] {
            for default_left in [true, false] {
                let bt = stump(split, default_left);
                let ff = flat_of(vec![bt.clone()]);
                // every present bin around the split, plus missing and NaN
                for b in 0..8u32 {
                    let want = bt.leaf_value_for(|_| Some(b));
                    assert_eq!(route(&ff, b + 1), want, "split={split} b={b}");
                }
                let want_missing = bt.leaf_value_for(|_| None);
                assert_eq!(
                    route(&ff, ABSENT),
                    want_missing,
                    "missing split={split} dl={default_left}"
                );
                let want_nan = bt.leaf_value_for(|_| Some(u32::MAX));
                assert_eq!(route(&ff, NAN_BIN), want_nan, "stored-NaN split={split}");
            }
        }
    }

    #[test]
    fn split_zero_default_left_routes_missing_left() {
        // the case an unshifted substitute bin cannot represent
        let ff = flat_of(vec![stump(0, true)]);
        assert_eq!(route(&ff, ABSENT), -1.0); // missing → left
        assert_eq!(route(&ff, 0 + 1), 1.0); // present bin 0 → right (0 < 0 false)
    }

    #[test]
    fn membership_split_matches_bintree_per_case() {
        // Membership stump on feature 0: ptrs[f] = 3 (the feature's bins
        // start at global bin 3), categories at local bins {0, 2, 5}.
        for default_left in [true, false] {
            let bt = BinTree {
                nodes: vec![
                    BinNode {
                        feature: 0,
                        split: 3, // repurposed: cuts.ptrs[f]
                        left: 1,
                        right: 2,
                        default_left,
                        leaf_value: 0.0,
                        cats: (1 << 0) | (1 << 2) | (1 << 5),
                    },
                    leaf(-1.0),
                    leaf(1.0),
                ],
            };
            let ff = flat_of(vec![bt.clone()]);
            // every nearby global bin, in and out of the feature's range
            for b in 0..12u32 {
                let want = bt.leaf_value_for(|_| Some(b));
                assert_eq!(route(&ff, b + 1), want, "bin {b} dl={default_left}");
            }
            let want_missing = bt.leaf_value_for(|_| None);
            assert_eq!(route(&ff, ABSENT), want_missing, "missing dl={default_left}");
            // stored NaN: never a member, always right — same as BinTree
            let want_nan = bt.leaf_value_for(|_| Some(u32::MAX));
            assert_eq!(route(&ff, NAN_BIN), want_nan, "stored NaN");
            assert_eq!(route(&ff, NAN_BIN), 1.0);
        }
    }

    #[test]
    fn bfs_layout_children_adjacent_leaf_sentinel() {
        // depth-2 left-heavy tree: root(0) -> [a(1), leaf(2)], a -> [leaf(3), leaf(4)]
        let t = BinTree {
            nodes: vec![
                BinNode {
                    feature: 0,
                    split: 2,
                    left: 1,
                    right: 2,
                    default_left: true,
                    leaf_value: 0.0,
                },
                BinNode {
                    feature: 1,
                    split: 3,
                    left: 3,
                    right: 4,
                    default_left: false,
                    leaf_value: 0.0,
                },
                leaf(10.0),
                leaf(20.0),
                leaf(30.0),
            ],
        };
        let ff = flat_of(vec![t]);
        assert_eq!(ff.n_nodes(), 5);
        // BFS: root at 0, its children at 1,2 (adjacent), grandchildren 3,4
        assert_eq!(ff.left[0], 1);
        assert_eq!(ff.left[1], 3);
        for leaf_slot in [2usize, 3, 4] {
            assert_eq!(ff.left[leaf_slot], 0, "leaf sentinel");
        }
        assert_eq!(ff.leaf[2], 10.0);
        assert_eq!(ff.leaf[3], 20.0);
        assert_eq!(ff.leaf[4], 30.0);
    }

    #[test]
    fn from_quantised_dense_and_sparse_shift_correctly() {
        let x = DMatrix::dense(vec![0.5, Float::NAN, 3.5, 1.0], 2, 2);
        let cuts = HistogramCuts::from_dmatrix(&x, 4, None);
        let qb = QuantisedBatch::from_dmatrix(&x, &cuts, 0).unwrap();
        let fb = FlatBatch::from_quantised(&qb, 2);
        // dense NaN slot is absent
        assert_eq!(fb.bin(0, 1), ABSENT);
        for row in 0..2 {
            for f in 0..2 {
                match qb.feature_bin(row, f) {
                    Some(b) => assert_eq!(fb.bin(row, f), b + 1),
                    None => assert_eq!(fb.bin(row, f), ABSENT),
                }
            }
        }
        // sparse with a stored NaN keeps the NAN_BIN sentinel
        let xs = DMatrix::csr(vec![0, 1, 2], vec![0, 0], vec![Float::NAN, 2.0], 2, 2);
        let qs = QuantisedBatch::from_dmatrix(&xs, &cuts, 0).unwrap();
        let fs = FlatBatch::from_quantised(&qs, 2);
        assert_eq!(fs.bin(0, 0), NAN_BIN);
        assert_eq!(fs.bin(0, 1), ABSENT);
    }

    /// Randomised parity: flat traversal == BinTree == float traversal,
    /// with missing values, stored bins on cut boundaries, multi-tree
    /// accumulation and both thread counts.
    #[test]
    fn random_forest_flat_matches_bin_and_margins_match() {
        check(0xf1a7, 25, |g: &mut Gen| {
            let n = g.int(10, 200);
            let cols = g.int(1, 4);
            let vals: Vec<Float> = (0..n * cols)
                .map(|_| {
                    if g.bool(0.15) {
                        Float::NAN
                    } else {
                        g.int(0, 10) as Float - 5.0
                    }
                })
                .collect();
            let x = DMatrix::dense(vals, n, cols);
            let cuts = HistogramCuts::from_dmatrix(&x, g.int(2, 12), None);
            let mut trees = Vec::new();
            for _ in 0..g.int(1, 4) {
                let mut t = crate::tree::RegTree::new_root(0.0, 1.0);
                let mut frontier = vec![(0usize, 0usize)];
                while let Some((nid, depth)) = frontier.pop() {
                    if depth >= 3 || g.bool(0.35) {
                        continue;
                    }
                    let f = g.int(0, cols - 1);
                    let fc = cuts.feature_cuts(f);
                    let threshold = fc[g.int(0, fc.len() - 1)];
                    let (l, r) = t.apply_split(
                        nid,
                        f as u32,
                        threshold,
                        g.bool(0.5),
                        1.0,
                        g.f32(-1.0, 1.0),
                        1.0,
                        g.f32(-1.0, 1.0),
                        1.0,
                    );
                    frontier.push((l, depth + 1));
                    frontier.push((r, depth + 1));
                }
                trees.push(t);
            }
            let forest = BinForest::from_trees(&[trees.clone()], &cuts);
            let flat = FlatForest::from_bin_forest(&forest).unwrap();
            let qb = QuantisedBatch::from_dmatrix(&x, &cuts, 0).unwrap();
            let fb = FlatBatch::from_quantised(&qb, cols);
            // per-row leaf parity against both references
            for row in 0..n {
                for (ti, bt) in forest.groups[0].iter().enumerate() {
                    let root = flat.group_roots(0)[ti];
                    let flat_v = flat.leaf_value(root, |f| fb.bin(row, f as usize));
                    let bin_v = bt.leaf_value_for(|f| qb.feature_bin(row, f));
                    let float_v = {
                        let leaf = trees[ti].leaf_for_row(&x, row);
                        trees[ti].nodes[leaf].leaf_value
                    };
                    assert_eq!(flat_v.to_bits(), bin_v.to_bits(), "row {row} tree {ti}");
                    assert_eq!(flat_v.to_bits(), float_v.to_bits(), "row {row} tree {ti}");
                }
            }
            // block-accumulated margins parity at 1 and 4 threads
            let base = [g.f32(-1.0, 1.0)];
            let want = crate::predict::predict_margins(&[trees], &base, &x);
            for t in [1usize, 4] {
                let got = flat.predict_margins(&base, &fb, &ExecContext::new(t));
                for row in 0..n {
                    assert_eq!(
                        got[0][row].to_bits(),
                        want[0][row].to_bits(),
                        "threads {t} row {row}"
                    );
                }
            }
        });
    }
}
