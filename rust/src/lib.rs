//! # xgb-tpu — XGBoost: Scalable GPU Accelerated Learning, re-built for a
//! Rust + JAX + Pallas three-layer stack.
//!
//! This crate is a from-scratch reproduction of the system described in
//! *"XGBoost: Scalable GPU Accelerated Learning"* (Mitchell, Adinets, Rao,
//! Frank; 2018): an end-to-end accelerator-resident gradient boosting
//! pipeline — feature quantile generation, data compression, multi-device
//! histogram-based decision tree construction (Algorithm 1 of the paper),
//! prediction and gradient evaluation.
//!
//! ## Architecture
//!
//! * **Layer 3 (this crate)** — the coordinator: quantile sketch,
//!   bit-packed compressed matrix, the multi-device tree builder with ring
//!   all-reduce, growth policies, objectives, metrics, boosting loop, CLI.
//! * **Layer 2 (JAX, build time)** — gradient / prediction / histogram
//!   array programs, lowered once to HLO text in `artifacts/`.
//! * **Layer 1 (Pallas, build time)** — the histogram hot-spot kernel
//!   (one-hot matmul formulation; see `DESIGN.md` §Hardware-Adaptation).
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT C API
//! (`xla` crate) so the training hot path never touches Python.
//!
//! ## Data flow: streaming two-pass ingestion
//!
//! The paper's scale story (115M rows, §3) rests on never holding the
//! data in its expensive form: features are quantised (§2.1) and
//! bit-packed to `⌈log2(symbols)⌉` bits (§2.2) so only the compressed
//! ELLPACK representation persists. Ingestion honours that end to end —
//! every construction path (files, synthetic generators, in-memory
//! matrices) rides one pull-based [`data::BatchSource`] pipeline:
//!
//! 1. **Pass 1 — sketch** ([`data::scan_source`]): each bounded row batch
//!    folds into the per-column incremental quantile sketch
//!    ([`quantile::StreamingSketch`], merge/prune per chunk) while
//!    O(`n_rows`) metadata accumulates (labels, ranking groups, row
//!    widths). Output: frozen [`quantile::HistogramCuts`].
//! 2. **Pass 2 — quantise + pack**: the source is reset and re-streamed;
//!    each batch is quantised against the frozen cuts and bit-packed
//!    **directly into the owning device shard's pages**
//!    ([`compress::CompressedMatrixBuilder`]) — the raw float matrix and
//!    the u32 bin matrix never materialize. Peak transient float-buffer
//!    bytes are O(`batch_rows × n_cols`), not O(`n_rows × n_cols`)
//!    (measured by `benches/memory_footprint.rs` → `BENCH_memory.json`).
//!
//! Streamed and in-memory training are **bit-identical** for every batch
//! size and thread count: the sketch is a pure function of each column's
//! value sequence, batches quantise row-locally, and rows append to
//! shards in global order (`rust/tests/streaming_ingest.rs`). Train
//! out-of-core with [`gbm::Learner::train_from_source`] (CLI: `--stream
//! --batch-rows N`).
//!
//! ## Memory hierarchy: resident vs spilled pages
//!
//! Streaming ingestion bounds the *transient* buffers, but the packed
//! shards themselves were still an O(`n_rows`) allocation — the ceiling
//! was host RAM. With `max_resident_pages > 0`
//! ([`gbm::LearnerParams::max_resident_pages`]; CLI
//! `--max-resident-pages N`, page size `--page-rows`), pass 2 spills each
//! sealed fixed-row-count page to a per-shard temp file
//! ([`compress::page`]) and every page lives in exactly one of three
//! states: **spilled** (on disk), **resident** (checksum-verified into a
//! ref-counted handle by the histogram round's double-buffered prefetch
//! worker or the repartition cursor), or **released** (handle dropped as
//! the row walk leaves the page). The peak-memory contract is now stated
//! per shard in pages: resident compressed bytes ≤ `max_resident_pages ×
//! page_bytes`, measured per tree in
//! [`coordinator::BuildStats::peak_resident_page_bytes`] (with
//! `pages_loaded` and the prefetch-hidden I/O seconds alongside) and
//! tracked by `benches/memory_footprint.rs` (M3). The training ceiling
//! moves from host RAM to disk; trees, predictions and metrics stay
//! **bit-identical** to the fully resident run at every page size,
//! budget, thread count and device count
//! (`rust/tests/external_memory.rs`).
//!
//! ### Prediction lifecycle: frozen cuts → bin trees → paged traversal
//!
//! Inference rides the same hierarchy ([`predict::quantised`]). The
//! frozen [`quantile::HistogramCuts`] travel with the trained model
//! (`Booster::cuts`, persisted in the model file), and each trained
//! tree's float thresholds are translated **once** into per-feature bin
//! thresholds (`threshold_to_bin`). Because every split threshold *is* a
//! cut value, the bin comparison `bin < threshold_to_bin(t)` is exactly
//! the float comparison `v < t` — so prediction walks the packed ELLPACK
//! symbols directly (resident [`compress::CompressedMatrix`] words, or
//! spilled pages streamed back through the same prefetch worker and
//! `max_resident_pages` budget as training) and is **bit-identical** to
//! the float path. Three inference shapes, one result:
//!
//! * **shard prediction** — `MultiDeviceCoordinator::predict_margins` /
//!   `predict_leaf_indices` score the training shards in place, paged or
//!   resident, concurrently on the exec pool;
//! * **streaming prediction** — `Booster::predict_from_source` /
//!   `evaluate_from_source` quantise each [`data::BatchSource`] batch
//!   against the frozen cuts (unclamped transient form, exact even for
//!   values outside the training range) and score batch-at-a-time:
//!   O(`batch_rows × n_cols`) transient bytes, no second pass;
//! * **external-memory prediction** — `Booster::predict_paged` packs the
//!   stream into spilled pages and traverses them under the budget (CLI
//!   `predict --stream` / `--max-resident-pages`, ditto `eval`).
//!
//! In-training validation scoring uses the same translation (the valid
//! set is quantised once against the training cuts), closing the last
//! float-matrix dependency of the boosting loop: ingest → train →
//! predict/eval all run from the compressed representation, pinned by
//! `rust/tests/compressed_predict.rs`.
//!
//! ### Serving lifecycle: flat forest, hot-swap registry, micro-batches
//!
//! Online inference (`xgb-tpu serve`, module [`serve`]) extends the
//! same chain one more link. A [`serve::ModelRegistry`] loads the model
//! file (fail-fast if it carries no `cuts` section — legacy files must
//! be retrained and re-saved), translates the trees to bin space
//! ([`predict::quantised::BinForest`]) and flattens them into a
//! [`serve::FlatForest`]: one contiguous SoA arena (`feature` / `split`
//! / `left` / `miss` / `leaf` parallel arrays), each tree BFS-relabelled
//! so its hot top levels lead and siblings sit adjacent, traversed
//! branchlessly over shifted bins (`left + (bin >= split)` per level,
//! missing and stored-NaN folded into the same unsigned compare).
//! Requests stream in line-by-line ([`serve::protocol`]), coalesce in a
//! bounded micro-batch queue ([`serve::queue`]) and score on the
//! [`exec`] pool; `!reload` (or an mtime poll) atomically swaps the
//! `Arc`'d model — in-flight batches finish on the old epoch, new
//! batches see the new one.
//!
//! **Determinism contract:** each stream's responses return in request
//! order (checked per reply), and every value is bit-identical to the
//! `predict` CLI — same FNV-1a fingerprint — at every `--threads`,
//! `--batch-max` and coalescing pattern, because flat traversal routes
//! identically to `BinForest` (and hence to float traversal) and
//! batches accumulate margins with the same chunk bracketing
//! (`rust/tests/serving.rs`, `rust/tests/prop_invariants.rs`).
//!
//! ## Quickstart
//!
//! Training goes through the typed [`gbm::Learner`] façade: pick an
//! [`gbm::ObjectiveKind`], configure the fluent builder, and `build()`
//! validates the whole configuration up front (reporting *every*
//! cross-field problem, not just the first) before any data is touched.
//!
//! ```no_run
//! use xgb_tpu::data::synthetic::{self, DatasetSpec};
//! use xgb_tpu::gbm::{EarlyStopping, Learner, MetricKind, ObjectiveKind};
//!
//! let ds = synthetic::generate(&DatasetSpec::higgs_like(10_000), 42);
//! let mut learner = Learner::builder()
//!     .objective(ObjectiveKind::BinaryLogistic)
//!     .eval_metric(MetricKind::Auc)
//!     .num_rounds(20)
//!     .callback(Box::new(EarlyStopping::new(3)))
//!     .build()
//!     .expect("configuration is valid");
//! let booster = learner.train(&ds.train, Some(&ds.valid)).unwrap();
//! let preds = booster.predict(&ds.valid.x);
//! # let _ = preds;
//! ```
//!
//! User-defined losses and metrics register by name alongside the
//! built-ins (`gbm::ObjectiveRegistry` / `gbm::MetricRegistry`) and then
//! work everywhere a name does: the builder, config files, the CLI, and
//! model-file round-trips. Training behaviour is extensible through the
//! `gbm::Callback` trait (`EarlyStopping`, `EvalLogger`, `TimeBudget`
//! ship in-crate).
//!
//! ## Execution model
//!
//! Two clocks coexist (see [`exec`] for the full story):
//!
//! * **Simulated multi-GPU clock** — the coordinator prices each
//!   histogram round as `max(per-device compute) + ring-collective cost`
//!   (DESIGN.md §5). This is the Figure-2 analytic quantity and is
//!   independent of the host machine.
//! * **Real parallel engine** — device shards actually run concurrently
//!   on OS threads, and the per-shard hot loops (histogram build, row
//!   repartitioning, quantile sketching, gradient computation, batch
//!   prediction) are chunk-parallel on the same pool. The thread budget
//!   is the `threads` knob on [`gbm::LearnerParams`] /
//!   [`gbm::LearnerBuilder`] and the CLI (`--threads`; `0` = all cores,
//!   `1` = serial). Measured per-phase wall-clock is reported in
//!   `coordinator::BuildStats` alongside the simulated clock.
//!
//! The engine is a **persistent parked worker pool**: the first parallel
//! call spawns `threads - 1` workers which then *park* on a condvar
//! between calls — each subsequent round pays one wake broadcast (the
//! cumulative cost is `exec::ExecContext::wake_wall_secs`, surfaced as
//! `BuildStats::wake_wall_secs`) instead of `threads` spawn/join pairs,
//! mirroring how a GPU keeps its SMs resident rather than re-launching a
//! context per kernel. `ExecContext::fork` never spawns: a forked
//! sub-context is a *budget sub-slice* of the same pool, so nested
//! device × in-shard parallelism shares one set of OS threads. Workers
//! join only when the pool is dropped. `XGB_SCOPED_EXEC=1` selects the
//! previous spawn-per-call scoped engine, kept as the independent
//! reference the property tests and the `ci.sh` exec-mode smoke compare
//! against — both engines are bit-identical by construction because the
//! chunking and merge order (below) never depend on which engine ran.
//!
//! On top of the pool sits a **round-arena layer**: the buffers a
//! boosting round churns through — histogram partials and stored
//! node histograms, flattened all-reduce payloads, decode blocks,
//! partitioner scratch, per-round gradient vectors, the serve scorer's
//! batch scratch — come from reusable pools (`exec::BufferPool`,
//! `hist::HistArena`) that recycle capacity instead of reallocating, so
//! the steady state allocates ~nothing after the first round.
//! `BuildStats::arena_allocs` counts the fresh allocations per round
//! (≈0 at steady state) and `BuildStats::arena_bytes_reused` the bytes
//! served from recycled capacity; the serve path reports the analogous
//! `ServeStats::arena_reuse`.
//!
//! Results are **bit-identical for every thread count**: all
//! floating-point reductions split work into fixed-size chunks and merge
//! partials in ascending chunk order (never completion order), so
//! parallelism changes wall-clock only — trees, predictions and metrics
//! do not move. `rust/tests/parallel_exec.rs` pins this contract.
//!
//! Within each chunk the hot loops run as **blocked, branchless
//! kernels** — the CPU mirror of the paper's wide data-parallel GPU
//! kernels. Histogram accumulation decodes each block of rows through a
//! multi-symbol shift-cascade unpacker ([`compress::unpack`]; every
//! packed 64-bit word read once), converts the block's gradients to f64
//! once, and replaces the per-symbol validity branch with index
//! arithmetic into a one-slot-wider partial histogram (`min(bin,
//! n_bins)`: nulls land in a scratch slot discarded on merge — the
//! "null-scratch-slot" trick, [`hist`] module docs). Bin-tree traversal
//! advances `exec::BLOCK_ROWS` rows one tree level at a time with a
//! branchless child select ([`predict::quantised`], [`serve`]). Both
//! shapes batch only non-floating-point work — the f64/f32 adds stay
//! strictly row-sequential inside each chunk — so blocked and scalar
//! kernels are **bit-identical by construction**, not just numerically
//! close. `XGB_SCALAR_KERNELS=1` selects the row-at-a-time scalar
//! reference loops (kept as the independent implementation the property
//! tests compare against); `rust/tests/prop_invariants.rs` and the
//! `ci.sh` kernel-mode smoke pin the equivalence.
//!
//! **Distributed transport** — the multi-device collective exists in two
//! interchangeable forms. By default the per-device histogram partials
//! live in one process and merge through the in-process ring simulation
//! ([`comm::ring`]), which also feeds the calibrated α–β cost model.
//! With `--dist-peers` (API: `dist_peers` on [`gbm::LearnerParams`]),
//! each rank becomes its own OS process: it ingests the same input,
//! builds only its own rank's device histograms, and merges them over a
//! real TCP ring ([`comm::wire`]) — length-prefixed, FNV-1a-checksummed
//! frames ([`comm::net`]) with connect retry + backoff during ring
//! assembly and 30-second read/write timeouts afterwards, so a crashed
//! peer surfaces as an actionable error naming the rank instead of a
//! hang. The wire engine replays the simulation's exact chunk
//! boundaries and f64 operand order, so a `w`-process run is
//! **byte-identical** — trees, eval lines, prediction checksums — to a
//! single-process run with `n_devices == w`
//! (`prop_wire_ring_matches_simulation_bitwise` and the `ci.sh`
//! distributed smoke pin this). Chunks ship quantised by default
//! (lossless zero-bin mask + narrow bit-packing through [`compress`];
//! `--dist-payload raw` for plain f64 bytes).
//!
//! ## Scenario surface
//!
//! Three workload families extend the core pipeline beyond plain
//! regression/classification, each riding the same bit-identity
//! contract across threads × devices × resident/paged/streamed
//! (`rust/tests/scenarios.rs`):
//!
//! * **Objective contract** — an objective ([`gbm::Objective`],
//!   registered by name in `gbm::ObjectiveRegistry`) maps margins to
//!   per-row `(grad, hess)` pairs; `gradients_par` must be bit-identical
//!   to the serial path at every thread count (chunk-concatenation, no
//!   reductions). The derivatives are checked against central finite
//!   differences of the reference losses for **every** registered
//!   built-in (`prop_objective_gradients_match_finite_difference`, with
//!   a coverage guard that fails when a new objective is registered
//!   without a test). Two intentional conventions differ from the true
//!   second derivative and are pinned rather than FD-checked:
//!   `reg:quantile` (pinball loss; the subgradient at `y == margin`
//!   takes the `y − margin ≤ 0` branch, i.e. grad `1 − α`, and the
//!   hessian is the constant `1.0` Newton damping), and `multi:softmax`
//!   (hessian `2·p·(1−p)`, XGBoost's convention, not the cross-entropy
//!   `p·(1−p)`). `reg:tweedie` (`--tweedie-variance-power` ∈ (1,2)) and
//!   `survival:aft` (normal/logistic log-likelihood over
//!   `(lower, upper)` interval labels; `--aft-sigma`) are exact
//!   derivatives of their NLLs, floored at `1e-16` like
//!   `binary:logistic`.
//!
//! * **Categorical features** — features tagged via the loader's `cat:`
//!   CSV-header prefix, `--categorical`, or
//!   `LearnerBuilder::categorical_features` carry integer codes in
//!   `[0, 64)`. Codes are
//!   sketched like floats but cut at integer boundaries (one bin per
//!   observed code), and splits on categorical features are
//!   **membership** tests (gain-sorted greedy one-vs-rest growth): the
//!   split node stores a u64 bitset over raw codes — bit `c` set ⇔ code
//!   `c` routes left — written to the model file as a `cat` node line.
//!   Missing values follow the learned default edge; values outside
//!   `[0, 64)` route right, and non-integer values share the routing of
//!   their integer truncation. At bin translation the code bitset
//!   becomes a local-bin bitset against the frozen cuts, so float,
//!   bin-tree and flat-serving traversal agree bit-for-bit on every
//!   in-vocabulary value; a code never seen at training time routes
//!   right on the float path but quantises to the nearest larger
//!   trained code's bin on the compressed paths — keep inference data
//!   in the training vocabulary when exact cross-path parity matters.
//!
//! * **Training continuation** — [`gbm::Learner::resume`] (CLI
//!   `--resume model.txt`) loads a serialized [`gbm::Booster`],
//!   revalidates the live params against the persisted ones (objective
//!   + its shaping params, `max_bins`) and keeps boosting **against the
//!   frozen cuts**: new data is quantised on the original grid, never
//!   re-sketched, so `train(a)` then `resume(b)` is byte-identical —
//!   model file included — to an uninterrupted `train(a + b)` (the
//!   sampling RNG fast-forwards by the prior round count). Pinned by
//!   `resume_reproduces_uninterrupted_run_bit_for_bit` and the `ci.sh`
//!   continuation smoke.

pub mod baselines;
pub mod bench;
pub mod comm;
pub mod compress;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod gbm;
pub mod hist;
pub mod predict;
pub mod quantile;
pub mod runtime;
pub mod serve;
pub mod tree;
pub mod util;

/// Scalar type used for feature values and raw gradients.
pub type Float = f32;

/// A first/second-order gradient pair (paper §2.5). Stored single-precision;
/// histogram accumulation is double-precision (`hist::GradPairF64`), matching
/// XGBoost's `GradientPair` / `GradientPairPrecise` split.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct GradPair {
    pub grad: Float,
    pub hess: Float,
}

impl GradPair {
    #[inline]
    pub fn new(grad: Float, hess: Float) -> Self {
        Self { grad, hess }
    }
}

impl std::ops::Add for GradPair {
    type Output = GradPair;
    #[inline]
    fn add(self, rhs: GradPair) -> GradPair {
        GradPair::new(self.grad + rhs.grad, self.hess + rhs.hess)
    }
}

impl std::ops::AddAssign for GradPair {
    #[inline]
    fn add_assign(&mut self, rhs: GradPair) {
        self.grad += rhs.grad;
        self.hess += rhs.hess;
    }
}

impl std::ops::Sub for GradPair {
    type Output = GradPair;
    #[inline]
    fn sub(self, rhs: GradPair) -> GradPair {
        GradPair::new(self.grad - rhs.grad, self.hess - rhs.hess)
    }
}
