//! Gradient histogram construction — the compute hot-spot of the paper
//! (§2.3: "the tree construction problem [reduces] largely to one gradient
//! summation into histograms").
//!
//! A node's histogram is a flat array over the **global bins** of
//! [`crate::quantile::HistogramCuts`]: entry `b` holds the (f64-accumulated)
//! sum of gradient pairs of the node's rows whose feature value falls in
//! bin `b`. Builders exist for both the uncompressed
//! [`QuantizedMatrix`](crate::quantile::QuantizedMatrix) and the bit-packed
//! [`CompressedMatrix`](crate::compress::CompressedMatrix) (§2.2) — the
//! parity between the two is an integration test and the cost difference is
//! an ablation bench.
//!
//! The **subtraction trick** (`sibling = parent − built_child`) halves the
//! histogram work per level: only the smaller child of each split is built
//! from rows; see [`Histogram::subtract_from`].
//!
//! On real hardware this phase is the paper's GPU kernel with shared-memory
//! atomics; the Pallas L1 kernel re-expresses it as a one-hot matmul (see
//! `python/compile/kernels/histogram.py` and DESIGN.md §1). The Rust
//! builder here is the per-device reference implementation and the CPU
//! baseline.
//!
//! ## Canonical accumulation order
//!
//! All builders — serial and parallel — accumulate through the same
//! **fixed-chunk** structure: the row set is split into
//! [`crate::exec::ROW_CHUNK`]-sized chunks (boundaries depend only on the
//! row count), each chunk is summed into a fresh partial histogram in row
//! order, and partials are folded into `out` in ascending chunk order.
//! Because the bracketing of every f64 sum is a pure function of the
//! input, `build_histogram_*` and `build_histogram_*_par` agree **bit
//! for bit** at every thread count; the parallel variants only change
//! which OS thread computes each chunk.
//!
//! ## Blocked kernels and the null-scratch-slot trick
//!
//! Inside one chunk, the default [`crate::exec::KernelMode::Blocked`]
//! kernels process rows in [`crate::exec::HIST_BLOCK_ROWS`]-row blocks:
//! the block's `GradPair`s are converted to f64 **once** up front (the
//! scalar loop runs `GradPairF64::from_single` per row per node per
//! round) and packed symbols are block-decoded into a small scratch
//! buffer through `compress::unpack` (each packed word read once, its
//! symbols emitted by a shift cascade). The inner accumulation replaces
//! the scalar `if b < null` branch with mask arithmetic: every partial
//! histogram carries **one extra scratch slot** at index `n_bins`, the
//! null symbol's own index, and each symbol adds at `min(b, n_bins)` —
//! unconditionally in bounds (packed symbols are ≤ null by
//! construction), with null/padding gradients landing in the scratch
//! slot, which the chunk merge simply discards.
//!
//! **Bit-parity argument.** Blocking batches only non-floating-point
//! work — symbol decode and the one-time gradient conversion. The f64
//! adds into any given bin still happen strictly in row order within the
//! chunk (the block passes iterate rows in sequence), and partials still
//! fold in ascending chunk order, so the bracketing of every f64 sum is
//! *unchanged* from the scalar reference: `KernelMode::Scalar` (env knob
//! `XGB_SCALAR_KERNELS=1`) and `KernelMode::Blocked` agree bit for bit
//! at every thread count, page size and budget. Pinned by the
//! cross-width property test in `rust/tests/prop_invariants.rs` and the
//! `ci.sh` checksum smoke.

use anyhow::Result;

use crate::compress::page::{PageHandle, PageStore};
use crate::compress::CompressedMatrix;
use crate::exec::{ArenaStats, BufferPool, ExecContext, KernelMode, HIST_BLOCK_ROWS, ROW_CHUNK};
use crate::quantile::QuantizedMatrix;
use crate::GradPair;

/// Reusable round scratch for the histogram builders: the per-chunk
/// scratch-extended partials (`n_bins + 1` slots) and the blocked
/// kernels' per-block symbol decode buffers. Owned long-term by the
/// executing backend (`coordinator::NativeBackend`), so after the
/// warm-up round every chunk takes a recycled buffer instead of
/// allocating — the steady-state training rounds allocate ~nothing
/// here. Buffer reuse never changes *values*: partials come back
/// cleared and the decode scratch is fully overwritten before reads,
/// so the bit-identity contract is untouched.
#[derive(Debug, Default)]
pub struct HistArena {
    /// Per-chunk partial histograms (`Vec<GradPairF64>`, width `n_bins + 1`).
    pub partials: BufferPool<GradPairF64>,
    /// Blocked-kernel symbol decode scratch (`HIST_BLOCK_ROWS × stride`).
    pub sym: BufferPool<u32>,
}

impl HistArena {
    /// Combined read-and-reset counters of both pools.
    pub fn drain_stats(&self) -> ArenaStats {
        let mut s = self.partials.drain_stats();
        s.merge(self.sym.drain_stats());
        s
    }
}

impl Clone for HistArena {
    /// Clones start with fresh (empty) pools — an arena is per-owner
    /// scratch, not shared state.
    fn clone(&self) -> Self {
        HistArena::default()
    }
}

/// Double-precision gradient pair used for histogram accumulation
/// (XGBoost's `GradientPairPrecise`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct GradPairF64 {
    pub grad: f64,
    pub hess: f64,
}

impl GradPairF64 {
    #[inline]
    pub fn new(grad: f64, hess: f64) -> Self {
        Self { grad, hess }
    }

    #[inline]
    pub fn from_single(g: GradPair) -> Self {
        Self {
            grad: g.grad as f64,
            hess: g.hess as f64,
        }
    }
}

impl std::ops::Add for GradPairF64 {
    type Output = GradPairF64;
    #[inline]
    fn add(self, r: GradPairF64) -> GradPairF64 {
        GradPairF64::new(self.grad + r.grad, self.hess + r.hess)
    }
}

impl std::ops::AddAssign for GradPairF64 {
    #[inline]
    fn add_assign(&mut self, r: GradPairF64) {
        self.grad += r.grad;
        self.hess += r.hess;
    }
}

impl std::ops::Sub for GradPairF64 {
    type Output = GradPairF64;
    #[inline]
    fn sub(self, r: GradPairF64) -> GradPairF64 {
        GradPairF64::new(self.grad - r.grad, self.hess - r.hess)
    }
}

/// A per-node gradient histogram over all global bins.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Histogram {
    pub bins: Vec<GradPairF64>,
}

impl Histogram {
    pub fn zeros(n_bins: usize) -> Self {
        Histogram {
            bins: vec![GradPairF64::default(); n_bins],
        }
    }

    pub fn n_bins(&self) -> usize {
        self.bins.len()
    }

    /// Zero every bin (scratch reuse in the chunked builders).
    pub fn reset(&mut self) {
        self.bins.fill(GradPairF64::default());
    }

    /// Total gradient sum over one feature's bin range. The range is
    /// validated once by the subslice; the fold then iterates without
    /// any per-element bounds re-check (same add order as before, so
    /// split evaluation is bit-unchanged).
    pub fn feature_sum(&self, lo: usize, hi: usize) -> GradPairF64 {
        self.bins[lo..hi]
            .iter()
            .fold(GradPairF64::default(), |acc, b| acc + *b)
    }

    /// `self = other − self` — the subtraction trick, computing this
    /// (larger) sibling from the parent's histogram and the built smaller
    /// child currently stored in `self`... inverted: callers hold
    /// `parent` and `small_child`; see [`subtract`] for the free function.
    pub fn subtract_from(&mut self, parent: &Histogram) {
        assert_eq!(self.bins.len(), parent.bins.len());
        for (s, p) in self.bins.iter_mut().zip(parent.bins.iter()) {
            *s = *p - *s;
        }
    }

    /// Elementwise add (all-reduce combiner).
    pub fn add(&mut self, other: &Histogram) {
        assert_eq!(self.bins.len(), other.bins.len());
        for (s, o) in self.bins.iter_mut().zip(other.bins.iter()) {
            *s += *o;
        }
    }

    /// Flatten to `[g0, h0, g1, h1, ...]` (wire format for the all-reduce
    /// and the XLA artifact boundary).
    pub fn to_flat(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.bins.len() * 2);
        for b in &self.bins {
            out.push(b.grad);
            out.push(b.hess);
        }
        out
    }

    pub fn from_flat(flat: &[f64]) -> Self {
        assert_eq!(flat.len() % 2, 0);
        Histogram {
            bins: flat
                .chunks_exact(2)
                .map(|c| GradPairF64::new(c[0], c[1]))
                .collect(),
        }
    }
}

/// `parent − child`, allocating.
pub fn subtract(parent: &Histogram, child: &Histogram) -> Histogram {
    let mut out = child.clone();
    out.subtract_from(parent);
    out
}

/// Scalar reference kernel over the uncompressed quantised matrix: sum
/// one chunk of rows in row order, one branchy add per symbol. Kept as
/// the `KernelMode::Scalar` path the blocked kernel is pinned against.
/// `bins` is the scratch-extended partial (`n_bins + 1` slots); the
/// scalar loop never touches the scratch slot.
fn accumulate_quantized_scalar(
    qm: &QuantizedMatrix,
    gradients: &[GradPair],
    rows: &[u32],
    bins: &mut [GradPairF64],
) {
    let null = qm.null_symbol();
    let stride = qm.row_stride;
    for &r in rows {
        let r = r as usize;
        let g = GradPairF64::from_single(gradients[r]);
        let row = &qm.bins[r * stride..(r + 1) * stride];
        for &b in row {
            // `b < null == n_bins` is the validity test AND the bounds
            // proof (quantizer guarantees symbols <= null).
            if b < null {
                // Safety: b < n_bins < bins.len(), checked above.
                unsafe { *bins.get_unchecked_mut(b as usize) += g };
            }
        }
    }
}

/// Blocked, branchless kernel over the uncompressed quantised matrix
/// (module docs): per `HIST_BLOCK_ROWS` block, convert the gradients to
/// f64 once, then add every symbol at `min(b, n_bins)` — nulls land in
/// the scratch slot, real bins in place, no branch in the inner loop.
/// The f64 adds stay strictly row-sequential, so the result is
/// bit-identical to [`accumulate_quantized_scalar`].
fn accumulate_quantized_blocked(
    qm: &QuantizedMatrix,
    gradients: &[GradPair],
    rows: &[u32],
    bins: &mut [GradPairF64],
) {
    let scratch = bins.len() - 1; // == qm.n_bins, the null symbol's slot
    let stride = qm.row_stride;
    let mut g = [GradPairF64::default(); HIST_BLOCK_ROWS];
    for block in rows.chunks(HIST_BLOCK_ROWS) {
        for (gj, &r) in g.iter_mut().zip(block) {
            *gj = GradPairF64::from_single(gradients[r as usize]);
        }
        for (j, &r) in block.iter().enumerate() {
            let r = r as usize;
            let gj = g[j];
            for &b in &qm.bins[r * stride..(r + 1) * stride] {
                let idx = (b as usize).min(scratch);
                // Safety: idx <= scratch < bins.len() by the min above.
                unsafe { *bins.get_unchecked_mut(idx) += gj };
            }
        }
    }
}

/// Scalar reference kernel over the bit-packed compressed matrix — the
/// original per-symbol u128 cursor decode plus the branchy add; the
/// `KernelMode::Scalar` path.
fn accumulate_compressed_scalar(
    cm: &CompressedMatrix,
    gradients: &[GradPair],
    rows: &[u32],
    bins: &mut [GradPairF64],
) {
    let null = cm.null_symbol();
    let n_bins = bins.len() as u32 - 1;
    for &r in rows {
        let r = r as usize;
        let g = GradPairF64::from_single(gradients[r]);
        cm.for_each_symbol_in_row_scalar(r, |b| {
            // the packed mask can exceed n_bins, so `b < n_bins` (== null)
            // is both the null/padding filter and the bounds proof
            debug_assert!(b <= null);
            if b < n_bins {
                // Safety: b < n_bins < bins.len(), checked above.
                unsafe { *bins.get_unchecked_mut(b as usize) += g };
            }
        });
    }
}

/// Blocked, branchless kernel over the bit-packed compressed matrix —
/// the paper's §2.2 "packed and unpacked at runtime using bitwise
/// operations" path restructured for data-level parallelism: each
/// `HIST_BLOCK_ROWS` block decodes its rows through the multi-symbol
/// shift-cascade decoder into a scratch buffer (each packed word read
/// once) and converts its gradients once, then the branchless
/// `min(b, n_bins)` accumulation runs over the decoded symbols in row
/// order. Bit-identical to [`accumulate_compressed_scalar`].
fn accumulate_compressed_blocked(
    cm: &CompressedMatrix,
    gradients: &[GradPair],
    rows: &[u32],
    bins: &mut [GradPairF64],
    sym_pool: &BufferPool<u32>,
) {
    let scratch = bins.len() - 1; // == cm.n_bins, the null symbol's slot
    let stride = cm.row_stride;
    let mut g = [GradPairF64::default(); HIST_BLOCK_ROWS];
    let mut sym = sym_pool.take(HIST_BLOCK_ROWS * stride);
    for block in rows.chunks(HIST_BLOCK_ROWS) {
        for (j, &r) in block.iter().enumerate() {
            g[j] = GradPairF64::from_single(gradients[r as usize]);
            cm.decode_row_into(r as usize, &mut sym[j * stride..(j + 1) * stride]);
        }
        for j in 0..block.len() {
            let gj = g[j];
            for &b in &sym[j * stride..(j + 1) * stride] {
                let idx = (b as usize).min(scratch);
                // Safety: idx <= scratch < bins.len() by the min above.
                unsafe { *bins.get_unchecked_mut(idx) += gj };
            }
        }
    }
    sym_pool.put(sym);
}

/// Fold the real bins of a scratch-extended partial into `out` in
/// ascending bin order; the trailing null-scratch slot is discarded
/// (`zip` stops at `out.bins.len()`).
fn fold_partial(out: &mut Histogram, partial: &[GradPairF64]) {
    debug_assert_eq!(partial.len(), out.bins.len() + 1);
    for (o, p) in out.bins.iter_mut().zip(partial.iter()) {
        *o += *p;
    }
}

/// The canonical fixed-chunk accumulation shared by every builder (see
/// module docs): identical bracketing whether chunks run inline or on the
/// pool, so results are bit-identical at every thread count. Every chunk
/// accumulates into a zeroed scratch-extended partial (`n_bins + 1`
/// slots — the extra slot is the blocked kernels' null scratch; the
/// scalar kernels simply never touch it) whose real bins fold into `out`
/// in ascending chunk order. Starting every f64 chain at `+0.0` keeps
/// the fold bit-exact: a chain seeded at `+0.0` can never produce
/// `-0.0`, and `+0.0 + x == x` bitwise for every such `x`.
fn chunked_build<F>(
    n_bins: usize,
    rows: &[u32],
    out: &mut Histogram,
    exec: &ExecContext,
    arena: &HistArena,
    accumulate: F,
) where
    F: Fn(&[u32], &mut [GradPairF64]) + Sync,
{
    let width = n_bins + 1;
    if rows.len() <= ROW_CHUNK {
        let mut partial = arena.partials.take(width);
        accumulate(rows, &mut partial);
        fold_partial(out, &partial);
        arena.partials.put(partial);
        return;
    }
    if exec.threads() <= 1 {
        let mut partial = arena.partials.take(width);
        for chunk in rows.chunks(ROW_CHUNK) {
            partial.fill(GradPairF64::default());
            accumulate(chunk, &mut partial);
            fold_partial(out, &partial);
        }
        arena.partials.put(partial);
    } else {
        let partials = exec.map_chunks(rows.len(), ROW_CHUNK, |_, r| {
            let mut p = arena.partials.take(width);
            accumulate(&rows[r], &mut p);
            p
        });
        // merge in ascending chunk index — the determinism contract
        for p in partials {
            fold_partial(out, &p);
            arena.partials.put(p);
        }
    }
}

/// Histogram builder over the uncompressed quantised matrix.
///
/// `rows` selects the node's instances (the row partitioner's segment).
pub fn build_histogram_quantized(
    qm: &QuantizedMatrix,
    gradients: &[GradPair],
    rows: &[u32],
    out: &mut Histogram,
) {
    build_histogram_quantized_par(qm, gradients, rows, out, &ExecContext::serial());
}

/// Chunk-parallel histogram builder over the uncompressed quantised
/// matrix — bit-identical to [`build_histogram_quantized`] at every
/// thread count. Kernel mode comes from the environment
/// (`XGB_SCALAR_KERNELS`, read once); both modes are bit-identical.
pub fn build_histogram_quantized_par(
    qm: &QuantizedMatrix,
    gradients: &[GradPair],
    rows: &[u32],
    out: &mut Histogram,
    exec: &ExecContext,
) {
    let arena = HistArena::default();
    build_histogram_quantized_par_mode(qm, gradients, rows, out, exec, KernelMode::from_env(), &arena);
}

/// [`build_histogram_quantized_par`] with an explicit [`KernelMode`] and
/// a caller-owned [`HistArena`] — lets benches and parity tests compare
/// Blocked vs Scalar in-process, and lets the training backend recycle
/// chunk scratch across rounds.
pub fn build_histogram_quantized_par_mode(
    qm: &QuantizedMatrix,
    gradients: &[GradPair],
    rows: &[u32],
    out: &mut Histogram,
    exec: &ExecContext,
    mode: KernelMode,
    arena: &HistArena,
) {
    assert_eq!(out.n_bins(), qm.n_bins);
    match mode {
        KernelMode::Blocked => chunked_build(qm.n_bins, rows, out, exec, arena, |chunk, bins| {
            accumulate_quantized_blocked(qm, gradients, chunk, bins)
        }),
        KernelMode::Scalar => chunked_build(qm.n_bins, rows, out, exec, arena, |chunk, bins| {
            accumulate_quantized_scalar(qm, gradients, chunk, bins)
        }),
    }
}

/// Histogram builder over the bit-packed compressed matrix (§2.2).
pub fn build_histogram_compressed(
    cm: &CompressedMatrix,
    gradients: &[GradPair],
    rows: &[u32],
    out: &mut Histogram,
) {
    build_histogram_compressed_par(cm, gradients, rows, out, &ExecContext::serial());
}

/// Chunk-parallel histogram builder over the bit-packed compressed
/// matrix — bit-identical to [`build_histogram_compressed`] at every
/// thread count. Kernel mode comes from the environment
/// (`XGB_SCALAR_KERNELS`, read once); both modes are bit-identical.
pub fn build_histogram_compressed_par(
    cm: &CompressedMatrix,
    gradients: &[GradPair],
    rows: &[u32],
    out: &mut Histogram,
    exec: &ExecContext,
) {
    let arena = HistArena::default();
    build_histogram_compressed_par_mode(cm, gradients, rows, out, exec, KernelMode::from_env(), &arena);
}

/// [`build_histogram_compressed_par`] with an explicit [`KernelMode`] and
/// a caller-owned [`HistArena`] — lets benches and parity tests compare
/// Blocked vs Scalar in-process, and lets the training backend recycle
/// chunk scratch across rounds.
pub fn build_histogram_compressed_par_mode(
    cm: &CompressedMatrix,
    gradients: &[GradPair],
    rows: &[u32],
    out: &mut Histogram,
    exec: &ExecContext,
    mode: KernelMode,
    arena: &HistArena,
) {
    assert_eq!(out.n_bins(), cm.n_bins);
    match mode {
        KernelMode::Blocked => chunked_build(cm.n_bins, rows, out, exec, arena, |chunk, bins| {
            accumulate_compressed_blocked(cm, gradients, chunk, bins, &arena.sym)
        }),
        KernelMode::Scalar => chunked_build(cm.n_bins, rows, out, exec, arena, |chunk, bins| {
            accumulate_compressed_scalar(cm, gradients, chunk, bins)
        }),
    }
}

/// Accumulate one fixed chunk of `rows` from spilled pages into a
/// scratch-extended partial, fetching pages through `fetch` as the walk
/// crosses page boundaries. The per-row arithmetic matches the in-memory
/// compressed kernels (each page *is* a `CompressedMatrix` over its row
/// slice), so only the source of the packed words differs. Page fetch
/// order is a pure function of the row list in both modes — the blocked
/// variant resolves each row's page before decoding it, in row order —
/// so prefetch scheduling and the residency budget are unaffected by
/// `mode`. The previous page is dropped **before** the next is fetched,
/// which is what keeps the pipeline inside `max_resident_pages`.
fn accumulate_paged_chunk<F>(
    store: &PageStore,
    gradients: &[GradPair],
    chunk: &[u32],
    bins: &mut [GradPairF64],
    current: &mut Option<PageHandle>,
    fetch: &mut F,
    mode: KernelMode,
    arena: &HistArena,
) -> Result<()>
where
    F: FnMut(usize) -> Result<PageHandle>,
{
    let n_bins = bins.len() as u32 - 1;
    match mode {
        KernelMode::Scalar => {
            for &r in chunk {
                let r = r as usize;
                let want = store.page_of_row(r);
                if current.as_ref().map(|p| p.index) != Some(want) {
                    *current = None; // release before fetching: stay inside budget
                    *current = Some(fetch(want)?);
                }
                let page = current.as_ref().expect("page fetched above");
                let local = r - page.first_row;
                let g = GradPairF64::from_single(gradients[r]);
                page.matrix.for_each_symbol_in_row_scalar(local, |b| {
                    // `b < n_bins` (== null symbol) is the padding filter
                    // and the bounds proof
                    if b < n_bins {
                        // Safety: b < n_bins < bins.len(), checked above.
                        unsafe { *bins.get_unchecked_mut(b as usize) += g };
                    }
                });
            }
        }
        KernelMode::Blocked => {
            let scratch = bins.len() - 1;
            let stride = store.shape.row_stride;
            let mut g = [GradPairF64::default(); HIST_BLOCK_ROWS];
            let mut sym = arena.sym.take(HIST_BLOCK_ROWS * stride);
            for block in chunk.chunks(HIST_BLOCK_ROWS) {
                // pass 1 (row order): resolve pages, convert gradients,
                // block-decode each row's symbols from its page
                for (j, &r) in block.iter().enumerate() {
                    let r = r as usize;
                    let want = store.page_of_row(r);
                    if current.as_ref().map(|p| p.index) != Some(want) {
                        *current = None;
                        *current = Some(fetch(want)?);
                    }
                    let page = current.as_ref().expect("page fetched above");
                    g[j] = GradPairF64::from_single(gradients[r]);
                    page.matrix
                        .decode_row_into(r - page.first_row, &mut sym[j * stride..(j + 1) * stride]);
                }
                // pass 2 (row order): branchless accumulate from scratch
                for j in 0..block.len() {
                    let gj = g[j];
                    for &b in &sym[j * stride..(j + 1) * stride] {
                        let idx = (b as usize).min(scratch);
                        // Safety: idx <= scratch < bins.len() by the min.
                        unsafe { *bins.get_unchecked_mut(idx) += gj };
                    }
                }
            }
            arena.sym.put(sym);
        }
    }
    Ok(())
}

/// Drive the canonical fixed-chunk bracketing over spilled pages: chunk
/// boundaries are `ROW_CHUNK` positions in the `rows` list (the same pure
/// function of the row count the in-memory builders use — **never** a
/// function of the page size), partials fold in ascending chunk index,
/// and pages are fetched in first-use order as the walk advances.
fn paged_chunked_build<F>(
    store: &PageStore,
    gradients: &[GradPair],
    rows: &[u32],
    out: &mut Histogram,
    fetch: &mut F,
    mode: KernelMode,
    arena: &HistArena,
) -> Result<()>
where
    F: FnMut(usize) -> Result<PageHandle>,
{
    let width = out.n_bins() + 1;
    let mut current: Option<PageHandle> = None;
    let mut partial = arena.partials.take(width);
    if rows.len() <= ROW_CHUNK {
        accumulate_paged_chunk(
            store, gradients, rows, &mut partial, &mut current, fetch, mode, arena,
        )?;
        fold_partial(out, &partial);
        arena.partials.put(partial);
        return Ok(());
    }
    for chunk in rows.chunks(ROW_CHUNK) {
        partial.fill(GradPairF64::default());
        accumulate_paged_chunk(
            store, gradients, chunk, &mut partial, &mut current, fetch, mode, arena,
        )?;
        fold_partial(out, &partial);
    }
    arena.partials.put(partial);
    Ok(())
}

/// Histogram builder over an external-memory [`PageStore`] — page-at-a-
/// time with double-buffered async prefetch.
///
/// **Bit-identity.** The accumulation bracketing is the in-memory
/// builders' fixed `ROW_CHUNK` chunking of the node's row list, so the
/// merged histogram equals [`build_histogram_compressed`] on the fully
/// resident shard **bit for bit** for every page size, thread count and
/// residency budget (`rust/tests/external_memory.rs`). Paging only
/// changes *where* the packed words come from.
///
/// **Prefetch.** Runs on the shared in-order pipeline
/// [`crate::compress::page::with_prefetched_pages`]: with
/// `exec.threads() > 1` and a budget of at least two pages an I/O worker
/// loads page *k+1* while page *k* accumulates, with queue + in-flight
/// load + the accumulating page bounded by `max_resident_pages`. Serial
/// engines, or a budget of one page, load synchronously. Load and
/// blocked-wait seconds are recorded on the store and surface as
/// `BuildStats::{page_load_secs, page_wait_secs}`.
pub fn build_histogram_paged(
    store: &PageStore,
    gradients: &[GradPair],
    rows: &[u32],
    out: &mut Histogram,
    exec: &ExecContext,
) -> Result<()> {
    let arena = HistArena::default();
    build_histogram_paged_mode(store, gradients, rows, out, exec, KernelMode::from_env(), &arena)
}

/// [`build_histogram_paged`] with an explicit [`KernelMode`] — lets
/// benches and parity tests compare Blocked vs Scalar in-process.
pub fn build_histogram_paged_mode(
    store: &PageStore,
    gradients: &[GradPair],
    rows: &[u32],
    out: &mut Histogram,
    exec: &ExecContext,
    mode: KernelMode,
    arena: &HistArena,
) -> Result<()> {
    assert_eq!(out.n_bins(), store.shape.n_bins);
    // first-use page sequence (consecutive dedup) — the prefetch schedule
    let mut seq: Vec<usize> = Vec::new();
    for &r in rows {
        let p = store.page_of_row(r as usize);
        if seq.last() != Some(&p) {
            seq.push(p);
        }
    }
    crate::compress::page::with_prefetched_pages(store, exec, seq, |fetch| {
        paged_chunked_build(store, gradients, rows, out, &mut |p| fetch(p), mode, arena)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CompressedMatrix;
    use crate::data::DMatrix;
    use crate::quantile::{HistogramCuts, Quantizer};
    use crate::util::Pcg64;
    use crate::Float;

    fn fixture(n: usize, d: usize, seed: u64) -> (QuantizedMatrix, Vec<GradPair>) {
        let mut rng = Pcg64::new(seed);
        let vals: Vec<Float> = (0..n * d)
            .map(|_| {
                if rng.next_f64() < 0.15 {
                    Float::NAN
                } else {
                    rng.next_f32() * 10.0
                }
            })
            .collect();
        let x = DMatrix::dense(vals, n, d);
        let cuts = HistogramCuts::from_dmatrix(&x, 8, None);
        let qm = Quantizer::new(cuts).quantize(&x);
        let grads: Vec<GradPair> = (0..n)
            .map(|_| GradPair::new(rng.next_f32() * 2.0 - 1.0, rng.next_f32() + 0.1))
            .collect();
        (qm, grads)
    }

    #[test]
    fn histogram_sums_match_per_row_totals() {
        let (qm, grads) = fixture(200, 4, 1);
        let rows: Vec<u32> = (0..200).collect();
        let mut h = Histogram::zeros(qm.n_bins);
        build_histogram_quantized(&qm, &grads, &rows, &mut h);
        // every feature's bin-sum equals the gradient total over rows where
        // that feature is present
        let cuts_total: f64 = h.bins.iter().map(|b| b.grad).sum();
        let mut expect = 0.0f64;
        for r in 0..200usize {
            let present = qm.row(r).iter().filter(|&&b| b != qm.null_symbol()).count();
            expect += grads[r].grad as f64 * present as f64;
        }
        assert!((cuts_total - expect).abs() < 1e-6);
    }

    #[test]
    fn compressed_matches_quantized() {
        let (qm, grads) = fixture(300, 6, 2);
        let cm = CompressedMatrix::from_quantized(&qm);
        let rows: Vec<u32> = (0..300).step_by(3).collect();
        let mut hq = Histogram::zeros(qm.n_bins);
        let mut hc = Histogram::zeros(qm.n_bins);
        build_histogram_quantized(&qm, &grads, &rows, &mut hq);
        build_histogram_compressed(&cm, &grads, &rows, &mut hc);
        assert_eq!(hq, hc);
    }

    #[test]
    fn subtraction_trick_is_exact() {
        let (qm, grads) = fixture(400, 5, 3);
        let all: Vec<u32> = (0..400).collect();
        let (left, right): (Vec<u32>, Vec<u32>) = all.iter().partition(|&&r| r % 3 == 0);
        let mut parent = Histogram::zeros(qm.n_bins);
        let mut hl = Histogram::zeros(qm.n_bins);
        let mut hr = Histogram::zeros(qm.n_bins);
        build_histogram_quantized(&qm, &grads, &all, &mut parent);
        build_histogram_quantized(&qm, &grads, &left, &mut hl);
        build_histogram_quantized(&qm, &grads, &right, &mut hr);
        let derived_right = subtract(&parent, &hl);
        for (a, b) in derived_right.bins.iter().zip(hr.bins.iter()) {
            assert!((a.grad - b.grad).abs() < 1e-9);
            assert!((a.hess - b.hess).abs() < 1e-9);
        }
    }

    #[test]
    fn empty_rows_empty_histogram() {
        let (qm, grads) = fixture(50, 3, 4);
        let mut h = Histogram::zeros(qm.n_bins);
        build_histogram_quantized(&qm, &grads, &[], &mut h);
        assert!(h.bins.iter().all(|b| b.grad == 0.0 && b.hess == 0.0));
    }

    #[test]
    fn flat_roundtrip() {
        let (qm, grads) = fixture(100, 3, 5);
        let rows: Vec<u32> = (0..100).collect();
        let mut h = Histogram::zeros(qm.n_bins);
        build_histogram_quantized(&qm, &grads, &rows, &mut h);
        let flat = h.to_flat();
        assert_eq!(flat.len(), qm.n_bins * 2);
        assert_eq!(Histogram::from_flat(&flat), h);
    }

    #[test]
    fn add_is_union() {
        let (qm, grads) = fixture(120, 4, 6);
        let a_rows: Vec<u32> = (0..60).collect();
        let b_rows: Vec<u32> = (60..120).collect();
        let all: Vec<u32> = (0..120).collect();
        let mut ha = Histogram::zeros(qm.n_bins);
        let mut hb = Histogram::zeros(qm.n_bins);
        let mut hall = Histogram::zeros(qm.n_bins);
        build_histogram_quantized(&qm, &grads, &a_rows, &mut ha);
        build_histogram_quantized(&qm, &grads, &b_rows, &mut hb);
        build_histogram_quantized(&qm, &grads, &all, &mut hall);
        ha.add(&hb);
        for (x, y) in ha.bins.iter().zip(hall.bins.iter()) {
            assert!((x.grad - y.grad).abs() < 1e-9);
            assert!((x.hess - y.hess).abs() < 1e-9);
        }
    }

    #[test]
    fn parallel_builder_bit_identical_across_threads() {
        // > 2 chunks so the merge order actually matters
        let (qm, grads) = fixture(20_000, 5, 9);
        let cm = CompressedMatrix::from_quantized(&qm);
        let rows: Vec<u32> = (0..20_000).collect();
        let mut serial = Histogram::zeros(qm.n_bins);
        build_histogram_quantized(&qm, &grads, &rows, &mut serial);
        for t in [2usize, 4, 8] {
            let exec = crate::exec::ExecContext::new(t);
            let mut hq = Histogram::zeros(qm.n_bins);
            let mut hc = Histogram::zeros(qm.n_bins);
            build_histogram_quantized_par(&qm, &grads, &rows, &mut hq, &exec);
            build_histogram_compressed_par(&cm, &grads, &rows, &mut hc, &exec);
            for (a, b) in serial.bins.iter().zip(hq.bins.iter()) {
                assert_eq!(a.grad.to_bits(), b.grad.to_bits(), "threads = {t}");
                assert_eq!(a.hess.to_bits(), b.hess.to_bits(), "threads = {t}");
            }
            assert_eq!(hq, hc, "compressed parity at threads = {t}");
        }
    }

    #[test]
    fn paged_builder_bit_identical_to_resident() {
        use crate::compress::page::PagedMatrixBuilder;
        // > 2 row chunks and page sizes that do NOT divide ROW_CHUNK, so
        // chunk boundaries straddle pages every which way
        let (qm, grads) = fixture(20_000, 5, 11);
        let cm = CompressedMatrix::from_quantized(&qm);
        let rows: Vec<u32> = (0..20_000).collect();
        let mut resident = Histogram::zeros(qm.n_bins);
        build_histogram_compressed(&cm, &grads, &rows, &mut resident);
        for page_rows in [100usize, 777, 8192, 50_000] {
            for (threads, budget) in [(1usize, 1usize), (1, 3), (4, 1), (4, 2), (4, 4)] {
                let path = std::env::temp_dir().join(format!(
                    "xgb_tpu_hist_paged_{}_{page_rows}_{threads}_{budget}",
                    std::process::id()
                ));
                let mut b = PagedMatrixBuilder::new(
                    &path,
                    qm.n_rows,
                    qm.n_features,
                    qm.row_stride,
                    qm.n_bins,
                    qm.dense,
                    page_rows,
                    budget,
                )
                .unwrap();
                for r in 0..qm.n_rows {
                    b.push_row(qm.row(r)).unwrap();
                }
                let store = b.finish().unwrap();
                let exec = crate::exec::ExecContext::new(threads);
                let mut paged = Histogram::zeros(qm.n_bins);
                build_histogram_paged(&store, &grads, &rows, &mut paged, &exec).unwrap();
                for (a, b) in resident.bins.iter().zip(paged.bins.iter()) {
                    assert_eq!(
                        a.grad.to_bits(),
                        b.grad.to_bits(),
                        "page_rows={page_rows} threads={threads} budget={budget}"
                    );
                    assert_eq!(a.hess.to_bits(), b.hess.to_bits());
                }
                // nothing left resident after the build
                assert_eq!(store.resident_bytes(), 0);
                let stats = store.take_round_stats();
                assert!(stats.pages_loaded as usize >= qm.n_rows.div_ceil(page_rows));
                assert!(
                    stats.peak_resident_bytes <= budget * store.max_page_bytes(),
                    "peak {} > {budget} x {}",
                    stats.peak_resident_bytes,
                    store.max_page_bytes()
                );
            }
        }
    }

    #[test]
    fn paged_builder_on_node_subsets() {
        use crate::compress::page::PagedMatrixBuilder;
        // non-contiguous row subset (every third row) — the post-split shape
        let (qm, grads) = fixture(9_000, 4, 13);
        let cm = CompressedMatrix::from_quantized(&qm);
        let rows: Vec<u32> = (0..9_000u32).filter(|r| r % 3 == 0).collect();
        let mut resident = Histogram::zeros(qm.n_bins);
        build_histogram_compressed(&cm, &grads, &rows, &mut resident);
        let path = std::env::temp_dir()
            .join(format!("xgb_tpu_hist_paged_subset_{}", std::process::id()));
        let mut b = PagedMatrixBuilder::new(
            &path, qm.n_rows, qm.n_features, qm.row_stride, qm.n_bins, qm.dense, 512, 2,
        )
        .unwrap();
        for r in 0..qm.n_rows {
            b.push_row(qm.row(r)).unwrap();
        }
        let store = b.finish().unwrap();
        for threads in [1usize, 4] {
            let mut paged = Histogram::zeros(qm.n_bins);
            build_histogram_paged(
                &store,
                &grads,
                &rows,
                &mut paged,
                &crate::exec::ExecContext::new(threads),
            )
            .unwrap();
            assert_eq!(paged, resident, "threads = {threads}");
        }
    }

    #[test]
    fn blocked_and_scalar_modes_bit_identical() {
        use crate::compress::page::PagedMatrixBuilder;
        use crate::exec::KernelMode;
        // sizes straddle HIST_BLOCK_ROWS and ROW_CHUNK boundaries
        for n in [1usize, 7, 9, 63, 200, 9_000] {
            let (qm, grads) = fixture(n, 5, 17 + n as u64);
            let cm = CompressedMatrix::from_quantized(&qm);
            let rows: Vec<u32> = (0..n as u32).collect();
            for threads in [1usize, 4] {
                let exec = crate::exec::ExecContext::new(threads);
                let arena = HistArena::default();
                let mut pairs: Vec<(Histogram, Histogram)> = Vec::new();
                let mut qs = Histogram::zeros(qm.n_bins);
                let mut qb = Histogram::zeros(qm.n_bins);
                build_histogram_quantized_par_mode(
                    &qm, &grads, &rows, &mut qs, &exec, KernelMode::Scalar, &arena,
                );
                build_histogram_quantized_par_mode(
                    &qm, &grads, &rows, &mut qb, &exec, KernelMode::Blocked, &arena,
                );
                pairs.push((qs, qb));
                let mut cs = Histogram::zeros(qm.n_bins);
                let mut cb = Histogram::zeros(qm.n_bins);
                build_histogram_compressed_par_mode(
                    &cm, &grads, &rows, &mut cs, &exec, KernelMode::Scalar, &arena,
                );
                build_histogram_compressed_par_mode(
                    &cm, &grads, &rows, &mut cb, &exec, KernelMode::Blocked, &arena,
                );
                pairs.push((cs, cb));
                let path = std::env::temp_dir().join(format!(
                    "xgb_tpu_hist_mode_{}_{n}_{threads}",
                    std::process::id()
                ));
                let mut b = PagedMatrixBuilder::new(
                    &path, qm.n_rows, qm.n_features, qm.row_stride, qm.n_bins, qm.dense, 77, 2,
                )
                .unwrap();
                for r in 0..qm.n_rows {
                    b.push_row(qm.row(r)).unwrap();
                }
                let store = b.finish().unwrap();
                let mut ps = Histogram::zeros(qm.n_bins);
                let mut pb = Histogram::zeros(qm.n_bins);
                build_histogram_paged_mode(
                    &store,
                    &grads,
                    &rows,
                    &mut ps,
                    &exec,
                    KernelMode::Scalar,
                    &arena,
                )
                .unwrap();
                build_histogram_paged_mode(
                    &store,
                    &grads,
                    &rows,
                    &mut pb,
                    &exec,
                    KernelMode::Blocked,
                    &arena,
                )
                .unwrap();
                pairs.push((ps, pb));
                for (kind, (s, b)) in ["quantized", "compressed", "paged"].iter().zip(&pairs) {
                    for (x, y) in s.bins.iter().zip(b.bins.iter()) {
                        assert_eq!(
                            x.grad.to_bits(),
                            y.grad.to_bits(),
                            "{kind} n={n} threads={threads}"
                        );
                        assert_eq!(x.hess.to_bits(), y.hess.to_bits());
                    }
                }
            }
        }
    }

    #[test]
    fn feature_sum_hessian_counts_present_rows() {
        let (qm, grads) = fixture(80, 2, 7);
        let rows: Vec<u32> = (0..80).collect();
        let mut h = Histogram::zeros(qm.n_bins);
        build_histogram_quantized(&qm, &grads, &rows, &mut h);
        // feature 0 occupies bins 0..k; its hessian sum == sum of hessians
        // of rows where feature 0 is present
        let k = qm.n_bins; // need cuts; recompute from layout: slot 0 = feature 0
        let _ = k;
        let mut expect = 0.0f64;
        for r in 0..80usize {
            if qm.get(r, 0).is_some() {
                expect += grads[r].hess as f64;
            }
        }
        // feature 0 bins are those observed in slot 0
        let mut f0_bins: Vec<u32> = (0..80).filter_map(|r| qm.get(r, 0)).collect();
        f0_bins.sort_unstable();
        f0_bins.dedup();
        let got: f64 = f0_bins.iter().map(|&b| h.bins[b as usize].hess).sum();
        assert!((got - expect).abs() < 1e-9);
    }
}
