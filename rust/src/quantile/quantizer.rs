//! Quantisation of the input matrix onto histogram bins (paper §2.1),
//! producing the ELLPACK-layout `QuantizedMatrix` that feeds both the
//! histogram builder and the bit-packing compressor (§2.2).
//!
//! ELLPACK layout: every row occupies exactly `row_stride` symbols
//! (`row_stride` = max present-values-per-row; == `n_cols` for dense
//! input). Missing slots hold the **null symbol** `total_bins`. This is
//! the same trick XGBoost's GPU `EllpackPage` uses: fixed stride makes the
//! kernel's addressing affine, at the cost of padding sparse rows.

use crate::data::DMatrix;
use crate::quantile::HistogramCuts;

/// The quantised input matrix in ELLPACK layout.
#[derive(Debug, Clone)]
pub struct QuantizedMatrix {
    /// Global bin indices, `n_rows * row_stride` entries; `null_symbol()`
    /// marks padding.
    pub bins: Vec<u32>,
    pub n_rows: usize,
    pub n_features: usize,
    pub row_stride: usize,
    /// Total bins across features (== cuts.total_bins()).
    pub n_bins: usize,
    /// Whether rows are dense (slot i of a row always holds feature i).
    /// Dense layout lets the histogram kernel skip feature lookups.
    pub dense: bool,
}

impl QuantizedMatrix {
    /// Null / padding symbol: one past the last valid bin.
    #[inline]
    pub fn null_symbol(&self) -> u32 {
        self.n_bins as u32
    }

    /// Number of symbols in the alphabet (bins + null).
    #[inline]
    pub fn n_symbols(&self) -> usize {
        self.n_bins + 1
    }

    /// Bin of `(row, slot)`; `None` for padding.
    #[inline]
    pub fn get(&self, row: usize, slot: usize) -> Option<u32> {
        let b = self.bins[row * self.row_stride + slot];
        if b == self.null_symbol() {
            None
        } else {
            Some(b)
        }
    }

    /// Slice of one row's symbols (including padding).
    #[inline]
    pub fn row(&self, row: usize) -> &[u32] {
        &self.bins[row * self.row_stride..(row + 1) * self.row_stride]
    }

    /// Uncompressed size in bytes (u32 per symbol).
    pub fn bytes(&self) -> usize {
        self.bins.len() * std::mem::size_of::<u32>()
    }
}

/// Builds [`QuantizedMatrix`] from raw data and cut points.
#[derive(Debug, Clone)]
pub struct Quantizer {
    pub cuts: HistogramCuts,
}

impl Quantizer {
    pub fn new(cuts: HistogramCuts) -> Self {
        Quantizer { cuts }
    }

    /// Quantise a matrix. Dense inputs keep positional layout (slot ==
    /// feature); sparse inputs use packed ELLPACK with the true
    /// `row_stride` = max row nnz.
    pub fn quantize(&self, x: &DMatrix) -> QuantizedMatrix {
        let n_rows = x.n_rows();
        let n_features = x.n_cols();
        let n_bins = self.cuts.total_bins();
        let null = n_bins as u32;
        match x {
            DMatrix::Dense { .. } => {
                let row_stride = n_features;
                let mut bins = vec![null; n_rows * row_stride];
                for row in 0..n_rows {
                    for (f, v) in x.iter_row(row) {
                        bins[row * row_stride + f] = self.cuts.bin_index(f, v);
                    }
                }
                QuantizedMatrix {
                    bins,
                    n_rows,
                    n_features,
                    row_stride,
                    n_bins,
                    dense: true,
                }
            }
            DMatrix::Csr { indptr, .. } => {
                let row_stride = (0..n_rows)
                    .map(|r| indptr[r + 1] - indptr[r])
                    .max()
                    .unwrap_or(0)
                    .max(1);
                let mut bins = vec![null; n_rows * row_stride];
                for row in 0..n_rows {
                    let mut slot = 0;
                    for (f, v) in x.iter_row(row) {
                        bins[row * row_stride + slot] = self.cuts.bin_index(f, v);
                        slot += 1;
                    }
                }
                QuantizedMatrix {
                    bins,
                    n_rows,
                    n_features,
                    row_stride,
                    n_bins,
                    dense: false,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DMatrix;
    use crate::Float;

    fn dense_fixture() -> (DMatrix, Quantizer) {
        let mut v = Vec::new();
        for r in 0..16 {
            v.push(r as Float); // feature 0: 0..16
            v.push(if r % 4 == 0 { Float::NAN } else { (r % 3) as Float });
        }
        let x = DMatrix::dense(v, 16, 2);
        let cuts = HistogramCuts::from_dmatrix(&x, 4, None);
        (x, Quantizer::new(cuts))
    }

    #[test]
    fn dense_layout_positional() {
        let (x, q) = dense_fixture();
        let qm = q.quantize(&x);
        assert!(qm.dense);
        assert_eq!(qm.row_stride, 2);
        assert_eq!(qm.n_rows, 16);
        // missing entries -> null symbol
        assert_eq!(qm.get(0, 1), None);
        assert_eq!(qm.get(1, 1).map(|b| q.cuts.feature_of_bin(b)), Some(1));
    }

    #[test]
    fn bins_respect_feature_ranges() {
        let (x, q) = dense_fixture();
        let qm = q.quantize(&x);
        for r in 0..16 {
            for (f, v) in x.iter_row(r) {
                let b = qm.get(r, f).unwrap();
                assert_eq!(q.cuts.feature_of_bin(b), f);
                assert!(v < q.cuts.cut_of_bin(b));
            }
        }
    }

    #[test]
    fn sparse_ellpack_stride() {
        // rows with nnz 1, 3, 2
        let x = DMatrix::csr(
            vec![0, 1, 4, 6],
            vec![0, 0, 1, 2, 1, 2],
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            3,
            3,
        );
        let cuts = HistogramCuts::from_dmatrix(&x, 4, None);
        let qm = Quantizer::new(cuts).quantize(&x);
        assert!(!qm.dense);
        assert_eq!(qm.row_stride, 3);
        // row 0 has 1 real symbol + 2 padding
        assert!(qm.get(0, 0).is_some());
        assert_eq!(qm.get(0, 1), None);
        assert_eq!(qm.get(0, 2), None);
        // row 1 fully populated
        assert!(qm.get(1, 0).is_some() && qm.get(1, 1).is_some() && qm.get(1, 2).is_some());
    }

    #[test]
    fn histogram_from_quantized_matches_direct_binning() {
        let (x, q) = dense_fixture();
        let qm = q.quantize(&x);
        let mut counts = vec![0usize; qm.n_bins];
        for r in 0..qm.n_rows {
            for s in 0..qm.row_stride {
                if let Some(b) = qm.get(r, s) {
                    counts[b as usize] += 1;
                }
            }
        }
        let mut expect = vec![0usize; qm.n_bins];
        for r in 0..x.n_rows() {
            for (f, v) in x.iter_row(r) {
                expect[q.cuts.bin_index(f, v) as usize] += 1;
            }
        }
        assert_eq!(counts, expect);
    }

    #[test]
    fn n_symbols_includes_null() {
        let (x, q) = dense_fixture();
        let qm = q.quantize(&x);
        assert_eq!(qm.n_symbols(), qm.n_bins + 1);
        assert!(qm.bins.iter().all(|&b| b <= qm.null_symbol()));
    }
}
