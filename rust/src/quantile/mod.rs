//! Feature quantile generation (paper §2.1).
//!
//! The paper quantises the input matrix onto per-feature quantile bins
//! before tree construction, reducing split finding to histogram
//! aggregation. This module provides:
//!
//! * [`sketch::WQSummary`] — a weighted quantile summary with the
//!   merge/prune operations of the GK/XGBoost sketch and its ε error
//!   bound,
//! * [`sketch::StreamingSketch`] — the incremental per-column fold of
//!   streamed row batches (pass 1 of the out-of-core ingestion pipeline);
//!   batch-size- and thread-count-invariant by construction,
//! * [`cuts::HistogramCuts`] — per-feature cut points derived from the
//!   sketches (global bin indexing, as in XGBoost's `HistogramCuts`),
//! * [`quantizer::QuantizedMatrix`] — the input matrix mapped to bin
//!   indices, the form consumed by histogram construction and by the
//!   [`crate::compress`] bit-packing stage.

pub mod cuts;
pub mod quantizer;
pub mod sketch;

pub use cuts::HistogramCuts;
pub use quantizer::{QuantizedMatrix, Quantizer};
pub use sketch::{StreamingSketch, WQSummary};
