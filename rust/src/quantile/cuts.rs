//! Per-feature histogram cut points with global bin indexing, derived from
//! the per-feature quantile sketches (paper §2.1).
//!
//! Layout follows XGBoost's `HistogramCuts`: `ptrs[f]..ptrs[f+1]` indexes
//! the ascending cut values of feature `f` inside the flat `values` array,
//! so a (feature, local bin) pair maps to the **global bin**
//! `ptrs[f] + local_bin`. Histograms are allocated flat over
//! `total_bins()`, which is what makes the one-hot-matmul histogram kernel
//! (L1) and the compressed matrix addressing work without per-feature
//! indirection.

use crate::data::DMatrix;
use crate::quantile::sketch::SketchBuilder;
use crate::Float;

/// Quantile cut points for every feature.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramCuts {
    /// `ptrs[f]..ptrs[f+1]` — range of `values` belonging to feature `f`.
    pub ptrs: Vec<u32>,
    /// Ascending upper-bound cut values, concatenated over features.
    /// A value `v` of feature `f` falls in the first local bin whose cut is
    /// `> v`; the last cut of each feature is an upper sentinel above the
    /// feature's maximum.
    pub values: Vec<Float>,
    /// Per-feature minimum seen value (kept for completeness / debugging,
    /// as XGBoost does).
    pub min_vals: Vec<Float>,
}

impl HistogramCuts {
    /// Build cuts from a dataset using per-feature quantile sketches with at
    /// most `max_bins` bins per feature.
    ///
    /// `hessians`, when provided, weight the sketch (XGBoost's weighted
    /// quantile sketch); pass `None` for the unweighted first iteration.
    pub fn from_dmatrix(x: &DMatrix, max_bins: usize, hessians: Option<&[f64]>) -> Self {
        assert!(max_bins >= 2, "need at least 2 bins");
        let n_cols = x.n_cols();
        let sketch_limit = (max_bins * 8).max(64);
        let mut builders: Vec<SketchBuilder> =
            (0..n_cols).map(|_| SketchBuilder::new(sketch_limit)).collect();
        for col in 0..n_cols {
            let b = &mut builders[col];
            x.for_each_in_column(col, |row, v| {
                let w = hessians.map(|h| h[row]).unwrap_or(1.0);
                b.push(v, w.max(1e-16));
            });
        }
        let summaries: Vec<_> = builders.into_iter().map(|b| b.finish()).collect();
        Self::from_summaries(&summaries, max_bins)
    }

    /// Build cuts from already-reduced per-feature summaries (the
    /// multi-device path: each device sketches its shard, summaries are
    /// all-reduced, then this runs on the result).
    pub fn from_summaries(
        summaries: &[crate::quantile::WQSummary],
        max_bins: usize,
    ) -> Self {
        let mut ptrs: Vec<u32> = Vec::with_capacity(summaries.len() + 1);
        let mut values: Vec<Float> = Vec::new();
        let mut min_vals: Vec<Float> = Vec::with_capacity(summaries.len());
        ptrs.push(0);
        for summary in summaries {
            let total = summary.total_weight();
            let mut last: Option<Float> = None;
            if summary.is_empty() {
                // feature never observed: single sentinel bin
                min_vals.push(0.0);
                values.push(Float::MAX);
                ptrs.push(values.len() as u32);
                continue;
            }
            min_vals.push(summary.entries.first().unwrap().value);
            let max_val = summary.entries.last().unwrap().value;
            // interior cuts at ranks k * total / max_bins, k = 1..max_bins-1
            for k in 1..max_bins {
                let d = total * k as f64 / max_bins as f64;
                if let Some(q) = summary.query(d) {
                    if q < max_val && last != Some(q) {
                        values.push(q);
                        last = Some(q);
                    }
                }
            }
            // final sentinel strictly above the max so every present value
            // falls in a bin (XGBoost uses max * (1+2e); handle max<=0 too)
            let sentinel = if max_val > 0.0 {
                max_val * (1.0 + 1e-5) + 1e-35
            } else {
                max_val * (1.0 - 1e-5) + 1e-35
            };
            let sentinel = if sentinel <= max_val {
                // degenerate precision case
                Float::from_bits(max_val.to_bits() + 1)
            } else {
                sentinel
            };
            values.push(sentinel);
            ptrs.push(values.len() as u32);
        }
        HistogramCuts {
            ptrs,
            values,
            min_vals,
        }
    }

    pub fn n_features(&self) -> usize {
        self.ptrs.len() - 1
    }

    /// Total number of bins across all features — the width of every flat
    /// histogram and the symbol alphabet of the compressed matrix.
    pub fn total_bins(&self) -> usize {
        *self.ptrs.last().unwrap() as usize
    }

    /// Number of bins of feature `f`.
    pub fn feature_bins(&self, f: usize) -> usize {
        (self.ptrs[f + 1] - self.ptrs[f]) as usize
    }

    /// Cut values of feature `f`.
    pub fn feature_cuts(&self, f: usize) -> &[Float] {
        &self.values[self.ptrs[f] as usize..self.ptrs[f + 1] as usize]
    }

    /// Map `(feature, value)` to its **global** bin index:
    /// `ptrs[f] + upper_bound(cuts_f, value)` clamped into the feature's
    /// range. Values above the sentinel clamp into the last bin.
    #[inline]
    pub fn bin_index(&self, f: usize, v: Float) -> u32 {
        let lo = self.ptrs[f] as usize;
        let hi = self.ptrs[f + 1] as usize;
        let cuts = &self.values[lo..hi];
        // first cut strictly greater than v
        let local = cuts.partition_point(|&c| c <= v);
        let local = local.min(cuts.len() - 1);
        (lo + local) as u32
    }

    /// [`bin_index`](Self::bin_index) **without** the into-range clamp:
    /// values at or above the feature's sentinel cut map to
    /// `ptrs[f + 1]` (one past the feature's last bin) instead of being
    /// folded into it. The quantised prediction path uses this for
    /// transient (unpacked) batches so that the bin comparison
    /// `bin < threshold_to_bin(t)` reproduces the float comparison
    /// `v < t` exactly even for values outside the training range — the
    /// packed alphabet cannot represent the overflow symbol, so packed
    /// storages keep the clamped form (where every value is in range by
    /// construction of the cuts).
    #[inline]
    pub fn bin_index_unclamped(&self, f: usize, v: Float) -> u32 {
        let lo = self.ptrs[f] as usize;
        let hi = self.ptrs[f + 1] as usize;
        let cuts = &self.values[lo..hi];
        (lo + cuts.partition_point(|&c| c <= v)) as u32
    }

    /// Inverse-ish mapping for split thresholds: the representative split
    /// value of a global bin is its cut (split condition `v < cut` goes
    /// left).
    #[inline]
    pub fn cut_of_bin(&self, global_bin: u32) -> Float {
        self.values[global_bin as usize]
    }

    /// Which feature a global bin belongs to (binary search over `ptrs`).
    pub fn feature_of_bin(&self, global_bin: u32) -> usize {
        debug_assert!((global_bin as usize) < self.total_bins());
        self.ptrs.partition_point(|&p| p <= global_bin) - 1
    }

    /// In-memory size of the cut structure (for the memory-footprint bench).
    pub fn bytes(&self) -> usize {
        self.ptrs.len() * 4 + self.values.len() * 4 + self.min_vals.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DMatrix;

    fn simple_matrix() -> DMatrix {
        // 8 rows, 2 features; feature 0 uniform 0..8, feature 1 constant
        let mut v = Vec::new();
        for r in 0..8 {
            v.push(r as Float);
            v.push(5.0);
        }
        DMatrix::dense(v, 8, 2)
    }

    #[test]
    fn cuts_cover_all_values() {
        let x = simple_matrix();
        let cuts = HistogramCuts::from_dmatrix(&x, 4, None);
        assert_eq!(cuts.n_features(), 2);
        // every present value must land in a valid bin of its feature
        for r in 0..8 {
            for (f, v) in x.iter_row(r) {
                let b = cuts.bin_index(f, v);
                assert!(b >= cuts.ptrs[f] && b < cuts.ptrs[f + 1]);
                // value is below its bin's cut
                assert!(v < cuts.cut_of_bin(b), "v={v} cut={}", cuts.cut_of_bin(b));
            }
        }
    }

    #[test]
    fn constant_feature_gets_one_bin() {
        let x = simple_matrix();
        let cuts = HistogramCuts::from_dmatrix(&x, 8, None);
        assert_eq!(cuts.feature_bins(1), 1);
    }

    #[test]
    fn bin_count_bounded_by_max_bins() {
        let mut rng = crate::util::Pcg64::new(1);
        let vals: Vec<Float> = (0..1000).map(|_| rng.next_f32()).collect();
        let x = DMatrix::dense(vals, 1000, 1);
        for max_bins in [2, 4, 16, 64, 256] {
            let cuts = HistogramCuts::from_dmatrix(&x, max_bins, None);
            assert!(cuts.feature_bins(0) <= max_bins, "max_bins={max_bins}");
            assert!(cuts.feature_bins(0) >= max_bins / 2, "too few bins");
        }
    }

    #[test]
    fn bins_are_monotone_in_value() {
        let mut rng = crate::util::Pcg64::new(2);
        let vals: Vec<Float> = (0..500).map(|_| rng.next_f32() * 10.0).collect();
        let x = DMatrix::dense(vals.clone(), 500, 1);
        let cuts = HistogramCuts::from_dmatrix(&x, 16, None);
        let mut sorted = vals;
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut prev = 0u32;
        for v in sorted {
            let b = cuts.bin_index(0, v);
            assert!(b >= prev, "bin must be monotone in value");
            prev = b;
        }
    }

    #[test]
    fn global_indexing_is_contiguous() {
        let x = simple_matrix();
        let cuts = HistogramCuts::from_dmatrix(&x, 4, None);
        assert_eq!(cuts.ptrs[0], 0);
        assert_eq!(cuts.total_bins(), cuts.values.len());
        for f in 0..cuts.n_features() {
            assert_eq!(cuts.feature_cuts(f).len(), cuts.feature_bins(f));
            // cut values ascend within a feature
            let fc = cuts.feature_cuts(f);
            for w in fc.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }

    #[test]
    fn feature_of_bin_roundtrip() {
        let x = simple_matrix();
        let cuts = HistogramCuts::from_dmatrix(&x, 4, None);
        for f in 0..cuts.n_features() {
            for b in cuts.ptrs[f]..cuts.ptrs[f + 1] {
                assert_eq!(cuts.feature_of_bin(b), f);
            }
        }
    }

    #[test]
    fn weighted_cuts_shift_toward_heavy_rows() {
        // rows 0..100 value i; weight 10 on low half, 1 on high half:
        // the median cut should land well below 50.
        let vals: Vec<Float> = (0..100).map(|i| i as Float).collect();
        let x = DMatrix::dense(vals, 100, 1);
        let w: Vec<f64> = (0..100).map(|i| if i < 50 { 10.0 } else { 1.0 }).collect();
        let cuts = HistogramCuts::from_dmatrix(&x, 2, Some(&w));
        // single interior cut at the weighted median (~27)
        let c = cuts.feature_cuts(0)[0];
        assert!(c < 40.0, "weighted median cut {c}");
    }

    #[test]
    fn negative_max_sentinel_covers() {
        let vals: Vec<Float> = vec![-5.0, -4.0, -3.0, -2.0];
        let x = DMatrix::dense(vals.clone(), 4, 1);
        let cuts = HistogramCuts::from_dmatrix(&x, 4, None);
        for v in vals {
            let b = cuts.bin_index(0, v);
            assert!(v < cuts.cut_of_bin(b));
        }
    }
}
