//! Per-feature histogram cut points with global bin indexing, derived from
//! the per-feature quantile sketches (paper §2.1).
//!
//! Layout follows XGBoost's `HistogramCuts`: `ptrs[f]..ptrs[f+1]` indexes
//! the ascending cut values of feature `f` inside the flat `values` array,
//! so a (feature, local bin) pair maps to the **global bin**
//! `ptrs[f] + local_bin`. Histograms are allocated flat over
//! `total_bins()`, which is what makes the one-hot-matmul histogram kernel
//! (L1) and the compressed matrix addressing work without per-feature
//! indirection.

use crate::data::DMatrix;
use crate::quantile::sketch::SketchBuilder;
use crate::Float;

/// Quantile cut points for every feature.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramCuts {
    /// `ptrs[f]..ptrs[f+1]` — range of `values` belonging to feature `f`.
    pub ptrs: Vec<u32>,
    /// Ascending upper-bound cut values, concatenated over features.
    /// A value `v` of feature `f` falls in the first local bin whose cut is
    /// `> v`; the last cut of each feature is an upper sentinel above the
    /// feature's maximum.
    pub values: Vec<Float>,
    /// Per-feature minimum seen value (kept for completeness / debugging,
    /// as XGBoost does).
    pub min_vals: Vec<Float>,
    /// Per-feature categorical flag. **Empty means no categorical
    /// features** (the common case; older serialized cuts deserialize to
    /// this). When non-empty it has one entry per feature; a flagged
    /// feature's bins hold exactly one category value each (bin `i` ↔ the
    /// feature's `i`-th smallest category, see
    /// [`category_of_local_bin`](Self::category_of_local_bin)) and splits
    /// on it are bitset membership tests, not threshold comparisons.
    pub categorical: Vec<bool>,
}

/// Sentinel cut strictly above `max_val`, so every present value falls in
/// a bin (XGBoost uses `max * (1+2e)`; the `<= 0` branch and the
/// bit-increment fallback handle negative and denormal maxima).
fn sentinel_above(max_val: Float) -> Float {
    let sentinel = if max_val > 0.0 {
        max_val * (1.0 + 1e-5) + 1e-35
    } else {
        max_val * (1.0 - 1e-5) + 1e-35
    };
    if sentinel <= max_val {
        // degenerate precision case
        Float::from_bits(max_val.to_bits() + 1)
    } else {
        sentinel
    }
}

impl HistogramCuts {
    /// Build cuts from a dataset using per-feature quantile sketches with at
    /// most `max_bins` bins per feature.
    ///
    /// `hessians`, when provided, weight the sketch (XGBoost's weighted
    /// quantile sketch); pass `None` for the unweighted first iteration.
    pub fn from_dmatrix(x: &DMatrix, max_bins: usize, hessians: Option<&[f64]>) -> Self {
        assert!(max_bins >= 2, "need at least 2 bins");
        let n_cols = x.n_cols();
        let sketch_limit = (max_bins * 8).max(64);
        let mut builders: Vec<SketchBuilder> =
            (0..n_cols).map(|_| SketchBuilder::new(sketch_limit)).collect();
        for col in 0..n_cols {
            let b = &mut builders[col];
            x.for_each_in_column(col, |row, v| {
                let w = hessians.map(|h| h[row]).unwrap_or(1.0);
                b.push(v, w.max(1e-16));
            });
        }
        let summaries: Vec<_> = builders.into_iter().map(|b| b.finish()).collect();
        Self::from_summaries(&summaries, max_bins)
    }

    /// Build cuts from already-reduced per-feature summaries (the
    /// multi-device path: each device sketches its shard, summaries are
    /// all-reduced, then this runs on the result).
    pub fn from_summaries(
        summaries: &[crate::quantile::WQSummary],
        max_bins: usize,
    ) -> Self {
        let mut ptrs: Vec<u32> = Vec::with_capacity(summaries.len() + 1);
        let mut values: Vec<Float> = Vec::new();
        let mut min_vals: Vec<Float> = Vec::with_capacity(summaries.len());
        ptrs.push(0);
        for summary in summaries {
            let total = summary.total_weight();
            let mut last: Option<Float> = None;
            if summary.is_empty() {
                // feature never observed: single sentinel bin
                min_vals.push(0.0);
                values.push(Float::MAX);
                ptrs.push(values.len() as u32);
                continue;
            }
            min_vals.push(summary.entries.first().unwrap().value);
            let max_val = summary.entries.last().unwrap().value;
            // interior cuts at ranks k * total / max_bins, k = 1..max_bins-1
            for k in 1..max_bins {
                let d = total * k as f64 / max_bins as f64;
                if let Some(q) = summary.query(d) {
                    if q < max_val && last != Some(q) {
                        values.push(q);
                        last = Some(q);
                    }
                }
            }
            values.push(sentinel_above(max_val));
            ptrs.push(values.len() as u32);
        }
        HistogramCuts {
            ptrs,
            values,
            min_vals,
            categorical: Vec::new(),
        }
    }

    /// Replace the quantile cuts of the given features with
    /// **one-bin-per-category** cuts and flag them categorical. `cats`
    /// maps feature index → its ascending distinct category values; for
    /// categories `c_0 < … < c_{K−1}` the feature's cuts become
    /// `[c_1, …, c_{K−1}, sentinel]` (K bins), so the standard
    /// upper-bound [`bin_index`](Self::bin_index) maps `c_i` to local bin
    /// `i` **exactly** — the packed/float binning machinery needs no
    /// categorical special case.
    pub fn apply_categories(&mut self, cats: &std::collections::BTreeMap<usize, Vec<Float>>) {
        let nf = self.n_features();
        let mut ptrs: Vec<u32> = Vec::with_capacity(nf + 1);
        let mut values: Vec<Float> = Vec::new();
        let mut min_vals: Vec<Float> = Vec::with_capacity(nf);
        let mut categorical = vec![false; nf];
        ptrs.push(0);
        for f in 0..nf {
            if let Some(cat) = cats.get(&f) {
                assert!(!cat.is_empty(), "empty category set for feature {f}");
                debug_assert!(
                    cat.windows(2).all(|w| w[0] < w[1]),
                    "category values must be ascending and distinct"
                );
                categorical[f] = true;
                min_vals.push(cat[0]);
                values.extend_from_slice(&cat[1..]);
                values.push(sentinel_above(*cat.last().unwrap()));
            } else {
                min_vals.push(self.min_vals[f]);
                values.extend_from_slice(self.feature_cuts(f));
            }
            ptrs.push(values.len() as u32);
        }
        self.ptrs = ptrs;
        self.values = values;
        self.min_vals = min_vals;
        self.categorical = categorical;
    }

    /// Whether feature `f` is categorical.
    #[inline]
    pub fn is_categorical(&self, f: usize) -> bool {
        self.categorical.get(f).copied().unwrap_or(false)
    }

    /// Whether any feature is categorical.
    pub fn has_categorical(&self) -> bool {
        self.categorical.iter().any(|&c| c)
    }

    /// The category value held by local bin `local` of categorical
    /// feature `f`: bin 0 holds the smallest category (`min_vals[f]`),
    /// bin `i ≥ 1` holds the cut value `values[ptrs[f] + i − 1]` (each
    /// category is the *lower edge* of its bin — i.e. the previous bin's
    /// upper cut).
    pub fn category_of_local_bin(&self, f: usize, local: usize) -> Float {
        debug_assert!(self.is_categorical(f), "feature {f} is not categorical");
        if local == 0 {
            self.min_vals[f]
        } else {
            self.values[self.ptrs[f] as usize + local - 1]
        }
    }

    pub fn n_features(&self) -> usize {
        self.ptrs.len() - 1
    }

    /// Total number of bins across all features — the width of every flat
    /// histogram and the symbol alphabet of the compressed matrix.
    pub fn total_bins(&self) -> usize {
        *self.ptrs.last().unwrap() as usize
    }

    /// Number of bins of feature `f`.
    pub fn feature_bins(&self, f: usize) -> usize {
        (self.ptrs[f + 1] - self.ptrs[f]) as usize
    }

    /// Cut values of feature `f`.
    pub fn feature_cuts(&self, f: usize) -> &[Float] {
        &self.values[self.ptrs[f] as usize..self.ptrs[f + 1] as usize]
    }

    /// Map `(feature, value)` to its **global** bin index:
    /// `ptrs[f] + upper_bound(cuts_f, value)` clamped into the feature's
    /// range. Values above the sentinel clamp into the last bin.
    #[inline]
    pub fn bin_index(&self, f: usize, v: Float) -> u32 {
        let lo = self.ptrs[f] as usize;
        let hi = self.ptrs[f + 1] as usize;
        let cuts = &self.values[lo..hi];
        // first cut strictly greater than v
        let local = cuts.partition_point(|&c| c <= v);
        let local = local.min(cuts.len() - 1);
        (lo + local) as u32
    }

    /// [`bin_index`](Self::bin_index) **without** the into-range clamp:
    /// values at or above the feature's sentinel cut map to
    /// `ptrs[f + 1]` (one past the feature's last bin) instead of being
    /// folded into it. The quantised prediction path uses this for
    /// transient (unpacked) batches so that the bin comparison
    /// `bin < threshold_to_bin(t)` reproduces the float comparison
    /// `v < t` exactly even for values outside the training range — the
    /// packed alphabet cannot represent the overflow symbol, so packed
    /// storages keep the clamped form (where every value is in range by
    /// construction of the cuts).
    #[inline]
    pub fn bin_index_unclamped(&self, f: usize, v: Float) -> u32 {
        let lo = self.ptrs[f] as usize;
        let hi = self.ptrs[f + 1] as usize;
        let cuts = &self.values[lo..hi];
        (lo + cuts.partition_point(|&c| c <= v)) as u32
    }

    /// Inverse-ish mapping for split thresholds: the representative split
    /// value of a global bin is its cut (split condition `v < cut` goes
    /// left).
    #[inline]
    pub fn cut_of_bin(&self, global_bin: u32) -> Float {
        self.values[global_bin as usize]
    }

    /// Which feature a global bin belongs to (binary search over `ptrs`).
    pub fn feature_of_bin(&self, global_bin: u32) -> usize {
        debug_assert!((global_bin as usize) < self.total_bins());
        self.ptrs.partition_point(|&p| p <= global_bin) - 1
    }

    /// In-memory size of the cut structure (for the memory-footprint bench).
    pub fn bytes(&self) -> usize {
        self.ptrs.len() * 4 + self.values.len() * 4 + self.min_vals.len() * 4 + self.categorical.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DMatrix;

    fn simple_matrix() -> DMatrix {
        // 8 rows, 2 features; feature 0 uniform 0..8, feature 1 constant
        let mut v = Vec::new();
        for r in 0..8 {
            v.push(r as Float);
            v.push(5.0);
        }
        DMatrix::dense(v, 8, 2)
    }

    #[test]
    fn cuts_cover_all_values() {
        let x = simple_matrix();
        let cuts = HistogramCuts::from_dmatrix(&x, 4, None);
        assert_eq!(cuts.n_features(), 2);
        // every present value must land in a valid bin of its feature
        for r in 0..8 {
            for (f, v) in x.iter_row(r) {
                let b = cuts.bin_index(f, v);
                assert!(b >= cuts.ptrs[f] && b < cuts.ptrs[f + 1]);
                // value is below its bin's cut
                assert!(v < cuts.cut_of_bin(b), "v={v} cut={}", cuts.cut_of_bin(b));
            }
        }
    }

    #[test]
    fn constant_feature_gets_one_bin() {
        let x = simple_matrix();
        let cuts = HistogramCuts::from_dmatrix(&x, 8, None);
        assert_eq!(cuts.feature_bins(1), 1);
    }

    #[test]
    fn bin_count_bounded_by_max_bins() {
        let mut rng = crate::util::Pcg64::new(1);
        let vals: Vec<Float> = (0..1000).map(|_| rng.next_f32()).collect();
        let x = DMatrix::dense(vals, 1000, 1);
        for max_bins in [2, 4, 16, 64, 256] {
            let cuts = HistogramCuts::from_dmatrix(&x, max_bins, None);
            assert!(cuts.feature_bins(0) <= max_bins, "max_bins={max_bins}");
            assert!(cuts.feature_bins(0) >= max_bins / 2, "too few bins");
        }
    }

    #[test]
    fn bins_are_monotone_in_value() {
        let mut rng = crate::util::Pcg64::new(2);
        let vals: Vec<Float> = (0..500).map(|_| rng.next_f32() * 10.0).collect();
        let x = DMatrix::dense(vals.clone(), 500, 1);
        let cuts = HistogramCuts::from_dmatrix(&x, 16, None);
        let mut sorted = vals;
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut prev = 0u32;
        for v in sorted {
            let b = cuts.bin_index(0, v);
            assert!(b >= prev, "bin must be monotone in value");
            prev = b;
        }
    }

    #[test]
    fn global_indexing_is_contiguous() {
        let x = simple_matrix();
        let cuts = HistogramCuts::from_dmatrix(&x, 4, None);
        assert_eq!(cuts.ptrs[0], 0);
        assert_eq!(cuts.total_bins(), cuts.values.len());
        for f in 0..cuts.n_features() {
            assert_eq!(cuts.feature_cuts(f).len(), cuts.feature_bins(f));
            // cut values ascend within a feature
            let fc = cuts.feature_cuts(f);
            for w in fc.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }

    #[test]
    fn feature_of_bin_roundtrip() {
        let x = simple_matrix();
        let cuts = HistogramCuts::from_dmatrix(&x, 4, None);
        for f in 0..cuts.n_features() {
            for b in cuts.ptrs[f]..cuts.ptrs[f + 1] {
                assert_eq!(cuts.feature_of_bin(b), f);
            }
        }
    }

    #[test]
    fn weighted_cuts_shift_toward_heavy_rows() {
        // rows 0..100 value i; weight 10 on low half, 1 on high half:
        // the median cut should land well below 50.
        let vals: Vec<Float> = (0..100).map(|i| i as Float).collect();
        let x = DMatrix::dense(vals, 100, 1);
        let w: Vec<f64> = (0..100).map(|i| if i < 50 { 10.0 } else { 1.0 }).collect();
        let cuts = HistogramCuts::from_dmatrix(&x, 2, Some(&w));
        // single interior cut at the weighted median (~27)
        let c = cuts.feature_cuts(0)[0];
        assert!(c < 40.0, "weighted median cut {c}");
    }

    #[test]
    fn categorical_cuts_map_each_category_to_its_own_bin() {
        let vals: Vec<Float> = vec![2.0, 5.0, 7.0, 5.0, 2.0, 7.0, 2.0, 5.0];
        let x = DMatrix::dense(vals, 8, 1);
        let mut cuts = HistogramCuts::from_dmatrix(&x, 16, None);
        let mut cats = std::collections::BTreeMap::new();
        cats.insert(0usize, vec![2.0 as Float, 5.0, 7.0]);
        cuts.apply_categories(&cats);
        assert!(cuts.is_categorical(0));
        assert!(cuts.has_categorical());
        assert_eq!(cuts.feature_bins(0), 3);
        for (i, &c) in [2.0 as Float, 5.0, 7.0].iter().enumerate() {
            assert_eq!(cuts.bin_index(0, c) as usize, i, "category {c}");
            assert_eq!(cuts.category_of_local_bin(0, i), c);
        }
        // a single-category feature still gets one bin with a sentinel
        let mut one = HistogramCuts::from_dmatrix(&DMatrix::dense(vec![3.0; 4], 4, 1), 4, None);
        let mut c1 = std::collections::BTreeMap::new();
        c1.insert(0usize, vec![3.0 as Float]);
        one.apply_categories(&c1);
        assert_eq!(one.feature_bins(0), 1);
        assert_eq!(one.bin_index(0, 3.0), 0);
        assert_eq!(one.category_of_local_bin(0, 0), 3.0);
    }

    #[test]
    fn apply_categories_preserves_numeric_features() {
        // f0 numeric uniform, f1 categorical {0,1,2}
        let mut v = Vec::new();
        for r in 0..9 {
            v.push(r as Float);
            v.push((r % 3) as Float);
        }
        let x = DMatrix::dense(v, 9, 2);
        let mut cuts = HistogramCuts::from_dmatrix(&x, 4, None);
        let numeric_before = cuts.feature_cuts(0).to_vec();
        let min_before = cuts.min_vals[0];
        let mut cats = std::collections::BTreeMap::new();
        cats.insert(1usize, vec![0.0 as Float, 1.0, 2.0]);
        cuts.apply_categories(&cats);
        assert!(!cuts.is_categorical(0));
        assert!(cuts.is_categorical(1));
        assert_eq!(cuts.feature_cuts(0), &numeric_before[..]);
        assert_eq!(cuts.min_vals[0], min_before);
        assert_eq!(cuts.feature_bins(1), 3);
        assert_eq!(cuts.total_bins(), cuts.values.len());
        // global indexing stays contiguous after the rebuild
        for f in 0..2 {
            for b in cuts.ptrs[f]..cuts.ptrs[f + 1] {
                assert_eq!(cuts.feature_of_bin(b), f);
            }
        }
    }

    #[test]
    fn negative_max_sentinel_covers() {
        let vals: Vec<Float> = vec![-5.0, -4.0, -3.0, -2.0];
        let x = DMatrix::dense(vals.clone(), 4, 1);
        let cuts = HistogramCuts::from_dmatrix(&x, 4, None);
        for v in vals {
            let b = cuts.bin_index(0, v);
            assert!(v < cuts.cut_of_bin(b));
        }
    }
}
