//! Weighted quantile summary with merge and prune — the sketch underlying
//! XGBoost's quantile generation (§2.1 of the paper; Chen & Guestrin 2016,
//! appendix).
//!
//! A summary is a sorted list of [`Entry`]s, each carrying the minimum and
//! maximum possible rank (`rmin`, `rmax`) of its value in the underlying
//! weighted multiset and the weight `wmin` of elements equal to the value.
//! Exact summaries are built from sorted chunks; [`WQSummary::combine`]
//! merges two summaries; [`WQSummary::prune`] shrinks a summary to a size
//! budget while growing the rank uncertainty by at most `total_weight /
//! (maxsize - 1)`. The resulting ε bound is exercised by the property
//! tests in `rust/tests/prop_quantile.rs`.

use crate::Float;

/// One sketch entry: a value with rank bounds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Entry {
    /// Minimum possible rank (sum of weights strictly before `value`,
    /// lower bound).
    pub rmin: f64,
    /// Maximum possible rank (sum of weights up to and including `value`,
    /// upper bound).
    pub rmax: f64,
    /// Total weight of elements equal to `value` (lower bound).
    pub wmin: f64,
    pub value: Float,
}

impl Entry {
    #[inline]
    pub fn new(rmin: f64, rmax: f64, wmin: f64, value: Float) -> Self {
        Entry {
            rmin,
            rmax,
            wmin,
            value,
        }
    }

    /// Tightest upper bound on the rank of values `< self.value`
    /// (XGBoost `RMaxPrev`).
    #[inline]
    pub fn rmax_prev(&self) -> f64 {
        self.rmax - self.wmin
    }

    /// Tightest lower bound on the rank of values `<= self.value`
    /// (XGBoost `RMinNext`).
    #[inline]
    pub fn rmin_next(&self) -> f64 {
        self.rmin + self.wmin
    }
}

/// A weighted quantile summary (sorted by value, strictly increasing).
#[derive(Debug, Clone, Default)]
pub struct WQSummary {
    pub entries: Vec<Entry>,
}

impl WQSummary {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build an exact summary from `(value, weight)` pairs (need not be
    /// sorted; NaN values must already be filtered out).
    pub fn from_weighted(mut data: Vec<(Float, f64)>) -> Self {
        data.retain(|(v, _)| !v.is_nan());
        data.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut entries: Vec<Entry> = Vec::new();
        let mut rank = 0.0f64;
        let mut i = 0;
        while i < data.len() {
            let v = data[i].0;
            let mut w = 0.0;
            while i < data.len() && data[i].0 == v {
                w += data[i].1;
                i += 1;
            }
            entries.push(Entry::new(rank, rank + w, w, v));
            rank += w;
        }
        WQSummary { entries }
    }

    /// Build an exact summary from unweighted values.
    pub fn from_values(values: &[Float]) -> Self {
        Self::from_weighted(values.iter().map(|&v| (v, 1.0)).collect())
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Total weight covered (== rmax of the last entry for exact and merged
    /// summaries).
    pub fn total_weight(&self) -> f64 {
        self.entries.last().map(|e| e.rmax).unwrap_or(0.0)
    }

    /// Maximum rank uncertainty of any entry: `max(rmax - rmin - wmin)`.
    /// For an exact summary this is 0.
    pub fn max_error(&self) -> f64 {
        self.entries
            .iter()
            .map(|e| e.rmax - e.rmin - e.wmin)
            .fold(0.0, f64::max)
    }

    /// Query the value at rank `d` (in `[0, total_weight]`): returns the
    /// entry value whose rank interval best covers `d` (XGSBoost
    /// `WQSummary::Query` logic).
    pub fn query(&self, d: f64) -> Option<Float> {
        if self.entries.is_empty() {
            return None;
        }
        // binary search for first entry with rmin_next >= d
        let mut lo = 0usize;
        let mut hi = self.entries.len();
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.entries[mid].rmin_next() < d {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        if lo >= self.entries.len() {
            return Some(self.entries.last().unwrap().value);
        }
        if lo + 1 < self.entries.len() {
            let a = &self.entries[lo];
            let b = &self.entries[lo + 1];
            // pick whichever side has tighter coverage of d
            if d >= b.rmax_prev() && (b.rmax_prev() - d).abs() < (d - a.rmin_next()).abs() {
                return Some(b.value);
            }
        }
        Some(self.entries[lo].value)
    }

    /// Merge two summaries into one covering both multisets (XGBoost
    /// `SetCombine`). Rank bounds remain valid: for every element, the
    /// combined `rmin`/`rmax` are the sums of the constituents' bounds at
    /// that value.
    pub fn combine(&self, other: &WQSummary) -> WQSummary {
        if self.is_empty() {
            return other.clone();
        }
        if other.is_empty() {
            return self.clone();
        }
        let (a, b) = (&self.entries, &other.entries);
        let mut out: Vec<Entry> = Vec::with_capacity(a.len() + b.len());
        let (mut i, mut j) = (0usize, 0usize);
        // running "previous" bounds from the other stream
        while i < a.len() && j < b.len() {
            let ea = &a[i];
            let eb = &b[j];
            if ea.value == eb.value {
                out.push(Entry::new(
                    ea.rmin + eb.rmin,
                    ea.rmax + eb.rmax,
                    ea.wmin + eb.wmin,
                    ea.value,
                ));
                i += 1;
                j += 1;
            } else if ea.value < eb.value {
                // b contributes: everything strictly below eb
                let b_prev = if j == 0 { 0.0 } else { b[j - 1].rmin_next() };
                let b_upper = eb.rmax_prev();
                out.push(Entry::new(
                    ea.rmin + b_prev,
                    ea.rmax + b_upper,
                    ea.wmin,
                    ea.value,
                ));
                i += 1;
            } else {
                let a_prev = if i == 0 { 0.0 } else { a[i - 1].rmin_next() };
                let a_upper = ea.rmax_prev();
                out.push(Entry::new(
                    eb.rmin + a_prev,
                    eb.rmax + a_upper,
                    eb.wmin,
                    eb.value,
                ));
                j += 1;
            }
        }
        let b_total = other.total_weight();
        while i < a.len() {
            let ea = &a[i];
            out.push(Entry::new(
                ea.rmin + b_total,
                ea.rmax + b_total,
                ea.wmin,
                ea.value,
            ));
            i += 1;
        }
        let a_total = self.total_weight();
        while j < b.len() {
            let eb = &b[j];
            out.push(Entry::new(
                eb.rmin + a_total,
                eb.rmax + a_total,
                eb.wmin,
                eb.value,
            ));
            j += 1;
        }
        WQSummary { entries: out }
    }

    /// Prune to at most `maxsize` entries (a faithful port of XGBoost's
    /// `WQSummary::SetPrune`): keeps the extreme values and selects
    /// interior entries whose doubled rank midpoint `rmin+rmax` brackets
    /// evenly spaced targets. Adds at most `total_weight / (maxsize - 1)`
    /// rank error per prune.
    pub fn prune(&self, maxsize: usize) -> WQSummary {
        assert!(maxsize >= 2, "prune needs room for both extremes");
        let src = &self.entries;
        if src.len() <= maxsize {
            return self.clone();
        }
        let begin = src[0].rmax;
        let range = src[src.len() - 1].rmin - begin;
        let n = maxsize - 1;
        let mut out: Vec<Entry> = Vec::with_capacity(maxsize);
        out.push(src[0]);
        let mut i = 1usize;
        let mut lastidx = 0usize;
        for k in 1..n {
            let dx2 = 2.0 * (k as f64 * range / n as f64 + begin);
            while i < src.len() - 1 && dx2 >= src[i + 1].rmax_prev() + src[i + 1].rmin_next() {
                i += 1;
            }
            if i == src.len() - 1 {
                break;
            }
            if dx2 < src[i].rmin_next() + src[i + 1].rmax_prev() {
                if i != lastidx {
                    out.push(src[i]);
                    lastidx = i;
                }
            } else if i + 1 != lastidx {
                out.push(src[i + 1]);
                lastidx = i + 1;
            }
        }
        if lastidx != src.len() - 1 {
            out.push(src[src.len() - 1]);
        }
        WQSummary { entries: out }
    }

    /// Validate structural invariants (sorted values, consistent ranks).
    /// Used by tests.
    pub fn check_invariants(&self) {
        for w in self.entries.windows(2) {
            assert!(w[0].value < w[1].value, "values must be strictly increasing");
            assert!(
                w[0].rmin_next() <= w[1].rmax_prev() + 1e-9,
                "rank bounds must be consistent between neighbours"
            );
        }
        for e in &self.entries {
            assert!(e.rmin >= -1e-9);
            assert!(e.rmax >= e.rmin + e.wmin - 1e-9, "rmax >= rmin + wmin");
            assert!(e.wmin >= 0.0);
        }
    }
}

/// Streaming sketch builder: accumulates values in chunks, turning each
/// chunk into an exact summary and merging with prune to bound memory —
/// the CPU analogue of the paper's GPU multi-pass sketch.
#[derive(Debug, Clone)]
pub struct SketchBuilder {
    /// Size limit for the maintained summary.
    pub limit: usize,
    /// Chunk size before folding into the summary.
    pub chunk: usize,
    buffer: Vec<(Float, f64)>,
    summary: WQSummary,
}

impl SketchBuilder {
    /// `eps`-style constructor: `limit` entries gives roughly `1/limit`
    /// relative rank error per prune.
    pub fn new(limit: usize) -> Self {
        SketchBuilder {
            limit: limit.max(4),
            chunk: (limit.max(4)) * 8,
            buffer: Vec::new(),
            summary: WQSummary::new(),
        }
    }

    pub fn push(&mut self, value: Float, weight: f64) {
        if value.is_nan() {
            return;
        }
        self.buffer.push((value, weight));
        if self.buffer.len() >= self.chunk {
            self.flush();
        }
    }

    fn flush(&mut self) {
        if self.buffer.is_empty() {
            return;
        }
        let exact = WQSummary::from_weighted(std::mem::take(&mut self.buffer));
        self.summary = self.summary.combine(&exact).prune(self.limit);
    }

    /// Merge another builder's state into this one (used for multi-device
    /// sketch reduction).
    pub fn merge(&mut self, mut other: SketchBuilder) {
        other.flush();
        self.flush();
        self.summary = self.summary.combine(&other.summary).prune(self.limit);
    }

    pub fn finish(mut self) -> WQSummary {
        self.flush();
        self.summary
    }
}

/// Incremental per-column quantile sketch over streamed row batches —
/// pass 1 of the out-of-core ingestion pipeline (`crate::data::source`).
///
/// One [`SketchBuilder`] per column; [`StreamingSketch::fold`] pushes each
/// batch's column values in row order, and the builder merges/prunes
/// internally at fixed chunk boundaries. Because a builder's state is a
/// pure function of its push *sequence* (flushes trigger on buffer length,
/// never on wall-clock or batching), the finished summaries — and hence
/// the histogram cuts — are **bit-identical for every batch size**, and
/// identical to sketching the fully materialized matrix. Column tasks run
/// on the [`ExecContext`](crate::exec::ExecContext) pool; columns are
/// independent, so the result is thread-count-invariant too.
#[derive(Debug, Clone)]
pub struct StreamingSketch {
    limit: usize,
    builders: Vec<SketchBuilder>,
}

impl StreamingSketch {
    /// `max_bins` sizes the per-column summaries exactly as the histogram
    /// cut generation does (`(max_bins * 8).max(64)` entries).
    pub fn new(max_bins: usize) -> Self {
        StreamingSketch {
            limit: (max_bins * 8).max(64),
            builders: Vec::new(),
        }
    }

    /// Columns seen so far (grows monotonically across batches; a LibSVM
    /// stream discovers its width as it goes).
    pub fn n_cols(&self) -> usize {
        self.builders.len()
    }

    /// Grow the column set to at least `n` (new columns start empty and
    /// finish as single-sentinel-bin features if never observed).
    pub fn ensure_cols(&mut self, n: usize) {
        while self.builders.len() < n {
            self.builders.push(SketchBuilder::new(self.limit));
        }
    }

    /// Fold one batch: every present value of column `c` is pushed (in row
    /// order, unit weight) into that column's builder. Chunk-parallel over
    /// columns on `exec`.
    pub fn fold(&mut self, x: &crate::data::DMatrix, exec: &crate::exec::ExecContext) {
        self.ensure_cols(x.n_cols());
        exec.parallel_map_mut(&mut self.builders, |col, b| {
            x.for_each_in_column(col, |_, v| b.push(v, 1.0));
        });
    }

    /// Finish every column's summary (consumes the sketch).
    pub fn finish(self) -> Vec<WQSummary> {
        self.builders.into_iter().map(|b| b.finish()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_summary_ranks() {
        let s = WQSummary::from_values(&[3.0, 1.0, 2.0, 2.0]);
        s.check_invariants();
        assert_eq!(s.len(), 3);
        assert_eq!(s.entries[0], Entry::new(0.0, 1.0, 1.0, 1.0));
        assert_eq!(s.entries[1], Entry::new(1.0, 3.0, 2.0, 2.0));
        assert_eq!(s.entries[2], Entry::new(3.0, 4.0, 1.0, 3.0));
        assert_eq!(s.total_weight(), 4.0);
        assert_eq!(s.max_error(), 0.0);
    }

    #[test]
    fn nan_filtered() {
        let s = WQSummary::from_values(&[1.0, f32::NAN, 2.0]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.total_weight(), 2.0);
    }

    #[test]
    fn combine_disjoint() {
        let a = WQSummary::from_values(&[1.0, 2.0]);
        let b = WQSummary::from_values(&[3.0, 4.0]);
        let c = a.combine(&b);
        c.check_invariants();
        assert_eq!(c.total_weight(), 4.0);
        let exact = WQSummary::from_values(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(c.entries, exact.entries);
    }

    #[test]
    fn combine_interleaved_equals_exact() {
        let a = WQSummary::from_values(&[1.0, 3.0, 5.0, 5.0]);
        let b = WQSummary::from_values(&[2.0, 3.0, 6.0]);
        let c = a.combine(&b);
        c.check_invariants();
        let exact = WQSummary::from_values(&[1.0, 3.0, 5.0, 5.0, 2.0, 3.0, 6.0]);
        assert_eq!(c.entries, exact.entries);
    }

    #[test]
    fn query_exact_median() {
        let s = WQSummary::from_values(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.query(2.5), Some(3.0));
        assert_eq!(s.query(0.0), Some(1.0));
        assert_eq!(s.query(5.0), Some(5.0));
    }

    #[test]
    fn prune_keeps_extremes_and_bounds_error() {
        let values: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let s = WQSummary::from_values(&values);
        let p = s.prune(16);
        p.check_invariants();
        assert!(p.len() <= 16);
        assert_eq!(p.entries.first().unwrap().value, 0.0);
        assert_eq!(p.entries.last().unwrap().value, 999.0);
        // error bound: total/(maxsize-1) per prune
        assert!(p.max_error() <= 1000.0 / 15.0 + 1e-6, "err {}", p.max_error());
    }

    #[test]
    fn builder_matches_quantiles_of_exact() {
        let n = 20_000usize;
        let mut rng = crate::util::Pcg64::new(42);
        let values: Vec<f32> = (0..n).map(|_| rng.next_f32() * 100.0).collect();
        let mut b = SketchBuilder::new(64);
        for &v in &values {
            b.push(v, 1.0);
        }
        let summary = b.finish();
        summary.check_invariants();
        let mut sorted = values.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // query deciles; sketch answer must be within eps*n ranks
        let eps = 4.0 / 64.0; // generous: a few prune rounds compound
        for k in 1..10 {
            let d = n as f64 * k as f64 / 10.0;
            let q = summary.query(d).unwrap();
            let rank = sorted.partition_point(|&v| v < q) as f64;
            assert!(
                (rank - d).abs() <= eps * n as f64 + 1.0,
                "decile {k}: rank {rank} vs target {d}"
            );
        }
    }

    #[test]
    fn builder_merge_covers_both_streams() {
        let mut a = SketchBuilder::new(32);
        let mut b = SketchBuilder::new(32);
        for i in 0..500 {
            a.push(i as f32, 1.0);
            b.push((i + 500) as f32, 1.0);
        }
        a.merge(b);
        let s = a.finish();
        assert!((s.total_weight() - 1000.0).abs() < 1e-9);
        assert_eq!(s.entries.first().unwrap().value, 0.0);
        assert_eq!(s.entries.last().unwrap().value, 999.0);
    }

    #[test]
    fn streaming_sketch_invariant_to_batch_size_and_threads() {
        use crate::data::DMatrix;
        let n = 5000usize;
        let mut rng = crate::util::Pcg64::new(17);
        let vals: Vec<f32> = (0..n * 3)
            .map(|_| if rng.next_f64() < 0.05 { f32::NAN } else { rng.next_f32() * 10.0 })
            .collect();
        let x = DMatrix::dense(vals, n, 3);
        let run = |batch: usize, threads: usize| -> Vec<Vec<Entry>> {
            let exec = crate::exec::ExecContext::new(threads);
            let mut s = StreamingSketch::new(16);
            let mut row = 0usize;
            while row < n {
                let hi = (row + batch).min(n);
                let rows: Vec<usize> = (row..hi).collect();
                s.fold(&x.take_rows(&rows), &exec);
                row = hi;
            }
            s.finish().into_iter().map(|w| w.entries).collect()
        };
        let reference = run(n, 1); // one batch == fully materialized
        for batch in [1usize, 7, 64, 999] {
            for threads in [1usize, 4] {
                assert_eq!(run(batch, threads), reference, "batch={batch} threads={threads}");
            }
        }
    }

    #[test]
    fn weighted_entries_respected() {
        let s = WQSummary::from_weighted(vec![(1.0, 10.0), (2.0, 1.0)]);
        assert_eq!(s.total_weight(), 11.0);
        // rank 5 lands inside the heavy value
        assert_eq!(s.query(5.0), Some(1.0));
    }
}
