//! CatBoost-style trainer: **oblivious (symmetric) decision tables** —
//! every node of a level shares one (feature, threshold) condition, so a
//! depth-d tree is a 2^d-entry lookup table indexed by d bit tests
//! (Dorogush et al., 2017). This is the algorithmic profile behind the
//! `cat-*` rows of Table 2: evaluation and histogram reuse are extremely
//! fast, but the shared-split constraint costs accuracy — visible in the
//! paper (cat rows: fastest GPU times, lowest accuracies).
//!
//! Split selection per level sums the split gain over all current nodes;
//! histograms are built once per level per node in a single pass over the
//! rows (node ids maintained incrementally, no per-node partition pass).

use std::time::Instant;

use anyhow::Result;

use crate::data::Dataset;
use crate::gbm::objective::objective_by_name;
use crate::gbm::{Booster, LearnerParams};
use crate::hist::{GradPairF64, Histogram};
use crate::predict;
use crate::quantile::{HistogramCuts, Quantizer};
use crate::tree::{RegTree, SplitEvaluator, TreeParams};
use crate::{Float, GradPair};

use super::BaselineStats;

/// CatBoost-flavoured hyperparameters.
#[derive(Debug, Clone)]
pub struct CatBoostParams {
    pub objective: String,
    pub num_class: usize,
    pub num_rounds: usize,
    pub learning_rate: f64,
    /// Depth of every symmetric tree (CatBoost default 6 → 64 leaves).
    pub depth: usize,
    pub max_bins: usize,
    pub lambda: f64,
    pub seed: u64,
}

impl Default for CatBoostParams {
    fn default() -> Self {
        CatBoostParams {
            objective: "binary:logistic".into(),
            num_class: 1,
            num_rounds: 50,
            learning_rate: 0.1,
            depth: 6,
            max_bins: 128,
            lambda: 3.0,
            seed: 0,
        }
    }
}

/// Train a CatBoost-like model of oblivious trees.
pub fn train_catboost_like(
    params: &CatBoostParams,
    train: &Dataset,
) -> Result<(Booster, BaselineStats)> {
    let t0 = Instant::now();
    let mut stats = BaselineStats::default();
    let objective = objective_by_name(&params.objective, params.num_class)?;
    let k = objective.n_outputs();

    let cuts = HistogramCuts::from_dmatrix(&train.x, params.max_bins, None);
    let qm = Quantizer::new(cuts.clone()).quantize(&train.x);
    let n = train.n_rows();

    let evaluator = SplitEvaluator::new(TreeParams {
        lambda: params.lambda,
        ..Default::default()
    });

    let base_score = objective.base_score(train);
    let mut margins: Vec<Vec<Float>> = base_score.iter().map(|&b| vec![b; n]).collect();
    let mut trees: Vec<Vec<RegTree>> = vec![Vec::new(); k];

    for _round in 0..params.num_rounds {
        let grads_all = objective.gradients(train, &margins);
        for c in 0..k {
            let tree = build_oblivious_tree(
                &qm,
                &cuts,
                &grads_all[c],
                &evaluator,
                params.learning_rate,
                params.depth,
                &mut stats,
            );
            let t = Instant::now();
            predict::accumulate_tree(&tree, &train.x, &mut margins[c]);
            stats.other_secs += t.elapsed().as_secs_f64();
            trees[c].push(tree);
        }
    }

    let train_secs = t0.elapsed().as_secs_f64();
    stats.other_secs = (train_secs - stats.hist_secs - stats.partition_secs).max(0.0);
    let bp = LearnerParams {
        objective: params.objective.parse().expect("infallible"),
        num_class: params.num_class,
        num_rounds: params.num_rounds,
        eta: params.learning_rate,
        max_depth: params.depth,
        max_bins: params.max_bins,
        ..Default::default()
    };
    Ok((Booster::from_parts(bp, base_score, trees, train_secs)?, stats))
}

/// The shared condition chosen for one level.
struct LevelSplit {
    feature: u32,
    split_bin: u32,
    threshold: Float,
    default_left: bool,
    gain: f64,
}

/// Build one oblivious tree: at each level, pick the single (feature, bin)
/// whose summed gain over all nodes is maximal.
fn build_oblivious_tree(
    qm: &crate::quantile::QuantizedMatrix,
    cuts: &HistogramCuts,
    grads: &[GradPair],
    evaluator: &SplitEvaluator,
    eta: f64,
    depth: usize,
    stats: &mut BaselineStats,
) -> RegTree {
    let n = qm.n_rows;
    let n_bins = cuts.total_bins();
    // node id of every row at the current level (level l: ids 0..2^l)
    let mut nid = vec![0u32; n];
    let mut level_splits: Vec<LevelSplit> = Vec::new();

    for level in 0..depth {
        let n_nodes = 1usize << level;
        // one pass: per-node histograms + per-node totals
        let t = Instant::now();
        let mut hists: Vec<Histogram> = (0..n_nodes).map(|_| Histogram::zeros(n_bins)).collect();
        let mut sums = vec![GradPairF64::default(); n_nodes];
        let null = qm.null_symbol();
        for r in 0..n {
            let node = nid[r] as usize;
            let g = GradPairF64::from_single(grads[r]);
            sums[node] += g;
            let row = qm.row(r);
            let h = &mut hists[node];
            for &b in row {
                if b != null {
                    h.bins[b as usize] += g;
                }
            }
        }
        stats.hist_secs += t.elapsed().as_secs_f64();
        stats.hist_rounds += 1;

        // choose the (feature, bin, default_dir) maximising summed gain
        let t = Instant::now();
        let mut best: Option<LevelSplit> = None;
        for f in 0..cuts.n_features() {
            let lo = cuts.ptrs[f] as usize;
            let hi = cuts.ptrs[f + 1] as usize;
            if hi - lo < 2 {
                continue;
            }
            // per-node forward scans, accumulated per (bin, dir)
            let mut left_present = vec![GradPairF64::default(); n_nodes];
            let present: Vec<GradPairF64> =
                (0..n_nodes).map(|m| hists[m].feature_sum(lo, hi)).collect();
            for b in lo..hi {
                for m in 0..n_nodes {
                    left_present[m] += hists[m].bins[b];
                }
                for default_left in [false, true] {
                    let mut gain = 0.0;
                    let mut feasible = false;
                    for m in 0..n_nodes {
                        let missing = sums[m] - present[m];
                        let left = if default_left {
                            left_present[m] + missing
                        } else {
                            left_present[m]
                        };
                        let right = sums[m] - left;
                        if left.hess >= evaluator.params.min_child_weight
                            && right.hess >= evaluator.params.min_child_weight
                        {
                            let g = evaluator.split_gain(sums[m], left, right);
                            if g > 0.0 {
                                gain += g;
                                feasible = true;
                            }
                        }
                    }
                    if feasible
                        && best.as_ref().map(|s| gain > s.gain + 1e-12).unwrap_or(true)
                    {
                        best = Some(LevelSplit {
                            feature: f as u32,
                            split_bin: b as u32,
                            threshold: cuts.cut_of_bin(b as u32),
                            default_left,
                            gain,
                        });
                    }
                }
            }
        }
        stats.other_secs += t.elapsed().as_secs_f64();

        let Some(split) = best else { break };

        // reassign rows: new id = old id * 2 + (goes right)
        let t = Instant::now();
        let flo = cuts.ptrs[split.feature as usize];
        let fhi = cuts.ptrs[split.feature as usize + 1];
        for r in 0..n {
            let row = qm.row(r);
            // dense layout: slot == feature; sparse: scan
            let bin = if qm.dense {
                let b = row[split.feature as usize];
                if b == null { None } else { Some(b) }
            } else {
                let mut found = None;
                for &b in row {
                    if b == null {
                        break;
                    }
                    if b >= flo && b < fhi {
                        found = Some(b);
                        break;
                    }
                }
                found
            };
            let goes_left = match bin {
                Some(b) => b <= split.split_bin,
                None => split.default_left,
            };
            nid[r] = nid[r] * 2 + u32::from(!goes_left);
        }
        stats.partition_secs += t.elapsed().as_secs_f64();
        level_splits.push(split);
    }

    // leaf values from final assignment
    let actual_depth = level_splits.len();
    let n_leaves = 1usize << actual_depth;
    let mut leaf_sums = vec![GradPairF64::default(); n_leaves];
    for r in 0..n {
        leaf_sums[nid[r] as usize] += GradPairF64::from_single(grads[r]);
    }

    // encode as a RegTree: a perfect binary tree whose level-l interior
    // nodes all carry level_splits[l]
    let total = GradPairF64::new(
        leaf_sums.iter().map(|s| s.grad).sum(),
        leaf_sums.iter().map(|s| s.hess).sum(),
    );
    let mut tree = RegTree::new_root((eta * evaluator.leaf_weight(total)) as Float,
                                     total.hess as Float);
    if actual_depth == 0 {
        return tree;
    }
    // breadth-first expansion; node at (level, index) owns leaf range
    // [index << (d-level), (index+1) << (d-level))
    let mut frontier: Vec<(usize, usize)> = vec![(0, 0)]; // (tree nid, level index)
    for (level, s) in level_splits.iter().enumerate() {
        let mut next = Vec::with_capacity(frontier.len() * 2);
        let shift = actual_depth - level - 1;
        for &(tnid, idx) in &frontier {
            let l_idx = idx * 2;
            let r_idx = idx * 2 + 1;
            let range_sum = |i: usize| -> GradPairF64 {
                let lo = i << shift;
                let hi = (i + 1) << shift;
                let mut acc = GradPairF64::default();
                for s in &leaf_sums[lo..hi] {
                    acc += *s;
                }
                acc
            };
            let ls = range_sum(l_idx);
            let rs = range_sum(r_idx);
            let (l, r) = tree.apply_split(
                tnid,
                s.feature,
                s.threshold,
                s.default_left,
                s.gain as Float,
                (eta * evaluator.leaf_weight(ls)) as Float,
                ls.hess as Float,
                (eta * evaluator.leaf_weight(rs)) as Float,
                rs.hess as Float,
            );
            next.push((l, l_idx));
            next.push((r, r_idx));
        }
        frontier = next;
    }
    tree
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetSpec};

    #[test]
    fn oblivious_tree_is_symmetric() {
        let g = generate(&DatasetSpec::higgs_like(2000), 29);
        let params = CatBoostParams {
            num_rounds: 1,
            depth: 4,
            max_bins: 16,
            ..Default::default()
        };
        let (booster, _) = train_catboost_like(&params, &g.train).unwrap();
        let tree = &booster.trees[0][0];
        // perfect binary tree: 2^(d+1) - 1 nodes
        let d = tree.max_depth();
        assert!(d >= 1);
        assert_eq!(tree.n_nodes(), (1 << (d + 1)) - 1);
        // all interior nodes at the same level share the same feature
        let mut level_of = vec![0usize; tree.n_nodes()];
        for (i, node) in tree.nodes.iter().enumerate() {
            if !node.is_leaf() {
                level_of[node.left as usize] = level_of[i] + 1;
                level_of[node.right as usize] = level_of[i] + 1;
            }
        }
        let mut feat_at_level: std::collections::HashMap<usize, u32> = Default::default();
        for (i, node) in tree.nodes.iter().enumerate() {
            if !node.is_leaf() {
                let f = *feat_at_level.entry(level_of[i]).or_insert(node.feature);
                assert_eq!(f, node.feature, "level {} shares its split", level_of[i]);
            }
        }
    }

    #[test]
    fn trains_and_learns() {
        let g = generate(&DatasetSpec::higgs_like(4000), 37);
        let params = CatBoostParams {
            num_rounds: 20,
            depth: 4,
            max_bins: 32,
            ..Default::default()
        };
        let (booster, stats) = train_catboost_like(&params, &g.train).unwrap();
        let acc = booster.evaluate(&g.valid, "accuracy").unwrap();
        let majority = {
            let pos: f64 =
                g.valid.y.iter().filter(|&&y| y == 1.0).count() as f64 / g.valid.y.len() as f64;
            100.0 * pos.max(1.0 - pos)
        };
        assert!(acc > majority, "acc {acc} vs majority {majority}");
        assert!(stats.hist_secs > 0.0);
        assert_eq!(stats.hist_rounds, 20 * 4);
    }

    #[test]
    fn regression_learns() {
        let g = generate(&DatasetSpec::year_prediction_like(2000), 41);
        let params = CatBoostParams {
            objective: "reg:squarederror".into(),
            num_rounds: 15,
            depth: 4,
            max_bins: 32,
            ..Default::default()
        };
        let (booster, _) = train_catboost_like(&params, &g.train).unwrap();
        let rmse = booster.evaluate(&g.valid, "rmse").unwrap();
        let base = {
            let mean: f32 = g.train.y.iter().sum::<f32>() / g.train.y.len() as f32;
            let se: f64 = g.valid.y.iter().map(|&y| ((y - mean) as f64).powi(2)).sum();
            (se / g.valid.y.len() as f64).sqrt()
        };
        assert!(rmse < base, "rmse {rmse} vs baseline {base}");
    }

    #[test]
    fn oblivious_less_expressive_than_xgb_on_same_budget() {
        // the Table 2 accuracy ordering driver: symmetric trees underfit
        // relative to free-form depth-wise trees with equal node budget
        let g = generate(&DatasetSpec::higgs_like(4000), 43);
        let cat = CatBoostParams {
            num_rounds: 10,
            depth: 4,
            max_bins: 32,
            ..Default::default()
        };
        let (cat_booster, _) = train_catboost_like(&cat, &g.train).unwrap();
        let cat_acc = cat_booster.evaluate(&g.valid, "accuracy").unwrap();
        let xgb = LearnerParams {
            objective: crate::gbm::ObjectiveKind::BinaryLogistic,
            num_rounds: 10,
            max_depth: 4,
            max_bins: 32,
            eta: 0.1,
            ..Default::default()
        };
        let xgb_booster = crate::gbm::Learner::from_params(xgb)
            .unwrap()
            .train(&g.train, None)
            .unwrap();
        let xgb_acc = xgb_booster.evaluate(&g.valid, "accuracy").unwrap();
        // xgb should be at least as good (allow small noise margin)
        assert!(
            xgb_acc >= cat_acc - 1.5,
            "xgb {xgb_acc} vs cat {cat_acc}"
        );
    }
}
