//! LightGBM-style trainer: histogram bins + **leaf-wise best-first
//! growth** bounded by `num_leaves`, with **GOSS** row sampling
//! (Ke et al., NeurIPS 2017) — the algorithmic profile behind the
//! `lightgbm-*` rows of the paper's Table 2.
//!
//! GOSS keeps the `top_rate` fraction of rows with the largest |gradient|
//! and a uniform `other_rate` sample of the rest, amplifying the sampled
//! rows' gradients by `(1 − top_rate) / other_rate` so histogram sums stay
//! unbiased estimates of the full-data sums.

use std::time::Instant;

use anyhow::Result;

use crate::data::Dataset;
use crate::gbm::objective::objective_by_name;
use crate::gbm::{Booster, LearnerParams};
use crate::hist::{build_histogram_quantized, subtract, GradPairF64, Histogram};
use crate::predict;
use crate::quantile::{HistogramCuts, Quantizer};
use crate::tree::partitioner::BinSource;
use crate::tree::{
    ExpandEntry, GrowthPolicy, PolicyQueue, RegTree, RowPartitioner, SplitEvaluator, TreeParams,
};
use crate::util::Pcg64;
use crate::{Float, GradPair};

use super::BaselineStats;

/// LightGBM-flavoured hyperparameters.
#[derive(Debug, Clone)]
pub struct LightGbmParams {
    pub objective: String,
    pub num_class: usize,
    pub num_rounds: usize,
    pub learning_rate: f64,
    /// Leaf budget per tree (LightGBM's `num_leaves`, default 31).
    pub num_leaves: usize,
    pub max_bins: usize,
    pub lambda: f64,
    pub min_child_weight: f64,
    /// GOSS: fraction of rows kept by |gradient| rank.
    pub top_rate: f64,
    /// GOSS: uniformly sampled fraction of the remainder.
    pub other_rate: f64,
    pub seed: u64,
}

impl Default for LightGbmParams {
    fn default() -> Self {
        LightGbmParams {
            objective: "binary:logistic".into(),
            num_class: 1,
            num_rounds: 50,
            learning_rate: 0.1,
            num_leaves: 31,
            max_bins: 256,
            lambda: 1.0,
            min_child_weight: 1.0,
            top_rate: 0.2,
            other_rate: 0.1,
            seed: 0,
        }
    }
}

/// GOSS sample: returns (row ids, amplified gradients). Exposed for
/// direct unit testing.
pub fn goss_sample(
    grads: &[GradPair],
    top_rate: f64,
    other_rate: f64,
    rng: &mut Pcg64,
) -> (Vec<u32>, Vec<GradPair>) {
    let n = grads.len();
    if top_rate + other_rate >= 1.0 {
        return (
            (0..n as u32).collect(),
            grads.to_vec(),
        );
    }
    let n_top = ((n as f64) * top_rate).round() as usize;
    let n_other = ((n as f64) * other_rate).round() as usize;
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_by(|&a, &b| {
        let ga = grads[a as usize].grad.abs();
        let gb = grads[b as usize].grad.abs();
        gb.partial_cmp(&ga).unwrap().then(a.cmp(&b))
    });
    let (top, rest) = order.split_at(n_top.min(n));
    let amplify = ((1.0 - top_rate) / other_rate.max(1e-12)) as Float;
    let mut rows: Vec<u32> = top.to_vec();
    let mut sampled = rng.sample_indices(rest.len(), n_other);
    sampled.sort_unstable();
    rows.extend(sampled.iter().map(|&i| rest[i]));
    let mut out = grads.to_vec();
    for &i in sampled.iter().map(|&i| &rest[i]) {
        let g = &mut out[i as usize];
        g.grad *= amplify;
        g.hess *= amplify;
    }
    (rows, out)
}

/// Train a LightGBM-like model; returns the booster (shared predict/
/// metric machinery) and per-phase stats for the GPU model.
pub fn train_lightgbm_like(
    params: &LightGbmParams,
    train: &Dataset,
) -> Result<(Booster, BaselineStats)> {
    let t0 = Instant::now();
    let mut stats = BaselineStats::default();
    let objective = objective_by_name(&params.objective, params.num_class)?;
    let k = objective.n_outputs();

    // quantise once (shared cuts, exact single-node sketch)
    let cuts = HistogramCuts::from_dmatrix(&train.x, params.max_bins, None);
    let qm = Quantizer::new(cuts.clone()).quantize(&train.x);
    let n = train.n_rows();
    let n_bins = cuts.total_bins();

    let evaluator = SplitEvaluator::new(TreeParams {
        lambda: params.lambda,
        gamma: 0.0,
        alpha: 0.0,
        min_child_weight: params.min_child_weight,
        max_depth: 0,
        max_leaves: params.num_leaves,
        monotone_constraints: Vec::new(),
    });

    let base_score = objective.base_score(train);
    let mut margins: Vec<Vec<Float>> = base_score.iter().map(|&b| vec![b; n]).collect();
    let mut trees: Vec<Vec<RegTree>> = vec![Vec::new(); k];
    let mut rng = Pcg64::new(params.seed ^ 0x11bb);

    for _round in 0..params.num_rounds {
        let grads_all = objective.gradients(train, &margins);
        for c in 0..k {
            let (rows, grads) =
                goss_sample(&grads_all[c], params.top_rate, params.other_rate, &mut rng);
            let tree = build_leafwise_tree(
                &qm,
                &cuts,
                &grads,
                rows,
                &evaluator,
                params.learning_rate,
                params.num_leaves,
                &mut stats,
            );
            // margins updated for ALL rows by raw traversal (sampled rows
            // alone would leave the rest stale)
            let t = Instant::now();
            predict::accumulate_tree(&tree, &train.x, &mut margins[c]);
            stats.other_secs += t.elapsed().as_secs_f64();
            trees[c].push(tree);
        }
    }

    let train_secs = t0.elapsed().as_secs_f64();
    stats.other_secs = (train_secs - stats.hist_secs - stats.partition_secs).max(0.0);
    let bp = LearnerParams {
        objective: params.objective.parse().expect("infallible"),
        num_class: params.num_class,
        num_rounds: params.num_rounds,
        eta: params.learning_rate,
        max_leaves: params.num_leaves,
        max_bins: params.max_bins,
        grow_policy: crate::gbm::GrowPolicy::LossGuide,
        ..Default::default()
    };
    Ok((Booster::from_parts(bp, base_score, trees, train_secs)?, stats))
}

/// Best-first tree growth over a (possibly sampled) row set.
#[allow(clippy::too_many_arguments)]
fn build_leafwise_tree(
    qm: &crate::quantile::QuantizedMatrix,
    cuts: &HistogramCuts,
    grads: &[GradPair],
    rows: Vec<u32>,
    evaluator: &SplitEvaluator,
    eta: f64,
    num_leaves: usize,
    stats: &mut BaselineStats,
) -> RegTree {
    let n_bins = cuts.total_bins();
    let mut partitioner = RowPartitioner::from_rows(rows);
    let root_rows = partitioner.node_rows(0).to_vec();

    let root_sum = root_rows.iter().fold(GradPairF64::default(), |a, &r| {
        a + GradPairF64::from_single(grads[r as usize])
    });
    let mut tree = RegTree::new_root(
        (eta * evaluator.leaf_weight(root_sum)) as Float,
        root_sum.hess as Float,
    );

    let mut hists: std::collections::HashMap<usize, Histogram> = Default::default();
    let t = Instant::now();
    let mut root_hist = Histogram::zeros(n_bins);
    build_histogram_quantized(qm, grads, &root_rows, &mut root_hist);
    stats.hist_secs += t.elapsed().as_secs_f64();
    stats.hist_rounds += 1;
    hists.insert(0, root_hist);

    let mut queue = PolicyQueue::new(GrowthPolicy::LossGuide);
    if let Some(split) = evaluator.evaluate(&hists[&0], cuts, root_sum) {
        queue.push(ExpandEntry {
            nid: 0,
            depth: 0,
            split,
            node_sum: root_sum,
            bounds: Default::default(),
            timestamp: 0,
        });
    }

    while let Some(entry) = queue.pop() {
        if tree.n_leaves() >= num_leaves {
            break;
        }
        let s = entry.split;
        let (left, right) = tree.apply_split(
            entry.nid,
            s.feature,
            s.threshold,
            s.default_left,
            s.gain as Float,
            (eta * evaluator.leaf_weight(s.left_sum)) as Float,
            s.left_sum.hess as Float,
            (eta * evaluator.leaf_weight(s.right_sum)) as Float,
            s.right_sum.hess as Float,
        );
        let t = Instant::now();
        let (nl, nr) =
            partitioner.apply_split(entry.nid, &s, left, right, &BinSource::Quantized(qm), cuts);
        stats.partition_secs += t.elapsed().as_secs_f64();

        // smaller child built, sibling derived (same trick as the paper)
        let (small, large) = if nl <= nr { (left, right) } else { (right, left) };
        let t = Instant::now();
        let mut small_hist = Histogram::zeros(n_bins);
        build_histogram_quantized(qm, grads, partitioner.node_rows(small), &mut small_hist);
        stats.hist_secs += t.elapsed().as_secs_f64();
        stats.hist_rounds += 1;
        let parent_hist = hists.remove(&entry.nid).expect("parent hist");
        let large_hist = subtract(&parent_hist, &small_hist);
        let (lh, rh) = if small == left {
            (small_hist, large_hist)
        } else {
            (large_hist, small_hist)
        };

        for (nid, hist, sum) in [(left, lh, s.left_sum), (right, rh, s.right_sum)] {
            if let Some(split) = evaluator.evaluate(&hist, cuts, sum) {
                queue.push(ExpandEntry {
                    nid,
                    depth: entry.depth + 1,
                    split,
                    node_sum: sum,
                    bounds: Default::default(),
                    timestamp: 0,
                });
                hists.insert(nid, hist);
            }
        }
    }
    tree
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetSpec};

    #[test]
    fn goss_keeps_top_gradients_and_amplifies_rest() {
        let grads: Vec<GradPair> = (0..100)
            .map(|i| GradPair::new(i as f32 / 100.0, 1.0))
            .collect();
        let mut rng = Pcg64::new(1);
        let (rows, out) = goss_sample(&grads, 0.1, 0.2, &mut rng);
        assert_eq!(rows.len(), 10 + 20);
        // the 10 largest |g| rows (90..99) all kept, unamplified
        for r in 90..100u32 {
            assert!(rows.contains(&r), "top row {r} kept");
            assert_eq!(out[r as usize].grad, grads[r as usize].grad);
        }
        // sampled rows amplified by (1-0.1)/0.2 = 4.5
        let amp = rows.iter().find(|&&r| r < 90).unwrap();
        assert!((out[*amp as usize].grad / grads[*amp as usize].grad - 4.5).abs() < 1e-5);
    }

    #[test]
    fn goss_expected_gradient_sum_is_preserved() {
        // amplification keeps the sampled sum an unbiased estimator:
        // E[sum(sampled amplified)] == sum(all). Check within tolerance
        // over many seeds.
        let mut rng_data = Pcg64::new(7);
        let grads: Vec<GradPair> = (0..2000)
            .map(|_| GradPair::new(rng_data.next_f32() * 2.0 - 1.0, 1.0))
            .collect();
        let full: f64 = grads.iter().map(|g| g.grad as f64).sum();
        let mut est = 0.0;
        let trials = 50;
        for seed in 0..trials {
            let mut rng = Pcg64::new(seed);
            let (rows, out) = goss_sample(&grads, 0.2, 0.1, &mut rng);
            est += rows.iter().map(|&r| out[r as usize].grad as f64).sum::<f64>();
        }
        est /= trials as f64;
        assert!(
            (est - full).abs() < full.abs().max(10.0) * 0.35,
            "estimator {est} vs true {full}"
        );
    }

    #[test]
    fn goss_degenerate_full_sample() {
        let grads = vec![GradPair::new(1.0, 1.0); 10];
        let mut rng = Pcg64::new(2);
        let (rows, out) = goss_sample(&grads, 0.6, 0.6, &mut rng);
        assert_eq!(rows.len(), 10);
        assert_eq!(out[0].grad, 1.0);
    }

    #[test]
    fn trains_and_beats_majority() {
        let g = generate(&DatasetSpec::higgs_like(4000), 17);
        let params = LightGbmParams {
            num_rounds: 20,
            max_bins: 32,
            ..Default::default()
        };
        let (booster, stats) = train_lightgbm_like(&params, &g.train).unwrap();
        let acc = booster.evaluate(&g.valid, "accuracy").unwrap();
        let majority = {
            let pos: f64 =
                g.valid.y.iter().filter(|&&y| y == 1.0).count() as f64 / g.valid.y.len() as f64;
            100.0 * pos.max(1.0 - pos)
        };
        assert!(acc > majority + 1.0, "acc {acc} vs majority {majority}");
        assert!(stats.hist_secs > 0.0);
        assert!(stats.hist_rounds >= 20);
    }

    #[test]
    fn leaf_budget_respected() {
        let g = generate(&DatasetSpec::higgs_like(2000), 19);
        let params = LightGbmParams {
            num_rounds: 3,
            num_leaves: 8,
            max_bins: 16,
            ..Default::default()
        };
        let (booster, _) = train_lightgbm_like(&params, &g.train).unwrap();
        for t in &booster.trees[0] {
            assert!(t.n_leaves() <= 8);
        }
    }

    #[test]
    fn regression_objective_works() {
        let g = generate(&DatasetSpec::synthetic_like(2000), 23);
        let params = LightGbmParams {
            objective: "reg:squarederror".into(),
            num_rounds: 10,
            max_bins: 32,
            ..Default::default()
        };
        let (booster, _) = train_lightgbm_like(&params, &g.train).unwrap();
        let rmse = booster.evaluate(&g.valid, "rmse").unwrap();
        let base = {
            let mean: f32 = g.train.y.iter().sum::<f32>() / g.train.y.len() as f32;
            let se: f64 = g.valid.y.iter().map(|&y| ((y - mean) as f64).powi(2)).sum();
            (se / g.valid.y.len() as f64).sqrt()
        };
        assert!(rmse < base, "rmse {rmse} vs baseline {base}");
    }
}
