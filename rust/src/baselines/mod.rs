//! Competitor re-implementations for the Table 2 comparison.
//!
//! The paper benchmarks against LightGBM and CatBoost binaries; neither is
//! available in this offline environment, so the *algorithms* that drive
//! their speed/accuracy trade-offs are re-implemented on this crate's
//! substrates (quantisation, histograms, split evaluation), per the
//! substitution rule in DESIGN.md §2:
//!
//! * [`lightgbm_like`] — leaf-wise (best-first) growth with GOSS
//!   (Gradient-based One-Side Sampling), LightGBM's two signature
//!   techniques (Ke et al., 2017),
//! * [`catboost_like`] — oblivious (symmetric) decision tables, CatBoost's
//!   signature structure: one shared split per level, which is fast and
//!   regularising but less expressive (the paper's Table 2 shows CatBoost
//!   fastest on GPU yet least accurate — this structure is why).
//!
//! Both produce a [`crate::gbm::Booster`] via `from_parts`, so prediction
//! and metric evaluation are shared with the main system, and both report
//! per-phase timings so the bench harness can apply the GPU-execution
//! models described in `benches/table2.rs`.

pub mod catboost_like;
pub mod lightgbm_like;

pub use catboost_like::{train_catboost_like, CatBoostParams};
pub use lightgbm_like::{train_lightgbm_like, LightGbmParams};

/// Per-phase timing shared by both baseline trainers, mirroring
/// [`crate::coordinator::BuildStats`] at the granularity the GPU models
/// need.
#[derive(Debug, Clone, Default)]
pub struct BaselineStats {
    /// Seconds spent building gradient histograms.
    pub hist_secs: f64,
    /// Seconds spent partitioning / reassigning rows.
    pub partition_secs: f64,
    /// Everything else (gradients, split search, bookkeeping).
    pub other_secs: f64,
    /// Number of histogram build passes.
    pub hist_rounds: usize,
}

impl BaselineStats {
    pub fn total(&self) -> f64 {
        self.hist_secs + self.partition_secs + self.other_secs
    }
}
