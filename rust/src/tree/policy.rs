//! Reconfigurable tree growth strategy (paper §2.3: "The tree growth
//! strategy in this algorithm is reconfigurable to prioritise expanding
//! nodes with a higher reduction in the objective function or nodes closer
//! to the root").
//!
//! * [`GrowthPolicy::DepthWise`] — expand nodes closest to the root first
//!   (XGBoost's default; processes a whole level per histogram round),
//! * [`GrowthPolicy::LossGuide`] — expand the node with the highest split
//!   gain first (LightGBM-style best-first growth, bounded by
//!   `max_leaves`).
//!
//! Both are expressed through one [`PolicyQueue`] over [`ExpandEntry`]s so
//! the multi-device coordinator (Algorithm 1's `expand_queue`) is policy-
//! agnostic.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::hist::GradPairF64;
use crate::tree::split::{NodeBounds, SplitCandidate};

/// Growth strategy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GrowthPolicy {
    DepthWise,
    LossGuide,
}

impl std::str::FromStr for GrowthPolicy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "depthwise" | "depth_wise" | "depth" => Ok(GrowthPolicy::DepthWise),
            "lossguide" | "loss_guide" | "loss" => Ok(GrowthPolicy::LossGuide),
            other => Err(format!(
                "unknown grow_policy {other:?}; valid policies: depthwise, lossguide"
            )),
        }
    }
}

impl std::fmt::Display for GrowthPolicy {
    /// Canonical config-file spelling; round-trips through [`FromStr`].
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            GrowthPolicy::DepthWise => "depthwise",
            GrowthPolicy::LossGuide => "lossguide",
        })
    }
}

/// A node awaiting expansion (Algorithm 1's queue entries).
#[derive(Debug, Clone)]
pub struct ExpandEntry {
    pub nid: usize,
    pub depth: usize,
    /// The best split found for this node (None = no feasible split; the
    /// node stays a leaf and is never queued).
    pub split: SplitCandidate,
    /// Node's total gradient sum, carried so children's evaluation doesn't
    /// re-reduce rows.
    pub node_sum: GradPairF64,
    /// Leaf-weight interval this node's subtree must respect (monotone
    /// constraint propagation; ±inf when unconstrained).
    pub bounds: NodeBounds,
    /// Monotone insertion stamp — ties in the heap break FIFO so the
    /// expansion order is deterministic.
    pub timestamp: u64,
}

struct HeapItem {
    entry: ExpandEntry,
    policy: GrowthPolicy,
}

impl PartialEq for HeapItem {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for HeapItem {}

impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; define "greater" = "expand sooner".
        let primary = match self.policy {
            GrowthPolicy::DepthWise => other.entry.depth.cmp(&self.entry.depth),
            GrowthPolicy::LossGuide => self
                .entry
                .split
                .gain
                .partial_cmp(&other.entry.split.gain)
                .unwrap_or(Ordering::Equal),
        };
        primary.then_with(|| other.entry.timestamp.cmp(&self.entry.timestamp))
    }
}

/// Priority queue over expansion entries, ordered by the chosen policy.
pub struct PolicyQueue {
    heap: BinaryHeap<HeapItem>,
    policy: GrowthPolicy,
    next_stamp: u64,
}

impl PolicyQueue {
    pub fn new(policy: GrowthPolicy) -> Self {
        PolicyQueue {
            heap: BinaryHeap::new(),
            policy,
            next_stamp: 0,
        }
    }

    pub fn policy(&self) -> GrowthPolicy {
        self.policy
    }

    pub fn push(&mut self, mut entry: ExpandEntry) {
        entry.timestamp = self.next_stamp;
        self.next_stamp += 1;
        self.heap.push(HeapItem {
            entry,
            policy: self.policy,
        });
    }

    pub fn pop(&mut self) -> Option<ExpandEntry> {
        self.heap.pop().map(|i| i.entry)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hist::GradPairF64;

    fn entry(nid: usize, depth: usize, gain: f64) -> ExpandEntry {
        ExpandEntry {
            nid,
            depth,
            split: SplitCandidate {
                feature: 0,
                split_bin: 0,
                threshold: 0.0,
                default_left: true,
                gain,
                left_sum: GradPairF64::default(),
                right_sum: GradPairF64::default(),
                categories: 0,
                cat_bins: 0,
            },
            node_sum: GradPairF64::default(),
            bounds: NodeBounds::default(),
            timestamp: 0,
        }
    }

    #[test]
    fn depthwise_expands_shallow_first() {
        let mut q = PolicyQueue::new(GrowthPolicy::DepthWise);
        q.push(entry(5, 2, 10.0));
        q.push(entry(1, 0, 0.1));
        q.push(entry(3, 1, 5.0));
        assert_eq!(q.pop().unwrap().nid, 1);
        assert_eq!(q.pop().unwrap().nid, 3);
        assert_eq!(q.pop().unwrap().nid, 5);
    }

    #[test]
    fn lossguide_expands_best_gain_first() {
        let mut q = PolicyQueue::new(GrowthPolicy::LossGuide);
        q.push(entry(1, 0, 0.1));
        q.push(entry(5, 3, 10.0));
        q.push(entry(3, 1, 5.0));
        assert_eq!(q.pop().unwrap().nid, 5);
        assert_eq!(q.pop().unwrap().nid, 3);
        assert_eq!(q.pop().unwrap().nid, 1);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = PolicyQueue::new(GrowthPolicy::DepthWise);
        q.push(entry(10, 1, 1.0));
        q.push(entry(20, 1, 2.0));
        q.push(entry(30, 1, 3.0));
        assert_eq!(q.pop().unwrap().nid, 10);
        assert_eq!(q.pop().unwrap().nid, 20);
        assert_eq!(q.pop().unwrap().nid, 30);
    }

    #[test]
    fn lossguide_ties_break_fifo() {
        let mut q = PolicyQueue::new(GrowthPolicy::LossGuide);
        q.push(entry(10, 0, 1.0));
        q.push(entry(20, 0, 1.0));
        assert_eq!(q.pop().unwrap().nid, 10);
        assert_eq!(q.pop().unwrap().nid, 20);
    }

    #[test]
    fn parse_policy() {
        assert_eq!("depthwise".parse::<GrowthPolicy>().unwrap(), GrowthPolicy::DepthWise);
        assert_eq!("lossguide".parse::<GrowthPolicy>().unwrap(), GrowthPolicy::LossGuide);
        assert!("x".parse::<GrowthPolicy>().is_err());
    }

    #[test]
    fn len_and_empty() {
        let mut q = PolicyQueue::new(GrowthPolicy::DepthWise);
        assert!(q.is_empty());
        q.push(entry(1, 0, 1.0));
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }
}
