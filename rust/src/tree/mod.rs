//! Decision tree substrate: the tree structure, split evaluation over
//! gradient histograms, the reconfigurable growth policy of paper §2.3,
//! and the row partitioner that sorts instances into leaves.

pub mod partitioner;
pub mod policy;
pub mod regtree;
pub mod split;

pub use partitioner::RowPartitioner;
pub use policy::{ExpandEntry, GrowthPolicy, PolicyQueue};
pub use regtree::{Node, RegTree};
pub use split::{SplitCandidate, SplitEvaluator, TreeParams};
