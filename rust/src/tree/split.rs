//! Split evaluation over gradient histograms (paper §2.3: "The split gain
//! may then be calculated for each feature and each quantile by performing
//! a scan over the gradient histogram").
//!
//! Implements the XGBoost regularised gain
//!
//! ```text
//! gain = 1/2 [ GL²/(HL+λ) + GR²/(HR+λ) − G²/(H+λ) ] − γ
//! ```
//!
//! with both missing-value default directions evaluated (missing rows'
//! gradient mass = node total − feature-present total), L1 (`alpha`)
//! thresholding on leaf weights, and `min_child_weight` feasibility.

use crate::hist::{GradPairF64, Histogram};
use crate::quantile::HistogramCuts;
use crate::Float;

/// Tree-regularisation hyperparameters (a subset of XGBoost's, the ones
/// the paper's benchmark sweeps touch).
#[derive(Debug, Clone)]
pub struct TreeParams {
    /// L2 regularisation on leaf weights (`lambda`).
    pub lambda: f64,
    /// Minimum loss reduction to make a split (`gamma` /
    /// `min_split_loss`).
    pub gamma: f64,
    /// L1 regularisation on leaf weights (`alpha`).
    pub alpha: f64,
    /// Minimum hessian sum in each child.
    pub min_child_weight: f64,
    /// Maximum tree depth (0 = unlimited, only sensible with loss-guided
    /// growth).
    pub max_depth: usize,
    /// Maximum number of leaves (0 = unlimited); the binding constraint
    /// under loss-guided growth, as in LightGBM.
    pub max_leaves: usize,
    /// Per-feature monotonicity: `1` = prediction non-decreasing in the
    /// feature, `-1` = non-increasing, `0` = unconstrained. Empty =
    /// no constraints. Enforced via leaf-weight bound propagation
    /// (XGBoost's `monotone_constraints`).
    pub monotone_constraints: Vec<i8>,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams {
            lambda: 1.0,
            gamma: 0.0,
            alpha: 0.0,
            min_child_weight: 1.0,
            max_depth: 6,
            max_leaves: 0,
            monotone_constraints: Vec::new(),
        }
    }
}

/// Leaf-weight interval a node's subtree must respect (monotone
/// constraint propagation).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeBounds {
    pub lower: f64,
    pub upper: f64,
}

impl Default for NodeBounds {
    fn default() -> Self {
        NodeBounds {
            lower: f64::NEG_INFINITY,
            upper: f64::INFINITY,
        }
    }
}

/// A candidate split produced by [`SplitEvaluator::evaluate`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SplitCandidate {
    pub feature: u32,
    /// Global bin index; rows with `bin <= split_bin` for this feature go
    /// left. `threshold` is the corresponding raw-value cut. For
    /// categorical splits (`categories != 0`) both are routing-irrelevant
    /// (`split_bin` keeps the last bin added to the left set for the
    /// deterministic tie-break; `threshold` is 0).
    pub split_bin: u32,
    pub threshold: Float,
    pub default_left: bool,
    pub gain: f64,
    pub left_sum: GradPairF64,
    pub right_sum: GradPairF64,
    /// Category-**value** membership bitset of a categorical split: bit
    /// `c` set ⇔ raw value `c` routes left. `0` means this is a numeric
    /// threshold split (an interior categorical split always has at
    /// least one left category, so 0 is unambiguous). Category codes are
    /// validated integers in `[0, 64)` at ingest, so a single `u64`
    /// suffices and the candidate stays `Copy`.
    pub categories: u64,
    /// The same left-membership over the feature's **local bins** (bit
    /// `i` ⇔ local bin `i` routes left) — what the packed/quantised
    /// routing paths test without a bin→value lookup.
    pub cat_bins: u64,
}

impl SplitCandidate {
    /// Whether this is a category-membership split.
    #[inline]
    pub fn is_categorical(&self) -> bool {
        self.categories != 0
    }
}

/// Stateless gain calculator.
#[derive(Debug, Clone)]
pub struct SplitEvaluator {
    pub params: TreeParams,
}

impl SplitEvaluator {
    pub fn new(params: TreeParams) -> Self {
        SplitEvaluator { params }
    }

    /// Optimal leaf weight `w* = -G̃/(H+λ)` with L1 soft-thresholding of G.
    #[inline]
    pub fn leaf_weight(&self, sum: GradPairF64) -> f64 {
        let g = threshold_l1(sum.grad, self.params.alpha);
        -g / (sum.hess + self.params.lambda)
    }

    /// Loss contribution `G̃²/(H+λ)` of a node.
    #[inline]
    pub fn gain_term(&self, sum: GradPairF64) -> f64 {
        let g = threshold_l1(sum.grad, self.params.alpha);
        g * g / (sum.hess + self.params.lambda)
    }

    /// Split gain for a (left, right) partition of `parent`.
    #[inline]
    pub fn split_gain(&self, parent: GradPairF64, left: GradPairF64, right: GradPairF64) -> f64 {
        0.5 * (self.gain_term(left) + self.gain_term(right) - self.gain_term(parent))
            - self.params.gamma
    }

    #[inline]
    fn feasible(&self, sum: GradPairF64) -> bool {
        sum.hess >= self.params.min_child_weight
    }

    /// Scan a node's histogram and return the best split across all
    /// features, or `None` if no feasible split has positive gain.
    ///
    /// `node_sum` is the node's total gradient pair (known exactly by the
    /// caller from the parent split; includes rows missing in every
    /// feature). For each feature, rows *missing that feature* contribute
    /// `node_sum − Σ feature bins`; both directions for that mass are
    /// evaluated (XGBoost's default-direction learning, §1 "fully supports
    /// sparse input data").
    pub fn evaluate(
        &self,
        hist: &Histogram,
        cuts: &HistogramCuts,
        node_sum: GradPairF64,
    ) -> Option<SplitCandidate> {
        self.evaluate_masked(hist, cuts, node_sum, None)
    }

    /// [`Self::evaluate`] restricted to features where `mask[f]` is true
    /// (column sampling — `colsample_bytree`). `None` = all features.
    pub fn evaluate_masked(
        &self,
        hist: &Histogram,
        cuts: &HistogramCuts,
        node_sum: GradPairF64,
        mask: Option<&[bool]>,
    ) -> Option<SplitCandidate> {
        self.evaluate_bounded(hist, cuts, node_sum, mask, NodeBounds::default())
    }

    /// Full evaluation: feature mask + monotone leaf-weight bounds.
    pub fn evaluate_bounded(
        &self,
        hist: &Histogram,
        cuts: &HistogramCuts,
        node_sum: GradPairF64,
        mask: Option<&[bool]>,
        bounds: NodeBounds,
    ) -> Option<SplitCandidate> {
        let mut best: Option<SplitCandidate> = None;
        let constrained = !self.params.monotone_constraints.is_empty();
        // the parent term is identical for every candidate (left + right
        // always equals node_sum) — hoist it out of the scan
        let parent_gain = if constrained {
            let wp = self.weight_clamped(node_sum, bounds);
            self.gain_given_weight(node_sum, wp) + 2.0 * self.params.gamma
        } else {
            self.gain_term(node_sum) + 2.0 * self.params.gamma
        };
        for f in 0..cuts.n_features() {
            if let Some(m) = mask {
                if !m[f] {
                    continue;
                }
            }
            let constraint = self.constraint_of(f);
            let lo = cuts.ptrs[f] as usize;
            let hi = cuts.ptrs[f + 1] as usize;
            if hi - lo < 2 {
                continue; // single-bin feature cannot split
            }
            if cuts.is_categorical(f) {
                // categories have no order, so a monotone constraint on a
                // categorical feature is meaningless — skip it entirely
                if constraint != 0 {
                    continue;
                }
                self.evaluate_categorical(
                    &mut best, f, lo, hi, hist, cuts, node_sum, parent_gain, bounds,
                );
                continue;
            }
            let present = hist.feature_sum(lo, hi);
            let missing = node_sum - present;
            // forward scan: accumulate present-left; try missing on each
            // side. The final bin is included: "all present left, missing
            // right" is the is-present split, meaningful on sparse data.
            let mut left_present = GradPairF64::default();
            for b in lo..hi {
                left_present += hist.bins[b];
                // candidate A: missing goes right
                let left = left_present;
                let right = node_sum - left;
                self.consider(
                    &mut best, f, b, cuts, false, left, right, parent_gain, constraint, bounds,
                    0, 0,
                );
                // candidate B: missing goes left
                let left_m = left_present + missing;
                let right_m = node_sum - left_m;
                self.consider(
                    &mut best, f, b, cuts, true, left_m, right_m, parent_gain, constraint,
                    bounds, 0, 0,
                );
            }
        }
        best
    }

    /// Monotone constraint of feature `f` (0 when unconfigured).
    #[inline]
    pub fn constraint_of(&self, f: usize) -> i8 {
        self.params
            .monotone_constraints
            .get(f)
            .copied()
            .unwrap_or(0)
    }

    /// Optimal leaf weight clamped into the node's bound interval.
    #[inline]
    pub fn weight_clamped(&self, sum: GradPairF64, bounds: NodeBounds) -> f64 {
        self.leaf_weight(sum).clamp(bounds.lower, bounds.upper)
    }

    /// Loss-reduction term for a node forced to weight `w`
    /// (`-(2 G̃ w + (H+λ) w²)`; equals `gain_term` at the unclamped
    /// optimum).
    #[inline]
    pub fn gain_given_weight(&self, sum: GradPairF64, w: f64) -> f64 {
        let g = threshold_l1(sum.grad, self.params.alpha);
        -(2.0 * g * w + (sum.hess + self.params.lambda) * w * w)
    }

    /// Child bound intervals after applying `split` under `bounds`
    /// (monotone propagation: both subtrees must stay on their side of
    /// the split's weight midpoint).
    pub fn child_bounds(
        &self,
        split: &SplitCandidate,
        bounds: NodeBounds,
    ) -> (NodeBounds, NodeBounds) {
        let c = self.constraint_of(split.feature as usize);
        if c == 0 {
            return (bounds, bounds);
        }
        let wl = self.weight_clamped(split.left_sum, bounds);
        let wr = self.weight_clamped(split.right_sum, bounds);
        let mid = 0.5 * (wl + wr);
        if c > 0 {
            (
                NodeBounds { lower: bounds.lower, upper: mid },
                NodeBounds { lower: mid, upper: bounds.upper },
            )
        } else {
            (
                NodeBounds { lower: mid, upper: bounds.upper },
                NodeBounds { lower: bounds.lower, upper: mid },
            )
        }
    }

    /// Gain-sorted greedy categorical partition search (LightGBM-style),
    /// plus the one-vs-rest candidates: category bins carrying gradient
    /// mass in this node are (a) each tried alone on the left, and
    /// (b) sorted by leaf-weight score `G/(H+λ)` and scanned as ordered
    /// prefixes like a numeric feature. Categories absent from the node
    /// (and at inference, out-of-vocabulary values) route right; missing
    /// values follow the learned `default_left`. Deterministic by
    /// construction: the score sort tie-breaks on bin index and the
    /// histogram is already bit-identical across devices.
    #[allow(clippy::too_many_arguments)]
    fn evaluate_categorical(
        &self,
        best: &mut Option<SplitCandidate>,
        f: usize,
        lo: usize,
        hi: usize,
        hist: &Histogram,
        cuts: &HistogramCuts,
        node_sum: GradPairF64,
        parent_gain: f64,
        bounds: NodeBounds,
    ) {
        let present = hist.feature_sum(lo, hi);
        let missing = node_sum - present;
        let lambda = self.params.lambda;
        let occupied: Vec<usize> = (0..hi - lo)
            .filter(|&i| {
                let s = hist.bins[lo + i];
                s.hess != 0.0 || s.grad != 0.0
            })
            .collect();
        if occupied.len() < 2 {
            return;
        }
        let cat_bit = |local: usize| -> u64 {
            let c = cuts.category_of_local_bin(f, local);
            debug_assert!(
                c >= 0.0 && c < 64.0 && c.fract() == 0.0,
                "category codes are validated at ingest"
            );
            1u64 << (c as u32)
        };
        // one-vs-rest over occupied categories
        for &i in &occupied {
            let left = hist.bins[lo + i];
            let cats = cat_bit(i);
            let bins = 1u64 << i;
            self.consider(
                best, f, lo + i, cuts, false, left, node_sum - left, parent_gain, 0, bounds,
                cats, bins,
            );
            let left_m = left + missing;
            self.consider(
                best, f, lo + i, cuts, true, left_m, node_sum - left_m, parent_gain, 0,
                bounds, cats, bins,
            );
        }
        // gain-sorted greedy grouping
        let mut order = occupied;
        order.sort_by(|&a, &b| {
            let sa = hist.bins[lo + a];
            let sb = hist.bins[lo + b];
            let ka = sa.grad / (sa.hess + lambda);
            let kb = sb.grad / (sb.hess + lambda);
            ka.partial_cmp(&kb)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut left = GradPairF64::default();
        let mut cats = 0u64;
        let mut bins = 0u64;
        for &i in &order {
            left += hist.bins[lo + i];
            cats |= cat_bit(i);
            bins |= 1u64 << i;
            // the full-prefix candidate is still meaningful with missing
            // right; degenerate empty-right candidates are rejected by
            // the feasibility/positive-gain checks in `consider`
            self.consider(
                best, f, lo + i, cuts, false, left, node_sum - left, parent_gain, 0, bounds,
                cats, bins,
            );
            let left_m = left + missing;
            self.consider(
                best, f, lo + i, cuts, true, left_m, node_sum - left_m, parent_gain, 0,
                bounds, cats, bins,
            );
        }
    }

    #[inline]
    #[allow(clippy::too_many_arguments)]
    fn consider(
        &self,
        best: &mut Option<SplitCandidate>,
        feature: usize,
        bin: usize,
        cuts: &HistogramCuts,
        default_left: bool,
        left: GradPairF64,
        right: GradPairF64,
        parent_gain: f64,
        constraint: i8,
        bounds: NodeBounds,
        categories: u64,
        cat_bins: u64,
    ) {
        if !self.feasible(left) || !self.feasible(right) {
            return;
        }
        let constrained = !self.params.monotone_constraints.is_empty();
        let gain = if constrained {
            let wl = self.weight_clamped(left, bounds);
            let wr = self.weight_clamped(right, bounds);
            // reject direction violations on the constrained feature
            if (constraint > 0 && wl > wr) || (constraint < 0 && wl < wr) {
                return;
            }
            0.5 * (self.gain_given_weight(left, wl) + self.gain_given_weight(right, wr))
                - 0.5 * parent_gain
        } else {
            // == split_gain(node_sum, left, right); parent term precomputed
            0.5 * (self.gain_term(left) + self.gain_term(right)) - 0.5 * parent_gain
        };
        if gain <= 0.0 {
            return;
        }
        let better = match best {
            None => true,
            // ties broken toward lower feature id then lower bin for
            // determinism across device counts
            Some(b) => {
                gain > b.gain + 1e-12
                    || ((gain - b.gain).abs() <= 1e-12
                        && (feature as u32, bin as u32) < (b.feature, b.split_bin))
            }
        };
        if better {
            *best = Some(SplitCandidate {
                feature: feature as u32,
                split_bin: bin as u32,
                threshold: if categories != 0 {
                    0.0
                } else {
                    cuts.cut_of_bin(bin as u32)
                },
                default_left,
                gain,
                left_sum: left,
                right_sum: right,
                categories,
                cat_bins,
            });
        }
    }
}

/// L1 soft-thresholding of the gradient sum.
#[inline]
fn threshold_l1(g: f64, alpha: f64) -> f64 {
    if alpha == 0.0 {
        g
    } else if g > alpha {
        g - alpha
    } else if g < -alpha {
        g + alpha
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DMatrix;
    use crate::hist::build_histogram_quantized;
    use crate::quantile::{HistogramCuts, Quantizer};
    use crate::GradPair;

    /// Brute-force best split over raw values for cross-checking.
    fn brute_force_best_gain(
        x: &DMatrix,
        grads: &[GradPair],
        cuts: &HistogramCuts,
        ev: &SplitEvaluator,
    ) -> f64 {
        let node_sum = grads.iter().fold(GradPairF64::default(), |a, g| {
            a + GradPairF64::from_single(*g)
        });
        let mut best = 0.0f64;
        for f in 0..x.n_cols() {
            for cut in cuts.feature_cuts(f) {
                for missing_left in [false, true] {
                    let mut left = GradPairF64::default();
                    for r in 0..x.n_rows() {
                        let goes_left = match x.get(r, f) {
                            Some(v) => v < *cut,
                            None => missing_left,
                        };
                        if goes_left {
                            left += GradPairF64::from_single(grads[r]);
                        }
                    }
                    let right = node_sum - left;
                    if left.hess >= ev.params.min_child_weight
                        && right.hess >= ev.params.min_child_weight
                    {
                        best = best.max(ev.split_gain(node_sum, left, right));
                    }
                }
            }
        }
        best
    }

    fn fixture(seed: u64, n: usize, d: usize, p_nan: f64) -> (DMatrix, Vec<GradPair>) {
        let mut rng = crate::util::Pcg64::new(seed);
        let vals: Vec<Float> = (0..n * d)
            .map(|_| {
                if rng.next_f64() < p_nan {
                    Float::NAN
                } else {
                    rng.next_f32() * 4.0 - 2.0
                }
            })
            .collect();
        let x = DMatrix::dense(vals, n, d);
        let grads: Vec<GradPair> = (0..n)
            .map(|_| GradPair::new(rng.next_f32() * 2.0 - 1.0, rng.next_f32() * 0.9 + 0.1))
            .collect();
        (x, grads)
    }

    #[test]
    fn histogram_split_matches_brute_force() {
        for seed in 0..5u64 {
            let (x, grads) = fixture(seed, 150, 3, 0.1);
            let cuts = HistogramCuts::from_dmatrix(&x, 16, None);
            let qm = Quantizer::new(cuts.clone()).quantize(&x);
            let rows: Vec<u32> = (0..x.n_rows() as u32).collect();
            let mut hist = Histogram::zeros(qm.n_bins);
            build_histogram_quantized(&qm, &grads, &rows, &mut hist);
            let node_sum = grads.iter().fold(GradPairF64::default(), |a, g| {
                a + GradPairF64::from_single(*g)
            });
            let ev = SplitEvaluator::new(TreeParams {
                min_child_weight: 0.0,
                ..Default::default()
            });
            let got = ev.evaluate(&hist, &cuts, node_sum).map(|s| s.gain).unwrap_or(0.0);
            let want = brute_force_best_gain(&x, &grads, &cuts, &ev);
            assert!(
                (got - want).abs() < 1e-9,
                "seed {seed}: hist gain {got} vs brute force {want}"
            );
        }
    }

    #[test]
    fn leaf_weight_formula() {
        let ev = SplitEvaluator::new(TreeParams {
            lambda: 1.0,
            ..Default::default()
        });
        let w = ev.leaf_weight(GradPairF64::new(4.0, 3.0));
        assert!((w - (-1.0)).abs() < 1e-12);
    }

    #[test]
    fn l1_shrinks_leaf_weight() {
        let ev = SplitEvaluator::new(TreeParams {
            lambda: 0.0,
            alpha: 1.0,
            ..Default::default()
        });
        assert!((ev.leaf_weight(GradPairF64::new(3.0, 2.0)) - (-1.0)).abs() < 1e-12);
        assert_eq!(ev.leaf_weight(GradPairF64::new(0.5, 2.0)), 0.0);
    }

    #[test]
    fn gamma_suppresses_weak_splits() {
        let (x, grads) = fixture(1, 100, 2, 0.0);
        let cuts = HistogramCuts::from_dmatrix(&x, 8, None);
        let qm = Quantizer::new(cuts.clone()).quantize(&x);
        let rows: Vec<u32> = (0..100).collect();
        let mut hist = Histogram::zeros(qm.n_bins);
        build_histogram_quantized(&qm, &grads, &rows, &mut hist);
        let node_sum = grads.iter().fold(GradPairF64::default(), |a, g| {
            a + GradPairF64::from_single(*g)
        });
        let weak = SplitEvaluator::new(TreeParams::default())
            .evaluate(&hist, &cuts, node_sum);
        let strong_gamma = SplitEvaluator::new(TreeParams {
            gamma: 1e9,
            ..Default::default()
        })
        .evaluate(&hist, &cuts, node_sum);
        assert!(weak.is_some());
        assert!(strong_gamma.is_none());
    }

    #[test]
    fn min_child_weight_blocks_tiny_children() {
        // perfectly separable single feature; huge min_child_weight blocks
        let x = DMatrix::dense(vec![0.0, 1.0, 2.0, 3.0], 4, 1);
        let grads = vec![
            GradPair::new(-1.0, 1.0),
            GradPair::new(-1.0, 1.0),
            GradPair::new(1.0, 1.0),
            GradPair::new(1.0, 1.0),
        ];
        let cuts = HistogramCuts::from_dmatrix(&x, 4, None);
        let qm = Quantizer::new(cuts.clone()).quantize(&x);
        let mut hist = Histogram::zeros(qm.n_bins);
        build_histogram_quantized(&qm, &grads, &[0, 1, 2, 3], &mut hist);
        let node_sum = GradPairF64::new(0.0, 4.0);
        let ok = SplitEvaluator::new(TreeParams {
            min_child_weight: 2.0,
            ..Default::default()
        })
        .evaluate(&hist, &cuts, node_sum);
        assert!(ok.is_some());
        assert_eq!(ok.unwrap().left_sum.hess, 2.0);
        let blocked = SplitEvaluator::new(TreeParams {
            min_child_weight: 3.0,
            ..Default::default()
        })
        .evaluate(&hist, &cuts, node_sum);
        assert!(blocked.is_none());
    }

    #[test]
    fn missing_direction_is_learned() {
        // feature present on half the rows; missing rows all have positive
        // gradient, present-low rows negative -> best split should send
        // missing right with the positives
        let mut vals = Vec::new();
        let mut grads = Vec::new();
        for i in 0..40 {
            if i % 2 == 0 {
                vals.push((i % 10) as Float);
                grads.push(GradPair::new(if i % 10 < 5 { -1.0 } else { 1.0 }, 1.0));
            } else {
                vals.push(Float::NAN);
                grads.push(GradPair::new(1.0, 1.0));
            }
        }
        let x = DMatrix::dense(vals, 40, 1);
        let cuts = HistogramCuts::from_dmatrix(&x, 8, None);
        let qm = Quantizer::new(cuts.clone()).quantize(&x);
        let rows: Vec<u32> = (0..40).collect();
        let mut hist = Histogram::zeros(qm.n_bins);
        build_histogram_quantized(&qm, &grads, &rows, &mut hist);
        let node_sum = grads.iter().fold(GradPairF64::default(), |a, g| {
            a + GradPairF64::from_single(*g)
        });
        let ev = SplitEvaluator::new(TreeParams {
            min_child_weight: 0.0,
            ..Default::default()
        });
        let s = ev.evaluate(&hist, &cuts, node_sum).unwrap();
        assert!(!s.default_left, "missing mass should go right: {s:?}");
    }

    fn categorical_fixture() -> (DMatrix, Vec<GradPair>, HistogramCuts) {
        // codes {0,1,2,3}; {0,2} pull negative, {1,3} positive — only a
        // membership split can separate them cleanly
        let n = 40;
        let mut vals = Vec::new();
        let mut grads = Vec::new();
        for i in 0..n {
            vals.push((i % 4) as Float);
            let g = if i % 4 == 0 || i % 4 == 2 { -1.0 } else { 1.0 };
            grads.push(GradPair::new(g, 1.0));
        }
        let x = DMatrix::dense(vals, n, 1);
        let mut cuts = HistogramCuts::from_dmatrix(&x, 16, None);
        let mut cats = std::collections::BTreeMap::new();
        cats.insert(0usize, vec![0.0 as Float, 1.0, 2.0, 3.0]);
        cuts.apply_categories(&cats);
        (x, grads, cuts)
    }

    #[test]
    fn categorical_membership_split_beats_thresholds() {
        let (x, grads, cuts) = categorical_fixture();
        let qm = Quantizer::new(cuts.clone()).quantize(&x);
        let rows: Vec<u32> = (0..x.n_rows() as u32).collect();
        let mut hist = Histogram::zeros(qm.n_bins);
        build_histogram_quantized(&qm, &grads, &rows, &mut hist);
        let node_sum = grads.iter().fold(GradPairF64::default(), |a, g| {
            a + GradPairF64::from_single(*g)
        });
        let ev = SplitEvaluator::new(TreeParams {
            min_child_weight: 0.0,
            ..Default::default()
        });
        let s = ev.evaluate(&hist, &cuts, node_sum).unwrap();
        assert!(s.is_categorical(), "{s:?}");
        assert!(
            s.categories == 0b0101 || s.categories == 0b1010,
            "left categories {:#06b}",
            s.categories
        );
        assert_eq!(
            s.cat_bins, s.categories,
            "bins mirror values when codes are exactly 0..K"
        );
        let total = s.left_sum + s.right_sum;
        assert!((total.grad - node_sum.grad).abs() < 1e-9);
        assert!((total.hess - node_sum.hess).abs() < 1e-9);

        // the same data split by ordered thresholds is strictly worse
        let ncuts = HistogramCuts::from_dmatrix(&x, 16, None);
        let nqm = Quantizer::new(ncuts.clone()).quantize(&x);
        let mut nhist = Histogram::zeros(nqm.n_bins);
        build_histogram_quantized(&nqm, &grads, &rows, &mut nhist);
        let ns = ev.evaluate(&nhist, &ncuts, node_sum).unwrap();
        assert!(!ns.is_categorical());
        assert!(
            s.gain > ns.gain + 1.0,
            "membership gain {} vs threshold gain {}",
            s.gain,
            ns.gain
        );
    }

    #[test]
    fn monotone_constraint_skips_categorical_feature() {
        let (x, grads, cuts) = categorical_fixture();
        let qm = Quantizer::new(cuts.clone()).quantize(&x);
        let rows: Vec<u32> = (0..x.n_rows() as u32).collect();
        let mut hist = Histogram::zeros(qm.n_bins);
        build_histogram_quantized(&qm, &grads, &rows, &mut hist);
        let node_sum = grads.iter().fold(GradPairF64::default(), |a, g| {
            a + GradPairF64::from_single(*g)
        });
        let ev = SplitEvaluator::new(TreeParams {
            min_child_weight: 0.0,
            monotone_constraints: vec![1],
            ..Default::default()
        });
        assert!(
            ev.evaluate(&hist, &cuts, node_sum).is_none(),
            "categories are unordered — monotone-constrained cat feature must not split"
        );
    }

    #[test]
    fn split_sums_partition_node_sum() {
        let (x, grads) = fixture(3, 200, 4, 0.2);
        let cuts = HistogramCuts::from_dmatrix(&x, 16, None);
        let qm = Quantizer::new(cuts.clone()).quantize(&x);
        let rows: Vec<u32> = (0..200).collect();
        let mut hist = Histogram::zeros(qm.n_bins);
        build_histogram_quantized(&qm, &grads, &rows, &mut hist);
        let node_sum = grads.iter().fold(GradPairF64::default(), |a, g| {
            a + GradPairF64::from_single(*g)
        });
        let ev = SplitEvaluator::new(TreeParams::default());
        let s = ev.evaluate(&hist, &cuts, node_sum).unwrap();
        let total = s.left_sum + s.right_sum;
        assert!((total.grad - node_sum.grad).abs() < 1e-9);
        assert!((total.hess - node_sum.hess).abs() < 1e-9);
    }
}
