//! Array-encoded regression tree (XGBoost `RegTree`).
//!
//! Nodes live in a flat vector; children are indices. The same encoding is
//! exported to the L2 JAX predictor (`python/compile/model.py`) as four
//! parallel arrays (feature, threshold, default_left, children/leaf value),
//! so the Rust structure is the single source of truth for both predictors.

use crate::data::DMatrix;
use crate::Float;

/// Sentinel for "no child".
pub const NO_CHILD: i32 = -1;

/// One tree node. Interior nodes split on `feature < threshold`, or —
/// when `cats != 0` — on category **membership**: bit `c` of `cats` set
/// ⇔ raw value `c` routes left (missing → `default_left` either way);
/// leaves carry `leaf_value` (already scaled by the learning rate at
/// construction time).
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub feature: u32,
    pub threshold: Float,
    pub left: i32,
    pub right: i32,
    pub default_left: bool,
    pub leaf_value: Float,
    /// Loss reduction achieved by this node's split (interior only).
    pub gain: Float,
    /// Sum of hessians of the training rows that reached this node
    /// ("cover" in XGBoost dumps).
    pub cover: Float,
    /// Category-value bitset of a membership split; `0` = threshold
    /// split. Present float values are truncated to their integer code
    /// for the test, so out-of-vocabulary non-integer values share the
    /// routing of their truncation (documented in `lib.rs`); values
    /// outside `[0, 64)` route right.
    pub cats: u64,
}

impl Node {
    pub fn leaf(value: Float, cover: Float) -> Self {
        Node {
            feature: 0,
            threshold: 0.0,
            left: NO_CHILD,
            right: NO_CHILD,
            default_left: true,
            leaf_value: value,
            gain: 0.0,
            cover,
            cats: 0,
        }
    }

    #[inline]
    pub fn is_leaf(&self) -> bool {
        self.left == NO_CHILD
    }
}

/// A regression tree.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RegTree {
    pub nodes: Vec<Node>,
}

impl RegTree {
    /// A single-leaf tree (the state before any split).
    pub fn new_root(value: Float, cover: Float) -> Self {
        RegTree {
            nodes: vec![Node::leaf(value, cover)],
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn n_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_leaf()).count()
    }

    pub fn max_depth(&self) -> usize {
        if self.nodes.is_empty() {
            return 0;
        }
        let mut depth = vec![0usize; self.nodes.len()];
        let mut max = 0;
        for (i, n) in self.nodes.iter().enumerate() {
            if !n.is_leaf() {
                depth[n.left as usize] = depth[i] + 1;
                depth[n.right as usize] = depth[i] + 1;
                max = max.max(depth[i] + 1);
            }
        }
        max
    }

    /// Convert leaf `nid` into an interior node splitting on
    /// `feature < threshold`; returns the `(left, right)` child ids.
    /// Children start as leaves with the provided values/covers.
    #[allow(clippy::too_many_arguments)]
    pub fn apply_split(
        &mut self,
        nid: usize,
        feature: u32,
        threshold: Float,
        default_left: bool,
        gain: Float,
        left_value: Float,
        left_cover: Float,
        right_value: Float,
        right_cover: Float,
    ) -> (usize, usize) {
        assert!(self.nodes[nid].is_leaf(), "can only split a leaf");
        let left = self.nodes.len();
        let right = left + 1;
        self.nodes.push(Node::leaf(left_value, left_cover));
        self.nodes.push(Node::leaf(right_value, right_cover));
        let n = &mut self.nodes[nid];
        n.feature = feature;
        n.threshold = threshold;
        n.default_left = default_left;
        n.gain = gain;
        n.leaf_value = 0.0; // interior nodes carry no leaf value
        n.left = left as i32;
        n.right = right as i32;
        (left, right)
    }

    /// Turn the just-split interior node `nid` into a category-membership
    /// split (bit `c` of `cats` ⇔ raw value `c` routes left). Call right
    /// after [`apply_split`](Self::apply_split) with the candidate's
    /// category bitset; a zero bitset is a no-op (numeric split).
    pub fn set_categories(&mut self, nid: usize, cats: u64) {
        debug_assert!(!self.nodes[nid].is_leaf(), "leaves cannot carry categories");
        self.nodes[nid].cats = cats;
    }

    /// Route one example (by raw feature values) to its leaf; returns the
    /// node id.
    #[inline]
    pub fn leaf_for_row(&self, x: &DMatrix, row: usize) -> usize {
        let mut nid = 0usize;
        loop {
            let n = &self.nodes[nid];
            if n.is_leaf() {
                return nid;
            }
            let go_left = match x.get(row, n.feature as usize) {
                None => n.default_left,
                Some(v) if n.cats != 0 => {
                    v >= 0.0 && v < 64.0 && (n.cats >> (v as u32)) & 1 == 1
                }
                Some(v) => v < n.threshold,
            };
            nid = if go_left { n.left as usize } else { n.right as usize };
        }
    }

    /// Predict the tree output for one row.
    #[inline]
    pub fn predict_row(&self, x: &DMatrix, row: usize) -> Float {
        self.nodes[self.leaf_for_row(x, row)].leaf_value
    }

    /// Dump in an XGBoost-text-like format (docs / debugging).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.dump_node(0, 0, &mut out);
        out
    }

    fn dump_node(&self, nid: usize, indent: usize, out: &mut String) {
        let pad = "  ".repeat(indent);
        let n = &self.nodes[nid];
        if n.is_leaf() {
            out.push_str(&format!("{pad}{nid}:leaf={:.6},cover={:.1}\n", n.leaf_value, n.cover));
        } else {
            if n.cats != 0 {
                let cats: Vec<String> = (0..64)
                    .filter(|c| (n.cats >> c) & 1 == 1)
                    .map(|c| c.to_string())
                    .collect();
                out.push_str(&format!(
                    "{pad}{nid}:[f{} in {{{}}}] yes={},no={},missing={},gain={:.4},cover={:.1}\n",
                    n.feature,
                    cats.join(","),
                    n.left,
                    n.right,
                    if n.default_left { n.left } else { n.right },
                    n.gain,
                    n.cover
                ));
            } else {
                out.push_str(&format!(
                    "{pad}{nid}:[f{}<{:.6}] yes={},no={},missing={},gain={:.4},cover={:.1}\n",
                    n.feature,
                    n.threshold,
                    n.left,
                    n.right,
                    if n.default_left { n.left } else { n.right },
                    n.gain,
                    n.cover
                ));
            }
            self.dump_node(n.left as usize, indent + 1, out);
            self.dump_node(n.right as usize, indent + 1, out);
        }
    }

    /// Export as parallel arrays padded to `max_nodes` (the fixed-shape
    /// encoding consumed by the AOT-compiled L2 predictor; see
    /// `python/compile/model.py::predict_ensemble`).
    pub fn to_arrays(&self, max_nodes: usize) -> TreeArrays {
        assert!(self.nodes.len() <= max_nodes, "tree exceeds artifact capacity");
        assert!(
            self.nodes.iter().all(|n| n.cats == 0),
            "categorical splits are not supported by the array export"
        );
        let mut a = TreeArrays {
            feature: vec![0; max_nodes],
            threshold: vec![0.0; max_nodes],
            left: vec![NO_CHILD; max_nodes],
            right: vec![NO_CHILD; max_nodes],
            default_left: vec![1; max_nodes],
            leaf_value: vec![0.0; max_nodes],
        };
        for (i, n) in self.nodes.iter().enumerate() {
            a.feature[i] = n.feature as i32;
            a.threshold[i] = n.threshold;
            a.left[i] = n.left;
            a.right[i] = n.right;
            a.default_left[i] = n.default_left as i32;
            a.leaf_value[i] = n.leaf_value;
        }
        a
    }
}

/// Fixed-shape parallel-array encoding of a tree (XLA boundary format).
#[derive(Debug, Clone)]
pub struct TreeArrays {
    pub feature: Vec<i32>,
    pub threshold: Vec<Float>,
    pub left: Vec<i32>,
    pub right: Vec<i32>,
    pub default_left: Vec<i32>,
    pub leaf_value: Vec<Float>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DMatrix;

    fn split_tree() -> RegTree {
        // root: f0 < 5 ? left : right; missing -> right
        let mut t = RegTree::new_root(0.0, 10.0);
        t.apply_split(0, 0, 5.0, false, 1.5, -1.0, 6.0, 2.0, 4.0);
        t
    }

    #[test]
    fn root_is_single_leaf() {
        let t = RegTree::new_root(0.5, 3.0);
        assert_eq!(t.n_nodes(), 1);
        assert_eq!(t.n_leaves(), 1);
        assert_eq!(t.max_depth(), 0);
        assert!(t.nodes[0].is_leaf());
    }

    #[test]
    fn apply_split_structure() {
        let t = split_tree();
        assert_eq!(t.n_nodes(), 3);
        assert_eq!(t.n_leaves(), 2);
        assert_eq!(t.max_depth(), 1);
        assert!(!t.nodes[0].is_leaf());
        assert_eq!(t.nodes[0].left, 1);
        assert_eq!(t.nodes[0].right, 2);
    }

    #[test]
    fn routing_with_missing() {
        let t = split_tree();
        let x = DMatrix::dense(vec![3.0, 7.0, Float::NAN], 3, 1);
        assert_eq!(t.predict_row(&x, 0), -1.0); // 3 < 5 -> left
        assert_eq!(t.predict_row(&x, 1), 2.0); // 7 >= 5 -> right
        assert_eq!(t.predict_row(&x, 2), 2.0); // missing -> default right
    }

    #[test]
    fn deeper_routing() {
        let mut t = split_tree();
        // split left child on f1 < 0, missing -> left
        t.apply_split(1, 1, 0.0, true, 0.7, -2.0, 3.0, -0.5, 3.0);
        let x = DMatrix::dense(
            vec![
                3.0, -1.0, // -> left,left
                3.0, 1.0, // -> left,right
                3.0, Float::NAN, // -> left, missing->left
            ],
            3,
            2,
        );
        assert_eq!(t.predict_row(&x, 0), -2.0);
        assert_eq!(t.predict_row(&x, 1), -0.5);
        assert_eq!(t.predict_row(&x, 2), -2.0);
        assert_eq!(t.max_depth(), 2);
        assert_eq!(t.n_leaves(), 3);
    }

    #[test]
    fn categorical_membership_routing() {
        // root: f0 in {1, 3} ? left : right; missing -> right
        let mut t = RegTree::new_root(0.0, 8.0);
        t.apply_split(0, 0, 0.0, false, 2.0, -1.0, 4.0, 2.0, 4.0);
        t.set_categories(0, (1 << 1) | (1 << 3));
        let x = DMatrix::dense(
            vec![1.0, 3.0, 0.0, 2.0, 63.0, -1.0, 64.0, Float::NAN],
            8,
            1,
        );
        assert_eq!(t.predict_row(&x, 0), -1.0); // cat 1 -> left
        assert_eq!(t.predict_row(&x, 1), -1.0); // cat 3 -> left
        assert_eq!(t.predict_row(&x, 2), 2.0); // cat 0 -> right
        assert_eq!(t.predict_row(&x, 3), 2.0); // cat 2 -> right
        assert_eq!(t.predict_row(&x, 4), 2.0); // in-range, not in set
        assert_eq!(t.predict_row(&x, 5), 2.0); // below range -> right
        assert_eq!(t.predict_row(&x, 6), 2.0); // above range -> right
        assert_eq!(t.predict_row(&x, 7), 2.0); // missing -> default right
        let d = t.dump();
        assert!(d.contains("[f0 in {1,3}]"), "{d}");
    }

    #[test]
    #[should_panic(expected = "not supported by the array export")]
    fn to_arrays_rejects_categorical_nodes() {
        let mut t = split_tree();
        t.set_categories(0, 1);
        t.to_arrays(8);
    }

    #[test]
    #[should_panic(expected = "can only split a leaf")]
    fn double_split_panics() {
        let mut t = split_tree();
        t.apply_split(0, 0, 1.0, true, 0.0, 0.0, 1.0, 0.0, 1.0);
    }

    #[test]
    fn dump_contains_structure() {
        let t = split_tree();
        let d = t.dump();
        assert!(d.contains("[f0<5"));
        assert!(d.contains("leaf=-1"));
        assert!(d.contains("leaf=2"));
    }

    #[test]
    fn to_arrays_padding() {
        let t = split_tree();
        let a = t.to_arrays(8);
        assert_eq!(a.feature.len(), 8);
        assert_eq!(a.left[0], 1);
        assert_eq!(a.left[3], NO_CHILD); // padding
        assert_eq!(a.leaf_value[1], -1.0);
    }

    #[test]
    #[should_panic(expected = "exceeds artifact capacity")]
    fn to_arrays_overflow_panics() {
        split_tree().to_arrays(2);
    }
}
