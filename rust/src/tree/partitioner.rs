//! Row partitioner: maintains the mapping from tree leaves to the training
//! rows they contain, and re-sorts rows into child leaves after each split
//! (Algorithm 1's `RepartitionInstances`).
//!
//! Layout mirrors XGBoost's GPU `RowPartitioner`: one flat `row index`
//! array per device shard, with each in-construction leaf owning a
//! contiguous segment. A split stably partitions the node's segment in
//! place (two-cursor pass through a scratch buffer), so child segments
//! stay contiguous — which is what keeps the histogram builder's row reads
//! linear.

use crate::compress::page::PageStore;
use crate::compress::CompressedMatrix;
use crate::exec::{ExecContext, ROW_CHUNK};
use crate::quantile::{HistogramCuts, QuantizedMatrix};
use crate::tree::split::SplitCandidate;

/// Source of quantised bins for routing decisions — the partitioner works
/// identically over the compressed, uncompressed and externally-paged
/// matrix forms.
pub enum BinSource<'a> {
    Quantized(&'a QuantizedMatrix),
    Compressed(&'a CompressedMatrix),
    /// Spilled pages ([`crate::compress::page`]). Reads go through the
    /// store's one-slot row cursor, so a repartition pass over ascending
    /// rows loads each page once and holds **one** page resident; the
    /// chunk-parallel split path is bypassed for this variant (see
    /// [`RowPartitioner::apply_split_par`]) to preserve that bound.
    Paged(&'a PageStore),
}

impl<'a> BinSource<'a> {
    #[inline]
    fn row_stride(&self) -> usize {
        match self {
            BinSource::Quantized(q) => q.row_stride,
            BinSource::Compressed(c) => c.row_stride,
            BinSource::Paged(p) => p.shape.row_stride,
        }
    }

    #[inline]
    fn dense(&self) -> bool {
        match self {
            BinSource::Quantized(q) => q.dense,
            BinSource::Compressed(c) => c.dense,
            BinSource::Paged(p) => p.shape.dense,
        }
    }

    #[inline]
    fn null_symbol(&self) -> u32 {
        match self {
            BinSource::Quantized(q) => q.null_symbol(),
            BinSource::Compressed(c) => c.null_symbol(),
            BinSource::Paged(p) => p.shape.n_bins as u32,
        }
    }

    #[inline]
    fn symbol(&self, flat: usize) -> u32 {
        match self {
            BinSource::Quantized(q) => q.bins[flat],
            BinSource::Compressed(c) => c.symbol(flat),
            BinSource::Paged(_) => unreachable!("paged reads resolve a page first"),
        }
    }

    /// The bin of `(row, feature)`, or None if missing.
    /// Dense layout: direct slot lookup. Sparse ELLPACK: scan the row's
    /// symbols for one inside the feature's global-bin range.
    /// `pub(crate)`: quantised prediction routes through this exact
    /// lookup too ([`crate::predict::quantised`]).
    #[inline]
    pub(crate) fn feature_bin(&self, row: usize, feature: usize, cuts: &HistogramCuts) -> Option<u32> {
        if let BinSource::Paged(store) = self {
            // resolve the row's page once, then read symbols from it.
            // Deliberate panic on I/O failure: the routing API is
            // infallible by design (every in-memory source is), a
            // mid-partition read failure is unrecoverable for the tree
            // anyway, and the expect payload Debug-prints the full
            // anyhow chain (path, page index, checksum detail).
            let page = store
                .page_for_row(row)
                .expect("loading spilled page during repartition");
            let local = row - page.first_row;
            return Self::feature_bin_at(
                |flat| page.matrix.symbol(flat),
                local,
                feature,
                cuts,
                self.row_stride(),
                self.dense(),
                self.null_symbol(),
            );
        }
        Self::feature_bin_at(
            |flat| self.symbol(flat),
            row,
            feature,
            cuts,
            self.row_stride(),
            self.dense(),
            self.null_symbol(),
        )
    }

    /// Shared routing lookup over any symbol reader (in-memory matrices
    /// read at the shard-flat index; pages at the page-local index).
    /// `pub(crate)`: the quantised prediction path
    /// ([`crate::predict::quantised`]) routes with exactly this lookup so
    /// prediction and training repartition can never disagree.
    #[inline]
    pub(crate) fn feature_bin_at(
        symbol: impl Fn(usize) -> u32,
        row: usize,
        feature: usize,
        cuts: &HistogramCuts,
        stride: usize,
        dense: bool,
        null: u32,
    ) -> Option<u32> {
        let base = row * stride;
        if dense {
            let b = symbol(base + feature);
            if b == null {
                None
            } else {
                Some(b)
            }
        } else {
            let lo = cuts.ptrs[feature];
            let hi = cuts.ptrs[feature + 1];
            for s in 0..stride {
                let b = symbol(base + s);
                if b >= lo && b < hi {
                    return Some(b);
                }
                if b == null {
                    break; // padding is trailing
                }
            }
            None
        }
    }
}

/// Contiguous segment of `rows` belonging to one in-construction leaf.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Segment {
    pub begin: usize,
    pub end: usize,
}

impl Segment {
    pub fn len(&self) -> usize {
        self.end - self.begin
    }
    pub fn is_empty(&self) -> bool {
        self.begin == self.end
    }
}

/// Per-shard row partitioner.
#[derive(Debug, Clone)]
pub struct RowPartitioner {
    /// Row indices (local to the shard), grouped by leaf segment.
    rows: Vec<u32>,
    /// `segments[nid]` — the segment of tree node `nid`, if it is a leaf
    /// this shard tracks.
    segments: Vec<Option<Segment>>,
    scratch: Vec<u32>,
    scratch_right: Vec<u32>,
    /// Per-chunk `(left, right)` runs for the chunk-parallel split path —
    /// kept across splits and trees so steady-state repartitions reuse
    /// the same buffers instead of allocating a pair per chunk.
    chunk_scratch: Vec<(Vec<u32>, Vec<u32>)>,
}

impl RowPartitioner {
    /// All `n_rows` rows start in the root node (nid 0).
    pub fn new(n_rows: usize) -> Self {
        Self::from_rows((0..n_rows as u32).collect())
    }

    /// Start from an explicit row subset (e.g. a GOSS sample): all given
    /// rows begin in the root node.
    pub fn from_rows(rows: Vec<u32>) -> Self {
        let n = rows.len();
        RowPartitioner {
            rows,
            segments: vec![Some(Segment { begin: 0, end: n })],
            scratch: Vec::new(),
            scratch_right: Vec::new(),
            chunk_scratch: Vec::new(),
        }
    }

    /// Back to the all-rows-in-root state without dropping a single
    /// allocation — `rows`, `segments`, the stable-partition scratch and
    /// the per-chunk buffers all keep their capacity. This is the
    /// per-tree path in steady-state training ([`DeviceShard::begin_tree`]
    /// calls it every boosting round).
    ///
    /// [`DeviceShard::begin_tree`]: crate::coordinator::DeviceShard::begin_tree
    pub fn reset(&mut self, n_rows: usize) {
        self.rows.clear();
        self.rows.extend(0..n_rows as u32);
        self.segments.clear();
        self.segments.push(Some(Segment {
            begin: 0,
            end: n_rows,
        }));
    }

    /// Rows currently in node `nid` (empty slice if untracked).
    pub fn node_rows(&self, nid: usize) -> &[u32] {
        match self.segments.get(nid).copied().flatten() {
            Some(s) => &self.rows[s.begin..s.end],
            None => &[],
        }
    }

    pub fn node_count(&self, nid: usize) -> usize {
        self.segments
            .get(nid)
            .copied()
            .flatten()
            .map(|s| s.len())
            .unwrap_or(0)
    }

    /// Total rows managed.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Apply `split` of node `nid`, materialising children `left`/`right`:
    /// stably partitions the node's segment so left-going rows precede
    /// right-going rows. Returns `(n_left, n_right)`.
    pub fn apply_split(
        &mut self,
        nid: usize,
        split: &SplitCandidate,
        left: usize,
        right: usize,
        bins: &BinSource<'_>,
        cuts: &HistogramCuts,
    ) -> (usize, usize) {
        self.apply_split_par(nid, split, left, right, bins, cuts, &ExecContext::serial())
    }

    /// Chunk-parallel [`apply_split`](Self::apply_split): the node's
    /// segment is cut into fixed chunks, each chunk stably partitioned on
    /// a worker, and the per-chunk left/right runs concatenated in chunk
    /// order — exactly the serial stable partition, so the resulting row
    /// layout is identical at every thread count.
    #[allow(clippy::too_many_arguments)]
    pub fn apply_split_par(
        &mut self,
        nid: usize,
        split: &SplitCandidate,
        left: usize,
        right: usize,
        bins: &BinSource<'_>,
        cuts: &HistogramCuts,
        exec: &ExecContext,
    ) -> (usize, usize) {
        let seg = self.segments[nid].expect("splitting an untracked node");
        let n = seg.len();
        self.scratch.clear();
        self.scratch_right.clear();
        self.scratch.reserve(n);
        let slice = &self.rows[seg.begin..seg.end];
        // Paged sources route through the store's one-page row cursor;
        // concurrent chunks would thrash it and hold several pages
        // resident at once. The serial pass produces the identical stable
        // layout (pinned by `parallel_split_identical_to_serial`), so
        // paged repartition always runs serially within the shard.
        let paged = matches!(bins, BinSource::Paged(_));
        if exec.threads() <= 1 || n <= ROW_CHUNK || paged {
            // single stable pass: each row's routing decision evaluated once
            for &r in slice {
                if Self::goes_left(r, split, bins, cuts) {
                    self.scratch.push(r);
                } else {
                    self.scratch_right.push(r);
                }
            }
        } else {
            // Per-chunk buffers come from `chunk_scratch` (cleared, not
            // reallocated); chunk boundaries and the chunk-order
            // concatenation below are unchanged, so the layout stays
            // bit-identical to the serial pass.
            let n_chunks = n.div_ceil(ROW_CHUNK);
            if self.chunk_scratch.len() < n_chunks {
                self.chunk_scratch.resize_with(n_chunks, Default::default);
            }
            let parts = &mut self.chunk_scratch[..n_chunks];
            exec.parallel_map_mut(parts, |ci, (l, r)| {
                l.clear();
                r.clear();
                let lo = ci * ROW_CHUNK;
                let hi = (lo + ROW_CHUNK).min(n);
                for &row in &slice[lo..hi] {
                    if Self::goes_left(row, split, bins, cuts) {
                        l.push(row);
                    } else {
                        r.push(row);
                    }
                }
            });
            for (l, _) in parts.iter() {
                self.scratch.extend_from_slice(l);
            }
            for (_, r) in parts.iter() {
                self.scratch_right.extend_from_slice(r);
            }
        }
        let n_left = self.scratch.len();
        self.rows[seg.begin..seg.begin + n_left].copy_from_slice(&self.scratch);
        self.rows[seg.begin + n_left..seg.end].copy_from_slice(&self.scratch_right);
        let mid = seg.begin + n_left;
        if self.segments.len() <= right {
            self.segments.resize(right + 1, None);
        }
        self.segments[nid] = None;
        self.segments[left] = Some(Segment {
            begin: seg.begin,
            end: mid,
        });
        self.segments[right] = Some(Segment {
            begin: mid,
            end: seg.end,
        });
        (n_left, seg.len() - n_left)
    }

    /// Routing decision on quantised data: row goes left iff its bin for
    /// the split feature is `<= split_bin` — or, for a categorical split,
    /// iff the bit of its **local** bin is set in the candidate's
    /// `cat_bins` membership set; missing uses the learned default
    /// direction either way.
    #[inline]
    pub fn goes_left(
        row: u32,
        split: &SplitCandidate,
        bins: &BinSource<'_>,
        cuts: &HistogramCuts,
    ) -> bool {
        match bins.feature_bin(row as usize, split.feature as usize, cuts) {
            Some(b) if split.is_categorical() => {
                let local = b - cuts.ptrs[split.feature as usize];
                debug_assert!(local < 64, "categorical features have at most 64 bins");
                (split.cat_bins >> local) & 1 == 1
            }
            Some(b) => b <= split.split_bin,
            None => split.default_left,
        }
    }

    /// Final leaf assignment of every row: `out[row] = nid`. Used to update
    /// the training predictions cache without re-traversing trees.
    pub fn leaf_of_rows(&self) -> Vec<(usize, &[u32])> {
        self.segments
            .iter()
            .enumerate()
            .filter_map(|(nid, s)| s.map(|seg| (nid, &self.rows[seg.begin..seg.end])))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DMatrix;
    use crate::hist::GradPairF64;
    use crate::quantile::Quantizer;
    use crate::Float;

    fn fixture() -> (QuantizedMatrix, HistogramCuts) {
        // single feature, values 0..16
        let vals: Vec<Float> = (0..16).map(|i| i as Float).collect();
        let x = DMatrix::dense(vals, 16, 1);
        let cuts = HistogramCuts::from_dmatrix(&x, 4, None);
        let qm = Quantizer::new(cuts.clone()).quantize(&x);
        (qm, cuts)
    }

    fn split_at_bin(bin: u32) -> SplitCandidate {
        SplitCandidate {
            feature: 0,
            split_bin: bin,
            threshold: 0.0,
            default_left: false,
            gain: 1.0,
            left_sum: GradPairF64::default(),
            right_sum: GradPairF64::default(),
            categories: 0,
            cat_bins: 0,
        }
    }

    #[test]
    fn initial_root_owns_all() {
        let p = RowPartitioner::new(10);
        assert_eq!(p.node_rows(0).len(), 10);
        assert_eq!(p.node_count(0), 10);
    }

    #[test]
    fn split_partitions_and_preserves_rows() {
        let (qm, cuts) = fixture();
        let mut p = RowPartitioner::new(16);
        let src = BinSource::Quantized(&qm);
        let (nl, nr) = p.apply_split(0, &split_at_bin(1), 1, 2, &src, &cuts);
        assert_eq!(nl + nr, 16);
        assert!(nl > 0 && nr > 0);
        // all rows preserved as a set
        let mut all: Vec<u32> = p.node_rows(1).iter().chain(p.node_rows(2)).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..16).collect::<Vec<u32>>());
        // parent no longer tracked
        assert_eq!(p.node_count(0), 0);
        // left rows all have bin <= 1
        for &r in p.node_rows(1) {
            assert!(qm.get(r as usize, 0).unwrap() <= 1);
        }
        for &r in p.node_rows(2) {
            assert!(qm.get(r as usize, 0).unwrap() > 1);
        }
    }

    #[test]
    fn split_is_stable() {
        let (qm, cuts) = fixture();
        let mut p = RowPartitioner::new(16);
        let src = BinSource::Quantized(&qm);
        p.apply_split(0, &split_at_bin(1), 1, 2, &src, &cuts);
        // within each side, original order preserved (rows ascending here)
        let left = p.node_rows(1).to_vec();
        let mut sorted = left.clone();
        sorted.sort_unstable();
        assert_eq!(left, sorted);
    }

    #[test]
    fn nested_splits_stay_contiguous() {
        let (qm, cuts) = fixture();
        let mut p = RowPartitioner::new(16);
        let src = BinSource::Quantized(&qm);
        p.apply_split(0, &split_at_bin(1), 1, 2, &src, &cuts);
        let n1 = p.node_count(1);
        p.apply_split(1, &split_at_bin(0), 3, 4, &src, &cuts);
        assert_eq!(p.node_count(3) + p.node_count(4), n1);
        for &r in p.node_rows(3) {
            assert_eq!(qm.get(r as usize, 0).unwrap(), 0);
        }
        // node 2 untouched
        assert!(p.node_count(2) > 0);
    }

    #[test]
    fn missing_rows_follow_default() {
        let vals = vec![0.0, Float::NAN, 2.0, Float::NAN];
        let x = DMatrix::dense(vals, 4, 1);
        let cuts = HistogramCuts::from_dmatrix(&x, 4, None);
        let qm = Quantizer::new(cuts.clone()).quantize(&x);
        let src = BinSource::Quantized(&qm);

        let mut split = split_at_bin(0);
        split.default_left = true;
        let mut p = RowPartitioner::new(4);
        p.apply_split(0, &split, 1, 2, &src, &cuts);
        let left: Vec<u32> = p.node_rows(1).to_vec();
        assert!(left.contains(&1) && left.contains(&3), "{left:?}");

        split.default_left = false;
        let mut p = RowPartitioner::new(4);
        p.apply_split(0, &split, 1, 2, &src, &cuts);
        let right: Vec<u32> = p.node_rows(2).to_vec();
        assert!(right.contains(&1) && right.contains(&3), "{right:?}");
    }

    #[test]
    fn compressed_source_matches_quantized() {
        let (qm, cuts) = fixture();
        let cm = crate::compress::CompressedMatrix::from_quantized(&qm);
        let mut p1 = RowPartitioner::new(16);
        let mut p2 = RowPartitioner::new(16);
        p1.apply_split(0, &split_at_bin(2), 1, 2, &BinSource::Quantized(&qm), &cuts);
        p2.apply_split(0, &split_at_bin(2), 1, 2, &BinSource::Compressed(&cm), &cuts);
        assert_eq!(p1.node_rows(1), p2.node_rows(1));
        assert_eq!(p1.node_rows(2), p2.node_rows(2));
    }

    #[test]
    fn sparse_feature_lookup() {
        // CSR with feature 1 present only on some rows
        let x = DMatrix::csr(
            vec![0, 1, 3, 4],
            vec![0, 0, 1, 1],
            vec![1.0, 2.0, 3.0, 4.0],
            3,
            2,
        );
        let cuts = HistogramCuts::from_dmatrix(&x, 4, None);
        let qm = Quantizer::new(cuts.clone()).quantize(&x);
        let src = BinSource::Quantized(&qm);
        // row 0 missing feature 1; rows 1, 2 have it
        assert_eq!(src.feature_bin(0, 1, &cuts), None);
        assert!(src.feature_bin(1, 1, &cuts).is_some());
        assert!(src.feature_bin(2, 1, &cuts).is_some());
        // and feature 0: rows 0,1 present, row 2 missing
        assert!(src.feature_bin(0, 0, &cuts).is_some());
        assert_eq!(src.feature_bin(2, 0, &cuts), None);
    }

    #[test]
    fn parallel_split_identical_to_serial() {
        // big enough for several chunks; interleaved values so both sides
        // of the split are populated in every chunk
        let n = 40_000usize;
        let vals: Vec<Float> = (0..n).map(|i| (i % 64) as Float).collect();
        let x = DMatrix::dense(vals, n, 1);
        let cuts = HistogramCuts::from_dmatrix(&x, 16, None);
        let qm = Quantizer::new(cuts.clone()).quantize(&x);
        let src = BinSource::Quantized(&qm);
        let split = split_at_bin(5);
        let mut serial = RowPartitioner::new(n);
        let (sl, sr) = serial.apply_split(0, &split, 1, 2, &src, &cuts);
        for t in [2usize, 4, 8] {
            let exec = ExecContext::new(t);
            let mut par = RowPartitioner::new(n);
            let (pl, pr) = par.apply_split_par(0, &split, 1, 2, &src, &cuts, &exec);
            assert_eq!((pl, pr), (sl, sr), "threads = {t}");
            assert_eq!(par.node_rows(1), serial.node_rows(1), "threads = {t}");
            assert_eq!(par.node_rows(2), serial.node_rows(2), "threads = {t}");
        }
    }

    #[test]
    fn categorical_split_routes_by_membership() {
        // codes 0..4 cycling over 16 rows; left set = categories {0, 2}
        let vals: Vec<Float> = (0..16).map(|i| (i % 4) as Float).collect();
        let x = DMatrix::dense(vals, 16, 1);
        let mut cuts = HistogramCuts::from_dmatrix(&x, 8, None);
        let mut cm = std::collections::BTreeMap::new();
        cm.insert(0usize, vec![0.0 as Float, 1.0, 2.0, 3.0]);
        cuts.apply_categories(&cm);
        let qm = Quantizer::new(cuts.clone()).quantize(&x);
        let src = BinSource::Quantized(&qm);
        let mut split = split_at_bin(0);
        split.categories = 0b0101;
        split.cat_bins = 0b0101;
        let mut p = RowPartitioner::new(16);
        let (nl, nr) = p.apply_split(0, &split, 1, 2, &src, &cuts);
        assert_eq!((nl, nr), (8, 8));
        for &r in p.node_rows(1) {
            assert!(r % 4 == 0 || r % 4 == 2, "row {r} wrongly left");
        }
        for &r in p.node_rows(2) {
            assert!(r % 4 == 1 || r % 4 == 3, "row {r} wrongly right");
        }
    }

    #[test]
    fn leaf_of_rows_covers_everything() {
        let (qm, cuts) = fixture();
        let mut p = RowPartitioner::new(16);
        let src = BinSource::Quantized(&qm);
        p.apply_split(0, &split_at_bin(1), 1, 2, &src, &cuts);
        p.apply_split(2, &split_at_bin(2), 3, 4, &src, &cuts);
        let leaves = p.leaf_of_rows();
        let total: usize = leaves.iter().map(|(_, rows)| rows.len()).sum();
        assert_eq!(total, 16);
        let nids: Vec<usize> = leaves.iter().map(|(n, _)| *n).collect();
        assert_eq!(nids, vec![1, 3, 4]);
    }
}
