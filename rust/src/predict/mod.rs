//! Native tree-ensemble prediction (paper §2.4).
//!
//! The paper maps prediction to the device with one thread per instance,
//! iterating trees sequentially; the AOT-compiled analogue lives in
//! `python/compile/model.py::predict_ensemble` and is driven by
//! [`crate::runtime::XlaPredictor`]. This module is the Rust reference
//! implementation used by the CPU baselines, by incremental validation
//! scoring inside the booster, and as the parity oracle for the XLA path.

use crate::data::DMatrix;
use crate::exec::{ExecContext, ROW_CHUNK};
use crate::tree::RegTree;
use crate::Float;

pub mod quantised;

/// Accumulate one tree's predictions into `margins` (length n_rows).
pub fn accumulate_tree(tree: &RegTree, x: &DMatrix, margins: &mut [Float]) {
    accumulate_tree_par(tree, x, margins, &ExecContext::serial());
}

/// Chunk-parallel [`accumulate_tree`] — one worker per row chunk (the
/// paper's one-thread-per-instance mapping, batched). Per-row traversal
/// is independent, so results are bit-identical at every thread count.
pub fn accumulate_tree_par(
    tree: &RegTree,
    x: &DMatrix,
    margins: &mut [Float],
    exec: &ExecContext,
) {
    debug_assert_eq!(margins.len(), x.n_rows());
    exec.for_each_slice_mut(margins, ROW_CHUNK, |_, start, chunk| {
        for (k, m) in chunk.iter_mut().enumerate() {
            *m += tree.predict_row(x, start + k);
        }
    });
}

/// Predict raw margins for a forest grouped by output
/// (`trees[output][round]`), starting from `base_score[output]`.
pub fn predict_margins(
    trees: &[Vec<RegTree>],
    base_score: &[Float],
    x: &DMatrix,
) -> Vec<Vec<Float>> {
    predict_margins_par(trees, base_score, x, &ExecContext::serial())
}

/// Chunk-parallel [`predict_margins`]; bit-identical to the serial path.
/// Rows are chunked once per output group and each worker iterates the
/// whole forest for its rows (per-row tree order unchanged), rather than
/// paying a pool dispatch per tree.
pub fn predict_margins_par(
    trees: &[Vec<RegTree>],
    base_score: &[Float],
    x: &DMatrix,
    exec: &ExecContext,
) -> Vec<Vec<Float>> {
    let n = x.n_rows();
    let mut out: Vec<Vec<Float>> = base_score.iter().map(|&b| vec![b; n]).collect();
    for (k, group) in trees.iter().enumerate() {
        exec.for_each_slice_mut(&mut out[k], ROW_CHUNK, |_, start, chunk| {
            for (i, m) in chunk.iter_mut().enumerate() {
                let row = start + i;
                for tree in group {
                    *m += tree.predict_row(x, row);
                }
            }
        });
    }
    out
}

/// Leaf indices for every row of every tree of one output group — the
/// `pred_leaf` debugging/feature-engineering output XGBoost exposes.
pub fn predict_leaf_indices(trees: &[RegTree], x: &DMatrix) -> Vec<Vec<u32>> {
    predict_leaf_indices_par(trees, x, &ExecContext::serial())
}

/// Chunk-parallel [`predict_leaf_indices`] on the exec engine — per-row
/// traversal is independent, so results are bit-identical at every
/// thread count (the `threads` knob finally applies to this path too).
pub fn predict_leaf_indices_par(
    trees: &[RegTree],
    x: &DMatrix,
    exec: &ExecContext,
) -> Vec<Vec<u32>> {
    trees
        .iter()
        .map(|t| {
            let mut out = vec![0u32; x.n_rows()];
            exec.for_each_slice_mut(&mut out, ROW_CHUNK, |_, start, chunk| {
                for (k, o) in chunk.iter_mut().enumerate() {
                    *o = t.leaf_for_row(x, start + k) as u32;
                }
            });
            out
        })
        .collect()
}

/// FNV-1a 64 over the predictions' bit patterns — the cross-path parity
/// fingerprint the CLI prints (`predict`/`eval`) so CI can require the
/// float, streaming-quantised and paged-quantised paths to agree to the
/// last bit without diffing whole prediction files.
pub fn prediction_checksum(preds: &[Float]) -> u64 {
    crate::compress::page::fnv1a64(preds.iter().flat_map(|p| p.to_bits().to_le_bytes()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DMatrix;

    fn stump(threshold: Float, left: Float, right: Float) -> RegTree {
        let mut t = RegTree::new_root(0.0, 1.0);
        t.apply_split(0, 0, threshold, true, 1.0, left, 1.0, right, 1.0);
        t
    }

    #[test]
    fn accumulate_sums_trees() {
        let x = DMatrix::dense(vec![0.0, 10.0], 2, 1);
        let t1 = stump(5.0, -1.0, 1.0);
        let t2 = stump(5.0, -2.0, 2.0);
        let m = predict_margins(&[vec![t1, t2]], &[0.5], &x);
        assert_eq!(m[0], vec![0.5 - 3.0, 0.5 + 3.0]);
    }

    #[test]
    fn multi_output_groups_are_independent() {
        let x = DMatrix::dense(vec![0.0, 10.0], 2, 1);
        let m = predict_margins(
            &[vec![stump(5.0, -1.0, 1.0)], vec![stump(5.0, 7.0, 8.0)]],
            &[0.0, 100.0],
            &x,
        );
        assert_eq!(m[0], vec![-1.0, 1.0]);
        assert_eq!(m[1], vec![107.0, 108.0]);
    }

    #[test]
    fn empty_forest_returns_base() {
        let x = DMatrix::dense(vec![1.0, 2.0, 3.0], 3, 1);
        let m = predict_margins(&[vec![]], &[0.25], &x);
        assert_eq!(m[0], vec![0.25; 3]);
    }

    #[test]
    fn leaf_indices_route_correctly() {
        let x = DMatrix::dense(vec![0.0, 10.0], 2, 1);
        let t = stump(5.0, -1.0, 1.0);
        let li = predict_leaf_indices(&[t], &x);
        assert_eq!(li[0], vec![1, 2]);
    }

    #[test]
    fn leaf_indices_bit_identical_across_threads() {
        // enough rows for several ROW_CHUNK chunks so the parallel path
        // actually engages; values interleave both sides of the splits
        let n = 20_000usize;
        let vals: Vec<Float> = (0..n).map(|i| (i % 17) as Float).collect();
        let x = DMatrix::dense(vals, n, 1);
        let trees = vec![stump(5.0, -1.0, 1.0), stump(11.0, 0.5, -0.5)];
        let serial = predict_leaf_indices(&trees, &x);
        for t in [1usize, 2, 8] {
            let par = predict_leaf_indices_par(&trees, &x, &ExecContext::new(t));
            assert_eq!(par, serial, "threads = {t}");
        }
    }

    #[test]
    fn checksum_is_bit_sensitive() {
        let a = prediction_checksum(&[1.0, 2.0, 3.0]);
        let b = prediction_checksum(&[1.0, 2.0, 3.0000001]);
        assert_ne!(a, b);
        assert_eq!(a, prediction_checksum(&[1.0, 2.0, 3.0]));
        // 0.0 and -0.0 compare equal but are different predictions bytes
        assert_ne!(prediction_checksum(&[0.0]), prediction_checksum(&[-0.0]));
    }
}
