//! Quantised (compressed-representation) prediction — the last phase of
//! the paper's "prediction, gradient calculation, feature quantisation,
//! decision tree construction and evaluation phases all computed on
//! device" claim (§1, §2.2) to come off the float matrix.
//!
//! After out-of-core ingestion (PR 3) and external-memory training
//! (PR 4), the packed ELLPACK shards are the only full-size
//! representation of the data — but the float prediction path
//! ([`crate::predict::predict_margins_par`]) still walks a raw
//! [`DMatrix`], capping inference at host RAM. This module removes that
//! dependency: trained trees are translated once into **bin-threshold
//! form** and traversed directly over the quantised symbols, whether they
//! live in a [`QuantizedMatrix`], a bit-packed [`CompressedMatrix`], a
//! spilled [`PageStore`] (streamed back with the same double-buffered
//! prefetch and `max_resident_pages` budget as training), or a transient
//! [`QuantisedBatch`] quantised on the fly from a streaming
//! [`BatchSource`].
//!
//! # The bin-vs-float equivalence argument
//!
//! Splits are chosen *at cut values*: `SplitCandidate::threshold` is
//! always `cuts.cut_of_bin(split_bin)` (see `tree/split.rs`), so a float
//! comparison `v < t` can be translated exactly into bin space. Let
//! `cuts_f` be feature `f`'s ascending cut values and define
//!
//! ```text
//! threshold_to_bin(f, t) = ptrs[f] + |{c in cuts_f : c <= t}|
//! bin(v)                 = ptrs[f] + |{c in cuts_f : c <= v}|   (unclamped)
//! ```
//!
//! Then for `t = cuts_f[j]` (every trained threshold):
//! `v < t  ⇔  every cut ≤ v is one of cuts_f[0..j]  ⇔  bin(v) < ptrs[f]+j+1
//! = threshold_to_bin(f, t)` — for **every** real `v`, including values
//! beyond the training range. Missing values carry no bin and take the
//! learned default direction in both representations. So routing a row by
//! `bin < threshold_to_bin(t)` visits exactly the nodes the float
//! traversal visits, and the two predictions are **bit-identical**
//! (`rust/tests/compressed_predict.rs`; the translation round-trip is a
//! property test in `prop_invariants.rs`).
//!
//! The packed storages use the *clamped* bin index (the alphabet has no
//! overflow symbol), which is the same function as `bin(v)` for every
//! value below the feature's sentinel cut — true of all data the cuts
//! were built from, i.e. of every training shard. Transient prediction
//! batches ([`QuantisedBatch`]) are never packed, so they keep the
//! unclamped index and stay exact even for out-of-range inputs.
//!
//! Note the routing rule `bin <= split_bin` used by the training
//! repartitioner ([`crate::tree::RowPartitioner::goes_left`]) is the same
//! predicate: `threshold_to_bin(cut_of_bin(split_bin)) = split_bin + 1`.
//!
//! # Memory contracts
//!
//! * **Resident packed shards** — prediction reads the packed words in
//!   place; no decode buffer beyond one node lookup at a time.
//! * **Paged shards** — pages stream back in index order through the same
//!   prefetch-worker/bounded-channel pipeline as the paged histogram
//!   build; resident packed bytes never exceed
//!   `max_resident_pages × page_bytes` and the load/wait seconds land in
//!   the store's round counters.
//! * **Streaming prediction** ([`stream_margins`]) — one pull over the
//!   source; each batch is quantised against the frozen cuts into a
//!   transient [`QuantisedBatch`] and scored batch-at-a-time, so peak
//!   transient bytes are O(`batch_rows × n_cols`) (measured:
//!   [`StreamedMargins::peak_transient_bytes`]).

use anyhow::{ensure, Context, Result};

use crate::compress::page::{PageHandle, PagedMatrixBuilder, PageStore, SPILL_DIR_PREFIX};
use crate::compress::CompressedMatrix;
use crate::data::loader::groups_from_qids;
use crate::data::source::BatchSource;
use crate::data::DMatrix;
use crate::exec::{ExecContext, KernelMode, BLOCK_ROWS, ROW_CHUNK};
use crate::quantile::{HistogramCuts, QuantizedMatrix};
use crate::tree::partitioner::BinSource;
use crate::tree::regtree::NO_CHILD;
use crate::tree::RegTree;
use crate::Float;

/// Translate a float split threshold into its **exclusive upper global
/// bin**: a present row goes left iff its (unclamped) global bin is
/// `< threshold_to_bin(cuts, f, t)`. See the module docs for the
/// exactness argument; for trained trees (`t == cut_of_bin(split_bin)`)
/// this returns `split_bin + 1`, i.e. the repartitioner's
/// `bin <= split_bin` rule. Thresholds below the feature's first cut
/// return `ptrs[f]` (nothing present goes left); thresholds above the
/// sentinel return `ptrs[f + 1]` (everything present goes left).
#[inline]
pub fn threshold_to_bin(cuts: &HistogramCuts, feature: usize, threshold: Float) -> u32 {
    // deliberately the SAME function that quantises prediction values
    // (`|{cuts ≤ x}|` in the feature's range): the whole equivalence
    // proof rests on threshold and value passing through one mapping
    cuts.bin_index_unclamped(feature, threshold)
}

/// One node of a bin-translated tree. Interior nodes route on
/// `feature`'s global bin: present rows go left iff `bin < split` — or,
/// for membership nodes (`cats != 0`), iff the bit of the row's **local**
/// bin (`bin − split`, with `split` repurposed as the feature's first
/// global bin `ptrs[f]`) is set in `cats` (missing → `default_left`
/// either way); leaves carry `leaf_value` unchanged from the source
/// [`RegTree`].
#[derive(Debug, Clone, PartialEq)]
pub struct BinNode {
    pub feature: u32,
    /// Exclusive upper global bin of the left subtree
    /// ([`threshold_to_bin`] of the float threshold). For membership
    /// nodes this instead holds `cuts.ptrs[feature]`, the offset that
    /// turns the row's global bin into the local bit index.
    pub split: u32,
    pub left: i32,
    pub right: i32,
    pub default_left: bool,
    pub leaf_value: Float,
    /// Local-bin membership bitset of a categorical split (`0` = numeric
    /// threshold node). Translated from the tree node's category-value
    /// bitset via [`HistogramCuts::category_of_local_bin`] at
    /// construction, so bin routing and float routing agree exactly on
    /// every in-vocabulary value.
    pub cats: u64,
}

impl BinNode {
    #[inline]
    pub fn is_leaf(&self) -> bool {
        self.left == NO_CHILD
    }
}

/// A [`RegTree`] with every float threshold translated to bin space
/// against a fixed set of cuts — same node ids, same shape, bit-identical
/// routing (module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct BinTree {
    pub nodes: Vec<BinNode>,
}

impl BinTree {
    /// Translate `tree` against `cuts`. O(n_nodes); done once per tree,
    /// amortised over every row scored.
    pub fn from_tree(tree: &RegTree, cuts: &HistogramCuts) -> Self {
        BinTree {
            nodes: tree
                .nodes
                .iter()
                .map(|n| {
                    let (split, cats) = if n.is_leaf() {
                        (0, 0)
                    } else if n.cats != 0 {
                        // translate the category-VALUE bitset into the
                        // feature's local-BIN bitset against these cuts
                        let f = n.feature as usize;
                        let mut bits = 0u64;
                        for i in 0..cuts.feature_bins(f) {
                            let c = cuts.category_of_local_bin(f, i);
                            if c >= 0.0 && c < 64.0 && (n.cats >> (c as u32)) & 1 == 1 {
                                bits |= 1 << i;
                            }
                        }
                        (cuts.ptrs[f], bits)
                    } else {
                        (threshold_to_bin(cuts, n.feature as usize, n.threshold), 0)
                    };
                    BinNode {
                        feature: n.feature,
                        split,
                        left: n.left,
                        right: n.right,
                        default_left: n.default_left,
                        leaf_value: n.leaf_value,
                        cats,
                    }
                })
                .collect(),
        }
    }

    /// Route one row to its leaf; `lookup(feature)` returns the row's
    /// global bin for that feature (`None` = missing). Returns the node
    /// id — identical to [`RegTree::leaf_for_row`] on the raw values.
    #[inline]
    pub fn leaf_for(&self, mut lookup: impl FnMut(usize) -> Option<u32>) -> usize {
        let mut nid = 0usize;
        loop {
            let n = &self.nodes[nid];
            if n.is_leaf() {
                return nid;
            }
            let go_left = match lookup(n.feature as usize) {
                Some(b) if n.cats != 0 => {
                    let local = b.wrapping_sub(n.split);
                    local < 64 && (n.cats >> local) & 1 == 1
                }
                Some(b) => b < n.split,
                None => n.default_left,
            };
            nid = if go_left { n.left as usize } else { n.right as usize };
        }
    }

    /// Leaf value for one row (see [`leaf_for`](Self::leaf_for)).
    #[inline]
    pub fn leaf_value_for(&self, lookup: impl FnMut(usize) -> Option<u32>) -> Float {
        self.nodes[self.leaf_for(lookup)].leaf_value
    }
}

/// A whole ensemble translated to bin space, grouped by output exactly
/// like `Booster::trees` (`groups[output][round]`).
#[derive(Debug, Clone)]
pub struct BinForest {
    pub groups: Vec<Vec<BinTree>>,
}

impl BinForest {
    pub fn from_trees(trees: &[Vec<RegTree>], cuts: &HistogramCuts) -> Self {
        BinForest {
            groups: trees
                .iter()
                .map(|g| g.iter().map(|t| BinTree::from_tree(t, cuts)).collect())
                .collect(),
        }
    }

    /// Flatten into the serving-side SoA arena
    /// ([`crate::serve::FlatForest`]): same routing bit for bit, laid
    /// out for traversal latency instead of translation convenience —
    /// the serving stack's entry point into this module's equivalence
    /// chain.
    pub fn flatten(&self) -> Result<crate::serve::FlatForest> {
        crate::serve::FlatForest::from_bin_forest(self)
    }
}

/// A block of rows whose bins can be looked up by block-local index —
/// the abstraction the blocked traversal walks over. `prime` prepares
/// rows `[row0, row0 + n)` (`n <= BLOCK_ROWS`); `bin(i, f)` answers for
/// block-local row `i`. Implementations either pass lookups through
/// ([`PlainBins`]) or batch the expensive part per block
/// ([`DecodedBins`] unpacks a compressed block's symbols exactly once).
trait BlockBins {
    fn prime(&mut self, row0: usize, n: usize);
    fn bin(&self, i: usize, f: usize) -> Option<u32>;
}

/// Pass-through [`BlockBins`] over any per-row lookup: `prime` just
/// records the block origin.
struct PlainBins<'a, L> {
    lookup: &'a L,
    row0: usize,
}

impl<L: Fn(usize, usize) -> Option<u32>> BlockBins for PlainBins<'_, L> {
    #[inline]
    fn prime(&mut self, row0: usize, _n: usize) {
        self.row0 = row0;
    }
    #[inline]
    fn bin(&self, i: usize, f: usize) -> Option<u32> {
        (self.lookup)(self.row0 + i, f)
    }
}

/// [`BlockBins`] over a bit-packed shard: `prime` runs the multi-symbol
/// block decoder ([`CompressedMatrix::decode_rows_block`]) once per
/// block — each packed word read once — and every tree-level lookup is
/// then served from the scratch buffer instead of re-unpacking the same
/// symbols per node visit. Routing is identical to the per-symbol path
/// because the decoder is pinned symbol-for-symbol against it.
struct DecodedBins<'a> {
    cm: &'a CompressedMatrix,
    cuts: &'a HistogramCuts,
    scratch: Vec<u32>,
    stride: usize,
    dense: bool,
    null: u32,
}

impl<'a> DecodedBins<'a> {
    fn new(cm: &'a CompressedMatrix, cuts: &'a HistogramCuts) -> Self {
        let stride = cm.row_stride;
        DecodedBins {
            cm,
            cuts,
            scratch: vec![0u32; BLOCK_ROWS * stride],
            stride,
            dense: cm.dense,
            null: cm.n_bins as u32,
        }
    }
}

impl BlockBins for DecodedBins<'_> {
    #[inline]
    fn prime(&mut self, row0: usize, n: usize) {
        self.cm
            .decode_rows_block(row0, n, &mut self.scratch[..n * self.stride]);
    }
    #[inline]
    fn bin(&self, i: usize, f: usize) -> Option<u32> {
        BinSource::feature_bin_at(
            |flat| self.scratch[flat],
            i,
            f,
            self.cuts,
            self.stride,
            self.dense,
            self.null,
        )
    }
}

/// Route a block of `n` rows to their leaves, one tree **level** at a
/// time (the `serve/flat.rs::predict_margins` pattern): every sweep
/// advances each still-interior row by one level with a branchless child
/// select, so the per-row node sequence — and therefore the leaf —
/// is exactly what [`BinTree::leaf_for`] visits row-at-a-time.
fn walk_block<B: BlockBins>(tree: &BinTree, bins: &B, n: usize, nid: &mut [u32; BLOCK_ROWS]) {
    nid[..n].fill(0);
    if tree.nodes[0].is_leaf() {
        return;
    }
    loop {
        let mut any = false;
        for i in 0..n {
            let node = &tree.nodes[nid[i] as usize];
            if node.is_leaf() {
                continue;
            }
            any = true;
            let go_left = match bins.bin(i, node.feature as usize) {
                Some(b) if node.cats != 0 => {
                    let local = b.wrapping_sub(node.split);
                    local < 64 && (node.cats >> local) & 1 == 1
                }
                Some(b) => b < node.split,
                None => node.default_left,
            };
            let child = [node.right, node.left];
            nid[i] = child[go_left as usize] as u32;
        }
        if !any {
            return;
        }
    }
}

/// Blocked twin of [`margins_with_lookup`]'s inner loop: rows advance in
/// `BLOCK_ROWS` groups, each primed once and walked level-synchronously
/// per tree. Per output slot the f32 adds still run in forest tree
/// order starting from the base score — the identical chain the scalar
/// path builds — so the result is bit-identical at every thread count.
fn margins_blocked<B, M>(
    forest: &BinForest,
    base_score: &[Float],
    n_rows: usize,
    make: &M,
    exec: &ExecContext,
) -> Vec<Vec<Float>>
where
    B: BlockBins,
    M: Fn() -> B + Sync,
{
    let mut out: Vec<Vec<Float>> = base_score.iter().map(|&b| vec![b; n_rows]).collect();
    for (k, group) in forest.groups.iter().enumerate() {
        exec.for_each_slice_mut(&mut out[k], ROW_CHUNK, |_, start, chunk| {
            let mut bins = make();
            let mut nid = [0u32; BLOCK_ROWS];
            let mut lo = 0usize;
            while lo < chunk.len() {
                let n = BLOCK_ROWS.min(chunk.len() - lo);
                bins.prime(start + lo, n);
                for tree in group {
                    walk_block(tree, &bins, n, &mut nid);
                    for (i, m) in chunk[lo..lo + n].iter_mut().enumerate() {
                        *m += tree.nodes[nid[i] as usize].leaf_value;
                    }
                }
                lo += n;
            }
        });
    }
    out
}

/// Blocked twin of [`leaf_indices_with_lookup`] — pure index writes, so
/// equivalence needs only the per-row routing argument of
/// [`walk_block`].
fn leaf_indices_blocked<B, M>(
    trees: &[BinTree],
    n_rows: usize,
    make: &M,
    exec: &ExecContext,
) -> Vec<Vec<u32>>
where
    B: BlockBins,
    M: Fn() -> B + Sync,
{
    trees
        .iter()
        .map(|t| {
            let mut out = vec![0u32; n_rows];
            exec.for_each_slice_mut(&mut out, ROW_CHUNK, |_, start, chunk| {
                let mut bins = make();
                let mut nid = [0u32; BLOCK_ROWS];
                let mut lo = 0usize;
                while lo < chunk.len() {
                    let n = BLOCK_ROWS.min(chunk.len() - lo);
                    bins.prime(start + lo, n);
                    walk_block(t, &bins, n, &mut nid);
                    chunk[lo..lo + n].copy_from_slice(&nid[..n]);
                    lo += n;
                }
            });
            out
        })
        .collect()
}

/// Chunk-parallel margin accumulation over any per-row bin lookup — the
/// quantised twin of [`crate::predict::predict_margins_par`]: rows are
/// chunked once per output group, each worker iterates the whole forest
/// for its rows in tree order, so the floating-point accumulation
/// bracketing (and therefore every bit of the result) is identical to
/// the float path at every thread count. In the default
/// [`KernelMode::Blocked`] the rows advance through each tree in
/// level-synchronous `BLOCK_ROWS` groups (bit-identical — see
/// [`margins_blocked`]); `XGB_SCALAR_KERNELS=1` keeps the row-at-a-time
/// reference walk.
fn margins_with_lookup<L>(
    forest: &BinForest,
    base_score: &[Float],
    n_rows: usize,
    lookup: &L,
    exec: &ExecContext,
) -> Vec<Vec<Float>>
where
    L: Fn(usize, usize) -> Option<u32> + Sync,
{
    if KernelMode::from_env() == KernelMode::Blocked {
        return margins_blocked(forest, base_score, n_rows, &|| PlainBins { lookup, row0: 0 }, exec);
    }
    let mut out: Vec<Vec<Float>> = base_score.iter().map(|&b| vec![b; n_rows]).collect();
    for (k, group) in forest.groups.iter().enumerate() {
        exec.for_each_slice_mut(&mut out[k], ROW_CHUNK, |_, start, chunk| {
            for (i, m) in chunk.iter_mut().enumerate() {
                let row = start + i;
                for tree in group {
                    *m += tree.leaf_value_for(|f| lookup(row, f));
                }
            }
        });
    }
    out
}

/// Leaf indices (one vec per tree) over any per-row bin lookup — the
/// quantised twin of [`crate::predict::predict_leaf_indices_par`].
fn leaf_indices_with_lookup<L>(
    trees: &[BinTree],
    n_rows: usize,
    lookup: &L,
    exec: &ExecContext,
) -> Vec<Vec<u32>>
where
    L: Fn(usize, usize) -> Option<u32> + Sync,
{
    if KernelMode::from_env() == KernelMode::Blocked {
        return leaf_indices_blocked(trees, n_rows, &|| PlainBins { lookup, row0: 0 }, exec);
    }
    trees
        .iter()
        .map(|t| {
            let mut out = vec![0u32; n_rows];
            exec.for_each_slice_mut(&mut out, ROW_CHUNK, |_, start, chunk| {
                for (i, o) in chunk.iter_mut().enumerate() {
                    let row = start + i;
                    *o = t.leaf_for(|f| lookup(row, f)) as u32;
                }
            });
            out
        })
        .collect()
}

/// Margins straight from an uncompressed quantised shard.
pub fn predict_margins_quantized(
    forest: &BinForest,
    base_score: &[Float],
    qm: &QuantizedMatrix,
    cuts: &HistogramCuts,
    exec: &ExecContext,
) -> Vec<Vec<Float>> {
    let src = BinSource::Quantized(qm);
    margins_with_lookup(
        forest,
        base_score,
        qm.n_rows,
        &|row, f| src.feature_bin(row, f, cuts),
        exec,
    )
}

/// Margins straight from a bit-packed shard (§2.2): symbols unpack
/// during traversal; the float matrix never exists. In the default
/// blocked mode each `BLOCK_ROWS` block's symbols decode **once**
/// through the multi-symbol unpacker and every tree-level lookup reads
/// the scratch buffer; `XGB_SCALAR_KERNELS=1` unpacks per node visit.
pub fn predict_margins_compressed(
    forest: &BinForest,
    base_score: &[Float],
    cm: &CompressedMatrix,
    cuts: &HistogramCuts,
    exec: &ExecContext,
) -> Vec<Vec<Float>> {
    if KernelMode::from_env() == KernelMode::Blocked {
        return margins_blocked(
            forest,
            base_score,
            cm.n_rows,
            &|| DecodedBins::new(cm, cuts),
            exec,
        );
    }
    let src = BinSource::Compressed(cm);
    margins_with_lookup(
        forest,
        base_score,
        cm.n_rows,
        &|row, f| src.feature_bin(row, f, cuts),
        exec,
    )
}

/// Leaf indices from an uncompressed quantised shard.
pub fn leaf_indices_quantized(
    trees: &[BinTree],
    qm: &QuantizedMatrix,
    cuts: &HistogramCuts,
    exec: &ExecContext,
) -> Vec<Vec<u32>> {
    let src = BinSource::Quantized(qm);
    leaf_indices_with_lookup(trees, qm.n_rows, &|row, f| src.feature_bin(row, f, cuts), exec)
}

/// Leaf indices from a bit-packed shard (block-decoded like
/// [`predict_margins_compressed`]).
pub fn leaf_indices_compressed(
    trees: &[BinTree],
    cm: &CompressedMatrix,
    cuts: &HistogramCuts,
    exec: &ExecContext,
) -> Vec<Vec<u32>> {
    if KernelMode::from_env() == KernelMode::Blocked {
        return leaf_indices_blocked(trees, cm.n_rows, &|| DecodedBins::new(cm, cuts), exec);
    }
    let src = BinSource::Compressed(cm);
    leaf_indices_with_lookup(trees, cm.n_rows, &|row, f| src.feature_bin(row, f, cuts), exec)
}

/// Walk every page of a spilled shard in index order, feeding each
/// resident page to `visit` — prediction's use of the shared prefetch
/// pipeline [`crate::compress::page::with_prefetched_pages`] (the same
/// worker/bounded-channel scheme and `max_resident_pages` accounting as
/// the paged histogram build; load and blocked-wait seconds land on the
/// store's round counters).
fn walk_pages<F>(store: &PageStore, exec: &ExecContext, mut visit: F) -> Result<()>
where
    F: FnMut(&PageHandle) -> Result<()> + Send,
{
    let n = store.n_pages();
    crate::compress::page::with_prefetched_pages(store, exec, (0..n).collect(), move |fetch| {
        for want in 0..n {
            let page = fetch(want)?;
            visit(&page)?;
        }
        Ok(())
    })
}

/// Margins from an external-memory shard: pages stream back in order
/// under the residency budget; per-row traversal (and so every result
/// bit) is identical to the resident compressed path — paging only
/// changes where the packed words come from.
pub fn predict_margins_paged(
    forest: &BinForest,
    base_score: &[Float],
    store: &PageStore,
    cuts: &HistogramCuts,
    exec: &ExecContext,
) -> Result<Vec<Vec<Float>>> {
    let n = store.n_rows();
    let mut out: Vec<Vec<Float>> = base_score.iter().map(|&b| vec![b; n]).collect();
    if KernelMode::from_env() == KernelMode::Blocked {
        // blocked walk over each resident page: per output slot the f32
        // adds still run in forest tree order from the base score, so
        // the result matches the scalar page walk bit for bit
        walk_pages(store, exec, |page| {
            let m = &page.matrix;
            let mut bins = DecodedBins::new(m, cuts);
            let mut nid = [0u32; BLOCK_ROWS];
            let mut lo = 0usize;
            while lo < m.n_rows {
                let nb = BLOCK_ROWS.min(m.n_rows - lo);
                bins.prime(lo, nb);
                for (k, group) in forest.groups.iter().enumerate() {
                    for tree in group {
                        walk_block(tree, &bins, nb, &mut nid);
                        for (i, &id) in nid[..nb].iter().enumerate() {
                            out[k][page.first_row + lo + i] += tree.nodes[id as usize].leaf_value;
                        }
                    }
                }
                lo += nb;
            }
            Ok(())
        })?;
        return Ok(out);
    }
    let (stride, dense, null) = (
        store.shape.row_stride,
        store.shape.dense,
        store.shape.n_bins as u32,
    );
    walk_pages(store, exec, |page| {
        let m = &page.matrix;
        for local in 0..m.n_rows {
            let row = page.first_row + local;
            for (k, group) in forest.groups.iter().enumerate() {
                let slot = &mut out[k][row];
                for tree in group {
                    *slot += tree.leaf_value_for(|f| {
                        BinSource::feature_bin_at(
                            |flat| m.symbol(flat),
                            local,
                            f,
                            cuts,
                            stride,
                            dense,
                            null,
                        )
                    });
                }
            }
        }
        Ok(())
    })?;
    Ok(out)
}

/// Leaf indices from an external-memory shard (same page walk as
/// [`predict_margins_paged`]).
pub fn leaf_indices_paged(
    trees: &[BinTree],
    store: &PageStore,
    cuts: &HistogramCuts,
    exec: &ExecContext,
) -> Result<Vec<Vec<u32>>> {
    let n = store.n_rows();
    let mut out: Vec<Vec<u32>> = trees.iter().map(|_| vec![0u32; n]).collect();
    if KernelMode::from_env() == KernelMode::Blocked {
        walk_pages(store, exec, |page| {
            let m = &page.matrix;
            let mut bins = DecodedBins::new(m, cuts);
            let mut nid = [0u32; BLOCK_ROWS];
            let mut lo = 0usize;
            while lo < m.n_rows {
                let nb = BLOCK_ROWS.min(m.n_rows - lo);
                bins.prime(lo, nb);
                for (t, tree) in trees.iter().enumerate() {
                    walk_block(tree, &bins, nb, &mut nid);
                    let row0 = page.first_row + lo;
                    out[t][row0..row0 + nb].copy_from_slice(&nid[..nb]);
                }
                lo += nb;
            }
            Ok(())
        })?;
        return Ok(out);
    }
    let (stride, dense, null) = (
        store.shape.row_stride,
        store.shape.dense,
        store.shape.n_bins as u32,
    );
    walk_pages(store, exec, |page| {
        let m = &page.matrix;
        for local in 0..m.n_rows {
            let row = page.first_row + local;
            for (t, tree) in trees.iter().enumerate() {
                out[t][row] = tree.leaf_for(|f| {
                    BinSource::feature_bin_at(
                        |flat| m.symbol(flat),
                        local,
                        f,
                        cuts,
                        stride,
                        dense,
                        null,
                    )
                }) as u32;
            }
        }
        Ok(())
    })?;
    Ok(out)
}

/// Missing marker of the transient dense quantised layout (never packed,
/// so the marker need not fit the packed alphabet).
const MISSING: u32 = u32::MAX;

/// A transient, **unclamped** quantised batch for prediction: global bin
/// per present value (`bin_index_unclamped`, so out-of-range values keep
/// the information the clamped packed form drops — module docs), with
/// dense rows as one bin per slot and sparse rows as explicit
/// `(col, bin)` pairs. O(`n_rows × n_cols`) u32s; lives only as long as
/// one streamed batch.
pub enum QuantisedBatch {
    Dense {
        /// `bins[row * n_cols + f]`; `u32::MAX` marks absent values.
        bins: Vec<u32>,
        n_rows: usize,
        n_cols: usize,
    },
    Sparse {
        indptr: Vec<usize>,
        /// Column index per present value (ascending within a row).
        cols: Vec<u32>,
        /// Unclamped global bin per present value.
        bins: Vec<u32>,
        n_rows: usize,
    },
}

impl QuantisedBatch {
    /// Quantise a float matrix against frozen cuts. `col_shift` is
    /// subtracted from raw column indices (1 for 1-based LibSVM streams,
    /// 0 otherwise — the same convention as ingestion's
    /// [`crate::data::IngestMeta::col_shift`]).
    pub fn from_dmatrix(x: &DMatrix, cuts: &HistogramCuts, col_shift: u32) -> Result<Self> {
        let n_features = cuts.n_features();
        let shift = col_shift as usize;
        match x {
            DMatrix::Dense { .. } => {
                let n_cols = x.n_cols();
                ensure!(
                    n_cols == n_features,
                    "prediction rows have {n_cols} features but the model was trained on {n_features}"
                );
                let n_rows = x.n_rows();
                let mut bins = vec![MISSING; n_rows * n_cols];
                for row in 0..n_rows {
                    for (f, v) in x.iter_row(row) {
                        bins[row * n_cols + f] = cuts.bin_index_unclamped(f, v);
                    }
                }
                Ok(QuantisedBatch::Dense {
                    bins,
                    n_rows,
                    n_cols,
                })
            }
            DMatrix::Csr { .. } => {
                let n_rows = x.n_rows();
                let mut indptr = Vec::with_capacity(n_rows + 1);
                let mut cols: Vec<u32> = Vec::new();
                let mut bins: Vec<u32> = Vec::new();
                indptr.push(0usize);
                for row in 0..n_rows {
                    for (c, v) in x.iter_row(row) {
                        ensure!(
                            c >= shift,
                            "column index {c} below the stream's column base {shift}"
                        );
                        let f = c - shift;
                        ensure!(
                            f < n_features,
                            "prediction rows use feature {f} but the model was trained on {n_features}"
                        );
                        cols.push(f as u32);
                        // a STORED NaN (sparse files can carry explicit
                        // `nan` values): the float traversal evaluates
                        // `NaN < t` = false at every split — "present,
                        // always right" — which u32::MAX represents
                        // exactly (above every translated threshold).
                        // Dense NaN never reaches here: RowIter skips it,
                        // matching DMatrix::get's missing semantics.
                        bins.push(if v.is_nan() {
                            u32::MAX
                        } else {
                            cuts.bin_index_unclamped(f, v)
                        });
                    }
                    indptr.push(cols.len());
                }
                Ok(QuantisedBatch::Sparse {
                    indptr,
                    cols,
                    bins,
                    n_rows,
                })
            }
        }
    }

    pub fn n_rows(&self) -> usize {
        match self {
            QuantisedBatch::Dense { n_rows, .. } | QuantisedBatch::Sparse { n_rows, .. } => *n_rows,
        }
    }

    /// Transient bytes of this batch (the quantity the streaming
    /// prediction peak-memory contract bounds).
    pub fn bytes(&self) -> usize {
        match self {
            QuantisedBatch::Dense { bins, .. } => bins.len() * 4,
            QuantisedBatch::Sparse {
                indptr, cols, bins, ..
            } => indptr.len() * 8 + (cols.len() + bins.len()) * 4,
        }
    }

    /// The row's unclamped global bin for `feature`, `None` if missing.
    #[inline]
    pub fn feature_bin(&self, row: usize, feature: usize) -> Option<u32> {
        match self {
            QuantisedBatch::Dense { bins, n_cols, .. } => {
                let b = bins[row * n_cols + feature];
                if b == MISSING {
                    None
                } else {
                    Some(b)
                }
            }
            QuantisedBatch::Sparse {
                indptr, cols, bins, ..
            } => {
                let (lo, hi) = (indptr[row], indptr[row + 1]);
                cols[lo..hi]
                    .binary_search(&(feature as u32))
                    .ok()
                    .map(|i| bins[lo + i])
            }
        }
    }
}

/// Accumulate one bin-translated tree into `margins` — the quantised
/// twin of [`crate::predict::accumulate_tree_par`], bit-identical to it
/// on the raw values at every thread count (module docs). This is what
/// the training loop's per-round validation scoring runs on.
pub fn accumulate_bin_tree_par(
    tree: &BinTree,
    batch: &QuantisedBatch,
    margins: &mut [Float],
    exec: &ExecContext,
) {
    debug_assert_eq!(margins.len(), batch.n_rows());
    if KernelMode::from_env() == KernelMode::Blocked {
        let lookup = |row: usize, f: usize| batch.feature_bin(row, f);
        exec.for_each_slice_mut(margins, ROW_CHUNK, |_, start, chunk| {
            let mut bins = PlainBins { lookup: &lookup, row0: 0 };
            let mut nid = [0u32; BLOCK_ROWS];
            let mut lo = 0usize;
            while lo < chunk.len() {
                let n = BLOCK_ROWS.min(chunk.len() - lo);
                bins.prime(start + lo, n);
                walk_block(tree, &bins, n, &mut nid);
                for (i, m) in chunk[lo..lo + n].iter_mut().enumerate() {
                    *m += tree.nodes[nid[i] as usize].leaf_value;
                }
                lo += n;
            }
        });
        return;
    }
    exec.for_each_slice_mut(margins, ROW_CHUNK, |_, start, chunk| {
        for (i, m) in chunk.iter_mut().enumerate() {
            *m += tree.leaf_value_for(|f| batch.feature_bin(start + i, f));
        }
    });
}

/// Margins for a whole transient batch (streaming prediction's
/// per-batch kernel).
pub fn predict_margins_batch(
    forest: &BinForest,
    base_score: &[Float],
    batch: &QuantisedBatch,
    exec: &ExecContext,
) -> Vec<Vec<Float>> {
    margins_with_lookup(
        forest,
        base_score,
        batch.n_rows(),
        &|row, f| batch.feature_bin(row, f),
        exec,
    )
}

/// Result of one streaming prediction pass over a [`BatchSource`].
#[derive(Debug, Clone)]
pub struct StreamedMargins {
    /// Raw margins per output group, in stream row order — bit-identical
    /// to `predict_margins_par` over the equivalent in-memory matrix.
    pub margins: Vec<Vec<Float>>,
    /// Labels collected from the stream (evaluation substrate).
    pub labels: Vec<Float>,
    /// Ranking group boundaries reconstructed from qids (empty = none).
    pub groups: Vec<usize>,
    pub n_rows: usize,
    pub n_batches: usize,
    /// Measured peak transient bytes: one batch of floats plus its
    /// quantised form — O(`batch_rows × n_cols`), never O(`n_rows`).
    pub peak_transient_bytes: usize,
    /// Column base subtracted from raw stream indices (LibSVM).
    pub col_shift: u32,
}

/// The column-base rule every prediction path shares (and ingestion's
/// pass-1 autodetect encodes the same way): shift by 1 iff the stream
/// has present values and every raw index is ≥ 1 — 1-based files never
/// use column 0. `min` is the minimum raw index over the whole stream
/// (`None` for resolved-column or value-free streams ⇒ shift 0).
#[inline]
fn shift_from_min_col(min: Option<u32>) -> u32 {
    u32::from(matches!(min, Some(m) if m >= 1))
}

/// Detect the column base of a raw-indexed stream via
/// [`BatchSource::min_raw_col`] — file sources answer with an
/// index-token-only scan, so no second full parse of the stream
/// happens. Leaves the source reset. Returns 0 for sources with
/// resolved columns.
pub fn detect_col_shift(src: &mut dyn BatchSource) -> Result<u32> {
    if !src.columns_are_raw() {
        return Ok(0);
    }
    let min = src.min_raw_col()?;
    src.reset()?;
    Ok(shift_from_min_col(min))
}

/// **Streaming prediction**: one pass over `src`, quantising each batch
/// against the frozen `cuts` and scoring it batch-at-a-time (two-pass
/// free — the cuts are already known, unlike ingestion's sketch pass;
/// raw-indexed sources pay one extra indices-only scan for the column
/// base). Margins are bit-identical to the in-memory float path for any
/// batch size and thread count.
pub fn stream_margins(
    trees: &[Vec<RegTree>],
    base_score: &[Float],
    cuts: &HistogramCuts,
    src: &mut dyn BatchSource,
    exec: &ExecContext,
) -> Result<StreamedMargins> {
    src.reset()?;
    let col_shift = detect_col_shift(src)?;
    let forest = BinForest::from_trees(trees, cuts);
    let mut margins: Vec<Vec<Float>> = base_score.iter().map(|_| Vec::new()).collect();
    let mut labels: Vec<Float> = Vec::new();
    let mut qids: Vec<i64> = Vec::new();
    let mut n_batches = 0usize;
    let mut peak = 0usize;
    while let Some(batch) = src.next_batch()? {
        let qb = QuantisedBatch::from_dmatrix(&batch.x, cuts, col_shift)
            .with_context(|| format!("quantising prediction batch {n_batches}"))?;
        peak = peak.max(batch.x.float_bytes() + qb.bytes());
        let bm = predict_margins_batch(&forest, base_score, &qb, exec);
        for (k, m) in bm.into_iter().enumerate() {
            margins[k].extend_from_slice(&m);
        }
        labels.extend_from_slice(&batch.y);
        if batch.qid.is_empty() {
            qids.resize(qids.len() + batch.n_rows(), -1);
        } else {
            qids.extend_from_slice(&batch.qid);
        }
        n_batches += 1;
    }
    let n_rows = labels.len();
    let groups = groups_from_qids(&qids)?;
    Ok(StreamedMargins {
        margins,
        labels,
        groups,
        n_rows,
        n_batches,
        peak_transient_bytes: peak,
        col_shift,
    })
}

/// A prediction input packed into spilled ELLPACK pages: the
/// external-memory inference substrate (quantise → pack → spill, then
/// traverse under the residency budget).
pub struct PackedPrediction {
    pub store: PageStore,
    pub labels: Vec<Float>,
    /// Ranking group boundaries (empty = none).
    pub groups: Vec<usize>,
    /// Sparse values that fell at or above their feature's sentinel cut
    /// (or were stored NaN) and were clamped into the last bin (dense
    /// inputs never clamp — see [`pack_source`]). Non-zero means rows
    /// containing them may route differently from the float path at
    /// is-present splits; the CLI warns when this is non-zero.
    pub clamped_values: u64,
}

/// Quantise a streamed source against frozen `cuts` and spill it into a
/// page file (two light passes: count/labels, then quantise+pack —
/// O(`batch_rows × n_cols`) transient bytes, `budget × page_bytes`
/// resident afterwards).
///
/// **Dense inputs pack exactly**: the page alphabet is widened by one
/// symbol so the unclamped bin index survives packing — a value at or
/// above feature `f`'s sentinel stores `ptrs[f+1]` (slot position keeps
/// the feature identity; the widened null cannot collide), and paged
/// prediction is bit-identical to the float path for **every** input,
/// in or out of the training range.
///
/// **Sparse (ELLPACK) inputs clamp**: symbols carry the feature identity
/// through their bin range, so there is no per-feature overflow encoding
/// — out-of-range values fold into the feature's last bin exactly like
/// training-time quantisation. [`PackedPrediction::clamped_values`]
/// counts them (zero for anything inside the training range, where the
/// paths are bit-identical).
pub fn pack_source(
    src: &mut dyn BatchSource,
    cuts: &HistogramCuts,
    page_rows: usize,
    max_resident_pages: usize,
) -> Result<PackedPrediction> {
    ensure!(page_rows >= 1, "page_rows must be >= 1");
    ensure!(max_resident_pages >= 1, "max_resident_pages must be >= 1");
    let n_features = cuts.n_features();
    let raw = src.columns_are_raw();

    // pass A: labels, qids, per-row widths, column base
    src.reset()?;
    let mut labels: Vec<Float> = Vec::new();
    let mut qids: Vec<i64> = Vec::new();
    let mut row_nnz: Vec<u32> = Vec::new();
    let mut dense: Option<bool> = None;
    let mut min_col: Option<u32> = None;
    while let Some(batch) = src.next_batch()? {
        let b_rows = batch.n_rows();
        ensure!(b_rows > 0, "source yielded an empty batch");
        let batch_dense = matches!(batch.x, DMatrix::Dense { .. });
        match dense {
            None => dense = Some(batch_dense),
            Some(d) => ensure!(d == batch_dense, "source switched dense/sparse"),
        }
        if batch_dense {
            ensure!(
                batch.x.n_cols() == n_features,
                "prediction rows have {} features but the model was trained on {n_features}",
                batch.x.n_cols()
            );
        } else if let DMatrix::Csr {
            indptr, indices, ..
        } = &batch.x
        {
            for r in 0..b_rows {
                row_nnz.push((indptr[r + 1] - indptr[r]) as u32);
            }
            if raw {
                for &c in indices {
                    min_col = Some(min_col.map_or(c, |m| m.min(c)));
                }
            }
        }
        labels.extend_from_slice(&batch.y);
        if batch.qid.is_empty() {
            qids.resize(qids.len() + b_rows, -1);
        } else {
            qids.extend_from_slice(&batch.qid);
        }
    }
    let n_rows = labels.len();
    ensure!(n_rows >= 1, "prediction source yielded no rows");
    let dense = dense.unwrap_or(true);
    // the SAME min→shift decision detect_col_shift applies to the
    // streaming path (the min scan itself stays fused into this pass)
    let shift = shift_from_min_col(min_col) as usize;
    let stride = if dense {
        n_features
    } else {
        row_nnz.iter().copied().max().unwrap_or(0).max(1) as usize
    };

    // pass B: quantise (clamped) and pack straight into the spill writer
    src.reset()?;
    static PACK_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "{}{}_predict{}",
        SPILL_DIR_PREFIX,
        std::process::id(),
        PACK_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&dir)
        .with_context(|| format!("creating prediction spill dir {}", dir.display()))?;
    let n_bins = cuts.total_bins();
    // dense pages widen the alphabet by one symbol so the last feature's
    // overflow bin (== total_bins) stays distinct from the null/padding
    // symbol; sparse pages keep the training alphabet (and clamp)
    let page_bins = if dense { n_bins + 1 } else { n_bins };
    let null = page_bins as u32;
    let mut clamped = 0u64;
    let mut builder = PagedMatrixBuilder::new(
        dir.join("predict.pages"),
        n_rows,
        n_features,
        stride,
        page_bins,
        dense,
        page_rows,
        max_resident_pages,
    )?;
    let mut rowbuf: Vec<u32> = Vec::with_capacity(stride);
    while let Some(batch) = src.next_batch()? {
        for r in 0..batch.n_rows() {
            rowbuf.clear();
            if dense {
                rowbuf.resize(n_features, null);
                for (f, v) in batch.x.iter_row(r) {
                    // unclamped: overflow of feature f stores ptrs[f+1];
                    // the slot keeps the feature identity, so routing is
                    // exact even beyond the training range
                    rowbuf[f] = cuts.bin_index_unclamped(f, v);
                }
            } else {
                for (c, v) in batch.x.iter_row(r) {
                    ensure!(c >= shift, "column index {c} below column base {shift}");
                    let f = c - shift;
                    ensure!(
                        f < n_features,
                        "prediction rows use feature {f} but the model was trained on {n_features}"
                    );
                    let hi = cuts.ptrs[f + 1];
                    // stored NaN routes "always right" on the float path
                    // (`NaN < t` is false); the packed alphabet cannot
                    // express that, so it clamps (and is counted) with
                    // the overflow values
                    let b = if v.is_nan() {
                        hi
                    } else {
                        cuts.bin_index_unclamped(f, v)
                    };
                    if b >= hi {
                        // ELLPACK symbols carry the feature through their
                        // bin range — no overflow encoding; clamp (and
                        // count) exactly like training-time quantisation
                        clamped += 1;
                        rowbuf.push(hi - 1);
                    } else {
                        rowbuf.push(b);
                    }
                }
            }
            builder.push_row(&rowbuf)?;
        }
    }
    ensure!(
        builder.rows_filled() == n_rows,
        "pass B replay yielded {} rows, pass A saw {n_rows}",
        builder.rows_filled()
    );
    let store = builder.finish()?;
    let groups = groups_from_qids(&qids)?;
    Ok(PackedPrediction {
        store,
        labels,
        groups,
        clamped_values: clamped,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::source::DMatrixSource;
    use crate::data::synthetic::{generate, DatasetSpec};
    use crate::data::Dataset;
    use crate::predict;
    use crate::quantile::Quantizer;
    use crate::util::Pcg64;

    /// Random dense matrix with missing values + cuts fit on it.
    fn fixture(n: usize, d: usize, seed: u64) -> (DMatrix, HistogramCuts) {
        let mut rng = Pcg64::new(seed);
        let vals: Vec<Float> = (0..n * d)
            .map(|_| {
                if rng.next_f64() < 0.15 {
                    Float::NAN
                } else {
                    rng.next_f32() * 10.0 - 5.0
                }
            })
            .collect();
        let x = DMatrix::dense(vals, n, d);
        let cuts = HistogramCuts::from_dmatrix(&x, 8, None);
        (x, cuts)
    }

    /// Random tree over `d` features whose thresholds are cut values —
    /// the trained-tree invariant.
    fn random_tree(cuts: &HistogramCuts, depth: usize, rng: &mut Pcg64) -> RegTree {
        let mut t = RegTree::new_root(rng.next_f32() - 0.5, 1.0);
        let mut frontier = vec![(0usize, 0usize)];
        while let Some((nid, lvl)) = frontier.pop() {
            if lvl >= depth || rng.next_f64() < 0.25 {
                continue;
            }
            let f = rng.gen_range(cuts.n_features());
            let fc = cuts.feature_cuts(f);
            let threshold = fc[rng.gen_range(fc.len())];
            let (l, r) = t.apply_split(
                nid,
                f as u32,
                threshold,
                rng.next_f64() < 0.5,
                1.0,
                rng.next_f32() - 0.5,
                1.0,
                rng.next_f32() - 0.5,
                1.0,
            );
            frontier.push((l, lvl + 1));
            frontier.push((r, lvl + 1));
        }
        t
    }

    #[test]
    fn threshold_to_bin_round_trips_split_bins() {
        let (x, cuts) = fixture(200, 4, 1);
        let _ = x;
        for f in 0..cuts.n_features() {
            let lo = cuts.ptrs[f];
            for b in lo..cuts.ptrs[f + 1] {
                let t = cuts.cut_of_bin(b);
                assert_eq!(
                    threshold_to_bin(&cuts, f, t),
                    b + 1,
                    "feature {f} bin {b}: translation must be split_bin + 1"
                );
            }
            // below the first cut / above the sentinel
            let first = cuts.feature_cuts(f)[0];
            let last = *cuts.feature_cuts(f).last().unwrap();
            assert_eq!(threshold_to_bin(&cuts, f, first - 1.0), lo);
            assert_eq!(
                threshold_to_bin(&cuts, f, last + last.abs() + 1.0),
                cuts.ptrs[f + 1]
            );
        }
    }

    #[test]
    fn bin_traversal_matches_float_on_all_storages() {
        let (x, cuts) = fixture(500, 5, 2);
        let mut rng = Pcg64::new(7);
        let trees: Vec<RegTree> = (0..6).map(|_| random_tree(&cuts, 4, &mut rng)).collect();
        let forest = BinForest::from_trees(&[trees.clone()], &cuts);
        let base = [0.25f32];
        let float = predict::predict_margins(&[trees.clone()], &base, &x);

        let qm = Quantizer::new(cuts.clone()).quantize(&x);
        let cm = CompressedMatrix::from_quantized(&qm);
        let exec = ExecContext::serial();
        let mq = predict_margins_quantized(&forest, &base, &qm, &cuts, &exec);
        let mc = predict_margins_compressed(&forest, &base, &cm, &cuts, &exec);
        for (a, b) in float[0].iter().zip(mq[0].iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "quantized");
        }
        for (a, b) in float[0].iter().zip(mc[0].iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "compressed");
        }

        // transient unclamped batch (the streaming representation)
        let qb = QuantisedBatch::from_dmatrix(&x, &cuts, 0).unwrap();
        let mb = predict_margins_batch(&forest, &base, &qb, &exec);
        assert_eq!(mb[0], float[0], "batch");

        // leaf indices agree too
        let fl = predict::predict_leaf_indices(&trees, &x);
        let bl = leaf_indices_compressed(&forest.groups[0], &cm, &cuts, &exec);
        assert_eq!(fl, bl);
    }

    #[test]
    fn unclamped_batch_is_exact_beyond_training_range() {
        // cuts fit on narrow data; prediction rows exceed the sentinel —
        // the transient representation must still match float traversal,
        // including on a split at a feature's last bin (is-present split)
        let vals: Vec<Float> = (0..64).map(|i| (i % 8) as Float).collect();
        let x = DMatrix::dense(vals, 64, 1);
        let cuts = HistogramCuts::from_dmatrix(&x, 4, None);
        let hi = cuts.ptrs[1] - 1; // the feature's last (sentinel) bin
        let mut t = RegTree::new_root(0.0, 1.0);
        t.apply_split(0, 0, cuts.cut_of_bin(hi), false, 1.0, -1.0, 1.0, 2.0, 1.0);
        let probe = DMatrix::dense(vec![0.0, 7.0, 1e9, Float::NAN], 4, 1);
        let float: Vec<Float> = (0..4).map(|r| t.predict_row(&probe, r)).collect();
        let qb = QuantisedBatch::from_dmatrix(&probe, &cuts, 0).unwrap();
        let bt = BinTree::from_tree(&t, &cuts);
        let quant: Vec<Float> = (0..4)
            .map(|r| bt.leaf_value_for(|f| qb.feature_bin(r, f)))
            .collect();
        assert_eq!(float, quant, "out-of-range values must route identically");
        assert_eq!(quant[2], 2.0, "1e9 exceeds the sentinel -> right");
        assert_eq!(quant[3], 2.0, "missing follows default right");
    }

    #[test]
    fn categorical_bin_traversal_matches_float() {
        // f0 categorical with codes {0, 2, 5}; f1 numeric
        let n = 120usize;
        let mut rng = Pcg64::new(11);
        let mut vals = Vec::new();
        for _ in 0..n {
            vals.push([0.0 as Float, 2.0, 5.0][rng.gen_range(3)]);
            vals.push(rng.next_f32() * 4.0);
        }
        let x = DMatrix::dense(vals, n, 2);
        let mut cuts = HistogramCuts::from_dmatrix(&x, 8, None);
        let mut cat = std::collections::BTreeMap::new();
        cat.insert(0usize, vec![0.0 as Float, 2.0, 5.0]);
        cuts.apply_categories(&cat);
        // root: f0 in {0, 5} ? left : right; left child splits numeric f1
        let mut t = RegTree::new_root(0.0, 1.0);
        let (l, _r) = t.apply_split(0, 0, 0.0, false, 1.0, -1.0, 1.0, 2.0, 1.0);
        t.set_categories(0, (1 << 0) | (1 << 5));
        let f1cut = cuts.feature_cuts(1)[1];
        t.apply_split(l, 1, f1cut, true, 0.5, -2.0, 1.0, -0.5, 1.0);

        let float: Vec<Float> = (0..n).map(|r| t.predict_row(&x, r)).collect();
        let forest = BinForest::from_trees(&[vec![t.clone()]], &cuts);
        let base = [0.0f32];
        let qm = Quantizer::new(cuts.clone()).quantize(&x);
        let packed = CompressedMatrix::from_quantized(&qm);
        let exec = ExecContext::serial();
        let mq = predict_margins_quantized(&forest, &base, &qm, &cuts, &exec);
        let mc = predict_margins_compressed(&forest, &base, &packed, &cuts, &exec);
        let qb = QuantisedBatch::from_dmatrix(&x, &cuts, 0).unwrap();
        let mb = predict_margins_batch(&forest, &base, &qb, &exec);
        for r in 0..n {
            assert_eq!(mq[0][r], float[r], "quantized row {r}");
            assert_eq!(mc[0][r], float[r], "compressed row {r}");
            assert_eq!(mb[0][r], float[r], "batch row {r}");
        }
    }

    #[test]
    fn paged_margins_match_resident_under_every_budget() {
        let (x, cuts) = fixture(800, 4, 3);
        let mut rng = Pcg64::new(11);
        let trees: Vec<RegTree> = (0..4).map(|_| random_tree(&cuts, 3, &mut rng)).collect();
        let forest = BinForest::from_trees(&[trees.clone()], &cuts);
        let base = [0.0f32];
        let qm = Quantizer::new(cuts.clone()).quantize(&x);
        let cm = CompressedMatrix::from_quantized(&qm);
        let resident =
            predict_margins_compressed(&forest, &base, &cm, &cuts, &ExecContext::serial());
        for (page_rows, budget, threads) in
            [(64usize, 1usize, 1usize), (64, 3, 4), (900, 1, 4), (123, 2, 2)]
        {
            let path = std::env::temp_dir().join(format!(
                "xgb_tpu_qpred_{page_rows}_{budget}_{threads}_{}",
                std::process::id()
            ));
            let mut b = PagedMatrixBuilder::new(
                &path, qm.n_rows, qm.n_features, qm.row_stride, qm.n_bins, qm.dense, page_rows,
                budget,
            )
            .unwrap();
            for r in 0..qm.n_rows {
                b.push_row(qm.row(r)).unwrap();
            }
            let store = b.finish().unwrap();
            let exec = ExecContext::new(threads);
            let paged = predict_margins_paged(&forest, &base, &store, &cuts, &exec).unwrap();
            for (a, b) in resident[0].iter().zip(paged[0].iter()) {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "page_rows={page_rows} budget={budget} threads={threads}"
                );
            }
            assert_eq!(store.resident_bytes(), 0, "nothing left resident");
            let stats = store.take_round_stats();
            assert!(
                stats.peak_resident_bytes <= budget * store.max_page_bytes(),
                "peak {} > {budget} x {}",
                stats.peak_resident_bytes,
                store.max_page_bytes()
            );
        }
    }

    #[test]
    fn stream_margins_match_in_memory_and_stay_bounded() {
        let g = generate(&DatasetSpec::higgs_like(600), 17);
        let cuts = HistogramCuts::from_dmatrix(&g.train.x, 16, None);
        let mut rng = Pcg64::new(23);
        let trees: Vec<RegTree> = (0..5).map(|_| random_tree(&cuts, 4, &mut rng)).collect();
        let base = [0.5f32];
        let float = predict::predict_margins(&[trees.clone()], &base, &g.train.x);
        for batch_rows in [7usize, 64, g.train.n_rows()] {
            let mut src = DMatrixSource::from_dataset(&g.train, batch_rows);
            let sm = stream_margins(
                &[trees.clone()],
                &base,
                &cuts,
                &mut src,
                &ExecContext::serial(),
            )
            .unwrap();
            assert_eq!(sm.margins[0], float[0], "batch_rows={batch_rows}");
            assert_eq!(sm.labels, g.train.y);
            assert_eq!(sm.n_batches, g.train.n_rows().div_ceil(batch_rows));
            // transient bytes scale with the batch, not the dataset
            let bound = batch_rows * g.train.n_cols() * 8 + (batch_rows + 1) * 8;
            assert!(
                sm.peak_transient_bytes <= bound,
                "batch_rows={batch_rows}: {} > {bound}",
                sm.peak_transient_bytes
            );
        }
    }

    #[test]
    fn pack_source_spills_and_predicts_identically() {
        let g = generate(&DatasetSpec::higgs_like(400), 29);
        let cuts = HistogramCuts::from_dmatrix(&g.train.x, 16, None);
        let mut rng = Pcg64::new(31);
        let trees: Vec<RegTree> = (0..4).map(|_| random_tree(&cuts, 3, &mut rng)).collect();
        let forest = BinForest::from_trees(&[trees.clone()], &cuts);
        let base = [0.0f32];
        let float = predict::predict_margins(&[trees.clone()], &base, &g.train.x);
        let mut src = DMatrixSource::from_dataset(&g.train, 53);
        let packed = pack_source(&mut src, &cuts, 64, 2).unwrap();
        assert_eq!(packed.labels, g.train.y);
        assert_eq!(packed.clamped_values, 0, "training data is in-range");
        let paged = predict_margins_paged(
            &forest,
            &base,
            &packed.store,
            &cuts,
            &ExecContext::new(2),
        )
        .unwrap();
        assert_eq!(paged[0], float[0]);
    }

    #[test]
    fn dense_packed_prediction_exact_beyond_training_range() {
        // the widened-alphabet encoding: a dense prediction input with
        // values above the sentinel must predict bit-identically to the
        // float path through pack_source + paged traversal, even across
        // an is-present split at a feature's last bin
        let vals: Vec<Float> = (0..64).map(|i| (i % 8) as Float).collect();
        let x = DMatrix::dense(vals, 64, 1);
        let cuts = HistogramCuts::from_dmatrix(&x, 4, None);
        let hi = cuts.ptrs[1] - 1; // the feature's last (sentinel) bin
        let mut t = RegTree::new_root(0.0, 1.0);
        t.apply_split(0, 0, cuts.cut_of_bin(hi), false, 1.0, -1.0, 1.0, 2.0, 1.0);
        let probe = Dataset::new(
            DMatrix::dense(vec![0.0, 7.0, 1e9, Float::NAN], 4, 1),
            vec![0.0; 4],
        );
        let float: Vec<Float> = (0..4).map(|r| t.predict_row(&probe.x, r)).collect();
        let forest = BinForest::from_trees(&[vec![t]], &cuts);
        let mut src = DMatrixSource::from_dataset(&probe, 2);
        let packed = pack_source(&mut src, &cuts, 2, 1).unwrap();
        assert_eq!(packed.clamped_values, 0, "dense inputs never clamp");
        let paged =
            predict_margins_paged(&forest, &[0.0], &packed.store, &cuts, &ExecContext::serial())
                .unwrap();
        assert_eq!(paged[0], float, "1e9 must route right, NaN by default");
    }

    #[test]
    fn stored_csr_nan_routes_like_float() {
        // sparse files can carry explicit nan values; the float path
        // treats them as present-and-always-right (`NaN < t` is false),
        // unlike a truly absent value which takes the default direction
        let train = DMatrix::csr(vec![0, 1, 2], vec![0, 0], vec![1.0, 5.0], 2, 1);
        let cuts = HistogramCuts::from_dmatrix(&train, 4, None);
        let mut t = RegTree::new_root(0.0, 1.0);
        // default LEFT, so "missing" and "stored NaN" diverge observably
        t.apply_split(0, 0, cuts.feature_cuts(0)[0], true, 1.0, -1.0, 1.0, 2.0, 1.0);
        let probe = DMatrix::csr(vec![0, 1, 1], vec![0], vec![Float::NAN], 2, 1);
        let float: Vec<Float> = (0..2).map(|r| t.predict_row(&probe, r)).collect();
        assert_eq!(float, vec![2.0, -1.0], "stored NaN right, absent default-left");
        let qb = QuantisedBatch::from_dmatrix(&probe, &cuts, 0).unwrap();
        let bt = BinTree::from_tree(&t, &cuts);
        let quant: Vec<Float> = (0..2)
            .map(|r| bt.leaf_value_for(|f| qb.feature_bin(r, f)))
            .collect();
        assert_eq!(float, quant);
    }

    #[test]
    fn sparse_packed_prediction_counts_clamped_values() {
        // sparse ELLPACK symbols cannot encode per-feature overflow: an
        // out-of-range value clamps into the last bin and is counted
        let x = DMatrix::csr(
            vec![0, 1, 2],
            vec![0, 0],
            vec![3.0, 4.0],
            2,
            1,
        );
        let cuts = HistogramCuts::from_dmatrix(&x, 4, None);
        let probe = Dataset::new(
            DMatrix::csr(vec![0, 1, 2], vec![0, 0], vec![3.0, 1e9], 2, 1),
            vec![0.0; 2],
        );
        let mut src = DMatrixSource::from_dataset(&probe, 8);
        let packed = pack_source(&mut src, &cuts, 8, 1).unwrap();
        assert_eq!(packed.clamped_values, 1, "the 1e9 value clamps");
        // the clamped symbol still lands in the feature's last bin
        let page = packed.store.load_page(0).unwrap();
        assert_eq!(page.matrix.get(1, 0), Some(cuts.ptrs[1] - 1));
    }
}
