//! XLA-backed ensemble prediction (§2.4): drives the AOT-compiled
//! array-tree traversal artifact over row tiles and tree chunks.

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::data::DMatrix;
use crate::runtime::Artifacts;
use crate::tree::RegTree;
use crate::Float;

/// Batched predictor over the `predict` artifact.
pub struct XlaPredictor {
    artifacts: Arc<Artifacts>,
}

impl XlaPredictor {
    pub fn new(artifacts: Arc<Artifacts>) -> Self {
        XlaPredictor { artifacts }
    }

    /// Maximum feature count the artifact supports.
    pub fn max_features(&self) -> usize {
        self.artifacts.manifest.predict_features
    }

    /// Predict margins for one output group of trees, starting from
    /// `base_score`. `x` may have fewer features than the artifact (the
    /// rest are padded missing); more is an error.
    pub fn predict_margins(
        &self,
        trees: &[RegTree],
        base_score: Float,
        x: &DMatrix,
    ) -> Result<Vec<Float>> {
        let m = self.artifacts.manifest.clone();
        ensure!(
            x.n_cols() <= m.predict_features,
            "dataset has {} features; predict artifact supports {} (regenerate \
             artifacts with a larger PRED_FEATURES)",
            x.n_cols(),
            m.predict_features
        );
        for t in trees {
            ensure!(
                t.n_nodes() <= m.predict_nodes,
                "tree with {} nodes exceeds artifact capacity {}",
                t.n_nodes(),
                m.predict_nodes
            );
        }
        let n = x.n_rows();
        let mut out = vec![base_score; n];

        // pre-encode tree chunks once (shared across row tiles)
        let tn = m.predict_trees * m.predict_nodes;
        let mut chunks: Vec<(Vec<i32>, Vec<Float>, Vec<i32>, Vec<i32>, Vec<i32>, Vec<Float>)> =
            Vec::new();
        for chunk in trees.chunks(m.predict_trees) {
            let mut feature = vec![0i32; tn];
            let mut threshold = vec![0.0 as Float; tn];
            let mut left = vec![-1i32; tn];
            let mut right = vec![-1i32; tn];
            let mut default_left = vec![1i32; tn];
            let mut leaf_value = vec![0.0 as Float; tn];
            for (ti, tree) in chunk.iter().enumerate() {
                let a = tree.to_arrays(m.predict_nodes);
                let lo = ti * m.predict_nodes;
                feature[lo..lo + m.predict_nodes].copy_from_slice(&a.feature);
                threshold[lo..lo + m.predict_nodes].copy_from_slice(&a.threshold);
                left[lo..lo + m.predict_nodes].copy_from_slice(&a.left);
                right[lo..lo + m.predict_nodes].copy_from_slice(&a.right);
                default_left[lo..lo + m.predict_nodes].copy_from_slice(&a.default_left);
                leaf_value[lo..lo + m.predict_nodes].copy_from_slice(&a.leaf_value);
            }
            chunks.push((feature, threshold, left, right, default_left, leaf_value));
        }

        let mut x_buf = vec![Float::NAN; m.predict_rows * m.predict_features];
        let mut row_lo = 0usize;
        while row_lo < n {
            let row_hi = (row_lo + m.predict_rows).min(n);
            x_buf.fill(Float::NAN);
            for (ti, row) in (row_lo..row_hi).enumerate() {
                for (c, v) in x.iter_row(row) {
                    x_buf[ti * m.predict_features + c] = v;
                }
            }
            for (feature, threshold, left, right, default_left, leaf_value) in &chunks {
                let margins = self.artifacts.predict_tile(
                    &x_buf,
                    feature,
                    threshold,
                    left,
                    right,
                    default_left,
                    leaf_value,
                )?;
                for (ti, row) in (row_lo..row_hi).enumerate() {
                    out[row] += margins[ti];
                }
            }
            row_lo = row_hi;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetSpec};
    use crate::gbm::{Learner, LearnerParams, ObjectiveKind};

    fn artifacts() -> Option<Arc<Artifacts>> {
        crate::runtime::find_artifact_dir(None)
            .and_then(|d| Artifacts::load(d).ok())
            .map(Arc::new)
    }

    #[test]
    fn xla_predict_matches_native_predict() {
        let Some(a) = artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let g = generate(&DatasetSpec::higgs_like(2500), 31);
        let params = LearnerParams {
            objective: ObjectiveKind::BinaryLogistic,
            num_rounds: 60, // > predict_trees to exercise tree chunking
            max_depth: 5,
            max_bins: 32,
            eval_every: 0,
            ..Default::default()
        };
        let b = Learner::from_params(params)
            .unwrap()
            .train(&g.train, None)
            .unwrap();
        assert!(b.trees[0].len() > a.manifest.predict_trees);
        let native = b.predict_margins(&g.valid.x);
        let xla = XlaPredictor::new(a)
            .predict_margins(&b.trees[0], b.base_score[0], &g.valid.x)
            .unwrap();
        let mut max_err = 0.0f32;
        for (n, x) in native[0].iter().zip(xla.iter()) {
            max_err = max_err.max((n - x).abs());
        }
        assert!(max_err < 1e-3, "max margin error {max_err}");
    }

    #[test]
    fn too_many_features_is_clear_error() {
        let Some(a) = artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let g = generate(&DatasetSpec::covtype_like(200), 33);
        let p = XlaPredictor::new(a);
        if g.train.n_cols() > p.max_features() {
            let t = RegTree::new_root(0.0, 1.0);
            let err = p.predict_margins(&[t], 0.0, &g.train.x);
            assert!(err.is_err());
            assert!(format!("{:?}", err.unwrap_err()).contains("features"));
        }
    }
}
