//! XLA-backed histogram backend: routes the coordinator's per-device
//! `BuildPartialHistograms` calls (Algorithm 1) through the AOT-compiled
//! Pallas one-hot-matmul kernel.
//!
//! The artifact has a fixed `(rows, slots, bins)` tile; this adapter
//! chunks a node's row set into row tiles, a shard whose `row_stride`
//! exceeds `slots` into slot groups, and a cut set wider than `bins` into
//! bin windows, padding each tile's tail. The padding symbol is
//! `i32::MAX/2`, which one-hots to nothing in every window.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::device::{DeviceShard, HistBackend, ShardStorage};
use crate::exec::ExecContext;
use crate::hist::Histogram;
use crate::runtime::Artifacts;
use crate::Float;

/// Symbol guaranteed outside every bin window (after offset subtraction it
/// stays far out of range — i32 arithmetic cannot wrap it back into a
/// window since offsets are < 2^24 in practice).
const PAD_SYMBOL: i32 = i32::MAX / 2;

/// Histogram backend executing on the PJRT client.
pub struct XlaHistBackend {
    artifacts: Arc<Artifacts>,
    // reusable tile buffers
    bins_buf: Vec<i32>,
    grads_buf: Vec<Float>,
    row_scratch: Vec<u32>,
}

impl XlaHistBackend {
    pub fn new(artifacts: Arc<Artifacts>) -> Self {
        let m = &artifacts.manifest;
        XlaHistBackend {
            bins_buf: vec![PAD_SYMBOL; m.hist_rows * m.hist_slots],
            grads_buf: vec![0.0; m.hist_rows * 2],
            row_scratch: Vec::new(),
            artifacts,
        }
    }

    /// Fill one `(rows, slots)` tile from shard rows
    /// `rows[row_lo..row_hi]`, slot group starting at `slot_lo`.
    fn fill_tile(
        &mut self,
        shard: &DeviceShard,
        rows: &[u32],
        row_lo: usize,
        row_hi: usize,
        slot_lo: usize,
    ) {
        let m = &self.artifacts.manifest;
        let stride = shard.storage.row_stride();
        self.bins_buf.fill(PAD_SYMBOL);
        self.grads_buf.fill(0.0);
        self.row_scratch.resize(stride, 0);
        for (ti, &r) in rows[row_lo..row_hi].iter().enumerate() {
            let r = r as usize;
            match &shard.storage {
                ShardStorage::Quantized(qm) => {
                    let row = qm.row(r);
                    let null = qm.null_symbol();
                    for s in 0..m.hist_slots.min(stride.saturating_sub(slot_lo)) {
                        let b = row[slot_lo + s];
                        if b != null {
                            self.bins_buf[ti * m.hist_slots + s] = b as i32;
                        }
                    }
                }
                ShardStorage::Compressed(cm) => {
                    let null = cm.null_symbol();
                    let base = r * stride;
                    for s in 0..m.hist_slots.min(stride.saturating_sub(slot_lo)) {
                        let b = cm.symbol(base + slot_lo + s);
                        if b != null {
                            self.bins_buf[ti * m.hist_slots + s] = b as i32;
                        }
                    }
                }
                ShardStorage::Paged(ps) => {
                    // tile rows are visited in ascending order, so the
                    // store's one-slot row cursor gives one load per page
                    let page = ps
                        .page_for_row(r)
                        .expect("loading spilled page for XLA tile");
                    let null = page.matrix.null_symbol();
                    let base = (r - page.first_row) * stride;
                    for s in 0..m.hist_slots.min(stride.saturating_sub(slot_lo)) {
                        let b = page.matrix.symbol(base + slot_lo + s);
                        if b != null {
                            self.bins_buf[ti * m.hist_slots + s] = b as i32;
                        }
                    }
                }
            }
            let g = shard.gradients[r];
            self.grads_buf[ti * 2] = g.grad;
            self.grads_buf[ti * 2 + 1] = g.hess;
        }
    }
}

impl HistBackend for XlaHistBackend {
    // `exec` is ignored: the PJRT client is Rc-based, so this backend is
    // pinned to the coordinator's executor thread (`as_parallel` stays at
    // the default `None` and the device loop runs serially).
    fn build_histogram(
        &mut self,
        shard: &DeviceShard,
        rows: &[u32],
        out: &mut Histogram,
        _exec: &ExecContext,
    ) -> Result<()> {
        let m = self.artifacts.manifest.clone();
        let n_bins = out.n_bins();
        let stride = shard.storage.row_stride();
        let n_windows = n_bins.div_ceil(m.hist_bins);
        let n_slot_groups = stride.div_ceil(m.hist_slots);

        let mut row_lo = 0usize;
        while row_lo < rows.len() {
            let row_hi = (row_lo + m.hist_rows).min(rows.len());
            for sg in 0..n_slot_groups {
                self.fill_tile(shard, rows, row_lo, row_hi, sg * m.hist_slots);
                for w in 0..n_windows {
                    let offset = (w * m.hist_bins) as i32;
                    let partial = self.artifacts.histogram_tile(
                        &self.bins_buf,
                        &self.grads_buf,
                        offset,
                    )?;
                    let lo = w * m.hist_bins;
                    let hi = (lo + m.hist_bins).min(n_bins);
                    for (b, slot) in (lo..hi).enumerate() {
                        out.bins[slot].grad += partial[b * 2] as f64;
                        out.bins[slot].hess += partial[b * 2 + 1] as f64;
                    }
                }
            }
            row_lo = row_hi;
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::device::NativeBackend;
    use crate::coordinator::{CoordinatorParams, MultiDeviceCoordinator};
    use crate::data::synthetic::{generate, DatasetSpec};
    use crate::GradPair;

    fn artifacts() -> Option<Arc<Artifacts>> {
        crate::runtime::find_artifact_dir(None)
            .and_then(|d| Artifacts::load(d).ok())
            .map(Arc::new)
    }

    #[test]
    fn xla_histogram_matches_native_backend() {
        let Some(a) = artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        // covers bin windows > 1 (28 features x 64 bins ~ 1.7k bins)
        let g = generate(&DatasetSpec::higgs_like(1500), 21);
        let params = CoordinatorParams {
            max_bins: 64,
            compress: true,
            ..Default::default()
        };
        let c = MultiDeviceCoordinator::from_dmatrix(&g.train.x, params).unwrap();
        let shard = &c.devices[0];
        let mut shard_owned = DeviceShard::new(0, 0, shard.storage.clone_in_memory());
        let mut rng = crate::util::Pcg64::new(3);
        let grads: Vec<GradPair> = (0..shard_owned.n_rows())
            .map(|_| GradPair::new(rng.next_f32() - 0.5, rng.next_f32() + 0.1))
            .collect();
        shard_owned.begin_tree(&grads);

        let rows: Vec<u32> = (0..shard_owned.n_rows() as u32).collect();
        let n_bins = c.n_bins();
        let mut h_native = Histogram::zeros(n_bins);
        let mut h_xla = Histogram::zeros(n_bins);
        let exec = ExecContext::serial();
        NativeBackend::default()
            .build_histogram(&shard_owned, &rows, &mut h_native, &exec)
            .unwrap();
        XlaHistBackend::new(a)
            .build_histogram(&shard_owned, &rows, &mut h_xla, &exec)
            .unwrap();
        for (i, (n, x)) in h_native.bins.iter().zip(h_xla.bins.iter()).enumerate() {
            assert!(
                (n.grad - x.grad).abs() < 1e-2 && (n.hess - x.hess).abs() < 1e-2,
                "bin {i}: native {n:?} vs xla {x:?}"
            );
        }
    }

    #[test]
    fn xla_backend_handles_wide_sparse_stride() {
        let Some(a) = artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        // bosch-like: sparse CSR, stride > 16 slots
        let g = generate(&DatasetSpec::bosch_like(400), 23);
        let params = CoordinatorParams {
            max_bins: 8,
            compress: false,
            ..Default::default()
        };
        let c = MultiDeviceCoordinator::from_dmatrix(&g.train.x, params).unwrap();
        let mut shard = DeviceShard::new(0, 0, c.devices[0].storage.clone_in_memory());
        let grads: Vec<GradPair> = (0..shard.n_rows())
            .map(|i| GradPair::new((i % 5) as f32 - 2.0, 1.0))
            .collect();
        shard.begin_tree(&grads);
        let rows: Vec<u32> = (0..shard.n_rows() as u32).collect();
        let n_bins = c.n_bins();
        let mut h_native = Histogram::zeros(n_bins);
        let mut h_xla = Histogram::zeros(n_bins);
        let exec = ExecContext::serial();
        NativeBackend::default()
            .build_histogram(&shard, &rows, &mut h_native, &exec)
            .unwrap();
        XlaHistBackend::new(a)
            .build_histogram(&shard, &rows, &mut h_xla, &exec)
            .unwrap();
        for (i, (n, x)) in h_native.bins.iter().zip(h_xla.bins.iter()).enumerate() {
            assert!(
                (n.grad - x.grad).abs() < 1e-2 && (n.hess - x.hess).abs() < 1e-2,
                "bin {i}: native {n:?} vs xla {x:?}"
            );
        }
    }
}
