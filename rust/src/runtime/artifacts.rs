//! Artifact registry: manifest parsing, HLO loading/compilation, and typed
//! execution wrappers over the PJRT CPU client.

use std::path::{Path, PathBuf};

// `ensure` is only exercised by the xla-gated execution paths.
#[cfg_attr(not(feature = "xla"), allow(unused_imports))]
use anyhow::{ensure, Context, Result};

use crate::util::Config;
use crate::Float;

/// Which gradient artifact to run (paper §2.5: these two objectives are
/// device-resident; multiclass/ranking stay on the CPU).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GradKind {
    Logistic,
    Squared,
}

/// Tile geometry read from `manifest.txt`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub grad_tile: usize,
    pub hist_rows: usize,
    pub hist_slots: usize,
    pub hist_bins: usize,
    pub predict_rows: usize,
    pub predict_features: usize,
    pub predict_trees: usize,
    pub predict_nodes: usize,
    pub predict_iters: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let cfg = Config::from_file(dir.join("manifest.txt"))
            .context("reading artifact manifest")?;
        Ok(Manifest {
            grad_tile: cfg.get_parse("grad.tile", 0usize)?,
            hist_rows: cfg.get_parse("hist.rows", 0usize)?,
            hist_slots: cfg.get_parse("hist.slots", 0usize)?,
            hist_bins: cfg.get_parse("hist.bins", 0usize)?,
            predict_rows: cfg.get_parse("predict.rows", 0usize)?,
            predict_features: cfg.get_parse("predict.features", 0usize)?,
            predict_trees: cfg.get_parse("predict.trees", 0usize)?,
            predict_nodes: cfg.get_parse("predict.nodes", 0usize)?,
            predict_iters: cfg.get_parse("predict.iters", 0usize)?,
        })
    }
}

/// Compiled PJRT executables — only present when the crate is built with
/// the `xla` feature (the bindings are not vendored; see Cargo.toml).
#[cfg(feature = "xla")]
struct Execs {
    client: xla::PjRtClient,
    grad_logistic: xla::PjRtLoadedExecutable,
    grad_squared: xla::PjRtLoadedExecutable,
    histogram: xla::PjRtLoadedExecutable,
    predict: xla::PjRtLoadedExecutable,
}

/// Loaded + compiled artifact set over one PJRT CPU client.
#[cfg(feature = "xla")]
pub struct Artifacts {
    pub manifest: Manifest,
    pub dir: PathBuf,
    execs: Execs,
    /// Executions performed, per artifact (telemetry for EXPERIMENTS.md).
    pub exec_counts: std::cell::RefCell<[u64; 4]>,
}

/// Stub when built without the `xla` feature: [`Artifacts::load`] always
/// fails, so this is never instantiated (see Cargo.toml).
#[cfg(not(feature = "xla"))]
pub struct Artifacts {
    pub manifest: Manifest,
    pub dir: PathBuf,
    /// Executions performed, per artifact (telemetry for EXPERIMENTS.md).
    pub exec_counts: std::cell::RefCell<[u64; 4]>,
}

#[cfg(feature = "xla")]
fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("non-utf8 artifact path")?,
    )
    .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))
}

impl Artifacts {
    /// Convenience: locate via [`crate::runtime::find_artifact_dir`].
    pub fn discover() -> Result<Self> {
        let dir = crate::runtime::find_artifact_dir(None)
            .context("artifacts/ not found; run `make artifacts`")?;
        Self::load(dir)
    }
}

#[cfg(feature = "xla")]
impl Artifacts {
    /// Load and compile every artifact in `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        ensure!(manifest.hist_bins > 0, "manifest missing hist.bins");
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Artifacts {
            execs: Execs {
                grad_logistic: compile(&client, &dir.join("grad_logistic.hlo.txt"))?,
                grad_squared: compile(&client, &dir.join("grad_squared.hlo.txt"))?,
                histogram: compile(&client, &dir.join("histogram.hlo.txt"))?,
                predict: compile(&client, &dir.join("predict.hlo.txt"))?,
                client,
            },
            manifest,
            dir,
            exec_counts: std::cell::RefCell::new([0; 4]),
        })
    }

    pub fn platform(&self) -> String {
        self.execs.client.platform_name()
    }

    /// §2.5 on-device gradients: returns `(grad, hess)` for all `n`
    /// instances, tiling + padding to the artifact's static shape.
    pub fn gradients(
        &self,
        kind: GradKind,
        margins: &[Float],
        labels: &[Float],
    ) -> Result<(Vec<Float>, Vec<Float>)> {
        ensure!(margins.len() == labels.len(), "margins/labels mismatch");
        let tile = self.manifest.grad_tile;
        let exe = match kind {
            GradKind::Logistic => &self.execs.grad_logistic,
            GradKind::Squared => &self.execs.grad_squared,
        };
        let n = margins.len();
        let mut grad = Vec::with_capacity(n);
        let mut hess = Vec::with_capacity(n);
        let mut m_buf = vec![0.0 as Float; tile];
        let mut y_buf = vec![0.0 as Float; tile];
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + tile).min(n);
            let len = hi - lo;
            m_buf[..len].copy_from_slice(&margins[lo..hi]);
            y_buf[..len].copy_from_slice(&labels[lo..hi]);
            m_buf[len..].fill(0.0);
            y_buf[len..].fill(0.0);
            let m_lit = xla::Literal::vec1(&m_buf);
            let y_lit = xla::Literal::vec1(&y_buf);
            let result = exe
                .execute::<xla::Literal>(&[m_lit, y_lit])
                .map_err(|e| anyhow::anyhow!("grad execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("grad fetch: {e:?}"))?;
            let (g, h) = result
                .to_tuple2()
                .map_err(|e| anyhow::anyhow!("grad tuple: {e:?}"))?;
            let g = g.to_vec::<Float>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            let h = h.to_vec::<Float>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            grad.extend_from_slice(&g[..len]);
            hess.extend_from_slice(&h[..len]);
            self.exec_counts.borrow_mut()[kind as usize] += 1;
            lo = hi;
        }
        Ok((grad, hess))
    }

    /// One histogram-tile execution (the §2.3 hot-spot): `bins` is the
    /// row-major `(hist_rows, hist_slots)` i32 tile (pad with an
    /// out-of-window symbol), `grads` the `(hist_rows, 2)` gradient pairs
    /// (pad with zeros), `offset` the bin window start. Returns the
    /// `(hist_bins, 2)` partial histogram.
    pub fn histogram_tile(
        &self,
        bins: &[i32],
        grads: &[Float],
        offset: i32,
    ) -> Result<Vec<Float>> {
        let m = &self.manifest;
        ensure!(bins.len() == m.hist_rows * m.hist_slots, "bins tile shape");
        ensure!(grads.len() == m.hist_rows * 2, "grads tile shape");
        let bins_lit = xla::Literal::vec1(bins)
            .reshape(&[m.hist_rows as i64, m.hist_slots as i64])
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let grads_lit = xla::Literal::vec1(grads)
            .reshape(&[m.hist_rows as i64, 2])
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let off_lit = xla::Literal::scalar(offset);
        let result = self
            .execs
            .histogram
            .execute::<xla::Literal>(&[bins_lit, grads_lit, off_lit])
            .map_err(|e| anyhow::anyhow!("histogram execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("histogram fetch: {e:?}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?
            .to_vec::<Float>()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        self.exec_counts.borrow_mut()[2] += 1;
        Ok(out)
    }

    /// One prediction-tile execution (§2.4): `x` is `(predict_rows,
    /// predict_features)` row-major f32 (NaN missing, pad rows with NaN),
    /// tree arrays are `(predict_trees, predict_nodes)` (pad trees with
    /// single zero leaves). Returns `(predict_rows,)` margin sums.
    #[allow(clippy::too_many_arguments)]
    pub fn predict_tile(
        &self,
        x: &[Float],
        feature: &[i32],
        threshold: &[Float],
        left: &[i32],
        right: &[i32],
        default_left: &[i32],
        leaf_value: &[Float],
    ) -> Result<Vec<Float>> {
        let m = &self.manifest;
        ensure!(x.len() == m.predict_rows * m.predict_features, "x tile shape");
        let tn = m.predict_trees * m.predict_nodes;
        ensure!(
            feature.len() == tn
                && threshold.len() == tn
                && left.len() == tn
                && right.len() == tn
                && default_left.len() == tn
                && leaf_value.len() == tn,
            "tree array shapes"
        );
        let r = |e: xla::Error| anyhow::anyhow!("{e:?}");
        let t2 = [m.predict_trees as i64, m.predict_nodes as i64];
        let args = [
            xla::Literal::vec1(x)
                .reshape(&[m.predict_rows as i64, m.predict_features as i64])
                .map_err(r)?,
            xla::Literal::vec1(feature).reshape(&t2).map_err(r)?,
            xla::Literal::vec1(threshold).reshape(&t2).map_err(r)?,
            xla::Literal::vec1(left).reshape(&t2).map_err(r)?,
            xla::Literal::vec1(right).reshape(&t2).map_err(r)?,
            xla::Literal::vec1(default_left).reshape(&t2).map_err(r)?,
            xla::Literal::vec1(leaf_value).reshape(&t2).map_err(r)?,
        ];
        let result = self
            .execs
            .predict
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("predict execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("predict fetch: {e:?}"))?;
        let out = result
            .to_tuple1()
            .map_err(r)?
            .to_vec::<Float>()
            .map_err(r)?;
        self.exec_counts.borrow_mut()[3] += 1;
        Ok(out)
    }
}

/// Stubs when the `xla` bindings are unavailable: the API surface is
/// identical, but [`Artifacts::load`] fails up front with a clear message
/// so callers (CLI `--backend xla`, the integration tests' self-skip
/// probes) degrade gracefully to the native stack.
#[cfg(not(feature = "xla"))]
impl Artifacts {
    const UNAVAILABLE: &'static str =
        "xgb_tpu was built without the `xla` feature; the PJRT artifact \
         runtime is unavailable (rebuild with `--features xla` and the xla \
         bindings crate, see Cargo.toml)";

    /// Always fails: the PJRT runtime is compiled out.
    pub fn load(_dir: impl AsRef<Path>) -> Result<Self> {
        anyhow::bail!(Self::UNAVAILABLE)
    }

    pub fn platform(&self) -> String {
        "unavailable (built without `xla` feature)".to_string()
    }

    /// Unreachable in practice ([`Artifacts::load`] never succeeds).
    pub fn gradients(
        &self,
        _kind: GradKind,
        _margins: &[Float],
        _labels: &[Float],
    ) -> Result<(Vec<Float>, Vec<Float>)> {
        anyhow::bail!(Self::UNAVAILABLE)
    }

    /// Unreachable in practice ([`Artifacts::load`] never succeeds).
    pub fn histogram_tile(&self, _bins: &[i32], _grads: &[Float], _offset: i32) -> Result<Vec<Float>> {
        anyhow::bail!(Self::UNAVAILABLE)
    }

    /// Unreachable in practice ([`Artifacts::load`] never succeeds).
    #[allow(clippy::too_many_arguments)]
    pub fn predict_tile(
        &self,
        _x: &[Float],
        _feature: &[i32],
        _threshold: &[Float],
        _left: &[i32],
        _right: &[i32],
        _default_left: &[i32],
        _leaf_value: &[Float],
    ) -> Result<Vec<Float>> {
        anyhow::bail!(Self::UNAVAILABLE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<Artifacts> {
        // integration-style: requires `make artifacts` to have run
        crate::runtime::find_artifact_dir(None).and_then(|d| Artifacts::load(d).ok())
    }

    #[test]
    fn logistic_gradients_match_native() {
        let Some(a) = artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let n = 20_000; // forces 2 tiles
        let mut rng = crate::util::Pcg64::new(5);
        let margins: Vec<Float> = (0..n).map(|_| rng.next_f32() * 6.0 - 3.0).collect();
        let labels: Vec<Float> = (0..n).map(|_| (rng.next_f32() < 0.5) as u8 as f32).collect();
        let (g, h) = a.gradients(GradKind::Logistic, &margins, &labels).unwrap();
        assert_eq!(g.len(), n);
        for i in (0..n).step_by(997) {
            let p = 1.0 / (1.0 + (-margins[i]).exp());
            assert!((g[i] - (p - labels[i])).abs() < 1e-5, "i={i}");
            assert!((h[i] - p * (1.0 - p)).abs() < 1e-5, "i={i}");
        }
    }

    #[test]
    fn squared_gradients_match_native() {
        let Some(a) = artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let margins = vec![1.0, 2.0, 3.0];
        let labels = vec![0.5, 2.0, 10.0];
        let (g, h) = a.gradients(GradKind::Squared, &margins, &labels).unwrap();
        assert_eq!(g, vec![0.5, 0.0, -7.0]);
        assert_eq!(h, vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn histogram_tile_sums() {
        let Some(a) = artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = a.manifest.clone();
        // every row puts its slots in bin 3 of the window
        let bins = vec![3i32; m.hist_rows * m.hist_slots];
        let mut grads = vec![0.0 as Float; m.hist_rows * 2];
        for r in 0..m.hist_rows {
            grads[r * 2] = 1.0;
            grads[r * 2 + 1] = 0.5;
        }
        let out = a.histogram_tile(&bins, &grads, 0).unwrap();
        let expect_g = (m.hist_rows * m.hist_slots) as f32;
        assert!((out[3 * 2] - expect_g).abs() < 1.0, "{}", out[6]);
        assert!((out[3 * 2 + 1] - expect_g * 0.5).abs() < 1.0);
        // out-of-window offset zeroes everything
        let out2 = a.histogram_tile(&bins, &grads, 1000).unwrap();
        assert!(out2.iter().all(|&v| v == 0.0));
    }
}
