//! PJRT runtime: loads the AOT-compiled HLO artifacts (`make artifacts`)
//! and executes them on the hot path — Python never runs at training time.
//!
//! The interchange format is HLO **text**: `HloModuleProto::from_text_file`
//! re-parses and re-numbers instruction ids, sidestepping the 64-bit-id
//! protos jax ≥ 0.5 emits that xla_extension 0.5.1 rejects (see
//! /opt/xla-example/README.md and `python/compile/aot.py`).
//!
//! Three executables, one per device-resident phase of Figure 1:
//!
//! * `grad_{logistic,squared}` — paper §2.5 gradient evaluation,
//! * `histogram` — the §2.3 hot-spot (L1 Pallas one-hot-matmul kernel,
//!   lowered in interpret mode), driven by [`XlaHistBackend`],
//! * `predict` — §2.4 batched ensemble traversal, driven by
//!   [`XlaPredictor`].
//!
//! All artifacts have static tile shapes recorded in `manifest.txt`; this
//! module pads and chunks dynamic workloads onto those tiles.

pub mod artifacts;
pub mod hist_backend;
pub mod predictor;

pub use artifacts::{Artifacts, GradKind};
pub use hist_backend::XlaHistBackend;
pub use predictor::XlaPredictor;

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: explicit arg, `XGB_TPU_ARTIFACTS` env
/// var, or walk up from the current directory looking for
/// `artifacts/manifest.txt`.
pub fn find_artifact_dir(explicit: Option<&str>) -> Option<std::path::PathBuf> {
    if let Some(p) = explicit {
        return Some(p.into());
    }
    if let Ok(p) = std::env::var("XGB_TPU_ARTIFACTS") {
        return Some(p.into());
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join(DEFAULT_ARTIFACT_DIR);
        if cand.join("manifest.txt").is_file() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}
