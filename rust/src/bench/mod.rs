//! Micro-benchmark harness (the offline crate mirror has no `criterion`):
//! warmup + timed iterations with mean/p50/p95/stddev, throughput
//! helpers, and paper-style table printing used by every target in
//! `rust/benches/`.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub p50_secs: f64,
    pub p95_secs: f64,
    pub stddev_secs: f64,
}

impl BenchResult {
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean_secs
    }

    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10} {:>12} {:>12} {:>12}",
            self.name,
            self.iters,
            fmt_secs(self.mean_secs),
            fmt_secs(self.p50_secs),
            fmt_secs(self.p95_secs),
        )
    }
}

/// Human-readable seconds.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}us", s * 1e6)
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Runner {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Runner {
    fn default() -> Self {
        Runner {
            warmup: 1,
            iters: 5,
        }
    }
}

impl Runner {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Runner { warmup, iters }
    }

    /// From env (`XGB_BENCH_WARMUP` / `XGB_BENCH_ITERS`) with defaults.
    pub fn from_env() -> Self {
        let get = |k: &str, d: usize| {
            std::env::var(k)
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(d)
        };
        Runner::new(get("XGB_BENCH_WARMUP", 1), get("XGB_BENCH_ITERS", 5))
    }

    /// Time `f` and return statistics. The closure's return value is
    /// black-boxed to keep the optimiser honest.
    pub fn run<T>(&self, name: impl Into<String>, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters.max(1) {
            let t = Instant::now();
            black_box(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / n as f64;
        BenchResult {
            name: name.into(),
            iters: n,
            mean_secs: mean,
            p50_secs: samples[n / 2],
            p95_secs: samples[(n * 95 / 100).min(n - 1)],
            stddev_secs: var.sqrt(),
        }
    }

    pub fn header() -> String {
        format!(
            "{:<44} {:>10} {:>12} {:>12} {:>12}",
            "benchmark", "iters", "mean", "p50", "p95"
        )
    }
}

/// Optimisation barrier (re-exported so benches don't import std::hint
/// everywhere).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Fixed-width table printer for paper-style tables.
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn add_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                line.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_statistics_sane() {
        let r = Runner::new(0, 7);
        let res = r.run("sleep", || {
            std::thread::sleep(std::time::Duration::from_millis(2))
        });
        assert_eq!(res.iters, 7);
        assert!(res.mean_secs >= 0.002);
        assert!(res.p50_secs <= res.p95_secs);
        assert!(res.row().contains("sleep"));
    }

    #[test]
    fn throughput_math() {
        let res = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_secs: 0.5,
            p50_secs: 0.5,
            p95_secs: 0.5,
            stddev_secs: 0.0,
        };
        assert_eq!(res.throughput(100.0), 200.0);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(2.5).ends_with('s'));
        assert!(fmt_secs(0.0025).contains("ms"));
        assert!(fmt_secs(0.0000025).contains("us"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.add_row(vec!["a".into(), "1".into()]);
        t.add_row(vec!["longer-name".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("| name "));
        assert!(s.contains("| longer-name |"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.add_row(vec!["x".into()]);
    }
}
