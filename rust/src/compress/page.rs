//! External-memory page store: spill sealed bit-packed pages to a
//! per-shard on-disk file and fetch them back per histogram round (Ou,
//! *Out-of-Core GPU Gradient Boosting*, arXiv 2005.09148 — the missing
//! piece of the paper's §2.2 story once the dataset's *packed* form no
//! longer fits in host RAM).
//!
//! # Page format
//!
//! A shard's page file is a fixed-stride sequence of self-describing
//! pages. Every page holds `page_rows` consecutive shard rows (the last
//! page may be shorter), bit-packed **independently** from bit 0 with the
//! shard's symbol width — so each page's words are exactly what
//! [`CompressedMatrix::from_quantized`] produces for that row slice
//! (pinned by the page-format property test). On disk a page is
//!
//! ```text
//! [magic u64][rows u64][bit-width u64][word count u64][checksum u64]
//! [words ... little-endian u64 ...]
//! ```
//!
//! with the checksum an FNV-1a 64 over the words' bytes; a flipped bit
//! anywhere in the payload fails the load with a corruption error.
//!
//! # Residency contract
//!
//! [`PageStore::load_page`] is the only way page words enter memory, and
//! every loaded page is accounted against the store's resident-byte
//! counters until the last [`PageHandle`] drops. The training paths keep
//! at most `max_resident_pages` handles alive per shard (the paged
//! histogram builder's double-buffered prefetch counts its queue, the
//! in-flight load and the page being accumulated against the same
//! budget), so peak resident compressed bytes are bounded by
//! `max_resident_pages × page_bytes` — measured, not assumed:
//! [`PageStore::take_round_stats`] reports the observed peak, surfaced as
//! `BuildStats::peak_resident_page_bytes`.
//!
//! The page file is deleted when the store drops (spill files are
//! per-process temporaries, never a persistence format).

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::compress::{bits_for_symbols, CompressedMatrix, CompressedMatrixBuilder};

/// Magic prefix of every on-disk page.
pub const PAGE_MAGIC: u64 = 0x5847_4250_4147_4531; // "XGBPAGE1"

/// Default rows per sealed page. At 28 dense features × 9 bits/symbol
/// this is ~2 MB of packed words per page — large enough that sequential
/// reads dominate seek cost, small enough that a handful of resident
/// pages stays far below any realistic host budget.
pub const DEFAULT_PAGE_ROWS: usize = 65_536;

/// FNV-1a 64 core over a byte stream — shared by the page payload
/// checksum and the CLI's prediction fingerprint
/// ([`crate::predict::prediction_checksum`]), so the hash constants
/// live in exactly one place.
pub fn fnv1a64(bytes: impl IntoIterator<Item = u8>) -> u64 {
    const FNV64_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV64_PRIME);
    }
    h
}

/// FNV-1a 64 over the packed words' bytes — the page payload checksum.
pub fn checksum64(words: &[u64]) -> u64 {
    fnv1a64(words.iter().flat_map(|w| w.to_le_bytes()))
}

/// In-memory index entry for one on-disk page.
#[derive(Debug, Clone, Copy)]
pub struct PageMeta {
    /// Byte offset of the page header in the file.
    pub offset: u64,
    /// Rows packed in this page.
    pub rows: usize,
    /// Packed words written (including the branch-free pad word).
    pub words: usize,
    /// FNV-1a 64 over the words' bytes.
    pub checksum: u64,
}

/// Shape shared by every page of a shard (the ELLPACK geometry).
#[derive(Debug, Clone, Copy)]
pub struct PageShape {
    pub n_rows: usize,
    pub n_features: usize,
    pub row_stride: usize,
    pub n_bins: usize,
    pub dense: bool,
    pub symbol_bits: u32,
}

/// One page fetched from disk: a self-contained [`CompressedMatrix`] over
/// the page's rows plus its position in the shard. Resident bytes are
/// released (and the store's counter decremented) when the last clone of
/// the owning [`PageHandle`] drops.
pub struct LoadedPage {
    /// Packed rows of this page; `matrix.n_rows == meta.rows`, row 0 of
    /// the matrix is shard row `first_row`.
    pub matrix: CompressedMatrix,
    /// Shard-local index of the page's first row.
    pub first_row: usize,
    /// Page index within the shard's file.
    pub index: usize,
    bytes: usize,
    counters: Arc<ResidentCounters>,
}

impl Drop for LoadedPage {
    fn drop(&mut self) {
        self.counters.resident.fetch_sub(self.bytes, Ordering::Relaxed);
    }
}

/// Shared, cheaply clonable reference to a resident page.
pub type PageHandle = Arc<LoadedPage>;

#[derive(Default)]
struct ResidentCounters {
    /// Sum of bytes of all currently resident pages.
    resident: AtomicUsize,
    /// High-water mark of `resident` since the last stats drain.
    peak: AtomicUsize,
}

#[derive(Default)]
struct LoadCounters {
    pages_loaded: AtomicU64,
    load_nanos: AtomicU64,
    wait_nanos: AtomicU64,
}

/// Per-round paging statistics drained by the coordinator after each
/// tree ([`PageStore::take_round_stats`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct PageRoundStats {
    pub pages_loaded: u64,
    /// Total seconds spent reading + verifying pages (I/O worker time).
    pub load_secs: f64,
    /// Seconds the accumulator actually blocked waiting for a page; the
    /// difference `load_secs − wait_secs` is the I/O latency hidden by
    /// prefetch.
    pub wait_secs: f64,
    pub peak_resident_bytes: usize,
}

/// A sealed, spilled shard: the page index plus an open handle on the
/// page file. All reads go through [`PageStore::load_page`]; the file is
/// removed on drop.
pub struct PageStore {
    path: PathBuf,
    file: Mutex<File>,
    metas: Vec<PageMeta>,
    pub shape: PageShape,
    /// Fixed row count of every page except possibly the last.
    pub page_rows: usize,
    /// Resident-page budget this store was built under (≥ 1).
    pub max_resident_pages: usize,
    resident: Arc<ResidentCounters>,
    loads: LoadCounters,
    /// One-slot row cursor for random-access readers (the partitioner's
    /// [`BinSource`](crate::tree::partitioner::BinSource) path): rows are
    /// visited in ascending order there, so a single cached handle turns
    /// per-row access into one load per page. The old page is dropped
    /// *before* the next loads, keeping this path at one resident page.
    row_cache: Mutex<Option<PageHandle>>,
}

impl std::fmt::Debug for PageStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PageStore")
            .field("path", &self.path)
            .field("pages", &self.metas.len())
            .field("page_rows", &self.page_rows)
            .field("shape", &self.shape)
            .finish()
    }
}

/// Delete a spill page file and, when its parent is a coordinator-owned
/// spill dir (never an arbitrary caller directory like `$TMPDIR`
/// itself), the dir too once the last sibling's file is gone
/// (`remove_dir` fails while non-empty — that's fine).
fn cleanup_spill_file(path: &Path) {
    let _ = std::fs::remove_file(path);
    if let Some(dir) = path.parent() {
        let owned = dir
            .file_name()
            .and_then(|n| n.to_str())
            .is_some_and(|n| n.starts_with(SPILL_DIR_PREFIX));
        if owned {
            let _ = std::fs::remove_dir(dir);
        }
    }
}

/// Name prefix of per-coordinator spill directories — the marker
/// [`cleanup_spill_file`] uses to tell dirs this module owns apart from
/// caller-provided locations.
pub const SPILL_DIR_PREFIX: &str = "xgb_tpu_spill_";

impl Drop for PageStore {
    fn drop(&mut self) {
        cleanup_spill_file(&self.path);
    }
}

impl PageStore {
    pub fn n_pages(&self) -> usize {
        self.metas.len()
    }

    pub fn n_rows(&self) -> usize {
        self.shape.n_rows
    }

    /// Page index holding shard row `row`.
    #[inline]
    pub fn page_of_row(&self, row: usize) -> usize {
        row / self.page_rows
    }

    /// Total packed bytes across all pages — the *spilled* size (what a
    /// fully resident `CompressedMatrix` of this shard would occupy,
    /// modulo per-page pad words).
    pub fn spilled_bytes(&self) -> usize {
        self.metas.iter().map(|m| m.words * 8).sum()
    }

    /// Largest single page's packed bytes — the `page_bytes` factor of
    /// the peak-memory bound `max_resident_pages × page_bytes`.
    pub fn max_page_bytes(&self) -> usize {
        self.metas.iter().map(|m| m.words * 8).max().unwrap_or(0)
    }

    /// Currently resident packed bytes (live [`PageHandle`]s).
    pub fn resident_bytes(&self) -> usize {
        self.resident.resident.load(Ordering::Relaxed)
    }

    /// Read, verify and account one page. The returned handle keeps the
    /// page's bytes resident until dropped.
    pub fn load_page(&self, index: usize) -> Result<PageHandle> {
        let t = Instant::now();
        let meta = *self
            .metas
            .get(index)
            .with_context(|| format!("page {index} out of range ({})", self.metas.len()))?;
        // decode straight into the word vector through a small staging
        // buffer: during a load only ~1x page_bytes of packed data exist
        // (plus 8 KB scratch), keeping the measured residency honest
        // against the `max_resident_pages × page_bytes` bound
        let mut header_buf = [0u8; 40];
        let mut words = vec![0u64; meta.words];
        {
            let mut file = self.file.lock().unwrap();
            file.seek(SeekFrom::Start(meta.offset))
                .with_context(|| format!("seeking page {index} in {}", self.path.display()))?;
            file.read_exact(&mut header_buf)
                .with_context(|| format!("reading page {index} from {}", self.path.display()))?;
            let mut staged = [0u8; 8192];
            let mut filled = 0usize;
            while filled < meta.words {
                let take = (meta.words - filled).min(staged.len() / 8);
                let bytes = &mut staged[..take * 8];
                file.read_exact(bytes).with_context(|| {
                    format!("reading page {index} payload from {}", self.path.display())
                })?;
                for (k, c) in bytes.chunks_exact(8).enumerate() {
                    words[filled + k] = u64::from_le_bytes(c.try_into().unwrap());
                }
                filled += take;
            }
        }
        let header: Vec<u64> = header_buf
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        ensure!(
            header[0] == PAGE_MAGIC,
            "page {index} of {}: bad magic {:#x}",
            self.path.display(),
            header[0]
        );
        ensure!(
            header[1] as usize == meta.rows
                && header[2] == self.shape.symbol_bits as u64
                && header[3] as usize == meta.words,
            "page {index} of {}: header disagrees with the page table",
            self.path.display()
        );
        let sum = checksum64(&words);
        if sum != meta.checksum || sum != header[4] {
            bail!(
                "page {index} of {} is corrupted: checksum {sum:#x} != recorded {:#x}",
                self.path.display(),
                meta.checksum
            );
        }
        let bytes = words.len() * 8;
        let resident = self.resident.resident.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.resident.peak.fetch_max(resident, Ordering::Relaxed);
        let page = Arc::new(LoadedPage {
            matrix: CompressedMatrix::from_words(
                words,
                self.shape.symbol_bits,
                meta.rows,
                self.shape.n_features,
                self.shape.row_stride,
                self.shape.n_bins,
                self.shape.dense,
            ),
            first_row: index * self.page_rows,
            index,
            bytes,
            counters: Arc::clone(&self.resident),
        });
        self.loads.pages_loaded.fetch_add(1, Ordering::Relaxed);
        self.loads
            .load_nanos
            .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok(page)
    }

    /// Record seconds a consumer spent blocked waiting for a page (the
    /// paged histogram builder calls this around its prefetch receives).
    pub fn note_wait(&self, secs: f64) {
        self.loads
            .wait_nanos
            .fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
    }

    /// Random-access row read through the one-slot cursor cache — the
    /// repartition path. Drops the previously cached page before loading
    /// the next, so this path never holds more than one page resident.
    pub fn page_for_row(&self, row: usize) -> Result<PageHandle> {
        let index = self.page_of_row(row);
        let mut cache = self.row_cache.lock().unwrap();
        if let Some(h) = cache.as_ref() {
            if h.index == index {
                return Ok(Arc::clone(h));
            }
        }
        *cache = None; // release before loading: ≤ 1 resident on this path
        let h = self.load_page(index)?;
        *cache = Some(Arc::clone(&h));
        Ok(h)
    }

    /// Drop the row cursor's cached page (called before a histogram round
    /// so the round's prefetch queue owns the whole residency budget).
    pub fn clear_row_cache(&self) {
        *self.row_cache.lock().unwrap() = None;
    }

    /// Drain the per-round counters (the peak resets to the *current*
    /// residency so per-tree maxima accumulate correctly).
    pub fn take_round_stats(&self) -> PageRoundStats {
        let stats = PageRoundStats {
            pages_loaded: self.loads.pages_loaded.swap(0, Ordering::Relaxed),
            load_secs: self.loads.load_nanos.swap(0, Ordering::Relaxed) as f64 / 1e9,
            wait_secs: self.loads.wait_nanos.swap(0, Ordering::Relaxed) as f64 / 1e9,
            peak_resident_bytes: self.resident.peak.load(Ordering::Relaxed),
        };
        self.resident
            .peak
            .store(self.resident.resident.load(Ordering::Relaxed), Ordering::Relaxed);
        stats
    }
}

/// Streaming page-file writer: appends sealed pages, accumulating the
/// in-memory page table [`PageFileWriter::finish`] hands to the store.
/// A writer dropped **without** `finish` (an ingestion error path)
/// deletes its partially written file, so failed runs leave no spill
/// litter behind; after `finish` the [`PageStore`] owns the cleanup.
pub struct PageFileWriter {
    /// `None` after `finish` hands ownership (and cleanup) to the store.
    path: Option<PathBuf>,
    out: Option<BufWriter<File>>,
    metas: Vec<PageMeta>,
    offset: u64,
    symbol_bits: u32,
}

impl Drop for PageFileWriter {
    fn drop(&mut self) {
        if let Some(path) = self.path.take() {
            drop(self.out.take()); // close before unlinking
            cleanup_spill_file(&path);
        }
    }
}

impl PageFileWriter {
    pub fn create(path: impl AsRef<Path>, symbol_bits: u32) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let file = OpenOptions::new()
            .create(true)
            .truncate(true)
            .read(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("creating page file {}", path.display()))?;
        Ok(PageFileWriter {
            path: Some(path),
            out: Some(BufWriter::new(file)),
            metas: Vec::new(),
            offset: 0,
            symbol_bits,
        })
    }

    /// Spill one sealed page (a [`CompressedMatrix`] over the page's rows,
    /// packed from bit 0 — what [`CompressedMatrixBuilder::finish`]
    /// produces for the row slice).
    pub fn write_page(&mut self, page: &CompressedMatrix) -> Result<()> {
        ensure!(
            page.symbol_bits == self.symbol_bits,
            "page symbol width {} != shard width {}",
            page.symbol_bits,
            self.symbol_bits
        );
        let words = page.words();
        let checksum = checksum64(words);
        let header = [
            PAGE_MAGIC,
            page.n_rows as u64,
            self.symbol_bits as u64,
            words.len() as u64,
            checksum,
        ];
        let out = self.out.as_mut().expect("writer already finished");
        for h in header {
            out.write_all(&h.to_le_bytes())?;
        }
        for w in words {
            out.write_all(&w.to_le_bytes())?;
        }
        self.metas.push(PageMeta {
            offset: self.offset,
            rows: page.n_rows,
            words: words.len(),
            checksum,
        });
        self.offset += 40 + words.len() as u64 * 8;
        Ok(())
    }

    /// Flush and seal the file into a readable [`PageStore`] (which takes
    /// over deleting it on drop).
    pub fn finish(
        mut self,
        shape: PageShape,
        page_rows: usize,
        max_resident_pages: usize,
    ) -> Result<PageStore> {
        ensure!(page_rows >= 1, "page_rows must be >= 1");
        ensure!(max_resident_pages >= 1, "max_resident_pages must be >= 1");
        let mut out = self.out.take().expect("writer already finished");
        out.flush()?;
        let file = out
            .into_inner()
            .map_err(|e| anyhow::anyhow!("flushing page file: {e}"))?;
        Ok(PageStore {
            // taking the path disarms this writer's Drop cleanup
            path: self.path.take().expect("writer already finished"),
            file: Mutex::new(file),
            metas: std::mem::take(&mut self.metas),
            shape,
            page_rows,
            max_resident_pages,
            resident: Arc::new(ResidentCounters::default()),
            loads: LoadCounters::default(),
            row_cache: Mutex::new(None),
        })
    }
}

/// Run `consume` with an **in-order page fetcher** backed by the
/// double-buffered prefetch pipeline every paged phase shares (the paged
/// histogram build and paged prediction): with `exec.threads() > 1` and
/// a budget of at least two pages, an I/O worker loads the pages of
/// `seq` ahead of the consumer over a bounded channel of capacity
/// `max_resident_pages − 2` — queue + the load in flight + the page
/// being consumed = the budget. Serial engines, a budget of one page, or
/// a single-page schedule load synchronously (one page resident at a
/// time). The fetcher must be called with exactly the pages of `seq` in
/// order (it verifies and errors on divergence); load and blocked-wait
/// seconds land on the store's round counters either way. The
/// repartition cursor's cached page is released first so the schedule
/// owns the whole residency allowance.
pub fn with_prefetched_pages<R: Send>(
    store: &PageStore,
    exec: &crate::exec::ExecContext,
    seq: Vec<usize>,
    consume: impl FnOnce(&mut dyn FnMut(usize) -> Result<PageHandle>) -> Result<R> + Send,
) -> Result<R> {
    store.clear_row_cache();
    let budget = store.max_resident_pages;
    if exec.threads() > 1 && budget >= 2 && seq.len() > 1 {
        let cap = budget - 2;
        let (tx, rx) = std::sync::mpsc::sync_channel::<Result<PageHandle>>(cap);
        exec.run_with_worker(
            move || {
                for p in seq {
                    if tx.send(store.load_page(p)).is_err() {
                        break; // consumer bailed (error path); stop loading
                    }
                }
            },
            move || {
                let mut fetch = |want: usize| -> Result<PageHandle> {
                    let t = Instant::now();
                    let page = rx
                        .recv()
                        .map_err(|_| anyhow::anyhow!("page prefetch worker exited early"))??;
                    store.note_wait(t.elapsed().as_secs_f64());
                    ensure!(
                        page.index == want,
                        "prefetch schedule diverged: got page {}, want {want}",
                        page.index
                    );
                    Ok(page)
                };
                consume(&mut fetch)
            },
        )
    } else {
        let mut fetch = |want: usize| -> Result<PageHandle> {
            let t = Instant::now();
            let page = store.load_page(want)?;
            store.note_wait(t.elapsed().as_secs_f64());
            Ok(page)
        };
        consume(&mut fetch)
    }
}

/// Row-append packer that seals fixed-row-count pages straight into a
/// spill file — the external-memory twin of [`CompressedMatrixBuilder`]
/// (pass 2 of the streaming pipeline pushes rows here when a
/// `max_resident_pages` budget is set, so the full packed shard never
/// materializes in RAM either).
pub struct PagedMatrixBuilder {
    writer: PageFileWriter,
    current: CompressedMatrixBuilder,
    shape: PageShape,
    page_rows: usize,
    max_resident_pages: usize,
    rows_pushed: usize,
}

impl PagedMatrixBuilder {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        path: impl AsRef<Path>,
        n_rows: usize,
        n_features: usize,
        row_stride: usize,
        n_bins: usize,
        dense: bool,
        page_rows: usize,
        max_resident_pages: usize,
    ) -> Result<Self> {
        ensure!(page_rows >= 1, "page_rows must be >= 1");
        ensure!(max_resident_pages >= 1, "max_resident_pages must be >= 1");
        let symbol_bits = bits_for_symbols(n_bins + 1);
        let shape = PageShape {
            n_rows,
            n_features,
            row_stride,
            n_bins,
            dense,
            symbol_bits,
        };
        Ok(PagedMatrixBuilder {
            writer: PageFileWriter::create(path, symbol_bits)?,
            current: CompressedMatrixBuilder::new(
                page_rows.min(n_rows.max(1)),
                n_features,
                row_stride,
                n_bins,
                dense,
            ),
            shape,
            page_rows,
            max_resident_pages,
            rows_pushed: 0,
        })
    }

    /// Append one row (padded to the stride exactly as the in-memory
    /// builder pads); seals and spills the page when it fills.
    pub fn push_row(&mut self, symbols: &[u32]) -> Result<()> {
        ensure!(
            self.rows_pushed < self.shape.n_rows,
            "paged builder received more rows than declared ({})",
            self.shape.n_rows
        );
        self.current.push_row(symbols);
        self.rows_pushed += 1;
        if self.current.rows_filled() == self.current.n_rows() {
            self.seal_page()?;
        }
        Ok(())
    }

    fn seal_page(&mut self) -> Result<()> {
        let remaining = self.shape.n_rows - self.rows_pushed;
        let next = CompressedMatrixBuilder::new(
            self.page_rows.min(remaining.max(1)),
            self.shape.n_features,
            self.shape.row_stride,
            self.shape.n_bins,
            self.shape.dense,
        );
        let sealed = std::mem::replace(&mut self.current, next).finish();
        self.writer.write_page(&sealed)
    }

    pub fn rows_filled(&self) -> usize {
        self.rows_pushed
    }

    /// Seal any trailing partial page and open the store for reading.
    pub fn finish(mut self) -> Result<PageStore> {
        ensure!(
            self.rows_pushed == self.shape.n_rows,
            "paged builder finished with {} of {} rows",
            self.rows_pushed,
            self.shape.n_rows
        );
        if self.current.rows_filled() > 0 {
            let sealed = self.current.finish();
            self.writer.write_page(&sealed)?;
        }
        self.writer
            .finish(self.shape, self.page_rows, self.max_resident_pages)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quantile::QuantizedMatrix;
    use crate::util::prop::{check, Gen};

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("xgb_tpu_page_{name}_{}", std::process::id()))
    }

    fn random_qm(g: &mut Gen, n_rows: usize, n_cols: usize, n_bins: usize) -> QuantizedMatrix {
        // dense alphabet of n_bins real symbols + the null symbol; rows
        // carry arbitrary symbols incl. null so padding round-trips too
        let bins: Vec<u32> = (0..n_rows * n_cols)
            .map(|_| g.int(0, n_bins) as u32)
            .collect();
        QuantizedMatrix {
            bins,
            n_rows,
            n_features: n_cols,
            row_stride: n_cols,
            n_bins,
            dense: true,
        }
    }

    fn spill(qm: &QuantizedMatrix, page_rows: usize, path: &Path) -> PageStore {
        let mut b = PagedMatrixBuilder::new(
            path,
            qm.n_rows,
            qm.n_features,
            qm.row_stride,
            qm.n_bins,
            qm.dense,
            page_rows,
            2,
        )
        .unwrap();
        for r in 0..qm.n_rows {
            b.push_row(qm.row(r)).unwrap();
        }
        b.finish().unwrap()
    }

    #[test]
    fn page_words_bit_exact_vs_from_quantized() {
        // property: for random (rows, cols, bit-width, page size), every
        // spilled page's words equal from_quantized over the row slice
        check(0x9a6e, 40, |g| {
            let n_rows = g.int(1, 200);
            let n_cols = g.int(1, 12);
            // bit-width via the bin count: 1..=4097 symbols -> 1..13 bits
            let n_bins = g.int(1, 1 << g.int(0, 12));
            let page_rows = g.int(1, n_rows + 3);
            let qm = random_qm(g, n_rows, n_cols, n_bins);
            let path = tmp(&format!("prop_{}", g.case));
            let store = spill(&qm, page_rows, &path);
            assert_eq!(store.n_pages(), n_rows.div_ceil(page_rows));
            for p in 0..store.n_pages() {
                let lo = p * page_rows;
                let hi = (lo + page_rows).min(n_rows);
                let slice = QuantizedMatrix {
                    bins: qm.bins[lo * qm.row_stride..hi * qm.row_stride].to_vec(),
                    n_rows: hi - lo,
                    n_features: qm.n_features,
                    row_stride: qm.row_stride,
                    n_bins: qm.n_bins,
                    dense: qm.dense,
                };
                let reference = CompressedMatrix::from_quantized(&slice);
                let loaded = store.load_page(p).unwrap();
                assert_eq!(
                    loaded.matrix.words(),
                    reference.words(),
                    "page {p}: spilled words must be bit-exact vs from_quantized"
                );
                assert_eq!(loaded.matrix.symbol_bits, reference.symbol_bits);
                assert_eq!(loaded.first_row, lo);
                assert_eq!(loaded.matrix.decode().bins, slice.bins);
            }
        });
    }

    #[test]
    fn corrupted_checksum_rejected() {
        let mut g = Gen {
            rng: crate::util::Pcg64::new(77),
            case: 0,
        };
        let qm = random_qm(&mut g, 64, 5, 15);
        let path = tmp("corrupt");
        let store = spill(&qm, 16, &path);
        assert!(store.load_page(1).is_ok());
        // flip one byte inside page 1's payload
        let meta = store.metas[1];
        {
            let mut f = OpenOptions::new().read(true).write(true).open(&path).unwrap();
            f.seek(SeekFrom::Start(meta.offset + 40 + 3)).unwrap();
            let mut b = [0u8; 1];
            f.read_exact(&mut b).unwrap();
            f.seek(SeekFrom::Start(meta.offset + 40 + 3)).unwrap();
            f.write_all(&[b[0] ^ 0xff]).unwrap();
        }
        let err = store.load_page(1).unwrap_err();
        assert!(format!("{err:#}").contains("corrupt"), "{err:#}");
        // untouched pages still load
        assert!(store.load_page(0).is_ok());
    }

    #[test]
    fn residency_is_accounted_and_released() {
        let mut g = Gen {
            rng: crate::util::Pcg64::new(78),
            case: 0,
        };
        let qm = random_qm(&mut g, 100, 4, 7);
        let path = tmp("resident");
        let store = spill(&qm, 32, &path);
        assert_eq!(store.resident_bytes(), 0);
        let a = store.load_page(0).unwrap();
        let b = store.load_page(1).unwrap();
        assert_eq!(store.resident_bytes(), a.bytes + b.bytes);
        drop(a);
        assert_eq!(store.resident_bytes(), b.bytes);
        drop(b);
        assert_eq!(store.resident_bytes(), 0);
        let stats = store.take_round_stats();
        assert_eq!(stats.pages_loaded, 2);
        assert!(stats.peak_resident_bytes > 0);
        assert!(stats.peak_resident_bytes <= 2 * store.max_page_bytes());
    }

    #[test]
    fn row_cursor_holds_one_page() {
        let mut g = Gen {
            rng: crate::util::Pcg64::new(79),
            case: 0,
        };
        let qm = random_qm(&mut g, 90, 3, 5);
        let path = tmp("cursor");
        let store = spill(&qm, 16, &path);
        for row in 0..qm.n_rows {
            let h = store.page_for_row(row).unwrap();
            let local = row - h.first_row;
            for s in 0..qm.row_stride {
                assert_eq!(
                    h.matrix.symbol(local * qm.row_stride + s),
                    qm.bins[row * qm.row_stride + s],
                    "row {row} slot {s}"
                );
            }
            drop(h);
            // cursor cache + nothing else => at most one page resident
            assert!(store.resident_bytes() <= store.max_page_bytes());
        }
        store.clear_row_cache();
        assert_eq!(store.resident_bytes(), 0);
    }

    #[test]
    fn abandoned_writer_removes_partial_file() {
        // ingestion error path: a builder dropped without finish() must
        // not leave spill litter behind
        let mut g = Gen {
            rng: crate::util::Pcg64::new(81),
            case: 0,
        };
        let qm = random_qm(&mut g, 40, 3, 5);
        let path = tmp("abandoned");
        let mut b = PagedMatrixBuilder::new(
            &path, qm.n_rows, qm.n_features, qm.row_stride, qm.n_bins, qm.dense, 8, 2,
        )
        .unwrap();
        for r in 0..qm.n_rows / 2 {
            b.push_row(qm.row(r)).unwrap();
        }
        assert!(path.exists());
        drop(b); // no finish(): simulated pass-2 failure
        assert!(!path.exists(), "partial spill file must be deleted");
    }

    #[test]
    fn spill_file_removed_on_drop() {
        let mut g = Gen {
            rng: crate::util::Pcg64::new(80),
            case: 0,
        };
        let qm = random_qm(&mut g, 20, 2, 3);
        let path = tmp("cleanup");
        let store = spill(&qm, 8, &path);
        assert!(path.exists());
        drop(store);
        assert!(!path.exists(), "page file must be deleted with the store");
    }
}
