//! Multi-symbol block decoder for the bit-packed symbol stream — the
//! §2.2 "unpacked at runtime using bitwise operations" hot path,
//! restructured for data-level parallelism.
//!
//! The scalar decoder ([`super::CompressedMatrix::symbol_scalar`])
//! re-derives a bit cursor and reassembles a u128 double-word window per
//! symbol. This module instead reads each 64-bit word **once** and emits
//! all `floor(64 / symbol_bits)` symbols it fully contains via a shift
//! cascade (`cur >>= bits; cur & mask` — plain u64 ops the compiler can
//! unroll and keep in registers), falling back to a two-word remainder
//! path only for the one symbol per word that may straddle the boundary.
//! For 8-bit symbols that is one word load + 8 shift/mask pairs instead
//! of 8 independent u128 reconstructions.
//!
//! Both entry points require the packing invariant every constructor in
//! [`super`] maintains: `words` carries one trailing pad word, so reading
//! `words[word + 1]` is in bounds for every valid symbol index.

/// Decode `out.len()` consecutive symbols starting at flat symbol index
/// `start` into `out`. `mask == (1 << symbol_bits) - 1` (hoisted by the
/// caller; [`super::CompressedMatrix`] stores it at construction).
///
/// Exactly equivalent to `out[i] = unpack_one(words, bits, mask,
/// start + i)` — pinned by the tests below and by the cross-width
/// property test in `rust/tests/prop_invariants.rs`.
pub fn unpack_block(words: &[u64], symbol_bits: u32, mask: u64, start: usize, out: &mut [u32]) {
    debug_assert!(symbol_bits >= 1 && symbol_bits <= 32);
    debug_assert!(
        out.is_empty()
            || (start + out.len()) as u64 * symbol_bits as u64 <= (words.len() as u64 - 1) * 64,
        "symbol range must fit the padded word stream"
    );
    let bits = symbol_bits as u64;
    let mut bit = start as u64 * bits;
    let mut i = 0usize;
    while i < out.len() {
        let word = (bit >> 6) as usize;
        let off = (bit & 63) as u32;
        let avail = 64 - off;
        let lo = words[word] >> off;
        let n_full = (avail / symbol_bits) as usize;
        if n_full == 0 {
            // Straddle: `avail ∈ [1, 63]` low bits of the symbol sit at
            // the top of this word, the rest at the bottom of the next
            // (the pad word guarantees `word + 1` is in bounds).
            out[i] = ((lo | (words[word + 1] << avail)) & mask) as u32;
            i += 1;
            bit += bits;
            continue;
        }
        // Shift cascade: every symbol fully inside this word, one shift +
        // mask each, no second word touched.
        let n = n_full.min(out.len() - i);
        let mut cur = lo;
        for o in &mut out[i..i + n] {
            *o = (cur & mask) as u32;
            cur >>= symbol_bits;
        }
        i += n;
        bit += n as u64 * bits;
    }
}

/// Random-access single-symbol unpack via a branch-free two-word read —
/// no u128: the high word contributes `(hi << 1) << (63 - off)`, which is
/// `hi << (64 - off)` for `off ≥ 1` and exactly 0 for `off == 0`, so the
/// shift amount never reaches 64.
#[inline(always)]
pub fn unpack_one(words: &[u64], symbol_bits: u32, mask: u64, i: usize) -> u32 {
    let bit = i as u64 * symbol_bits as u64;
    let word = (bit >> 6) as usize;
    let off = (bit & 63) as u32;
    // Safety: every constructor pads the stream with one trailing word,
    // so `word + 1` is in bounds for every valid symbol index.
    let (lo, hi) = unsafe { (*words.get_unchecked(word), *words.get_unchecked(word + 1)) };
    (((lo >> off) | ((hi << 1) << (63 - off))) & mask) as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    /// Reference: gather the symbol's bits one at a time.
    fn bit_gather(words: &[u64], bits: u32, i: usize) -> u32 {
        let mut v = 0u64;
        for b in 0..bits as u64 {
            let pos = i as u64 * bits as u64 + b;
            let w = (pos / 64) as usize;
            let o = pos % 64;
            v |= ((words[w] >> o) & 1) << b;
        }
        v as u32
    }

    fn pack(symbols: &[u32], bits: u32) -> Vec<u64> {
        let total_bits = symbols.len() as u64 * bits as u64;
        let mut words = vec![0u64; total_bits.div_ceil(64) as usize + 1];
        for (i, &sym) in symbols.iter().enumerate() {
            let bit = i as u64 * bits as u64;
            let word = (bit / 64) as usize;
            let off = (bit % 64) as u32;
            words[word] |= (sym as u64) << off;
            if off + bits > 64 {
                words[word + 1] |= (sym as u64) >> (64 - off);
            }
        }
        words
    }

    #[test]
    fn one_and_block_match_reference_across_widths() {
        let mut rng = Pcg64::new(42);
        for bits in [1u32, 3, 5, 8, 9, 13, 17, 20, 31, 32] {
            let mask = ((1u128 << bits) - 1) as u64;
            let n = 500;
            let symbols: Vec<u32> =
                (0..n).map(|_| (rng.next_u64() & mask) as u32).collect();
            let words = pack(&symbols, bits);
            for (i, &want) in symbols.iter().enumerate() {
                assert_eq!(unpack_one(&words, bits, mask, i), want, "bits={bits} i={i}");
                assert_eq!(bit_gather(&words, bits, i), want, "reference self-check");
            }
            let mut out = vec![0u32; n];
            unpack_block(&words, bits, mask, 0, &mut out);
            assert_eq!(out, symbols, "bits={bits} full-stream block");
        }
    }

    #[test]
    fn block_decode_at_odd_starts_and_lengths() {
        let mut rng = Pcg64::new(7);
        for bits in [5u32, 9, 13] {
            let mask = (1u64 << bits) - 1;
            let symbols: Vec<u32> =
                (0..300).map(|_| (rng.next_u64() & mask) as u32).collect();
            let words = pack(&symbols, bits);
            for start in [0usize, 1, 4, 12, 63, 64, 65, 127, 200] {
                for len in [0usize, 1, 2, 7, 8, 9, 64, 100] {
                    if start + len > symbols.len() {
                        continue;
                    }
                    let mut out = vec![u32::MAX; len];
                    unpack_block(&words, bits, mask, start, &mut out);
                    assert_eq!(
                        out,
                        &symbols[start..start + len],
                        "bits={bits} start={start} len={len}"
                    );
                }
            }
        }
    }

    #[test]
    fn straddle_path_exercised_every_offset() {
        // 13-bit symbols cycle through all 64 phase offsets every 64
        // symbols, hitting the straddle remainder path repeatedly
        let bits = 13u32;
        let mask = (1u64 << bits) - 1;
        let symbols: Vec<u32> = (0..256).map(|i| (i * 31 + 7) as u32 & mask as u32).collect();
        let words = pack(&symbols, bits);
        let mut out = vec![0u32; symbols.len()];
        unpack_block(&words, bits, mask, 0, &mut out);
        assert_eq!(out, symbols);
    }
}
