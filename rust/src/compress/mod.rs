//! Data compression of the quantised matrix (paper §2.2).
//!
//! "Matrix values are compressed down to `log2(max_value)` bits, where
//! `max_value` is the maximum integer value of any quantised matrix
//! element. Values are packed and unpacked at runtime using bitwise
//! operations." — this module is exactly that: a fixed-width bit-packed
//! symbol stream over the ELLPACK matrix's alphabet (`n_bins` real symbols
//! plus the null/padding symbol), with branch-free unpacking on the hot
//! path and a streaming iterator used by the histogram builder.
//!
//! Decoding is centralised in [`unpack`]: a block decoder that reads each
//! packed word once and emits its symbols via a shift cascade. The
//! scalar per-symbol decoders (`symbol_scalar`,
//! `for_each_symbol_in_row_scalar`) are kept as the independent reference
//! implementation the parity tests compare against (and the
//! `XGB_SCALAR_KERNELS=1` escape hatch runs on — see
//! [`crate::exec::KernelMode`]).
//!
//! With 256 bins/feature and a few dozen features the symbol width is
//! 10–15 bits vs 32 for the raw float (or u32 bin) representation — the
//! "four times or more" memory reduction the paper reports, measured by
//! `benches/memory_footprint.rs`.

use crate::quantile::QuantizedMatrix;

pub mod page;
pub mod unpack;

/// Bit-packed ELLPACK matrix.
#[derive(Debug, Clone)]
pub struct CompressedMatrix {
    /// Packed little-endian bit stream in 64-bit words.
    words: Vec<u64>,
    /// Bits per symbol = ⌈log2(n_symbols)⌉.
    pub symbol_bits: u32,
    /// `(1 << symbol_bits) - 1`, hoisted at construction so the decode
    /// hot loops never recompute it.
    mask: u64,
    pub n_rows: usize,
    pub n_features: usize,
    pub row_stride: usize,
    pub n_bins: usize,
    pub dense: bool,
}

/// Number of bits needed for `n_symbols` distinct symbols.
#[inline]
pub fn bits_for_symbols(n_symbols: usize) -> u32 {
    debug_assert!(n_symbols >= 1);
    usize::BITS - (n_symbols - 1).max(1).leading_zeros()
}

/// `(1 << symbol_bits) - 1` without overflow at the full word width.
#[inline]
fn symbol_mask(symbol_bits: u32) -> u64 {
    debug_assert!((1..=64).contains(&symbol_bits));
    u64::MAX >> (64 - symbol_bits)
}

impl CompressedMatrix {
    /// Pack a quantised matrix. Symbols must all be `< qm.n_symbols()`.
    pub fn from_quantized(qm: &QuantizedMatrix) -> Self {
        let symbol_bits = bits_for_symbols(qm.n_symbols());
        let total_symbols = qm.n_rows * qm.row_stride;
        let total_bits = total_symbols as u64 * symbol_bits as u64;
        let n_words = total_bits.div_ceil(64) as usize;
        let mut words = vec![0u64; n_words + 1]; // +1 pad word for branch-free reads
        for (i, &sym) in qm.bins.iter().enumerate() {
            debug_assert!((sym as usize) < qm.n_symbols());
            let bit = i as u64 * symbol_bits as u64;
            let word = (bit / 64) as usize;
            let off = (bit % 64) as u32;
            words[word] |= (sym as u64) << off;
            if off + symbol_bits > 64 {
                words[word + 1] |= (sym as u64) >> (64 - off);
            }
        }
        CompressedMatrix {
            words,
            symbol_bits,
            mask: symbol_mask(symbol_bits),
            n_rows: qm.n_rows,
            n_features: qm.n_features,
            row_stride: qm.row_stride,
            n_bins: qm.n_bins,
            dense: qm.dense,
        }
    }

    /// Reassemble from raw packed words (the external-memory page loader;
    /// `words` must carry the trailing pad word and use the exact layout
    /// of [`CompressedMatrix::from_quantized`]).
    pub fn from_words(
        words: Vec<u64>,
        symbol_bits: u32,
        n_rows: usize,
        n_features: usize,
        row_stride: usize,
        n_bins: usize,
        dense: bool,
    ) -> Self {
        let total_bits = (n_rows * row_stride) as u64 * symbol_bits as u64;
        assert!(
            words.len() == total_bits.div_ceil(64) as usize + 1,
            "word count {} does not match shape ({} rows x {} stride x {} bits)",
            words.len(),
            n_rows,
            row_stride,
            symbol_bits
        );
        CompressedMatrix {
            words,
            symbol_bits,
            mask: symbol_mask(symbol_bits),
            n_rows,
            n_features,
            row_stride,
            n_bins,
            dense,
        }
    }

    /// The packed little-endian word stream (incl. the trailing pad word).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    #[inline]
    pub fn null_symbol(&self) -> u32 {
        self.n_bins as u32
    }

    /// Unpack the symbol at flat index `i` (the §2.2 "unpacked at runtime
    /// using bitwise operations") — a branch-free two-word read through
    /// [`unpack::unpack_one`] with the construction-time mask.
    #[inline(always)]
    pub fn symbol(&self, i: usize) -> u32 {
        unpack::unpack_one(&self.words, self.symbol_bits, self.mask, i)
    }

    /// Scalar reference decoder: the original u128 double-word window
    /// reconstruction, kept verbatim as the implementation the block
    /// decoder is tested against (and as the `XGB_SCALAR_KERNELS=1`
    /// reference path).
    #[inline(always)]
    pub fn symbol_scalar(&self, i: usize) -> u32 {
        let bit = i as u64 * self.symbol_bits as u64;
        let word = (bit >> 6) as usize;
        let off = (bit & 63) as u32;
        // Safety: `words` always carries one pad word at the end, so
        // `word + 1` is in bounds for every valid symbol index.
        let (lo, hi) = unsafe {
            (
                *self.words.get_unchecked(word),
                *self.words.get_unchecked(word + 1),
            )
        };
        let pair = lo as u128 | ((hi as u128) << 64);
        ((pair >> off) as u64 & self.mask) as u32
    }

    /// Decode the symbols of row `row` in slot order through the block
    /// decoder (a small stack buffer amortises each word read across its
    /// symbols). `f` receives each slot's symbol in order.
    #[inline]
    pub fn for_each_symbol_in_row(&self, row: usize, mut f: impl FnMut(u32)) {
        let mut buf = [0u32; 64];
        let start = row * self.row_stride;
        let mut done = 0usize;
        while done < self.row_stride {
            let n = (self.row_stride - done).min(buf.len());
            unpack::unpack_block(&self.words, self.symbol_bits, self.mask, start + done, &mut buf[..n]);
            for &s in &buf[..n] {
                f(s);
            }
            done += n;
        }
    }

    /// Scalar reference twin of [`for_each_symbol_in_row`](Self::for_each_symbol_in_row):
    /// a running bit cursor with one u128 window per symbol.
    #[inline]
    pub fn for_each_symbol_in_row_scalar(&self, row: usize, mut f: impl FnMut(u32)) {
        let base = row * self.row_stride;
        for s in 0..self.row_stride {
            f(self.symbol_scalar(base + s));
        }
    }

    /// Unpack `(row, slot)`; `None` for padding.
    #[inline]
    pub fn get(&self, row: usize, slot: usize) -> Option<u32> {
        let s = self.symbol(row * self.row_stride + slot);
        if s == self.null_symbol() {
            None
        } else {
            Some(s)
        }
    }

    /// Decode an entire row into `out` (length `row_stride`), including
    /// null symbols — one block-decode call over the row's contiguous
    /// symbol range.
    #[inline]
    pub fn decode_row_into(&self, row: usize, out: &mut [u32]) {
        debug_assert_eq!(out.len(), self.row_stride);
        unpack::unpack_block(
            &self.words,
            self.symbol_bits,
            self.mask,
            row * self.row_stride,
            out,
        );
    }

    /// Decode `n_rows` **consecutive** rows starting at `first_row` into
    /// `out` (length `n_rows * row_stride`) — consecutive rows form one
    /// contiguous symbol range, so the whole block is a single shift-
    /// cascade pass. The blocked prediction kernels decode
    /// [`crate::exec::BLOCK_ROWS`]-row groups through this.
    #[inline]
    pub fn decode_rows_block(&self, first_row: usize, n_rows: usize, out: &mut [u32]) {
        debug_assert!(first_row + n_rows <= self.n_rows);
        debug_assert_eq!(out.len(), n_rows * self.row_stride);
        unpack::unpack_block(
            &self.words,
            self.symbol_bits,
            self.mask,
            first_row * self.row_stride,
            out,
        );
    }

    /// Fully decode back to a [`QuantizedMatrix`] (tests / parity checks).
    pub fn decode(&self) -> QuantizedMatrix {
        let mut bins = vec![0u32; self.n_rows * self.row_stride];
        unpack::unpack_block(&self.words, self.symbol_bits, self.mask, 0, &mut bins);
        QuantizedMatrix {
            bins,
            n_rows: self.n_rows,
            n_features: self.n_features,
            row_stride: self.row_stride,
            n_bins: self.n_bins,
            dense: self.dense,
        }
    }

    /// Packed size in bytes.
    pub fn bytes(&self) -> usize {
        self.words.len() * std::mem::size_of::<u64>()
    }

    /// Compression ratio vs a dense `f32` ELLPACK of the same stride.
    pub fn ratio_vs_float(&self) -> f64 {
        let float_bytes = (self.n_rows * self.row_stride * 4) as f64;
        float_bytes / self.bytes() as f64
    }

    /// Compression ratio vs the pre-quantisation device representation the
    /// paper's §2.2 "four times or more" is measured against: XGBoost's
    /// GPU CSR entries stored `(u32 column, f32 value)` = 8 bytes per
    /// present element (Mitchell & Frank 2017). One packed symbol replaces
    /// one such entry.
    pub fn ratio_vs_csr_entry(&self) -> f64 {
        let csr_bytes = (self.n_rows * self.row_stride * 8) as f64;
        csr_bytes / self.bytes() as f64
    }

    /// Compression ratio vs the unpacked u32 bin representation.
    pub fn ratio_vs_u32(&self) -> f64 {
        let u32_bytes = (self.n_rows * self.row_stride * 4) as f64;
        u32_bytes / self.bytes() as f64
    }
}

/// Incremental row-by-row packer — the append API of the out-of-core
/// ingestion pipeline (pass 2 bit-packs each streamed batch **directly**
/// into the owning device shard's pages; no `QuantizedMatrix` is ever
/// materialized).
///
/// The word layout is identical to [`CompressedMatrix::from_quantized`]:
/// the packed buffer is preallocated to `ceil(n_rows·row_stride·bits/64)`
/// words plus the branch-free pad word, and symbols are OR-ed at the same
/// bit offsets — so a streamed shard is bit-for-bit equal to packing the
/// materialized matrix (pinned by `streamed_builder_matches_bulk_pack`).
#[derive(Debug, Clone)]
pub struct CompressedMatrixBuilder {
    words: Vec<u64>,
    symbol_bits: u32,
    n_rows: usize,
    n_features: usize,
    row_stride: usize,
    n_bins: usize,
    dense: bool,
    /// Symbols written so far.
    cursor: usize,
}

impl CompressedMatrixBuilder {
    /// Start a packer for a shard of known shape. The alphabet is
    /// `n_bins` real symbols plus the null/padding symbol, exactly as in
    /// [`CompressedMatrix::from_quantized`].
    pub fn new(
        n_rows: usize,
        n_features: usize,
        row_stride: usize,
        n_bins: usize,
        dense: bool,
    ) -> Self {
        let symbol_bits = bits_for_symbols(n_bins + 1);
        let total_bits = (n_rows * row_stride) as u64 * symbol_bits as u64;
        let n_words = total_bits.div_ceil(64) as usize;
        CompressedMatrixBuilder {
            words: vec![0u64; n_words + 1], // +1 pad word for branch-free reads
            symbol_bits,
            n_rows,
            n_features,
            row_stride,
            n_bins,
            dense,
            cursor: 0,
        }
    }

    #[inline]
    fn push_symbol(&mut self, sym: u32) {
        debug_assert!((sym as usize) <= self.n_bins, "symbol out of alphabet");
        let bit = self.cursor as u64 * self.symbol_bits as u64;
        let word = (bit / 64) as usize;
        let off = (bit % 64) as u32;
        self.words[word] |= (sym as u64) << off;
        if off + self.symbol_bits > 64 {
            self.words[word + 1] |= (sym as u64) >> (64 - off);
        }
        self.cursor += 1;
    }

    /// Append one row. Rows shorter than the stride (sparse ELLPACK) are
    /// padded with the null symbol; dense rows must fill the stride.
    pub fn push_row(&mut self, symbols: &[u32]) {
        assert!(
            symbols.len() <= self.row_stride,
            "row has {} symbols but stride is {}",
            symbols.len(),
            self.row_stride
        );
        for &s in symbols {
            self.push_symbol(s);
        }
        let null = self.n_bins as u32;
        for _ in symbols.len()..self.row_stride {
            self.push_symbol(null);
        }
    }

    /// Rows appended so far.
    pub fn rows_filled(&self) -> usize {
        if self.row_stride == 0 {
            0
        } else {
            self.cursor / self.row_stride
        }
    }

    /// Rows this builder was declared for.
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Finish packing; panics if fewer rows were appended than declared.
    pub fn finish(self) -> CompressedMatrix {
        assert_eq!(
            self.cursor,
            self.n_rows * self.row_stride,
            "builder finished with {} of {} symbols",
            self.cursor,
            self.n_rows * self.row_stride
        );
        CompressedMatrix {
            words: self.words,
            symbol_bits: self.symbol_bits,
            mask: symbol_mask(self.symbol_bits),
            n_rows: self.n_rows,
            n_features: self.n_features,
            row_stride: self.row_stride,
            n_bins: self.n_bins,
            dense: self.dense,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DMatrix;
    use crate::quantile::{HistogramCuts, Quantizer};
    use crate::util::Pcg64;
    use crate::Float;

    fn random_quantized(n_rows: usize, n_cols: usize, max_bins: usize, seed: u64) -> QuantizedMatrix {
        let mut rng = Pcg64::new(seed);
        let vals: Vec<Float> = (0..n_rows * n_cols)
            .map(|_| {
                if rng.next_f64() < 0.1 {
                    Float::NAN
                } else {
                    rng.next_f32() * 100.0
                }
            })
            .collect();
        let x = DMatrix::dense(vals, n_rows, n_cols);
        let cuts = HistogramCuts::from_dmatrix(&x, max_bins, None);
        Quantizer::new(cuts).quantize(&x)
    }

    #[test]
    fn bits_for_symbols_exact() {
        assert_eq!(bits_for_symbols(1), 1);
        assert_eq!(bits_for_symbols(2), 1);
        assert_eq!(bits_for_symbols(3), 2);
        assert_eq!(bits_for_symbols(4), 2);
        assert_eq!(bits_for_symbols(5), 3);
        assert_eq!(bits_for_symbols(256), 8);
        assert_eq!(bits_for_symbols(257), 9);
        assert_eq!(bits_for_symbols(1 << 20), 20);
    }

    #[test]
    fn roundtrip_exact() {
        let qm = random_quantized(100, 7, 16, 1);
        let cm = CompressedMatrix::from_quantized(&qm);
        let back = cm.decode();
        assert_eq!(qm.bins, back.bins);
        assert_eq!(qm.row_stride, back.row_stride);
    }

    #[test]
    fn roundtrip_wide_symbols() {
        // force symbol width > 12 bits via many features * many bins
        let qm = random_quantized(400, 40, 256, 2);
        assert!(qm.n_symbols() > (1 << 12));
        let cm = CompressedMatrix::from_quantized(&qm);
        assert_eq!(cm.decode().bins, qm.bins);
    }

    #[test]
    fn get_matches_quantized_get() {
        let qm = random_quantized(64, 5, 8, 3);
        let cm = CompressedMatrix::from_quantized(&qm);
        for r in 0..qm.n_rows {
            for s in 0..qm.row_stride {
                assert_eq!(cm.get(r, s), qm.get(r, s), "({r},{s})");
            }
        }
    }

    #[test]
    fn decode_row_matches() {
        let qm = random_quantized(32, 6, 8, 4);
        let cm = CompressedMatrix::from_quantized(&qm);
        let mut buf = vec![0u32; cm.row_stride];
        for r in 0..qm.n_rows {
            cm.decode_row_into(r, &mut buf);
            assert_eq!(&buf[..], qm.row(r));
        }
    }

    #[test]
    fn compression_ratio_formula() {
        // ratio vs raw f32 is 32 / symbol_bits (§2.2); the paper's "4x or
        // more" corresponds to symbol widths <= 8 bits, which low-
        // cardinality datasets (few effective bins per feature) reach. The
        // memory_footprint bench reports the measured ratio per dataset.
        let qm = random_quantized(200, 28, 256, 5);
        let cm = CompressedMatrix::from_quantized(&qm);
        let expect = 32.0 / cm.symbol_bits as f64;
        let got = cm.ratio_vs_float();
        assert!((got - expect).abs() / expect < 0.05, "{got} vs {expect}");
    }

    #[test]
    fn csr_entry_ratio_hits_4x_at_256_bins() {
        // §2.2 "four times or more": vs the 8-byte (index, value) device
        // CSR entries of the pre-quantisation implementation.
        let qm = random_quantized(300, 28, 256, 6);
        let cm = CompressedMatrix::from_quantized(&qm);
        assert!(
            cm.ratio_vs_csr_entry() >= 4.0,
            "ratio {} (bits {})",
            cm.ratio_vs_csr_entry(),
            cm.symbol_bits
        );
    }

    #[test]
    fn low_cardinality_hits_4x_paper_claim() {
        // 13 airline-like columns with <= 16 distinct values each keeps the
        // global alphabet under 256 symbols -> 8 bits -> 4x vs f32.
        let mut rng = Pcg64::new(11);
        let vals: Vec<Float> = (0..5000 * 13)
            .map(|_| (rng.gen_range(12) as Float))
            .collect();
        let x = DMatrix::dense(vals, 5000, 13);
        let cuts = HistogramCuts::from_dmatrix(&x, 16, None);
        let qm = Quantizer::new(cuts).quantize(&x);
        let cm = CompressedMatrix::from_quantized(&qm);
        assert!(cm.symbol_bits <= 8, "symbol bits {}", cm.symbol_bits);
        // 3.99 not 4.0: the packed stream carries one 8-byte pad word
        assert!(cm.ratio_vs_float() >= 3.99, "ratio {}", cm.ratio_vs_float());
    }

    #[test]
    fn empty_matrix() {
        let qm = QuantizedMatrix {
            bins: vec![],
            n_rows: 0,
            n_features: 0,
            row_stride: 0,
            n_bins: 0,
            dense: true,
        };
        let cm = CompressedMatrix::from_quantized(&qm);
        assert_eq!(cm.decode().bins.len(), 0);
    }

    #[test]
    fn single_symbol_width() {
        // alphabet of exactly 2 symbols packs to 1 bit
        let qm = QuantizedMatrix {
            bins: vec![0, 1, 1, 0, 1, 0, 0, 1],
            n_rows: 4,
            n_features: 2,
            row_stride: 2,
            n_bins: 1,
            dense: true,
        };
        let cm = CompressedMatrix::from_quantized(&qm);
        assert_eq!(cm.symbol_bits, 1);
        assert_eq!(cm.decode().bins, qm.bins);
    }

    #[test]
    fn streamed_builder_matches_bulk_pack() {
        // the streaming append path must produce the exact words that
        // packing a materialized QuantizedMatrix does — the shard-level
        // half of the streaming-ingestion bit-identity contract
        for (n_rows, n_cols, max_bins, seed) in
            [(100usize, 7usize, 16usize, 1u64), (400, 40, 256, 2), (33, 3, 4, 3)]
        {
            let qm = random_quantized(n_rows, n_cols, max_bins, seed);
            let bulk = CompressedMatrix::from_quantized(&qm);
            let mut b = CompressedMatrixBuilder::new(
                qm.n_rows,
                qm.n_features,
                qm.row_stride,
                qm.n_bins,
                qm.dense,
            );
            for r in 0..qm.n_rows {
                b.push_row(qm.row(r));
            }
            assert_eq!(b.rows_filled(), qm.n_rows);
            let streamed = b.finish();
            assert_eq!(streamed.words, bulk.words, "packed words must be identical");
            assert_eq!(streamed.symbol_bits, bulk.symbol_bits);
            assert_eq!(streamed.decode().bins, qm.bins);
        }
    }

    #[test]
    fn builder_pads_short_rows_with_null() {
        // sparse ELLPACK append: a 2-symbol row into a stride-4 shard
        let mut b = CompressedMatrixBuilder::new(2, 5, 4, 9, false);
        b.push_row(&[3, 7]);
        b.push_row(&[0, 1, 2, 8]);
        let cm = b.finish();
        assert_eq!(cm.get(0, 0), Some(3));
        assert_eq!(cm.get(0, 1), Some(7));
        assert_eq!(cm.get(0, 2), None, "padding decodes as null");
        assert_eq!(cm.get(0, 3), None);
        assert_eq!(cm.get(1, 3), Some(8));
    }

    #[test]
    fn cross_word_boundary_symbols() {
        // 13-bit symbols guarantee many straddle 64-bit word boundaries
        let n = 1000;
        let mut rng = Pcg64::new(9);
        let bins: Vec<u32> = (0..n).map(|_| rng.gen_range(7000) as u32).collect();
        let qm = QuantizedMatrix {
            bins: bins.clone(),
            n_rows: n,
            n_features: 1,
            row_stride: 1,
            n_bins: 6999,
            dense: true,
        };
        let cm = CompressedMatrix::from_quantized(&qm);
        assert_eq!(cm.symbol_bits, 13);
        for (i, &b) in bins.iter().enumerate() {
            assert_eq!(cm.symbol(i), b, "index {i}");
        }
    }

    #[test]
    fn block_decoder_matches_scalar_reference() {
        // the dedup contract: every routed decoder (symbol /
        // for_each_symbol_in_row / decode_row_into / decode_rows_block)
        // agrees with the kept scalar u128 reference, across widths that
        // exercise both the shift cascade and the straddle path
        for (max_bins, seed) in [(4usize, 21u64), (16, 22), (256, 23)] {
            let qm = random_quantized(97, 9, max_bins, seed);
            let cm = CompressedMatrix::from_quantized(&qm);
            for i in 0..qm.n_rows * qm.row_stride {
                assert_eq!(cm.symbol(i), cm.symbol_scalar(i), "flat index {i}");
            }
            let mut via_scalar = Vec::new();
            let mut via_block = Vec::new();
            for r in 0..qm.n_rows {
                cm.for_each_symbol_in_row_scalar(r, |s| via_scalar.push(s));
                cm.for_each_symbol_in_row(r, |s| via_block.push(s));
            }
            assert_eq!(via_block, via_scalar);
            assert_eq!(via_block, qm.bins);
        }
    }

    #[test]
    fn decode_rows_block_matches_per_row_decode() {
        let qm = random_quantized(131, 7, 32, 29);
        let cm = CompressedMatrix::from_quantized(&qm);
        let stride = cm.row_stride;
        let mut rowbuf = vec![0u32; stride];
        // block sizes straddling every alignment, incl. the full matrix
        for (first, n) in [(0usize, 1usize), (0, 64), (1, 63), (63, 65), (130, 1), (0, 131)] {
            let mut block = vec![0u32; n * stride];
            cm.decode_rows_block(first, n, &mut block);
            for (j, r) in (first..first + n).enumerate() {
                cm.decode_row_into(r, &mut rowbuf);
                assert_eq!(&block[j * stride..(j + 1) * stride], &rowbuf[..], "row {r}");
                assert_eq!(&rowbuf[..], qm.row(r), "row {r} vs source");
            }
        }
    }
}
