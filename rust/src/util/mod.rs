//! Utility substrates: deterministic RNG, CLI parsing, config system,
//! timing, and a hand-rolled property-testing harness.
//!
//! The offline crate mirror for this environment does not carry `rand`,
//! `clap`, `serde`, or `proptest`, so these are implemented from scratch
//! (see `DESIGN.md` §2).

pub mod cli;
pub mod config;
pub mod prop;
pub mod rng;
pub mod timer;

pub use cli::ArgParser;
pub use config::Config;
pub use rng::Pcg64;
pub use timer::{ScopedTimer, Stopwatch};
