//! Deterministic pseudo-random number generation.
//!
//! PCG-XSH-RR 64/32 (O'Neill 2014) with a 64-bit state extension giving a
//! `u64` output per step. Deterministic across platforms, splittable via
//! [`Pcg64::split`] so parallel device shards and dataset generators get
//! independent streams from a single seed.

/// PCG-based 64-bit generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Create a generator from a seed. Different seeds give uncorrelated
    /// streams; the same seed always gives the same sequence.
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: (seed << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(0x853c49e6748fea9b ^ seed);
        rng.next_u32();
        rng
    }

    /// Derive an independent stream, keyed by `key`. Used to give each
    /// simulated device / feature generator its own deterministic stream.
    pub fn split(&self, key: u64) -> Pcg64 {
        // Mix the current state with the key through splitmix64 and use the
        // result to seed a fresh stream with a distinct increment.
        let mixed = splitmix64(self.state ^ key.wrapping_mul(0x9e3779b97f4a7c15));
        let mut rng = Pcg64 {
            state: splitmix64(mixed),
            inc: ((key.wrapping_mul(2) | 1) ^ 0xda3e39cb94b95bdb) | 1,
        };
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with 53-bit precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire rejection method).
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_hi_lo(x, bound);
            if lo >= bound || lo >= x.wrapping_neg() % bound {
                return hi as usize;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > 1e-300 {
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[inline]
fn mul_hi_lo(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

/// splitmix64 — used for stream derivation only.
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_streams_are_independent() {
        let root = Pcg64::new(99);
        let mut s1 = root.split(0);
        let mut s2 = root.split(1);
        let same = (0..64).filter(|_| s1.next_u64() == s2.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::new(3);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut rng = Pcg64::new(4);
        for _ in 0..10_000 {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut rng = Pcg64::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.gen_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::new(6);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let x = rng.next_gaussian();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(8);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::new(9);
        let s = rng.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
        assert!(t.iter().all(|&i| i < 50));
    }

    #[test]
    fn sample_indices_k_ge_n() {
        let mut rng = Pcg64::new(10);
        let s = rng.sample_indices(5, 50);
        assert_eq!(s.len(), 5);
    }
}
