//! Hand-rolled property-based testing harness (the offline mirror has no
//! `proptest`/`quickcheck`).
//!
//! A property is a closure over a [`Gen`] (a seeded random source with
//! shape-generation helpers). [`check`] runs it for `N` cases with distinct
//! derived seeds and reports the failing seed on panic, so failures are
//! reproducible with [`check_seeded`].
//!
//! Used by `rust/tests/prop_*.rs` for the coordinator, quantile, compression
//! and tree invariants called out in `DESIGN.md` §6.

use crate::util::rng::Pcg64;

/// Random generator handed to properties, with convenience constructors for
/// the shapes this codebase cares about.
pub struct Gen {
    pub rng: Pcg64,
    /// Case index (0..cases); useful for size-ramping.
    pub case: usize,
}

impl Gen {
    /// Integer in `[lo, hi]`.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.gen_range(hi - lo + 1)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.rng.next_f64() < p_true
    }

    /// Vector of uniform f32 values, possibly containing NaNs (missing
    /// values) with probability `p_nan`.
    pub fn feature_column(&mut self, n: usize, p_nan: f64) -> Vec<f32> {
        (0..n)
            .map(|_| {
                if self.rng.next_f64() < p_nan {
                    f32::NAN
                } else {
                    self.rng.next_f32() * 20.0 - 10.0
                }
            })
            .collect()
    }

    /// Vector of gradient pairs with positive hessians.
    pub fn grad_pairs(&mut self, n: usize) -> Vec<crate::GradPair> {
        (0..n)
            .map(|_| {
                crate::GradPair::new(
                    self.rng.next_f32() * 2.0 - 1.0,
                    self.rng.next_f32() * 0.9 + 0.1,
                )
            })
            .collect()
    }

    /// Random u32 bin values below `n_bins`.
    pub fn bins(&mut self, n: usize, n_bins: u32) -> Vec<u32> {
        (0..n).map(|_| self.rng.gen_range(n_bins as usize) as u32).collect()
    }
}

/// Run `prop` for `cases` random cases under the root `seed`.
/// Panics (propagating the property's panic) after printing the failing
/// case's reproduction seed.
pub fn check<F: FnMut(&mut Gen)>(seed: u64, cases: usize, mut prop: F) {
    for case in 0..cases {
        let case_seed = crate::util::rng::splitmix64(seed ^ (case as u64).wrapping_mul(0x9e3779b97f4a7c15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen {
                rng: Pcg64::new(case_seed),
                case,
            };
            prop(&mut g);
        }));
        if let Err(e) = result {
            eprintln!(
                "property failed at case {case}/{cases}; reproduce with \
                 check_seeded({case_seed:#x}, ..)"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Re-run a single failing case by its printed seed.
pub fn check_seeded<F: FnMut(&mut Gen)>(case_seed: u64, mut prop: F) {
    let mut g = Gen {
        rng: Pcg64::new(case_seed),
        case: 0,
    };
    prop(&mut g);
}

/// Assert two f64 slices are elementwise close.
#[track_caller]
pub fn assert_allclose(a: &[f64], b: &[f64], atol: f64, rtol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        assert!(
            (x - y).abs() <= tol || (x.is_nan() && y.is_nan()),
            "index {i}: {x} vs {y} (tol {tol})"
        );
    }
}

/// Assert two f32 slices are elementwise close.
#[track_caller]
pub fn assert_allclose_f32(a: &[f32], b: &[f32], atol: f32, rtol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        assert!(
            (x - y).abs() <= tol || (x.is_nan() && y.is_nan()),
            "index {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut count = 0;
        check(1, 25, |_| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    fn cases_get_distinct_randomness() {
        let mut values = Vec::new();
        check(2, 10, |g| values.push(g.rng.next_u64()));
        let mut sorted = values.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), values.len());
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        check(3, 10, |g| {
            let v = g.int(0, 100);
            assert!(v < 1000); // passes
            assert!(g.case < 5, "fail at case >= 5");
        });
    }

    #[test]
    fn feature_column_nan_rate() {
        let mut g = Gen {
            rng: Pcg64::new(4),
            case: 0,
        };
        let col = g.feature_column(10_000, 0.2);
        let nans = col.iter().filter(|v| v.is_nan()).count();
        assert!((nans as f64 / 10_000.0 - 0.2).abs() < 0.03);
    }

    #[test]
    fn allclose_accepts_and_rejects() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-9, 2.0], 1e-6, 0.0);
        let r = std::panic::catch_unwind(|| assert_allclose(&[1.0], &[1.1], 1e-6, 0.0));
        assert!(r.is_err());
    }

    #[test]
    fn grad_pairs_have_positive_hessians() {
        let mut g = Gen {
            rng: Pcg64::new(5),
            case: 0,
        };
        for gp in g.grad_pairs(1000) {
            assert!(gp.hess > 0.0);
        }
    }
}
