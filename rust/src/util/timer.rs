//! Timing utilities used by the coordinator's per-phase accounting and by
//! the bench harness.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// A restartable accumulating stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Option<Instant>,
    accumulated: Duration,
    laps: usize,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch {
            start: None,
            accumulated: Duration::ZERO,
            laps: 0,
        }
    }

    pub fn start(&mut self) {
        self.start = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(s) = self.start.take() {
            self.accumulated += s.elapsed();
            self.laps += 1;
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.accumulated
            + self
                .start
                .map(|s| s.elapsed())
                .unwrap_or(Duration::ZERO)
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn laps(&self) -> usize {
        self.laps
    }

    pub fn reset(&mut self) {
        *self = Stopwatch::new();
    }
}

/// Named per-phase timing registry (e.g. "hist", "partition", "allreduce").
#[derive(Debug, Clone, Default)]
pub struct PhaseTimers {
    timers: BTreeMap<String, Stopwatch>,
}

impl PhaseTimers {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under phase `name`, accumulating across calls.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let sw = self.timers.entry(name.to_string()).or_default();
        sw.start();
        let out = f();
        // re-borrow: closure may have inserted phases if it had access; here
        // it cannot, so the entry still exists.
        self.timers.get_mut(name).unwrap().stop();
        out
    }

    pub fn add(&mut self, name: &str, d: Duration) {
        let sw = self.timers.entry(name.to_string()).or_default();
        sw.accumulated += d;
        sw.laps += 1;
    }

    pub fn secs(&self, name: &str) -> f64 {
        self.timers.get(name).map(|t| t.elapsed_secs()).unwrap_or(0.0)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, f64)> {
        self.timers.iter().map(|(k, v)| (k.as_str(), v.elapsed_secs()))
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.iter() {
            out.push_str(&format!("{k:>16}: {v:9.4}s\n"));
        }
        out
    }
}

/// RAII timer that prints on drop when verbose mode is on; used in examples.
pub struct ScopedTimer {
    label: String,
    start: Instant,
    verbose: bool,
}

impl ScopedTimer {
    pub fn new(label: impl Into<String>) -> Self {
        ScopedTimer {
            label: label.into(),
            start: Instant::now(),
            verbose: true,
        }
    }

    pub fn quiet(label: impl Into<String>) -> Self {
        ScopedTimer {
            label: label.into(),
            start: Instant::now(),
            verbose: false,
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Drop for ScopedTimer {
    fn drop(&mut self) {
        if self.verbose {
            eprintln!("[time] {}: {:.4}s", self.label, self.elapsed_secs());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        let first = sw.elapsed();
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        assert!(sw.elapsed() > first);
        assert_eq!(sw.laps(), 2);
    }

    #[test]
    fn stopwatch_running_elapsed() {
        let mut sw = Stopwatch::new();
        sw.start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.elapsed() > Duration::ZERO);
    }

    #[test]
    fn phase_timers_accumulate_by_name() {
        let mut pt = PhaseTimers::new();
        pt.time("hist", || std::thread::sleep(Duration::from_millis(2)));
        pt.time("hist", || std::thread::sleep(Duration::from_millis(2)));
        pt.time("split", || ());
        assert!(pt.secs("hist") >= 0.004);
        assert!(pt.secs("split") >= 0.0);
        assert_eq!(pt.iter().count(), 2);
        assert!(pt.report().contains("hist"));
    }

    #[test]
    fn phase_timers_add_duration() {
        let mut pt = PhaseTimers::new();
        pt.add("comm", Duration::from_millis(250));
        assert!((pt.secs("comm") - 0.25).abs() < 1e-9);
    }
}
