//! Minimal GNU-style argument parser (the offline mirror has no `clap`).
//!
//! Supports `--key value`, `--key=value`, boolean flags, repeated keys and
//! positional arguments. Used by the `xgb-tpu` binary, the examples and the
//! bench harness.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct ArgParser {
    named: BTreeMap<String, Vec<String>>,
    positional: Vec<String>,
    program: String,
}

impl ArgParser {
    /// Parse from `std::env::args()`.
    pub fn from_env() -> Self {
        let args: Vec<String> = std::env::args().collect();
        Self::parse(&args)
    }

    /// Parse from an explicit argv (index 0 is the program name).
    pub fn parse(argv: &[String]) -> Self {
        let mut p = ArgParser {
            program: argv.first().cloned().unwrap_or_default(),
            ..Default::default()
        };
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    let (k, v) = stripped.split_at(eq);
                    p.named
                        .entry(k.to_string())
                        .or_default()
                        .push(v[1..].to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    p.named
                        .entry(stripped.to_string())
                        .or_default()
                        .push(argv[i + 1].clone());
                    i += 1;
                } else {
                    // boolean flag
                    p.named
                        .entry(stripped.to_string())
                        .or_default()
                        .push("true".to_string());
                }
            } else {
                p.positional.push(a.clone());
            }
            i += 1;
        }
        p
    }

    pub fn program(&self) -> &str {
        &self.program
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Last value given for `key`, if any.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.named.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All values given for `key`.
    pub fn get_all(&self, key: &str) -> &[String] {
        self.named.get(key).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn has(&self, key: &str) -> bool {
        self.named.contains_key(key)
    }

    /// Boolean flag: present without value, or `--key true/false`.
    pub fn flag(&self, key: &str) -> bool {
        match self.get(key) {
            Some("false") | Some("0") | Some("no") => false,
            Some(_) => true,
            None => false,
        }
    }

    /// Typed getter with default. Panics with a readable message on a
    /// malformed value — appropriate for CLI boundary code.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("--{key}: cannot parse {v:?}: {e}")),
        }
    }

    /// String getter with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Iterate over all `--key value` pairs in insertion-agnostic (sorted)
    /// order; used to forward unknown keys into a [`crate::util::Config`].
    pub fn iter(&self) -> impl Iterator<Item = (&str, &str)> {
        self.named
            .iter()
            .flat_map(|(k, vs)| vs.iter().map(move |v| (k.as_str(), v.as_str())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        std::iter::once("prog")
            .chain(s.iter().copied())
            .map(String::from)
            .collect()
    }

    #[test]
    fn parses_key_value_pairs() {
        let p = ArgParser::parse(&argv(&["--rows", "100", "--name=airline"]));
        assert_eq!(p.get("rows"), Some("100"));
        assert_eq!(p.get("name"), Some("airline"));
    }

    #[test]
    fn parses_flags() {
        let p = ArgParser::parse(&argv(&["--verbose", "--compress", "false"]));
        assert!(p.flag("verbose"));
        assert!(!p.flag("compress"));
        assert!(!p.flag("absent"));
    }

    #[test]
    fn positional_args() {
        let p = ArgParser::parse(&argv(&["train", "--n", "5", "data.csv"]));
        assert_eq!(p.positional(), &["train".to_string(), "data.csv".to_string()]);
    }

    #[test]
    fn typed_getters() {
        let p = ArgParser::parse(&argv(&["--eta", "0.3", "--depth", "6"]));
        assert_eq!(p.get_parse::<f64>("eta", 0.1), 0.3);
        assert_eq!(p.get_parse::<usize>("depth", 8), 6);
        assert_eq!(p.get_parse::<usize>("missing", 8), 8);
    }

    #[test]
    fn repeated_keys_keep_all_values() {
        let p = ArgParser::parse(&argv(&["--dataset", "higgs", "--dataset", "bosch"]));
        assert_eq!(p.get_all("dataset"), &["higgs".to_string(), "bosch".to_string()]);
        assert_eq!(p.get("dataset"), Some("bosch"));
    }

    #[test]
    #[should_panic(expected = "cannot parse")]
    fn malformed_value_panics() {
        let p = ArgParser::parse(&argv(&["--eta", "abc"]));
        let _ = p.get_parse::<f64>("eta", 0.1);
    }
}
