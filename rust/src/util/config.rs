//! Key–value configuration system (the offline mirror has no `serde`).
//!
//! Mirrors XGBoost's flat string-parameter interface: every trainer
//! parameter is addressable as `key=value`. Sources compose in priority
//! order: defaults < config file < CLI overrides. Config files use a simple
//! `key = value` line format with `#` comments (a TOML subset).

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use anyhow::{Context, Result};

/// Flat, typed-on-read configuration store.
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse a `key = value` file (TOML-subset; `#` comments, blank lines,
    /// optional quotes around the value, `[section]` headers flattened to
    /// `section.key`).
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        Self::from_str_contents(&text)
    }

    /// Parse config from a string (same format as [`Config::from_file`]).
    pub fn from_str_contents(text: &str) -> Result<Self> {
        let mut cfg = Config::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') && line.ends_with(']') {
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let eq = line
                .find('=')
                .with_context(|| format!("config line {}: missing '=': {raw:?}", lineno + 1))?;
            let key = line[..eq].trim();
            let mut value = line[eq + 1..].trim();
            if value.len() >= 2
                && ((value.starts_with('"') && value.ends_with('"'))
                    || (value.starts_with('\'') && value.ends_with('\'')))
            {
                value = &value[1..value.len() - 1];
            }
            let full_key = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            cfg.values.insert(full_key, value.to_string());
        }
        Ok(cfg)
    }

    pub fn set(&mut self, key: impl Into<String>, value: impl Into<String>) -> &mut Self {
        self.values.insert(key.into(), value.into());
        self
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn contains(&self, key: &str) -> bool {
        self.values.contains_key(key)
    }

    /// Typed read with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                anyhow::anyhow!("config key {key}: cannot parse {v:?} as {}",
                    std::any::type_name::<T>())
            }),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.values.get(key).map(|s| s.as_str()) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            _ => default,
        }
    }

    /// Overlay `other` on top of `self` (other wins).
    pub fn merge(&mut self, other: &Config) -> &mut Self {
        for (k, v) in &other.values {
            self.values.insert(k.clone(), v.clone());
        }
        self
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &str)> {
        self.values.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

impl fmt::Display for Config {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (k, v) in &self.values {
            writeln!(f, "{k} = {v}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_file() {
        let cfg = Config::from_str_contents(
            "# comment\nmax_depth = 6\neta = 0.3  # inline\nname = \"airline\"\n",
        )
        .unwrap();
        assert_eq!(cfg.get("max_depth"), Some("6"));
        assert_eq!(cfg.get_parse("eta", 0.0).unwrap(), 0.3);
        assert_eq!(cfg.get("name"), Some("airline"));
    }

    #[test]
    fn sections_flatten() {
        let cfg = Config::from_str_contents("[tree]\nmax_depth = 8\n[booster]\neta = 0.1\n")
            .unwrap();
        assert_eq!(cfg.get("tree.max_depth"), Some("8"));
        assert_eq!(cfg.get("booster.eta"), Some("0.1"));
    }

    #[test]
    fn merge_overrides() {
        let mut a = Config::from_str_contents("x = 1\ny = 2\n").unwrap();
        let b = Config::from_str_contents("y = 3\nz = 4\n").unwrap();
        a.merge(&b);
        assert_eq!(a.get("x"), Some("1"));
        assert_eq!(a.get("y"), Some("3"));
        assert_eq!(a.get("z"), Some("4"));
    }

    #[test]
    fn bool_parsing() {
        let cfg = Config::from_str_contents("a = true\nb = 0\nc = yes\n").unwrap();
        assert!(cfg.get_bool("a", false));
        assert!(!cfg.get_bool("b", true));
        assert!(cfg.get_bool("c", false));
        assert!(cfg.get_bool("absent", true));
    }

    #[test]
    fn missing_equals_is_error() {
        assert!(Config::from_str_contents("novalue\n").is_err());
    }

    #[test]
    fn bad_typed_read_is_error() {
        let cfg = Config::from_str_contents("eta = abc\n").unwrap();
        assert!(cfg.get_parse::<f64>("eta", 0.1).is_err());
    }
}
