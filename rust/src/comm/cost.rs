//! α–β communication cost model pricing the all-reduce traffic for the
//! simulated multi-GPU wall-clock (DESIGN.md §5).
//!
//! `T = steps · α + bytes_per_device / β` — the classic latency/bandwidth
//! (Hockney) model. Default constants approximate NCCL on an NVLink-
//! connected 8×V100 DGX-1, the paper's testbed:
//!
//! * `alpha` — per-step launch + link latency. NCCL ring steps cost a few
//!   microseconds each; we use 8 µs (NCCL's own tuning tables use 6–10 µs
//!   for intra-node rings).
//! * `bandwidth` — per-link sustained bandwidth. V100 NVLink2 gives
//!   ~23 GB/s per direction per link aggregated by NCCL to ~100 GB/s bus
//!   bandwidth; the per-device ring throughput the paper's setup reaches
//!   is ≈ 60 GB/s sustained, which we use as the default.
//!
//! The model is deliberately simple: Figure 2's *shape* (when does adding
//! GPUs stop paying) is governed by the ratio of histogram compute to
//! `2(p−1)/p · H / β`, which this captures. Constants are overridable from
//! the CLI (`--comm-alpha`, `--comm-bandwidth`) for sensitivity ablations.

use crate::comm::ring::AllReduceStats;

/// Latency/bandwidth cost model for collectives.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Per-step latency, seconds.
    pub alpha: f64,
    /// Sustained per-device bandwidth, bytes/second.
    pub bandwidth: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            alpha: 8e-6,
            bandwidth: 60e9,
        }
    }
}

impl CostModel {
    pub fn new(alpha: f64, bandwidth: f64) -> Self {
        assert!(alpha >= 0.0 && bandwidth > 0.0);
        CostModel { alpha, bandwidth }
    }

    /// Wall-clock seconds for a collective with the given traffic stats.
    pub fn time(&self, stats: &AllReduceStats) -> f64 {
        stats.steps as f64 * self.alpha + stats.bytes_per_device as f64 / self.bandwidth
    }

    /// Closed-form ring all-reduce time for `n_elems` f64 over `p` devices
    /// (used by analytic projections without running the simulation).
    pub fn ring_time(&self, p: usize, n_elems: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let bytes = 2.0 * (p as f64 - 1.0) / p as f64 * n_elems as f64 * 8.0;
        2.0 * (p as f64 - 1.0) * self.alpha + bytes / self.bandwidth
    }

    /// Host-to-device (PCIe-like) transfer time for initially scattering
    /// `bytes` to each device; used in end-to-end projections.
    pub fn h2d_time(&self, bytes: usize) -> f64 {
        // PCIe gen3 x16 ~ 12 GB/s effective
        bytes as f64 / 12e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_is_linear_in_traffic() {
        let m = CostModel::default();
        let s1 = AllReduceStats {
            n_devices: 4,
            n_elems: 1000,
            bytes_per_device: 12_000,
            steps: 6,
        };
        let s2 = AllReduceStats {
            bytes_per_device: 24_000,
            ..s1
        };
        let t1 = m.time(&s1);
        let t2 = m.time(&s2);
        assert!(t2 > t1);
        assert!(((t2 - t1) - 12_000.0 / m.bandwidth).abs() < 1e-12);
    }

    #[test]
    fn ring_time_matches_simulated_stats() {
        let m = CostModel::default();
        for p in [2usize, 4, 8] {
            let n = 10_000usize;
            let mut bufs: Vec<Vec<f64>> = (0..p).map(|_| vec![1.0; n]).collect();
            let stats = crate::comm::ring::ring_allreduce(&mut bufs);
            let sim = m.time(&stats);
            let analytic = m.ring_time(p, n);
            assert!(
                (sim - analytic).abs() / analytic < 0.02,
                "p={p}: {sim} vs {analytic}"
            );
        }
    }

    #[test]
    fn single_device_costs_nothing() {
        let m = CostModel::default();
        assert_eq!(m.ring_time(1, 1_000_000), 0.0);
    }

    #[test]
    fn more_devices_more_latency_less_marginal_bandwidth() {
        let m = CostModel::default();
        // for small payloads, time grows with p (latency dominated)
        assert!(m.ring_time(8, 100) > m.ring_time(2, 100));
        // bandwidth term saturates at 2·n·8/β as p -> inf
        let t_inf = 2.0 * 1e6 * 8.0 / m.bandwidth;
        assert!(m.ring_time(8, 1_000_000) < t_inf + 16.0 * m.alpha + 1e-9);
    }

    #[test]
    #[should_panic]
    fn invalid_bandwidth_panics() {
        CostModel::new(1e-6, 0.0);
    }
}
