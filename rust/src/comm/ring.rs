//! Exact simulation of the NCCL-style chunked ring all-reduce.
//!
//! The schedule is the standard two-phase ring over `p` devices with the
//! buffer split into `p` chunks:
//!
//! 1. **reduce-scatter** — `p−1` steps; in step `s`, device `d` sends chunk
//!    `(d − s) mod p` to device `(d+1) mod p`, which adds it into its own
//!    copy. After the phase, device `d` owns the fully reduced chunk
//!    `(d+1) mod p`.
//! 2. **all-gather** — `p−1` steps circulating the reduced chunks.
//!
//! Total bytes sent per device: `2 (p−1)/p · n·8`, the textbook
//! bandwidth-optimal figure the [`crate::comm::cost::CostModel`] prices.
//! The simulation performs the real additions in schedule order, so
//! numerical results (including f64 rounding order) are reproducible and
//! independent of host thread count.

/// Traffic statistics of one collective, consumed by the cost model.
///
/// Convention: all byte figures count bytes **sent** by a device, never
/// bytes received. Every send has a matching receive, so counting both
/// would double every figure; counting sends only keeps ring and serial
/// numbers in the same units (the serial leader's receives are exactly
/// the followers' sends, and vice versa).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AllReduceStats {
    /// Number of participating devices.
    pub n_devices: usize,
    /// Elements per device buffer.
    pub n_elems: usize,
    /// Bytes sent by the busiest device over the whole collective (the
    /// true max over devices). When `n % p != 0` the chunks are uneven,
    /// so per-device totals differ by a few chunk-remainder elements;
    /// when `p` divides `n` all devices send exactly this much.
    pub bytes_per_device: usize,
    /// Number of communication steps (latency terms).
    pub steps: usize,
}

impl AllReduceStats {
    pub fn noop(n_elems: usize) -> Self {
        AllReduceStats {
            n_devices: 1,
            n_elems,
            bytes_per_device: 0,
            steps: 0,
        }
    }
}

/// Chunk boundaries: chunk `c` covers `chunk_range(n, p, c)`.
///
/// Shared with the wire engine (`comm::wire`): the TCP ring uses the
/// exact same boundaries so distributed merges are bit-identical to the
/// in-process simulation.
#[inline]
pub(crate) fn chunk_range(n: usize, p: usize, c: usize) -> std::ops::Range<usize> {
    let base = n / p;
    let rem = n % p;
    let start = c * base + c.min(rem);
    let len = base + usize::from(c < rem);
    start..start + len
}

/// Ring all-reduce over per-device buffers, in place. All buffers must
/// have equal length. Returns traffic stats for the cost model.
pub fn ring_allreduce(buffers: &mut [Vec<f64>]) -> AllReduceStats {
    let p = buffers.len();
    assert!(p > 0, "need at least one device");
    let n = buffers[0].len();
    assert!(
        buffers.iter().all(|b| b.len() == n),
        "all device buffers must have equal length"
    );
    if p == 1 {
        return AllReduceStats::noop(n);
    }

    // Exact per-device send totals. With uneven chunks (`n % p != 0`) a
    // device sends a different-sized chunk each step, and no device
    // sends the largest chunk at every step, so summing the per-step max
    // would overstate the busiest device's total. Track each device's
    // actual bytes and report the true max.
    let mut sent_bytes = vec![0usize; p];

    // Phase 1: reduce-scatter. Message payloads must be snapshotted per
    // step (all sends happen "simultaneously"), matching real NCCL
    // semantics where a step's send uses the pre-step buffer state.
    for step in 0..p - 1 {
        let mut messages: Vec<(usize, usize, Vec<f64>)> = Vec::with_capacity(p);
        for d in 0..p {
            let c = (d + p - step) % p;
            let r = chunk_range(n, p, c);
            sent_bytes[d] += (r.end - r.start) * 8;
            messages.push((d, c, buffers[d][r].to_vec()));
        }
        for (d, c, payload) in messages {
            let dst = (d + 1) % p;
            let r = chunk_range(n, p, c);
            for (x, v) in buffers[dst][r].iter_mut().zip(payload.iter()) {
                *x += *v;
            }
        }
    }

    // Phase 2: all-gather. Device d now owns reduced chunk (d+1) mod p;
    // circulate the reduced chunks around the ring.
    for step in 0..p - 1 {
        let mut messages: Vec<(usize, usize, Vec<f64>)> = Vec::with_capacity(p);
        for d in 0..p {
            let c = (d + 1 + p - step) % p;
            let r = chunk_range(n, p, c);
            sent_bytes[d] += (r.end - r.start) * 8;
            messages.push((d, c, buffers[d][r].to_vec()));
        }
        for (d, c, payload) in messages {
            let dst = (d + 1) % p;
            let r = chunk_range(n, p, c);
            buffers[dst][r].copy_from_slice(&payload);
        }
    }

    AllReduceStats {
        n_devices: p,
        n_elems: n,
        bytes_per_device: sent_bytes.iter().copied().max().unwrap_or(0),
        steps: 2 * (p - 1),
    }
}

/// Reference all-reduce: gather to device 0, then broadcast. Used to
/// verify the ring and as the "naive" ablation (p−1× more leader traffic).
pub fn serial_allreduce(buffers: &mut [Vec<f64>]) -> AllReduceStats {
    let p = buffers.len();
    assert!(p > 0);
    let n = buffers[0].len();
    assert!(buffers.iter().all(|b| b.len() == n));
    if p == 1 {
        return AllReduceStats::noop(n);
    }
    let (leader, rest) = buffers.split_first_mut().unwrap();
    for b in rest.iter() {
        for (x, v) in leader.iter_mut().zip(b.iter()) {
            *x += *v;
        }
    }
    for b in rest.iter_mut() {
        b.copy_from_slice(leader);
    }
    AllReduceStats {
        n_devices: p,
        n_elems: n,
        // Send-bytes convention (see `AllReduceStats`): the leader is the
        // busiest sender with `(p-1)·n` elements broadcast out; its
        // `(p-1)·n` receives are the followers' sends and are not counted
        // here, exactly as the ring counts sends only.
        bytes_per_device: (p - 1) * n * 8,
        steps: 2 * (p - 1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn random_buffers(p: usize, n: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Pcg64::new(seed);
        (0..p)
            .map(|_| (0..n).map(|_| rng.next_f64() * 2.0 - 1.0).collect())
            .collect()
    }

    fn expected_sum(buffers: &[Vec<f64>]) -> Vec<f64> {
        let n = buffers[0].len();
        let mut out = vec![0.0; n];
        for b in buffers {
            for (o, v) in out.iter_mut().zip(b.iter()) {
                *o += *v;
            }
        }
        out
    }

    #[test]
    fn ring_equals_sum_various_p_and_n() {
        for p in [1, 2, 3, 4, 7, 8] {
            for n in [1, 2, 5, 16, 64, 257] {
                if n < p {
                    continue;
                }
                let mut bufs = random_buffers(p, n, (p * 1000 + n) as u64);
                let want = expected_sum(&bufs);
                let stats = ring_allreduce(&mut bufs);
                for (d, b) in bufs.iter().enumerate() {
                    for (i, (&x, &w)) in b.iter().zip(want.iter()).enumerate() {
                        assert!(
                            (x - w).abs() < 1e-9,
                            "p={p} n={n} dev={d} idx={i}: {x} vs {w}"
                        );
                    }
                }
                assert_eq!(stats.steps, if p == 1 { 0 } else { 2 * (p - 1) });
            }
        }
    }

    #[test]
    fn ring_handles_n_smaller_than_p() {
        // 3 elements over 8 devices: some chunks are empty
        let mut bufs = random_buffers(8, 3, 42);
        let want = expected_sum(&bufs);
        ring_allreduce(&mut bufs);
        for b in &bufs {
            for (x, w) in b.iter().zip(want.iter()) {
                assert!((x - w).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn serial_equals_sum() {
        let mut bufs = random_buffers(5, 33, 7);
        let want = expected_sum(&bufs);
        serial_allreduce(&mut bufs);
        for b in &bufs {
            for (x, w) in b.iter().zip(want.iter()) {
                assert!((x - w).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn ring_matches_serial() {
        let mut a = random_buffers(4, 100, 9);
        let mut b = a.clone();
        ring_allreduce(&mut a);
        serial_allreduce(&mut b);
        for (x, y) in a[0].iter().zip(b[0].iter()) {
            assert!((x - y).abs() < 1e-9);
        }
    }

    #[test]
    fn ring_bandwidth_is_optimal_factor() {
        // bytes per device = 2 (p-1)/p · n · 8, exact when p divides n
        let p = 8;
        let n = 8000;
        let mut bufs = random_buffers(p, n, 11);
        let stats = ring_allreduce(&mut bufs);
        let ideal = 2 * (p - 1) * n / p * 8;
        assert_eq!(stats.bytes_per_device, ideal);
        // Both algorithms count send-bytes only, so the serial leader's
        // (p-1)·n·8 is exactly p/2× the ring figure (4× here at p=8).
        let mut bufs = random_buffers(p, n, 11);
        let serial = serial_allreduce(&mut bufs);
        assert_eq!(serial.bytes_per_device, (p - 1) * n * 8);
        assert_eq!(serial.bytes_per_device, stats.bytes_per_device * p / 2);
        assert!(serial.bytes_per_device > stats.bytes_per_device * 3);
    }

    #[test]
    fn uneven_chunks_report_true_max_send_total() {
        // n=257, p=8: chunk 0 has 33 elements, chunks 1..7 have 32.
        // Reduce-scatter: device d sends every chunk except chunk d, so
        // d=0 sends 257−33=224 elements and d=1..7 send 257−32=225.
        // All-gather: device d sends every chunk except chunk (d+1)%p,
        // so d=7 sends 224 and the rest send 225. Per-device totals:
        // d=0 → 449, d=1..6 → 450, d=7 → 449. True max = 450 elements
        // = 3600 bytes. (The old per-step-max accounting charged 33
        // elements on all 14 steps: 33·14·8 = 3696 — no device ever
        // sends that much.)
        let mut bufs = random_buffers(8, 257, 13);
        let stats = ring_allreduce(&mut bufs);
        assert_eq!(stats.bytes_per_device, 3600);
        assert_eq!(stats.steps, 14);
    }

    #[test]
    fn single_device_noop() {
        let mut bufs = vec![vec![1.0, 2.0, 3.0]];
        let stats = ring_allreduce(&mut bufs);
        assert_eq!(bufs[0], vec![1.0, 2.0, 3.0]);
        assert_eq!(stats.bytes_per_device, 0);
    }

    #[test]
    fn chunk_ranges_partition() {
        for n in [1usize, 7, 16, 100] {
            for p in [1usize, 2, 3, 8] {
                let mut covered = 0;
                for c in 0..p {
                    let r = chunk_range(n, p, c);
                    assert_eq!(r.start, covered);
                    covered = r.end;
                }
                assert_eq!(covered, n);
            }
        }
    }

    #[test]
    #[should_panic(expected = "equal length")]
    fn unequal_buffers_panic() {
        let mut bufs = vec![vec![1.0], vec![1.0, 2.0]];
        ring_allreduce(&mut bufs);
    }
}
