//! Collective communication substrate (paper §2.3: "The partial histograms
//! are merged using an AllReduce operation provided by the NCCL library").
//!
//! This environment has no GPUs and no NCCL, so the collective is built
//! from scratch and *executed exactly*: [`ring::ring_allreduce`] simulates
//! the chunked ring schedule NCCL uses (reduce-scatter + all-gather),
//! message by message, so every device ends with the true elementwise sum
//! and the per-step traffic is accounted. A calibrated α–β
//! [`cost::CostModel`] converts that traffic into the wall-clock a real
//! NVLink ring would take — this is what the Figure 2 scaling bench
//! reports (see DESIGN.md §5).

pub mod cost;
pub mod ring;

pub use cost::CostModel;
pub use ring::{ring_allreduce, serial_allreduce, AllReduceStats};

/// Strategy selector for histogram merging.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllReduceAlgo {
    /// NCCL-style chunked ring (the paper's configuration).
    Ring,
    /// Gather-to-leader + broadcast (reference implementation; ablation).
    Serial,
}

impl std::str::FromStr for AllReduceAlgo {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "ring" => Ok(AllReduceAlgo::Ring),
            "serial" | "naive" => Ok(AllReduceAlgo::Serial),
            other => Err(format!(
                "unknown allreduce algo {other:?}; valid algorithms: ring, serial"
            )),
        }
    }
}

impl std::fmt::Display for AllReduceAlgo {
    /// Canonical config-file spelling; round-trips through [`FromStr`].
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            AllReduceAlgo::Ring => "ring",
            AllReduceAlgo::Serial => "serial",
        })
    }
}

/// Run the selected all-reduce over per-device buffers in place: after the
/// call every device's buffer holds the elementwise sum.
pub fn allreduce(algo: AllReduceAlgo, buffers: &mut [Vec<f64>]) -> AllReduceStats {
    match algo {
        AllReduceAlgo::Ring => ring_allreduce(buffers),
        AllReduceAlgo::Serial => serial_allreduce(buffers),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_parse() {
        assert_eq!("ring".parse::<AllReduceAlgo>().unwrap(), AllReduceAlgo::Ring);
        assert_eq!("serial".parse::<AllReduceAlgo>().unwrap(), AllReduceAlgo::Serial);
        assert!("tree".parse::<AllReduceAlgo>().is_err());
    }

    #[test]
    fn dispatcher_reduces() {
        for algo in [AllReduceAlgo::Ring, AllReduceAlgo::Serial] {
            let mut bufs = vec![vec![1.0, 2.0], vec![10.0, 20.0], vec![100.0, 200.0]];
            allreduce(algo, &mut bufs);
            for b in &bufs {
                assert_eq!(b, &vec![111.0, 222.0]);
            }
        }
    }
}
