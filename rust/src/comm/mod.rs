//! Collective communication (paper §2.3: "The partial histograms are
//! merged using an AllReduce operation provided by the NCCL library").
//!
//! Two implementations of the same NCCL-style chunked ring schedule
//! (reduce-scatter + all-gather over [`ring::chunk_range`] boundaries):
//!
//! * **In-process simulation** — [`ring::ring_allreduce`] executes the
//!   schedule message by message over the per-device buffers of one
//!   process. It is the default `n_devices > 1` path, the reference the
//!   wire engine is pinned against, and the input to the calibrated α–β
//!   [`cost::CostModel`] that converts the accounted traffic
//!   ([`AllReduceStats`], send-bytes convention) into the wall-clock a
//!   real NVLink ring would take — which is what the Figure 2 scaling
//!   bench and the ring-vs-serial ablation report.
//! * **Real TCP transport** — [`net`] frames (length-prefixed,
//!   FNV-1a-checksummed, read/write timeouts, connect retry with
//!   backoff) carrying [`wire::WireRing`]'s multi-process ring. Same
//!   chunk boundaries, same step order, same f64 operand order as the
//!   simulation, so distributed merges are **bit-identical** to
//!   in-process ones; chunk payloads ship raw or losslessly packed
//!   through the `compress/` symbol machinery
//!   ([`wire::WirePayload::Quant`]) to cut wire bytes. Engaged when
//!   `CoordinatorParams::dist` is set (CLI `--dist-rank/--dist-peers`).
//!
//! The simulation is *not* legacy: single-process multi-device runs and
//! every cost-model bench keep using it, and the wire engine inherits
//! its correctness tests by construction (the distributed property suite
//! asserts wire == simulation bit-for-bit).

pub mod cost;
pub mod net;
pub mod ring;
pub mod wire;

pub use cost::CostModel;
pub use ring::{ring_allreduce, serial_allreduce, AllReduceStats};
pub use wire::{DistConfig, WirePayload, WireRing, WireStats};

/// Strategy selector for histogram merging.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllReduceAlgo {
    /// NCCL-style chunked ring (the paper's configuration).
    Ring,
    /// Gather-to-leader + broadcast (reference implementation; ablation).
    Serial,
}

impl std::str::FromStr for AllReduceAlgo {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "ring" => Ok(AllReduceAlgo::Ring),
            "serial" | "naive" => Ok(AllReduceAlgo::Serial),
            other => Err(format!(
                "unknown allreduce algo {other:?}; valid algorithms: ring, serial"
            )),
        }
    }
}

impl std::fmt::Display for AllReduceAlgo {
    /// Canonical config-file spelling; round-trips through [`FromStr`].
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            AllReduceAlgo::Ring => "ring",
            AllReduceAlgo::Serial => "serial",
        })
    }
}

/// Run the selected all-reduce over per-device buffers in place: after the
/// call every device's buffer holds the elementwise sum.
pub fn allreduce(algo: AllReduceAlgo, buffers: &mut [Vec<f64>]) -> AllReduceStats {
    match algo {
        AllReduceAlgo::Ring => ring_allreduce(buffers),
        AllReduceAlgo::Serial => serial_allreduce(buffers),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_parse() {
        assert_eq!("ring".parse::<AllReduceAlgo>().unwrap(), AllReduceAlgo::Ring);
        assert_eq!("serial".parse::<AllReduceAlgo>().unwrap(), AllReduceAlgo::Serial);
        assert!("tree".parse::<AllReduceAlgo>().is_err());
    }

    #[test]
    fn dispatcher_reduces() {
        for algo in [AllReduceAlgo::Ring, AllReduceAlgo::Serial] {
            let mut bufs = vec![vec![1.0, 2.0], vec![10.0, 20.0], vec![100.0, 200.0]];
            allreduce(algo, &mut bufs);
            for b in &bufs {
                assert_eq!(b, &vec![111.0, 222.0]);
            }
        }
    }
}
