//! Multi-process TCP ring all-reduce — the real transport behind the
//! schedule that `comm::ring` simulates.
//!
//! ## Determinism contract
//!
//! [`WireRing::allreduce`] runs the exact reduce-scatter + all-gather
//! schedule of [`ring_allreduce`](crate::comm::ring::ring_allreduce):
//! the same [`chunk_range`] boundaries, the same step order, and the
//! same f64 operand order (`own[i] += received[i]` during
//! reduce-scatter, overwrite during all-gather). Rank `r`'s buffer
//! plays the role of device `r`'s buffer, so the merged result on
//! every rank is **bit-identical** to what the in-process simulation
//! produces over the same per-device buffers — which is what makes
//! distributed trees byte-equal to single-process ones.
//!
//! ## Payload codecs
//!
//! * [`WirePayload::Raw`] ships each chunk as `n·8` little-endian f64
//!   bytes.
//! * [`WirePayload::Quant`] packs chunks through the `compress/`
//!   symbol machinery **losslessly**: a nonzero bitmask drops the empty
//!   histogram bins (plentiful in deep-node rounds), and the surviving
//!   bit patterns are shifted by their common trailing-zero count and
//!   bit-packed at the narrowest width that covers them (f32-origin
//!   gradient sums carry ~29 zero low mantissa bits). Dequantisation
//!   reconstructs the exact original bits, so bit-parity holds in both
//!   modes; only the wire byte count differs.
//!
//! ## Topology
//!
//! Rank `r` listens on `peers[r]`, dials `peers[(r+1) % world]`
//! (retry + backoff, peers start in any order) and accepts one
//! connection from rank `(r−1) % world`, then the ends exchange
//! `Hello{rank, world}` frames so a miswired ring fails fast with the
//! offending rank in the message. Each step sends on a scoped thread
//! while the receive runs on the caller — payloads larger than the
//! socket buffers cannot deadlock the ring.

use std::net::TcpListener;

use anyhow::{bail, Context, Result};

use crate::comm::net::{
    accept_with_deadline, connect_with_retry, FrameKind, FramedStream, CONNECT_RETRY_TOTAL,
};
use crate::comm::ring::chunk_range;
use crate::compress::{CompressedMatrix, CompressedMatrixBuilder};

/// How chunk payloads are encoded on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WirePayload {
    /// Lossless packed encoding (default): zero-bin mask + trailing-zero
    /// shift + narrowest-width bit packing.
    #[default]
    Quant,
    /// Plain little-endian f64 bytes.
    Raw,
}

impl std::str::FromStr for WirePayload {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "quant" | "quantised" | "quantized" => Ok(WirePayload::Quant),
            "raw" | "f64" => Ok(WirePayload::Raw),
            other => Err(format!(
                "unknown wire payload {other:?} (expected quant|raw)"
            )),
        }
    }
}

impl std::fmt::Display for WirePayload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WirePayload::Quant => write!(f, "quant"),
            WirePayload::Raw => write!(f, "raw"),
        }
    }
}

/// Static description of one rank's place in a distributed run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DistConfig {
    /// This process's rank in `0..peers.len()`.
    pub rank: usize,
    /// Listen addresses of every rank, rank-ordered and identical on
    /// all processes.
    pub peers: Vec<String>,
    /// Chunk payload encoding.
    pub payload: WirePayload,
}

/// Measured traffic of one (or several accumulated) wire collectives.
#[derive(Debug, Clone, Copy, Default)]
pub struct WireStats {
    /// Bytes this rank actually put on the wire (frame headers included).
    pub bytes_sent: usize,
    /// Frames this rank sent.
    pub frames_sent: usize,
    /// Communication steps executed.
    pub steps: usize,
}

/// An established ring membership: one outgoing connection to the next
/// rank, one incoming from the previous.
pub struct WireRing {
    rank: usize,
    world: usize,
    payload: WirePayload,
    next: FramedStream,
    prev: FramedStream,
}

impl WireRing {
    /// Bind this rank's listener at `peers[rank]` and assemble the ring.
    pub fn establish(cfg: &DistConfig) -> Result<WireRing> {
        let world = cfg.peers.len();
        if world < 2 {
            bail!("distributed mode needs at least 2 peers, got {world}");
        }
        if cfg.rank >= world {
            bail!("--dist-rank {} out of range for {world} peers", cfg.rank);
        }
        let addr = &cfg.peers[cfg.rank];
        let listener = TcpListener::bind(addr).with_context(|| {
            format!(
                "binding the rank-{} ring listener at {addr} — port already in use (stale worker?) \
                 or address not local to this host",
                cfg.rank
            )
        })?;
        Self::establish_with_listener(cfg.rank, &cfg.peers, listener, cfg.payload)
    }

    /// Assemble the ring over an already-bound listener (tests and
    /// benches bind port 0 first so the peer list can carry the real
    /// ephemeral ports before any rank starts connecting).
    pub fn establish_with_listener(
        rank: usize,
        peers: &[String],
        listener: TcpListener,
        payload: WirePayload,
    ) -> Result<WireRing> {
        let world = peers.len();
        if world < 2 {
            bail!("distributed mode needs at least 2 peers, got {world}");
        }
        let next_rank = (rank + 1) % world;
        let prev_rank = (rank + world - 1) % world;
        // Dial next first: the connection parks in the peer listener's
        // backlog even before that process calls accept, so the
        // connect/accept order across ranks cannot deadlock.
        let next_desc = format!("rank {next_rank} ({})", peers[next_rank]);
        let stream = connect_with_retry(&peers[next_rank], &next_desc, CONNECT_RETRY_TOTAL)?;
        let mut next = FramedStream::new(stream, next_desc)?;
        let prev_desc = format!("rank {prev_rank} ({})", peers[prev_rank]);
        let stream = accept_with_deadline(&listener, &prev_desc, CONNECT_RETRY_TOTAL)?;
        let mut prev = FramedStream::new(stream, prev_desc)?;

        // Handshake: tell next who we are, learn who connected to us.
        let mut hello = [0u8; 16];
        hello[0..8].copy_from_slice(&(rank as u64).to_le_bytes());
        hello[8..16].copy_from_slice(&(world as u64).to_le_bytes());
        next.send(FrameKind::Hello, &hello)?;
        let (kind, payload_bytes) = prev.recv()?;
        if kind != FrameKind::Hello || payload_bytes.len() != 16 {
            bail!(
                "ring handshake from {} malformed (kind {kind:?}, {} bytes)",
                prev.peer(),
                payload_bytes.len()
            );
        }
        let got_rank = u64::from_le_bytes(payload_bytes[0..8].try_into().unwrap()) as usize;
        let got_world = u64::from_le_bytes(payload_bytes[8..16].try_into().unwrap()) as usize;
        if got_world != world {
            bail!(
                "ring handshake: {} believes the world has {got_world} ranks, this process {world} — \
                 inconsistent --dist-peers lists",
                prev.peer()
            );
        }
        if got_rank != prev_rank {
            bail!(
                "ring handshake: expected rank {prev_rank} on the incoming connection, got rank {got_rank} — \
                 inconsistent --dist-rank/--dist-peers wiring"
            );
        }
        Ok(WireRing {
            rank,
            world,
            payload,
            next,
            prev,
        })
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// All-reduce this rank's buffer in place against every other
    /// rank's equally-sized buffer. Bit-identical to
    /// [`ring_allreduce`](crate::comm::ring::ring_allreduce) over the
    /// same per-rank buffers (see module docs).
    pub fn allreduce(&mut self, buf: &mut [f64]) -> Result<WireStats> {
        let p = self.world;
        let r = self.rank;
        let n = buf.len();
        let mut stats = WireStats {
            steps: 2 * (p - 1),
            ..WireStats::default()
        };

        // Phase 1: reduce-scatter. Step indexing mirrors the simulation
        // verbatim: at step s, device d sends chunk (d − s) mod p, so
        // this rank receives chunk (r − 1 − s) mod p from rank r−1 and
        // adds it into its own copy (own += received — the simulation's
        // operand order).
        for step in 0..p - 1 {
            let send_c = (r + p - step) % p;
            let recv_c = (r + 2 * p - 1 - step) % p;
            let out = encode_payload(&buf[chunk_range(n, p, send_c)], self.payload);
            let rr = chunk_range(n, p, recv_c);
            let vals = exchange(&mut self.next, &mut self.prev, &out, rr.len(), &mut stats)?;
            for (x, v) in buf[rr].iter_mut().zip(vals.iter()) {
                *x += *v;
            }
        }

        // Phase 2: all-gather — circulate the reduced chunks, overwrite
        // on receive.
        for step in 0..p - 1 {
            let send_c = (r + 1 + p - step) % p;
            let recv_c = (r + p - step) % p;
            let out = encode_payload(&buf[chunk_range(n, p, send_c)], self.payload);
            let rr = chunk_range(n, p, recv_c);
            let vals = exchange(&mut self.next, &mut self.prev, &out, rr.len(), &mut stats)?;
            buf[rr].copy_from_slice(&vals);
        }
        Ok(stats)
    }
}

/// One ring step: send our encoded chunk to `next` on a scoped thread
/// while receiving the incoming chunk from `prev` on the caller — the
/// two directions progress independently, so chunks larger than the
/// socket buffers cannot deadlock the ring.
fn exchange(
    next: &mut FramedStream,
    prev: &mut FramedStream,
    out: &(FrameKind, Vec<u8>),
    expect_n: usize,
    stats: &mut WireStats,
) -> Result<Vec<f64>> {
    let (sent, received) = std::thread::scope(|scope| {
        let sender = scope.spawn(|| next.send(out.0, &out.1));
        let received = prev.recv();
        let sent = sender.join().expect("ring sender thread panicked");
        (sent, received)
    });
    stats.bytes_sent += sent?;
    stats.frames_sent += 1;
    let (kind, bytes) = received?;
    decode_payload(kind, &bytes, expect_n)
        .with_context(|| format!("decoding chunk from {}", prev.peer()))
}

/// Encode a chunk for the wire. Lossless in both modes: decoding
/// returns the exact input bit patterns.
pub fn encode_payload(vals: &[f64], mode: WirePayload) -> (FrameKind, Vec<u8>) {
    match mode {
        WirePayload::Raw => {
            let mut out = Vec::with_capacity(vals.len() * 8);
            for v in vals {
                out.extend_from_slice(&v.to_bits().to_le_bytes());
            }
            (FrameKind::RawF64, out)
        }
        WirePayload::Quant => (FrameKind::Quant, encode_quant(vals)),
    }
}

/// Quant layout (all integers LE):
///
/// ```text
/// n      u64   value count
/// n_nz   u64   nonzero-bit-pattern count
/// tz     u8    common trailing-zero shift of the nonzero patterns
/// sw     u8    packed symbol width in bits (1..=32; 0 iff n_nz == 0)
/// ns     u8    symbols per value (1..=2; 0 iff n_nz == 0)
/// mask   ⌈n/64⌉ u64 words, bit i set iff value i is nonzero
/// words  CompressedMatrixBuilder stream over the nonzero values
///        (n_nz rows × ns symbols of sw bits, incl. the pad word)
/// ```
fn encode_quant(vals: &[f64]) -> Vec<u8> {
    let n = vals.len();
    let mut mask_words = vec![0u64; n.div_ceil(64)];
    let mut nz: Vec<u64> = Vec::new();
    for (i, v) in vals.iter().enumerate() {
        let b = v.to_bits();
        if b != 0 {
            mask_words[i / 64] |= 1u64 << (i % 64);
            nz.push(b);
        }
    }
    let (tz, sw, ns, words) = if nz.is_empty() {
        (0u32, 0u32, 0u32, Vec::new())
    } else {
        let tz = nz.iter().map(|b| b.trailing_zeros()).min().unwrap();
        let width = nz.iter().map(|b| 64 - (b >> tz).leading_zeros()).max().unwrap();
        let ns = width.div_ceil(32); // 1 or 2 → symbols stay u32-sized
        let sw = width.div_ceil(ns);
        let sym_mask = (1u64 << sw) - 1;
        let mut b = CompressedMatrixBuilder::new(
            nz.len(),
            ns as usize,
            ns as usize,
            sym_mask as usize,
            true,
        );
        let mut row = [0u32; 2];
        for &bits in &nz {
            let shifted = bits >> tz;
            for (j, slot) in row.iter_mut().enumerate().take(ns as usize) {
                *slot = ((shifted >> (j as u32 * sw)) & sym_mask) as u32;
            }
            b.push_row(&row[..ns as usize]);
        }
        (tz, sw, ns, b.finish().words().to_vec())
    };
    let mut out = Vec::with_capacity(19 + (mask_words.len() + words.len()) * 8);
    out.extend_from_slice(&(n as u64).to_le_bytes());
    out.extend_from_slice(&(nz.len() as u64).to_le_bytes());
    out.push(tz as u8);
    out.push(sw as u8);
    out.push(ns as u8);
    for w in &mask_words {
        out.extend_from_slice(&w.to_le_bytes());
    }
    for w in &words {
        out.extend_from_slice(&w.to_le_bytes());
    }
    out
}

/// Decode a chunk, validating the declared shape against `expect_n`
/// and the byte count before touching any value. Corruption inside an
/// intact frame cannot occur (the transport checksum vetoes it), so
/// every error here points at a protocol bug, not line noise.
pub fn decode_payload(kind: FrameKind, bytes: &[u8], expect_n: usize) -> Result<Vec<f64>> {
    match kind {
        FrameKind::Hello => bail!("unexpected Hello frame mid-collective"),
        FrameKind::RawF64 => {
            if bytes.len() != expect_n * 8 {
                bail!(
                    "raw chunk length mismatch: got {} bytes, expected {} ({expect_n} f64s)",
                    bytes.len(),
                    expect_n * 8
                );
            }
            Ok(bytes
                .chunks_exact(8)
                .map(|c| f64::from_bits(u64::from_le_bytes(c.try_into().unwrap())))
                .collect())
        }
        FrameKind::Quant => decode_quant(bytes, expect_n),
    }
}

fn decode_quant(bytes: &[u8], expect_n: usize) -> Result<Vec<f64>> {
    if bytes.len() < 19 {
        bail!("quant chunk shorter than its 19-byte header");
    }
    let n = u64::from_le_bytes(bytes[0..8].try_into().unwrap()) as usize;
    let n_nz = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    let (tz, sw, ns) = (bytes[16] as u32, bytes[17] as u32, bytes[18] as u32);
    if n != expect_n {
        bail!("quant chunk length mismatch: header declares {n} values, expected {expect_n}");
    }
    if n_nz > n {
        bail!("quant chunk declares {n_nz} nonzeros out of {n} values");
    }
    let mask_len = n.div_ceil(64);
    let n_words = if n_nz == 0 {
        0
    } else {
        if !(1..=32).contains(&sw) || !(1..=2).contains(&ns) || tz > 63 {
            bail!("quant chunk header out of range: tz={tz} sw={sw} ns={ns}");
        }
        ((n_nz * ns as usize) as u64 * sw as u64).div_ceil(64) as usize + 1
    };
    let want_len = 19 + (mask_len + n_words) * 8;
    if bytes.len() != want_len {
        bail!(
            "quant chunk length mismatch: got {} bytes, shape needs {want_len}",
            bytes.len()
        );
    }
    let word_at = |i: usize| -> u64 {
        u64::from_le_bytes(bytes[19 + i * 8..27 + i * 8].try_into().unwrap())
    };
    let mask_words: Vec<u64> = (0..mask_len).map(word_at).collect();
    let set_bits: u32 = mask_words.iter().map(|w| w.count_ones()).sum();
    if set_bits as usize != n_nz {
        bail!("quant chunk mask has {set_bits} set bits but declares {n_nz} nonzeros");
    }
    if n > 0 && n % 64 != 0 && mask_words.last().map_or(false, |w| w >> (n % 64) != 0) {
        bail!("quant chunk mask has bits set beyond value {n}");
    }
    let mut out = vec![0.0f64; n];
    if n_nz == 0 {
        return Ok(out);
    }
    let words: Vec<u64> = (mask_len..mask_len + n_words).map(word_at).collect();
    let sym_mask = (1u64 << sw) - 1;
    let m = CompressedMatrix::from_words(
        words,
        sw,
        n_nz,
        ns as usize,
        ns as usize,
        sym_mask as usize,
        true,
    );
    let mut k = 0usize;
    for (i, slot) in out.iter_mut().enumerate() {
        if mask_words[i / 64] >> (i % 64) & 1 == 1 {
            let mut bits = 0u64;
            for j in 0..ns as usize {
                bits |= (m.symbol(k * ns as usize + j) as u64) << (j as u32 * sw);
            }
            *slot = f64::from_bits(bits << tz);
            k += 1;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn quant_codec_round_trips_exactly() {
        let mut rng = Pcg64::new(0xc0dec);
        let mut cases: Vec<Vec<f64>> = vec![
            vec![],
            vec![0.0],
            vec![0.0; 257],
            vec![-0.0, 0.0, 1.0, -1.0],
            vec![f64::NAN, f64::INFINITY, f64::NEG_INFINITY, f64::MIN_POSITIVE],
            vec![f64::from_bits(1), f64::from_bits(u64::MAX)],
        ];
        // f32-origin sums (the histogram regime): wide trailing-zero runs
        let f32ish: Vec<f64> = (0..300)
            .map(|_| (rng.next_f64() as f32 * 4.0 - 2.0) as f64)
            .collect();
        cases.push(f32ish);
        // arbitrary f64 bit patterns incl. zeros
        let arb: Vec<f64> = (0..513)
            .map(|i| {
                if i % 5 == 0 {
                    0.0
                } else {
                    f64::from_bits(rng.next_u64())
                }
            })
            .collect();
        cases.push(arb);
        for vals in cases {
            for mode in [WirePayload::Quant, WirePayload::Raw] {
                let (kind, bytes) = encode_payload(&vals, mode);
                let got = decode_payload(kind, &bytes, vals.len()).unwrap();
                assert_eq!(got.len(), vals.len());
                for (g, w) in got.iter().zip(vals.iter()) {
                    assert_eq!(g.to_bits(), w.to_bits(), "mode {mode}: {g} vs {w}");
                }
            }
        }
    }

    #[test]
    fn quant_beats_raw_on_sparse_f32_origin_payloads() {
        // Histogram-shaped data: 40% empty bins, the rest sums of f32
        // gradients — the regime the quant codec is built for.
        let mut rng = Pcg64::new(7);
        let vals: Vec<f64> = (0..4096)
            .map(|i| {
                if i % 5 < 2 {
                    0.0
                } else {
                    (rng.next_f64() as f32 * 2.0 - 1.0) as f64
                }
            })
            .collect();
        let (_, quant) = encode_payload(&vals, WirePayload::Quant);
        let (_, raw) = encode_payload(&vals, WirePayload::Raw);
        assert!(
            quant.len() * 10 < raw.len() * 9,
            "quant {} bytes vs raw {} — expected >10% reduction",
            quant.len(),
            raw.len()
        );
    }

    #[test]
    fn malformed_quant_chunks_are_rejected() {
        let (kind, bytes) = encode_payload(&[1.0, 2.0, 0.0], WirePayload::Quant);
        // wrong expected length
        assert!(decode_payload(kind, &bytes, 4).is_err());
        // truncated body
        assert!(decode_payload(kind, &bytes[..bytes.len() - 1], 3).is_err());
        // raw with wrong byte count
        assert!(decode_payload(FrameKind::RawF64, &[0u8; 12], 2).is_err());
        // hello mid-collective
        assert!(decode_payload(FrameKind::Hello, &[], 0).is_err());
    }
}
