//! Length-prefixed, checksummed frame transport for the distributed
//! ring (`comm::wire`), built on std TCP only.
//!
//! ## Frame format
//!
//! Every message on a ring connection is one frame:
//!
//! ```text
//! magic    u32 LE   0x5852_494e ("NIRX" LE) — catches cross-protocol
//!                   connects (e.g. a serve client dialing a ring port)
//! kind     u8       Hello | RawF64 | Quant (comm::wire payload codecs)
//! len      u64 LE   payload byte count
//! checksum u64 LE   FNV-1a 64 over the payload bytes (page::fnv1a64 —
//!                   the same core that guards spilled pages and
//!                   prediction fingerprints)
//! payload  [u8; len]
//! ```
//!
//! A truncated frame surfaces as a length/EOF error, a flipped payload
//! bit as a checksum mismatch — never as a silently wrong histogram
//! sum. Both are detected on the receive side before any bytes reach
//! the dequantiser.
//!
//! ## Timeouts and retry
//!
//! * **Connect** retries with exponential backoff (10 ms doubling to
//!   500 ms) for up to [`CONNECT_RETRY_TOTAL`], because peer processes
//!   launch in arbitrary order and spend unequal time in ingest before
//!   they bind their listeners.
//! * **Established connections** carry [`IO_TIMEOUT`] read/write
//!   timeouts as a failure detector: a healthy peer answers a ring step
//!   in microseconds, so a timeout means the peer crashed or stalled,
//!   and the error says which rank/address to look at.

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::compress::page::fnv1a64;

/// First four bytes of every frame.
pub const FRAME_MAGIC: u32 = 0x5852_494e;
/// Fixed frame header size: magic + kind + len + checksum.
pub const FRAME_HEADER_LEN: usize = 4 + 1 + 8 + 8;
/// Hard cap on a single frame payload — a corrupt length field must not
/// turn into a multi-gigabyte allocation before the checksum can veto it.
pub const MAX_FRAME_LEN: u64 = 1 << 32;
/// Read/write timeout on established ring connections (failure detector,
/// not a polling interval — see module docs).
pub const IO_TIMEOUT: Duration = Duration::from_secs(30);
/// Total budget for connect retries while the ring assembles.
pub const CONNECT_RETRY_TOTAL: Duration = Duration::from_secs(60);

/// What a frame's payload contains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Ring-assembly handshake: `rank u64 LE, world u64 LE`.
    Hello,
    /// `n` f64 values as `n·8` little-endian bytes.
    RawF64,
    /// Losslessly packed f64s (`comm::wire::encode_payload` layout).
    Quant,
}

impl FrameKind {
    fn to_byte(self) -> u8 {
        match self {
            FrameKind::Hello => 0,
            FrameKind::RawF64 => 1,
            FrameKind::Quant => 2,
        }
    }

    fn from_byte(b: u8) -> Result<FrameKind> {
        match b {
            0 => Ok(FrameKind::Hello),
            1 => Ok(FrameKind::RawF64),
            2 => Ok(FrameKind::Quant),
            other => bail!("unknown frame kind byte {other:#04x}"),
        }
    }
}

/// Serialize one frame into `w`. Returns the total bytes written
/// (header + payload) so callers can account wire traffic exactly.
pub fn write_frame_to(w: &mut impl Write, kind: FrameKind, payload: &[u8]) -> Result<usize> {
    let mut header = [0u8; FRAME_HEADER_LEN];
    header[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
    header[4] = kind.to_byte();
    header[5..13].copy_from_slice(&(payload.len() as u64).to_le_bytes());
    header[13..21].copy_from_slice(&fnv1a64(payload.iter().copied()).to_le_bytes());
    w.write_all(&header).context("writing frame header")?;
    w.write_all(payload).context("writing frame payload")?;
    Ok(FRAME_HEADER_LEN + payload.len())
}

/// Read and verify one frame from `r`. A short read is a length error
/// ("truncated frame"), a payload whose FNV-1a does not match the
/// header is a checksum error — corrupted data never decodes.
pub fn read_frame_from(r: &mut impl Read) -> Result<(FrameKind, Vec<u8>)> {
    let mut header = [0u8; FRAME_HEADER_LEN];
    r.read_exact(&mut header)
        .context("truncated frame: short read inside the frame header")?;
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    if magic != FRAME_MAGIC {
        bail!("bad frame magic {magic:#010x} (expected {FRAME_MAGIC:#010x}) — peer is not speaking the ring protocol");
    }
    let kind = FrameKind::from_byte(header[4])?;
    let len = u64::from_le_bytes(header[5..13].try_into().unwrap());
    if len > MAX_FRAME_LEN {
        bail!("frame length {len} exceeds the {MAX_FRAME_LEN}-byte cap — corrupt length field?");
    }
    let want_sum = u64::from_le_bytes(header[13..21].try_into().unwrap());
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)
        .with_context(|| format!("truncated frame: payload shorter than the declared {len} bytes"))?;
    let got_sum = fnv1a64(payload.iter().copied());
    if got_sum != want_sum {
        bail!(
            "frame checksum mismatch: payload hashes to {got_sum:#018x}, header declares {want_sum:#018x} — corrupted in transit"
        );
    }
    Ok((kind, payload))
}

/// One ring connection: a TCP stream plus peer identity for error
/// messages and exact sent/received byte counters.
pub struct FramedStream {
    stream: TcpStream,
    /// Human-readable peer identity, e.g. `rank 2 (127.0.0.1:7003)`.
    peer: String,
    pub bytes_sent: usize,
    pub bytes_received: usize,
}

impl FramedStream {
    /// Wrap an established connection, arming [`IO_TIMEOUT`] read/write
    /// timeouts on it.
    pub fn new(stream: TcpStream, peer: String) -> Result<FramedStream> {
        stream
            .set_read_timeout(Some(IO_TIMEOUT))
            .with_context(|| format!("setting read timeout towards {peer}"))?;
        stream
            .set_write_timeout(Some(IO_TIMEOUT))
            .with_context(|| format!("setting write timeout towards {peer}"))?;
        stream.set_nodelay(true).ok(); // latency over batching for ring steps
        Ok(FramedStream {
            stream,
            peer,
            bytes_sent: 0,
            bytes_received: 0,
        })
    }

    pub fn peer(&self) -> &str {
        &self.peer
    }

    pub fn send(&mut self, kind: FrameKind, payload: &[u8]) -> Result<usize> {
        let n = write_frame_to(&mut self.stream, kind, payload)
            .map_err(|e| annotate_peer_error(e, &self.peer))?;
        self.bytes_sent += n;
        Ok(n)
    }

    pub fn recv(&mut self) -> Result<(FrameKind, Vec<u8>)> {
        let (kind, payload) =
            read_frame_from(&mut self.stream).map_err(|e| annotate_peer_error(e, &self.peer))?;
        self.bytes_received += FRAME_HEADER_LEN + payload.len();
        Ok((kind, payload))
    }
}

/// Make IO failures actionable: name the peer, and translate a timeout
/// into "the peer stalled" rather than a bare os error.
fn annotate_peer_error(e: anyhow::Error, peer: &str) -> anyhow::Error {
    let timed_out = e
        .downcast_ref::<std::io::Error>()
        .map(|io| matches!(io.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut))
        .unwrap_or(false);
    if timed_out {
        e.context(format!(
            "peer {peer} did not answer within {IO_TIMEOUT:?} — worker crashed or stalled?"
        ))
    } else {
        e.context(format!("ring connection to {peer} failed"))
    }
}

/// Dial `addr` with exponential backoff until `budget` elapses. Ring
/// peers start in arbitrary order, so early connection refusals are
/// expected and retried; only exhausting the budget is an error.
pub fn connect_with_retry(addr: &str, peer: &str, budget: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + budget;
    let mut backoff = Duration::from_millis(10);
    let mut last_err: Option<std::io::Error> = None;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    let detail = last_err
                        .map(|l| format!("{l}"))
                        .unwrap_or_else(|| format!("{e}"));
                    bail!(
                        "could not connect to {peer} at {addr} within {budget:?}: {detail} — \
                         is that worker running with the same --dist-peers list?"
                    );
                }
                last_err = Some(e);
                std::thread::sleep(backoff.min(deadline.saturating_duration_since(Instant::now())));
                backoff = (backoff * 2).min(Duration::from_millis(500));
            }
        }
    }
}

/// Accept one connection on `listener` before `budget` elapses,
/// polling non-blockingly so a never-arriving peer produces an
/// actionable error instead of a hang.
pub fn accept_with_deadline(
    listener: &TcpListener,
    peer: &str,
    budget: Duration,
) -> Result<TcpStream> {
    listener
        .set_nonblocking(true)
        .context("setting ring listener nonblocking")?;
    let deadline = Instant::now() + budget;
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false).context("resetting accepted ring stream to blocking")?;
                return Ok(stream);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    bail!(
                        "no connection from {peer} within {budget:?} — \
                         is that worker running, and does its --dist-peers entry point at this process?"
                    );
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e).with_context(|| format!("accepting ring connection from {peer}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_round_trips() {
        for payload in [&[][..], &[0u8][..], &[1, 2, 3, 0xff][..], &vec![7u8; 4096][..]] {
            let mut buf = Vec::new();
            let n = write_frame_to(&mut buf, FrameKind::RawF64, payload).unwrap();
            assert_eq!(n, FRAME_HEADER_LEN + payload.len());
            assert_eq!(buf.len(), n);
            let (kind, got) = read_frame_from(&mut &buf[..]).unwrap();
            assert_eq!(kind, FrameKind::RawF64);
            assert_eq!(got, payload);
        }
    }

    #[test]
    fn flipped_payload_bit_is_a_checksum_error() {
        let payload = vec![0x5au8; 257];
        let mut buf = Vec::new();
        write_frame_to(&mut buf, FrameKind::Quant, &payload).unwrap();
        for flip_at in [FRAME_HEADER_LEN, buf.len() - 1, FRAME_HEADER_LEN + 100] {
            let mut bad = buf.clone();
            bad[flip_at] ^= 0x01;
            let err = read_frame_from(&mut &bad[..]).unwrap_err();
            assert!(
                format!("{err:#}").contains("checksum"),
                "flip at {flip_at}: {err:#}"
            );
        }
    }

    #[test]
    fn truncated_frame_is_a_length_error() {
        let payload = vec![9u8; 64];
        let mut buf = Vec::new();
        write_frame_to(&mut buf, FrameKind::RawF64, &payload).unwrap();
        for cut in [1, FRAME_HEADER_LEN - 1, FRAME_HEADER_LEN + 10, buf.len() - 1] {
            let err = read_frame_from(&mut &buf[..cut]).unwrap_err();
            assert!(
                format!("{err:#}").contains("truncated"),
                "cut at {cut}: {err:#}"
            );
        }
    }

    #[test]
    fn bad_magic_and_kind_are_rejected() {
        let mut buf = Vec::new();
        write_frame_to(&mut buf, FrameKind::Hello, &[1, 2]).unwrap();
        let mut bad = buf.clone();
        bad[0] ^= 0xff;
        assert!(format!("{:#}", read_frame_from(&mut &bad[..]).unwrap_err()).contains("magic"));
        let mut bad = buf.clone();
        bad[4] = 99;
        assert!(
            format!("{:#}", read_frame_from(&mut &bad[..]).unwrap_err()).contains("frame kind")
        );
    }

    #[test]
    fn oversized_length_field_is_rejected_before_allocating() {
        let mut buf = Vec::new();
        write_frame_to(&mut buf, FrameKind::RawF64, &[0u8; 8]).unwrap();
        buf[5..13].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = read_frame_from(&mut &buf[..]).unwrap_err();
        assert!(format!("{err:#}").contains("cap"), "{err:#}");
    }
}
