//! Execution engine: a dependency-free **persistent parked worker pool**
//! with `parallel_map` / `parallel_for` primitives and a **deterministic
//! fixed-chunk reduction order**.
//!
//! # Pool lifecycle: spawn once → park → wake → join-at-drop
//!
//! An [`ExecContext`] with `threads > 1` owns a pool of long-lived worker
//! threads. Workers are spawned **once** (lazily, on the first parallel
//! call) and then *park* on a condvar between calls; each parallel
//! primitive publishes one *job* (an atomic task-claim counter plus a
//! borrowed closure), wakes the pool, participates in its own job from
//! the calling thread, and returns when every task has completed. Workers
//! go back to parking — they are never re-spawned. Dropping the last
//! clone of the context shuts the pool down and joins the workers.
//!
//! [`ExecContext::fork`] hands out *budget sub-slices of the same pool*:
//! a forked context caps how many workers may join its jobs
//! (`max_helpers`) but shares the worker threads, so nested device/shard
//! parallelism (devices × chunks) never oversubscribes the machine. A
//! pool worker that itself submits a nested job always participates in
//! that job, so nesting cannot deadlock even when every worker is busy.
//!
//! The previous engine — scoped `std::thread::scope` spawning per call —
//! is kept, byte-for-byte result-identical, behind `XGB_SCOPED_EXEC=1`
//! (mirroring the `XGB_SCALAR_KERNELS` kernel-mode escape hatch) as the
//! independent reference the property tests and the `ci.sh` exec-mode
//! smoke compare against. Per-call wake/spawn overhead is measured either
//! way and surfaced as `BuildStats::wake_wall_secs`.
//!
//! # Real threads vs the simulated multi-GPU clock
//!
//! The coordinator models the paper's multi-GPU system two ways at once:
//!
//! * the **simulated clock** (`BuildStats::simulated_secs`) prices each
//!   round as `max_d(compute_d) + comm(round)` under the ring cost model —
//!   the analytic Figure-2 quantity, independent of host hardware;
//! * the **real engine** (this module) actually executes device shards on
//!   OS threads and chunk-parallelises the per-shard hot loops, so
//!   measured wall-clock (`BuildStats::hist_wall_secs` /
//!   `partition_wall_secs`) genuinely improves with
//!   [`ExecContext::threads`].
//!
//! # Determinism contract
//!
//! Floating-point addition is not associative, so naive work-stealing
//! reductions produce thread-count-dependent results. Every reduction in
//! this crate therefore follows one rule: **work is split into fixed-size
//! chunks whose boundaries depend only on the input size, and partial
//! results are merged in ascending chunk index** — never in completion
//! order. Workers may *compute* chunks in any order (claims go through an
//! atomic counter for load balance) but the merge is a fixed left-to-right
//! fold, so `threads = 1` and `threads = 64` produce bit-identical
//! histograms, trees, predictions and metrics — and the parked pool and
//! the scoped engine are bit-identical to each other, because results are
//! always slot-addressed by task index and never depend on which worker
//! (pooled or freshly spawned) ran a task. `rust/tests/parallel_exec.rs`
//! and the exec-mode property in `rust/tests/prop_invariants.rs` pin this.
//!
//! # Round arenas
//!
//! [`BufferPool`] is the reusable-buffer primitive behind the
//! zero-allocation steady state: hot phases *take* a scratch buffer
//! (recycled, cleared) and *put* it back after the round, so after the
//! warm-up round the steady state allocates ~nothing. Pools count hits,
//! misses (fresh allocations) and reused bytes ([`ArenaStats`]), which
//! the coordinator aggregates into `BuildStats::arena_bytes_reused` /
//! `arena_allocs`.

use std::any::Any;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

/// Default rows-per-chunk for row-wise phases (histograms, partitioning,
/// gradients, prediction). Chunk boundaries are a pure function of the
/// input length — **never** of the thread count — which is what keeps the
/// reduction order fixed (see module docs).
pub const ROW_CHUNK: usize = 8192;

/// Rows per traversal block inside a `ROW_CHUNK`: a block of rows walks
/// one tree (level-synchronously) before the next tree runs, keeping the
/// tree's hot top levels in cache across the block. Interchanging *which
/// row traverses next* never reorders any single row's `+=` chain, so
/// blocked traversal is bit-identical to row-at-a-time (proved out by
/// `serve/flat.rs::predict_margins`, now shared by the quantised
/// prediction kernels in `predict/quantised.rs`).
pub const BLOCK_ROWS: usize = 64;

/// Rows per histogram micro-block: gradients are pre-converted to f64
/// and packed symbols block-decoded `HIST_BLOCK_ROWS` rows at a time
/// before the accumulation loop runs. Strictly smaller than `ROW_CHUNK`
/// and always applied *inside* one chunk, so the f64 accumulation order
/// is untouched (see `hist/mod.rs` module docs).
pub const HIST_BLOCK_ROWS: usize = 8;

/// Read a boolean env flag exactly once per process (`1`/any non-empty
/// value other than `0` is true), caching the answer in the caller's
/// `OnceLock`. Shared by every mode-selection env var so there is a
/// single idiom and no per-call env reads or races.
fn env_flag(var: &str, cell: &OnceLock<bool>) -> bool {
    *cell.get_or_init(|| {
        std::env::var(var)
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
    })
}

/// Which inner-loop implementation the hot kernels run: the blocked,
/// branchless kernels (default) or the original scalar loops kept as the
/// bit-parity reference. Selected once per process from the
/// `XGB_SCALAR_KERNELS` env var (`1`/any non-empty value other than `0`
/// selects `Scalar`); benches and the property tests bypass the env and
/// pass a mode explicitly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// Block-decoded, branchless kernels (`hist`/`predict` hot loops).
    Blocked,
    /// The original row-at-a-time scalar loops — the reference the
    /// blocked kernels are pinned bit-identical to.
    Scalar,
}

impl KernelMode {
    /// The process-wide mode (env read once, then cached).
    pub fn from_env() -> KernelMode {
        static SCALAR: OnceLock<bool> = OnceLock::new();
        if env_flag("XGB_SCALAR_KERNELS", &SCALAR) {
            KernelMode::Scalar
        } else {
            KernelMode::Blocked
        }
    }
}

/// Which execution engine [`ExecContext::new`] builds: the persistent
/// parked worker pool (default) or the original scoped spawn-per-call
/// engine kept as the independent reference (`XGB_SCOPED_EXEC=1`). The
/// two are bit-identical in every result; only wake/spawn overhead
/// differs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Long-lived workers parked between calls (spawn once → park →
    /// wake → join-at-drop).
    Persistent,
    /// `std::thread::scope` spawn-per-call — the reference engine.
    Scoped,
}

/// 0 = follow the env, 1 = force Persistent, 2 = force Scoped.
static MODE_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Process-wide override of the engine choice, for in-process
/// mode-comparison tests and benches that cannot use the (once-cached)
/// env var. Safe to flip mid-process *because* the engines are
/// bit-identical: concurrently running code only ever differs in
/// wake overhead, never in results.
pub fn set_exec_mode_override(mode: Option<ExecMode>) {
    let v = match mode {
        None => 0,
        Some(ExecMode::Persistent) => 1,
        Some(ExecMode::Scoped) => 2,
    };
    MODE_OVERRIDE.store(v, Ordering::SeqCst);
}

impl ExecMode {
    /// The process-wide mode: the test/bench override if set, else the
    /// `XGB_SCOPED_EXEC` env var (read once, then cached).
    pub fn from_env() -> ExecMode {
        static SCOPED: OnceLock<bool> = OnceLock::new();
        match MODE_OVERRIDE.load(Ordering::SeqCst) {
            1 => ExecMode::Persistent,
            2 => ExecMode::Scoped,
            _ => {
                if env_flag("XGB_SCOPED_EXEC", &SCOPED) {
                    ExecMode::Scoped
                } else {
                    ExecMode::Persistent
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Persistent worker pool
// ---------------------------------------------------------------------------

/// Lifetime-erased pointer to a job's task closure. The pointer is only
/// dereferenced between a successful task claim and that task's
/// `pending` decrement, a window during which the submitting call is
/// still blocked in [`WorkerPool::run_job`] — so the borrowed closure is
/// guaranteed alive (see the safety comment in [`Job::execute`]).
struct TaskPtr(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointee is `Sync` (shared calls from any thread are fine)
// and the pointer's validity is enforced by the run_job completion wait.
unsafe impl Send for TaskPtr {}
unsafe impl Sync for TaskPtr {}

/// One published batch of tasks: an atomic claim counter over
/// `0..n_tasks`, the erased closure, and completion/panic bookkeeping.
struct Job {
    task: TaskPtr,
    n_tasks: usize,
    /// Next unclaimed task index (may overshoot `n_tasks`).
    next: AtomicUsize,
    /// Tasks not yet *completed*. The submitter returns only when this
    /// hits zero — the memory-safety linchpin for the borrowed closure.
    pending: AtomicUsize,
    /// Workers that have joined this job (the submitter is not counted).
    /// Capped at `max_helpers` so a forked budget never oversubscribes.
    helpers: AtomicUsize,
    max_helpers: usize,
    done: Mutex<bool>,
    done_cv: Condvar,
    /// First panic payload from any task, resumed on the submitter.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

impl Job {
    /// May another parked worker usefully join? (Checked under the pool
    /// mutex, so the helper cap is never overshot.)
    fn joinable(&self) -> bool {
        self.next.load(Ordering::Relaxed) < self.n_tasks
            && self.helpers.load(Ordering::Relaxed) < self.max_helpers
    }

    /// Claim-and-run loop shared by the submitter and every helper.
    fn execute(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n_tasks {
                break;
            }
            // SAFETY: we hold an unfinished claim on task `i`, so
            // `pending >= 1` until the decrement below — and the
            // submitter blocks in run_job until `pending == 0`, keeping
            // the closure (a borrow of its stack) alive for this call.
            let f = unsafe { &*self.task.0 };
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| f(i))) {
                let mut slot = self.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(p);
                }
            }
            if self.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                let mut d = self.done.lock().unwrap();
                *d = true;
                self.done_cv.notify_all();
            }
        }
    }
}

struct PoolState {
    /// Jobs with unclaimed tasks. Submitters push/remove; parked workers
    /// scan for a joinable entry.
    jobs: Vec<Arc<Job>>,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Parked workers wait here; notified on job submission + shutdown.
    work_cv: Condvar,
}

fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(j) = st.jobs.iter().find(|j| j.joinable()) {
                    j.helpers.fetch_add(1, Ordering::Relaxed);
                    break j.clone();
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        job.execute();
        // parked again on the next lock/wait above
    }
}

/// The persistent pool: `n_workers` parked OS threads plus whatever
/// thread calls in. Joined (after a shutdown flag + wake) when the last
/// owning [`ExecContext`] clone drops.
struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    n_workers: usize,
    /// Accumulated submit/wake + post-claim join-wait nanos — the pool's
    /// per-call overhead (everything that is not task execution on the
    /// calling thread).
    wake_nanos: AtomicU64,
}

impl WorkerPool {
    fn start(n_workers: usize) -> WorkerPool {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                jobs: Vec::new(),
                shutdown: false,
            }),
            work_cv: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(n_workers);
        for k in 0..n_workers {
            let sh = shared.clone();
            // a failed spawn just means fewer helpers; jobs still
            // complete on the submitting thread
            if let Ok(h) = std::thread::Builder::new()
                .name(format!("xgb-exec-{k}"))
                .spawn(move || worker_loop(sh))
            {
                handles.push(h);
            }
        }
        WorkerPool {
            shared,
            n_workers: handles.len(),
            handles,
            wake_nanos: AtomicU64::new(0),
        }
    }

    /// Publish `n_tasks` tasks under a `budget`-thread cap, participate
    /// from the calling thread, and return once every task completed.
    /// Nested submissions from pool workers are fine: the submitter
    /// always participates, so progress never depends on a free worker.
    fn run_job(&self, budget: usize, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        debug_assert!(n_tasks > 0);
        let t0 = Instant::now();
        // lifetime erasure; validity is enforced by the completion wait
        // below (see Job::execute safety comment)
        let task = TaskPtr(unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
                as *const _
        });
        let job = Arc::new(Job {
            task,
            n_tasks,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(n_tasks),
            helpers: AtomicUsize::new(0),
            max_helpers: budget.min(n_tasks).saturating_sub(1),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
            panic: Mutex::new(None),
        });
        if job.max_helpers > 0 {
            self.shared.state.lock().unwrap().jobs.push(job.clone());
            self.shared.work_cv.notify_all();
        }
        let submitted = t0.elapsed();
        job.execute();
        let wait_t = Instant::now();
        {
            let mut d = job.done.lock().unwrap();
            while !*d {
                d = job.done_cv.wait(d).unwrap();
            }
        }
        let waited = wait_t.elapsed();
        if job.max_helpers > 0 {
            let mut st = self.shared.state.lock().unwrap();
            st.jobs.retain(|j| !Arc::ptr_eq(j, &job));
        }
        self.wake_nanos.fetch_add(
            (submitted.as_nanos() + waited.as_nanos()) as u64,
            Ordering::Relaxed,
        );
        if let Some(p) = job.panic.lock().unwrap().take() {
            resume_unwind(p);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Pool storage shared by every clone/fork of a pooled [`ExecContext`].
/// Workers are spawned lazily on the first parallel call (so contexts
/// created only to *report* a thread count never spawn anything).
struct LazyPool {
    /// The root context's resolved budget; the pool spawns
    /// `root_threads - 1` workers (the caller is the remaining thread).
    root_threads: usize,
    cell: OnceLock<WorkerPool>,
}

impl LazyPool {
    fn get(&self) -> &WorkerPool {
        self.cell
            .get_or_init(|| WorkerPool::start(self.root_threads.saturating_sub(1)))
    }
}

#[derive(Clone)]
enum Engine {
    /// `threads <= 1`: every primitive runs inline on the caller.
    Serial,
    /// Scoped spawn-per-call reference engine; the counter accumulates
    /// measured spawn nanos (the scoped analogue of pool wake time).
    Scoped(Arc<AtomicU64>),
    /// The persistent parked pool (shared across clones and forks).
    Pooled(Arc<LazyPool>),
}

/// A thread budget for the parallel primitives, backed by either the
/// persistent pool or the scoped reference engine (module docs). Cheap
/// to clone: clones and [`fork`](ExecContext::fork)s share one pool.
#[derive(Clone)]
pub struct ExecContext {
    threads: usize,
    engine: Engine,
}

impl std::fmt::Debug for ExecContext {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mode = match self.engine {
            Engine::Serial => "serial",
            Engine::Scoped(_) => "scoped",
            Engine::Pooled(_) => "pooled",
        };
        write!(f, "ExecContext({} threads, {mode})", self.threads)
    }
}

impl Default for ExecContext {
    /// Defaults to all available cores (same as `ExecContext::new(0)`).
    fn default() -> Self {
        ExecContext::new(0)
    }
}

impl ExecContext {
    /// `threads = 0` resolves to the machine's available parallelism;
    /// `threads = 1` is the serial engine (no threads are ever spawned).
    /// The engine is the persistent pool unless `XGB_SCOPED_EXEC=1` (or
    /// a [`set_exec_mode_override`]) selects the scoped reference.
    pub fn new(threads: usize) -> Self {
        Self::with_mode(threads, ExecMode::from_env())
    }

    /// Explicit-engine constructor for benches and mode-parity tests
    /// (the env-independent analogue of the kernel `_mode` functions).
    pub fn with_mode(threads: usize, mode: ExecMode) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        if threads <= 1 {
            return ExecContext::serial();
        }
        let engine = match mode {
            ExecMode::Scoped => Engine::Scoped(Arc::new(AtomicU64::new(0))),
            ExecMode::Persistent => Engine::Pooled(Arc::new(LazyPool {
                root_threads: threads,
                cell: OnceLock::new(),
            })),
        };
        ExecContext { threads, engine }
    }

    /// The serial engine: every primitive runs inline on the caller.
    pub fn serial() -> Self {
        ExecContext {
            threads: 1,
            engine: Engine::Serial,
        }
    }

    /// Resolved worker count (>= 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Split this budget across `ways` concurrent consumers (e.g. give
    /// each of `p` device shards `threads / p` workers for its own
    /// chunk-level parallelism). Never returns a zero budget. The forked
    /// context **shares this context's worker pool** — the sub-budget
    /// caps how many pooled workers may join each of its jobs, so nested
    /// parallelism never oversubscribes the root budget.
    pub fn fork(&self, ways: usize) -> ExecContext {
        ExecContext {
            threads: (self.threads / ways.max(1)).max(1),
            engine: self.engine.clone(),
        }
    }

    /// Persistent workers currently spawned for this context's pool
    /// (0 for the serial/scoped engines, and before the first parallel
    /// call wakes the lazy pool).
    pub fn pool_workers(&self) -> usize {
        match &self.engine {
            Engine::Pooled(p) => p.cell.get().map(|w| w.n_workers).unwrap_or(0),
            _ => 0,
        }
    }

    /// Accumulated engine overhead seconds: pool submit/wake + join-wait
    /// time (persistent), or measured thread-spawn time (scoped). Shared
    /// across clones/forks of one context; 0 for the serial engine.
    pub fn wake_wall_secs(&self) -> f64 {
        let nanos = match &self.engine {
            Engine::Serial => 0,
            Engine::Scoped(n) => n.load(Ordering::Relaxed),
            Engine::Pooled(p) => p
                .cell
                .get()
                .map(|w| w.wake_nanos.load(Ordering::Relaxed))
                .unwrap_or(0),
        };
        nanos as f64 * 1e-9
    }

    /// Core primitive: run `f(0), f(1), …, f(n_tasks - 1)` and return the
    /// results **in task-index order**, regardless of which worker ran
    /// which task. Tasks are claimed from an atomic counter so long tasks
    /// don't serialise behind short ones.
    pub fn run_indexed<R, F>(&self, n_tasks: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        if self.threads <= 1 || n_tasks <= 1 {
            return (0..n_tasks).map(f).collect();
        }
        let slots: Vec<Mutex<Option<R>>> = (0..n_tasks).map(|_| Mutex::new(None)).collect();
        match &self.engine {
            Engine::Serial => unreachable!("serial engines have threads == 1"),
            Engine::Pooled(pool) => {
                pool.get().run_job(self.threads, n_tasks, &|i| {
                    *slots[i].lock().unwrap() = Some(f(i));
                });
            }
            Engine::Scoped(spawn_nanos) => {
                let n_workers = self.threads.min(n_tasks);
                let next = AtomicUsize::new(0);
                let t0 = Instant::now();
                std::thread::scope(|scope| {
                    for _ in 0..n_workers {
                        scope.spawn(|| loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= n_tasks {
                                break;
                            }
                            let r = f(i);
                            *slots[i].lock().unwrap() = Some(r);
                        });
                    }
                    spawn_nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                });
            }
        }
        slots
            .into_iter()
            .map(|s| s.into_inner().unwrap().expect("worker filled every slot"))
            .collect()
    }

    /// Parallel map over a shared slice; results in item order.
    pub fn parallel_map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        self.run_indexed(items.len(), |i| f(i, &items[i]))
    }

    /// Parallel map with exclusive access to each item (one task per
    /// item — the device-shard shape); results in item order.
    pub fn parallel_map_mut<T, R, F>(&self, items: &mut [T], f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> R + Sync,
    {
        if self.threads <= 1 || items.len() <= 1 {
            return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        // Hand each worker a distinct &mut T through a per-item Mutex;
        // indices are claimed exactly once so each lock is uncontended.
        let cells: Vec<Mutex<&mut T>> = items.iter_mut().map(Mutex::new).collect();
        self.run_indexed(cells.len(), |i| {
            let mut guard = cells[i].lock().unwrap();
            f(i, &mut **guard)
        })
    }

    /// Map over fixed chunks of `0..n` (chunk boundaries depend only on
    /// `n` and `chunk`); results in ascending chunk-index order. This is
    /// the primitive behind every deterministic reduction in the crate.
    pub fn map_chunks<R, F>(&self, n: usize, chunk: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, Range<usize>) -> R + Sync,
    {
        let chunk = chunk.max(1);
        let n_chunks = n.div_ceil(chunk);
        self.run_indexed(n_chunks, |ci| {
            let lo = ci * chunk;
            let hi = (lo + chunk).min(n);
            f(ci, lo..hi)
        })
    }

    /// Parallel for over fixed chunks of `0..n`, no results collected.
    pub fn for_each_chunk<F>(&self, n: usize, chunk: usize, f: F)
    where
        F: Fn(usize, Range<usize>) + Sync,
    {
        self.map_chunks(n, chunk, |ci, r| f(ci, r));
    }

    /// Run `main` on the caller while `worker` runs concurrently on a
    /// scoped thread spawned for this call — the producer/consumer shape
    /// of the paged histogram build (the worker prefetches the next page
    /// from disk while the caller accumulates the current one). The
    /// worker thread is **in addition to** the configured `threads()`
    /// budget and deliberately *not* a pool worker: it spends its life
    /// blocked on I/O or a channel, not computing, so parking a compute
    /// worker on it would waste a budget slot. It always runs; callers
    /// that want a serial fallback (e.g. `threads() <= 1`) should skip
    /// this call and inline both sides. A panicking worker propagates at
    /// the scope join as usual.
    pub fn run_with_worker<R, W, F>(&self, worker: W, main: F) -> R
    where
        R: Send,
        W: FnOnce() + Send,
        F: FnOnce() -> R + Send,
    {
        std::thread::scope(|scope| {
            scope.spawn(worker);
            main()
        })
    }

    /// Parallel for over disjoint mutable chunks of a slice. `f` receives
    /// `(chunk_index, start_offset, chunk)`; chunks are the usual fixed
    /// partition of the slice so writes are trivially race-free.
    pub fn for_each_slice_mut<T, F>(&self, data: &mut [T], chunk: usize, f: F)
    where
        T: Send,
        F: Fn(usize, usize, &mut [T]) + Sync,
    {
        let chunk = chunk.max(1);
        if self.threads <= 1 || data.len() <= chunk {
            // same chunk layout as the parallel path, run inline
            for (ci, c) in data.chunks_mut(chunk).enumerate() {
                f(ci, ci * chunk, c);
            }
            return;
        }
        let cells: Vec<Mutex<(usize, &mut [T])>> = data
            .chunks_mut(chunk)
            .enumerate()
            .map(|(ci, c)| Mutex::new((ci * chunk, c)))
            .collect();
        self.run_indexed(cells.len(), |i| {
            let mut guard = cells[i].lock().unwrap();
            let start = guard.0;
            f(i, start, &mut *guard.1);
        });
    }
}

// ---------------------------------------------------------------------------
// Round arenas
// ---------------------------------------------------------------------------

/// Hit/miss/reuse counters of one or more [`BufferPool`]s. `misses` is
/// the number of *fresh allocations* — the steady-state target is ~0 per
/// round after warm-up (`BuildStats::arena_allocs`).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ArenaStats {
    /// Takes served from a recycled buffer.
    pub hits: u64,
    /// Takes that had to allocate fresh.
    pub misses: u64,
    /// Bytes of pre-existing capacity handed back out on hits.
    pub bytes_reused: u64,
}

impl ArenaStats {
    pub fn merge(&mut self, other: ArenaStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.bytes_reused += other.bytes_reused;
    }
}

/// A reusable-buffer pool: the round-arena primitive. `take(len)` hands
/// out a cleared, `len`-sized buffer (recycled when one is parked,
/// freshly allocated otherwise — counted as a miss); `put` parks a
/// buffer for the next round. Internally synchronised, so chunk workers
/// can take/put concurrently; buffers carry their capacity across
/// rounds, which is what makes the steady state allocation-free.
#[derive(Debug)]
pub struct BufferPool<T> {
    free: Mutex<Vec<Vec<T>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    bytes_reused: AtomicU64,
}

impl<T> Default for BufferPool<T> {
    fn default() -> Self {
        BufferPool {
            free: Mutex::new(Vec::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            bytes_reused: AtomicU64::new(0),
        }
    }
}

impl<T: Clone + Default> BufferPool<T> {
    /// A cleared buffer of exactly `len` elements (all `T::default()`).
    pub fn take(&self, len: usize) -> Vec<T> {
        let recycled = self.free.lock().unwrap().pop();
        match recycled {
            Some(mut buf) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                self.bytes_reused.fetch_add(
                    (buf.capacity().min(len) * std::mem::size_of::<T>()) as u64,
                    Ordering::Relaxed,
                );
                buf.clear();
                buf.resize(len, T::default());
                buf
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                vec![T::default(); len]
            }
        }
    }

    /// Park a buffer for reuse (empty-capacity buffers are dropped).
    pub fn put(&self, buf: Vec<T>) {
        if buf.capacity() > 0 {
            self.free.lock().unwrap().push(buf);
        }
    }

    /// Counters since construction (or the last [`drain_stats`](Self::drain_stats)).
    pub fn stats(&self) -> ArenaStats {
        ArenaStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            bytes_reused: self.bytes_reused.load(Ordering::Relaxed),
        }
    }

    /// Read-and-reset the counters (per-tree/round accounting).
    pub fn drain_stats(&self) -> ArenaStats {
        ArenaStats {
            hits: self.hits.swap(0, Ordering::Relaxed),
            misses: self.misses.swap(0, Ordering::Relaxed),
            bytes_reused: self.bytes_reused.swap(0, Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_indexed_preserves_order() {
        let exec = ExecContext::new(4);
        // vary task duration so completion order scrambles
        let out = exec.run_indexed(64, |i| {
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            i * 2
        });
        assert_eq!(out, (0..64).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let items: Vec<u64> = (0..1000).collect();
        let serial = ExecContext::serial().parallel_map(&items, |i, &x| x * x + i as u64);
        for t in [2usize, 3, 8] {
            let par = ExecContext::new(t).parallel_map(&items, |i, &x| x * x + i as u64);
            assert_eq!(par, serial, "threads = {t}");
        }
    }

    #[test]
    fn scoped_and_pooled_engines_agree() {
        let items: Vec<u64> = (0..4096).collect();
        let want = ExecContext::serial().parallel_map(&items, |i, &x| x * 3 + i as u64);
        for t in [2usize, 4, 8] {
            for mode in [ExecMode::Persistent, ExecMode::Scoped] {
                let exec = ExecContext::with_mode(t, mode);
                let got = exec.parallel_map(&items, |i, &x| x * 3 + i as u64);
                assert_eq!(got, want, "threads = {t}, mode = {mode:?}");
            }
        }
    }

    #[test]
    fn pool_lifecycle_stable_across_100_calls() {
        use std::collections::HashSet;
        use std::sync::Mutex as StdMutex;
        let exec = ExecContext::with_mode(4, ExecMode::Persistent);
        assert_eq!(exec.pool_workers(), 0, "lazy: nothing spawned before first call");
        let seen: StdMutex<HashSet<std::thread::ThreadId>> = StdMutex::new(HashSet::new());
        let mut workers_after_first = None;
        for call in 0..100 {
            let out = exec.run_indexed(16, |i| {
                seen.lock().unwrap().insert(std::thread::current().id());
                i * i
            });
            assert_eq!(out, (0..16).map(|i| i * i).collect::<Vec<_>>(), "call {call}");
            let w = exec.pool_workers();
            assert!(w <= 3, "at most threads-1 persistent workers, got {w}");
            match workers_after_first {
                None => workers_after_first = Some(w),
                Some(first) => assert_eq!(w, first, "worker count moved at call {call}"),
            }
        }
        // every thread that ever ran a task is either the caller or one
        // of the persistent workers — no thread was ever re-spawned
        let distinct = seen.lock().unwrap().len();
        assert!(
            distinct <= exec.pool_workers() + 1,
            "{distinct} distinct threads for {} workers + caller",
            exec.pool_workers()
        );
        assert!(exec.wake_wall_secs() >= 0.0);
    }

    #[test]
    fn nested_fork_submissions_complete_on_shared_pool() {
        // devices × chunks on one pool: the outer job's workers submit
        // inner jobs; the submitter-participates rule means this cannot
        // deadlock even with every worker busy
        let exec = ExecContext::with_mode(4, ExecMode::Persistent);
        let dev_exec = exec.fork(2);
        let per_dev: Vec<u64> = exec.run_indexed(2, |d| {
            dev_exec
                .map_chunks(10_000, 512, |_, r| r.map(|x| x as u64).sum::<u64>())
                .into_iter()
                .sum::<u64>()
                + d as u64
        });
        let want: u64 = (0..10_000u64).sum();
        assert_eq!(per_dev, vec![want, want + 1]);
    }

    #[test]
    fn pooled_panic_propagates_to_submitter() {
        let exec = ExecContext::with_mode(4, ExecMode::Persistent);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            exec.run_indexed(8, |i| {
                if i == 5 {
                    panic!("task 5 exploded");
                }
                i
            })
        }));
        assert!(caught.is_err(), "panic must reach the submitter");
        // the pool survives a panicked job: the next call works
        let out = exec.run_indexed(8, |i| i + 1);
        assert_eq!(out, (1..9).collect::<Vec<_>>());
    }

    #[test]
    fn map_chunks_boundaries_are_fixed() {
        let n = 10_000usize;
        let collect = |t: usize| {
            ExecContext::new(t).map_chunks(n, 512, |ci, r| (ci, r.start, r.end))
        };
        let serial = collect(1);
        assert_eq!(serial.len(), n.div_ceil(512));
        assert_eq!(serial[0], (0, 0, 512));
        assert_eq!(serial.last().copied().unwrap(), (19, 19 * 512, n));
        for t in [2usize, 5, 16] {
            assert_eq!(collect(t), serial, "chunk layout must not depend on threads");
        }
    }

    #[test]
    fn parallel_map_mut_gives_exclusive_access() {
        let mut items: Vec<Vec<u32>> = (0..8).map(|i| vec![i]).collect();
        let lens = ExecContext::new(4).parallel_map_mut(&mut items, |i, v| {
            v.push(i as u32 * 10);
            v.len()
        });
        assert_eq!(lens, vec![2; 8]);
        for (i, v) in items.iter().enumerate() {
            assert_eq!(v, &vec![i as u32, i as u32 * 10]);
        }
    }

    #[test]
    fn for_each_slice_mut_covers_every_element() {
        let mut data = vec![0u32; 5000];
        ExecContext::new(4).for_each_slice_mut(&mut data, 700, |_, start, chunk| {
            for (k, v) in chunk.iter_mut().enumerate() {
                *v = (start + k) as u32;
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u32);
        }
    }

    #[test]
    fn fork_splits_budget() {
        let exec = ExecContext::new(8);
        assert_eq!(exec.fork(2).threads(), 4);
        assert_eq!(exec.fork(3).threads(), 2);
        assert_eq!(exec.fork(100).threads(), 1);
        assert_eq!(ExecContext::serial().fork(0).threads(), 1);
    }

    #[test]
    fn zero_resolves_to_available_parallelism() {
        assert!(ExecContext::new(0).threads() >= 1);
        assert_eq!(ExecContext::default().threads(), ExecContext::new(0).threads());
    }

    #[test]
    fn empty_input_is_fine() {
        let exec = ExecContext::new(4);
        let out: Vec<u32> = exec.run_indexed(0, |_| unreachable!());
        assert!(out.is_empty());
        let out: Vec<(usize, usize)> = exec.map_chunks(0, 64, |_, r| (r.start, r.end));
        assert!(out.is_empty());
        let mut nothing: Vec<u8> = Vec::new();
        exec.for_each_slice_mut(&mut nothing, 4, |_, _, _| unreachable!());
    }

    #[test]
    fn run_with_worker_overlaps_producer_and_consumer() {
        // a rendezvous channel deadlocks unless both sides actually run
        // concurrently — which is exactly the prefetch contract
        let (tx, rx) = std::sync::mpsc::sync_channel::<usize>(0);
        let got = ExecContext::new(2).run_with_worker(
            move || {
                for i in 0..16 {
                    if tx.send(i).is_err() {
                        break;
                    }
                }
            },
            || rx.iter().sum::<usize>(),
        );
        assert_eq!(got, (0..16).sum());
    }

    #[test]
    fn deterministic_float_reduction_across_thread_counts() {
        // the exact pattern the histogram builder uses: per-chunk partial
        // sums merged in chunk order must be bit-identical for any T
        let vals: Vec<f64> = (0..50_000)
            .map(|i| ((i as f64) * 0.731).sin() * 1e-3 + 1.0)
            .collect();
        let sum_with = |t: usize| -> f64 {
            ExecContext::new(t)
                .map_chunks(vals.len(), ROW_CHUNK, |_, r| {
                    vals[r].iter().fold(0.0f64, |a, &b| a + b)
                })
                .into_iter()
                .fold(0.0f64, |a, b| a + b)
        };
        let s1 = sum_with(1);
        for t in [2usize, 4, 8] {
            assert_eq!(s1.to_bits(), sum_with(t).to_bits(), "threads = {t}");
        }
    }

    #[test]
    fn buffer_pool_recycles_capacity() {
        let pool: BufferPool<u64> = BufferPool::default();
        let a = pool.take(1000);
        assert_eq!(a.len(), 1000);
        let s = pool.stats();
        assert_eq!((s.hits, s.misses), (0, 1), "first take is a miss");
        pool.put(a);
        let b = pool.take(500);
        assert_eq!(b.len(), 500);
        assert!(b.iter().all(|&x| x == 0), "recycled buffers come back cleared");
        let s = pool.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.bytes_reused, 500 * 8);
        pool.put(b);
        let d = pool.drain_stats();
        assert_eq!(d.hits, 1);
        assert_eq!(pool.stats(), ArenaStats::default(), "drain resets");
    }
}
