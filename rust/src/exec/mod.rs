//! Execution engine: a vendored, dependency-free scoped thread pool with
//! `parallel_map` / `parallel_for` primitives and a **deterministic
//! fixed-chunk reduction order**.
//!
//! # Real threads vs the simulated multi-GPU clock
//!
//! The coordinator models the paper's multi-GPU system two ways at once:
//!
//! * the **simulated clock** (`BuildStats::simulated_secs`) prices each
//!   round as `max_d(compute_d) + comm(round)` under the ring cost model —
//!   the analytic Figure-2 quantity, independent of host hardware;
//! * the **real engine** (this module) actually executes device shards on
//!   OS threads and chunk-parallelises the per-shard hot loops, so
//!   measured wall-clock (`BuildStats::hist_wall_secs` /
//!   `partition_wall_secs`) genuinely improves with
//!   [`ExecContext::threads`].
//!
//! # Determinism contract
//!
//! Floating-point addition is not associative, so naive work-stealing
//! reductions produce thread-count-dependent results. Every reduction in
//! this crate therefore follows one rule: **work is split into fixed-size
//! chunks whose boundaries depend only on the input size, and partial
//! results are merged in ascending chunk index** — never in completion
//! order. Workers may *compute* chunks in any order (claims go through an
//! atomic counter for load balance) but the merge is a fixed left-to-right
//! fold, so `threads = 1` and `threads = 64` produce bit-identical
//! histograms, trees, predictions and metrics. The regression test
//! `rust/tests/parallel_exec.rs` pins this contract.
//!
//! The pool is scoped (`std::thread::scope`): workers borrow the caller's
//! stack data directly, no `'static` bounds, no channels, and a panicking
//! worker propagates at the join as usual. Threads are spawned per call;
//! for the millisecond-scale phases this engine serves, spawn cost is
//! noise, and small inputs skip spawning entirely via the serial fast
//! path.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Default rows-per-chunk for row-wise phases (histograms, partitioning,
/// gradients, prediction). Chunk boundaries are a pure function of the
/// input length — **never** of the thread count — which is what keeps the
/// reduction order fixed (see module docs).
pub const ROW_CHUNK: usize = 8192;

/// Rows per traversal block inside a `ROW_CHUNK`: a block of rows walks
/// one tree (level-synchronously) before the next tree runs, keeping the
/// tree's hot top levels in cache across the block. Interchanging *which
/// row traverses next* never reorders any single row's `+=` chain, so
/// blocked traversal is bit-identical to row-at-a-time (proved out by
/// `serve/flat.rs::predict_margins`, now shared by the quantised
/// prediction kernels in `predict/quantised.rs`).
pub const BLOCK_ROWS: usize = 64;

/// Rows per histogram micro-block: gradients are pre-converted to f64
/// and packed symbols block-decoded `HIST_BLOCK_ROWS` rows at a time
/// before the accumulation loop runs. Strictly smaller than `ROW_CHUNK`
/// and always applied *inside* one chunk, so the f64 accumulation order
/// is untouched (see `hist/mod.rs` module docs).
pub const HIST_BLOCK_ROWS: usize = 8;

/// Which inner-loop implementation the hot kernels run: the blocked,
/// branchless kernels (default) or the original scalar loops kept as the
/// bit-parity reference. Selected once per process from the
/// `XGB_SCALAR_KERNELS` env var (`1`/any non-empty value other than `0`
/// selects `Scalar`); benches and the property tests bypass the env and
/// pass a mode explicitly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// Block-decoded, branchless kernels (`hist`/`predict` hot loops).
    Blocked,
    /// The original row-at-a-time scalar loops — the reference the
    /// blocked kernels are pinned bit-identical to.
    Scalar,
}

impl KernelMode {
    /// The process-wide mode (env read once, then cached).
    pub fn from_env() -> KernelMode {
        static SCALAR: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
        let scalar = *SCALAR.get_or_init(|| {
            std::env::var("XGB_SCALAR_KERNELS")
                .map(|v| !v.is_empty() && v != "0")
                .unwrap_or(false)
        });
        if scalar {
            KernelMode::Scalar
        } else {
            KernelMode::Blocked
        }
    }
}

/// A thread budget for the parallel primitives. Cheap to clone/copy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecContext {
    threads: usize,
}

impl Default for ExecContext {
    /// Defaults to all available cores (same as `ExecContext::new(0)`).
    fn default() -> Self {
        ExecContext::new(0)
    }
}

impl ExecContext {
    /// `threads = 0` resolves to the machine's available parallelism;
    /// `threads = 1` is the serial engine (no threads are ever spawned).
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        ExecContext { threads }
    }

    /// The serial engine: every primitive runs inline on the caller.
    pub fn serial() -> Self {
        ExecContext { threads: 1 }
    }

    /// Resolved worker count (>= 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Split this budget across `ways` concurrent consumers (e.g. give
    /// each of `p` device shards `threads / p` workers for its own
    /// chunk-level parallelism). Never returns a zero budget.
    pub fn fork(&self, ways: usize) -> ExecContext {
        ExecContext {
            threads: (self.threads / ways.max(1)).max(1),
        }
    }

    /// Core primitive: run `f(0), f(1), …, f(n_tasks - 1)` and return the
    /// results **in task-index order**, regardless of which worker ran
    /// which task. Tasks are claimed from an atomic counter so long tasks
    /// don't serialise behind short ones.
    pub fn run_indexed<R, F>(&self, n_tasks: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        if self.threads <= 1 || n_tasks <= 1 {
            return (0..n_tasks).map(f).collect();
        }
        let n_workers = self.threads.min(n_tasks);
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<R>>> = (0..n_tasks).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..n_workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n_tasks {
                        break;
                    }
                    let r = f(i);
                    *slots[i].lock().unwrap() = Some(r);
                });
            }
        });
        slots
            .into_iter()
            .map(|s| s.into_inner().unwrap().expect("worker filled every slot"))
            .collect()
    }

    /// Parallel map over a shared slice; results in item order.
    pub fn parallel_map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        self.run_indexed(items.len(), |i| f(i, &items[i]))
    }

    /// Parallel map with exclusive access to each item (one task per
    /// item — the device-shard shape); results in item order.
    pub fn parallel_map_mut<T, R, F>(&self, items: &mut [T], f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> R + Sync,
    {
        if self.threads <= 1 || items.len() <= 1 {
            return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        // Hand each worker a distinct &mut T through a per-item Mutex;
        // indices are claimed exactly once so each lock is uncontended.
        let cells: Vec<Mutex<&mut T>> = items.iter_mut().map(Mutex::new).collect();
        self.run_indexed(cells.len(), |i| {
            let mut guard = cells[i].lock().unwrap();
            f(i, &mut **guard)
        })
    }

    /// Map over fixed chunks of `0..n` (chunk boundaries depend only on
    /// `n` and `chunk`); results in ascending chunk-index order. This is
    /// the primitive behind every deterministic reduction in the crate.
    pub fn map_chunks<R, F>(&self, n: usize, chunk: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, Range<usize>) -> R + Sync,
    {
        let chunk = chunk.max(1);
        let n_chunks = n.div_ceil(chunk);
        self.run_indexed(n_chunks, |ci| {
            let lo = ci * chunk;
            let hi = (lo + chunk).min(n);
            f(ci, lo..hi)
        })
    }

    /// Parallel for over fixed chunks of `0..n`, no results collected.
    pub fn for_each_chunk<F>(&self, n: usize, chunk: usize, f: F)
    where
        F: Fn(usize, Range<usize>) + Sync,
    {
        self.map_chunks(n, chunk, |ci, r| f(ci, r));
    }

    /// Run `main` on the caller while `worker` runs concurrently on a
    /// scoped thread spawned for this call — the producer/consumer shape
    /// of the paged histogram build (the worker prefetches the next page
    /// from disk while the caller accumulates the current one). The
    /// worker thread is **in addition to** the configured `threads()`
    /// budget (it spends its life blocked on I/O or a channel, not
    /// computing, so it is not counted against the compute budget) and
    /// always runs; callers that want a serial fallback (e.g.
    /// `threads() <= 1`) should skip this call and inline both sides. A
    /// panicking worker propagates at the scope join as usual.
    pub fn run_with_worker<R, W, F>(&self, worker: W, main: F) -> R
    where
        R: Send,
        W: FnOnce() + Send,
        F: FnOnce() -> R + Send,
    {
        std::thread::scope(|scope| {
            scope.spawn(worker);
            main()
        })
    }

    /// Parallel for over disjoint mutable chunks of a slice. `f` receives
    /// `(chunk_index, start_offset, chunk)`; chunks are the usual fixed
    /// partition of the slice so writes are trivially race-free.
    pub fn for_each_slice_mut<T, F>(&self, data: &mut [T], chunk: usize, f: F)
    where
        T: Send,
        F: Fn(usize, usize, &mut [T]) + Sync,
    {
        let chunk = chunk.max(1);
        if self.threads <= 1 || data.len() <= chunk {
            // same chunk layout as the parallel path, run inline
            for (ci, c) in data.chunks_mut(chunk).enumerate() {
                f(ci, ci * chunk, c);
            }
            return;
        }
        let cells: Vec<Mutex<(usize, &mut [T])>> = data
            .chunks_mut(chunk)
            .enumerate()
            .map(|(ci, c)| Mutex::new((ci * chunk, c)))
            .collect();
        self.run_indexed(cells.len(), |i| {
            let mut guard = cells[i].lock().unwrap();
            let start = guard.0;
            f(i, start, &mut *guard.1);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_indexed_preserves_order() {
        let exec = ExecContext::new(4);
        // vary task duration so completion order scrambles
        let out = exec.run_indexed(64, |i| {
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            i * 2
        });
        assert_eq!(out, (0..64).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let items: Vec<u64> = (0..1000).collect();
        let serial = ExecContext::serial().parallel_map(&items, |i, &x| x * x + i as u64);
        for t in [2usize, 3, 8] {
            let par = ExecContext::new(t).parallel_map(&items, |i, &x| x * x + i as u64);
            assert_eq!(par, serial, "threads = {t}");
        }
    }

    #[test]
    fn map_chunks_boundaries_are_fixed() {
        let n = 10_000usize;
        let collect = |t: usize| {
            ExecContext::new(t).map_chunks(n, 512, |ci, r| (ci, r.start, r.end))
        };
        let serial = collect(1);
        assert_eq!(serial.len(), n.div_ceil(512));
        assert_eq!(serial[0], (0, 0, 512));
        assert_eq!(serial.last().copied().unwrap(), (19, 19 * 512, n));
        for t in [2usize, 5, 16] {
            assert_eq!(collect(t), serial, "chunk layout must not depend on threads");
        }
    }

    #[test]
    fn parallel_map_mut_gives_exclusive_access() {
        let mut items: Vec<Vec<u32>> = (0..8).map(|i| vec![i]).collect();
        let lens = ExecContext::new(4).parallel_map_mut(&mut items, |i, v| {
            v.push(i as u32 * 10);
            v.len()
        });
        assert_eq!(lens, vec![2; 8]);
        for (i, v) in items.iter().enumerate() {
            assert_eq!(v, &vec![i as u32, i as u32 * 10]);
        }
    }

    #[test]
    fn for_each_slice_mut_covers_every_element() {
        let mut data = vec![0u32; 5000];
        ExecContext::new(4).for_each_slice_mut(&mut data, 700, |_, start, chunk| {
            for (k, v) in chunk.iter_mut().enumerate() {
                *v = (start + k) as u32;
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u32);
        }
    }

    #[test]
    fn fork_splits_budget() {
        let exec = ExecContext::new(8);
        assert_eq!(exec.fork(2).threads(), 4);
        assert_eq!(exec.fork(3).threads(), 2);
        assert_eq!(exec.fork(100).threads(), 1);
        assert_eq!(ExecContext::serial().fork(0).threads(), 1);
    }

    #[test]
    fn zero_resolves_to_available_parallelism() {
        assert!(ExecContext::new(0).threads() >= 1);
        assert_eq!(ExecContext::default().threads(), ExecContext::new(0).threads());
    }

    #[test]
    fn empty_input_is_fine() {
        let exec = ExecContext::new(4);
        let out: Vec<u32> = exec.run_indexed(0, |_| unreachable!());
        assert!(out.is_empty());
        let out: Vec<(usize, usize)> = exec.map_chunks(0, 64, |_, r| (r.start, r.end));
        assert!(out.is_empty());
        let mut nothing: Vec<u8> = Vec::new();
        exec.for_each_slice_mut(&mut nothing, 4, |_, _, _| unreachable!());
    }

    #[test]
    fn run_with_worker_overlaps_producer_and_consumer() {
        // a rendezvous channel deadlocks unless both sides actually run
        // concurrently — which is exactly the prefetch contract
        let (tx, rx) = std::sync::mpsc::sync_channel::<usize>(0);
        let got = ExecContext::new(2).run_with_worker(
            move || {
                for i in 0..16 {
                    if tx.send(i).is_err() {
                        break;
                    }
                }
            },
            || rx.iter().sum::<usize>(),
        );
        assert_eq!(got, (0..16).sum());
    }

    #[test]
    fn deterministic_float_reduction_across_thread_counts() {
        // the exact pattern the histogram builder uses: per-chunk partial
        // sums merged in chunk order must be bit-identical for any T
        let vals: Vec<f64> = (0..50_000)
            .map(|i| ((i as f64) * 0.731).sin() * 1e-3 + 1.0)
            .collect();
        let sum_with = |t: usize| -> f64 {
            ExecContext::new(t)
                .map_chunks(vals.len(), ROW_CHUNK, |_, r| {
                    vals[r].iter().fold(0.0f64, |a, &b| a + b)
                })
                .into_iter()
                .fold(0.0f64, |a, b| a + b)
        };
        let s1 = sum_with(1);
        for t in [2usize, 4, 8] {
            assert_eq!(s1.to_bits(), sum_with(t).to_bits(), "threads = {t}");
        }
    }
}
