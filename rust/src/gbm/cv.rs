//! K-fold cross-validation driver — the standard companion utility for
//! hyperparameter selection (`xgboost.cv` analogue).

use anyhow::{ensure, Result};

use crate::data::Dataset;
use crate::gbm::{Learner, LearnerParams};
use crate::util::Pcg64;

/// Per-fold and aggregate cross-validation results.
#[derive(Debug, Clone)]
pub struct CvResult {
    pub metric: &'static str,
    /// Final validation metric of each fold.
    pub fold_scores: Vec<f64>,
    pub mean: f64,
    pub std: f64,
}

/// Run `k`-fold cross-validation of `params` on `data`.
///
/// Folds are deterministic in `seed`. Returns the per-fold final
/// validation scores of the objective's default (or configured) metric.
pub fn cross_validate(
    params: &LearnerParams,
    data: &Dataset,
    k: usize,
    seed: u64,
) -> Result<CvResult> {
    ensure!(k >= 2, "need at least 2 folds");
    let n = data.n_rows();
    ensure!(n >= k, "fewer rows than folds");
    // validate once up front rather than once per fold
    let mut learner = Learner::from_params(params.clone())?;
    let mut idx: Vec<usize> = (0..n).collect();
    Pcg64::new(seed).shuffle(&mut idx);

    let mut fold_scores = Vec::with_capacity(k);
    let mut metric_name = "";
    for fold in 0..k {
        let lo = fold * n / k;
        let hi = (fold + 1) * n / k;
        let valid_rows = &idx[lo..hi];
        let train_rows: Vec<usize> = idx[..lo].iter().chain(&idx[hi..]).copied().collect();
        let take = |rows: &[usize]| {
            Dataset::new(
                data.x.take_rows(rows),
                rows.iter().map(|&r| data.y[r]).collect(),
            )
        };
        let train = take(&train_rows);
        let valid = take(valid_rows);
        let booster = learner.train(&train, Some(&valid))?;
        let rec = booster
            .eval_history
            .last()
            .ok_or_else(|| anyhow::anyhow!("no evaluation recorded"))?;
        metric_name = rec.metric;
        fold_scores.push(rec.valid.unwrap_or(f64::NAN));
    }
    let mean = fold_scores.iter().sum::<f64>() / k as f64;
    let var = fold_scores.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / k as f64;
    Ok(CvResult {
        metric: metric_name,
        fold_scores,
        mean,
        std: var.sqrt(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetSpec};

    fn params() -> LearnerParams {
        LearnerParams {
            objective: crate::gbm::ObjectiveKind::BinaryLogistic,
            num_rounds: 8,
            max_depth: 4,
            max_bins: 16,
            eval_metric: Some(crate::gbm::MetricKind::Accuracy),
            ..Default::default()
        }
    }

    #[test]
    fn cv_runs_all_folds_and_aggregates() {
        let g = generate(&DatasetSpec::higgs_like(2500), 61);
        let r = cross_validate(&params(), &g.train, 4, 7).unwrap();
        assert_eq!(r.fold_scores.len(), 4);
        assert_eq!(r.metric, "accuracy");
        assert!(r.fold_scores.iter().all(|s| *s > 55.0), "{:?}", r.fold_scores);
        assert!((r.mean - r.fold_scores.iter().sum::<f64>() / 4.0).abs() < 1e-12);
        assert!(r.std >= 0.0);
    }

    #[test]
    fn cv_is_deterministic_in_seed() {
        let g = generate(&DatasetSpec::higgs_like(1200), 63);
        let a = cross_validate(&params(), &g.train, 3, 1).unwrap();
        let b = cross_validate(&params(), &g.train, 3, 1).unwrap();
        assert_eq!(a.fold_scores, b.fold_scores);
        let c = cross_validate(&params(), &g.train, 3, 2).unwrap();
        assert_ne!(a.fold_scores, c.fold_scores);
    }

    #[test]
    fn cv_rejects_bad_k() {
        let g = generate(&DatasetSpec::higgs_like(300), 65);
        assert!(cross_validate(&params(), &g.train, 1, 0).is_err());
    }
}
