//! Model persistence: a line-oriented text dump of a trained booster
//! (analogous to XGBoost's text model format) and its loader, so trained
//! models survive process restarts and can be served by a separate
//! process.
//!
//! Format (`xgb-tpu-model v1`):
//!
//! ```text
//! xgb-tpu-model v1
//! objective = binary:logistic
//! num_class = 1
//! eta = 0.3
//! quantile_alpha = 0.9          (optional objective-shaping lines,
//! tweedie_variance_power = 1.5   written only for the objectives that
//! aft_distribution = normal      use them; absent in legacy files)
//! aft_sigma = 1
//! base_score = 0.5 [0.5 ...]
//! groups = <k>
//! group 0 trees = <t>
//! tree 0 0 nodes = <n>
//! <nid> split <feature> <threshold> <left> <right> <default L|R> <gain> <cover>
//! <nid> cat <feature> <c0,c1,...> <left> <right> <default L|R> <gain> <cover>
//! <nid> leaf <value> <cover>
//! ...
//! cuts features = <f>          (optional trailing section)
//! cuts ptrs = <p0> <p1> ...
//! cuts values = <v0> <v1> ...
//! cuts minvals = <m0> <m1> ...
//! cuts categorical = <f3> <f7> (optional, only when any feature is
//!                               categorical)
//! ```
//!
//! The trailing `cuts` section persists the frozen quantisation cuts the
//! model was trained against, so a reloaded model can predict straight
//! from the compressed representation (CLI `predict --stream` /
//! `--max-resident-pages`). It is optional: files written before it
//! existed load fine (with `Booster::cuts = None`, float prediction
//! only). Float values round-trip exactly — Rust's shortest `Display`
//! form re-parses to the identical bits.
//!
//! A `cat` node is a categorical **membership** split: the
//! comma-separated integer codes are the categories routed *left*
//! (`Node::cats` bitset, value domain); everything else — including
//! missing values when the default is `R` — routes right. The
//! objective-shaping lines make reload → [`crate::gbm::Learner::resume`]
//! reconstruct the exact training objective (a reloaded `reg:quantile`
//! model evaluates `pinball` at its trained α, not the default).

use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::gbm::params::{LearnerParams, ObjectiveKind};
use crate::gbm::Booster;
use crate::tree::regtree::{Node, NO_CHILD};
use crate::tree::RegTree;
use crate::Float;

/// Serialise a booster to the v1 text format.
pub fn save_model(booster: &Booster, mut w: impl Write) -> Result<()> {
    writeln!(w, "xgb-tpu-model v1")?;
    writeln!(w, "objective = {}", booster.params.objective)?;
    writeln!(w, "num_class = {}", booster.params.num_class)?;
    writeln!(w, "eta = {}", booster.params.eta)?;
    match booster.params.objective {
        ObjectiveKind::QuantileReg => {
            writeln!(w, "quantile_alpha = {}", booster.params.quantile_alpha)?;
        }
        ObjectiveKind::Tweedie => {
            writeln!(
                w,
                "tweedie_variance_power = {}",
                booster.params.tweedie_variance_power
            )?;
        }
        ObjectiveKind::SurvivalAft => {
            writeln!(w, "aft_distribution = {}", booster.params.aft_distribution)?;
            writeln!(w, "aft_sigma = {}", booster.params.aft_sigma)?;
        }
        _ => {}
    }
    let base: Vec<String> = booster.base_score.iter().map(|b| format!("{b}")).collect();
    writeln!(w, "base_score = {}", base.join(" "))?;
    writeln!(w, "groups = {}", booster.trees.len())?;
    for (g, group) in booster.trees.iter().enumerate() {
        writeln!(w, "group {g} trees = {}", group.len())?;
        for (t, tree) in group.iter().enumerate() {
            writeln!(w, "tree {g} {t} nodes = {}", tree.n_nodes())?;
            for (nid, n) in tree.nodes.iter().enumerate() {
                if n.is_leaf() {
                    writeln!(w, "{nid} leaf {} {}", n.leaf_value, n.cover)?;
                } else if n.cats != 0 {
                    let cats: Vec<String> = (0..64u32)
                        .filter(|c| (n.cats >> c) & 1 == 1)
                        .map(|c| c.to_string())
                        .collect();
                    writeln!(
                        w,
                        "{nid} cat {} {} {} {} {} {} {}",
                        n.feature,
                        cats.join(","),
                        n.left,
                        n.right,
                        if n.default_left { "L" } else { "R" },
                        n.gain,
                        n.cover
                    )?;
                } else {
                    writeln!(
                        w,
                        "{nid} split {} {} {} {} {} {} {}",
                        n.feature,
                        n.threshold,
                        n.left,
                        n.right,
                        if n.default_left { "L" } else { "R" },
                        n.gain,
                        n.cover
                    )?;
                }
            }
        }
    }
    if let Some(cuts) = &booster.cuts {
        writeln!(w, "cuts features = {}", cuts.n_features())?;
        let ptrs: Vec<String> = cuts.ptrs.iter().map(|p| format!("{p}")).collect();
        writeln!(w, "cuts ptrs = {}", ptrs.join(" "))?;
        let values: Vec<String> = cuts.values.iter().map(|v| format!("{v}")).collect();
        writeln!(w, "cuts values = {}", values.join(" "))?;
        let mins: Vec<String> = cuts.min_vals.iter().map(|v| format!("{v}")).collect();
        writeln!(w, "cuts minvals = {}", mins.join(" "))?;
        if cuts.has_categorical() {
            let flags: Vec<String> = cuts
                .categorical
                .iter()
                .enumerate()
                .filter(|(_, &c)| c)
                .map(|(f, _)| f.to_string())
                .collect();
            writeln!(w, "cuts categorical = {}", flags.join(" "))?;
        }
    }
    Ok(())
}

/// Save to a file path.
pub fn save_model_file(booster: &Booster, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    save_model(booster, std::io::BufWriter::new(f))
}

/// Next non-empty line, or `None` at end of input (the trailing `cuts`
/// section is optional, so EOF is only an error where a line is
/// required).
fn next_nonempty<B: std::io::BufRead>(lines: &mut std::io::Lines<B>) -> Result<Option<String>> {
    for l in lines.by_ref() {
        let l = l?;
        if !l.trim().is_empty() {
            return Ok(Some(l));
        }
    }
    Ok(None)
}

/// Load a booster from the v1 text format.
pub fn load_model(r: impl Read) -> Result<Booster> {
    let mut lines = BufReader::new(r).lines();
    let mut next = || -> Result<String> {
        match next_nonempty(&mut lines)? {
            Some(l) => Ok(l),
            None => bail!("unexpected end of model file"),
        }
    };

    let header = next()?;
    ensure!(header.trim() == "xgb-tpu-model v1", "bad header {header:?}");
    let kv = |line: &str, key: &str| -> Result<String> {
        let (k, v) = line
            .split_once('=')
            .with_context(|| format!("expected `{key} = ...`, got {line:?}"))?;
        ensure!(k.trim() == key, "expected key {key}, got {k:?}");
        Ok(v.trim().to_string())
    };

    let objective = kv(&next()?, "objective")?;
    let num_class: usize = kv(&next()?, "num_class")?.parse()?;
    let eta: f64 = kv(&next()?, "eta")?.parse()?;
    // optional objective-shaping lines (only the objectives that use them
    // write them; legacy files jump straight to base_score)
    let mut quantile_alpha: Option<f64> = None;
    let mut tweedie_variance_power: Option<f64> = None;
    let mut aft_distribution: Option<crate::gbm::params::AftDistribution> = None;
    let mut aft_sigma: Option<f64> = None;
    let base_line = loop {
        let line = next()?;
        let key = line.split('=').next().unwrap_or("").trim().to_string();
        match key.as_str() {
            "quantile_alpha" => quantile_alpha = Some(kv(&line, "quantile_alpha")?.parse()?),
            "tweedie_variance_power" => {
                tweedie_variance_power = Some(kv(&line, "tweedie_variance_power")?.parse()?)
            }
            "aft_distribution" => {
                aft_distribution = Some(
                    kv(&line, "aft_distribution")?
                        .parse()
                        .map_err(|e: String| anyhow::anyhow!(e))?,
                )
            }
            "aft_sigma" => aft_sigma = Some(kv(&line, "aft_sigma")?.parse()?),
            _ => break line,
        }
    };
    let base_score: Vec<Float> = kv(&base_line, "base_score")?
        .split_whitespace()
        .map(|t| t.parse::<Float>().context("base_score"))
        .collect::<Result<_>>()?;
    let n_groups: usize = kv(&next()?, "groups")?.parse()?;

    let mut trees: Vec<Vec<RegTree>> = Vec::with_capacity(n_groups);
    for g in 0..n_groups {
        let head = next()?;
        let expected = format!("group {g} trees");
        let n_trees: usize = kv(&head, &expected)?.parse()?;
        let mut group = Vec::with_capacity(n_trees);
        for t in 0..n_trees {
            let head = next()?;
            let expected = format!("tree {g} {t} nodes");
            let n_nodes: usize = kv(&head, &expected)?.parse()?;
            ensure!(n_nodes >= 1, "empty tree");
            let mut nodes = Vec::with_capacity(n_nodes);
            for want_nid in 0..n_nodes {
                let line = next()?;
                let toks: Vec<&str> = line.split_whitespace().collect();
                ensure!(toks.len() >= 2, "bad node line {line:?}");
                let nid: usize = toks[0].parse()?;
                ensure!(nid == want_nid, "node ids must be dense, got {nid}");
                match toks[1] {
                    "leaf" => {
                        ensure!(toks.len() == 4, "bad leaf line {line:?}");
                        let mut n = Node::leaf(toks[2].parse()?, toks[3].parse()?);
                        n.left = NO_CHILD;
                        nodes.push(n);
                    }
                    "split" => {
                        ensure!(toks.len() == 9, "bad split line {line:?}");
                        nodes.push(Node {
                            feature: toks[2].parse()?,
                            threshold: toks[3].parse()?,
                            left: toks[4].parse()?,
                            right: toks[5].parse()?,
                            default_left: match toks[6] {
                                "L" => true,
                                "R" => false,
                                other => bail!("bad default {other:?}"),
                            },
                            leaf_value: 0.0,
                            gain: toks[7].parse()?,
                            cover: toks[8].parse()?,
                            cats: 0,
                        });
                    }
                    "cat" => {
                        ensure!(toks.len() == 9, "bad cat line {line:?}");
                        let mut cats: u64 = 0;
                        for t in toks[3].split(',') {
                            let c: u32 = t
                                .parse()
                                .with_context(|| format!("category code {t:?}"))?;
                            ensure!(c < 64, "category code {c} out of range [0, 64)");
                            cats |= 1u64 << c;
                        }
                        ensure!(cats != 0, "empty category set in {line:?}");
                        nodes.push(Node {
                            feature: toks[2].parse()?,
                            // membership split: routing is the cats bitset
                            threshold: 0.0,
                            left: toks[4].parse()?,
                            right: toks[5].parse()?,
                            default_left: match toks[6] {
                                "L" => true,
                                "R" => false,
                                other => bail!("bad default {other:?}"),
                            },
                            leaf_value: 0.0,
                            gain: toks[7].parse()?,
                            cover: toks[8].parse()?,
                            cats,
                        });
                    }
                    other => bail!("unknown node kind {other:?}"),
                }
            }
            // structural validation: children in range, no cycles by
            // construction (children ids > parent is not guaranteed by the
            // format, so check reachability instead)
            let tree = RegTree { nodes };
            validate_tree(&tree)?;
            group.push(tree);
        }
        trees.push(group);
    }

    // optional trailing section: the frozen quantisation cuts (absent in
    // files written before compressed prediction existed)
    let cuts = match next_nonempty(&mut lines)? {
        None => None,
        Some(head) => {
            let n_features: usize = kv(&head, "cuts features")?.parse()?;
            let ptrs: Vec<u32> = kv(
                &next_nonempty(&mut lines)?.context("cuts ptrs line missing")?,
                "cuts ptrs",
            )?
            .split_whitespace()
            .map(|t| t.parse::<u32>().context("cuts ptrs"))
            .collect::<Result<_>>()?;
            ensure!(ptrs.len() == n_features + 1, "cuts ptrs length");
            ensure!(ptrs[0] == 0, "cuts ptrs must start at 0");
            // strictly: every feature carries at least one cut (even a
            // never-observed feature gets its sentinel), and an empty
            // range would make bin_index a silent no-op at predict time
            ensure!(
                ptrs.windows(2).all(|w| w[0] < w[1]),
                "cuts ptrs must strictly ascend (every feature has >= 1 cut)"
            );
            let values: Vec<Float> = kv(
                &next_nonempty(&mut lines)?.context("cuts values line missing")?,
                "cuts values",
            )?
            .split_whitespace()
            .map(|t| t.parse::<Float>().context("cuts values"))
            .collect::<Result<_>>()?;
            ensure!(
                values.len() == *ptrs.last().unwrap() as usize,
                "cuts values length {} != total bins {}",
                values.len(),
                ptrs.last().unwrap()
            );
            let min_vals: Vec<Float> = kv(
                &next_nonempty(&mut lines)?.context("cuts minvals line missing")?,
                "cuts minvals",
            )?
            .split_whitespace()
            .map(|t| t.parse::<Float>().context("cuts minvals"))
            .collect::<Result<_>>()?;
            ensure!(min_vals.len() == n_features, "cuts minvals length");
            // fail-fast like the rest of the format (page checksums,
            // dense node ids): unsorted cuts would make partition_point
            // — and so every quantised prediction — silently wrong
            for f in 0..n_features {
                let fc = &values[ptrs[f] as usize..ptrs[f + 1] as usize];
                ensure!(
                    fc.windows(2).all(|w| w[0] < w[1]),
                    "cuts values must strictly ascend within feature {f}"
                );
            }
            // optional: which features hold one-category-per-bin cuts
            let mut categorical = vec![false; n_features];
            if let Some(line) = next_nonempty(&mut lines)? {
                for t in kv(&line, "cuts categorical")?.split_whitespace() {
                    let f: usize = t.parse().context("cuts categorical")?;
                    ensure!(f < n_features, "categorical feature {f} out of range");
                    categorical[f] = true;
                }
            }
            Some(crate::quantile::HistogramCuts {
                ptrs,
                values,
                min_vals,
                categorical,
            })
        }
    };
    if let Some(c) = &cuts {
        // every split feature must exist in the cut grid, or the first
        // quantised prediction would panic instead of erroring at load
        for group in &trees {
            for tree in group {
                for node in &tree.nodes {
                    if !node.is_leaf() {
                        ensure!(
                            (node.feature as usize) < c.n_features(),
                            "tree splits on feature {} but cuts cover {}",
                            node.feature,
                            c.n_features()
                        );
                        // a membership split on a feature whose cuts are
                        // NOT one-category-per-bin would route nonsense
                        // through the bin-space traversal
                        ensure!(
                            node.cats == 0 || c.is_categorical(node.feature as usize),
                            "membership split on non-categorical feature {}",
                            node.feature
                        );
                    }
                }
            }
        }
    }

    // typed round-trip: the stored name parses back into ObjectiveKind
    // (user-registered names resolve through the ObjectiveRegistry when
    // the booster is assembled below); persisted shaping params feed the
    // reconstructed objective so resume/eval behave as at training time
    let objective: ObjectiveKind = objective.parse().expect("infallible");
    let d = LearnerParams::default();
    let params = LearnerParams {
        objective,
        num_class,
        eta,
        num_rounds: trees.first().map(|t| t.len()).unwrap_or(0),
        quantile_alpha: quantile_alpha.unwrap_or(d.quantile_alpha),
        tweedie_variance_power: tweedie_variance_power.unwrap_or(d.tweedie_variance_power),
        aft_distribution: aft_distribution.unwrap_or(d.aft_distribution),
        aft_sigma: aft_sigma.unwrap_or(d.aft_sigma),
        ..d
    };
    let mut booster = Booster::from_parts(params, base_score, trees, 0.0)?;
    booster.cuts = cuts;
    Ok(booster)
}

/// Load from a file path.
pub fn load_model_file(path: impl AsRef<Path>) -> Result<Booster> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    load_model(f)
}

/// Load a model for serving: [`load_model_file`] plus the fail-fast
/// cuts check ([`Booster::require_cuts`]). The registry (`crate::serve`)
/// loads exclusively through this, so a legacy `cuts: None` file is
/// rejected at load/hot-swap time with the actionable retrain/re-save
/// message — never mid-request.
pub fn load_servable_model_file(path: impl AsRef<Path>) -> Result<Booster> {
    let path = path.as_ref();
    let booster = load_model_file(path)?;
    booster
        .require_cuts()
        .with_context(|| format!("model {} is not servable", path.display()))?;
    Ok(booster)
}

fn validate_tree(tree: &RegTree) -> Result<()> {
    let n = tree.n_nodes();
    let mut seen = vec![false; n];
    let mut stack = vec![0usize];
    while let Some(nid) = stack.pop() {
        ensure!(nid < n, "child id {nid} out of range");
        ensure!(!seen[nid], "node {nid} reachable twice (cycle or DAG)");
        seen[nid] = true;
        let node = &tree.nodes[nid];
        if !node.is_leaf() {
            ensure!(node.right != NO_CHILD, "half-split node {nid}");
            stack.push(node.left as usize);
            stack.push(node.right as usize);
        }
    }
    ensure!(seen.iter().all(|&s| s), "unreachable nodes in tree");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetSpec};

    fn trained(objective: &str, num_class: usize) -> (Booster, crate::data::Dataset) {
        let spec = if num_class > 1 {
            DatasetSpec::covtype_like(1500)
        } else {
            DatasetSpec::higgs_like(1500)
        };
        let g = generate(&spec, 51);
        let params = LearnerParams {
            objective: objective.parse().expect("infallible"),
            num_class,
            num_rounds: 4,
            max_depth: 4,
            max_bins: 16,
            eval_every: 0,
            ..Default::default()
        };
        let booster = crate::gbm::Learner::from_params(params)
            .unwrap()
            .train(&g.train, None)
            .unwrap();
        (booster, g.valid)
    }

    #[test]
    fn roundtrip_binary() {
        let (b, valid) = trained("binary:logistic", 1);
        let mut buf = Vec::new();
        save_model(&b, &mut buf).unwrap();
        let loaded = load_model(buf.as_slice()).unwrap();
        assert_eq!(loaded.trees, b.trees);
        assert_eq!(loaded.base_score, b.base_score);
        assert_eq!(loaded.predict(&valid.x), b.predict(&valid.x));
    }

    #[test]
    fn roundtrip_multiclass() {
        let (b, valid) = trained("multi:softmax", 7);
        let mut buf = Vec::new();
        save_model(&b, &mut buf).unwrap();
        let loaded = load_model(buf.as_slice()).unwrap();
        assert_eq!(loaded.trees.len(), 7);
        assert_eq!(loaded.predict(&valid.x), b.predict(&valid.x));
    }

    #[test]
    fn typed_params_survive_round_trip() {
        let (b, _) = trained("binary:logistic", 1);
        let mut buf = Vec::new();
        save_model(&b, &mut buf).unwrap();
        let loaded = load_model(buf.as_slice()).unwrap();
        assert_eq!(loaded.params.objective, ObjectiveKind::BinaryLogistic);
        assert_eq!(loaded.params.num_class, 1);
        assert_eq!(loaded.params.eta, b.params.eta);
    }

    #[test]
    fn file_roundtrip() {
        let (b, _) = trained("reg:squarederror", 1);
        let path = std::env::temp_dir().join("xgb_tpu_model_test.txt");
        save_model_file(&b, &path).unwrap();
        let loaded = load_model_file(&path).unwrap();
        assert_eq!(loaded.trees, b.trees);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cuts_round_trip_and_enable_stream_prediction() {
        let (b, valid) = trained("binary:logistic", 1);
        assert!(b.cuts.is_some(), "Learner-trained models carry cuts");
        let mut buf = Vec::new();
        save_model(&b, &mut buf).unwrap();
        let loaded = load_model(buf.as_slice()).unwrap();
        assert_eq!(loaded.cuts, b.cuts, "cuts must round-trip bit-exactly");
        // the reloaded model predicts from the compressed path,
        // bit-identical to its float path
        let float = loaded.predict(&valid.x);
        let mut src = crate::data::source::DMatrixSource::from_dataset(&valid, 37);
        let streamed = loaded.predict_from_source(&mut src).unwrap();
        assert_eq!(float, streamed);
    }

    #[test]
    fn model_without_cuts_section_still_loads() {
        let ok = "xgb-tpu-model v1\nobjective = reg:squarederror\nnum_class = 1\n\
                  eta = 0.3\nbase_score = 0\ngroups = 1\ngroup 0 trees = 1\n\
                  tree 0 0 nodes = 1\n0 leaf 0.5 1\n";
        let b = load_model(ok.as_bytes()).unwrap();
        assert!(b.cuts.is_none());
        // compressed prediction is unavailable, with a useful error
        let ds = crate::data::Dataset::new(
            crate::data::DMatrix::dense(vec![1.0], 1, 1),
            vec![0.0],
        );
        let mut src = crate::data::source::DMatrixSource::from_dataset(&ds, 8);
        let err = b.predict_from_source(&mut src).unwrap_err();
        assert!(format!("{err:#}").contains("cuts"), "{err:#}");
    }

    #[test]
    fn servable_load_fails_fast_on_legacy_model() {
        // a valid pre-cuts model file: loads fine in general, but the
        // serving load path must reject it up front with the actionable
        // retrain/re-save message — not panic or fall back to float
        let legacy = "xgb-tpu-model v1\nobjective = reg:squarederror\nnum_class = 1\n\
                      eta = 0.3\nbase_score = 0\ngroups = 1\ngroup 0 trees = 1\n\
                      tree 0 0 nodes = 1\n0 leaf 0.5 1\n";
        let path = std::env::temp_dir().join("xgb_tpu_legacy_model_test.txt");
        std::fs::write(&path, legacy).unwrap();
        assert!(load_model_file(&path).is_ok(), "plain load still works");
        let err = load_servable_model_file(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("cuts"), "{msg}");
        assert!(msg.contains("retrain"), "names the fix: {msg}");
        assert!(msg.contains("re-save"), "names the fix: {msg}");
        assert!(msg.contains("not servable"), "names the load site: {msg}");
        std::fs::remove_file(&path).ok();
        // a cuts-carrying model passes the same gate
        let (b, _) = trained("binary:logistic", 1);
        let ok_path = std::env::temp_dir().join("xgb_tpu_servable_model_test.txt");
        save_model_file(&b, &ok_path).unwrap();
        assert!(load_servable_model_file(&ok_path).is_ok());
        std::fs::remove_file(&ok_path).ok();
    }

    #[test]
    fn objective_shaping_params_round_trip() {
        let g = generate(&DatasetSpec::higgs_like(1200), 77);
        let params = LearnerParams {
            objective: "reg:quantile".parse().expect("infallible"),
            quantile_alpha: 0.9,
            num_rounds: 3,
            max_depth: 3,
            max_bins: 16,
            eval_every: 0,
            ..Default::default()
        };
        let b = crate::gbm::Learner::from_params(params)
            .unwrap()
            .train(&g.train, None)
            .unwrap();
        let mut buf = Vec::new();
        save_model(&b, &mut buf).unwrap();
        let text = String::from_utf8(buf.clone()).unwrap();
        assert!(text.contains("quantile_alpha = 0.9"), "{text}");
        let loaded = load_model(buf.as_slice()).unwrap();
        assert_eq!(loaded.params.quantile_alpha, 0.9);
        assert_eq!(loaded.predict(&g.valid.x), b.predict(&g.valid.x));
    }

    #[test]
    fn categorical_model_round_trips_and_routes() {
        // f0 cycles a sparse integer vocabulary (a membership split can
        // separate {0, 5} from {1, 3, 7} where thresholds cannot), f1 is
        // continuous noise
        let n = 300;
        let cats = [0.0, 1.0, 3.0, 5.0, 7.0];
        let mut xs: Vec<Float> = Vec::with_capacity(n * 2);
        let mut y: Vec<Float> = Vec::with_capacity(n);
        for i in 0..n {
            let c = cats[i % 5];
            xs.push(c);
            xs.push((i % 17) as Float * 0.1);
            y.push(if c == 0.0 || c == 5.0 { 1.0 } else { 0.0 });
        }
        let ds = crate::data::Dataset::new(crate::data::DMatrix::dense(xs, n, 2), y);
        let params = LearnerParams {
            objective: "reg:squarederror".parse().expect("infallible"),
            num_rounds: 3,
            max_depth: 3,
            max_bins: 16,
            eta: 0.5,
            eval_every: 0,
            categorical_features: vec![0],
            ..Default::default()
        };
        let b = crate::gbm::Learner::from_params(params)
            .unwrap()
            .train(&ds, None)
            .unwrap();
        let found_cat = b
            .trees
            .iter()
            .flatten()
            .flat_map(|t| t.nodes.iter())
            .any(|n| n.cats != 0);
        assert!(found_cat, "expected a membership split on this target");
        let mut buf = Vec::new();
        save_model(&b, &mut buf).unwrap();
        let text = String::from_utf8(buf.clone()).unwrap();
        assert!(text.contains(" cat 0 "), "{text}");
        assert!(text.contains("cuts categorical = 0"), "{text}");
        let loaded = load_model(buf.as_slice()).unwrap();
        assert_eq!(loaded.trees, b.trees, "cat bitsets must round-trip");
        assert_eq!(loaded.cuts, b.cuts, "categorical flags must round-trip");
        assert_eq!(loaded.predict(&ds.x), b.predict(&ds.x));
    }

    #[test]
    fn cat_node_on_non_categorical_feature_rejected() {
        let bad = "xgb-tpu-model v1\nobjective = reg:squarederror\nnum_class = 1\n\
                   eta = 0.3\nbase_score = 0\ngroups = 1\ngroup 0 trees = 1\n\
                   tree 0 0 nodes = 3\n0 cat 0 1,3 1 2 L 0 1\n1 leaf 0.1 1\n2 leaf 0.2 1\n\
                   cuts features = 1\ncuts ptrs = 0 2\ncuts values = 1 2\ncuts minvals = 0\n";
        let err = load_model(bad.as_bytes()).unwrap_err();
        assert!(
            format!("{err:#}").contains("non-categorical"),
            "{err:#}"
        );
        // out-of-range category codes fail fast too
        let bad2 = "xgb-tpu-model v1\nobjective = reg:squarederror\nnum_class = 1\n\
                    eta = 0.3\nbase_score = 0\ngroups = 1\ngroup 0 trees = 1\n\
                    tree 0 0 nodes = 3\n0 cat 0 64 1 2 L 0 1\n1 leaf 0.1 1\n2 leaf 0.2 1\n";
        let err2 = load_model(bad2.as_bytes()).unwrap_err();
        assert!(format!("{err2:#}").contains("[0, 64)"), "{err2:#}");
    }

    #[test]
    fn rejects_corrupt_models() {
        assert!(load_model("not a model".as_bytes()).is_err());
        // cycle: node 0 points at itself
        let bad = "xgb-tpu-model v1\nobjective = reg:squarederror\nnum_class = 1\n\
                   eta = 0.3\nbase_score = 0\ngroups = 1\ngroup 0 trees = 1\n\
                   tree 0 0 nodes = 1\n0 split 0 1.0 0 0 L 0 1\n";
        assert!(load_model(bad.as_bytes()).is_err());
        // out-of-range child
        let bad2 = "xgb-tpu-model v1\nobjective = reg:squarederror\nnum_class = 1\n\
                    eta = 0.3\nbase_score = 0\ngroups = 1\ngroup 0 trees = 1\n\
                    tree 0 0 nodes = 1\n0 split 0 1.0 5 6 L 0 1\n";
        assert!(load_model(bad2.as_bytes()).is_err());
    }

    #[test]
    fn corrupt_cuts_section_rejected() {
        // descending cut values within a feature must fail at load, not
        // produce silently wrong partition_point results at predict
        let bad = "xgb-tpu-model v1\nobjective = reg:squarederror\nnum_class = 1\n\
                   eta = 0.3\nbase_score = 0\ngroups = 1\ngroup 0 trees = 1\n\
                   tree 0 0 nodes = 1\n0 leaf 0.5 1\n\
                   cuts features = 1\ncuts ptrs = 0 2\ncuts values = 2 1\ncuts minvals = 0\n";
        let err = load_model(bad.as_bytes()).unwrap_err();
        assert!(format!("{err:#}").contains("ascend"), "{err:#}");
        // a split on a feature the cuts don't cover fails at load too
        let bad2 = "xgb-tpu-model v1\nobjective = reg:squarederror\nnum_class = 1\n\
                    eta = 0.3\nbase_score = 0\ngroups = 1\ngroup 0 trees = 1\n\
                    tree 0 0 nodes = 3\n0 split 5 1.0 1 2 L 0 1\n1 leaf 0.1 1\n2 leaf 0.2 1\n\
                    cuts features = 1\ncuts ptrs = 0 1\ncuts values = 9\ncuts minvals = 0\n";
        let err2 = load_model(bad2.as_bytes()).unwrap_err();
        assert!(format!("{err2:#}").contains("feature 5"), "{err2:#}");
    }

    #[test]
    fn unreachable_node_rejected() {
        let bad = "xgb-tpu-model v1\nobjective = reg:squarederror\nnum_class = 1\n\
                   eta = 0.3\nbase_score = 0\ngroups = 1\ngroup 0 trees = 1\n\
                   tree 0 0 nodes = 2\n0 leaf 0.5 1\n1 leaf 0.2 1\n";
        assert!(load_model(bad.as_bytes()).is_err());
    }
}
