//! Pluggable objective / metric registries.
//!
//! The built-in objectives and metrics used to live behind closed `match`
//! statements (`objective_by_name` / `metric_by_name`), so a user-defined
//! loss — a headline XGBoost capability — could not be plugged in without
//! editing the crate. The registries keep the built-ins as fast static
//! matches and add a process-wide table where `Box<dyn Objective>` /
//! `Box<dyn Metric>` factories register by name; lookups fall back to that
//! table, and unknown-name errors list every valid name (built-in and
//! registered alike).
//!
//! Registration is global (a `OnceLock<Mutex<..>>`), mirroring how XGBoost
//! custom objectives are installed once per process. Registering the same
//! custom name twice replaces the factory (last wins); shadowing a
//! built-in name is rejected.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use anyhow::{anyhow, bail, ensure, Result};

use crate::gbm::metric::{
    Accuracy, AftNloglik, Auc, ErrorRate, LogLoss, Mae, Metric, MultiError, Ndcg, Pinball, Rmse,
    TweedieNll,
};
use crate::gbm::objective::{
    Logistic, Objective, PairwiseRank, QuantileReg, Softmax, SquaredError, SurvivalAft, Tweedie,
};
use crate::gbm::params::{AftDistribution, MetricKind, ObjectiveKind, ObjectiveParams};

// Factories are Arc'd so lookups can clone them out and release the
// registry lock before invoking — a factory may itself consult the
// registry (delegation, diagnostics) without deadlocking.
type ObjectiveFactory = Arc<dyn Fn(usize) -> Result<Box<dyn Objective>> + Send + Sync>;
type MetricFactory = Arc<dyn Fn() -> Box<dyn Metric> + Send + Sync>;

fn custom_objectives() -> MutexGuard<'static, BTreeMap<String, ObjectiveFactory>> {
    static MAP: OnceLock<Mutex<BTreeMap<String, ObjectiveFactory>>> = OnceLock::new();
    MAP.get_or_init(|| Mutex::new(BTreeMap::new()))
        .lock()
        .expect("objective registry poisoned")
}

fn custom_metrics() -> MutexGuard<'static, BTreeMap<String, MetricFactory>> {
    static MAP: OnceLock<Mutex<BTreeMap<String, MetricFactory>>> = OnceLock::new();
    MAP.get_or_init(|| Mutex::new(BTreeMap::new()))
        .lock()
        .expect("metric registry poisoned")
}

/// Process-wide objective registry: built-ins plus user factories.
pub struct ObjectiveRegistry;

impl ObjectiveRegistry {
    /// Register a user objective factory under `name`. The factory
    /// receives `num_class` (1 for single-output objectives). Re-using a
    /// custom name replaces the previous factory; built-in names are
    /// rejected.
    pub fn register<F>(name: impl Into<String>, factory: F) -> Result<()>
    where
        F: Fn(usize) -> Result<Box<dyn Objective>> + Send + Sync + 'static,
    {
        let name = name.into();
        ensure!(
            !Self::is_builtin(&name) && name != "reg:linear",
            "cannot shadow built-in objective {name:?}"
        );
        ensure!(!name.is_empty(), "objective name must be non-empty");
        custom_objectives().insert(name, Arc::new(factory));
        Ok(())
    }

    /// Is `name` one of the compiled-in objectives?
    pub fn is_builtin(name: &str) -> bool {
        ObjectiveKind::BUILTIN_NAMES.iter().any(|&b| b == name)
    }

    /// Is `name` resolvable right now (built-in or registered)?
    pub fn is_registered(name: &str) -> bool {
        Self::is_builtin(name) || name == "reg:linear" || custom_objectives().contains_key(name)
    }

    /// Every currently valid objective name (built-ins first, then
    /// registered customs in sorted order) — used by error messages.
    pub fn names() -> Vec<String> {
        let mut names: Vec<String> = ObjectiveKind::BUILTIN_NAMES
            .iter()
            .map(|s| s.to_string())
            .collect();
        names.extend(custom_objectives().keys().cloned());
        names
    }

    /// Instantiate an objective with the full objective-shaping parameter
    /// set ([`ObjectiveParams`]) — the path the learner and model loader
    /// take, so `reg:quantile`'s α, `reg:tweedie`'s ρ and `survival:aft`'s
    /// distribution/σ come from the configuration instead of defaults.
    pub fn create_with(name: &str, p: &ObjectiveParams) -> Result<Box<dyn Objective>> {
        Ok(match name {
            "reg:quantile" => Box::new(QuantileReg {
                alpha: p.quantile_alpha,
            }),
            "reg:tweedie" => Box::new(Tweedie {
                rho: p.tweedie_variance_power,
            }),
            "survival:aft" => Box::new(SurvivalAft {
                dist: p.aft_distribution,
                sigma: p.aft_sigma,
            }),
            other => return Self::create(other, p.num_class),
        })
    }

    /// Instantiate an objective by name. Unknown names error with the full
    /// valid-name list. The parametrised scenario objectives resolve with
    /// their default parameters here; use
    /// [`create_with`](Self::create_with) to shape them.
    pub fn create(name: &str, num_class: usize) -> Result<Box<dyn Objective>> {
        Ok(match name {
            "reg:squarederror" | "reg:linear" => Box::new(SquaredError),
            "binary:logistic" => Box::new(Logistic),
            "reg:quantile" | "reg:tweedie" | "survival:aft" => {
                let p = ObjectiveParams {
                    num_class,
                    ..Default::default()
                };
                return Self::create_with(name, &p);
            }
            "multi:softmax" | "multi:softprob" => {
                ensure!(
                    num_class >= 2,
                    "{name} requires num_class >= 2, got {num_class}"
                );
                Box::new(Softmax {
                    k: num_class,
                    prob_output: name == "multi:softprob",
                })
            }
            "rank:pairwise" => Box::new(PairwiseRank::default()),
            other => {
                // clone the factory out and drop the lock before calling:
                // both the factory and the error path may re-enter the
                // registry (delegation, names()) without deadlocking
                let factory = custom_objectives().get(other).cloned();
                match factory {
                    Some(factory) => return factory(num_class),
                    None => bail!(
                        "unknown objective {other:?}; valid objectives: {}",
                        Self::names().join(", ")
                    ),
                }
            }
        })
    }
}

/// Process-wide metric registry: built-ins plus user factories.
pub struct MetricRegistry;

impl MetricRegistry {
    /// Register a user metric factory under `name`. Re-using a custom name
    /// replaces the previous factory; built-in names are rejected.
    pub fn register<F>(name: impl Into<String>, factory: F) -> Result<()>
    where
        F: Fn() -> Box<dyn Metric> + Send + Sync + 'static,
    {
        let name = name.into();
        ensure!(
            !Self::is_builtin(&name) && name != "acc",
            "cannot shadow built-in metric {name:?}"
        );
        ensure!(!name.is_empty(), "metric name must be non-empty");
        custom_metrics().insert(name, Arc::new(factory));
        Ok(())
    }

    /// Is `name` one of the compiled-in metrics?
    pub fn is_builtin(name: &str) -> bool {
        MetricKind::BUILTIN_NAMES.iter().any(|&b| b == name)
    }

    /// Is `name` resolvable right now (built-in, a well-formed
    /// parametrised form like `pinball@0.9`, or registered)?
    pub fn is_registered(name: &str) -> bool {
        Self::is_builtin(name)
            || name == "acc"
            || matches!(parametrised_metric(name), Some(Ok(_)))
            || custom_metrics().contains_key(name)
    }

    /// Instantiate the metric `name`, shaping the parametrised scenario
    /// metrics from `op` when the name carries no explicit `@param` — the
    /// learner's default-metric path, so `reg:quantile` at α = 0.9
    /// evaluates `pinball` at 0.9 without the user spelling it out.
    pub fn create_for(name: &str, op: &ObjectiveParams) -> Result<Box<dyn Metric>> {
        Ok(match name {
            "pinball" => Box::new(Pinball {
                alpha: op.quantile_alpha,
            }),
            "tweedie-nloglik" => Box::new(TweedieNll {
                rho: op.tweedie_variance_power,
            }),
            "aft-nloglik" => Box::new(AftNloglik {
                dist: op.aft_distribution,
                sigma: op.aft_sigma,
            }),
            other => return Self::create(other),
        })
    }

    /// Every currently valid metric name (built-ins first, then registered
    /// customs in sorted order) — used by error messages.
    pub fn names() -> Vec<String> {
        let mut names: Vec<String> =
            MetricKind::BUILTIN_NAMES.iter().map(|s| s.to_string()).collect();
        names.extend(custom_metrics().keys().cloned());
        names
    }

    /// Instantiate a metric by name. Unknown names error with the full
    /// valid-name list. The scenario metrics accept parameters after `@`:
    /// `pinball@0.9`, `tweedie-nloglik@1.3`, `aft-nloglik@logistic,0.5`
    /// (bare names take the [`ObjectiveParams`] defaults).
    pub fn create(name: &str) -> Result<Box<dyn Metric>> {
        if let Some(parsed) = parametrised_metric(name) {
            return parsed;
        }
        Ok(match name {
            "rmse" => Box::new(Rmse),
            "mae" => Box::new(Mae),
            "logloss" => Box::new(LogLoss),
            "accuracy" | "acc" => Box::new(Accuracy),
            "error" => Box::new(ErrorRate),
            "auc" => Box::new(Auc),
            "merror" => Box::new(MultiError),
            "ndcg" => Box::new(Ndcg { k: 10 }),
            other => {
                // clone the factory out and drop the lock before calling
                // (factories may re-enter the registry)
                let factory = custom_metrics().get(other).cloned();
                match factory {
                    Some(factory) => return Ok(factory()),
                    None => bail!(
                        "unknown metric {other:?}; valid metrics: {}",
                        Self::names().join(", ")
                    ),
                }
            }
        })
    }
}

/// Parse the parametrised scenario-metric names. Returns `None` when the
/// base name is not one of them (fall through to the static/custom
/// lookup), `Some(Err(..))` when the base matches but the parameter text
/// is malformed or out of range.
fn parametrised_metric(name: &str) -> Option<Result<Box<dyn Metric>>> {
    let (base, arg) = match name.split_once('@') {
        Some((b, a)) => (b, Some(a)),
        None => (name, None),
    };
    let d = ObjectiveParams::default();
    match base {
        "pinball" => Some((|| {
            let alpha = match arg {
                None => d.quantile_alpha,
                Some(a) => a
                    .parse::<f64>()
                    .map_err(|_| anyhow!("pinball@α: cannot parse {a:?} as a number"))?,
            };
            ensure!(
                alpha > 0.0 && alpha < 1.0,
                "pinball@α requires α in (0, 1), got {alpha}"
            );
            Ok(Box::new(Pinball { alpha }) as Box<dyn Metric>)
        })()),
        "tweedie-nloglik" => Some((|| {
            let rho = match arg {
                None => d.tweedie_variance_power,
                Some(a) => a
                    .parse::<f64>()
                    .map_err(|_| anyhow!("tweedie-nloglik@ρ: cannot parse {a:?} as a number"))?,
            };
            ensure!(
                rho > 1.0 && rho < 2.0,
                "tweedie-nloglik@ρ requires ρ in (1, 2), got {rho}"
            );
            Ok(Box::new(TweedieNll { rho }) as Box<dyn Metric>)
        })()),
        "aft-nloglik" => Some((|| {
            let (dist, sigma) = match arg {
                None => (d.aft_distribution, d.aft_sigma),
                Some(a) => {
                    let (dist_text, sigma_text) = match a.split_once(',') {
                        Some((x, y)) => (x, Some(y)),
                        None => (a, None),
                    };
                    let dist: AftDistribution =
                        dist_text.parse().map_err(|e: String| anyhow!(e))?;
                    let sigma = match sigma_text {
                        None => d.aft_sigma,
                        Some(s) => s
                            .parse::<f64>()
                            .map_err(|_| anyhow!("aft-nloglik@dist,σ: cannot parse {s:?}"))?,
                    };
                    (dist, sigma)
                }
            };
            ensure!(sigma > 0.0, "aft-nloglik requires σ > 0, got {sigma}");
            Ok(Box::new(AftNloglik { dist, sigma }) as Box<dyn Metric>)
        })()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::{Float, GradPair};

    struct ConstantObjective;

    impl Objective for ConstantObjective {
        fn name(&self) -> &'static str {
            "test:constant"
        }
        fn base_score(&self, _train: &Dataset) -> Vec<Float> {
            vec![0.0]
        }
        fn gradients(&self, ds: &Dataset, margins: &[Vec<Float>]) -> Vec<Vec<GradPair>> {
            vec![ds
                .y
                .iter()
                .zip(margins[0].iter())
                .map(|(&y, &m)| GradPair::new(m - y, 1.0))
                .collect()]
        }
        fn transform(&self, margins: &[Vec<Float>]) -> Vec<Float> {
            margins[0].clone()
        }
    }

    #[test]
    fn builtin_objectives_resolve() {
        for name in ObjectiveKind::BUILTIN_NAMES {
            assert!(ObjectiveRegistry::create(name, 3).is_ok(), "{name}");
        }
        assert!(ObjectiveRegistry::create("multi:softmax", 1).is_err());
    }

    #[test]
    fn unknown_objective_error_lists_names() {
        let err = ObjectiveRegistry::create("definitely:not", 1).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("reg:squarederror"), "{msg}");
        assert!(msg.contains("binary:logistic"), "{msg}");
        // the scenario objectives appear in the valid set too
        assert!(msg.contains("reg:quantile"), "{msg}");
        assert!(msg.contains("reg:tweedie"), "{msg}");
        assert!(msg.contains("survival:aft"), "{msg}");
    }

    #[test]
    fn scenario_objectives_shape_from_params() {
        let p = ObjectiveParams {
            quantile_alpha: 0.9,
            tweedie_variance_power: 1.2,
            aft_distribution: AftDistribution::Logistic,
            aft_sigma: 0.5,
            ..Default::default()
        };
        for name in ["reg:quantile", "reg:tweedie", "survival:aft"] {
            assert!(ObjectiveRegistry::create_with(name, &p).is_ok(), "{name}");
            // bare create resolves with defaults too
            assert!(ObjectiveRegistry::create(name, 1).is_ok(), "{name}");
        }
        // create_with falls through to the classic path for other names
        assert!(ObjectiveRegistry::create_with("binary:logistic", &p).is_ok());
        assert!(ObjectiveRegistry::create_with("definitely:not", &p).is_err());
    }

    #[test]
    fn parametrised_metrics_resolve() {
        for name in [
            "pinball",
            "pinball@0.9",
            "tweedie-nloglik",
            "tweedie-nloglik@1.3",
            "aft-nloglik",
            "aft-nloglik@logistic",
            "aft-nloglik@normal,0.5",
        ] {
            assert!(MetricRegistry::create(name).is_ok(), "{name}");
            assert!(MetricRegistry::is_registered(name), "{name}");
        }
        for bad in ["pinball@2.0", "pinball@x", "tweedie-nloglik@3", "aft-nloglik@cauchy"] {
            assert!(MetricRegistry::create(bad).is_err(), "{bad}");
            assert!(!MetricRegistry::is_registered(bad), "{bad}");
        }
        // create_for shapes bare names from the objective params
        let op = ObjectiveParams {
            quantile_alpha: 0.75,
            ..Default::default()
        };
        let m = MetricRegistry::create_for("pinball", &op).unwrap();
        let d = Dataset::new(crate::data::DMatrix::dense(vec![0.0], 1, 1), vec![1.0]);
        // under-prediction by 1 at α = 0.75 costs 0.75
        assert!((m.eval(&d, &[0.0]) - 0.75).abs() < 1e-9);
    }

    #[test]
    fn unknown_metric_error_lists_names() {
        let err = MetricRegistry::create("definitely:not").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("rmse"), "{msg}");
        assert!(msg.contains("ndcg"), "{msg}");
    }

    #[test]
    fn custom_objective_registers_and_resolves() {
        ObjectiveRegistry::register("test:constant-registry", |_k| {
            Ok(Box::new(ConstantObjective))
        })
        .unwrap();
        assert!(ObjectiveRegistry::is_registered("test:constant-registry"));
        let o = ObjectiveRegistry::create("test:constant-registry", 1).unwrap();
        assert_eq!(o.n_outputs(), 1);
        assert!(ObjectiveRegistry::names()
            .iter()
            .any(|n| n == "test:constant-registry"));
    }

    #[test]
    fn builtin_names_cannot_be_shadowed() {
        assert!(
            ObjectiveRegistry::register("binary:logistic", |_| Ok(Box::new(ConstantObjective)))
                .is_err()
        );
        assert!(ObjectiveRegistry::register("reg:linear", |_| {
            Ok(Box::new(ConstantObjective))
        })
        .is_err());
        assert!(MetricRegistry::register("rmse", || Box::new(Rmse)).is_err());
        assert!(MetricRegistry::register("acc", || Box::new(Accuracy)).is_err());
    }
}
