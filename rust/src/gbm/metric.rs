//! Evaluation metrics — the numbers Table 2 reports (RMSE for the
//! regression datasets, accuracy for the classification ones) plus the
//! standard companions (logloss, AUC, multiclass error, NDCG for the
//! ranking objective).

use crate::data::Dataset;
use crate::Float;

/// An evaluation metric over transformed predictions.
pub trait Metric: Send {
    fn name(&self) -> &'static str;
    /// Lower is better? (drives early-stopping direction)
    fn minimize(&self) -> bool {
        true
    }
    /// `preds` layout matches `Objective::transform` output (length n, or
    /// n·k for `multi:softprob`).
    fn eval(&self, ds: &Dataset, preds: &[Float]) -> f64;
}

/// Look up a metric by name — built-in or registered through
/// [`crate::gbm::MetricRegistry`]. Unknown names error with the full
/// valid-name list.
pub fn metric_by_name(name: &str) -> anyhow::Result<Box<dyn Metric>> {
    crate::gbm::registry::MetricRegistry::create(name)
}

/// Root mean squared error.
pub struct Rmse;
impl Metric for Rmse {
    fn name(&self) -> &'static str {
        "rmse"
    }
    fn eval(&self, ds: &Dataset, preds: &[Float]) -> f64 {
        let n = ds.y.len();
        let se: f64 = ds
            .y
            .iter()
            .zip(preds.iter())
            .map(|(&y, &p)| ((p - y) as f64).powi(2))
            .sum();
        (se / n as f64).sqrt()
    }
}

/// Mean absolute error.
pub struct Mae;
impl Metric for Mae {
    fn name(&self) -> &'static str {
        "mae"
    }
    fn eval(&self, ds: &Dataset, preds: &[Float]) -> f64 {
        let n = ds.y.len();
        ds.y.iter()
            .zip(preds.iter())
            .map(|(&y, &p)| ((p - y) as f64).abs())
            .sum::<f64>()
            / n as f64
    }
}

/// Binary cross-entropy over probability predictions.
pub struct LogLoss;
impl Metric for LogLoss {
    fn name(&self) -> &'static str {
        "logloss"
    }
    fn eval(&self, ds: &Dataset, preds: &[Float]) -> f64 {
        let n = ds.y.len();
        ds.y.iter()
            .zip(preds.iter())
            .map(|(&y, &p)| {
                let p = (p as f64).clamp(1e-15, 1.0 - 1e-15);
                -(y as f64 * p.ln() + (1.0 - y as f64) * (1.0 - p).ln())
            })
            .sum::<f64>()
            / n as f64
    }
}

/// Binary accuracy (%) at threshold 0.5 — Table 2's classification metric.
pub struct Accuracy;
impl Metric for Accuracy {
    fn name(&self) -> &'static str {
        "accuracy"
    }
    fn minimize(&self) -> bool {
        false
    }
    fn eval(&self, ds: &Dataset, preds: &[Float]) -> f64 {
        let n = ds.y.len();
        let correct = ds
            .y
            .iter()
            .zip(preds.iter())
            .filter(|(&y, &p)| (p >= 0.5) == (y >= 0.5))
            .count();
        100.0 * correct as f64 / n as f64
    }
}

/// Binary error rate at threshold 0.5.
pub struct ErrorRate;
impl Metric for ErrorRate {
    fn name(&self) -> &'static str {
        "error"
    }
    fn eval(&self, ds: &Dataset, preds: &[Float]) -> f64 {
        100.0 - Accuracy.eval(ds, preds) / 1.0
    }
}

/// Area under the ROC curve over probability/margin predictions.
pub struct Auc;
impl Metric for Auc {
    fn name(&self) -> &'static str {
        "auc"
    }
    fn minimize(&self) -> bool {
        false
    }
    fn eval(&self, ds: &Dataset, preds: &[Float]) -> f64 {
        // rank-sum (Mann–Whitney) formulation with tie handling
        let mut idx: Vec<usize> = (0..preds.len()).collect();
        idx.sort_by(|&a, &b| preds[a].partial_cmp(&preds[b]).unwrap());
        let n = preds.len();
        let mut ranks = vec![0.0f64; n];
        let mut i = 0;
        while i < n {
            let mut j = i;
            while j + 1 < n && preds[idx[j + 1]] == preds[idx[i]] {
                j += 1;
            }
            let avg = (i + j) as f64 / 2.0 + 1.0;
            for k in i..=j {
                ranks[idx[k]] = avg;
            }
            i = j + 1;
        }
        let n_pos = ds.y.iter().filter(|&&y| y >= 0.5).count() as f64;
        let n_neg = n as f64 - n_pos;
        if n_pos == 0.0 || n_neg == 0.0 {
            return 0.5;
        }
        let rank_sum_pos: f64 = ds
            .y
            .iter()
            .zip(ranks.iter())
            .filter(|(&y, _)| y >= 0.5)
            .map(|(_, &r)| r)
            .sum();
        (rank_sum_pos - n_pos * (n_pos + 1.0) / 2.0) / (n_pos * n_neg)
    }
}

/// Multiclass error (%) over argmax class predictions.
pub struct MultiError;
impl Metric for MultiError {
    fn name(&self) -> &'static str {
        "merror"
    }
    fn eval(&self, ds: &Dataset, preds: &[Float]) -> f64 {
        let n = ds.y.len();
        let wrong = ds
            .y
            .iter()
            .zip(preds.iter())
            .filter(|(&y, &p)| (y as i64) != (p as i64))
            .count();
        100.0 * wrong as f64 / n as f64
    }
}

/// NDCG@k over query groups (ranking tasks).
pub struct Ndcg {
    pub k: usize,
}
impl Metric for Ndcg {
    fn name(&self) -> &'static str {
        "ndcg"
    }
    fn minimize(&self) -> bool {
        false
    }
    fn eval(&self, ds: &Dataset, preds: &[Float]) -> f64 {
        let groups: Vec<usize> = if ds.groups.is_empty() {
            vec![0, ds.y.len()]
        } else {
            ds.groups.clone()
        };
        let mut total = 0.0;
        let mut count = 0usize;
        for w in groups.windows(2) {
            let (lo, hi) = (w[0], w[1]);
            let mut order: Vec<usize> = (lo..hi).collect();
            order.sort_by(|&a, &b| preds[b].partial_cmp(&preds[a]).unwrap());
            let dcg: f64 = order
                .iter()
                .take(self.k)
                .enumerate()
                .map(|(i, &d)| ((1u64 << ds.y[d] as u32) as f64 - 1.0) / ((i + 2) as f64).log2())
                .sum();
            let mut ideal: Vec<Float> = ds.y[lo..hi].to_vec();
            ideal.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let idcg: f64 = ideal
                .iter()
                .take(self.k)
                .enumerate()
                .map(|(i, &y)| ((1u64 << y as u32) as f64 - 1.0) / ((i + 2) as f64).log2())
                .sum();
            if idcg > 0.0 {
                total += dcg / idcg;
                count += 1;
            }
        }
        if count == 0 {
            0.0
        } else {
            total / count as f64
        }
    }
}

/// Mean pinball loss at quantile `alpha` — the `reg:quantile` companion
/// (resolved as `pinball` or `pinball@α` through the registry).
pub struct Pinball {
    pub alpha: f64,
}
impl Metric for Pinball {
    fn name(&self) -> &'static str {
        "pinball"
    }
    fn eval(&self, ds: &Dataset, preds: &[Float]) -> f64 {
        let n = ds.y.len();
        ds.y.iter()
            .zip(preds.iter())
            .map(|(&y, &p)| crate::gbm::objective::pinball_loss(self.alpha, y as f64, p as f64))
            .sum::<f64>()
            / n as f64
    }
}

/// Mean Tweedie negative log-likelihood at variance power `rho` over
/// mean-scale predictions (the objective's transform is the log link, so
/// `preds` are `e^margin`; resolved as `tweedie-nloglik[@ρ]`).
pub struct TweedieNll {
    pub rho: f64,
}
impl Metric for TweedieNll {
    fn name(&self) -> &'static str {
        "tweedie-nloglik"
    }
    fn eval(&self, ds: &Dataset, preds: &[Float]) -> f64 {
        let n = ds.y.len();
        ds.y.iter()
            .zip(preds.iter())
            .map(|(&y, &p)| {
                let m = (p as f64).max(1e-30).ln();
                crate::gbm::objective::tweedie_nll(self.rho, y as f64, m)
            })
            .sum::<f64>()
            / n as f64
    }
}

/// Mean AFT negative log-likelihood over survival-time predictions
/// (`preds` are `e^margin`; labels are the dataset's `(lower, upper]`
/// interval bounds; resolved as `aft-nloglik[@dist,σ]`).
pub struct AftNloglik {
    pub dist: crate::gbm::params::AftDistribution,
    pub sigma: f64,
}
impl Metric for AftNloglik {
    fn name(&self) -> &'static str {
        "aft-nloglik"
    }
    fn eval(&self, ds: &Dataset, preds: &[Float]) -> f64 {
        let n = ds.y.len();
        let yu = ds.bounds_upper();
        ds.y.iter()
            .zip(yu.iter())
            .zip(preds.iter())
            .map(|((&lo, &up), &p)| {
                let m = (p as f64).max(1e-30).ln();
                crate::gbm::objective::aft_nll(self.dist, self.sigma, lo as f64, up as f64, m)
            })
            .sum::<f64>()
            / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DMatrix, Dataset};

    fn ds(y: Vec<Float>) -> Dataset {
        let n = y.len();
        Dataset::new(DMatrix::dense(vec![0.0; n], n, 1), y)
    }

    #[test]
    fn rmse_known_value() {
        let d = ds(vec![0.0, 0.0]);
        assert!((Rmse.eval(&d, &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn mae_known_value() {
        let d = ds(vec![0.0, 2.0]);
        assert!((Mae.eval(&d, &[1.0, 0.0]) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn accuracy_counts_threshold() {
        let d = ds(vec![1.0, 0.0, 1.0, 0.0]);
        let acc = Accuracy.eval(&d, &[0.9, 0.1, 0.4, 0.6]);
        assert!((acc - 50.0).abs() < 1e-12);
    }

    #[test]
    fn logloss_perfect_and_bad() {
        let d = ds(vec![1.0, 0.0]);
        assert!(LogLoss.eval(&d, &[1.0, 0.0]) < 1e-10);
        assert!(LogLoss.eval(&d, &[0.0, 1.0]) > 10.0);
    }

    #[test]
    fn auc_perfect_random_inverted() {
        let d = ds(vec![1.0, 1.0, 0.0, 0.0]);
        assert!((Auc.eval(&d, &[0.9, 0.8, 0.2, 0.1]) - 1.0).abs() < 1e-12);
        assert!((Auc.eval(&d, &[0.1, 0.2, 0.8, 0.9]) - 0.0).abs() < 1e-12);
        assert!((Auc.eval(&d, &[0.5, 0.5, 0.5, 0.5]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn auc_degenerate_classes() {
        let d = ds(vec![1.0, 1.0]);
        assert_eq!(Auc.eval(&d, &[0.3, 0.7]), 0.5);
    }

    #[test]
    fn merror_counts_class_mismatches() {
        let d = ds(vec![0.0, 1.0, 2.0, 2.0]);
        let e = MultiError.eval(&d, &[0.0, 1.0, 1.0, 2.0]);
        assert!((e - 25.0).abs() < 1e-12);
    }

    #[test]
    fn ndcg_perfect_is_one() {
        let x = DMatrix::dense(vec![0.0; 4], 4, 1);
        let d = Dataset::with_groups(x, vec![3.0, 2.0, 1.0, 0.0], vec![0, 4]);
        let n = Ndcg { k: 10 };
        assert!((n.eval(&d, &[4.0, 3.0, 2.0, 1.0]) - 1.0).abs() < 1e-12);
        assert!(n.eval(&d, &[1.0, 2.0, 3.0, 4.0]) < 1.0);
    }

    #[test]
    fn registry() {
        for m in ["rmse", "mae", "logloss", "accuracy", "auc", "merror", "ndcg"] {
            assert!(metric_by_name(m).is_ok(), "{m}");
        }
        let err = metric_by_name("nope").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("rmse") && msg.contains("auc"), "{msg}");
    }

    #[test]
    fn pinball_known_values() {
        let d = ds(vec![2.0, 2.0]);
        // preds 1.0 (under by 1) and 3.0 (over by 1) at α = 0.9:
        // 0.9·1 + 0.1·1 over 2 rows
        let m = Pinball { alpha: 0.9 };
        assert!((m.eval(&d, &[1.0, 3.0]) - 0.5).abs() < 1e-9);
        // exact predictions score 0
        assert_eq!(m.eval(&d, &[2.0, 2.0]), 0.0);
    }

    #[test]
    fn tweedie_nll_minimised_at_label_mean() {
        let d = ds(vec![4.0, 4.0]);
        let m = TweedieNll { rho: 1.5 };
        let at_mean = m.eval(&d, &[4.0, 4.0]);
        assert!(at_mean < m.eval(&d, &[2.0, 2.0]));
        assert!(at_mean < m.eval(&d, &[8.0, 8.0]));
    }

    #[test]
    fn aft_nloglik_prefers_in_interval_predictions() {
        let x = DMatrix::dense(vec![0.0; 2], 2, 1);
        let d = Dataset::with_bounds(x, vec![4.0, 2.0], vec![4.0, 8.0]);
        let m = AftNloglik {
            dist: crate::gbm::params::AftDistribution::Normal,
            sigma: 1.0,
        };
        // predicting inside the interval beats predicting far outside
        assert!(m.eval(&d, &[4.0, 4.0]) < m.eval(&d, &[0.5, 40.0]));
    }

    #[test]
    fn minimize_direction() {
        assert!(Rmse.minimize());
        assert!(!Accuracy.minimize());
        assert!(!Auc.minimize());
        assert!(!Ndcg { k: 5 }.minimize());
    }
}
