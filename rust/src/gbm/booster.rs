//! The boosting driver: the full Figure 1 pipeline.
//!
//! Per iteration: predict (margins are maintained incrementally from each
//! new tree's leaf assignments — no ensemble re-traversal of the training
//! set), evaluate gradients (objective), build one tree per output via the
//! multi-device coordinator (Algorithm 1), and score the validation set.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::{
    BuildStats, CoordinatorParams, HistBackend, MultiDeviceCoordinator, NativeBackend,
};
use crate::data::Dataset;
use crate::gbm::metric::{metric_by_name, Metric};
use crate::gbm::objective::{objective_by_name, Objective};
use crate::predict;
use crate::tree::RegTree;
use crate::util::Config;
use crate::Float;

/// Booster hyperparameters (XGBoost-style names).
#[derive(Debug, Clone)]
pub struct BoosterParams {
    pub objective: String,
    pub num_class: usize,
    pub num_rounds: usize,
    pub eta: f64,
    pub max_depth: usize,
    pub max_leaves: usize,
    pub max_bins: usize,
    pub lambda: f64,
    pub gamma: f64,
    pub alpha: f64,
    pub min_child_weight: f64,
    /// "depthwise" or "lossguide" (§2.3).
    pub grow_policy: String,
    /// Simulated device count (the paper's GPUs).
    pub n_devices: usize,
    /// Bit-packed shard storage (§2.2).
    pub compress: bool,
    /// "ring" or "serial" histogram all-reduce.
    pub allreduce: String,
    /// Evaluation metric name; empty = objective's default.
    pub eval_metric: String,
    /// Evaluate every k rounds (0 = only at the end).
    pub eval_every: usize,
    /// Stop if the validation metric hasn't improved in this many
    /// evaluations (0 = never).
    pub early_stopping_rounds: usize,
    /// Row subsampling rate per tree (1.0 = off). Implemented by zeroing
    /// the gradient pairs of unsampled rows, which excludes them from
    /// histograms and node sums while keeping margin updates global.
    pub subsample: f64,
    /// Column sampling rate per tree (1.0 = off).
    pub colsample_bytree: f64,
    /// Per-feature monotone constraints, e.g. `"1,0,-1"` or `"(1,0,-1)"`;
    /// empty = none. Shorter lists imply 0 for remaining features.
    pub monotone_constraints: String,
    /// Seed for subsampling.
    pub seed: u64,
    /// Print eval lines to stderr.
    pub verbose: bool,
}

impl Default for BoosterParams {
    fn default() -> Self {
        BoosterParams {
            objective: "reg:squarederror".into(),
            num_class: 1,
            num_rounds: 50,
            eta: 0.3,
            max_depth: 6,
            max_leaves: 0,
            max_bins: 256,
            lambda: 1.0,
            gamma: 0.0,
            alpha: 0.0,
            min_child_weight: 1.0,
            grow_policy: "depthwise".into(),
            n_devices: 1,
            compress: true,
            allreduce: "ring".into(),
            eval_metric: String::new(),
            eval_every: 1,
            early_stopping_rounds: 0,
            subsample: 1.0,
            colsample_bytree: 1.0,
            monotone_constraints: String::new(),
            seed: 0,
            verbose: false,
        }
    }
}

/// Parse `"1,0,-1"` / `"(1,0,-1)"` into a constraint vector.
fn parse_monotone(s: &str) -> Result<Vec<i8>> {
    let t = s.trim().trim_start_matches('(').trim_end_matches(')');
    if t.is_empty() {
        return Ok(Vec::new());
    }
    t.split(',')
        .map(|tok| {
            let v: i32 = tok.trim().parse().context("monotone_constraints")?;
            anyhow::ensure!((-1..=1).contains(&v), "constraint must be -1, 0 or 1");
            Ok(v as i8)
        })
        .collect()
}

impl BoosterParams {
    /// Read parameters from a [`Config`] (defaults for absent keys).
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let d = BoosterParams::default();
        Ok(BoosterParams {
            objective: cfg.get("objective").unwrap_or(&d.objective).to_string(),
            num_class: cfg.get_parse("num_class", d.num_class)?,
            num_rounds: cfg.get_parse("num_rounds", d.num_rounds)?,
            eta: cfg.get_parse("eta", d.eta)?,
            max_depth: cfg.get_parse("max_depth", d.max_depth)?,
            max_leaves: cfg.get_parse("max_leaves", d.max_leaves)?,
            max_bins: cfg.get_parse("max_bins", d.max_bins)?,
            lambda: cfg.get_parse("lambda", d.lambda)?,
            gamma: cfg.get_parse("gamma", d.gamma)?,
            alpha: cfg.get_parse("alpha", d.alpha)?,
            min_child_weight: cfg.get_parse("min_child_weight", d.min_child_weight)?,
            grow_policy: cfg.get("grow_policy").unwrap_or(&d.grow_policy).to_string(),
            n_devices: cfg.get_parse("n_devices", d.n_devices)?,
            compress: cfg.get_bool("compress", d.compress),
            allreduce: cfg.get("allreduce").unwrap_or(&d.allreduce).to_string(),
            eval_metric: cfg.get("eval_metric").unwrap_or("").to_string(),
            eval_every: cfg.get_parse("eval_every", d.eval_every)?,
            early_stopping_rounds: cfg
                .get_parse("early_stopping_rounds", d.early_stopping_rounds)?,
            subsample: cfg.get_parse("subsample", d.subsample)?,
            colsample_bytree: cfg.get_parse("colsample_bytree", d.colsample_bytree)?,
            monotone_constraints: cfg
                .get("monotone_constraints")
                .unwrap_or("")
                .to_string(),
            seed: cfg.get_parse("seed", d.seed)?,
            verbose: cfg.get_bool("verbose", d.verbose),
        })
    }

    /// Derive the coordinator configuration.
    pub fn coordinator_params(&self) -> Result<CoordinatorParams> {
        Ok(CoordinatorParams {
            n_devices: self.n_devices,
            compress: self.compress,
            tree: crate::tree::TreeParams {
                lambda: self.lambda,
                gamma: self.gamma,
                alpha: self.alpha,
                min_child_weight: self.min_child_weight,
                max_depth: self.max_depth,
                max_leaves: self.max_leaves,
                monotone_constraints: parse_monotone(&self.monotone_constraints)?,
            },
            policy: self
                .grow_policy
                .parse()
                .map_err(|e: String| anyhow::anyhow!(e))?,
            allreduce: self
                .allreduce
                .parse()
                .map_err(|e: String| anyhow::anyhow!(e))?,
            cost: Default::default(),
            eta: self.eta,
            max_bins: self.max_bins,
            subtraction: true,
            colsample_bytree: self.colsample_bytree,
            seed: self.seed,
        })
    }
}

/// One evaluation-history entry.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub round: usize,
    pub metric: &'static str,
    pub train: f64,
    pub valid: Option<f64>,
    pub elapsed_secs: f64,
}

/// A trained gradient-boosted ensemble.
pub struct Booster {
    pub params: BoosterParams,
    objective: Box<dyn Objective>,
    pub base_score: Vec<Float>,
    /// `trees[output][round]`.
    pub trees: Vec<Vec<RegTree>>,
    pub eval_history: Vec<EvalRecord>,
    /// Accumulated coordinator statistics over all trees.
    pub build_stats: BuildStats,
    /// Measured wall-clock of `train` (this process).
    pub train_secs: f64,
    /// Simulated multi-device clock (DESIGN.md §5) over all rounds.
    pub simulated_secs: f64,
}

impl Booster {
    /// Assemble a booster from pre-built trees (used by the baseline
    /// trainers in [`crate::baselines`] so prediction/metric code is
    /// shared).
    pub fn from_parts(
        params: BoosterParams,
        base_score: Vec<Float>,
        trees: Vec<Vec<RegTree>>,
        train_secs: f64,
    ) -> Result<Booster> {
        let objective = objective_by_name(&params.objective, params.num_class)?;
        anyhow::ensure!(trees.len() == objective.n_outputs(), "tree groups != outputs");
        Ok(Booster {
            params,
            objective,
            base_score,
            trees,
            eval_history: Vec::new(),
            build_stats: BuildStats::default(),
            train_secs,
            simulated_secs: 0.0,
        })
    }

    /// Train with the native histogram backend.
    pub fn train(
        params: &BoosterParams,
        train: &Dataset,
        valid: Option<&Dataset>,
    ) -> Result<Booster> {
        Self::train_with_backend(params, train, valid, Box::new(NativeBackend))
    }

    /// Train with an explicit histogram backend (e.g. the XLA runtime).
    pub fn train_with_backend(
        params: &BoosterParams,
        train: &Dataset,
        valid: Option<&Dataset>,
        backend: Box<dyn HistBackend>,
    ) -> Result<Booster> {
        let t0 = Instant::now();
        let objective = objective_by_name(&params.objective, params.num_class)
            .context("resolving objective")?;
        let k = objective.n_outputs();
        let metric: Box<dyn Metric> = if params.eval_metric.is_empty() {
            default_metric(objective.as_ref())?
        } else {
            metric_by_name(&params.eval_metric)?
        };

        let mut coordinator = MultiDeviceCoordinator::with_backend(
            &train.x,
            params.coordinator_params()?,
            backend,
        )?;

        let base_score = objective.base_score(train);
        let n = train.n_rows();
        let mut margins: Vec<Vec<Float>> =
            base_score.iter().map(|&b| vec![b; n]).collect();
        let mut valid_margins: Option<Vec<Vec<Float>>> = valid.map(|v| {
            base_score
                .iter()
                .map(|&b| vec![b; v.n_rows()])
                .collect()
        });

        let mut trees: Vec<Vec<RegTree>> = vec![Vec::new(); k];
        let mut eval_history = Vec::new();
        let mut build_stats = BuildStats::default();
        let mut best_metric: Option<f64> = None;
        let mut stale_evals = 0usize;

        let mut sub_rng = crate::util::Pcg64::new(params.seed ^ 0x5b5a);
        for round in 0..params.num_rounds {
            let mut grads = objective.gradients(train, &margins);
            if params.subsample < 1.0 {
                // exclude unsampled rows from this round's trees by zeroing
                // their gradient mass (same rows for all k outputs)
                for i in 0..n {
                    if sub_rng.next_f64() >= params.subsample {
                        for class_grads in grads.iter_mut() {
                            class_grads[i] = crate::GradPair::default();
                        }
                    }
                }
            }
            for (c, class_grads) in grads.iter().enumerate().take(k) {
                let result = coordinator.build_tree(class_grads)?;
                for (m, d) in margins[c].iter_mut().zip(result.deltas.iter()) {
                    *m += *d;
                }
                if let (Some(vm), Some(v)) = (valid_margins.as_mut(), valid) {
                    predict::accumulate_tree(&result.tree, &v.x, &mut vm[c]);
                }
                build_stats.accumulate(&result.stats);
                trees[c].push(result.tree);
            }

            let do_eval = params.eval_every > 0 && (round + 1) % params.eval_every == 0;
            if do_eval || round + 1 == params.num_rounds {
                let train_score = metric.eval(train, &objective.transform(&margins));
                let valid_score = valid_margins
                    .as_ref()
                    .zip(valid)
                    .map(|(vm, v)| metric.eval(v, &objective.transform(vm)));
                let rec = EvalRecord {
                    round: round + 1,
                    metric: metric.name(),
                    train: train_score,
                    valid: valid_score,
                    elapsed_secs: t0.elapsed().as_secs_f64(),
                };
                if params.verbose {
                    eprintln!(
                        "[{}] train-{}:{:.5}{}",
                        rec.round,
                        rec.metric,
                        rec.train,
                        rec.valid
                            .map(|v| format!(" valid-{}:{v:.5}", rec.metric))
                            .unwrap_or_default()
                    );
                }
                eval_history.push(rec);

                // early stopping on the validation score
                if params.early_stopping_rounds > 0 {
                    if let Some(score) = valid_score {
                        let improved = match best_metric {
                            None => true,
                            Some(best) => {
                                if metric.minimize() {
                                    score < best
                                } else {
                                    score > best
                                }
                            }
                        };
                        if improved {
                            best_metric = Some(score);
                            stale_evals = 0;
                        } else {
                            stale_evals += 1;
                            if stale_evals >= params.early_stopping_rounds {
                                break;
                            }
                        }
                    }
                }
            }
        }

        let simulated_secs = build_stats.simulated_secs;
        Ok(Booster {
            params: params.clone(),
            objective,
            base_score,
            trees,
            eval_history,
            build_stats,
            train_secs: t0.elapsed().as_secs_f64(),
            simulated_secs,
        })
    }

    /// Number of boosting rounds actually performed.
    pub fn n_rounds(&self) -> usize {
        self.trees.first().map(|t| t.len()).unwrap_or(0)
    }

    /// Raw margins for a feature matrix.
    pub fn predict_margins(&self, x: &crate::data::DMatrix) -> Vec<Vec<Float>> {
        predict::predict_margins(&self.trees, &self.base_score, x)
    }

    /// Transformed predictions (probability / class / value).
    pub fn predict(&self, x: &crate::data::DMatrix) -> Vec<Float> {
        self.objective.transform(&self.predict_margins(x))
    }

    /// Evaluate a named metric on a dataset.
    pub fn evaluate(&self, ds: &Dataset, metric_name: &str) -> Result<f64> {
        let metric = metric_by_name(metric_name)?;
        Ok(metric.eval(ds, &self.predict(&ds.x)))
    }
}

/// Objective-appropriate default metric (what Table 2 reports per task).
fn default_metric(objective: &dyn Objective) -> Result<Box<dyn Metric>> {
    metric_by_name(match objective.name() {
        "reg:squarederror" => "rmse",
        "binary:logistic" => "accuracy",
        "multi:softmax" => "accuracy",
        "rank:pairwise" => "ndcg",
        _ => "rmse",
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetSpec};

    fn quick_params(objective: &str, rounds: usize) -> BoosterParams {
        BoosterParams {
            objective: objective.into(),
            num_rounds: rounds,
            max_bins: 32,
            max_depth: 4,
            ..Default::default()
        }
    }

    #[test]
    fn regression_loss_decreases() {
        let g = generate(&DatasetSpec::year_prediction_like(3000), 1);
        let b = Booster::train(&quick_params("reg:squarederror", 15), &g.train, Some(&g.valid))
            .unwrap();
        let hist = &b.eval_history;
        assert!(hist.len() >= 10);
        let first = hist.first().unwrap().train;
        let last = hist.last().unwrap().train;
        assert!(last < first, "train rmse should fall: {first} -> {last}");
        // and beat the constant-prediction baseline on validation
        let base_rmse = {
            let mean: f32 = g.train.y.iter().sum::<f32>() / g.train.y.len() as f32;
            let se: f64 = g
                .valid
                .y
                .iter()
                .map(|&y| ((y - mean) as f64).powi(2))
                .sum();
            (se / g.valid.y.len() as f64).sqrt()
        };
        assert!(hist.last().unwrap().valid.unwrap() < base_rmse);
    }

    #[test]
    fn binary_classification_beats_majority() {
        let g = generate(&DatasetSpec::higgs_like(4000), 2);
        let b =
            Booster::train(&quick_params("binary:logistic", 20), &g.train, Some(&g.valid))
                .unwrap();
        let acc = b.eval_history.last().unwrap().valid.unwrap();
        let majority = {
            let pos: f64 =
                g.valid.y.iter().filter(|&&y| y == 1.0).count() as f64 / g.valid.y.len() as f64;
            100.0 * pos.max(1.0 - pos)
        };
        assert!(acc > majority + 1.0, "acc {acc} vs majority {majority}");
    }

    #[test]
    fn multiclass_trains_k_trees_per_round() {
        let g = generate(&DatasetSpec::covtype_like(3000), 3);
        let mut p = quick_params("multi:softmax", 5);
        p.num_class = 7;
        let b = Booster::train(&p, &g.train, Some(&g.valid)).unwrap();
        assert_eq!(b.trees.len(), 7);
        assert!(b.trees.iter().all(|t| t.len() == 5));
        let acc = b.eval_history.last().unwrap().valid.unwrap();
        assert!(acc > 30.0, "multiclass accuracy {acc} too low");
        // predictions are valid class ids
        let preds = b.predict(&g.valid.x);
        assert!(preds.iter().all(|&c| (0.0..7.0).contains(&c)));
    }

    #[test]
    fn ranking_improves_ndcg() {
        let g = generate(&DatasetSpec::ranking_like(2000), 4);
        let p = quick_params("rank:pairwise", 10);
        let b = Booster::train(&p, &g.train, Some(&g.valid)).unwrap();
        let first = b.eval_history.first().unwrap().train;
        let last = b.eval_history.last().unwrap().train;
        assert!(last > first, "train ndcg should rise: {first} -> {last}");
    }

    #[test]
    fn predict_matches_training_margins() {
        let g = generate(&DatasetSpec::higgs_like(2000), 5);
        let b = Booster::train(&quick_params("binary:logistic", 8), &g.train, None).unwrap();
        // re-predicting the training set via raw traversal must agree with
        // the last recorded train metric
        let acc = b.evaluate(&g.train, "accuracy").unwrap();
        let recorded = b.eval_history.last().unwrap().train;
        assert!((acc - recorded).abs() < 0.2, "{acc} vs {recorded}");
    }

    #[test]
    fn early_stopping_stops() {
        let g = generate(&DatasetSpec::higgs_like(1500), 6);
        let mut p = quick_params("binary:logistic", 200);
        p.early_stopping_rounds = 2;
        p.eta = 1.0; // aggressive -> quick overfit -> early stop
        let b = Booster::train(&p, &g.train, Some(&g.valid)).unwrap();
        assert!(b.n_rounds() < 200, "should stop early, ran {}", b.n_rounds());
    }

    #[test]
    fn multi_device_training_matches_quality() {
        let g = generate(&DatasetSpec::higgs_like(3000), 7);
        let mut p1 = quick_params("binary:logistic", 10);
        let mut p4 = quick_params("binary:logistic", 10);
        p1.n_devices = 1;
        p4.n_devices = 4;
        let b1 = Booster::train(&p1, &g.train, Some(&g.valid)).unwrap();
        let b4 = Booster::train(&p4, &g.train, Some(&g.valid)).unwrap();
        let a1 = b1.eval_history.last().unwrap().valid.unwrap();
        let a4 = b4.eval_history.last().unwrap().valid.unwrap();
        assert!((a1 - a4).abs() < 2.0, "p=1 acc {a1} vs p=4 acc {a4}");
        assert!(b4.build_stats.hist_secs.len() == 4);
        assert!(b4.simulated_secs > 0.0);
    }

    #[test]
    fn params_from_config() {
        let cfg = Config::from_str_contents(
            "objective = binary:logistic\nnum_rounds = 7\neta = 0.1\ncompress = false\n",
        )
        .unwrap();
        let p = BoosterParams::from_config(&cfg).unwrap();
        assert_eq!(p.objective, "binary:logistic");
        assert_eq!(p.num_rounds, 7);
        assert_eq!(p.eta, 0.1);
        assert!(!p.compress);
    }

    #[test]
    fn subsample_trains_and_differs() {
        let g = generate(&DatasetSpec::higgs_like(3000), 10);
        let full = quick_params("binary:logistic", 8);
        let mut sub = quick_params("binary:logistic", 8);
        sub.subsample = 0.5;
        let bf = Booster::train(&full, &g.train, Some(&g.valid)).unwrap();
        let bs = Booster::train(&sub, &g.train, Some(&g.valid)).unwrap();
        assert_ne!(bf.trees[0], bs.trees[0], "subsample must change trees");
        let af = bf.eval_history.last().unwrap().valid.unwrap();
        let asub = bs.eval_history.last().unwrap().valid.unwrap();
        assert!(asub > 60.0, "subsampled model still learns: {asub} vs full {af}");
    }

    #[test]
    fn monotone_constraint_enforced() {
        use crate::data::{DMatrix, Dataset};
        // y rises with f0 on average but with local dips that an
        // unconstrained model would fit
        let n = 4000;
        let mut rng = crate::util::Pcg64::new(77);
        let mut vals = vec![0.0 as Float; n * 3];
        let mut y = vec![0.0 as Float; n];
        for r in 0..n {
            let x0 = rng.next_f32() * 10.0;
            let x1 = rng.next_f32();
            let x2 = rng.next_f32();
            vals[r * 3] = x0;
            vals[r * 3 + 1] = x1;
            vals[r * 3 + 2] = x2;
            y[r] = x0 + 2.0 * (x0 * 2.0).sin() + x1 + (rng.next_f32() - 0.5);
        }
        let ds = Dataset::new(DMatrix::dense(vals, n, 3), y);
        let mut p = quick_params("reg:squarederror", 20);
        p.monotone_constraints = "1,0,0".into();
        p.eta = 0.3;
        let b = Booster::train(&p, &ds, None).unwrap();

        // probe: prediction must be non-decreasing along f0 for any fixed
        // (f1, f2)
        for probe in 0..5 {
            let f1 = probe as f32 * 0.2;
            let f2 = 1.0 - f1;
            let grid: Vec<Float> = (0..100)
                .flat_map(|i| [i as f32 * 0.1, f1, f2])
                .collect();
            let gx = DMatrix::dense(grid, 100, 3);
            let preds = b.predict(&gx);
            for w in preds.windows(2) {
                assert!(
                    w[1] >= w[0] - 1e-5,
                    "prediction must be monotone in f0: {} -> {}",
                    w[0],
                    w[1]
                );
            }
        }

        // unconstrained control: the sin dips should break monotonicity
        let pu = quick_params("reg:squarederror", 20);
        let bu = Booster::train(&pu, &ds, None).unwrap();
        let grid: Vec<Float> = (0..100).flat_map(|i| [i as f32 * 0.1, 0.5, 0.5]).collect();
        let preds = bu.predict(&DMatrix::dense(grid, 100, 3));
        assert!(
            preds.windows(2).any(|w| w[1] < w[0] - 1e-4),
            "unconstrained model should show non-monotone structure"
        );
    }

    #[test]
    fn monotone_parse_errors() {
        let mut p = quick_params("reg:squarederror", 1);
        p.monotone_constraints = "2,0".into();
        assert!(p.coordinator_params().is_err());
        p.monotone_constraints = "abc".into();
        assert!(p.coordinator_params().is_err());
        p.monotone_constraints = "(1, -1, 0)".into();
        assert!(p.coordinator_params().is_ok());
    }

    #[test]
    fn colsample_restricts_features_used() {
        let g = generate(&DatasetSpec::higgs_like(3000), 12);
        let mut p = quick_params("binary:logistic", 6);
        p.colsample_bytree = 0.25;
        let b = Booster::train(&p, &g.train, Some(&g.valid)).unwrap();
        // each individual tree touches at most ceil(0.25 * 28) = 7 features
        for t in &b.trees[0] {
            let mut feats: Vec<u32> = t
                .nodes
                .iter()
                .filter(|n| !n.is_leaf())
                .map(|n| n.feature)
                .collect();
            feats.sort_unstable();
            feats.dedup();
            assert!(feats.len() <= 7, "tree used {} features", feats.len());
        }
        // trees draw different subsets across rounds
        let first_feats: Vec<Vec<u32>> = b.trees[0]
            .iter()
            .map(|t| {
                let mut f: Vec<u32> = t
                    .nodes
                    .iter()
                    .filter(|n| !n.is_leaf())
                    .map(|n| n.feature)
                    .collect();
                f.sort_unstable();
                f.dedup();
                f
            })
            .collect();
        assert!(
            first_feats.windows(2).any(|w| w[0] != w[1]),
            "column samples should vary across trees"
        );
        // and the model still learns
        let acc = b.eval_history.last().unwrap().valid.unwrap();
        assert!(acc > 60.0, "colsampled accuracy {acc}");
    }

    #[test]
    fn lossguide_policy_trains() {
        let g = generate(&DatasetSpec::higgs_like(2000), 8);
        let mut p = quick_params("binary:logistic", 8);
        p.grow_policy = "lossguide".into();
        p.max_depth = 0;
        p.max_leaves = 16;
        let b = Booster::train(&p, &g.train, Some(&g.valid)).unwrap();
        assert!(b.trees[0].iter().all(|t| t.n_leaves() <= 16));
        let acc = b.eval_history.last().unwrap().valid.unwrap();
        assert!(acc > 55.0);
    }
}
