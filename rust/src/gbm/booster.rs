//! The trained ensemble ([`Booster`]) and the legacy stringly-typed
//! parameter surface ([`BoosterParams`]).
//!
//! The Figure-1 training loop lives in [`crate::gbm::learner`] behind the
//! typed [`Learner`](crate::gbm::learner::Learner) façade;
//! [`Booster::train`] remains as a deprecated shim that parses the old
//! string fields into [`LearnerParams`] and delegates.

use anyhow::{Context, Result};

use crate::coordinator::{BuildStats, CoordinatorParams, HistBackend, NativeBackend};
use crate::data::Dataset;
use crate::gbm::learner::Learner;
use crate::gbm::objective::Objective;
use crate::gbm::params::LearnerParams;
use crate::gbm::registry::ObjectiveRegistry;
use crate::predict;
use crate::tree::RegTree;
use crate::util::Config;
use crate::Float;

/// Legacy stringly-typed booster hyperparameters (XGBoost-style names).
///
/// Superseded by the typed [`LearnerParams`]: the `objective`,
/// `grow_policy`, `allreduce`, `eval_metric` and `monotone_constraints`
/// strings here are parsed (and can fail) only when training starts,
/// whereas [`Learner::builder`](crate::gbm::learner::Learner::builder)
/// validates everything up front. Kept so existing call sites and config
/// pipelines continue to work; convert with
/// [`BoosterParams::to_learner_params`].
#[derive(Debug, Clone)]
pub struct BoosterParams {
    pub objective: String,
    pub num_class: usize,
    pub num_rounds: usize,
    pub eta: f64,
    pub max_depth: usize,
    pub max_leaves: usize,
    pub max_bins: usize,
    pub lambda: f64,
    pub gamma: f64,
    pub alpha: f64,
    pub min_child_weight: f64,
    /// "depthwise" or "lossguide" (§2.3).
    pub grow_policy: String,
    /// Simulated device count (the paper's GPUs).
    pub n_devices: usize,
    /// Bit-packed shard storage (§2.2).
    pub compress: bool,
    /// "ring" or "serial" histogram all-reduce.
    pub allreduce: String,
    /// Evaluation metric name; empty = objective's default.
    pub eval_metric: String,
    /// Evaluate every k rounds (0 = only at the end).
    pub eval_every: usize,
    /// Stop if the validation metric hasn't improved in this many
    /// evaluations (0 = never).
    pub early_stopping_rounds: usize,
    /// Row subsampling rate per tree (1.0 = off).
    pub subsample: f64,
    /// Column sampling rate per tree (1.0 = off).
    pub colsample_bytree: f64,
    /// Per-feature monotone constraints, e.g. `"1,0,-1"` or `"(1,0,-1)"`;
    /// empty = none. Shorter lists imply 0 for remaining features.
    pub monotone_constraints: String,
    /// Seed for subsampling.
    pub seed: u64,
    /// Print eval lines to stderr.
    pub verbose: bool,
    /// Worker threads (`0` = all cores, `1` = serial); wall-clock only,
    /// results are bit-identical.
    pub threads: usize,
    /// Rows per batch for streaming ingestion (peak-memory knob; results
    /// are bit-identical for every value).
    pub batch_rows: usize,
    /// External-memory budget: resident packed pages per shard (0 = fully
    /// resident). Results are bit-identical for every value.
    pub max_resident_pages: usize,
    /// Rows per spilled page (external-memory page size).
    pub page_rows: usize,
}

impl Default for BoosterParams {
    fn default() -> Self {
        let d = LearnerParams::default();
        BoosterParams {
            objective: d.objective.to_string(),
            num_class: d.num_class,
            num_rounds: d.num_rounds,
            eta: d.eta,
            max_depth: d.max_depth,
            max_leaves: d.max_leaves,
            max_bins: d.max_bins,
            lambda: d.lambda,
            gamma: d.gamma,
            alpha: d.alpha,
            min_child_weight: d.min_child_weight,
            grow_policy: d.grow_policy.to_string(),
            n_devices: d.n_devices,
            compress: d.compress,
            allreduce: d.allreduce.to_string(),
            eval_metric: String::new(),
            eval_every: d.eval_every,
            early_stopping_rounds: d.early_stopping_rounds,
            subsample: d.subsample,
            colsample_bytree: d.colsample_bytree,
            monotone_constraints: String::new(),
            seed: d.seed,
            verbose: d.verbose,
            threads: d.threads,
            batch_rows: d.batch_rows,
            max_resident_pages: d.max_resident_pages,
            page_rows: d.page_rows,
        }
    }
}

impl BoosterParams {
    /// Read parameters from a [`Config`] (defaults for absent keys).
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let typed = LearnerParams::from_config(cfg)?;
        Ok(Self::from_learner_params(&typed))
    }

    /// Render typed params back to the legacy string form.
    pub fn from_learner_params(p: &LearnerParams) -> Self {
        BoosterParams {
            objective: p.objective.to_string(),
            num_class: p.num_class,
            num_rounds: p.num_rounds,
            eta: p.eta,
            max_depth: p.max_depth,
            max_leaves: p.max_leaves,
            max_bins: p.max_bins,
            lambda: p.lambda,
            gamma: p.gamma,
            alpha: p.alpha,
            min_child_weight: p.min_child_weight,
            grow_policy: p.grow_policy.to_string(),
            n_devices: p.n_devices,
            compress: p.compress,
            allreduce: p.allreduce.to_string(),
            eval_metric: p
                .eval_metric
                .as_ref()
                .map(|m| m.to_string())
                .unwrap_or_default(),
            eval_every: p.eval_every,
            early_stopping_rounds: p.early_stopping_rounds,
            subsample: p.subsample,
            colsample_bytree: p.colsample_bytree,
            monotone_constraints: p.monotone_constraints.to_string(),
            seed: p.seed,
            verbose: p.verbose,
            threads: p.threads,
            batch_rows: p.batch_rows,
            max_resident_pages: p.max_resident_pages,
            page_rows: p.page_rows,
        }
    }

    /// Parse the five string fields into the typed [`LearnerParams`].
    /// Fails on malformed text (`grow_policy = "sideways"`, monotone signs
    /// outside −1..=1, ...); name-level resolution of the objective/metric
    /// happens in [`LearnerParams::validate`].
    pub fn to_learner_params(&self) -> Result<LearnerParams> {
        Ok(LearnerParams {
            objective: self.objective.parse().expect("infallible"),
            num_class: self.num_class,
            num_rounds: self.num_rounds,
            eta: self.eta,
            max_depth: self.max_depth,
            max_leaves: self.max_leaves,
            max_bins: self.max_bins,
            lambda: self.lambda,
            gamma: self.gamma,
            alpha: self.alpha,
            min_child_weight: self.min_child_weight,
            grow_policy: self
                .grow_policy
                .parse()
                .map_err(|e: String| anyhow::anyhow!(e))?,
            n_devices: self.n_devices,
            compress: self.compress,
            allreduce: self
                .allreduce
                .parse()
                .map_err(|e: String| anyhow::anyhow!(e))?,
            eval_metric: if self.eval_metric.is_empty() {
                None
            } else {
                Some(self.eval_metric.parse().expect("infallible"))
            },
            eval_every: self.eval_every,
            early_stopping_rounds: self.early_stopping_rounds,
            subsample: self.subsample,
            colsample_bytree: self.colsample_bytree,
            monotone_constraints: self
                .monotone_constraints
                .parse()
                .map_err(|e: String| anyhow::anyhow!(e))
                .context("monotone_constraints")?,
            seed: self.seed,
            verbose: self.verbose,
            threads: self.threads,
            batch_rows: self.batch_rows,
            max_resident_pages: self.max_resident_pages,
            page_rows: self.page_rows,
            // scenario-shaping knobs (quantile α, tweedie ρ, AFT
            // distribution/σ, categorical flags) have no legacy string
            // field — the typed surface is the only way to set them
            ..LearnerParams::default()
        })
    }

    /// Derive the coordinator configuration (legacy path; parses the
    /// string fields first).
    pub fn coordinator_params(&self) -> Result<CoordinatorParams> {
        Ok(self.to_learner_params()?.coordinator_params())
    }
}

/// One evaluation-history entry.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub round: usize,
    pub metric: &'static str,
    pub train: f64,
    pub valid: Option<f64>,
    pub elapsed_secs: f64,
}

/// A trained gradient-boosted ensemble.
pub struct Booster {
    /// The (typed) configuration the ensemble was trained with.
    pub params: LearnerParams,
    pub(crate) objective: Box<dyn Objective>,
    pub base_score: Vec<Float>,
    /// `trees[output][round]`.
    pub trees: Vec<Vec<RegTree>>,
    /// The frozen quantisation cuts the model was trained against.
    /// Present on every `Learner`-trained booster (and on models saved
    /// by this version and reloaded); required by the compressed
    /// prediction paths ([`predict_from_source`](Self::predict_from_source)
    /// and the CLI's `--stream` / `--max-resident-pages` inference).
    /// `None` only for hand-assembled ensembles
    /// ([`from_parts`](Self::from_parts)) and models saved before cuts
    /// were persisted — those predict through the float path only.
    pub cuts: Option<crate::quantile::HistogramCuts>,
    pub eval_history: Vec<EvalRecord>,
    /// Accumulated coordinator statistics over all trees.
    pub build_stats: BuildStats,
    /// Measured wall-clock of `train` (this process).
    pub train_secs: f64,
    /// Simulated multi-device clock (DESIGN.md §5) over all rounds.
    pub simulated_secs: f64,
}

impl Booster {
    /// Assemble a booster from pre-built trees (used by the baseline
    /// trainers in [`crate::baselines`] and the model loader so
    /// prediction/metric code is shared).
    pub fn from_parts(
        params: LearnerParams,
        base_score: Vec<Float>,
        trees: Vec<Vec<RegTree>>,
        train_secs: f64,
    ) -> Result<Booster> {
        let objective =
            ObjectiveRegistry::create_with(params.objective.name(), &params.objective_params())?;
        anyhow::ensure!(trees.len() == objective.n_outputs(), "tree groups != outputs");
        Ok(Booster {
            params,
            objective,
            base_score,
            trees,
            cuts: None,
            eval_history: Vec::new(),
            build_stats: BuildStats::default(),
            train_secs,
            simulated_secs: 0.0,
        })
    }

    /// Train with the native histogram backend.
    #[deprecated(
        since = "0.2.0",
        note = "use `gbm::Learner::builder()` / `Learner::train` — typed params, \
                up-front validation, pluggable objectives and callbacks"
    )]
    pub fn train(
        params: &BoosterParams,
        train: &Dataset,
        valid: Option<&Dataset>,
    ) -> Result<Booster> {
        #[allow(deprecated)]
        Self::train_with_backend(params, train, valid, Box::new(NativeBackend::default()))
    }

    /// Train with an explicit histogram backend (e.g. the XLA runtime).
    #[deprecated(
        since = "0.2.0",
        note = "use `gbm::Learner::builder()` / `Learner::train_with_backend`"
    )]
    pub fn train_with_backend(
        params: &BoosterParams,
        train: &Dataset,
        valid: Option<&Dataset>,
        backend: Box<dyn HistBackend>,
    ) -> Result<Booster> {
        let typed = params.to_learner_params()?;
        let mut learner = Learner::from_params(typed)?;
        learner.train_with_backend(train, valid, backend)
    }

    /// Number of boosting rounds actually performed.
    pub fn n_rounds(&self) -> usize {
        self.trees.first().map(|t| t.len()).unwrap_or(0)
    }

    /// Raw margins for a feature matrix (batch prediction runs
    /// chunk-parallel under the model's `threads` budget; see §2.4).
    pub fn predict_margins(&self, x: &crate::data::DMatrix) -> Vec<Vec<Float>> {
        let exec = crate::exec::ExecContext::new(self.params.threads);
        predict::predict_margins_par(&self.trees, &self.base_score, x, &exec)
    }

    /// Transformed predictions (probability / class / value).
    pub fn predict(&self, x: &crate::data::DMatrix) -> Vec<Float> {
        self.objective.transform(&self.predict_margins(x))
    }

    /// Evaluate a named metric on a dataset (registry-resolved, so custom
    /// metrics work here too). Bare parametrised names (`pinball`,
    /// `tweedie-nloglik`, `aft-nloglik`) shape themselves from this
    /// model's objective parameters; an explicit `@arg` still wins.
    pub fn evaluate(&self, ds: &Dataset, metric_name: &str) -> Result<f64> {
        let metric = self.resolve_metric(metric_name)?;
        Ok(metric.eval(ds, &self.predict(&ds.x)))
    }

    /// Registry lookup shaped by this model's objective parameters.
    fn resolve_metric(&self, name: &str) -> Result<Box<dyn crate::gbm::metric::Metric>> {
        crate::gbm::registry::MetricRegistry::create_for(name, &self.params.objective_params())
    }

    /// Name of the objective's default evaluation metric (what `evaluate`
    /// should use when the caller doesn't pick one — the CLI `eval`
    /// subcommand's default).
    pub fn default_metric(&self) -> &'static str {
        self.objective.default_metric()
    }

    /// Leaf indices of every row for every tree, group-major (the
    /// `pred_leaf` output), chunk-parallel under the model's `threads`
    /// budget — bit-identical at every thread count.
    pub fn predict_leaf_indices(&self, x: &crate::data::DMatrix) -> Vec<Vec<u32>> {
        let exec = crate::exec::ExecContext::new(self.params.threads);
        let mut out = Vec::new();
        for group in &self.trees {
            out.extend(predict::predict_leaf_indices_par(group, x, &exec));
        }
        out
    }

    /// Feature-less evaluation substrate for the compressed eval paths:
    /// labels (and optional ranking groups) over an empty CSR matrix —
    /// metrics only read `y`/`groups`.
    fn labels_dataset(n_cols: usize, labels: Vec<Float>, groups: Vec<usize>) -> Dataset {
        let n = labels.len();
        let x = crate::data::DMatrix::csr(vec![0usize; n + 1], Vec::new(), Vec::new(), n, n_cols);
        if groups.is_empty() {
            Dataset::new(x, labels)
        } else {
            Dataset::with_groups(x, labels, groups)
        }
    }

    /// The frozen cuts, or a fail-fast error for legacy models.
    ///
    /// Serving (`crate::serve`) and every quantised prediction/eval path
    /// require the cuts section; a model loaded with `cuts: None` (a
    /// hand-assembled ensemble, or a file saved before the format's
    /// `cuts` section existed) must error here — clearly, and naming the
    /// fix — rather than panic later or silently fall back to float
    /// traversal with a different fingerprint.
    pub fn require_cuts(&self) -> Result<&crate::quantile::HistogramCuts> {
        self.cuts.as_ref().context(
            "model carries no quantisation cuts (`cuts: None`: a hand-assembled \
             ensemble, or a model file saved before the `cuts` section was added to \
             the format) — serving and quantised prediction/eval need the frozen \
             cuts. Fix: retrain through gbm::Learner (or `xgb-tpu train`) and \
             re-save with save_model_file / --model-out, which persists the cuts; \
             float-matrix `predict` remains available for legacy files",
        )
    }

    /// The frozen cuts, or an error explaining why compressed prediction
    /// is unavailable for this model.
    fn cuts_for_prediction(&self) -> Result<&crate::quantile::HistogramCuts> {
        self.require_cuts()
    }

    /// **Streaming quantised prediction**: one pass over a
    /// [`BatchSource`], quantising each batch against the model's frozen
    /// cuts and scoring it batch-at-a-time from the bin-translated trees
    /// — O(`batch_rows × n_cols`) transient bytes, never the full
    /// matrix. Returns the transformed predictions plus the
    /// [`StreamedMargins`](crate::predict::quantised::StreamedMargins)
    /// carrying labels/groups and the measured transient peak.
    /// Predictions are **bit-identical** to [`predict`](Self::predict)
    /// over the equivalent in-memory matrix for every batch size and
    /// thread count (`rust/tests/compressed_predict.rs`).
    pub fn predict_stream(
        &self,
        src: &mut dyn crate::data::BatchSource,
    ) -> Result<(Vec<Float>, crate::predict::quantised::StreamedMargins)> {
        let cuts = self.cuts_for_prediction()?;
        let exec = crate::exec::ExecContext::new(self.params.threads);
        let sm = crate::predict::quantised::stream_margins(
            &self.trees,
            &self.base_score,
            cuts,
            src,
            &exec,
        )?;
        let preds = self.objective.transform(&sm.margins);
        Ok((preds, sm))
    }

    /// Transformed predictions straight from a streaming source (see
    /// [`predict_stream`](Self::predict_stream)).
    pub fn predict_from_source(
        &self,
        src: &mut dyn crate::data::BatchSource,
    ) -> Result<Vec<Float>> {
        Ok(self.predict_stream(src)?.0)
    }

    /// **External-memory prediction**: quantise + bit-pack the streamed
    /// source against the model's frozen cuts straight into a spilled
    /// page file, then traverse the pages under the
    /// `max_resident_pages` budget (same prefetch pipeline as paged
    /// training). Peak memory is O(`batch_rows × n_cols`) transient plus
    /// `max_resident_pages × page_bytes` resident — inference is no
    /// longer capped by host RAM. Returns the transformed predictions
    /// and the packed input (labels/groups + the page store, whose
    /// round stats report pages loaded and the measured residency peak;
    /// its spill file is deleted on drop).
    pub fn predict_paged(
        &self,
        src: &mut dyn crate::data::BatchSource,
        page_rows: usize,
        max_resident_pages: usize,
    ) -> Result<(Vec<Float>, crate::predict::quantised::PackedPrediction)> {
        use crate::predict::quantised as q;
        let cuts = self.cuts_for_prediction()?;
        let packed = q::pack_source(src, cuts, page_rows, max_resident_pages)?;
        let exec = crate::exec::ExecContext::new(self.params.threads);
        let forest = q::BinForest::from_trees(&self.trees, cuts);
        let margins =
            q::predict_margins_paged(&forest, &self.base_score, &packed.store, cuts, &exec)?;
        Ok((self.objective.transform(&margins), packed))
    }

    /// Evaluate a named metric through the external-memory prediction
    /// path (see [`predict_paged`](Self::predict_paged)). Returns
    /// `(metric value, clamped sparse values)` — a non-zero second
    /// element means out-of-range/NaN sparse values clamped during
    /// packing and the value may differ from the float evaluation
    /// (callers should surface it; the CLI warns).
    pub fn evaluate_paged(
        &self,
        src: &mut dyn crate::data::BatchSource,
        metric_name: &str,
        page_rows: usize,
        max_resident_pages: usize,
    ) -> Result<(f64, u64)> {
        let n_cols = self.cuts_for_prediction()?.n_features();
        let (preds, packed) = self.predict_paged(src, page_rows, max_resident_pages)?;
        let metric = self.resolve_metric(metric_name)?;
        let clamped = packed.clamped_values;
        let ds = Self::labels_dataset(n_cols, packed.labels, packed.groups);
        Ok((metric.eval(&ds, &preds), clamped))
    }

    /// Evaluate a named metric over a streaming source in the same single
    /// pass that predicts it: labels (and qid-derived ranking groups)
    /// ride the stream, so no float matrix — and no second pass — is
    /// ever needed. Bit-identical to [`evaluate`](Self::evaluate) on the
    /// equivalent in-memory dataset.
    pub fn evaluate_from_source(
        &self,
        src: &mut dyn crate::data::BatchSource,
        metric_name: &str,
    ) -> Result<f64> {
        let n_cols = self.cuts_for_prediction()?.n_features();
        let (preds, sm) = self.predict_stream(src)?;
        let metric = self.resolve_metric(metric_name)?;
        let ds = Self::labels_dataset(n_cols, sm.labels, sm.groups);
        Ok(metric.eval(&ds, &preds))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetSpec};
    use crate::gbm::params::{GrowPolicy, MetricKind, ObjectiveKind};

    fn quick_params(objective: ObjectiveKind, rounds: usize) -> LearnerParams {
        LearnerParams {
            objective,
            num_rounds: rounds,
            max_bins: 32,
            max_depth: 4,
            ..Default::default()
        }
    }

    fn train(params: LearnerParams, train: &Dataset, valid: Option<&Dataset>) -> Booster {
        Learner::from_params(params)
            .unwrap()
            .train(train, valid)
            .unwrap()
    }

    #[test]
    fn regression_loss_decreases() {
        let g = generate(&DatasetSpec::year_prediction_like(3000), 1);
        let b = train(
            quick_params(ObjectiveKind::SquaredError, 15),
            &g.train,
            Some(&g.valid),
        );
        let hist = &b.eval_history;
        assert!(hist.len() >= 10);
        let first = hist.first().unwrap().train;
        let last = hist.last().unwrap().train;
        assert!(last < first, "train rmse should fall: {first} -> {last}");
        // and beat the constant-prediction baseline on validation
        let base_rmse = {
            let mean: f32 = g.train.y.iter().sum::<f32>() / g.train.y.len() as f32;
            let se: f64 = g
                .valid
                .y
                .iter()
                .map(|&y| ((y - mean) as f64).powi(2))
                .sum();
            (se / g.valid.y.len() as f64).sqrt()
        };
        assert!(hist.last().unwrap().valid.unwrap() < base_rmse);
    }

    #[test]
    fn binary_classification_beats_majority() {
        let g = generate(&DatasetSpec::higgs_like(4000), 2);
        let b = train(
            quick_params(ObjectiveKind::BinaryLogistic, 20),
            &g.train,
            Some(&g.valid),
        );
        let acc = b.eval_history.last().unwrap().valid.unwrap();
        let majority = {
            let pos: f64 =
                g.valid.y.iter().filter(|&&y| y == 1.0).count() as f64 / g.valid.y.len() as f64;
            100.0 * pos.max(1.0 - pos)
        };
        assert!(acc > majority + 1.0, "acc {acc} vs majority {majority}");
    }

    #[test]
    fn multiclass_trains_k_trees_per_round() {
        let g = generate(&DatasetSpec::covtype_like(3000), 3);
        let mut p = quick_params(ObjectiveKind::MultiSoftmax, 5);
        p.num_class = 7;
        let b = train(p, &g.train, Some(&g.valid));
        assert_eq!(b.trees.len(), 7);
        assert!(b.trees.iter().all(|t| t.len() == 5));
        let acc = b.eval_history.last().unwrap().valid.unwrap();
        assert!(acc > 30.0, "multiclass accuracy {acc} too low");
        // predictions are valid class ids
        let preds = b.predict(&g.valid.x);
        assert!(preds.iter().all(|&c| (0.0..7.0).contains(&c)));
    }

    #[test]
    fn ranking_improves_ndcg() {
        let g = generate(&DatasetSpec::ranking_like(2000), 4);
        let b = train(
            quick_params(ObjectiveKind::RankPairwise, 10),
            &g.train,
            Some(&g.valid),
        );
        let first = b.eval_history.first().unwrap().train;
        let last = b.eval_history.last().unwrap().train;
        assert!(last > first, "train ndcg should rise: {first} -> {last}");
    }

    #[test]
    fn predict_matches_training_margins() {
        let g = generate(&DatasetSpec::higgs_like(2000), 5);
        let b = train(quick_params(ObjectiveKind::BinaryLogistic, 8), &g.train, None);
        // re-predicting the training set via raw traversal must agree with
        // the last recorded train metric
        let acc = b.evaluate(&g.train, "accuracy").unwrap();
        let recorded = b.eval_history.last().unwrap().train;
        assert!((acc - recorded).abs() < 0.2, "{acc} vs {recorded}");
    }

    #[test]
    fn early_stopping_stops() {
        let g = generate(&DatasetSpec::higgs_like(1500), 6);
        let mut p = quick_params(ObjectiveKind::BinaryLogistic, 200);
        p.early_stopping_rounds = 2;
        p.eta = 1.0; // aggressive -> quick overfit -> early stop
        let b = train(p, &g.train, Some(&g.valid));
        assert!(b.n_rounds() < 200, "should stop early, ran {}", b.n_rounds());
    }

    #[test]
    fn multi_device_training_matches_quality() {
        let g = generate(&DatasetSpec::higgs_like(3000), 7);
        let mut p1 = quick_params(ObjectiveKind::BinaryLogistic, 10);
        let mut p4 = quick_params(ObjectiveKind::BinaryLogistic, 10);
        p1.n_devices = 1;
        p4.n_devices = 4;
        let b1 = train(p1, &g.train, Some(&g.valid));
        let b4 = train(p4, &g.train, Some(&g.valid));
        let a1 = b1.eval_history.last().unwrap().valid.unwrap();
        let a4 = b4.eval_history.last().unwrap().valid.unwrap();
        assert!((a1 - a4).abs() < 2.0, "p=1 acc {a1} vs p=4 acc {a4}");
        assert!(b4.build_stats.hist_secs.len() == 4);
        assert!(b4.simulated_secs > 0.0);
    }

    #[test]
    fn params_from_config_legacy_surface() {
        let cfg = Config::from_str_contents(
            "objective = binary:logistic\nnum_rounds = 7\neta = 0.1\ncompress = false\n",
        )
        .unwrap();
        let p = BoosterParams::from_config(&cfg).unwrap();
        assert_eq!(p.objective, "binary:logistic");
        assert_eq!(p.num_rounds, 7);
        assert_eq!(p.eta, 0.1);
        assert!(!p.compress);
        // and the typed conversion round-trips the strings
        let typed = p.to_learner_params().unwrap();
        assert_eq!(typed.objective, ObjectiveKind::BinaryLogistic);
        assert_eq!(BoosterParams::from_learner_params(&typed).objective, p.objective);
    }

    #[test]
    fn deprecated_shim_still_trains() {
        let g = generate(&DatasetSpec::higgs_like(1200), 17);
        let p = BoosterParams {
            objective: "binary:logistic".into(),
            num_rounds: 4,
            max_bins: 16,
            max_depth: 3,
            ..Default::default()
        };
        #[allow(deprecated)]
        let b = Booster::train(&p, &g.train, Some(&g.valid)).unwrap();
        assert_eq!(b.n_rounds(), 4);
        assert_eq!(b.params.objective, ObjectiveKind::BinaryLogistic);
    }

    #[test]
    fn subsample_trains_and_differs() {
        let g = generate(&DatasetSpec::higgs_like(3000), 10);
        let full = quick_params(ObjectiveKind::BinaryLogistic, 8);
        let mut sub = quick_params(ObjectiveKind::BinaryLogistic, 8);
        sub.subsample = 0.5;
        let bf = train(full, &g.train, Some(&g.valid));
        let bs = train(sub, &g.train, Some(&g.valid));
        assert_ne!(bf.trees[0], bs.trees[0], "subsample must change trees");
        let af = bf.eval_history.last().unwrap().valid.unwrap();
        let asub = bs.eval_history.last().unwrap().valid.unwrap();
        assert!(asub > 60.0, "subsampled model still learns: {asub} vs full {af}");
    }

    #[test]
    fn monotone_constraint_enforced() {
        use crate::data::{DMatrix, Dataset};
        // y rises with f0 on average but with local dips that an
        // unconstrained model would fit
        let n = 4000;
        let mut rng = crate::util::Pcg64::new(77);
        let mut vals = vec![0.0 as Float; n * 3];
        let mut y = vec![0.0 as Float; n];
        for r in 0..n {
            let x0 = rng.next_f32() * 10.0;
            let x1 = rng.next_f32();
            let x2 = rng.next_f32();
            vals[r * 3] = x0;
            vals[r * 3 + 1] = x1;
            vals[r * 3 + 2] = x2;
            y[r] = x0 + 2.0 * (x0 * 2.0).sin() + x1 + (rng.next_f32() - 0.5);
        }
        let ds = Dataset::new(DMatrix::dense(vals, n, 3), y);
        let mut p = quick_params(ObjectiveKind::SquaredError, 20);
        p.monotone_constraints = "1,0,0".parse().unwrap();
        p.eta = 0.3;
        let b = train(p, &ds, None);

        // probe: prediction must be non-decreasing along f0 for any fixed
        // (f1, f2)
        for probe in 0..5 {
            let f1 = probe as f32 * 0.2;
            let f2 = 1.0 - f1;
            let grid: Vec<Float> = (0..100)
                .flat_map(|i| [i as f32 * 0.1, f1, f2])
                .collect();
            let gx = DMatrix::dense(grid, 100, 3);
            let preds = b.predict(&gx);
            for w in preds.windows(2) {
                assert!(
                    w[1] >= w[0] - 1e-5,
                    "prediction must be monotone in f0: {} -> {}",
                    w[0],
                    w[1]
                );
            }
        }

        // unconstrained control: the sin dips should break monotonicity
        let pu = quick_params(ObjectiveKind::SquaredError, 20);
        let bu = train(pu, &ds, None);
        let grid: Vec<Float> = (0..100).flat_map(|i| [i as f32 * 0.1, 0.5, 0.5]).collect();
        let preds = bu.predict(&DMatrix::dense(grid, 100, 3));
        assert!(
            preds.windows(2).any(|w| w[1] < w[0] - 1e-4),
            "unconstrained model should show non-monotone structure"
        );
    }

    #[test]
    fn monotone_parse_errors() {
        let mut p = BoosterParams {
            monotone_constraints: "2,0".into(),
            ..Default::default()
        };
        assert!(p.coordinator_params().is_err());
        p.monotone_constraints = "abc".into();
        assert!(p.coordinator_params().is_err());
        p.monotone_constraints = "(1, -1, 0)".into();
        assert!(p.coordinator_params().is_ok());
    }

    #[test]
    fn colsample_restricts_features_used() {
        let g = generate(&DatasetSpec::higgs_like(3000), 12);
        let mut p = quick_params(ObjectiveKind::BinaryLogistic, 6);
        p.colsample_bytree = 0.25;
        let b = train(p, &g.train, Some(&g.valid));
        // each individual tree touches at most ceil(0.25 * 28) = 7 features
        for t in &b.trees[0] {
            let mut feats: Vec<u32> = t
                .nodes
                .iter()
                .filter(|n| !n.is_leaf())
                .map(|n| n.feature)
                .collect();
            feats.sort_unstable();
            feats.dedup();
            assert!(feats.len() <= 7, "tree used {} features", feats.len());
        }
        // trees draw different subsets across rounds
        let first_feats: Vec<Vec<u32>> = b.trees[0]
            .iter()
            .map(|t| {
                let mut f: Vec<u32> = t
                    .nodes
                    .iter()
                    .filter(|n| !n.is_leaf())
                    .map(|n| n.feature)
                    .collect();
                f.sort_unstable();
                f.dedup();
                f
            })
            .collect();
        assert!(
            first_feats.windows(2).any(|w| w[0] != w[1]),
            "column samples should vary across trees"
        );
        // and the model still learns
        let acc = b.eval_history.last().unwrap().valid.unwrap();
        assert!(acc > 60.0, "colsampled accuracy {acc}");
    }

    #[test]
    fn lossguide_policy_trains() {
        let g = generate(&DatasetSpec::higgs_like(2000), 8);
        let mut p = quick_params(ObjectiveKind::BinaryLogistic, 8);
        p.grow_policy = GrowPolicy::LossGuide;
        p.max_depth = 0;
        p.max_leaves = 16;
        let b = train(p, &g.train, Some(&g.valid));
        assert!(b.trees[0].iter().all(|t| t.n_leaves() <= 16));
        let acc = b.eval_history.last().unwrap().valid.unwrap();
        assert!(acc > 55.0);
    }

    #[test]
    fn explicit_eval_metric_is_used() {
        let g = generate(&DatasetSpec::higgs_like(1200), 19);
        let mut p = quick_params(ObjectiveKind::BinaryLogistic, 4);
        p.eval_metric = Some(MetricKind::Auc);
        let b = train(p, &g.train, Some(&g.valid));
        assert_eq!(b.eval_history.last().unwrap().metric, "auc");
    }
}
