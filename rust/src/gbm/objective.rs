//! Training objectives: first/second-order gradient computation per
//! boosting iteration (paper §2.5, equations 1–2).
//!
//! The paper computes logistic and linear-regression gradients on device
//! (each thread one instance) and leaves multiclass/ranking on the CPU;
//! mirroring that, [`Objective::supports_device`] marks which objectives
//! the AOT-compiled XLA gradient artifact covers
//! (`python/compile/model.py::{logistic,squared}_gradients`) — the others
//! always run in Rust.

use crate::data::Dataset;
use crate::exec::{ExecContext, ROW_CHUNK};
use crate::{Float, GradPair};

/// Shape `out` as `k` gradient vectors of length `n` without dropping
/// capacity — the round-arena idiom for the out-param gradient path:
/// steady-state boosting rounds rewrite the same buffers in place.
fn prepare_out(out: &mut Vec<Vec<GradPair>>, k: usize, n: usize) {
    out.truncate(k);
    while out.len() < k {
        out.push(Vec::new());
    }
    for v in out.iter_mut() {
        v.clear();
        v.resize(n, GradPair::default());
    }
}

/// Chunk a single-output row-wise gradient map across the pool, writing
/// into the reusable out-param. Each row's pair is computed independently
/// and chunks concatenate in index order, so the result is bit-identical
/// to the serial map.
fn rowwise_par_into<F>(n: usize, exec: &ExecContext, out: &mut Vec<Vec<GradPair>>, f: F)
where
    F: Fn(usize) -> GradPair + Sync,
{
    prepare_out(out, 1, n);
    exec.for_each_slice_mut(&mut out[0], ROW_CHUNK, |_, start, chunk| {
        for (i, g) in chunk.iter_mut().enumerate() {
            *g = f(start + i);
        }
    });
}

/// A training objective.
///
/// `Sync` is part of the contract so a [`crate::gbm::Booster`] can be
/// shared behind an `Arc` by the serving stack (`crate::serve`): every
/// objective is a plain parameter struct scored immutably at predict
/// time, so the bound costs implementations nothing.
pub trait Objective: Send + Sync {
    fn name(&self) -> &'static str;

    /// Number of model outputs per instance (1, or `k` for multiclass).
    fn n_outputs(&self) -> usize {
        1
    }

    /// Initial raw margin (base score) per output.
    fn base_score(&self, train: &Dataset) -> Vec<Float>;

    /// Compute gradient pairs for all instances and outputs.
    ///
    /// * `margins` — `n_outputs` vectors of raw predictions, each length n.
    /// * returns `n_outputs` gradient vectors, each length n.
    fn gradients(&self, ds: &Dataset, margins: &[Vec<Float>]) -> Vec<Vec<GradPair>>;

    /// Chunk-parallel [`gradients`](Self::gradients) into a reusable
    /// out-param — must produce the same values bit for bit at every
    /// thread count. `out` keeps its allocation across boosting rounds
    /// (the learner passes the same buffer every round), so steady-state
    /// gradient computation allocates nothing. The default falls back to
    /// the serial path; the row-wise objectives (squared error, logistic)
    /// override with a pool-parallel map, mirroring the paper's §2.5
    /// split: those two run on device, the rest stay host-serial.
    fn gradients_par_into(
        &self,
        ds: &Dataset,
        margins: &[Vec<Float>],
        exec: &ExecContext,
        out: &mut Vec<Vec<GradPair>>,
    ) {
        let _ = exec;
        *out = self.gradients(ds, margins);
    }

    /// Allocating convenience over
    /// [`gradients_par_into`](Self::gradients_par_into) (tests, one-shot
    /// callers). Round loops should hold a buffer and call the `_into`
    /// form instead.
    fn gradients_par(
        &self,
        ds: &Dataset,
        margins: &[Vec<Float>],
        exec: &ExecContext,
    ) -> Vec<Vec<GradPair>> {
        let mut out = Vec::new();
        self.gradients_par_into(ds, margins, exec, &mut out);
        out
    }

    /// Transform raw margins into the user-facing prediction
    /// (probability, class index, value...).
    fn transform(&self, margins: &[Vec<Float>]) -> Vec<Float>;

    /// Whether the on-device (XLA artifact) gradient kernel covers this
    /// objective (paper §2.5: logistic + linear on device, others CPU).
    fn supports_device(&self) -> bool {
        false
    }

    /// Name of the metric evaluated when `eval_metric` is unset (what
    /// Table 2 reports per task). Custom objectives may override.
    fn default_metric(&self) -> &'static str {
        "rmse"
    }
}

/// Look up an objective by its XGBoost-style name — built-in or
/// registered through [`crate::gbm::ObjectiveRegistry`]. Unknown names
/// error with the full valid-name list.
pub fn objective_by_name(name: &str, num_class: usize) -> anyhow::Result<Box<dyn Objective>> {
    crate::gbm::registry::ObjectiveRegistry::create(name, num_class)
}

#[inline]
pub fn sigmoid(x: Float) -> Float {
    1.0 / (1.0 + (-x).exp())
}

/// `reg:squarederror` — g = ŷ − y, h = 1 (on-device per the paper).
pub struct SquaredError;

impl Objective for SquaredError {
    fn name(&self) -> &'static str {
        "reg:squarederror"
    }

    fn base_score(&self, train: &Dataset) -> Vec<Float> {
        let mean = train.y.iter().sum::<Float>() / train.y.len().max(1) as Float;
        vec![mean]
    }

    fn gradients(&self, ds: &Dataset, margins: &[Vec<Float>]) -> Vec<Vec<GradPair>> {
        vec![ds
            .y
            .iter()
            .zip(margins[0].iter())
            .map(|(&y, &m)| GradPair::new(m - y, 1.0))
            .collect()]
    }

    fn gradients_par_into(
        &self,
        ds: &Dataset,
        margins: &[Vec<Float>],
        exec: &ExecContext,
        out: &mut Vec<Vec<GradPair>>,
    ) {
        let (y, m) = (&ds.y, &margins[0]);
        rowwise_par_into(y.len(), exec, out, |i| GradPair::new(m[i] - y[i], 1.0));
    }

    fn transform(&self, margins: &[Vec<Float>]) -> Vec<Float> {
        margins[0].clone()
    }

    fn supports_device(&self) -> bool {
        true
    }
}

/// `binary:logistic` — equations (1)–(2) of the paper:
/// g = sigmoid(ŷ) − y, h = sigmoid(ŷ)(1 − sigmoid(ŷ)).
pub struct Logistic;

impl Objective for Logistic {
    fn name(&self) -> &'static str {
        "binary:logistic"
    }

    fn base_score(&self, train: &Dataset) -> Vec<Float> {
        // logit of the positive rate, clamped away from the poles
        let p = (train.y.iter().sum::<Float>() / train.y.len().max(1) as Float)
            .clamp(1e-6, 1.0 - 1e-6);
        vec![(p / (1.0 - p)).ln()]
    }

    fn gradients(&self, ds: &Dataset, margins: &[Vec<Float>]) -> Vec<Vec<GradPair>> {
        vec![ds
            .y
            .iter()
            .zip(margins[0].iter())
            .map(|(&y, &m)| {
                let p = sigmoid(m);
                GradPair::new(p - y, (p * (1.0 - p)).max(1e-16))
            })
            .collect()]
    }

    fn gradients_par_into(
        &self,
        ds: &Dataset,
        margins: &[Vec<Float>],
        exec: &ExecContext,
        out: &mut Vec<Vec<GradPair>>,
    ) {
        let (y, m) = (&ds.y, &margins[0]);
        rowwise_par_into(y.len(), exec, out, |i| {
            let p = sigmoid(m[i]);
            GradPair::new(p - y[i], (p * (1.0 - p)).max(1e-16))
        });
    }

    fn transform(&self, margins: &[Vec<Float>]) -> Vec<Float> {
        margins[0].iter().map(|&m| sigmoid(m)).collect()
    }

    fn supports_device(&self) -> bool {
        true
    }

    fn default_metric(&self) -> &'static str {
        "accuracy"
    }
}

/// `multi:softmax` / `multi:softprob` — k one-vs-rest trees per round with
/// softmax cross-entropy gradients (CPU-side, as in paper §2.5).
pub struct Softmax {
    pub k: usize,
    /// `multi:softprob` returns the flattened probability matrix instead
    /// of the argmax class.
    pub prob_output: bool,
}

impl Softmax {
    fn probs(&self, margins: &[Vec<Float>], i: usize) -> Vec<Float> {
        let mut mx = Float::MIN;
        for c in 0..self.k {
            mx = mx.max(margins[c][i]);
        }
        let mut e: Vec<Float> = (0..self.k).map(|c| (margins[c][i] - mx).exp()).collect();
        let s: Float = e.iter().sum();
        for v in e.iter_mut() {
            *v /= s;
        }
        e
    }

    /// Gradient pair of class `c` for one instance: `p_c − 1[label == c]`
    /// with XGBoost's `h = 2 p (1 − p)` softmax hessian.
    #[inline]
    fn pair(pc: Float, is_label: bool) -> GradPair {
        let g = pc - Float::from(is_label) * 1.0;
        let h = (2.0 * pc * (1.0 - pc)).max(1e-16);
        GradPair::new(g, h)
    }
}

impl Objective for Softmax {
    fn name(&self) -> &'static str {
        "multi:softmax"
    }

    fn n_outputs(&self) -> usize {
        self.k
    }

    fn base_score(&self, _train: &Dataset) -> Vec<Float> {
        vec![0.0; self.k]
    }

    fn gradients(&self, ds: &Dataset, margins: &[Vec<Float>]) -> Vec<Vec<GradPair>> {
        let n = ds.y.len();
        let mut out = vec![Vec::with_capacity(n); self.k];
        for i in 0..n {
            let p = self.probs(margins, i);
            let label = ds.y[i] as usize;
            for c in 0..self.k {
                out[c].push(Self::pair(p[c], label == c));
            }
        }
        out
    }

    /// Rows are independent (each instance's softmax touches only its own
    /// k margins), so multiclass chunks exactly like the row-wise
    /// objectives: per-chunk k-way partials concatenate in ascending chunk
    /// order, making the result bit-identical to the serial path at every
    /// thread count.
    fn gradients_par_into(
        &self,
        ds: &Dataset,
        margins: &[Vec<Float>],
        exec: &ExecContext,
        out: &mut Vec<Vec<GradPair>>,
    ) {
        let n = ds.y.len();
        let chunks: Vec<Vec<Vec<GradPair>>> = exec.map_chunks(n, ROW_CHUNK, |_, range| {
            let mut part: Vec<Vec<GradPair>> =
                (0..self.k).map(|_| Vec::with_capacity(range.len())).collect();
            for i in range {
                let p = self.probs(margins, i);
                let label = ds.y[i] as usize;
                for c in 0..self.k {
                    part[c].push(Self::pair(p[c], label == c));
                }
            }
            part
        });
        out.truncate(self.k);
        while out.len() < self.k {
            out.push(Vec::new());
        }
        for v in out.iter_mut() {
            v.clear();
            v.reserve(n);
        }
        for part in chunks {
            for (c, v) in part.into_iter().enumerate() {
                out[c].extend(v);
            }
        }
    }

    fn transform(&self, margins: &[Vec<Float>]) -> Vec<Float> {
        let n = margins[0].len();
        if self.prob_output {
            let mut flat = Vec::with_capacity(n * self.k);
            for i in 0..n {
                flat.extend(self.probs(margins, i));
            }
            flat
        } else {
            (0..n)
                .map(|i| {
                    let mut best = 0usize;
                    for c in 1..self.k {
                        if margins[c][i] > margins[best][i] {
                            best = c;
                        }
                    }
                    best as Float
                })
                .collect()
        }
    }

    fn default_metric(&self) -> &'static str {
        "accuracy"
    }
}

/// `rank:pairwise` — LambdaMART-style pairwise logistic loss within query
/// groups (CPU-side, as in paper §2.5). For every in-group pair with
/// `y_i > y_j`, the cross-entropy on the margin difference contributes
/// `ρ = sigmoid(-(s_i - s_j))`:  g_i −= ρ, g_j += ρ, h += ρ(1−ρ).
#[derive(Default)]
pub struct PairwiseRank;

impl Objective for PairwiseRank {
    fn name(&self) -> &'static str {
        "rank:pairwise"
    }

    fn base_score(&self, _train: &Dataset) -> Vec<Float> {
        vec![0.0]
    }

    fn gradients(&self, ds: &Dataset, margins: &[Vec<Float>]) -> Vec<Vec<GradPair>> {
        let n = ds.y.len();
        let m = &margins[0];
        let groups: Vec<usize> = if ds.groups.is_empty() {
            vec![0, n]
        } else {
            ds.groups.clone()
        };
        let mut grads = vec![GradPair::new(0.0, 1e-16); n];
        for w in groups.windows(2) {
            Self::group_gradients(&ds.y, m, w[0], w[1], &mut grads[w[0]..w[1]]);
        }
        vec![grads]
    }

    /// Chunk-parallel pairwise gradients. Groups are independent (every
    /// pair lives inside one group and writes only to that group's
    /// contiguous row range), so chunks of **whole groups** — boundaries
    /// a pure function of the group structure, never the thread count —
    /// concatenate to exactly the serial result: within a group the
    /// accumulation order is untouched, and across groups the rows are
    /// disjoint. Bit-identical at every thread count
    /// (`pairwise_parallel_gradients_bit_identical`).
    fn gradients_par_into(
        &self,
        ds: &Dataset,
        margins: &[Vec<Float>],
        exec: &ExecContext,
        out: &mut Vec<Vec<GradPair>>,
    ) {
        let n = ds.y.len();
        let m = &margins[0];
        let groups: Vec<usize> = if ds.groups.is_empty() {
            vec![0, n]
        } else {
            ds.groups.clone()
        };
        // fixed group-chunk boundaries: accumulate whole groups until a
        // chunk covers >= ROW_CHUNK rows (depends only on `groups`)
        let mut chunk_bounds: Vec<usize> = vec![0]; // indices into `groups`
        let mut rows_in_chunk = 0usize;
        for gi in 0..groups.len() - 1 {
            rows_in_chunk += groups[gi + 1] - groups[gi];
            if rows_in_chunk >= ROW_CHUNK {
                chunk_bounds.push(gi + 1);
                rows_in_chunk = 0;
            }
        }
        if *chunk_bounds.last().unwrap() != groups.len() - 1 {
            chunk_bounds.push(groups.len() - 1);
        }
        let parts: Vec<Vec<GradPair>> = exec.run_indexed(chunk_bounds.len() - 1, |ci| {
            let g_lo = chunk_bounds[ci];
            let g_hi = chunk_bounds[ci + 1];
            let row_lo = groups[g_lo];
            let row_hi = groups[g_hi];
            let mut part = vec![GradPair::new(0.0, 1e-16); row_hi - row_lo];
            for gi in g_lo..g_hi {
                let (lo, hi) = (groups[gi], groups[gi + 1]);
                Self::group_gradients(&ds.y, m, lo, hi, &mut part[lo - row_lo..hi - row_lo]);
            }
            part
        });
        out.truncate(1);
        if out.is_empty() {
            out.push(Vec::new());
        }
        let grads = &mut out[0];
        grads.clear();
        grads.reserve(n);
        for part in parts {
            grads.extend(part);
        }
    }

    fn transform(&self, margins: &[Vec<Float>]) -> Vec<Float> {
        margins[0].clone()
    }

    fn default_metric(&self) -> &'static str {
        "ndcg"
    }
}

impl PairwiseRank {
    /// Accumulate one query group's pairwise gradients into `out`
    /// (`out[k]` is row `lo + k`). Shared by the serial and chunked
    /// paths so the per-group accumulation order is identical.
    fn group_gradients(y: &[Float], m: &[Float], lo: usize, hi: usize, out: &mut [GradPair]) {
        for i in lo..hi {
            for j in lo..hi {
                if y[i] > y[j] {
                    let rho = sigmoid(-(m[i] - m[j]));
                    let h = (rho * (1.0 - rho)).max(1e-16);
                    out[i - lo].grad -= rho;
                    out[i - lo].hess += h;
                    out[j - lo].grad += rho;
                    out[j - lo].hess += h;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Scenario objectives (quantile / Tweedie / AFT) and their shared math.
//
// The loss functions live here as standalone `pub` f64 helpers so that the
// gradient code below, the matching metrics (`crate::gbm::metric`) and the
// finite-difference property suite (`tests/prop_invariants.rs`) all
// differentiate the *same* implementation — a sign or scale bug cannot hide
// in a private copy.
// ---------------------------------------------------------------------------

use crate::gbm::params::AftDistribution;

/// Pinball (quantile) loss at level `alpha` for one instance.
/// `α·r` when the residual `r = y − m` is positive, `(α − 1)·r` otherwise.
#[inline]
pub fn pinball_loss(alpha: f64, y: f64, m: f64) -> f64 {
    let r = y - m;
    if r > 0.0 {
        alpha * r
    } else {
        (alpha - 1.0) * r
    }
}

/// Tweedie negative log-likelihood (up to an `m`-free constant) at variance
/// power `rho` ∈ (1, 2) for one instance:
/// `−y·e^{(1−ρ)m}/(1−ρ) + e^{(2−ρ)m}/(2−ρ)`.
#[inline]
pub fn tweedie_nll(rho: f64, y: f64, m: f64) -> f64 {
    -y * ((1.0 - rho) * m).exp() / (1.0 - rho) + ((2.0 - rho) * m).exp() / (2.0 - rho)
}

/// Error function via the Abramowitz–Stegun 7.1.26 rational approximation
/// (|error| < 1.5e-7 — far below the f32 gradient precision downstream).
#[inline]
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    sign * (1.0 - poly * (-x * x).exp())
}

/// Standard normal CDF.
#[inline]
pub fn norm_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

/// Standard normal PDF.
#[inline]
pub fn norm_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

#[inline]
fn sigmoid64(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

/// AFT negative log-likelihood for one instance with interval label
/// `(lower, upper]` and margin `m` (the model is `ln t = m + σ·ε`).
///
/// Label convention (mirrors XGBoost's `label_lower_bound` /
/// `label_upper_bound`): `lower == upper > 0` is an uncensored event at
/// that time; `upper = +∞` is right-censored; `lower <= 0` is
/// left-censored (no lower bound); finite `lower < upper` is
/// interval-censored. The censored likelihood `F(z_hi) − F(z_lo)` is
/// clamped at `1e-12` before the log.
pub fn aft_nll(dist: AftDistribution, sigma: f64, lower: f64, upper: f64, m: f64) -> f64 {
    if lower > 0.0 && lower == upper {
        // uncensored: −ln f(z), dropping m-free constants
        let z = (lower.ln() - m) / sigma;
        match dist {
            AftDistribution::Normal => 0.5 * z * z,
            AftDistribution::Logistic => -z + 2.0 * (1.0 + z.exp()).ln(),
        }
    } else {
        let cdf = |z: f64| match dist {
            AftDistribution::Normal => norm_cdf(z),
            AftDistribution::Logistic => sigmoid64(z),
        };
        let f_hi = if upper.is_finite() {
            cdf((upper.max(1e-12).ln() - m) / sigma)
        } else {
            1.0
        };
        let f_lo = if lower > 0.0 {
            cdf((lower.ln() - m) / sigma)
        } else {
            0.0
        };
        -(f_hi - f_lo).max(1e-12).ln()
    }
}

/// `reg:quantile` — pinball loss at quantile `alpha` ∈ (0, 1).
///
/// Subgradient convention at the kink: a strictly positive residual
/// `y − m > 0` takes gradient `−α`; everything else — including `y == m`
/// exactly — takes `1 − α`. The hessian is the constant 1.0 (the loss is
/// piecewise linear; the unit hessian makes leaves average their
/// subgradients, XGBoost's own choice).
pub struct QuantileReg {
    pub alpha: f64,
}

impl QuantileReg {
    #[inline]
    fn pair(&self, y: Float, m: Float) -> GradPair {
        let g = if (y as f64) - (m as f64) > 0.0 {
            -self.alpha
        } else {
            1.0 - self.alpha
        };
        GradPair::new(g as Float, 1.0)
    }
}

impl Objective for QuantileReg {
    fn name(&self) -> &'static str {
        "reg:quantile"
    }

    fn base_score(&self, train: &Dataset) -> Vec<Float> {
        // the empirical lower α-quantile: sorted label at ⌊α·(n−1)⌋
        if train.y.is_empty() {
            return vec![0.0];
        }
        let mut sorted = train.y.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("labels must not be NaN"));
        let idx = (self.alpha * (sorted.len() - 1) as f64).floor() as usize;
        vec![sorted[idx.min(sorted.len() - 1)]]
    }

    fn gradients(&self, ds: &Dataset, margins: &[Vec<Float>]) -> Vec<Vec<GradPair>> {
        vec![ds
            .y
            .iter()
            .zip(margins[0].iter())
            .map(|(&y, &m)| self.pair(y, m))
            .collect()]
    }

    fn gradients_par_into(
        &self,
        ds: &Dataset,
        margins: &[Vec<Float>],
        exec: &ExecContext,
        out: &mut Vec<Vec<GradPair>>,
    ) {
        let (y, m) = (&ds.y, &margins[0]);
        rowwise_par_into(y.len(), exec, out, |i| self.pair(y[i], m[i]));
    }

    fn transform(&self, margins: &[Vec<Float>]) -> Vec<Float> {
        margins[0].clone()
    }

    fn default_metric(&self) -> &'static str {
        "pinball"
    }
}

/// `reg:tweedie` — compound-Poisson deviance with variance power
/// ρ ∈ (1, 2), log link: g = −y·e^{(1−ρ)m} + e^{(2−ρ)m},
/// h = (ρ−1)·y·e^{(1−ρ)m} + (2−ρ)·e^{(2−ρ)m} (floored at 1e-16).
/// Labels must be non-negative.
pub struct Tweedie {
    pub rho: f64,
}

impl Tweedie {
    #[inline]
    fn pair(&self, y: Float, m: Float) -> GradPair {
        let (y, m) = (y as f64, m as f64);
        let a = ((1.0 - self.rho) * m).exp();
        let b = ((2.0 - self.rho) * m).exp();
        let g = -y * a + b;
        let h = ((self.rho - 1.0) * y * a + (2.0 - self.rho) * b).max(1e-16);
        GradPair::new(g as Float, h as Float)
    }
}

impl Objective for Tweedie {
    fn name(&self) -> &'static str {
        "reg:tweedie"
    }

    fn base_score(&self, train: &Dataset) -> Vec<Float> {
        let mean = train.y.iter().map(|&y| y as f64).sum::<f64>() / train.y.len().max(1) as f64;
        vec![mean.max(1e-6).ln() as Float]
    }

    fn gradients(&self, ds: &Dataset, margins: &[Vec<Float>]) -> Vec<Vec<GradPair>> {
        vec![ds
            .y
            .iter()
            .zip(margins[0].iter())
            .map(|(&y, &m)| self.pair(y, m))
            .collect()]
    }

    fn gradients_par_into(
        &self,
        ds: &Dataset,
        margins: &[Vec<Float>],
        exec: &ExecContext,
        out: &mut Vec<Vec<GradPair>>,
    ) {
        let (y, m) = (&ds.y, &margins[0]);
        rowwise_par_into(y.len(), exec, out, |i| self.pair(y[i], m[i]));
    }

    fn transform(&self, margins: &[Vec<Float>]) -> Vec<Float> {
        margins[0].iter().map(|&m| m.exp()).collect()
    }

    fn default_metric(&self) -> &'static str {
        "tweedie-nloglik"
    }
}

/// `survival:aft` — accelerated failure time over `(lower, upper]`
/// interval labels (`Dataset::y` / `Dataset::y_upper`), error distribution
/// normal or logistic, scale σ. Gradients are the exact first/second
/// derivatives of [`aft_nll`] in f64, hessian floored at 1e-16 and both
/// clamped into `[-1e15, 1e15]` before the f32 cast (extreme margins push
/// the censored-likelihood ratio toward ±∞).
pub struct SurvivalAft {
    pub dist: AftDistribution,
    pub sigma: f64,
}

impl SurvivalAft {
    fn pair(&self, lower: Float, upper: Float, m: Float) -> GradPair {
        let s = self.sigma;
        let (lower, upper, m) = (lower as f64, upper as f64, m as f64);
        let (g, h) = if lower > 0.0 && lower == upper {
            // uncensored event
            let z = (lower.ln() - m) / s;
            match self.dist {
                AftDistribution::Normal => (-z / s, 1.0 / (s * s)),
                AftDistribution::Logistic => {
                    let p = sigmoid64(z);
                    ((1.0 - 2.0 * p) / s, 2.0 * p * (1.0 - p) / (s * s))
                }
            }
        } else {
            // censored interval: loss = −ln D, D = F(z_hi) − F(z_lo)
            let pdf = |z: f64| match self.dist {
                AftDistribution::Normal => norm_pdf(z),
                AftDistribution::Logistic => {
                    let p = sigmoid64(z);
                    p * (1.0 - p)
                }
            };
            let cdf = |z: f64| match self.dist {
                AftDistribution::Normal => norm_cdf(z),
                AftDistribution::Logistic => sigmoid64(z),
            };
            // df/dz, for the second derivative
            let dpdf = |z: f64| match self.dist {
                AftDistribution::Normal => -z * norm_pdf(z),
                AftDistribution::Logistic => {
                    let p = sigmoid64(z);
                    p * (1.0 - p) * (1.0 - 2.0 * p)
                }
            };
            let (f_hi, p_hi, dp_hi) = if upper.is_finite() {
                let z = (upper.max(1e-12).ln() - m) / s;
                (cdf(z), pdf(z), dpdf(z))
            } else {
                (1.0, 0.0, 0.0)
            };
            let (f_lo, p_lo, dp_lo) = if lower > 0.0 {
                let z = (lower.ln() - m) / s;
                (cdf(z), pdf(z), dpdf(z))
            } else {
                (0.0, 0.0, 0.0)
            };
            let d = (f_hi - f_lo).max(1e-12);
            // dD/dm = (−1/σ)(f(z_hi) − f(z_lo)); d²D/dm² = (1/σ²)(f'(z_hi) − f'(z_lo))
            let d1 = -(p_hi - p_lo) / s;
            let d2 = (dp_hi - dp_lo) / (s * s);
            let g = -d1 / d;
            (g, -d2 / d + g * g)
        };
        let g = g.clamp(-1e15, 1e15);
        let h = h.max(1e-16).min(1e15);
        GradPair::new(g as Float, h as Float)
    }
}

impl Objective for SurvivalAft {
    fn name(&self) -> &'static str {
        "survival:aft"
    }

    fn base_score(&self, train: &Dataset) -> Vec<Float> {
        // mean representative log-time over the interval labels
        let yu = train.bounds_upper();
        let mut sum = 0.0f64;
        for (i, &lo) in train.y.iter().enumerate() {
            let (lo, up) = (lo as f64, yu[i] as f64);
            sum += if lo > 0.0 && up.is_finite() {
                0.5 * (lo.ln() + up.max(1e-12).ln())
            } else if lo > 0.0 {
                lo.ln()
            } else if up.is_finite() && up > 0.0 {
                up.ln()
            } else {
                0.0
            };
        }
        vec![(sum / train.y.len().max(1) as f64) as Float]
    }

    fn gradients(&self, ds: &Dataset, margins: &[Vec<Float>]) -> Vec<Vec<GradPair>> {
        let yu = ds.bounds_upper();
        vec![ds
            .y
            .iter()
            .zip(yu.iter())
            .zip(margins[0].iter())
            .map(|((&lo, &up), &m)| self.pair(lo, up, m))
            .collect()]
    }

    fn gradients_par_into(
        &self,
        ds: &Dataset,
        margins: &[Vec<Float>],
        exec: &ExecContext,
        out: &mut Vec<Vec<GradPair>>,
    ) {
        let (y, m) = (&ds.y, &margins[0]);
        let yu = ds.bounds_upper();
        rowwise_par_into(y.len(), exec, out, |i| self.pair(y[i], yu[i], m[i]));
    }

    fn transform(&self, margins: &[Vec<Float>]) -> Vec<Float> {
        // predicted survival time on the original scale
        margins[0].iter().map(|&m| m.exp()).collect()
    }

    fn default_metric(&self) -> &'static str {
        "aft-nloglik"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DMatrix, Dataset};

    fn tiny_ds(y: Vec<Float>) -> Dataset {
        let n = y.len();
        Dataset::new(DMatrix::dense(vec![0.0; n], n, 1), y)
    }

    #[test]
    fn squared_error_gradients() {
        let ds = tiny_ds(vec![1.0, 3.0]);
        let o = SquaredError;
        let g = o.gradients(&ds, &[vec![2.0, 2.0]]);
        assert_eq!(g[0][0], GradPair::new(1.0, 1.0));
        assert_eq!(g[0][1], GradPair::new(-1.0, 1.0));
        assert_eq!(o.base_score(&ds), vec![2.0]);
    }

    #[test]
    fn logistic_gradients_match_equations() {
        // paper eq (1)-(2)
        let ds = tiny_ds(vec![1.0, 0.0]);
        let o = Logistic;
        let g = o.gradients(&ds, &[vec![0.0, 0.0]]);
        assert!((g[0][0].grad - (0.5 - 1.0)).abs() < 1e-6);
        assert!((g[0][0].hess - 0.25).abs() < 1e-6);
        assert!((g[0][1].grad - 0.5).abs() < 1e-6);
    }

    #[test]
    fn logistic_transform_is_probability() {
        let o = Logistic;
        let p = o.transform(&[vec![0.0, 100.0, -100.0]]);
        assert!((p[0] - 0.5).abs() < 1e-6);
        assert!(p[1] > 0.999);
        assert!(p[2] < 0.001);
    }

    #[test]
    fn softmax_gradients_sum_to_zero() {
        let ds = tiny_ds(vec![2.0, 0.0]);
        let o = Softmax {
            k: 3,
            prob_output: false,
        };
        let margins = vec![vec![0.1, 0.5], vec![0.2, 0.1], vec![0.3, 0.0]];
        let g = o.gradients(&ds, &margins);
        for i in 0..2 {
            let sum: Float = (0..3).map(|c| g[c][i].grad).sum();
            assert!(sum.abs() < 1e-6, "gradients over classes must sum to 0");
        }
        // true class has negative gradient
        assert!(g[2][0].grad < 0.0);
        assert!(g[0][1].grad < 0.0);
    }

    #[test]
    fn softmax_transform_argmax_and_probs() {
        let o = Softmax {
            k: 3,
            prob_output: false,
        };
        let margins = vec![vec![0.1], vec![2.0], vec![0.3]];
        assert_eq!(o.transform(&margins), vec![1.0]);
        let op = Softmax {
            k: 3,
            prob_output: true,
        };
        let p = op.transform(&margins);
        assert_eq!(p.len(), 3);
        assert!((p.iter().sum::<Float>() - 1.0).abs() < 1e-5);
        assert!(p[1] > p[0] && p[1] > p[2]);
    }

    #[test]
    fn pairwise_rank_pulls_relevant_up() {
        let x = DMatrix::dense(vec![0.0; 4], 4, 1);
        let ds = Dataset::with_groups(x, vec![2.0, 0.0, 1.0, 0.0], vec![0, 2, 4]);
        let o = PairwiseRank;
        let g = o.gradients(&ds, &[vec![0.0; 4]]);
        // higher-relevance docs get negative gradient (pushed up)
        assert!(g[0][0].grad < 0.0);
        assert!(g[0][1].grad > 0.0);
        assert!(g[0][2].grad < 0.0);
        assert!(g[0][3].grad > 0.0);
        // pairs confined to groups: doc 0 (rel 2) never compared with doc 3
        // (rel 0 in other group) — total pull magnitudes within groups match
        assert!((g[0][0].grad + g[0][1].grad).abs() < 1e-6);
        assert!((g[0][2].grad + g[0][3].grad).abs() < 1e-6);
    }

    #[test]
    fn registry_lookup() {
        assert!(objective_by_name("binary:logistic", 1).is_ok());
        assert!(objective_by_name("reg:squarederror", 1).is_ok());
        assert!(objective_by_name("multi:softmax", 7).is_ok());
        assert!(objective_by_name("multi:softmax", 1).is_err());
        assert!(objective_by_name("rank:pairwise", 1).is_ok());
        assert!(objective_by_name("nope", 1).is_err());
    }

    #[test]
    fn default_metrics_match_table2() {
        assert_eq!(SquaredError.default_metric(), "rmse");
        assert_eq!(Logistic.default_metric(), "accuracy");
        assert_eq!(Softmax { k: 3, prob_output: false }.default_metric(), "accuracy");
        assert_eq!(PairwiseRank.default_metric(), "ndcg");
    }

    #[test]
    fn unknown_objective_error_names_the_valid_set() {
        let err = objective_by_name("nope", 1).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("binary:logistic"), "{msg}");
    }

    #[test]
    fn device_support_flags_match_paper() {
        // §2.5: logistic + linear on device; multiclass + ranking CPU
        assert!(Logistic.supports_device());
        assert!(SquaredError.supports_device());
        assert!(!Softmax { k: 3, prob_output: false }.supports_device());
        assert!(!PairwiseRank.supports_device());
    }

    #[test]
    fn quantile_gradients_follow_subgradient_convention() {
        let ds = tiny_ds(vec![1.0, 3.0, 2.0]);
        let o = QuantileReg { alpha: 0.9 };
        let g = o.gradients(&ds, &[vec![2.0, 2.0, 2.0]]);
        // y < m → residual <= 0 → 1 − α
        assert!((g[0][0].grad - 0.1).abs() < 1e-6);
        // y > m → −α
        assert!((g[0][1].grad + 0.9).abs() < 1e-6);
        // y == m exactly: the kink takes the 1 − α branch
        assert!((g[0][2].grad - 0.1).abs() < 1e-6);
        for p in &g[0] {
            assert_eq!(p.hess, 1.0);
        }
        // base score: lower α-quantile of sorted labels
        let q = QuantileReg { alpha: 0.5 };
        assert_eq!(q.base_score(&ds), vec![2.0]);
        assert_eq!(QuantileReg { alpha: 0.01 }.base_score(&ds), vec![1.0]);
    }

    #[test]
    fn tweedie_gradient_zero_at_log_mean() {
        // at m = ln y the gradient is e^{(2−ρ)m}·(1 − y·e^{−m}) = 0
        let o = Tweedie { rho: 1.5 };
        let ds = tiny_ds(vec![4.0]);
        let g = o.gradients(&ds, &[vec![4.0f32.ln()]]);
        assert!(g[0][0].grad.abs() < 1e-5, "{}", g[0][0].grad);
        assert!(g[0][0].hess > 0.0);
        // transform is exp (log link)
        assert!((o.transform(&[vec![0.0]])[0] - 1.0).abs() < 1e-6);
        // zero labels keep a positive hessian (the floor + (2−ρ) term)
        let g0 = o.gradients(&tiny_ds(vec![0.0]), &[vec![0.0]]);
        assert!(g0[0][0].hess > 0.0);
    }

    #[test]
    fn aft_uncensored_gradient_zero_at_log_time() {
        for dist in [AftDistribution::Normal, AftDistribution::Logistic] {
            let o = SurvivalAft { dist, sigma: 1.0 };
            let ds = tiny_ds(vec![5.0]); // y_upper empty → uncensored at t=5
            let g = o.gradients(&ds, &[vec![5.0f32.ln()]]);
            assert!(g[0][0].grad.abs() < 1e-5, "{dist:?}: {}", g[0][0].grad);
            assert!(g[0][0].hess > 0.0);
            // margin below ln t: prediction too small → negative gradient
            let lo = o.gradients(&ds, &[vec![0.0]]);
            assert!(lo[0][0].grad < 0.0, "{dist:?}");
        }
    }

    #[test]
    fn aft_censored_gradients_point_into_the_interval() {
        let x = DMatrix::dense(vec![0.0; 3], 3, 1);
        // right-censored at 10, interval (2, 8], left-censored up to 3
        let ds = Dataset::with_bounds(
            x,
            vec![10.0, 2.0, 0.0],
            vec![Float::INFINITY, 8.0, 3.0],
        );
        let o = SurvivalAft {
            dist: AftDistribution::Normal,
            sigma: 1.0,
        };
        let g = o.gradients(&ds, &[vec![0.0, 0.0, 10.0]]);
        // right-censored far below the bound: push the margin up
        assert!(g[0][0].grad < 0.0);
        // interval row with margin below the interval: push up too
        assert!(g[0][1].grad < 0.0);
        // left-censored with a huge margin: push down
        assert!(g[0][2].grad > 0.0);
        for p in &g[0] {
            assert!(p.hess >= 1e-16 && p.hess.is_finite());
        }
    }

    #[test]
    fn erf_matches_known_values() {
        for (x, want) in [(0.0, 0.0), (1.0, 0.8427007), (-1.0, -0.8427007), (2.0, 0.9953223)] {
            assert!((erf(x) - want).abs() < 1e-6, "erf({x})");
        }
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((norm_cdf(1.96) - 0.975).abs() < 1e-3);
    }

    #[test]
    fn scenario_objectives_parallel_bit_identical() {
        let n = 30_000usize;
        let mut rng = crate::util::Pcg64::new(17);
        let y: Vec<Float> = (0..n).map(|_| rng.next_f32() * 9.0 + 1.0).collect();
        let yu: Vec<Float> = y
            .iter()
            .map(|&v| match rng.gen_range(3) {
                0 => v,                  // uncensored
                1 => Float::INFINITY,    // right-censored
                _ => v + 2.0,            // interval
            })
            .collect();
        let margins = vec![(0..n).map(|_| rng.next_f32() * 4.0 - 2.0).collect::<Vec<Float>>()];
        let ds = Dataset::with_bounds(DMatrix::dense(vec![0.0; n], n, 1), y, yu);
        let objs: Vec<Box<dyn Objective>> = vec![
            Box::new(QuantileReg { alpha: 0.9 }),
            Box::new(Tweedie { rho: 1.3 }),
            Box::new(SurvivalAft { dist: AftDistribution::Normal, sigma: 1.0 }),
            Box::new(SurvivalAft { dist: AftDistribution::Logistic, sigma: 0.7 }),
        ];
        for obj in &objs {
            let serial = obj.gradients(&ds, &margins);
            for t in [2usize, 8] {
                let par = obj.gradients_par(&ds, &margins, &crate::exec::ExecContext::new(t));
                assert_eq!(par, serial, "{} threads = {t}", obj.name());
            }
        }
    }

    #[test]
    fn parallel_gradients_bit_identical() {
        use crate::data::DMatrix;
        let n = 30_000usize; // > ROW_CHUNK so chunking engages
        let mut rng = crate::util::Pcg64::new(5);
        let y: Vec<Float> = (0..n).map(|_| (rng.next_f64() < 0.5) as u32 as Float).collect();
        let margins = vec![(0..n).map(|_| rng.next_f32() * 4.0 - 2.0).collect::<Vec<Float>>()];
        let ds = Dataset::new(DMatrix::dense(vec![0.0; n], n, 1), y);
        for obj in [&SquaredError as &dyn Objective, &Logistic] {
            let serial = obj.gradients(&ds, &margins);
            for t in [2usize, 8] {
                let par = obj.gradients_par(&ds, &margins, &crate::exec::ExecContext::new(t));
                assert_eq!(par, serial, "{} threads = {t}", obj.name());
            }
        }
    }

    #[test]
    fn pairwise_parallel_gradients_bit_identical() {
        use crate::data::DMatrix;
        // many small groups + a few large ones, > ROW_CHUNK total rows so
        // several group chunks engage; also a group straddling the
        // nominal chunk budget
        let mut rng = crate::util::Pcg64::new(11);
        let mut groups = vec![0usize];
        let mut n = 0usize;
        while n < 25_000 {
            let size = if rng.next_f64() < 0.05 {
                500 + rng.gen_range(400)
            } else {
                2 + rng.gen_range(30)
            };
            n += size;
            groups.push(n);
        }
        let y: Vec<Float> = (0..n).map(|_| rng.gen_range(4) as Float).collect();
        let margins = vec![(0..n).map(|_| rng.next_f32() * 4.0 - 2.0).collect::<Vec<Float>>()];
        let ds = Dataset::with_groups(DMatrix::dense(vec![0.0; n], n, 1), y, groups);
        let o = PairwiseRank;
        let serial = o.gradients(&ds, &margins);
        for t in [1usize, 2, 8] {
            let par = o.gradients_par(&ds, &margins, &crate::exec::ExecContext::new(t));
            assert_eq!(par, serial, "threads = {t}");
        }
        // the no-groups fallback (single implicit group) stays identical
        let ds1 = Dataset::new(DMatrix::dense(vec![0.0; 300], 300, 1), ds.y[..300].to_vec());
        let m1 = vec![margins[0][..300].to_vec()];
        let s1 = o.gradients(&ds1, &m1);
        for t in [2usize, 8] {
            assert_eq!(
                o.gradients_par(&ds1, &m1, &crate::exec::ExecContext::new(t)),
                s1,
                "no-groups threads = {t}"
            );
        }
    }

    #[test]
    fn softmax_parallel_gradients_bit_identical() {
        use crate::data::DMatrix;
        let k = 5usize;
        let n = 20_000usize; // > ROW_CHUNK so chunking engages
        let mut rng = crate::util::Pcg64::new(7);
        let y: Vec<Float> = (0..n).map(|_| rng.gen_range(k) as Float).collect();
        let margins: Vec<Vec<Float>> = (0..k)
            .map(|_| (0..n).map(|_| rng.next_f32() * 4.0 - 2.0).collect())
            .collect();
        let ds = Dataset::new(DMatrix::dense(vec![0.0; n], n, 1), y);
        let o = Softmax {
            k,
            prob_output: false,
        };
        let serial = o.gradients(&ds, &margins);
        for t in [1usize, 2, 8] {
            let par = o.gradients_par(&ds, &margins, &crate::exec::ExecContext::new(t));
            assert_eq!(par, serial, "threads = {t}");
        }
    }
}
