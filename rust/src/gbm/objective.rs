//! Training objectives: first/second-order gradient computation per
//! boosting iteration (paper §2.5, equations 1–2).
//!
//! The paper computes logistic and linear-regression gradients on device
//! (each thread one instance) and leaves multiclass/ranking on the CPU;
//! mirroring that, [`Objective::supports_device`] marks which objectives
//! the AOT-compiled XLA gradient artifact covers
//! (`python/compile/model.py::{logistic,squared}_gradients`) — the others
//! always run in Rust.

use crate::data::Dataset;
use crate::exec::{ExecContext, ROW_CHUNK};
use crate::{Float, GradPair};

/// Shape `out` as `k` gradient vectors of length `n` without dropping
/// capacity — the round-arena idiom for the out-param gradient path:
/// steady-state boosting rounds rewrite the same buffers in place.
fn prepare_out(out: &mut Vec<Vec<GradPair>>, k: usize, n: usize) {
    out.truncate(k);
    while out.len() < k {
        out.push(Vec::new());
    }
    for v in out.iter_mut() {
        v.clear();
        v.resize(n, GradPair::default());
    }
}

/// Chunk a single-output row-wise gradient map across the pool, writing
/// into the reusable out-param. Each row's pair is computed independently
/// and chunks concatenate in index order, so the result is bit-identical
/// to the serial map.
fn rowwise_par_into<F>(n: usize, exec: &ExecContext, out: &mut Vec<Vec<GradPair>>, f: F)
where
    F: Fn(usize) -> GradPair + Sync,
{
    prepare_out(out, 1, n);
    exec.for_each_slice_mut(&mut out[0], ROW_CHUNK, |_, start, chunk| {
        for (i, g) in chunk.iter_mut().enumerate() {
            *g = f(start + i);
        }
    });
}

/// A training objective.
///
/// `Sync` is part of the contract so a [`crate::gbm::Booster`] can be
/// shared behind an `Arc` by the serving stack (`crate::serve`): every
/// objective is a plain parameter struct scored immutably at predict
/// time, so the bound costs implementations nothing.
pub trait Objective: Send + Sync {
    fn name(&self) -> &'static str;

    /// Number of model outputs per instance (1, or `k` for multiclass).
    fn n_outputs(&self) -> usize {
        1
    }

    /// Initial raw margin (base score) per output.
    fn base_score(&self, train: &Dataset) -> Vec<Float>;

    /// Compute gradient pairs for all instances and outputs.
    ///
    /// * `margins` — `n_outputs` vectors of raw predictions, each length n.
    /// * returns `n_outputs` gradient vectors, each length n.
    fn gradients(&self, ds: &Dataset, margins: &[Vec<Float>]) -> Vec<Vec<GradPair>>;

    /// Chunk-parallel [`gradients`](Self::gradients) into a reusable
    /// out-param — must produce the same values bit for bit at every
    /// thread count. `out` keeps its allocation across boosting rounds
    /// (the learner passes the same buffer every round), so steady-state
    /// gradient computation allocates nothing. The default falls back to
    /// the serial path; the row-wise objectives (squared error, logistic)
    /// override with a pool-parallel map, mirroring the paper's §2.5
    /// split: those two run on device, the rest stay host-serial.
    fn gradients_par_into(
        &self,
        ds: &Dataset,
        margins: &[Vec<Float>],
        exec: &ExecContext,
        out: &mut Vec<Vec<GradPair>>,
    ) {
        let _ = exec;
        *out = self.gradients(ds, margins);
    }

    /// Allocating convenience over
    /// [`gradients_par_into`](Self::gradients_par_into) (tests, one-shot
    /// callers). Round loops should hold a buffer and call the `_into`
    /// form instead.
    fn gradients_par(
        &self,
        ds: &Dataset,
        margins: &[Vec<Float>],
        exec: &ExecContext,
    ) -> Vec<Vec<GradPair>> {
        let mut out = Vec::new();
        self.gradients_par_into(ds, margins, exec, &mut out);
        out
    }

    /// Transform raw margins into the user-facing prediction
    /// (probability, class index, value...).
    fn transform(&self, margins: &[Vec<Float>]) -> Vec<Float>;

    /// Whether the on-device (XLA artifact) gradient kernel covers this
    /// objective (paper §2.5: logistic + linear on device, others CPU).
    fn supports_device(&self) -> bool {
        false
    }

    /// Name of the metric evaluated when `eval_metric` is unset (what
    /// Table 2 reports per task). Custom objectives may override.
    fn default_metric(&self) -> &'static str {
        "rmse"
    }
}

/// Look up an objective by its XGBoost-style name — built-in or
/// registered through [`crate::gbm::ObjectiveRegistry`]. Unknown names
/// error with the full valid-name list.
pub fn objective_by_name(name: &str, num_class: usize) -> anyhow::Result<Box<dyn Objective>> {
    crate::gbm::registry::ObjectiveRegistry::create(name, num_class)
}

#[inline]
pub fn sigmoid(x: Float) -> Float {
    1.0 / (1.0 + (-x).exp())
}

/// `reg:squarederror` — g = ŷ − y, h = 1 (on-device per the paper).
pub struct SquaredError;

impl Objective for SquaredError {
    fn name(&self) -> &'static str {
        "reg:squarederror"
    }

    fn base_score(&self, train: &Dataset) -> Vec<Float> {
        let mean = train.y.iter().sum::<Float>() / train.y.len().max(1) as Float;
        vec![mean]
    }

    fn gradients(&self, ds: &Dataset, margins: &[Vec<Float>]) -> Vec<Vec<GradPair>> {
        vec![ds
            .y
            .iter()
            .zip(margins[0].iter())
            .map(|(&y, &m)| GradPair::new(m - y, 1.0))
            .collect()]
    }

    fn gradients_par_into(
        &self,
        ds: &Dataset,
        margins: &[Vec<Float>],
        exec: &ExecContext,
        out: &mut Vec<Vec<GradPair>>,
    ) {
        let (y, m) = (&ds.y, &margins[0]);
        rowwise_par_into(y.len(), exec, out, |i| GradPair::new(m[i] - y[i], 1.0));
    }

    fn transform(&self, margins: &[Vec<Float>]) -> Vec<Float> {
        margins[0].clone()
    }

    fn supports_device(&self) -> bool {
        true
    }
}

/// `binary:logistic` — equations (1)–(2) of the paper:
/// g = sigmoid(ŷ) − y, h = sigmoid(ŷ)(1 − sigmoid(ŷ)).
pub struct Logistic;

impl Objective for Logistic {
    fn name(&self) -> &'static str {
        "binary:logistic"
    }

    fn base_score(&self, train: &Dataset) -> Vec<Float> {
        // logit of the positive rate, clamped away from the poles
        let p = (train.y.iter().sum::<Float>() / train.y.len().max(1) as Float)
            .clamp(1e-6, 1.0 - 1e-6);
        vec![(p / (1.0 - p)).ln()]
    }

    fn gradients(&self, ds: &Dataset, margins: &[Vec<Float>]) -> Vec<Vec<GradPair>> {
        vec![ds
            .y
            .iter()
            .zip(margins[0].iter())
            .map(|(&y, &m)| {
                let p = sigmoid(m);
                GradPair::new(p - y, (p * (1.0 - p)).max(1e-16))
            })
            .collect()]
    }

    fn gradients_par_into(
        &self,
        ds: &Dataset,
        margins: &[Vec<Float>],
        exec: &ExecContext,
        out: &mut Vec<Vec<GradPair>>,
    ) {
        let (y, m) = (&ds.y, &margins[0]);
        rowwise_par_into(y.len(), exec, out, |i| {
            let p = sigmoid(m[i]);
            GradPair::new(p - y[i], (p * (1.0 - p)).max(1e-16))
        });
    }

    fn transform(&self, margins: &[Vec<Float>]) -> Vec<Float> {
        margins[0].iter().map(|&m| sigmoid(m)).collect()
    }

    fn supports_device(&self) -> bool {
        true
    }

    fn default_metric(&self) -> &'static str {
        "accuracy"
    }
}

/// `multi:softmax` / `multi:softprob` — k one-vs-rest trees per round with
/// softmax cross-entropy gradients (CPU-side, as in paper §2.5).
pub struct Softmax {
    pub k: usize,
    /// `multi:softprob` returns the flattened probability matrix instead
    /// of the argmax class.
    pub prob_output: bool,
}

impl Softmax {
    fn probs(&self, margins: &[Vec<Float>], i: usize) -> Vec<Float> {
        let mut mx = Float::MIN;
        for c in 0..self.k {
            mx = mx.max(margins[c][i]);
        }
        let mut e: Vec<Float> = (0..self.k).map(|c| (margins[c][i] - mx).exp()).collect();
        let s: Float = e.iter().sum();
        for v in e.iter_mut() {
            *v /= s;
        }
        e
    }

    /// Gradient pair of class `c` for one instance: `p_c − 1[label == c]`
    /// with XGBoost's `h = 2 p (1 − p)` softmax hessian.
    #[inline]
    fn pair(pc: Float, is_label: bool) -> GradPair {
        let g = pc - Float::from(is_label) * 1.0;
        let h = (2.0 * pc * (1.0 - pc)).max(1e-16);
        GradPair::new(g, h)
    }
}

impl Objective for Softmax {
    fn name(&self) -> &'static str {
        "multi:softmax"
    }

    fn n_outputs(&self) -> usize {
        self.k
    }

    fn base_score(&self, _train: &Dataset) -> Vec<Float> {
        vec![0.0; self.k]
    }

    fn gradients(&self, ds: &Dataset, margins: &[Vec<Float>]) -> Vec<Vec<GradPair>> {
        let n = ds.y.len();
        let mut out = vec![Vec::with_capacity(n); self.k];
        for i in 0..n {
            let p = self.probs(margins, i);
            let label = ds.y[i] as usize;
            for c in 0..self.k {
                out[c].push(Self::pair(p[c], label == c));
            }
        }
        out
    }

    /// Rows are independent (each instance's softmax touches only its own
    /// k margins), so multiclass chunks exactly like the row-wise
    /// objectives: per-chunk k-way partials concatenate in ascending chunk
    /// order, making the result bit-identical to the serial path at every
    /// thread count.
    fn gradients_par_into(
        &self,
        ds: &Dataset,
        margins: &[Vec<Float>],
        exec: &ExecContext,
        out: &mut Vec<Vec<GradPair>>,
    ) {
        let n = ds.y.len();
        let chunks: Vec<Vec<Vec<GradPair>>> = exec.map_chunks(n, ROW_CHUNK, |_, range| {
            let mut part: Vec<Vec<GradPair>> =
                (0..self.k).map(|_| Vec::with_capacity(range.len())).collect();
            for i in range {
                let p = self.probs(margins, i);
                let label = ds.y[i] as usize;
                for c in 0..self.k {
                    part[c].push(Self::pair(p[c], label == c));
                }
            }
            part
        });
        out.truncate(self.k);
        while out.len() < self.k {
            out.push(Vec::new());
        }
        for v in out.iter_mut() {
            v.clear();
            v.reserve(n);
        }
        for part in chunks {
            for (c, v) in part.into_iter().enumerate() {
                out[c].extend(v);
            }
        }
    }

    fn transform(&self, margins: &[Vec<Float>]) -> Vec<Float> {
        let n = margins[0].len();
        if self.prob_output {
            let mut flat = Vec::with_capacity(n * self.k);
            for i in 0..n {
                flat.extend(self.probs(margins, i));
            }
            flat
        } else {
            (0..n)
                .map(|i| {
                    let mut best = 0usize;
                    for c in 1..self.k {
                        if margins[c][i] > margins[best][i] {
                            best = c;
                        }
                    }
                    best as Float
                })
                .collect()
        }
    }

    fn default_metric(&self) -> &'static str {
        "accuracy"
    }
}

/// `rank:pairwise` — LambdaMART-style pairwise logistic loss within query
/// groups (CPU-side, as in paper §2.5). For every in-group pair with
/// `y_i > y_j`, the cross-entropy on the margin difference contributes
/// `ρ = sigmoid(-(s_i - s_j))`:  g_i −= ρ, g_j += ρ, h += ρ(1−ρ).
#[derive(Default)]
pub struct PairwiseRank;

impl Objective for PairwiseRank {
    fn name(&self) -> &'static str {
        "rank:pairwise"
    }

    fn base_score(&self, _train: &Dataset) -> Vec<Float> {
        vec![0.0]
    }

    fn gradients(&self, ds: &Dataset, margins: &[Vec<Float>]) -> Vec<Vec<GradPair>> {
        let n = ds.y.len();
        let m = &margins[0];
        let groups: Vec<usize> = if ds.groups.is_empty() {
            vec![0, n]
        } else {
            ds.groups.clone()
        };
        let mut grads = vec![GradPair::new(0.0, 1e-16); n];
        for w in groups.windows(2) {
            Self::group_gradients(&ds.y, m, w[0], w[1], &mut grads[w[0]..w[1]]);
        }
        vec![grads]
    }

    /// Chunk-parallel pairwise gradients. Groups are independent (every
    /// pair lives inside one group and writes only to that group's
    /// contiguous row range), so chunks of **whole groups** — boundaries
    /// a pure function of the group structure, never the thread count —
    /// concatenate to exactly the serial result: within a group the
    /// accumulation order is untouched, and across groups the rows are
    /// disjoint. Bit-identical at every thread count
    /// (`pairwise_parallel_gradients_bit_identical`).
    fn gradients_par_into(
        &self,
        ds: &Dataset,
        margins: &[Vec<Float>],
        exec: &ExecContext,
        out: &mut Vec<Vec<GradPair>>,
    ) {
        let n = ds.y.len();
        let m = &margins[0];
        let groups: Vec<usize> = if ds.groups.is_empty() {
            vec![0, n]
        } else {
            ds.groups.clone()
        };
        // fixed group-chunk boundaries: accumulate whole groups until a
        // chunk covers >= ROW_CHUNK rows (depends only on `groups`)
        let mut chunk_bounds: Vec<usize> = vec![0]; // indices into `groups`
        let mut rows_in_chunk = 0usize;
        for gi in 0..groups.len() - 1 {
            rows_in_chunk += groups[gi + 1] - groups[gi];
            if rows_in_chunk >= ROW_CHUNK {
                chunk_bounds.push(gi + 1);
                rows_in_chunk = 0;
            }
        }
        if *chunk_bounds.last().unwrap() != groups.len() - 1 {
            chunk_bounds.push(groups.len() - 1);
        }
        let parts: Vec<Vec<GradPair>> = exec.run_indexed(chunk_bounds.len() - 1, |ci| {
            let g_lo = chunk_bounds[ci];
            let g_hi = chunk_bounds[ci + 1];
            let row_lo = groups[g_lo];
            let row_hi = groups[g_hi];
            let mut part = vec![GradPair::new(0.0, 1e-16); row_hi - row_lo];
            for gi in g_lo..g_hi {
                let (lo, hi) = (groups[gi], groups[gi + 1]);
                Self::group_gradients(&ds.y, m, lo, hi, &mut part[lo - row_lo..hi - row_lo]);
            }
            part
        });
        out.truncate(1);
        if out.is_empty() {
            out.push(Vec::new());
        }
        let grads = &mut out[0];
        grads.clear();
        grads.reserve(n);
        for part in parts {
            grads.extend(part);
        }
    }

    fn transform(&self, margins: &[Vec<Float>]) -> Vec<Float> {
        margins[0].clone()
    }

    fn default_metric(&self) -> &'static str {
        "ndcg"
    }
}

impl PairwiseRank {
    /// Accumulate one query group's pairwise gradients into `out`
    /// (`out[k]` is row `lo + k`). Shared by the serial and chunked
    /// paths so the per-group accumulation order is identical.
    fn group_gradients(y: &[Float], m: &[Float], lo: usize, hi: usize, out: &mut [GradPair]) {
        for i in lo..hi {
            for j in lo..hi {
                if y[i] > y[j] {
                    let rho = sigmoid(-(m[i] - m[j]));
                    let h = (rho * (1.0 - rho)).max(1e-16);
                    out[i - lo].grad -= rho;
                    out[i - lo].hess += h;
                    out[j - lo].grad += rho;
                    out[j - lo].hess += h;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DMatrix, Dataset};

    fn tiny_ds(y: Vec<Float>) -> Dataset {
        let n = y.len();
        Dataset::new(DMatrix::dense(vec![0.0; n], n, 1), y)
    }

    #[test]
    fn squared_error_gradients() {
        let ds = tiny_ds(vec![1.0, 3.0]);
        let o = SquaredError;
        let g = o.gradients(&ds, &[vec![2.0, 2.0]]);
        assert_eq!(g[0][0], GradPair::new(1.0, 1.0));
        assert_eq!(g[0][1], GradPair::new(-1.0, 1.0));
        assert_eq!(o.base_score(&ds), vec![2.0]);
    }

    #[test]
    fn logistic_gradients_match_equations() {
        // paper eq (1)-(2)
        let ds = tiny_ds(vec![1.0, 0.0]);
        let o = Logistic;
        let g = o.gradients(&ds, &[vec![0.0, 0.0]]);
        assert!((g[0][0].grad - (0.5 - 1.0)).abs() < 1e-6);
        assert!((g[0][0].hess - 0.25).abs() < 1e-6);
        assert!((g[0][1].grad - 0.5).abs() < 1e-6);
    }

    #[test]
    fn logistic_transform_is_probability() {
        let o = Logistic;
        let p = o.transform(&[vec![0.0, 100.0, -100.0]]);
        assert!((p[0] - 0.5).abs() < 1e-6);
        assert!(p[1] > 0.999);
        assert!(p[2] < 0.001);
    }

    #[test]
    fn softmax_gradients_sum_to_zero() {
        let ds = tiny_ds(vec![2.0, 0.0]);
        let o = Softmax {
            k: 3,
            prob_output: false,
        };
        let margins = vec![vec![0.1, 0.5], vec![0.2, 0.1], vec![0.3, 0.0]];
        let g = o.gradients(&ds, &margins);
        for i in 0..2 {
            let sum: Float = (0..3).map(|c| g[c][i].grad).sum();
            assert!(sum.abs() < 1e-6, "gradients over classes must sum to 0");
        }
        // true class has negative gradient
        assert!(g[2][0].grad < 0.0);
        assert!(g[0][1].grad < 0.0);
    }

    #[test]
    fn softmax_transform_argmax_and_probs() {
        let o = Softmax {
            k: 3,
            prob_output: false,
        };
        let margins = vec![vec![0.1], vec![2.0], vec![0.3]];
        assert_eq!(o.transform(&margins), vec![1.0]);
        let op = Softmax {
            k: 3,
            prob_output: true,
        };
        let p = op.transform(&margins);
        assert_eq!(p.len(), 3);
        assert!((p.iter().sum::<Float>() - 1.0).abs() < 1e-5);
        assert!(p[1] > p[0] && p[1] > p[2]);
    }

    #[test]
    fn pairwise_rank_pulls_relevant_up() {
        let x = DMatrix::dense(vec![0.0; 4], 4, 1);
        let ds = Dataset::with_groups(x, vec![2.0, 0.0, 1.0, 0.0], vec![0, 2, 4]);
        let o = PairwiseRank;
        let g = o.gradients(&ds, &[vec![0.0; 4]]);
        // higher-relevance docs get negative gradient (pushed up)
        assert!(g[0][0].grad < 0.0);
        assert!(g[0][1].grad > 0.0);
        assert!(g[0][2].grad < 0.0);
        assert!(g[0][3].grad > 0.0);
        // pairs confined to groups: doc 0 (rel 2) never compared with doc 3
        // (rel 0 in other group) — total pull magnitudes within groups match
        assert!((g[0][0].grad + g[0][1].grad).abs() < 1e-6);
        assert!((g[0][2].grad + g[0][3].grad).abs() < 1e-6);
    }

    #[test]
    fn registry_lookup() {
        assert!(objective_by_name("binary:logistic", 1).is_ok());
        assert!(objective_by_name("reg:squarederror", 1).is_ok());
        assert!(objective_by_name("multi:softmax", 7).is_ok());
        assert!(objective_by_name("multi:softmax", 1).is_err());
        assert!(objective_by_name("rank:pairwise", 1).is_ok());
        assert!(objective_by_name("nope", 1).is_err());
    }

    #[test]
    fn default_metrics_match_table2() {
        assert_eq!(SquaredError.default_metric(), "rmse");
        assert_eq!(Logistic.default_metric(), "accuracy");
        assert_eq!(Softmax { k: 3, prob_output: false }.default_metric(), "accuracy");
        assert_eq!(PairwiseRank.default_metric(), "ndcg");
    }

    #[test]
    fn unknown_objective_error_names_the_valid_set() {
        let err = objective_by_name("nope", 1).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("binary:logistic"), "{msg}");
    }

    #[test]
    fn device_support_flags_match_paper() {
        // §2.5: logistic + linear on device; multiclass + ranking CPU
        assert!(Logistic.supports_device());
        assert!(SquaredError.supports_device());
        assert!(!Softmax { k: 3, prob_output: false }.supports_device());
        assert!(!PairwiseRank.supports_device());
    }

    #[test]
    fn parallel_gradients_bit_identical() {
        use crate::data::DMatrix;
        let n = 30_000usize; // > ROW_CHUNK so chunking engages
        let mut rng = crate::util::Pcg64::new(5);
        let y: Vec<Float> = (0..n).map(|_| (rng.next_f64() < 0.5) as u32 as Float).collect();
        let margins = vec![(0..n).map(|_| rng.next_f32() * 4.0 - 2.0).collect::<Vec<Float>>()];
        let ds = Dataset::new(DMatrix::dense(vec![0.0; n], n, 1), y);
        for obj in [&SquaredError as &dyn Objective, &Logistic] {
            let serial = obj.gradients(&ds, &margins);
            for t in [2usize, 8] {
                let par = obj.gradients_par(&ds, &margins, &crate::exec::ExecContext::new(t));
                assert_eq!(par, serial, "{} threads = {t}", obj.name());
            }
        }
    }

    #[test]
    fn pairwise_parallel_gradients_bit_identical() {
        use crate::data::DMatrix;
        // many small groups + a few large ones, > ROW_CHUNK total rows so
        // several group chunks engage; also a group straddling the
        // nominal chunk budget
        let mut rng = crate::util::Pcg64::new(11);
        let mut groups = vec![0usize];
        let mut n = 0usize;
        while n < 25_000 {
            let size = if rng.next_f64() < 0.05 {
                500 + rng.gen_range(400)
            } else {
                2 + rng.gen_range(30)
            };
            n += size;
            groups.push(n);
        }
        let y: Vec<Float> = (0..n).map(|_| rng.gen_range(4) as Float).collect();
        let margins = vec![(0..n).map(|_| rng.next_f32() * 4.0 - 2.0).collect::<Vec<Float>>()];
        let ds = Dataset::with_groups(DMatrix::dense(vec![0.0; n], n, 1), y, groups);
        let o = PairwiseRank;
        let serial = o.gradients(&ds, &margins);
        for t in [1usize, 2, 8] {
            let par = o.gradients_par(&ds, &margins, &crate::exec::ExecContext::new(t));
            assert_eq!(par, serial, "threads = {t}");
        }
        // the no-groups fallback (single implicit group) stays identical
        let ds1 = Dataset::new(DMatrix::dense(vec![0.0; 300], 300, 1), ds.y[..300].to_vec());
        let m1 = vec![margins[0][..300].to_vec()];
        let s1 = o.gradients(&ds1, &m1);
        for t in [2usize, 8] {
            assert_eq!(
                o.gradients_par(&ds1, &m1, &crate::exec::ExecContext::new(t)),
                s1,
                "no-groups threads = {t}"
            );
        }
    }

    #[test]
    fn softmax_parallel_gradients_bit_identical() {
        use crate::data::DMatrix;
        let k = 5usize;
        let n = 20_000usize; // > ROW_CHUNK so chunking engages
        let mut rng = crate::util::Pcg64::new(7);
        let y: Vec<Float> = (0..n).map(|_| rng.gen_range(k) as Float).collect();
        let margins: Vec<Vec<Float>> = (0..k)
            .map(|_| (0..n).map(|_| rng.next_f32() * 4.0 - 2.0).collect())
            .collect();
        let ds = Dataset::new(DMatrix::dense(vec![0.0; n], n, 1), y);
        let o = Softmax {
            k,
            prob_output: false,
        };
        let serial = o.gradients(&ds, &margins);
        for t in [1usize, 2, 8] {
            let par = o.gradients_par(&ds, &margins, &crate::exec::ExecContext::new(t));
            assert_eq!(par, serial, "threads = {t}");
        }
    }
}
