//! The typed training façade: [`LearnerBuilder`] → [`Learner`] →
//! [`Booster`].
//!
//! This module owns the Figure-1 boosting loop (predict → gradient →
//! quantised multi-device tree construction → evaluation). The legacy
//! `Booster::train(&BoosterParams, ..)` entry point is now a thin
//! deprecated shim over it.
//!
//! * [`LearnerBuilder`] — fluent, string-or-typed configuration whose
//!   [`build`](LearnerBuilder::build) runs the full cross-field validation
//!   matrix up front and reports **every** violation at once.
//! * [`Callback`] — round/eval/train-end hooks. The early-stopping and
//!   verbose-logging behaviour that used to be hardcoded in the training
//!   loop now ships as the [`EarlyStopping`] and [`EvalLogger`] callbacks
//!   (plus [`TimeBudget`] for wall-clock-capped runs); params-driven
//!   configurations get them implicitly, so behaviour is unchanged.
//!
//! ```no_run
//! use xgb_tpu::data::synthetic::{generate, DatasetSpec};
//! use xgb_tpu::gbm::{Learner, MetricKind, ObjectiveKind};
//!
//! let ds = generate(&DatasetSpec::higgs_like(10_000), 42);
//! let mut learner = Learner::builder()
//!     .objective(ObjectiveKind::BinaryLogistic)
//!     .eval_metric(MetricKind::Auc)
//!     .num_rounds(20)
//!     .build()
//!     .unwrap();
//! let booster = learner.train(&ds.train, Some(&ds.valid)).unwrap();
//! let preds = booster.predict(&ds.valid.x);
//! # let _ = preds;
//! ```

use std::time::Instant;

use anyhow::{Context as _, Result};

use crate::coordinator::{BuildStats, HistBackend, MultiDeviceCoordinator, NativeBackend};
use crate::data::source::BatchSource;
use crate::data::Dataset;
use crate::exec::ExecContext;
use crate::gbm::booster::{Booster, EvalRecord};
use crate::gbm::metric::Metric;
use crate::gbm::params::{
    AftDistribution, AllReduce, GrowPolicy, LearnerParams, MetricKind, MonotoneConstraints,
    ObjectiveKind, ValidationErrors, WirePayload,
};
use crate::gbm::registry::{MetricRegistry, ObjectiveRegistry};
use crate::predict::quantised::{self, QuantisedBatch};
use crate::tree::RegTree;
use crate::util::Config;
use crate::Float;

/// What a callback asks the training loop to do next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CallbackAction {
    Continue,
    /// Finish the current round's bookkeeping, then stop training.
    Stop,
}

/// Read-only view of training state handed to callbacks.
pub struct RoundContext<'a> {
    /// 1-based index of the round that just completed.
    pub round: usize,
    /// Configured round budget.
    pub num_rounds: usize,
    /// Wall-clock seconds since training started.
    pub elapsed_secs: f64,
    /// Evaluation history so far (most recent last).
    pub history: &'a [EvalRecord],
    /// Direction of the active metric (`true` = lower is better).
    pub minimize: bool,
}

/// Training lifecycle hooks.
///
/// All methods have no-op defaults; implement the ones you need. Hooks
/// returning [`CallbackAction::Stop`] end training after the current
/// round (the round's trees are kept, mirroring the legacy early-stop
/// semantics).
pub trait Callback: Send {
    /// Called once before the first round. Reset any per-run state here —
    /// the same callback instance is reused across `train` calls.
    fn on_train_begin(&mut self) -> Result<()> {
        Ok(())
    }

    /// Called after every round (whether or not an evaluation ran).
    fn on_round_end(&mut self, _ctx: &RoundContext) -> Result<CallbackAction> {
        Ok(CallbackAction::Continue)
    }

    /// Called after each evaluation with the fresh record
    /// (`ctx.history.last()` is the same record).
    fn on_eval(&mut self, _ctx: &RoundContext, _record: &EvalRecord) -> Result<CallbackAction> {
        Ok(CallbackAction::Continue)
    }

    /// Called once when training finishes (normally or via `Stop`).
    fn on_train_end(&mut self, _history: &[EvalRecord]) -> Result<()> {
        Ok(())
    }
}

/// Stop when the validation metric hasn't improved in `rounds`
/// consecutive evaluations — the callback form of the legacy
/// `early_stopping_rounds` behaviour.
pub struct EarlyStopping {
    rounds: usize,
    best: Option<f64>,
    stale: usize,
    /// Round of the best validation score seen (1-based), if any.
    pub best_round: Option<usize>,
}

impl EarlyStopping {
    pub fn new(rounds: usize) -> Self {
        EarlyStopping {
            rounds,
            best: None,
            stale: 0,
            best_round: None,
        }
    }
}

impl Callback for EarlyStopping {
    fn on_train_begin(&mut self) -> Result<()> {
        self.best = None;
        self.stale = 0;
        self.best_round = None;
        Ok(())
    }

    fn on_eval(&mut self, ctx: &RoundContext, record: &EvalRecord) -> Result<CallbackAction> {
        let Some(score) = record.valid else {
            return Ok(CallbackAction::Continue);
        };
        let improved = match self.best {
            None => true,
            Some(best) => {
                if ctx.minimize {
                    score < best
                } else {
                    score > best
                }
            }
        };
        if improved {
            self.best = Some(score);
            self.best_round = Some(record.round);
            self.stale = 0;
        } else {
            self.stale += 1;
            if self.stale >= self.rounds {
                return Ok(CallbackAction::Stop);
            }
        }
        Ok(CallbackAction::Continue)
    }
}

/// Print one `[round] train-metric:… valid-metric:…` line per evaluation
/// to stderr — the callback form of the legacy `verbose` flag.
pub struct EvalLogger;

impl Callback for EvalLogger {
    fn on_eval(&mut self, _ctx: &RoundContext, record: &EvalRecord) -> Result<CallbackAction> {
        eprintln!(
            "[{}] train-{}:{:.5}{}",
            record.round,
            record.metric,
            record.train,
            record
                .valid
                .map(|v| format!(" valid-{}:{v:.5}", record.metric))
                .unwrap_or_default()
        );
        Ok(CallbackAction::Continue)
    }
}

/// Machine-readable round-level training telemetry (ROADMAP item 5,
/// lite): one record per evaluation appended to a file — the CLI's
/// `--log-file` flag. Format follows the extension: `.json` / `.jsonl`
/// emit one JSON object per line, anything else CSV with a header.
/// Fields per record: `round`, `metric`, `train`, `valid` (empty/`null`
/// when training without a validation set), `elapsed_secs` (wall clock
/// since training began). Combine with `eval_every 1` for a full
/// per-round trace; the file is truncated at `on_train_begin`, so one
/// logger instance reused across `train` calls keeps only the last run.
pub struct RecordLogger {
    path: std::path::PathBuf,
    json: bool,
    file: Option<std::fs::File>,
}

impl RecordLogger {
    /// Log records to `path` (created/truncated when training starts,
    /// so constructing the logger never touches the filesystem).
    pub fn new(path: impl Into<std::path::PathBuf>) -> Self {
        let path = path.into();
        let json = matches!(
            path.extension().and_then(|e| e.to_str()),
            Some("json") | Some("jsonl")
        );
        RecordLogger {
            path,
            json,
            file: None,
        }
    }
}

impl Callback for RecordLogger {
    fn on_train_begin(&mut self) -> Result<()> {
        use std::io::Write as _;
        let mut f = std::fs::File::create(&self.path)
            .with_context(|| format!("creating training log {}", self.path.display()))?;
        if !self.json {
            writeln!(f, "round,metric,train,valid,elapsed_secs")?;
        }
        self.file = Some(f);
        Ok(())
    }

    fn on_eval(&mut self, _ctx: &RoundContext, record: &EvalRecord) -> Result<CallbackAction> {
        use std::io::Write as _;
        if let Some(f) = self.file.as_mut() {
            if self.json {
                writeln!(
                    f,
                    "{{\"round\":{},\"metric\":\"{}\",\"train\":{},\"valid\":{},\"elapsed_secs\":{:.3}}}",
                    record.round,
                    record.metric,
                    record.train,
                    record
                        .valid
                        .map(|v| v.to_string())
                        .unwrap_or_else(|| "null".to_string()),
                    record.elapsed_secs
                )?;
            } else {
                writeln!(
                    f,
                    "{},{},{},{},{:.3}",
                    record.round,
                    record.metric,
                    record.train,
                    record.valid.map(|v| v.to_string()).unwrap_or_default(),
                    record.elapsed_secs
                )?;
            }
        }
        Ok(CallbackAction::Continue)
    }

    fn on_train_end(&mut self, _history: &[EvalRecord]) -> Result<()> {
        use std::io::Write as _;
        if let Some(mut f) = self.file.take() {
            f.flush()
                .with_context(|| format!("flushing training log {}", self.path.display()))?;
        }
        Ok(())
    }
}

/// Stop training once the wall clock exceeds a budget. The round in
/// flight completes, so the produced ensemble is always usable.
pub struct TimeBudget {
    budget_secs: f64,
}

impl TimeBudget {
    pub fn new(budget_secs: f64) -> Self {
        TimeBudget { budget_secs }
    }
}

impl Callback for TimeBudget {
    fn on_round_end(&mut self, ctx: &RoundContext) -> Result<CallbackAction> {
        if ctx.elapsed_secs >= self.budget_secs {
            Ok(CallbackAction::Stop)
        } else {
            Ok(CallbackAction::Continue)
        }
    }
}

/// A validated training configuration plus its callbacks — the typed
/// front door to the Figure-1 pipeline.
pub struct Learner {
    params: LearnerParams,
    callbacks: Vec<Box<dyn Callback>>,
}

impl Learner {
    /// Start a fluent configuration.
    pub fn builder() -> LearnerBuilder {
        LearnerBuilder::new()
    }

    /// Wrap already-typed params, running the full validation matrix.
    pub fn from_params(params: LearnerParams) -> Result<Self, ValidationErrors> {
        params.validate()?;
        Ok(Learner {
            params,
            callbacks: Vec::new(),
        })
    }

    pub fn params(&self) -> &LearnerParams {
        &self.params
    }

    /// Attach a callback (chaining form).
    pub fn with_callback(mut self, callback: Box<dyn Callback>) -> Self {
        self.callbacks.push(callback);
        self
    }

    /// Attach a callback.
    pub fn add_callback(&mut self, callback: Box<dyn Callback>) -> &mut Self {
        self.callbacks.push(callback);
        self
    }

    /// Train with the native histogram backend.
    pub fn train(&mut self, train: &Dataset, valid: Option<&Dataset>) -> Result<Booster> {
        self.train_with_backend(train, valid, Box::new(NativeBackend::default()))
    }

    /// Train with an explicit histogram backend (e.g. the XLA runtime).
    pub fn train_with_backend(
        &mut self,
        train: &Dataset,
        valid: Option<&Dataset>,
        backend: Box<dyn HistBackend>,
    ) -> Result<Booster> {
        let t0 = Instant::now();
        let params = self.params.clone();

        // dataset-dependent validation that build() could not see
        params
            .monotone_constraints
            .check_n_features(train.x.n_cols())
            .map_err(|e: String| anyhow::anyhow!(e))?;

        let coordinator = MultiDeviceCoordinator::with_backend(
            &train.x,
            params.coordinator_params(),
            backend,
        )?;
        self.boost(params, coordinator, train, valid, t0, None)
    }

    /// **Out-of-core training**: ingest a [`BatchSource`] through the
    /// two-pass streaming pipeline (sketch → quantise+pack per batch; see
    /// [`crate::data::source`]) and run the boosting loop against the
    /// shards it built — the full float matrix never materializes.
    ///
    /// The trained model, its predictions and every recorded metric are
    /// **bit-identical** to [`train`](Self::train) on the equivalent
    /// in-memory dataset, for every batch size and thread count
    /// (`rust/tests/streaming_ingest.rs`). There is no shuffled holdout in
    /// this mode — pass an explicit `valid` dataset for evaluation.
    pub fn train_from_source(
        &mut self,
        src: &mut dyn BatchSource,
        valid: Option<&Dataset>,
    ) -> Result<Booster> {
        self.train_from_source_with_backend(src, valid, Box::new(NativeBackend::default()))
    }

    /// [`train_from_source`](Self::train_from_source) with an explicit
    /// histogram backend.
    pub fn train_from_source_with_backend(
        &mut self,
        src: &mut dyn BatchSource,
        valid: Option<&Dataset>,
        backend: Box<dyn HistBackend>,
    ) -> Result<Booster> {
        let t0 = Instant::now();
        let params = self.params.clone();
        let (coordinator, mut meta) = MultiDeviceCoordinator::from_source_with_backend(
            src,
            params.coordinator_params(),
            backend,
        )?;
        // feature count is only known after pass 1 on a true stream
        params
            .monotone_constraints
            .check_n_features(meta.n_cols)
            .map_err(|e: String| anyhow::anyhow!(e))?;
        let train = meta.take_label_dataset();
        self.boost(params, coordinator, &train, valid, t0, None)
    }

    /// **Training continuation**: boost `self.params.num_rounds` *further*
    /// rounds on top of an existing (possibly serialized-and-reloaded)
    /// booster. The prior model's frozen [`crate::quantile::HistogramCuts`]
    /// are reused verbatim — `train` is quantised against the *original*
    /// grid, never re-sketched — so the continued trees split on exactly
    /// the bins the prior run saw. Objective (with its shaping params) and
    /// `max_bins` must match the prior's persisted params; mismatches are
    /// rejected before any work happens.
    ///
    /// Bit-parity contract: `train(a+b rounds)` ==
    /// `train(a)` → serialize → reload → `resume(b)` — identical trees,
    /// margins and eval records, for every thread and device count
    /// (`rust/tests/scenarios.rs`).
    pub fn resume(
        &mut self,
        prior: &Booster,
        train: &Dataset,
        valid: Option<&Dataset>,
    ) -> Result<Booster> {
        self.resume_with_backend(prior, train, valid, Box::new(NativeBackend::default()))
    }

    /// [`resume`](Self::resume) with an explicit histogram backend.
    pub fn resume_with_backend(
        &mut self,
        prior: &Booster,
        train: &Dataset,
        valid: Option<&Dataset>,
        backend: Box<dyn HistBackend>,
    ) -> Result<Booster> {
        let t0 = Instant::now();
        let params = self.params.clone();
        params
            .monotone_constraints
            .check_n_features(train.x.n_cols())
            .map_err(|e: String| anyhow::anyhow!(e))?;
        let cuts = self.check_resume(prior)?;
        let coordinator = MultiDeviceCoordinator::with_cuts(
            &train.x,
            params.coordinator_params(),
            cuts,
            backend,
        )?;
        self.boost(params, coordinator, train, valid, t0, Some(prior))
    }

    /// [`resume`](Self::resume) over a streamed [`BatchSource`]: pass 1
    /// only scans labels/widths (no sketching — the cuts are frozen), pass
    /// 2 quantises against the prior's grid. Bit-identical to the
    /// in-memory resume for every batch size.
    pub fn resume_from_source(
        &mut self,
        prior: &Booster,
        src: &mut dyn BatchSource,
        valid: Option<&Dataset>,
    ) -> Result<Booster> {
        self.resume_from_source_with_backend(prior, src, valid, Box::new(NativeBackend::default()))
    }

    /// [`resume_from_source`](Self::resume_from_source) with an explicit
    /// histogram backend.
    pub fn resume_from_source_with_backend(
        &mut self,
        prior: &Booster,
        src: &mut dyn BatchSource,
        valid: Option<&Dataset>,
        backend: Box<dyn HistBackend>,
    ) -> Result<Booster> {
        let t0 = Instant::now();
        let params = self.params.clone();
        let cuts = self.check_resume(prior)?;
        let (coordinator, mut meta) = MultiDeviceCoordinator::from_source_with_cuts(
            src,
            params.coordinator_params(),
            cuts,
            backend,
        )?;
        params
            .monotone_constraints
            .check_n_features(meta.n_cols)
            .map_err(|e: String| anyhow::anyhow!(e))?;
        let train = meta.take_label_dataset();
        self.boost(params, coordinator, &train, valid, t0, Some(prior))
    }

    /// Validate that this learner's params are compatible with continuing
    /// `prior`, and hand back the frozen quantisation grid to reuse.
    fn check_resume(&self, prior: &Booster) -> Result<crate::quantile::HistogramCuts> {
        anyhow::ensure!(
            self.params.objective == prior.params.objective,
            "resume objective {:?} does not match the prior model's {:?}",
            self.params.objective,
            prior.params.objective
        );
        anyhow::ensure!(
            self.params.objective_params() == prior.params.objective_params(),
            "resume objective parameters (num_class / quantile_alpha / \
             tweedie_variance_power / aft_distribution / aft_sigma) do not \
             match the prior model's"
        );
        anyhow::ensure!(
            self.params.max_bins == prior.params.max_bins,
            "resume max_bins {} does not match the prior model's {} — the \
             frozen cuts were sketched at the original resolution",
            self.params.max_bins,
            prior.params.max_bins
        );
        let cuts = prior.cuts.as_ref().context(
            "prior booster carries no quantisation cuts — resume needs the \
             frozen grid (serialized models persist it)",
        )?;
        Ok(cuts.clone())
    }

    /// The Figure-1 boosting loop over an already-constructed coordinator.
    /// `train` supplies labels/groups for gradients and metrics; its
    /// feature matrix is only touched by validation-free paths (the
    /// streamed label dataset carries none). With `prior`, the loop
    /// *continues* that model: margins are rebuilt from its trees over the
    /// (re-quantised) training shards, the subsample/colsample rng streams
    /// fast-forward past the rounds it already consumed, and round
    /// numbering carries on from its last round — so `train(5)` →
    /// serialize → reload → `resume(5)` is bit-identical to `train(10)`.
    fn boost(
        &mut self,
        params: LearnerParams,
        mut coordinator: MultiDeviceCoordinator,
        train: &Dataset,
        valid: Option<&Dataset>,
        t0: Instant,
        prior: Option<&Booster>,
    ) -> Result<Booster> {
        let op = params.objective_params();
        let objective = ObjectiveRegistry::create_with(params.objective.name(), &op)
            .context("resolving objective")?;
        let k = objective.n_outputs();
        let metric: Box<dyn Metric> = match &params.eval_metric {
            Some(kind) => {
                MetricRegistry::create_for(kind.name(), &op).context("resolving eval_metric")?
            }
            None => MetricRegistry::create_for(objective.default_metric(), &op)
                .context("resolving the objective's default metric")?,
        };
        let minimize = metric.minimize();

        // params-driven implicit callbacks keep legacy behaviour intact
        let mut implicit: Vec<Box<dyn Callback>> = Vec::new();
        if params.verbose {
            implicit.push(Box::new(EvalLogger));
        }
        if params.early_stopping_rounds > 0 {
            implicit.push(Box::new(EarlyStopping::new(params.early_stopping_rounds)));
        }

        // one thread budget for every phase of the round: gradient
        // computation, tree construction and incremental validation
        // scoring (results are thread-count-invariant — see crate::exec)
        let exec = ExecContext::new(params.threads);

        let mut build_stats = BuildStats::default();
        // continuation keeps the prior's base score: it was fit on the
        // original objective/labels, and re-deriving it from the resume
        // data would shift every margin and break `train(a+b)` parity
        let base_score = match prior {
            Some(b) => b.base_score.clone(),
            None => objective.base_score(train),
        };
        let n = train.n_rows();
        let mut margins: Vec<Vec<Float>> = match prior {
            // rebuild training margins by traversing the prior trees over
            // the (re-quantised) shards — the same leaf values are summed
            // in the same tree order as the original run's accumulated
            // deltas, so the f32 addition sequence (and thus every
            // continued gradient) is bit-identical
            Some(b) => {
                let (m, s) = coordinator.predict_margins(&b.trees, &base_score)?;
                build_stats.accumulate(&s);
                m
            }
            None => base_score.iter().map(|&b| vec![b; n]).collect(),
        };
        let mut valid_margins: Option<Vec<Vec<Float>>> =
            valid.map(|v| base_score.iter().map(|&b| vec![b; v.n_rows()]).collect());
        // in-training eval runs on the compressed path: the validation
        // set is quantised ONCE against the frozen training cuts
        // (unclamped transient form, so even values outside the training
        // range route exactly as the float traversal would — see
        // crate::predict::quantised) and every new tree is translated to
        // bin-threshold form and accumulated over it. Bit-identical to
        // the old float-matrix scoring; the float valid matrix is no
        // longer touched after this point. Deliberate trade-off: the u32
        // form is an extra O(valid_rows × n_cols) held for the run (the
        // caller's float matrix stays alive regardless) — exactness over
        // memory; a bit-packed valid form would clamp out-of-range
        // values and break parity with float scoring.
        let quantised_valid: Option<QuantisedBatch> = match valid {
            Some(v) => Some(QuantisedBatch::from_dmatrix(&v.x, &coordinator.cuts, 0)?),
            None => None,
        };
        // replay the prior trees into the valid margins exactly as the
        // original run accumulated them round by round: same bin-space
        // translation, same per-tree addition order
        if let Some(b) = prior {
            if let (Some(vm), Some(qv)) = (valid_margins.as_mut(), quantised_valid.as_ref()) {
                for (c, group) in b.trees.iter().enumerate() {
                    for t in group {
                        let bt = quantised::BinTree::from_tree(t, &coordinator.cuts);
                        quantised::accumulate_bin_tree_par(&bt, qv, &mut vm[c], &exec);
                    }
                }
            }
        }

        // the continued ensemble extends the prior's trees in place
        let mut trees: Vec<Vec<RegTree>> = match prior {
            Some(b) => b.trees.clone(),
            None => vec![Vec::new(); k],
        };
        let offset = prior.map(|b| b.n_rounds()).unwrap_or(0);
        let mut eval_history: Vec<EvalRecord> = Vec::new();

        for cb in self.callbacks.iter_mut().chain(implicit.iter_mut()) {
            cb.on_train_begin()?;
        }

        let mut sub_rng = crate::util::Pcg64::new(params.seed ^ 0x5b5a);
        // fast-forward the shared rng streams past the rounds the prior
        // run consumed, so round `offset + r` here draws exactly what
        // round `offset + r` of an uninterrupted run would have drawn
        if params.subsample < 1.0 {
            for _ in 0..offset * n {
                sub_rng.next_f64();
            }
        }
        coordinator.skip_column_samples(offset * k);
        // round-arena out-param: the gradient buffers live outside the
        // round loop and are rewritten in place every round — after the
        // warm-up round the gradient phase allocates nothing
        let mut grads: Vec<Vec<crate::GradPair>> = Vec::new();
        for round in 0..params.num_rounds {
            objective.gradients_par_into(train, &margins, &exec, &mut grads);
            if params.subsample < 1.0 {
                // exclude unsampled rows from this round's trees by zeroing
                // their gradient mass (same rows for all k outputs)
                for i in 0..n {
                    if sub_rng.next_f64() >= params.subsample {
                        for class_grads in grads.iter_mut() {
                            class_grads[i] = crate::GradPair::default();
                        }
                    }
                }
            }
            for (c, class_grads) in grads.iter().enumerate().take(k) {
                let result = coordinator.build_tree(class_grads)?;
                for (m, d) in margins[c].iter_mut().zip(result.deltas.iter()) {
                    *m += *d;
                }
                if let (Some(vm), Some(qv)) = (valid_margins.as_mut(), quantised_valid.as_ref()) {
                    let t = Instant::now();
                    let bt = quantised::BinTree::from_tree(&result.tree, &coordinator.cuts);
                    quantised::accumulate_bin_tree_par(&bt, qv, &mut vm[c], &exec);
                    build_stats.predict_wall_secs += t.elapsed().as_secs_f64();
                }
                build_stats.accumulate(&result.stats);
                trees[c].push(result.tree);
                // spent delta buffer goes back to the coordinator's arena
                coordinator.recycle_deltas(result.deltas);
            }

            let mut stop = false;
            // round numbering (records, callbacks, eval cadence) runs in
            // the global frame: continuation round r is `offset + r + 1`,
            // so a resumed history lines up with the uninterrupted one
            let gr = offset + round + 1;
            let do_eval = params.eval_every > 0 && gr % params.eval_every == 0;
            if do_eval || round + 1 == params.num_rounds {
                let train_score = metric.eval(train, &objective.transform(&margins));
                let valid_score = valid_margins
                    .as_ref()
                    .zip(valid)
                    .map(|(vm, v)| metric.eval(v, &objective.transform(vm)));
                eval_history.push(EvalRecord {
                    round: gr,
                    metric: metric.name(),
                    train: train_score,
                    valid: valid_score,
                    elapsed_secs: t0.elapsed().as_secs_f64(),
                });
                let record = eval_history.last().unwrap().clone();
                let ctx = RoundContext {
                    round: gr,
                    num_rounds: offset + params.num_rounds,
                    elapsed_secs: t0.elapsed().as_secs_f64(),
                    history: &eval_history,
                    minimize,
                };
                for cb in self.callbacks.iter_mut().chain(implicit.iter_mut()) {
                    if cb.on_eval(&ctx, &record)? == CallbackAction::Stop {
                        stop = true;
                    }
                }
            }

            let ctx = RoundContext {
                round: gr,
                num_rounds: offset + params.num_rounds,
                elapsed_secs: t0.elapsed().as_secs_f64(),
                history: &eval_history,
                minimize,
            };
            for cb in self.callbacks.iter_mut().chain(implicit.iter_mut()) {
                if cb.on_round_end(&ctx)? == CallbackAction::Stop {
                    stop = true;
                }
            }
            if stop {
                break;
            }
        }

        for cb in self.callbacks.iter_mut().chain(implicit.iter_mut()) {
            cb.on_train_end(&eval_history)?;
        }

        let simulated_secs = build_stats.simulated_secs;
        Ok(Booster {
            params,
            objective,
            base_score,
            trees,
            // the frozen quantisation cuts travel with the model so
            // prediction/eval can run from the compressed representation
            // (streaming or paged) without re-sketching
            cuts: Some(coordinator.cuts.clone()),
            eval_history,
            build_stats,
            train_secs: t0.elapsed().as_secs_f64(),
            simulated_secs,
        })
    }
}

/// Fluent, validating constructor for [`Learner`].
///
/// Setters are typed; [`LearnerBuilder::set`] additionally accepts
/// `key`/`value` strings (the CLI/config surface) and records parse
/// failures. [`build`](LearnerBuilder::build) then reports **all**
/// problems — parse failures and cross-field violations — in one
/// [`ValidationErrors`].
#[derive(Default)]
pub struct LearnerBuilder {
    params: LearnerParams,
    callbacks: Vec<Box<dyn Callback>>,
    n_features: Option<usize>,
    parse_errors: Vec<String>,
}

macro_rules! setter {
    ($(#[$doc:meta])* $name:ident: $ty:ty) => {
        $(#[$doc])*
        pub fn $name(mut self, value: $ty) -> Self {
            self.params.$name = value;
            self
        }
    };
}

impl LearnerBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    setter!(objective: ObjectiveKind);
    setter!(num_class: usize);
    setter!(num_rounds: usize);
    setter!(eta: f64);
    setter!(max_depth: usize);
    setter!(max_leaves: usize);
    setter!(max_bins: usize);
    setter!(lambda: f64);
    setter!(gamma: f64);
    setter!(alpha: f64);
    setter!(min_child_weight: f64);
    setter!(grow_policy: GrowPolicy);
    setter!(n_devices: usize);
    setter!(compress: bool);
    setter!(allreduce: AllReduce);
    setter!(eval_every: usize);
    setter!(early_stopping_rounds: usize);
    setter!(subsample: f64);
    setter!(colsample_bytree: f64);
    setter!(monotone_constraints: MonotoneConstraints);
    setter!(seed: u64);
    setter!(verbose: bool);
    setter!(
        /// Target quantile for `reg:quantile` (pinball loss level α).
        quantile_alpha: f64
    );
    setter!(
        /// Tweedie variance power ρ ∈ (1, 2) for `reg:tweedie`.
        tweedie_variance_power: f64
    );
    setter!(
        /// Error distribution for `survival:aft` (normal | logistic).
        aft_distribution: AftDistribution
    );
    setter!(
        /// AFT scale parameter σ > 0.
        aft_sigma: f64
    );
    setter!(
        /// Feature indices treated as categorical (codes quantise to one
        /// bin per category; splits are membership bitsets).
        categorical_features: Vec<usize>
    );
    setter!(
        /// Worker threads for the parallel engine (`0` = all cores, `1` =
        /// serial). Changes wall-clock only; results are bit-identical.
        threads: usize
    );
    setter!(
        /// Rows per batch for streaming ingestion
        /// ([`Learner::train_from_source`]). Bounds peak transient memory;
        /// results are bit-identical for every value.
        batch_rows: usize
    );
    setter!(
        /// External-memory budget: packed pages each device shard keeps
        /// resident (`0` = fully resident). With a budget, shards spill
        /// sealed pages to disk and histogram rounds stream them back
        /// with async prefetch. Requires `compress`; results are
        /// bit-identical for every budget and page size.
        max_resident_pages: usize
    );
    setter!(
        /// Rows per sealed page when spilling (external-memory page
        /// size). Results are bit-identical for every value.
        page_rows: usize
    );
    setter!(
        /// This process's rank in a distributed run; inert while
        /// [`dist_peers`](Self::dist_peers) is empty.
        dist_rank: usize
    );
    setter!(
        /// `host:port` listen addresses of every rank, in rank order.
        /// Non-empty engages the real TCP ring all-reduce: this process
        /// builds only rank `dist_rank`'s device histograms and merges
        /// over the wire, bit-identical to a single-process run with
        /// `n_devices == dist_peers.len()`.
        dist_peers: Vec<String>
    );
    setter!(
        /// Wire encoding for distributed histogram chunks (`Quant` packs
        /// losslessly through `compress/`, `Raw` ships plain f64 bytes).
        dist_payload: WirePayload
    );

    /// Evaluation metric (`None`/unset = the objective's default).
    pub fn eval_metric(mut self, metric: MetricKind) -> Self {
        self.params.eval_metric = Some(metric);
        self
    }

    /// Declare the feature count so constraints can be checked at
    /// `build()` instead of first touching data at train time.
    pub fn n_features(mut self, n: usize) -> Self {
        self.n_features = Some(n);
        self
    }

    /// Attach a training callback.
    pub fn callback(mut self, callback: Box<dyn Callback>) -> Self {
        self.callbacks.push(callback);
        self
    }

    /// String-typed setter for the CLI/config surface. Unknown keys and
    /// unparsable values are recorded and reported by `build()`.
    pub fn set(mut self, key: &str, value: &str) -> Self {
        let mut err = |msg: String| self.parse_errors.push(msg);
        macro_rules! parse_into {
            ($field:ident) => {
                match value.parse() {
                    Ok(v) => self.params.$field = v,
                    Err(_) => err(format!(
                        "{key}: cannot parse {value:?} as {}",
                        stringify!($field)
                    )),
                }
            };
        }
        match key {
            "objective" => self.params.objective = value.parse().expect("infallible"),
            "eval_metric" => {
                self.params.eval_metric = if value.is_empty() {
                    None
                } else {
                    Some(value.parse().expect("infallible"))
                }
            }
            "grow_policy" => match value.parse() {
                Ok(v) => self.params.grow_policy = v,
                Err(e) => err(e),
            },
            "allreduce" => match value.parse() {
                Ok(v) => self.params.allreduce = v,
                Err(e) => err(e),
            },
            "monotone_constraints" => match value.parse() {
                Ok(v) => self.params.monotone_constraints = v,
                Err(e) => err(e),
            },
            "num_class" => parse_into!(num_class),
            "num_rounds" => parse_into!(num_rounds),
            "eta" => parse_into!(eta),
            "max_depth" => parse_into!(max_depth),
            "max_leaves" => parse_into!(max_leaves),
            "max_bins" => parse_into!(max_bins),
            "lambda" => parse_into!(lambda),
            "gamma" => parse_into!(gamma),
            "alpha" => parse_into!(alpha),
            "min_child_weight" => parse_into!(min_child_weight),
            "n_devices" => parse_into!(n_devices),
            "compress" => parse_into!(compress),
            "eval_every" => parse_into!(eval_every),
            "early_stopping_rounds" => parse_into!(early_stopping_rounds),
            "subsample" => parse_into!(subsample),
            "colsample_bytree" => parse_into!(colsample_bytree),
            "seed" => parse_into!(seed),
            "verbose" => parse_into!(verbose),
            "threads" => parse_into!(threads),
            "batch_rows" => parse_into!(batch_rows),
            "max_resident_pages" => parse_into!(max_resident_pages),
            "page_rows" => parse_into!(page_rows),
            "dist_rank" => parse_into!(dist_rank),
            "dist_peers" => {
                self.params.dist_peers = if value.is_empty() {
                    Vec::new()
                } else {
                    value.split(',').map(|p| p.trim().to_string()).collect()
                }
            }
            "dist_payload" => match value.parse() {
                Ok(v) => self.params.dist_payload = v,
                Err(e) => err(e),
            },
            "quantile_alpha" => parse_into!(quantile_alpha),
            "tweedie_variance_power" => parse_into!(tweedie_variance_power),
            "aft_sigma" => parse_into!(aft_sigma),
            "aft_distribution" => match value.parse() {
                Ok(v) => self.params.aft_distribution = v,
                Err(e) => err(e),
            },
            "categorical" | "categorical_features" => {
                match crate::gbm::params::parse_feature_list(value) {
                    Ok(v) => self.params.categorical_features = v,
                    Err(e) => err(format!("{e:#}")),
                }
            }
            other => err(format!("unknown parameter {other:?}")),
        }
        self
    }

    /// Replace the parameters with the ones read from a [`Config`]
    /// (defaults for absent keys; unrelated keys ignored, matching the
    /// merged CLI flow). Call *before* typed setters — this overwrites
    /// every field.
    pub fn apply_config(mut self, cfg: &Config) -> Self {
        match LearnerParams::from_config(cfg) {
            Ok(params) => self.params = params,
            Err(e) => self.parse_errors.push(format!("{e:#}")),
        }
        self
    }

    /// Validate everything and produce a [`Learner`]. Returns **all**
    /// accumulated problems, not just the first.
    pub fn build(self) -> Result<Learner, ValidationErrors> {
        let mut errs = self.parse_errors;
        errs.extend(self.params.validation_errors(self.n_features));
        if errs.is_empty() {
            Ok(Learner {
                params: self.params,
                callbacks: self.callbacks,
            })
        } else {
            Err(ValidationErrors(errs))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetSpec};

    fn quick(objective: ObjectiveKind, rounds: usize) -> LearnerParams {
        LearnerParams {
            objective,
            num_rounds: rounds,
            max_bins: 32,
            max_depth: 4,
            ..Default::default()
        }
    }

    #[test]
    fn builder_trains_binary_classifier() {
        let g = generate(&DatasetSpec::higgs_like(3000), 2);
        let mut learner = Learner::builder()
            .objective(ObjectiveKind::BinaryLogistic)
            .num_rounds(10)
            .max_bins(32)
            .max_depth(4)
            .build()
            .unwrap();
        let b = learner.train(&g.train, Some(&g.valid)).unwrap();
        let acc = b.eval_history.last().unwrap().valid.unwrap();
        assert!(acc > 60.0, "accuracy {acc}");
    }

    #[test]
    fn builder_collects_all_errors() {
        let err = Learner::builder()
            .objective(ObjectiveKind::MultiSoftmax) // missing num_class
            .eta(-1.0)
            .set("max_depth", "banana")
            .build()
            .unwrap_err();
        assert!(err.0.len() >= 3, "{err}");
    }

    #[test]
    fn set_accepts_string_surface() {
        let learner = Learner::builder()
            .set("objective", "binary:logistic")
            .set("num_rounds", "5")
            .set("eval_metric", "auc")
            .build()
            .unwrap();
        assert_eq!(learner.params().objective, ObjectiveKind::BinaryLogistic);
        assert_eq!(learner.params().num_rounds, 5);
        assert_eq!(learner.params().eval_metric, Some(MetricKind::Auc));
    }

    #[test]
    fn record_logger_writes_csv_and_jsonl_traces() {
        let g = generate(&DatasetSpec::higgs_like(1200), 11);
        let dir = std::env::temp_dir();
        let csv_path = dir.join(format!("xgb_tpu_recordlog_{}.csv", std::process::id()));
        let json_path = dir.join(format!("xgb_tpu_recordlog_{}.jsonl", std::process::id()));
        let mut p = quick(ObjectiveKind::BinaryLogistic, 4);
        p.eval_every = 1;
        let mut learner = Learner::from_params(p.clone())
            .unwrap()
            .with_callback(Box::new(RecordLogger::new(&csv_path)))
            .with_callback(Box::new(RecordLogger::new(&json_path)));
        learner.train(&g.train, Some(&g.valid)).unwrap();
        let csv = std::fs::read_to_string(&csv_path).unwrap();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "round,metric,train,valid,elapsed_secs");
        assert_eq!(lines.len(), 1 + 4, "one record per round:\n{csv}");
        let fields: Vec<&str> = lines[1].split(',').collect();
        assert_eq!(fields.len(), 5);
        assert_eq!(fields[0], "1");
        assert!(fields[2].parse::<f64>().is_ok(), "train metric parses");
        assert!(fields[3].parse::<f64>().is_ok(), "valid metric parses");
        let json = std::fs::read_to_string(&json_path).unwrap();
        assert_eq!(json.lines().count(), 4, "no header in jsonl:\n{json}");
        assert!(json.lines().next().unwrap().starts_with("{\"round\":1,"));
        // without a validation set the valid field is empty/null
        let mut learner2 = Learner::from_params(p)
            .unwrap()
            .with_callback(Box::new(RecordLogger::new(&csv_path)))
            .with_callback(Box::new(RecordLogger::new(&json_path)));
        learner2.train(&g.train, None).unwrap();
        let csv2 = std::fs::read_to_string(&csv_path).unwrap();
        assert!(csv2.lines().nth(1).unwrap().contains(",,"), "{csv2}");
        let json2 = std::fs::read_to_string(&json_path).unwrap();
        assert!(json2.contains("\"valid\":null"), "{json2}");
        std::fs::remove_file(&csv_path).ok();
        std::fs::remove_file(&json_path).ok();
    }

    #[test]
    fn early_stopping_callback_stops() {
        let g = generate(&DatasetSpec::higgs_like(1500), 6);
        let mut p = quick(ObjectiveKind::BinaryLogistic, 200);
        p.eta = 1.0; // aggressive -> quick overfit -> early stop
        let mut learner = Learner::from_params(p)
            .unwrap()
            .with_callback(Box::new(EarlyStopping::new(2)));
        let b = learner.train(&g.train, Some(&g.valid)).unwrap();
        assert!(b.n_rounds() < 200, "should stop early, ran {}", b.n_rounds());
    }

    #[test]
    fn time_budget_zero_stops_after_first_round() {
        let g = generate(&DatasetSpec::higgs_like(1000), 7);
        let mut learner = Learner::from_params(quick(ObjectiveKind::BinaryLogistic, 50))
            .unwrap()
            .with_callback(Box::new(TimeBudget::new(0.0)));
        let b = learner.train(&g.train, None).unwrap();
        assert_eq!(b.n_rounds(), 1);
    }

    #[test]
    fn monotone_longer_than_features_rejected_at_train() {
        let g = generate(&DatasetSpec::higgs_like(500), 8);
        let mut p = quick(ObjectiveKind::SquaredError, 2);
        p.monotone_constraints = "1,0,-1,1,0,-1,1,0,-1,1,0,-1,1,0,-1,1,0,-1,1,0,-1,1,0,-1,1,0,-1,1,0"
            .parse()
            .unwrap();
        assert_eq!(p.monotone_constraints.len(), 29); // higgs has 28 features
        let mut learner = Learner::from_params(p).unwrap();
        assert!(learner.train(&g.train, None).is_err());
    }

    #[test]
    fn builder_n_features_hint_checks_constraints() {
        let err = Learner::builder()
            .monotone_constraints("1,1,1".parse().unwrap())
            .n_features(2)
            .build()
            .unwrap_err();
        assert!(err.0[0].contains("monotone"), "{err}");
    }

    #[test]
    fn train_from_source_matches_in_memory() {
        // the full matrix covers batch sizes/threads; this is the smoke
        let g = generate(&DatasetSpec::higgs_like(800), 31);
        let p = quick(ObjectiveKind::BinaryLogistic, 4);
        let b_mem = Learner::from_params(p.clone())
            .unwrap()
            .train(&g.train, Some(&g.valid))
            .unwrap();
        let mut src = crate::data::source::DMatrixSource::from_dataset(&g.train, 64);
        let b_str = Learner::from_params(p)
            .unwrap()
            .train_from_source(&mut src, Some(&g.valid))
            .unwrap();
        assert_eq!(b_mem.trees, b_str.trees, "streamed trees must be bit-identical");
        assert_eq!(b_mem.base_score, b_str.base_score);
        for (a, b) in b_mem.eval_history.iter().zip(b_str.eval_history.iter()) {
            assert_eq!(a.train.to_bits(), b.train.to_bits(), "round {}", a.round);
            assert_eq!(
                a.valid.map(f64::to_bits),
                b.valid.map(f64::to_bits),
                "round {}",
                a.round
            );
        }
    }

    #[test]
    fn round_context_reports_history() {
        struct HistoryProbe {
            evals_seen: usize,
        }
        impl Callback for HistoryProbe {
            fn on_eval(
                &mut self,
                ctx: &RoundContext,
                record: &EvalRecord,
            ) -> Result<CallbackAction> {
                self.evals_seen += 1;
                assert_eq!(ctx.history.len(), self.evals_seen);
                assert_eq!(ctx.history.last().unwrap().round, record.round);
                Ok(CallbackAction::Continue)
            }
        }
        let g = generate(&DatasetSpec::higgs_like(800), 9);
        let mut learner = Learner::from_params(quick(ObjectiveKind::BinaryLogistic, 4))
            .unwrap()
            .with_callback(Box::new(HistoryProbe { evals_seen: 0 }));
        learner.train(&g.train, Some(&g.valid)).unwrap();
    }
}
