//! Feature importance over a trained ensemble — the three standard
//! XGBoost flavours: total gain, total cover, and split count (weight).

use std::collections::BTreeMap;

use crate::gbm::Booster;
use crate::tree::RegTree;

/// Importance flavour.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ImportanceKind {
    /// Sum of loss reduction over all splits on the feature.
    Gain,
    /// Sum of hessian cover over all splits on the feature.
    Cover,
    /// Number of splits on the feature.
    Weight,
}

impl std::str::FromStr for ImportanceKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "gain" => Ok(ImportanceKind::Gain),
            "cover" => Ok(ImportanceKind::Cover),
            "weight" | "frequency" => Ok(ImportanceKind::Weight),
            other => Err(format!("unknown importance kind {other:?}")),
        }
    }
}

/// Accumulate importance from a set of trees.
pub fn tree_importance(trees: &[RegTree], kind: ImportanceKind) -> BTreeMap<u32, f64> {
    let mut out: BTreeMap<u32, f64> = BTreeMap::new();
    for tree in trees {
        for node in &tree.nodes {
            if !node.is_leaf() {
                let v = match kind {
                    ImportanceKind::Gain => node.gain as f64,
                    ImportanceKind::Cover => node.cover as f64,
                    ImportanceKind::Weight => 1.0,
                };
                *out.entry(node.feature).or_insert(0.0) += v;
            }
        }
    }
    out
}

/// Importance over all output groups of a booster, sorted descending.
pub fn feature_importance(booster: &Booster, kind: ImportanceKind) -> Vec<(u32, f64)> {
    let mut map: BTreeMap<u32, f64> = BTreeMap::new();
    for group in &booster.trees {
        for (f, v) in tree_importance(group, kind) {
            *map.entry(f).or_insert(0.0) += v;
        }
    }
    let mut v: Vec<(u32, f64)> = map.into_iter().collect();
    v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetSpec};
    use crate::data::{DMatrix, Dataset};
    use crate::gbm::{Learner, LearnerParams, ObjectiveKind};
    use crate::Float;

    #[test]
    fn counts_and_sums_per_feature() {
        let mut t = RegTree::new_root(0.0, 10.0);
        let (l, _r) = t.apply_split(0, 3, 0.5, true, 2.0, 0.0, 5.0, 0.0, 5.0);
        t.apply_split(l, 1, 0.2, true, 1.0, 0.0, 2.0, 0.0, 3.0);
        let gain = tree_importance(&[t.clone()], ImportanceKind::Gain);
        assert_eq!(gain[&3], 2.0);
        assert_eq!(gain[&1], 1.0);
        let weight = tree_importance(&[t.clone(), t.clone()], ImportanceKind::Weight);
        assert_eq!(weight[&3], 2.0);
        let cover = tree_importance(&[t], ImportanceKind::Cover);
        assert_eq!(cover[&3], 10.0);
        assert_eq!(cover[&1], 5.0);
    }

    #[test]
    fn informative_feature_ranks_first() {
        // y depends only on feature 2; importance must rank it top
        let n = 3000;
        let mut rng = crate::util::Pcg64::new(5);
        let mut vals = vec![0.0 as Float; n * 5];
        let mut y = vec![0.0 as Float; n];
        for r in 0..n {
            for c in 0..5 {
                vals[r * 5 + c] = rng.next_f32();
            }
            y[r] = f32::from(vals[r * 5 + 2] > 0.5);
        }
        let ds = Dataset::new(DMatrix::dense(vals, n, 5), y);
        let params = LearnerParams {
            objective: ObjectiveKind::BinaryLogistic,
            num_rounds: 5,
            max_depth: 3,
            max_bins: 16,
            eval_every: 0,
            ..Default::default()
        };
        let b = Learner::from_params(params).unwrap().train(&ds, None).unwrap();
        for kind in [ImportanceKind::Gain, ImportanceKind::Cover, ImportanceKind::Weight] {
            let imp = feature_importance(&b, kind);
            assert_eq!(imp[0].0, 2, "{kind:?}: {imp:?}");
        }
    }

    #[test]
    fn multiclass_aggregates_groups() {
        let g = generate(&DatasetSpec::covtype_like(1500), 3);
        let params = LearnerParams {
            objective: ObjectiveKind::MultiSoftmax,
            num_class: 7,
            num_rounds: 2,
            max_depth: 3,
            max_bins: 16,
            eval_every: 0,
            ..Default::default()
        };
        let b = Learner::from_params(params)
            .unwrap()
            .train(&g.train, None)
            .unwrap();
        let imp = feature_importance(&b, ImportanceKind::Weight);
        assert!(!imp.is_empty());
        let total: f64 = imp.iter().map(|(_, v)| v).sum();
        let splits: usize = b
            .trees
            .iter()
            .flatten()
            .map(|t| t.n_nodes() - t.n_leaves())
            .sum();
        assert_eq!(total as usize, splits);
    }

    #[test]
    fn kind_parsing() {
        assert_eq!("gain".parse::<ImportanceKind>().unwrap(), ImportanceKind::Gain);
        assert_eq!("frequency".parse::<ImportanceKind>().unwrap(), ImportanceKind::Weight);
        assert!("x".parse::<ImportanceKind>().is_err());
    }
}
