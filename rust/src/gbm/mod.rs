//! Gradient boosting framework: the typed [`Learner`] front door
//! (builder-validated params, pluggable objective/metric registries,
//! training callbacks), objectives (paper §2.5), evaluation metrics, and
//! the trained [`Booster`] that ties quantisation, compression,
//! multi-device tree construction and prediction into the Figure 1
//! pipeline.

pub mod booster;
pub mod cv;
pub mod importance;
pub mod learner;
pub mod metric;
pub mod objective;
pub mod params;
pub mod registry;
pub mod serialize;

pub use booster::{Booster, BoosterParams, EvalRecord};
pub use cv::{cross_validate, CvResult};
pub use importance::{feature_importance, ImportanceKind};
pub use learner::{
    Callback, CallbackAction, EarlyStopping, EvalLogger, Learner, LearnerBuilder, RecordLogger,
    RoundContext, TimeBudget,
};
pub use metric::{metric_by_name, Metric};
pub use objective::{objective_by_name, Objective};
pub use params::{
    AftDistribution, AllReduce, GrowPolicy, LearnerParams, MetricKind, MonotoneConstraints,
    ObjectiveKind, ObjectiveParams, ValidationErrors,
};
pub use registry::{MetricRegistry, ObjectiveRegistry};
pub use serialize::{
    load_model, load_model_file, load_servable_model_file, save_model, save_model_file,
};
