//! Gradient boosting framework: objectives (paper §2.5), evaluation
//! metrics, and the boosting driver that ties quantisation, compression,
//! multi-device tree construction and prediction into the Figure 1
//! pipeline.

pub mod booster;
pub mod cv;
pub mod importance;
pub mod metric;
pub mod objective;
pub mod serialize;

pub use booster::{Booster, BoosterParams, EvalRecord};
pub use cv::{cross_validate, CvResult};
pub use importance::{feature_importance, ImportanceKind};
pub use metric::{metric_by_name, Metric};
pub use objective::{objective_by_name, Objective};
pub use serialize::{load_model, load_model_file, save_model, save_model_file};
